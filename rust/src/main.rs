//! `conccl` — leader entrypoint / CLI for the C3 + ConCCL system.
//!
//! See `cli::HELP` (or `conccl help`) for the subcommand reference.

use conccl::cli::{Args, HELP};
use conccl::config::workload::CollectiveKind;
use conccl::coordinator::{headline, report, run_suite, taxonomy_divergences, RunnerConfig};
use conccl::heuristics::{self, SlowdownTable};
use conccl::kernels::CollectiveKernel;
use conccl::sched::{C3Executor, Strategy};
use conccl::sweep::{execute as execute_sweep, parse_variants, ChunkSel, MachineVariant, SweepPlan};
use conccl::util::table::{f as fnum, speedup, Table};
use conccl::util::units::{fmt_seconds, MIB};
use conccl::workload::e2e::{run_e2e, E2eFamily, E2eSpec};
use conccl::workload::llama::LlamaConfig;
use conccl::workload::scenarios::{resolve, resolve_tag, suite, TABLE2};
use conccl::workload::trace::{fsdp_forward_trace, replay};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<(), String> {
    match args.subcommand.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "characterize" => characterize(args),
        "run" => run_one(args),
        "sweep" => sweep_cmd(args),
        "bench-gate" => bench_gate(args),
        "rp-sweep" => rp_sweep(args),
        "report" => full_report(args),
        "conccl-bw" => conccl_bw(args),
        "heuristics" => heuristics_cmd(args),
        "e2e" => e2e(args),
        "graph" => graph_cmd(args),
        other => Err(format!("unknown subcommand '{other}'\n\n{HELP}")),
    }
}

fn parse_collective(s: &str) -> Result<CollectiveKind, String> {
    match s {
        "all-gather" | "ag" => Ok(CollectiveKind::AllGather),
        "all-to-all" | "a2a" => Ok(CollectiveKind::AllToAll),
        "all-reduce" | "ar" => Ok(CollectiveKind::AllReduce),
        "reduce-scatter" | "rs" => Ok(CollectiveKind::ReduceScatter),
        other => Err(format!("unknown collective '{other}'")),
    }
}

fn parse_strategy(s: &str, comm_need: u32) -> Result<Strategy, String> {
    Strategy::parse(s, comm_need).map_err(|e| e.to_string())
}

fn find_scenario(
    tag: &str,
    kind: CollectiveKind,
) -> Result<conccl::workload::ResolvedScenario, String> {
    resolve_tag(tag, kind).map_err(|e| e.to_string())
}

fn characterize(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    report::render_table1(&m).print();
    println!();
    report::render_table2(&m).print();
    println!();
    report::render_fig5a(&m, &[0, 8, 16, 32, 64, 96, 128]).print();
    println!();
    let sizes = [896 * MIB, 3328 * MIB, 13 * 1024 * MIB];
    report::render_fig5bc(&m, CollectiveKind::AllGather, &sizes, &[8, 16, 32, 64, 128]).print();
    println!();
    report::render_fig5bc(&m, CollectiveKind::AllToAll, &sizes, &[8, 16, 32, 64, 128]).print();
    println!();
    report::render_fig6(&m, &[896 * MIB, 3328 * MIB]).print();
    Ok(())
}

fn run_one(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let kind = parse_collective(&args.opt("collective", "all-gather"))?;
    let sc = find_scenario(&args.opt("scenario", "mb1_896M"), kind)?;
    let nodes = args.opt_usize("nodes", 1)?.max(1);
    let exec = C3Executor::with_topology(m.clone(), m.topology(nodes));
    let mut strat = parse_strategy(&args.opt("strategy", "conccl"), sc.comm.cu_need(&exec.m))?;
    // --chunks auto|N applies to the chunked pipeline strategies: auto
    // asks the runtime-style heuristic (heuristics::chunk) on the
    // paper's single node — the regime it is calibrated for — and the
    // topology-aware exhaustive chunk sweep on multi-node topologies
    // (the heuristic's rooflines know nothing about the NIC, where
    // chunking's win shrinks); a number pins the count (clamped to
    // what the scenario supports).
    let mut chunk_note = String::new();
    // The multi-node auto path already simulates every candidate; keep
    // its winning run instead of re-simulating the same point.
    let mut swept_run = None;
    if strat.is_chunked() {
        let dma = !strat.comm_on_cus();
        let k = match args.opt("chunks", "auto").as_str() {
            "auto" if nodes <= 1 => {
                let k = heuristics::recommend_chunks(&exec.m, &sc, dma);
                chunk_note = format!("{k} (auto-tuned)");
                k
            }
            "auto" => {
                let (run, k) = exec
                    .try_run_chunk_sweep_with(&sc, dma, exec.baselines(&sc))
                    .map_err(|e| e.to_string())?;
                chunk_note = format!("{k} (swept, {nodes}-node topology)");
                swept_run = Some(run);
                k
            }
            other => {
                let k: u32 = other.parse().map_err(|e| format!("--chunks: {e}"))?;
                if k == 0 {
                    return Err("--chunks: chunk count must be >= 1 (or 'auto')".into());
                }
                let k = exec.clamp_chunks(&sc, k);
                chunk_note = k.to_string();
                k
            }
        };
        strat = match strat {
            Strategy::C3Chunked { .. } => Strategy::C3Chunked { chunks: k },
            Strategy::ConcclChunked { .. } => Strategy::ConcclChunked { chunks: k },
            other => other,
        };
    } else if args.options.contains_key("chunks") {
        // Silently ignoring --chunks would misreport the measurement.
        return Err(format!(
            "--chunks applies to the chunked pipeline strategies \
             (c3_chunked, conccl_chunked), not '{}'",
            strat.name()
        ));
    }
    let r = match swept_run {
        Some(run) => run,
        None => exec.try_run(&sc, strat).map_err(|e| e.to_string())?,
    };
    let mut t = Table::new(vec!["metric", "value"]).left_cols(2).title(format!(
        "{} × {} under {} ({nodes} node(s))",
        sc.tag(),
        kind.name(),
        strat.name()
    ));
    if !chunk_note.is_empty() {
        t.row(vec!["chunks".to_string(), chunk_note]);
    }
    t.row(vec!["serial".to_string(), fmt_seconds(r.serial)]);
    t.row(vec!["concurrent".to_string(), fmt_seconds(r.total)]);
    t.row(vec!["gemm finish".to_string(), fmt_seconds(r.gemm_finish)]);
    t.row(vec!["comm finish".to_string(), fmt_seconds(r.comm_finish)]);
    t.row(vec!["ideal speedup".to_string(), speedup(r.ideal)]);
    t.row(vec!["attained speedup".to_string(), speedup(r.speedup)]);
    t.row(vec!["% of ideal".to_string(), fnum(r.pct_ideal, 1)]);
    t.print();
    Ok(())
}

/// The parallel scenario-sweep engine: {scenarios × strategies ×
/// machine configs} evaluated concurrently, reported as tables + JSON.
fn sweep_cmd(args: &Args) -> Result<(), String> {
    // The pre-rename `sweep` took --scenario/--strategy (singular);
    // silently ignoring those would run a completely different
    // computation, so reject them loudly.
    if args.options.contains_key("scenario") {
        return Err(
            "`sweep` takes --scenarios (plural, comma-separated); for the single-scenario \
             CU-reservation sweep use `conccl rp-sweep --scenario ...`"
                .into(),
        );
    }
    if args.options.contains_key("strategy") {
        return Err("`sweep` takes --strategies (plural, comma-separated)".into());
    }
    let m = args.machine()?;
    let jitter: f64 = args
        .opt("jitter", "0")
        .parse()
        .map_err(|e| format!("--jitter: {e}"))?;
    let seed: u64 = args
        .opt("seed", "24301")
        .parse()
        .map_err(|e| format!("--seed: {e}"))?;
    let cfg = RunnerConfig {
        jitter,
        seed,
        ..RunnerConfig::default()
    };
    let kind_opt = args.opt("collective", "both");
    let kinds: Vec<CollectiveKind> = match kind_opt.as_str() {
        "both" | "all" => CollectiveKind::studied().to_vec(),
        other => vec![parse_collective(other)?],
    };
    let strat_opt = args.opt("strategies", "all");
    let strategy_names: Vec<&str> = csv_list(&strat_opt);
    let scen_opt = args.opt("scenarios", "all");
    let scenario_tags: Vec<&str> = csv_list(&scen_opt);
    let mut machines = vec![MachineVariant::base(m.clone())];
    if let Some(spec) = args.options.get("variants") {
        machines.extend(parse_variants(&m, spec).map_err(|e| e.to_string())?);
    }
    let threads = args.opt_usize("threads", 0)?;
    let node_counts: Vec<usize> = args
        .opt("nodes", "1")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().map_err(|e| format!("--nodes: {e}")))
        .collect::<Result<_, _>>()?;
    let chunk_counts: Vec<ChunkSel> = args
        .opt("chunks", "auto")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(ChunkSel::parse)
        .collect::<Result<_, _>>()
        .map_err(|e| format!("--chunks: {e}"))?;
    let e2e_specs: Vec<E2eSpec> = match args.options.get("e2e") {
        None => Vec::new(),
        Some(spec) => spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(E2eSpec::parse)
            .collect::<Result<_, _>>()
            .map_err(|e| format!("--e2e: {e}"))?,
    };
    let plan = SweepPlan::from_selection(machines, &scenario_tags, &kinds, &strategy_names, cfg)
        .and_then(|p| p.with_node_counts(node_counts))
        .and_then(|p| p.with_chunk_counts(chunk_counts))
        .and_then(|p| p.with_e2e(e2e_specs))
        .map_err(|e| e.to_string())?;
    let n_jobs = plan.job_count();
    let t0 = std::time::Instant::now();
    let results = execute_sweep(plan, threads);
    let elapsed = t0.elapsed().as_secs_f64();

    for (mi, mv) in results.plan.machines.iter().enumerate() {
        for (ni, &nodes) in results.plan.node_counts.iter().enumerate() {
            for (ci, &chunks) in results.plan.chunk_counts.iter().enumerate() {
                let mut headers: Vec<String> =
                    vec!["scenario".to_string(), "collective".to_string()];
                headers.extend(results.plan.strategies.iter().map(|k| k.name().to_string()));
                let mut t = Table::new(headers).left_cols(2).title(format!(
                    "sweep: machine '{}' × {nodes} node(s) × chunks={} — median-speedup per strategy",
                    mv.label,
                    chunks.label()
                ));
                for (si, sc) in results.plan.scenarios.iter().enumerate() {
                    let mut row = vec![sc.tag(), sc.comm.spec.kind.name().to_string()];
                    for (ki, _) in results.plan.strategies.iter().enumerate() {
                        let out = &results.outputs[results.plan.job_id(mi, ni, ci, si, ki)];
                        row.push(match &out.result {
                            Ok(meas) => match (out.rp_cus, out.chunks_used) {
                                (Some(k), _) => format!("{} @{k}CU", speedup(meas.speedup_median)),
                                (None, Some(k)) => {
                                    format!("{} @{k}ch", speedup(meas.speedup_median))
                                }
                                (None, None) => speedup(meas.speedup_median),
                            },
                            Err(_) => "ERR".to_string(),
                        });
                    }
                    t.row(row);
                }
                t.print();
                if let Ok(outs) = results.to_scenario_outcomes(mi, ni, ci) {
                    let h = headline(&outs);
                    let p = |k: &str| h.per_strategy[k].1;
                    println!(
                        "machine '{}' × {nodes} node(s) × chunks={}: avg %ideal — base {:.0}, \
                         sp {:.0}, rp {:.0}, best {:.0}, conccl {:.0}, conccl_rp {:.0}",
                        mv.label,
                        chunks.label(),
                        p("c3_base"),
                        p("c3_sp"),
                        p("c3_rp"),
                        p("c3_best"),
                        p("conccl"),
                        p("conccl_rp")
                    );
                }
                println!();
            }
            // End-to-end workload axis (graph engine): one table per
            // spec on this (machine, topology) point.
            for (si, spec) in results.plan.e2e.iter().enumerate() {
                let runs: Vec<_> = results
                    .e2e_point(mi, ni, si)
                    .into_iter()
                    .filter_map(|o| o.result.as_ref().ok().copied())
                    .collect();
                report::render_graph_e2e(
                    &format!(
                        "e2e workload '{}': machine '{}' × {nodes} node(s)",
                        spec.label(),
                        mv.label
                    ),
                    &runs,
                )
                .print();
                println!();
            }
        }
    }
    let errs = results.errors();
    if !errs.is_empty() {
        println!("{} job(s) failed (sweep continued without them):", errs.len());
        for (job, e) in &errs {
            println!(
                "  job {} [{} × {}n × {}ch × {} × {}]: {e}",
                job.id,
                results.machine_label(job.machine_idx),
                results.plan.node_counts[job.node_idx],
                results.plan.chunk_counts[job.chunk_idx].label(),
                results.plan.scenarios[job.scenario_idx].tag(),
                job.strategy.name()
            );
        }
    }
    // Failed e2e workload points are dropped from their tables above —
    // name them here so a non-JSON run cannot mistake a missing row
    // for success (the JSON carries the {"error": ...} object).
    let e2e_errs: Vec<&conccl::sweep::E2eOutput> = results
        .e2e_outputs
        .iter()
        .filter(|o| o.result.is_err())
        .collect();
    if !e2e_errs.is_empty() {
        println!("{} e2e workload point(s) failed:", e2e_errs.len());
        for o in &e2e_errs {
            println!(
                "  [{} × {}n × {} × {}]: {}",
                results.machine_label(o.machine_idx),
                results.plan.node_counts[o.node_idx],
                results.plan.e2e[o.spec_idx].label(),
                o.family.name(),
                o.result.as_ref().unwrap_err()
            );
        }
    }
    println!(
        "{n_jobs} jobs on {} worker thread(s) in {}",
        results.threads_used,
        fmt_seconds(elapsed)
    );
    if let Some(path) = args.options.get("json") {
        let j = results.to_json();
        if path == "-" {
            println!("{j}");
        } else {
            std::fs::write(path, &j).map_err(|e| format!("--json {path}: {e}"))?;
            println!("wrote JSON report to {path}");
        }
    }
    // Partial failure must not look like success to scripts/CI: the
    // tables and JSON above still describe what ran, but the exit
    // status reports the failed jobs (pairwise and e2e alike).
    if errs.is_empty() && e2e_errs.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "{} of {n_jobs} sweep jobs and {} e2e point(s) failed (see list above)",
            errs.len(),
            e2e_errs.len()
        ))
    }
}

/// CI perf-regression gate: compare a fresh `sweep --json` report
/// against the checked-in baseline; non-zero exit on any >tolerance
/// median-speedup regression. Without `--strict` a `{"seeded":false}`
/// baseline passes with seeding instructions (bootstrap mode, useful
/// locally); with `--strict` — what CI uses — an unseeded baseline is
/// a hard failure, so the gate can never pass vacuously.
fn bench_gate(args: &Args) -> Result<(), String> {
    let baseline_path = args.opt("baseline", "BENCH_baseline.json");
    let report_path = args
        .options
        .get("report")
        .ok_or("bench-gate needs --report <sweep --json output>")?;
    let tolerance: f64 = args
        .opt("tolerance", "0.02")
        .parse()
        .map_err(|e| format!("--tolerance: {e}"))?;
    let read = |p: &str| -> Result<conccl::sweep::Json, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))?;
        conccl::sweep::parse_json(&text).map_err(|e| format!("{p}: {e}"))
    };
    let baseline = read(&baseline_path)?;
    let report = read(report_path)?;
    if !conccl::sweep::is_seeded(&baseline) {
        let points = conccl::sweep::extract_points(&report)?;
        println!(
            "bench-gate: baseline '{baseline_path}' is not seeded yet; {} point(s) measured.",
            points.len()
        );
        println!(
            "  To seed the bench trajectory, commit the fresh report as {baseline_path}:\n  \
             cp {report_path} {baseline_path}"
        );
        // --strict: an unseeded/bootstrap baseline is a FAILURE, not a
        // pass — CI must gate against real numbers.
        if args.flag("strict") {
            return Err(format!(
                "--strict: baseline '{baseline_path}' is not seeded; seed it and re-run"
            ));
        }
        return Ok(());
    }
    let gate = conccl::sweep::gate(&baseline, &report, tolerance)?;
    print!("{}", gate.render(tolerance));
    if gate.passed() {
        Ok(())
    } else {
        Err(format!(
            "perf gate failed: {} regression(s), {} missing point(s)",
            gate.regressions.len(),
            gate.missing.len()
        ))
    }
}

/// Split a comma-separated option; "all" or empty means "everything".
fn csv_list(opt: &str) -> Vec<&str> {
    if opt == "all" || opt.trim().is_empty() {
        Vec::new()
    } else {
        opt.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
    }
}

/// The original single-scenario c3_rp CU-reservation sweep.
fn rp_sweep(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let kind = parse_collective(&args.opt("collective", "all-gather"))?;
    let sc = find_scenario(&args.opt("scenario", "cb1_896M"), kind)?;
    let exec = C3Executor::new(m);
    let mut t = Table::new(vec!["comm CUs", "total", "speedup", "%ideal"])
        .title(format!("c3_rp sweep: {} × {}", sc.tag(), kind.name()));
    for k in exec.m.rp_candidates() {
        let r = exec.run(&sc, Strategy::C3Rp { comm_cus: k });
        t.row(vec![
            k.to_string(),
            fmt_seconds(r.total),
            speedup(r.speedup),
            fnum(r.pct_ideal, 1),
        ]);
    }
    let (best, k) = exec.run_rp_sweep(&sc);
    t.rule();
    t.row(vec![
        format!("best={k}"),
        fmt_seconds(best.total),
        speedup(best.speedup),
        fnum(best.pct_ideal, 1),
    ]);
    t.print();
    Ok(())
}

fn full_report(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let jitter: f64 = args
        .opt("jitter", "0.01")
        .parse()
        .map_err(|e| format!("--jitter: {e}"))?;
    let cfg = RunnerConfig {
        jitter,
        ..RunnerConfig::default()
    };
    let outs = run_suite(&m, &suite(), &cfg);
    report::render_fig7(&outs).print();
    println!();
    report::render_fig8(&outs).print();
    println!();
    report::render_fig10(&outs).print();
    let div = taxonomy_divergences(&m, &outs);
    if !div.is_empty() {
        println!("\ntaxonomy divergences (paper label vs our models):");
        for (tag, paper, ours) in div {
            println!("  {tag}: paper {} / computed {}", paper.name(), ours.name());
        }
    }
    Ok(())
}

fn conccl_bw(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let sizes: Vec<u64> = [1, 4, 8, 16, 32, 64, 128, 256, 896, 2048, 8192, 20480]
        .iter()
        .map(|mb| mb * MIB)
        .collect();
    report::render_fig9(&m, &sizes).print();
    Ok(())
}

fn heuristics_cmd(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let table = SlowdownTable::build(&m);
    let exec = C3Executor::new(m.clone());
    let mut t = Table::new(vec![
        "scenario", "collective", "heuristic", "sweep-best", "match", "loss%",
    ])
    .title("§V-C RP heuristic vs exhaustive sweep")
    .left_cols(2);
    let mut matches = 0;
    let mut worst_loss: f64 = 0.0;
    let mut n = 0;
    for kind in CollectiveKind::studied() {
        for row in &TABLE2 {
            let sc = resolve(row, kind);
            let k_h = heuristics::recommend(&m, &table, &sc);
            let (best, k_b) = exec.run_rp_sweep(&sc);
            let r_h = exec.run_rp_at(&sc, k_h);
            let loss = (r_h.total / best.total - 1.0) * 100.0;
            let is_match = k_h == k_b || loss < 0.1;
            matches += is_match as usize;
            worst_loss = worst_loss.max(loss);
            n += 1;
            t.row(vec![
                sc.tag(),
                kind.name().to_string(),
                k_h.to_string(),
                k_b.to_string(),
                if is_match { "yes" } else { "no" }.to_string(),
                fnum(loss, 2),
            ]);
        }
    }
    t.print();
    println!(
        "heuristic optimal for {matches}/{n} scenarios; worst loss {worst_loss:.2}% \
         (paper: 24/30, <=1.5%)"
    );
    let sp_ok = TABLE2.iter().all(|row| {
        let sc = resolve(row, CollectiveKind::AllGather);
        heuristics::comm_first(&m, &sc.gemm, &sc.comm)
    });
    println!("SP heuristic schedules communication first for all scenarios: {sp_ok}");

    // Chunk-count tuner vs the exhaustive chunk sweep (the granularity
    // analog of the rp comparison above), on the ConCCL pipeline.
    let mut ct = Table::new(vec![
        "scenario", "collective", "heuristic k", "sweep-best k", "match", "loss%",
    ])
    .title("chunk auto-tuner vs exhaustive chunk sweep (conccl_chunked)")
    .left_cols(2);
    let mut c_matches = 0;
    let mut c_worst: f64 = 0.0;
    for kind in CollectiveKind::studied() {
        for row in &TABLE2 {
            let sc = resolve(row, kind);
            let k_h = heuristics::recommend_chunks(&m, &sc, true);
            let at_h = exec.run(&sc, Strategy::ConcclChunked { chunks: k_h });
            let (best, k_b) = exec.run_chunk_sweep(&sc, true);
            let loss = (at_h.total / best.total - 1.0) * 100.0;
            let is_match = k_h == k_b || loss < 0.1;
            c_matches += is_match as usize;
            c_worst = c_worst.max(loss);
            ct.row(vec![
                sc.tag(),
                kind.name().to_string(),
                k_h.to_string(),
                k_b.to_string(),
                if is_match { "yes" } else { "no" }.to_string(),
                fnum(loss, 2),
            ]);
        }
    }
    println!();
    ct.print();
    println!("chunk tuner optimal for {c_matches}/{n} scenarios; worst loss {c_worst:.2}%");
    Ok(())
}

/// Run one end-to-end workload graph (multi-layer FSDP/TP schedule) on
/// the workload-graph engine and report the e2e metrics per family.
fn graph_cmd(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let nodes = args.opt_usize("nodes", 1)?.max(1);
    let depth = args.opt_usize("prefetch-depth", 2)?.max(1);
    let layers = args.opt_usize("layers", 4)?.max(1);
    let spec_str = format!(
        "{}:{}:{layers}:{depth}",
        args.opt("workload", "fsdp_step"),
        args.opt("model", "70b"),
    );
    let spec = E2eSpec::parse(&spec_str).map_err(|e| e.to_string())?;
    let topo = m.topology(nodes);
    let trace = spec.trace();
    let families: Vec<E2eFamily> = match args.opt("family", "all").as_str() {
        "all" => E2eFamily::lineup().to_vec(),
        other => vec![E2eFamily::parse(other).map_err(|e| e.to_string())?],
    };
    let mut runs = Vec::with_capacity(families.len());
    for fam in families {
        runs.push(run_e2e(&m, &topo, &trace, spec.depth, fam).map_err(|e| e.to_string())?);
    }
    report::render_graph_e2e(
        &format!(
            "workload graph: {} ({} stages, prefetch depth {depth}, {nodes} node(s))",
            spec.label(),
            trace.stages.len()
        ),
        &runs,
    )
    .print();
    Ok(())
}

fn e2e(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let layers = args.opt_usize("layers", 4)?;
    let model = match args.opt("model", "70b").as_str() {
        "70b" => LlamaConfig::llama70b(),
        "405b" => LlamaConfig::llama405b(),
        other => return Err(format!("unknown model '{other}'")),
    };
    let trace = fsdp_forward_trace(&model, layers);
    let mut t = Table::new(vec!["strategy", "step time", "speedup", "%ideal"]).left_cols(1).title(format!(
        "FSDP forward, {} × {layers} layers ({} C3 stages)",
        model.name,
        trace.stages.len()
    ));
    for strat in [
        Strategy::Serial,
        Strategy::C3Base,
        Strategy::C3Sp,
        Strategy::Conccl,
        Strategy::ConcclRp { cus_removed: 8 },
        // Auto-tuned chunked pipeline, per stage (chunks: 0 = auto).
        Strategy::ConcclChunked { chunks: 0 },
    ] {
        let r = replay(&m, &trace, strat);
        t.row(vec![
            strat.name().to_string(),
            fmt_seconds(r.total),
            speedup(r.speedup()),
            fnum(r.pct_ideal(), 1),
        ]);
    }
    t.print();
    // Isolated comparison of CU vs DMA collectives on this trace.
    let mut wire = Table::new(vec!["stage", "gather", "rccl", "conccl"]).left_cols(2);
    for s in trace.stages.iter().take(2) {
        let dma = conccl::conccl::DmaCollective::try_new(s.gather.spec)
            .map_err(|e| e.to_string())?;
        wire.row(vec![
            s.label.clone(),
            s.gather.spec.size_tag(),
            fmt_seconds(CollectiveKernel::new(s.gather.spec).time_isolated_full(&m)),
            fmt_seconds(dma.time_isolated(&m)),
        ]);
    }
    println!();
    wire.print();
    // The workload-graph engine's continuous timeline for the same
    // forward trace: the prefetch window overlaps weight gathers across
    // stage boundaries, which the per-stage replay above only prices
    // pairwise. `conccl graph` exposes the full workload lineup.
    let depth = args.opt_usize("prefetch-depth", 2)?.max(1);
    let gtrace = conccl::workload::e2e::fsdp_forward_stages(&model, layers.max(1));
    let topo = m.topology(1);
    let mut runs = Vec::new();
    for fam in E2eFamily::lineup() {
        runs.push(run_e2e(&m, &topo, &gtrace, depth, fam).map_err(|e| e.to_string())?);
    }
    println!();
    report::render_graph_e2e(
        &format!("graph engine: FSDP forward × {layers} layers, prefetch depth {depth}"),
        &runs,
    )
    .print();
    Ok(())
}
