//! `conccl` — leader entrypoint for the C3 + ConCCL system: a thin
//! argv parser → dispatcher shell. All subcommand logic lives in
//! `conccl::cli::handlers` (one module per subcommand group); see
//! `cli::HELP` (or `conccl help`) for the subcommand reference.

use conccl::cli::{handlers, Args, HELP};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = handlers::dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
