//! FSDP execution traces: the per-layer C3 stages of a sharded
//! transformer forward pass (§II-C: "FSDP gathers model weights for a
//! given layer on a GPU (communication) while performing computations
//! of previous layers").
//!
//! Each trace stage pairs one layer's computation GEMM with the weight
//! all-gather of the *next* layer — exactly the overlap the Table II
//! LLaMA rows come from. The e2e driver replays a trace under each
//! strategy and sums the timeline.

use crate::config::machine::MachineConfig;
use crate::config::workload::{CollectiveKind, CollectiveSpec, DType, Source};
use crate::kernels::{CollectiveKernel, GemmKernel};
use crate::sched::{C3Executor, C3Run, Strategy};
use crate::workload::llama::{gemm_by_tag, LlamaConfig};
use crate::workload::scenarios::ResolvedScenario;

/// One C3 stage of the trace.
#[derive(Debug, Clone)]
pub struct TraceStage {
    /// Human label, e.g. `layer3/mlp`.
    pub label: String,
    /// This layer's computation.
    pub gemm: GemmKernel,
    /// The next layer's weight gather.
    pub gather: CollectiveKernel,
}

impl TraceStage {
    /// View as a resolved scenario for the executor.
    pub fn as_scenario(&self) -> ResolvedScenario {
        ResolvedScenario {
            scenario: crate::config::workload::C3Scenario {
                gemm_tag: self.gemm.tag.clone(),
                gemm: self.gemm.shape,
                comm: self.gather.spec,
                source: Source::Llama70B,
            },
            gemm: self.gemm.clone(),
            comm: self.gather,
            paper_type: crate::workload::taxonomy::C3Type::GLong,
        }
    }
}

/// An FSDP forward trace: alternating attention and MLP stages.
#[derive(Debug, Clone)]
pub struct FsdpTrace {
    pub model: &'static str,
    pub stages: Vec<TraceStage>,
}

/// Build the FSDP forward trace of `layers` transformer layers of a
/// LLaMA-like model: each layer contributes an attention stage (cb1-
/// style GEMM ∥ gather of the attn weight) and an MLP stage (mb1-style
/// GEMM ∥ gather of the fused MLP weight).
pub fn fsdp_forward_trace(l: &LlamaConfig, layers: usize) -> FsdpTrace {
    let (attn_tag, mlp_tag) = if l.hidden == 8192 {
        ("cb1", "mb1")
    } else {
        ("cb2", "mb2")
    };
    let attn_gemm = gemm_by_tag(attn_tag).expect("attn gemm");
    let mlp_gemm = gemm_by_tag(mlp_tag).expect("mlp gemm");
    let mut stages = Vec::with_capacity(2 * layers);
    for i in 0..layers {
        stages.push(TraceStage {
            label: format!("layer{i}/attn"),
            gemm: attn_gemm.clone(),
            gather: CollectiveKernel::new(CollectiveSpec::new(
                CollectiveKind::AllGather,
                l.attn_weight_bytes(DType::Bf16),
            )),
        });
        stages.push(TraceStage {
            label: format!("layer{i}/mlp"),
            gemm: mlp_gemm.clone(),
            gather: CollectiveKernel::new(CollectiveSpec::new(
                CollectiveKind::AllGather,
                l.mlp_weight_bytes(DType::Bf16),
            )),
        });
    }
    FsdpTrace {
        model: l.name,
        stages,
    }
}

/// Result of replaying a trace under one strategy.
#[derive(Debug, Clone)]
pub struct TraceReplay {
    pub strategy: Strategy,
    /// Per-stage runs.
    pub runs: Vec<C3Run>,
    /// End-to-end time (sum of stage makespans).
    pub total: f64,
    /// Serial baseline (sum of stage serial times).
    pub serial: f64,
    /// Sum of stage ideal lower bounds.
    pub ideal_total: f64,
}

impl TraceReplay {
    /// End-to-end speedup over the serial schedule.
    pub fn speedup(&self) -> f64 {
        self.serial / self.total
    }

    /// End-to-end %-of-ideal.
    pub fn pct_ideal(&self) -> f64 {
        let ideal_speedup = self.serial / self.ideal_total;
        crate::workload::taxonomy::pct_of_ideal(self.speedup(), ideal_speedup)
    }
}

/// Replay a trace under a strategy: stages execute back-to-back (the
/// gather of layer i+1 overlaps the compute of layer i within a stage;
/// stages serialize on the data dependency).
pub fn replay(m: &MachineConfig, trace: &FsdpTrace, strategy: Strategy) -> TraceReplay {
    let exec = C3Executor::new(m.clone());
    let mut runs = Vec::with_capacity(trace.stages.len());
    let mut total = 0.0;
    let mut serial = 0.0;
    let mut ideal_total = 0.0;
    for stage in &trace.stages {
        let sc = stage.as_scenario();
        let run = exec.run(&sc, strategy);
        total += run.total;
        serial += run.serial;
        ideal_total += run.serial / run.ideal;
        runs.push(run);
    }
    TraceReplay {
        strategy,
        runs,
        total,
        serial,
        ideal_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_structure() {
        let t = fsdp_forward_trace(&LlamaConfig::llama70b(), 4);
        assert_eq!(t.stages.len(), 8);
        assert_eq!(t.stages[0].label, "layer0/attn");
        assert_eq!(t.stages[1].gemm.tag, "mb1");
        // The MLP gather is the famous 896M payload.
        assert_eq!(
            t.stages[1].gather.spec.size_bytes,
            896 * 1024 * 1024
        );
    }

    #[test]
    fn replay_orderings_hold_end_to_end() {
        let m = MachineConfig::mi300x();
        let t = fsdp_forward_trace(&LlamaConfig::llama70b(), 3);
        let serial = replay(&m, &t, Strategy::Serial);
        let base = replay(&m, &t, Strategy::C3Base);
        let sp = replay(&m, &t, Strategy::C3Sp);
        let conccl = replay(&m, &t, Strategy::Conccl);
        assert!((serial.speedup() - 1.0).abs() < 1e-9);
        assert!(base.speedup() >= 0.95);
        // Per-stage sp vs base can be close on GC-equal-ish attention
        // stages; end-to-end sp must not lose to base materially.
        assert!(sp.speedup() + 0.02 >= base.speedup());
        assert!(conccl.speedup() > sp.speedup());
        assert!(conccl.speedup() > base.speedup());
        assert!(conccl.total < serial.total);
        // End-to-end %ideal in a sane band.
        assert!(conccl.pct_ideal() > 50.0 && conccl.pct_ideal() <= 100.0);
    }

    #[test]
    fn replay_supports_chunked_pipeline_end_to_end() {
        // The chunk axis reaches the FSDP e2e path: replaying the trace
        // under the auto-chunked ConCCL pipeline is never worse than
        // whole-kernel ConCCL (the swept chunk count includes k = 1).
        let m = MachineConfig::mi300x();
        let t = fsdp_forward_trace(&LlamaConfig::llama70b(), 3);
        let conccl = replay(&m, &t, Strategy::Conccl);
        let chunked = replay(&m, &t, Strategy::ConcclChunked { chunks: 0 });
        assert_eq!(chunked.runs.len(), conccl.runs.len());
        assert!(
            chunked.total <= conccl.total + 1e-12,
            "chunked {:.4}ms vs conccl {:.4}ms",
            chunked.total * 1e3,
            conccl.total * 1e3
        );
        assert!(chunked.speedup() >= 1.0);
        // A pinned chunk count also replays (and stays bounded).
        let fixed = replay(&m, &t, Strategy::ConcclChunked { chunks: 4 });
        assert!(fixed.speedup() > 0.9);
    }

    #[test]
    fn replay_405b_uses_405b_kernels() {
        let m = MachineConfig::mi300x();
        let t = fsdp_forward_trace(&LlamaConfig::llama405b(), 2);
        assert_eq!(t.stages[0].gemm.tag, "cb2");
        let r = replay(&m, &t, Strategy::Conccl);
        assert_eq!(r.runs.len(), 4);
        assert!(r.total > 0.0);
    }
}
