//! LLaMA-derived workloads: Table I GEMMs and the FSDP weight-gather
//! sizes behind Table II's collective payloads.
//!
//! The paper sources its shapes from LLaMA-3 70B / 405B training with
//! 8192 tokens per iteration (§IV-A2). We *derive* them from the
//! published model dimensions rather than hard-coding, so the mapping is
//! auditable:
//!
//! | tag | role | shape (M×N×K) |
//! |-----|------|----------------|
//! | cb1 | 70B attention projection fwd | tokens × h × h |
//! | cb2 | 405B attention projection grad (transposed) | h × tokens × h |
//! | cb3 | 405B attention weight grad `dW = dYᵀX` | h × h × tokens |
//! | cb4 | 405B fused-QKV fwd (transposed) | qkv × tokens × h |
//! | cb5 | 405B fused MLP-up fwd (transposed) | 2·ffn × tokens × h |
//! | mb1 | 70B fused MLP-up fwd | tokens × 2·ffn × h |
//! | mb2 | 405B MLP-up weight grad | h × 2·ffn × tokens |
//!
//! FSDP all-gathers materialize full layer weights from 8-way shards;
//! the gathered-weight sizes are exactly the paper's LLaMA-sourced
//! collective payloads (e.g. the 70B fused MLP weight, 8192×57344 bf16 =
//! 896 MiB, is Table II's `mb1_896M`).

use crate::config::workload::{DType, GemmShape};
use crate::kernels::gemm::GemmKernel;

/// Transformer dimensions needed to derive the paper's GEMMs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LlamaConfig {
    /// Model name for reports.
    pub name: &'static str,
    /// Hidden (model) dimension.
    pub hidden: usize,
    /// MLP intermediate dimension (one of the two fused projections).
    pub ffn: usize,
    /// Query heads.
    pub q_heads: usize,
    /// KV heads (GQA).
    pub kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Tokens processed per iteration (batch × sequence).
    pub tokens: usize,
}

impl LlamaConfig {
    /// LLaMA-3 70B.
    pub fn llama70b() -> Self {
        LlamaConfig {
            name: "LLaMA-70B",
            hidden: 8192,
            ffn: 28672,
            q_heads: 64,
            kv_heads: 8,
            head_dim: 128,
            tokens: 8192,
        }
    }

    /// LLaMA-3 405B.
    pub fn llama405b() -> Self {
        LlamaConfig {
            name: "LLaMA-405B",
            hidden: 16384,
            ffn: 53248,
            q_heads: 128,
            kv_heads: 8,
            head_dim: 128,
            tokens: 8192,
        }
    }

    /// Fused gate+up MLP projection width (2·ffn).
    pub fn ffn_fused(&self) -> usize {
        2 * self.ffn
    }

    /// Fused QKV projection width ((q_heads + 2·kv_heads) · head_dim).
    pub fn qkv_fused(&self) -> usize {
        (self.q_heads + 2 * self.kv_heads) * self.head_dim
    }

    /// Bytes of the full (gathered) fused MLP weight in `dtype`.
    pub fn mlp_weight_bytes(&self, dtype: DType) -> u64 {
        (self.hidden * self.ffn_fused() * dtype.bytes()) as u64
    }

    /// Bytes of the full attention-projection weight (h × h).
    pub fn attn_weight_bytes(&self, dtype: DType) -> u64 {
        (self.hidden * self.hidden * dtype.bytes()) as u64
    }

    /// Bytes of one unfused MLP projection weight (h × ffn).
    pub fn mlp_half_weight_bytes(&self, dtype: DType) -> u64 {
        (self.hidden * self.ffn * dtype.bytes()) as u64
    }
}

/// Table I: the seven GEMMs under study, derived from model dims.
pub fn table1() -> Vec<GemmKernel> {
    let l70 = LlamaConfig::llama70b();
    let l405 = LlamaConfig::llama405b();
    vec![
        GemmKernel::new("cb1", GemmShape::bf16(l70.tokens, l70.hidden, l70.hidden)),
        GemmKernel::new("cb2", GemmShape::bf16(l405.hidden, l405.tokens, l405.hidden)),
        GemmKernel::new("cb3", GemmShape::bf16(l405.hidden, l405.hidden, l405.tokens)),
        GemmKernel::new(
            "cb4",
            GemmShape::bf16(l405.qkv_fused(), l405.tokens, l405.hidden),
        ),
        GemmKernel::new(
            "cb5",
            GemmShape::bf16(l405.ffn_fused(), l405.tokens, l405.hidden),
        ),
        GemmKernel::new(
            "mb1",
            GemmShape::bf16(l70.tokens, l70.ffn_fused(), l70.hidden),
        ),
        GemmKernel::new(
            "mb2",
            GemmShape::bf16(l405.hidden, l405.ffn_fused(), l405.tokens),
        ),
    ]
}

/// Look up a Table I GEMM by tag.
pub fn gemm_by_tag(tag: &str) -> Option<GemmKernel> {
    table1().into_iter().find(|k| k.tag == tag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{GIB, MIB};

    #[test]
    fn table1_shapes_match_paper() {
        // Paper Table I, shapes written M×N×K.
        let expect = [
            ("cb1", 8192, 8192, 8192),
            ("cb2", 16384, 8192, 16384),
            ("cb3", 16384, 16384, 8192),
            ("cb4", 18432, 8192, 16384),
            ("cb5", 106496, 8192, 16384),
            ("mb1", 8192, 57344, 8192),
            ("mb2", 16384, 106496, 8192),
        ];
        let got = table1();
        assert_eq!(got.len(), expect.len());
        for (k, (tag, m, n, kk)) in got.iter().zip(expect) {
            assert_eq!(k.tag, tag);
            assert_eq!((k.shape.m, k.shape.n, k.shape.k), (m, n, kk), "{tag}");
        }
    }

    #[test]
    fn derived_dims_are_published_values() {
        let l70 = LlamaConfig::llama70b();
        let l405 = LlamaConfig::llama405b();
        assert_eq!(l70.ffn_fused(), 57344);
        assert_eq!(l405.ffn_fused(), 106496);
        assert_eq!(l405.qkv_fused(), 18432);
    }

    #[test]
    fn fsdp_weight_sizes_match_table2_payloads() {
        // Table II's LLaMA-sourced collective sizes are gathered weights.
        let l70 = LlamaConfig::llama70b();
        let l405 = LlamaConfig::llama405b();
        assert_eq!(l70.mlp_weight_bytes(DType::Bf16), 896 * MIB); // mb1_896M
        assert_eq!(l405.attn_weight_bytes(DType::Bf16), 512 * MIB); // cb3/cb4_512M
        assert_eq!(
            l405.mlp_weight_bytes(DType::Bf16),
            (3.25 * GIB as f64) as u64 // cb2/mb2_3.25G
        );
        assert_eq!(
            l405.mlp_half_weight_bytes(DType::Bf16),
            (1.625 * GIB as f64) as u64 // cb5_1.63G
        );
    }

    #[test]
    fn tag_lookup() {
        assert!(gemm_by_tag("mb1").is_some());
        assert!(gemm_by_tag("cb9").is_none());
    }
}
