//! Table II: the 15 C3 manifestations under study.
//!
//! Seven are manifested by FSDP training of LLaMA-70B/405B (8-way
//! sharding: the collective payload is the gathered layer weight — see
//! `workload::llama` for the exact derivations); eight are synthetic
//! additions for taxonomy coverage. Every scenario is evaluated with
//! both all-gather and all-to-all (30 scenario×collective combinations,
//! §V-C's "24 of 30").

use crate::config::machine::MachineConfig;
use crate::config::workload::{C3Scenario, CollectiveKind, CollectiveSpec, Source};
use crate::error::Error;
use crate::kernels::{CollectiveKernel, GemmKernel};
use crate::util::units::parse_bytes;
use crate::workload::llama::gemm_by_tag;
use crate::workload::taxonomy::C3Type;

/// One Table II row: GEMM tag + collective size + source + the paper's
/// printed taxonomy label (ours is recomputed; divergences are reported
/// by the tab2 bench and EXPERIMENTS.md).
#[derive(Debug, Clone, Copy)]
pub struct Table2Row {
    pub gemm_tag: &'static str,
    pub size: &'static str,
    pub source: Source,
    pub paper_type: C3Type,
}

/// The 15 rows of Table II, in paper order. (A `static`, not a `const`:
/// lookups hand out `&'static Table2Row` borrows of this array.)
pub static TABLE2: [Table2Row; 15] = [
    // C3-type: G-long
    Table2Row { gemm_tag: "mb1", size: "896M", source: Source::Llama70B, paper_type: C3Type::GLong },
    Table2Row { gemm_tag: "mb2", size: "3.25G", source: Source::Llama405B, paper_type: C3Type::GLong },
    Table2Row { gemm_tag: "mb1", size: "4G", source: Source::Synthetic, paper_type: C3Type::GLong },
    Table2Row { gemm_tag: "mb1", size: "6G", source: Source::Synthetic, paper_type: C3Type::GLong },
    Table2Row { gemm_tag: "cb3", size: "512M", source: Source::Llama405B, paper_type: C3Type::GLong },
    Table2Row { gemm_tag: "cb4", size: "512M", source: Source::Llama405B, paper_type: C3Type::GLong },
    Table2Row { gemm_tag: "cb5", size: "1.63G", source: Source::Llama405B, paper_type: C3Type::GLong },
    Table2Row { gemm_tag: "cb4", size: "1G", source: Source::Synthetic, paper_type: C3Type::GLong },
    // C3-type: C-long
    Table2Row { gemm_tag: "mb1", size: "13G", source: Source::Synthetic, paper_type: C3Type::CLong },
    Table2Row { gemm_tag: "cb2", size: "3.25G", source: Source::Llama405B, paper_type: C3Type::CLong },
    Table2Row { gemm_tag: "cb4", size: "2.5G", source: Source::Synthetic, paper_type: C3Type::CLong },
    Table2Row { gemm_tag: "cb1", size: "896M", source: Source::Llama70B, paper_type: C3Type::CLong },
    Table2Row { gemm_tag: "cb5", size: "20G", source: Source::Synthetic, paper_type: C3Type::CLong },
    // C3-type: GC-equal
    Table2Row { gemm_tag: "mb2", size: "26.5G", source: Source::Synthetic, paper_type: C3Type::GcEqual },
    Table2Row { gemm_tag: "cb5", size: "13G", source: Source::Synthetic, paper_type: C3Type::GcEqual },
];

/// A fully-resolved scenario ready for execution: models + metadata.
#[derive(Debug, Clone)]
pub struct ResolvedScenario {
    pub scenario: C3Scenario,
    pub gemm: GemmKernel,
    pub comm: CollectiveKernel,
    pub paper_type: C3Type,
}

impl ResolvedScenario {
    /// Paper-style tag, e.g. `mb1_896M`.
    pub fn tag(&self) -> String {
        self.scenario.tag()
    }

    /// Our computed C3 type from the models (may diverge from the
    /// paper's label on borderline rows).
    pub fn computed_type(&self, m: &MachineConfig) -> C3Type {
        C3Type::classify(
            self.gemm.time_isolated(m, m.cus_total()),
            self.comm.time_isolated_full(m),
        )
    }

    /// Largest chunk count this scenario supports for the chunked C3
    /// pipeline: one chunk per GEMM macro-tile row at most, one byte
    /// per collective chunk at least. The single clamp the executor,
    /// the pipeline simulator and the chunk tuner all share.
    pub fn chunk_cap(&self, m: &MachineConfig) -> u32 {
        self.gemm
            .max_m_chunks(m)
            .min(self.comm.spec.size_bytes.min(u32::MAX as u64) as u32)
            .max(1)
    }
}

/// Resolve one Table II row against a collective kind, surfacing an
/// [`Error`] on an unknown Table I tag or a malformed size literal
/// instead of panicking.
pub fn try_resolve(row: &Table2Row, kind: CollectiveKind) -> Result<ResolvedScenario, Error> {
    let gemm =
        gemm_by_tag(row.gemm_tag).ok_or_else(|| Error::UnknownGemmTag(row.gemm_tag.to_string()))?;
    let size = parse_bytes(row.size)
        .map_err(|e| Error::Config(format!("Table II size '{}': {e}", row.size)))?;
    let spec = CollectiveSpec::new(kind, size);
    Ok(ResolvedScenario {
        scenario: C3Scenario {
            gemm_tag: row.gemm_tag.to_string(),
            gemm: gemm.shape,
            comm: spec,
            source: row.source,
        },
        gemm,
        comm: CollectiveKernel::new(spec),
        paper_type: row.paper_type,
    })
}

/// Resolve one Table II row against a collective kind. Panicking
/// convenience wrapper over [`try_resolve`] for the static `TABLE2`
/// rows, which always resolve.
pub fn resolve(row: &Table2Row, kind: CollectiveKind) -> ResolvedScenario {
    try_resolve(row, kind).unwrap_or_else(|e| panic!("{e}"))
}

/// Look up a Table II row by its paper-style scenario tag
/// (e.g. `mb1_896M`).
pub fn find(tag: &str) -> Result<&'static Table2Row, Error> {
    TABLE2
        .iter()
        .find(|r| format!("{}_{}", r.gemm_tag, r.size) == tag)
        .ok_or_else(|| Error::UnknownScenario(tag.to_string()))
}

/// Resolve a scenario by tag + collective kind — the CLI's and sweep
/// planner's entry point; unknown tags are an `Err`, never a panic.
pub fn resolve_tag(tag: &str, kind: CollectiveKind) -> Result<ResolvedScenario, Error> {
    try_resolve(find(tag)?, kind)
}

/// The full evaluation suite: all 15 rows × the collective kinds the
/// paper sweeps (all-gather, all-to-all) = 30 combinations.
pub fn suite() -> Vec<ResolvedScenario> {
    let mut v = Vec::with_capacity(TABLE2.len() * 2);
    for kind in CollectiveKind::studied() {
        for row in &TABLE2 {
            v.push(resolve(row, kind));
        }
    }
    v
}

/// Suite restricted to one collective kind (15 scenarios).
pub fn suite_for(kind: CollectiveKind) -> Vec<ResolvedScenario> {
    TABLE2.iter().map(|r| resolve(r, kind)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_paper_structure() {
        assert_eq!(TABLE2.len(), 15);
        let g = TABLE2.iter().filter(|r| r.paper_type == C3Type::GLong).count();
        let c = TABLE2.iter().filter(|r| r.paper_type == C3Type::CLong).count();
        let e = TABLE2.iter().filter(|r| r.paper_type == C3Type::GcEqual).count();
        assert_eq!((g, c, e), (8, 5, 2));
        // 7 LLaMA-sourced rows (paper: "seven are manifested in training").
        let llama = TABLE2
            .iter()
            .filter(|r| r.source != Source::Synthetic)
            .count();
        assert_eq!(llama, 7);
    }

    #[test]
    fn unknown_tags_are_typed_errors() {
        assert!(matches!(
            find("zz_9G"),
            Err(crate::error::Error::UnknownScenario(_))
        ));
        assert!(resolve_tag("mb1_896M", CollectiveKind::AllGather).is_ok());
        let bad = Table2Row {
            gemm_tag: "cb9",
            size: "1G",
            source: Source::Synthetic,
            paper_type: C3Type::GLong,
        };
        assert!(matches!(
            try_resolve(&bad, CollectiveKind::AllGather),
            Err(crate::error::Error::UnknownGemmTag(_))
        ));
        let bad_size = Table2Row {
            gemm_tag: "mb1",
            size: "huge",
            source: Source::Synthetic,
            paper_type: C3Type::GLong,
        };
        assert!(try_resolve(&bad_size, CollectiveKind::AllGather).is_err());
    }

    #[test]
    fn suite_is_30_combinations() {
        let s = suite();
        assert_eq!(s.len(), 30);
        // Tags match the paper format.
        assert!(s.iter().any(|x| x.tag() == "mb1_896M"));
        assert!(s.iter().any(|x| x.tag() == "mb2_26.5G"));
    }

    #[test]
    fn computed_taxonomy_mostly_matches_paper() {
        // Our isolated-time models should agree with the paper's
        // taxonomy labels on at least 12 of 15 all-gather rows
        // (borderline rows may flip; EXPERIMENTS.md documents them).
        let m = MachineConfig::mi300x();
        let matches = suite_for(CollectiveKind::AllGather)
            .iter()
            .filter(|s| s.computed_type(&m) == s.paper_type)
            .count();
        assert!(matches >= 12, "only {matches}/15 taxonomy labels match");
    }

    #[test]
    fn ideal_speedups_span_paper_range() {
        // Fig 7: ideal speedups range ~1.1x to ~2x.
        let m = MachineConfig::mi300x();
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for s in suite_for(CollectiveKind::AllGather) {
            let tg = s.gemm.time_isolated(&m, m.cus_total());
            let tc = s.comm.time_isolated_full(&m);
            let ideal = (tg + tc) / tg.max(tc);
            lo = lo.min(ideal);
            hi = hi.max(ideal);
        }
        assert!(lo >= 1.05 && lo <= 1.25, "min ideal {lo:.3}");
        assert!(hi >= 1.75 && hi <= 2.0, "max ideal {hi:.3}");
    }
}
