//! End-to-end multi-layer schedules on the workload-graph engine
//! (§II-C's *stream* of per-layer C3 stages, executed as one continuous
//! timeline instead of a sum of isolated pairs).
//!
//! Three workload families:
//!
//! * **`fsdp_forward`** — the sharded-transformer forward pass: each
//!   stage's weight all-gather must land before its GEMM; a
//!   *prefetch-depth* window (in layers) bounds how many weight gathers
//!   may be in flight concurrently, so `depth >= 2` overlaps a stage's
//!   gather with the *previous* layers' compute — overlap across stage
//!   boundaries that the sum-of-pairs replay cannot express.
//! * **`fsdp_step`** — forward plus backward: backward re-gathers the
//!   (resharded) weights under the same window and issues a gradient
//!   *reduce-scatter* per stage. Reduce-scatter cannot run on DMA
//!   engines (no arithmetic, §VI-B), so even the ConCCL family runs it
//!   on CUs — the §VII-A2 hybrid, end to end.
//! * **`tp_chain`** — a Megatron-style tensor-parallel layer chain:
//!   AG(activations) → GEMM → RS(partials) per layer, where layer
//!   `i+1`'s all-gather depends on layer `i`'s GEMM output and overlaps
//!   layer `i`'s reduce-scatter.
//!
//! Under the `dma_overlap` family, concurrent weight gathers contend
//! for the GPU's finite SDMA engines (the `sdma` fluid resource) and
//! for HBM bandwidth; the run reports end-to-end metrics the pairwise
//! path could not: exposed-communication time, bubble time, and
//! per-resource occupancy.

use crate::conccl::DmaCollective;
use crate::config::machine::MachineConfig;
use crate::config::workload::{CollectiveKind, CollectiveSpec, DType};
use crate::error::Error;
use crate::fabric::Topology;
use crate::gpu::sdma::engine_demand;
use crate::kernels::{CollectiveKernel, GemmKernel};
use crate::sched::graph::{
    self, CommBackend, CommWork, CuPolicy, GemmWork, Graph, NodeSpec, PenaltyStyle, Ready, Work,
};
use crate::workload::llama::{gemm_by_tag, LlamaConfig};

/// Which end-to-end workload family a trace belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum E2eKind {
    FsdpForward,
    FsdpStep,
    TpChain,
}

impl E2eKind {
    /// Name used in CLI specs, JSON and gate keys.
    pub fn name(self) -> &'static str {
        match self {
            E2eKind::FsdpForward => "fsdp_forward",
            E2eKind::FsdpStep => "fsdp_step",
            E2eKind::TpChain => "tp_chain",
        }
    }
}

/// One stage of an end-to-end trace: a GEMM plus the collectives tied
/// to it (the weight/activation gather it consumes, the gradient/partial
/// reduce-scatter it produces).
#[derive(Debug, Clone)]
pub struct E2eStage {
    pub label: String,
    pub gemm: GemmKernel,
    pub gather: Option<CollectiveKernel>,
    pub reduce: Option<CollectiveKernel>,
}

/// A multi-layer end-to-end trace.
#[derive(Debug, Clone)]
pub struct E2eTrace {
    pub kind: E2eKind,
    pub model: &'static str,
    /// Stages per transformer layer (2 for FSDP attn+mlp, 1 for TP).
    pub stages_per_layer: usize,
    pub stages: Vec<E2eStage>,
}

fn fsdp_layer_kernels(l: &LlamaConfig) -> (GemmKernel, GemmKernel, u64, u64) {
    let (attn_tag, mlp_tag) = if l.hidden == 8192 { ("cb1", "mb1") } else { ("cb2", "mb2") };
    let attn_gemm = gemm_by_tag(attn_tag).expect("attn gemm");
    let mlp_gemm = gemm_by_tag(mlp_tag).expect("mlp gemm");
    (
        attn_gemm,
        mlp_gemm,
        l.attn_weight_bytes(DType::Bf16),
        l.mlp_weight_bytes(DType::Bf16),
    )
}

fn ag(bytes: u64) -> CollectiveKernel {
    CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, bytes))
}

fn rs(bytes: u64) -> CollectiveKernel {
    CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::ReduceScatter, bytes))
}

/// FSDP forward trace: per layer, an attention stage and an MLP stage,
/// each gathering its *own* stage's weights (the prefetch window decides
/// how far ahead the gathers run).
pub fn fsdp_forward_stages(l: &LlamaConfig, layers: usize) -> E2eTrace {
    assert!(layers >= 1, "need at least one layer");
    let (attn_gemm, mlp_gemm, attn_w, mlp_w) = fsdp_layer_kernels(l);
    let mut stages = Vec::with_capacity(2 * layers);
    for i in 0..layers {
        stages.push(E2eStage {
            label: format!("layer{i}/attn"),
            gemm: attn_gemm.clone(),
            gather: Some(ag(attn_w)),
            reduce: None,
        });
        stages.push(E2eStage {
            label: format!("layer{i}/mlp"),
            gemm: mlp_gemm.clone(),
            gather: Some(ag(mlp_w)),
            reduce: None,
        });
    }
    E2eTrace {
        kind: E2eKind::FsdpForward,
        model: l.name,
        stages_per_layer: 2,
        stages,
    }
}

/// FSDP training step: the forward stages plus a backward pass in
/// reverse layer order — each backward stage re-gathers its weights
/// (full resharding) and reduce-scatters its weight gradient. The
/// backward GEMM is modelled with the forward stage's kernel (the
/// dominant grad GEMMs share those shapes; Table I's cb2/cb3/mb2 *are*
/// grad GEMMs).
pub fn fsdp_step_stages(l: &LlamaConfig, layers: usize) -> E2eTrace {
    let mut t = fsdp_forward_stages(l, layers);
    t.kind = E2eKind::FsdpStep;
    let (attn_gemm, mlp_gemm, attn_w, mlp_w) = fsdp_layer_kernels(l);
    for i in (0..layers).rev() {
        t.stages.push(E2eStage {
            label: format!("layer{i}/bwd-mlp"),
            gemm: mlp_gemm.clone(),
            gather: Some(ag(mlp_w)),
            reduce: Some(rs(mlp_w)),
        });
        t.stages.push(E2eStage {
            label: format!("layer{i}/bwd-attn"),
            gemm: attn_gemm.clone(),
            gather: Some(ag(attn_w)),
            reduce: Some(rs(attn_w)),
        });
    }
    t
}

/// Megatron-style tensor-parallel layer chain: per layer, gather the
/// (sequence-sharded) activations, run the MLP GEMM, reduce-scatter the
/// partial outputs. Layer `i+1`'s gather depends on layer `i`'s GEMM
/// (an activation, not a weight — it cannot be prefetched) and overlaps
/// layer `i`'s reduce-scatter.
pub fn tp_chain_stages(l: &LlamaConfig, layers: usize) -> E2eTrace {
    assert!(layers >= 1, "need at least one layer");
    let (_, mlp_gemm, _, _) = fsdp_layer_kernels(l);
    let act = (l.tokens * l.hidden * DType::Bf16.bytes()) as u64;
    let stages = (0..layers)
        .map(|i| E2eStage {
            label: format!("layer{i}/tp"),
            gemm: mlp_gemm.clone(),
            gather: Some(ag(act)),
            reduce: Some(rs(act)),
        })
        .collect();
    E2eTrace {
        kind: E2eKind::TpChain,
        model: l.name,
        stages_per_layer: 1,
        stages,
    }
}

/// How an end-to-end trace's collectives execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum E2eFamily {
    /// Everything sequential on the RCCL baseline stack (speedup 1.0).
    Serial,
    /// Overlapped, collectives on CUs (the c3_sp discipline).
    CuOverlap,
    /// Overlapped, offloadable collectives on DMA engines (ConCCL);
    /// reduce-scatters stay on CUs (§VII-A2 hybrid).
    DmaOverlap,
}

impl E2eFamily {
    pub fn name(self) -> &'static str {
        match self {
            E2eFamily::Serial => "serial",
            E2eFamily::CuOverlap => "cu_overlap",
            E2eFamily::DmaOverlap => "dma_overlap",
        }
    }

    /// The three families every e2e point is evaluated under.
    pub fn lineup() -> [E2eFamily; 3] {
        [E2eFamily::Serial, E2eFamily::CuOverlap, E2eFamily::DmaOverlap]
    }

    /// Parse a CLI family name; `Err` (never a panic) on unknowns.
    pub fn parse(s: &str) -> Result<E2eFamily, Error> {
        match s {
            "serial" => Ok(E2eFamily::Serial),
            "cu" | "cu_overlap" => Ok(E2eFamily::CuOverlap),
            "dma" | "dma_overlap" | "conccl" => Ok(E2eFamily::DmaOverlap),
            other => Err(Error::Config(format!(
                "unknown e2e family '{other}' (expected serial, cu_overlap, dma_overlap)"
            ))),
        }
    }
}

/// Build a comm node for an e2e graph (executor-style derivations:
/// wire, HBM demand, §VII-A1 share, engine occupancy).
fn comm_node(
    m: &MachineConfig,
    topo: &Topology,
    kernel: CollectiveKernel,
    dma: bool,
) -> Result<(Work, Ready), Error> {
    let kind = kernel.spec.kind;
    if dma {
        let d = DmaCollective::try_new(kernel.spec)?;
        let wire = d.wire_time_on(m, topo);
        Ok((
            Work::Comm(CommWork {
                kernel,
                backend: CommBackend::Dma {
                    wire,
                    engines: engine_demand(m),
                },
                hbm: d.hbm_traffic(m),
                share: kernel.hbm_share_with_wire(m, wire),
                pollution: 0.0,
                co_penalty: m.comm_co_penalty(kind),
                sync: m.dma_sync_s,
                pen_style: PenaltyStyle::RateScaled,
            }),
            Ready::Queue {
                queue: 0,
                hold: m.num_gpus as f64 * m.dma_enqueue_s,
                post: m.dma_fetch_s,
            },
        ))
    } else {
        let need = kernel.cu_need(m);
        let wire = kernel.t_wire_on(m, topo, need.max(1));
        Ok((
            Work::Comm(CommWork {
                kernel,
                backend: CommBackend::Cu {
                    backlog_cus: need,
                    overlap_cus: need,
                    solo_cus: need,
                    backlog_until: 0.0,
                    wire_fixed: None,
                },
                hbm: kernel.hbm_traffic(m),
                share: kernel.hbm_share_with_wire(m, wire),
                pollution: m.l2_pollution(kind),
                co_penalty: m.comm_co_penalty(kind),
                sync: 0.0,
                pen_style: PenaltyStyle::RateScaled,
            }),
            Ready::AfterDeps {
                lag: m.coll_launch_s,
            },
        ))
    }
}

/// Build the workload graph of an e2e trace under an overlap family.
/// `depth` is the prefetch window in *layers*: up to
/// `depth × stages_per_layer` stages' weight gathers may be in flight
/// ahead of the compute consuming them (a stage's weights are freed
/// when its GEMM completes, which opens the slot for the gather
/// `window` stages later). TP-chain gathers carry a data dependency on
/// the previous GEMM instead — activations cannot be prefetched.
pub fn build_graph(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    depth: usize,
    family: E2eFamily,
) -> Result<Graph, Error> {
    assert!(
        family != E2eFamily::Serial,
        "the serial family is priced analytically (sum of isolated times)"
    );
    let cus = m.cus_total();
    let dma = family == E2eFamily::DmaOverlap;
    let window = trace.stages_per_layer * depth.max(1);
    let mut g = Graph::default();
    let mut gemm_ids: Vec<usize> = Vec::with_capacity(trace.stages.len());
    for (s, stage) in trace.stages.iter().enumerate() {
        let gather_id = match &stage.gather {
            None => None,
            Some(k) => {
                let issue_deps = match trace.kind {
                    // Activation dependency: the previous layer must
                    // have computed before its output can be gathered.
                    E2eKind::TpChain => match s.checked_sub(1) {
                        Some(i) => vec![gemm_ids[i]],
                        None => Vec::new(),
                    },
                    // Prefetch window: a stage's gathered weights live
                    // until its GEMM consumes them, so gather `s` may
                    // issue once the stage `window` back has been
                    // computed (freeing its weight buffer). At most
                    // `depth` layers' gathers are in flight.
                    _ => match s.checked_sub(window) {
                        Some(i) => vec![gemm_ids[i]],
                        None => Vec::new(),
                    },
                };
                let (work, ready) =
                    comm_node(m, topo, *k, dma && k.spec.kind.dma_offloadable())?;
                Some(g.push(NodeSpec {
                    label: format!("{}/gather", stage.label),
                    work,
                    issue_deps,
                    serial_deps: Vec::new(),
                    ready,
                }))
            }
        };
        let mut deps = Vec::new();
        if let Some(&prev) = gemm_ids.last() {
            deps.push(prev);
        }
        if let Some(gid) = gather_id {
            deps.push(gid);
        }
        let gemm_id = g.push(NodeSpec {
            label: format!("{}/gemm", stage.label),
            work: Work::Gemm(GemmWork {
                comp: stage.gemm.clone(),
                mem: stage.gemm.clone(),
                frac: 1.0,
                share: stage.gemm.hbm_share(m, cus),
                cu_policy: CuPolicy::Residual,
                pen_style: PenaltyStyle::RateScaled,
            }),
            issue_deps: deps,
            serial_deps: Vec::new(),
            ready: Ready::AfterDeps {
                lag: m.kernel_launch_s,
            },
        });
        gemm_ids.push(gemm_id);
        if let Some(k) = &stage.reduce {
            // Reduce-scatter is never DMA-offloadable: CUs even under
            // the ConCCL family (the §VII-A2 hybrid).
            let (work, ready) = comm_node(m, topo, *k, false)?;
            g.push(NodeSpec {
                label: format!("{}/reduce", stage.label),
                work,
                issue_deps: vec![gemm_id],
                serial_deps: Vec::new(),
                ready,
            });
        }
    }
    Ok(g)
}

/// Sum-of-pairs baseline of a trace under a pairwise strategy: each
/// stage priced as an isolated (GEMM ∥ gather) pair by the pairwise
/// executor — the pre-graph `trace::replay` model — plus the stage's
/// reduce-scatter serialized after the pair (the pairwise timeline has
/// exactly one compute and one collective slot per stage, so a second
/// concurrent collective is inexpressible there). The workload graph's
/// advantage over this number is overlap the pairwise model cannot
/// realize: gathers prefetched across stage boundaries and gradient
/// reduce-scatters hidden under subsequent backward compute.
pub fn sum_of_pairs_total(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    strategy: crate::sched::Strategy,
) -> Result<f64, Error> {
    let exec = crate::sched::C3Executor::with_topology(m.clone(), *topo);
    let cus = m.cus_total();
    let mut total = 0.0;
    for stage in &trace.stages {
        total += match &stage.gather {
            Some(k) => {
                let sc = crate::workload::ResolvedScenario {
                    scenario: crate::config::workload::C3Scenario {
                        gemm_tag: stage.gemm.tag.clone(),
                        gemm: stage.gemm.shape,
                        comm: k.spec,
                        source: crate::config::workload::Source::Synthetic,
                    },
                    gemm: stage.gemm.clone(),
                    comm: *k,
                    paper_type: crate::workload::taxonomy::C3Type::GLong,
                };
                exec.try_run(&sc, strategy)?.total
            }
            None => stage.gemm.time_isolated(m, cus),
        };
        if let Some(r) = &stage.reduce {
            total += r.time_isolated_full_on(m, topo);
        }
    }
    Ok(total)
}

/// Serial baseline of a trace: every stage's GEMM and collectives run
/// back-to-back in isolation on the RCCL baseline stack.
pub fn serial_total(m: &MachineConfig, topo: &Topology, trace: &E2eTrace) -> f64 {
    let cus = m.cus_total();
    trace
        .stages
        .iter()
        .map(|s| {
            s.gemm.time_isolated(m, cus)
                + s.gather.map_or(0.0, |k| k.time_isolated_full_on(m, topo))
                + s.reduce.map_or(0.0, |k| k.time_isolated_full_on(m, topo))
        })
        .sum()
}

/// Result of one end-to-end graph run.
#[derive(Debug, Clone, Copy)]
pub struct E2eRun {
    pub family: E2eFamily,
    /// End-to-end makespan, seconds.
    pub total: f64,
    /// Serial baseline (sum of isolated stage times).
    pub serial: f64,
    /// Speedup over the serial schedule.
    pub speedup: f64,
    /// Communication time not hidden under any compute.
    pub exposed_comm: f64,
    /// Time covered by neither compute nor communication.
    pub bubble: f64,
    /// Fraction of achievable HBM byte-capacity consumed.
    pub hbm_occupancy: f64,
    /// Fraction of SDMA engine-seconds consumed.
    pub sdma_occupancy: f64,
    /// Nodes in the executed graph (0 for the analytic serial family).
    pub graph_nodes: usize,
}

/// Evaluate one trace under one family at one prefetch depth.
pub fn run_e2e(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    depth: usize,
    family: E2eFamily,
) -> Result<E2eRun, Error> {
    let serial = serial_total(m, topo, trace);
    if family == E2eFamily::Serial {
        let comm: f64 = trace
            .stages
            .iter()
            .map(|s| {
                s.gather.map_or(0.0, |k| k.time_isolated_full_on(m, topo))
                    + s.reduce.map_or(0.0, |k| k.time_isolated_full_on(m, topo))
            })
            .sum();
        let hbm_bytes: f64 = trace
            .stages
            .iter()
            .map(|s| {
                s.gemm.hbm_traffic(m, m.cus_total())
                    + s.gather.map_or(0.0, |k| k.hbm_traffic(m))
                    + s.reduce.map_or(0.0, |k| k.hbm_traffic(m))
            })
            .sum();
        return Ok(E2eRun {
            family,
            total: serial,
            serial,
            speedup: 1.0,
            exposed_comm: comm,
            bubble: 0.0,
            hbm_occupancy: if serial > 0.0 {
                (hbm_bytes / (m.hbm_bw_achievable() * serial)).min(1.0)
            } else {
                0.0
            },
            sdma_occupancy: 0.0,
            graph_nodes: 0,
        });
    }
    let g = build_graph(m, topo, trace, depth, family)?;
    let r = graph::execute(m, topo, &g)?;
    Ok(E2eRun {
        family,
        total: r.total,
        serial,
        speedup: serial / r.total,
        exposed_comm: r.exposed_comm,
        bubble: r.bubble,
        hbm_occupancy: r.hbm_occupancy,
        sdma_occupancy: r.sdma_occupancy,
        graph_nodes: g.nodes.len(),
    })
}

/// One point of the sweep's end-to-end workload axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E2eSpec {
    pub kind: E2eKind,
    pub model: LlamaConfig,
    pub model_tag: &'static str,
    pub layers: usize,
    pub depth: usize,
}

impl E2eSpec {
    /// Parse a CLI axis entry: `workload[:model[:layers[:depth]]]`,
    /// e.g. `fsdp_step:70b:4:2` (defaults: 70b, 4 layers, depth 2).
    pub fn parse(s: &str) -> Result<E2eSpec, Error> {
        let mut it = s.split(':');
        let kind = match it.next().unwrap_or("") {
            "fsdp_forward" | "fsdp_fwd" => E2eKind::FsdpForward,
            "fsdp_step" | "fsdp" => E2eKind::FsdpStep,
            "tp_chain" | "tp" => E2eKind::TpChain,
            other => {
                return Err(Error::Config(format!(
                    "unknown e2e workload '{other}' (expected fsdp_forward, fsdp_step, tp_chain)"
                )))
            }
        };
        let (model, model_tag) = match it.next().unwrap_or("70b") {
            "70b" => (LlamaConfig::llama70b(), "70b"),
            "405b" => (LlamaConfig::llama405b(), "405b"),
            other => {
                return Err(Error::Config(format!(
                    "unknown e2e model '{other}' (expected 70b or 405b)"
                )))
            }
        };
        let parse_pos = |v: Option<&str>, what: &str, default: usize| -> Result<usize, Error> {
            match v {
                None => Ok(default),
                Some(raw) => raw
                    .parse::<usize>()
                    .ok()
                    .filter(|&x| x >= 1)
                    .ok_or_else(|| {
                        Error::Config(format!("e2e {what} '{raw}': expected a positive integer"))
                    }),
            }
        };
        let layers = parse_pos(it.next(), "layer count", 4)?;
        let depth = parse_pos(it.next(), "prefetch depth", 2)?;
        if let Some(extra) = it.next() {
            return Err(Error::Config(format!(
                "e2e spec '{s}': unexpected trailing segment '{extra}'"
            )));
        }
        Ok(E2eSpec {
            kind,
            model,
            model_tag,
            layers,
            depth,
        })
    }

    /// Stable label used in JSON and gate keys (no `/`).
    pub fn label(&self) -> String {
        format!(
            "{}-{}-l{}-d{}",
            self.kind.name(),
            self.model_tag,
            self.layers,
            self.depth
        )
    }

    /// Materialize the trace.
    pub fn trace(&self) -> E2eTrace {
        match self.kind {
            E2eKind::FsdpForward => fsdp_forward_stages(&self.model, self.layers),
            E2eKind::FsdpStep => fsdp_step_stages(&self.model, self.layers),
            E2eKind::TpChain => tp_chain_stages(&self.model, self.layers),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Strategy;
    use crate::workload::trace::{fsdp_forward_trace, replay};

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn topo1(m: &MachineConfig) -> Topology {
        m.topology(1)
    }

    #[test]
    fn traces_have_expected_structure() {
        let l = LlamaConfig::llama70b();
        let fwd = fsdp_forward_stages(&l, 3);
        assert_eq!(fwd.stages.len(), 6);
        assert!(fwd.stages.iter().all(|s| s.gather.is_some() && s.reduce.is_none()));
        assert_eq!(
            fwd.stages[1].gather.unwrap().spec.size_bytes,
            l.mlp_weight_bytes(DType::Bf16)
        );
        let step = fsdp_step_stages(&l, 3);
        assert_eq!(step.stages.len(), 12);
        // Backward stages reduce-scatter their gradients.
        assert!(step.stages[6..].iter().all(|s| s.reduce.is_some()));
        assert_eq!(
            step.stages[6].reduce.unwrap().spec.kind,
            CollectiveKind::ReduceScatter
        );
        // Backward runs in reverse layer order.
        assert_eq!(step.stages[6].label, "layer2/bwd-mlp");
        let tp = tp_chain_stages(&l, 4);
        assert_eq!(tp.stages.len(), 4);
        assert_eq!(tp.stages_per_layer, 1);
        assert_eq!(
            tp.stages[0].gather.unwrap().spec.size_bytes,
            (l.tokens * l.hidden * 2) as u64
        );
    }

    #[test]
    fn serial_family_is_identity() {
        let m = m();
        let topo = topo1(&m);
        let t = fsdp_forward_stages(&LlamaConfig::llama70b(), 2);
        let r = run_e2e(&m, &topo, &t, 2, E2eFamily::Serial).unwrap();
        assert!((r.speedup - 1.0).abs() < 1e-12);
        assert!((r.total - r.serial).abs() < 1e-12);
        assert!(r.bubble == 0.0 && r.sdma_occupancy == 0.0);
        assert!(r.exposed_comm > 0.0 && r.exposed_comm < r.total);
    }

    #[test]
    fn prefetch_depth_2_beats_sum_of_pairs() {
        // The acceptance criterion: the continuous graph timeline of
        // the LLaMA-70B FSDP step with prefetch depth >= 2 must beat
        // the sum-of-pairs total under ConCCL — the pairwise model
        // serializes every gradient reduce-scatter (no second
        // collective slot) and cannot carry a gather across a stage
        // boundary; the graph realizes both overlaps.
        let m = m();
        let topo = topo1(&m);
        let t = fsdp_step_stages(&LlamaConfig::llama70b(), 3);
        let d2 = run_e2e(&m, &topo, &t, 2, E2eFamily::DmaOverlap).unwrap();
        let pairs = sum_of_pairs_total(&m, &topo, &t, Strategy::Conccl).unwrap();
        assert!(
            d2.total < pairs * 0.95,
            "graph depth-2 {:.3}ms should clearly beat sum-of-pairs {:.3}ms",
            d2.total * 1e3,
            pairs * 1e3
        );
        assert!(d2.speedup > 1.0, "overlap must pay: {:.3}", d2.speedup);
        // Deeper prefetch hides the long MLP-weight gathers that a
        // 1-layer window leaves exposed.
        let d1 = run_e2e(&m, &topo, &t, 1, E2eFamily::DmaOverlap).unwrap();
        assert!(
            d2.total < d1.total,
            "depth 2 ({:.3}ms) should beat depth 1 ({:.3}ms)",
            d2.total * 1e3,
            d1.total * 1e3
        );
        assert!(d2.exposed_comm <= d1.exposed_comm + 1e-12);
        // Forward-only: the graph pays the real first-gather fill and
        // the multi-gather interference the pairwise replay never
        // prices, so it tracks — but need not beat — the all-G-long
        // replay total.
        let fwd = fsdp_forward_stages(&LlamaConfig::llama70b(), 4);
        let g_fwd = run_e2e(&m, &topo, &fwd, 2, E2eFamily::DmaOverlap).unwrap();
        let legacy =
            replay(&m, &fsdp_forward_trace(&LlamaConfig::llama70b(), 4), Strategy::Conccl);
        assert!(
            g_fwd.total < legacy.total * 1.10,
            "graph fwd {:.3}ms vs replay {:.3}ms",
            g_fwd.total * 1e3,
            legacy.total * 1e3
        );
    }

    #[test]
    fn dma_family_beats_cu_family_and_uses_engines() {
        let m = m();
        let topo = topo1(&m);
        let t = fsdp_forward_stages(&LlamaConfig::llama70b(), 3);
        let dma = run_e2e(&m, &topo, &t, 2, E2eFamily::DmaOverlap).unwrap();
        let cu = run_e2e(&m, &topo, &t, 2, E2eFamily::CuOverlap).unwrap();
        assert!(
            dma.total <= cu.total * 1.001,
            "conccl e2e {:.3}ms vs cu {:.3}ms",
            dma.total * 1e3,
            cu.total * 1e3
        );
        assert!(dma.sdma_occupancy > 0.0);
        assert!((cu.sdma_occupancy - 0.0).abs() < 1e-12);
        assert!(cu.speedup > 0.9 && cu.speedup <= 2.5);
    }

    #[test]
    fn fsdp_step_runs_with_hybrid_reduce_scatter() {
        let m = m();
        let topo = topo1(&m);
        let fwd = fsdp_forward_stages(&LlamaConfig::llama70b(), 2);
        let step = fsdp_step_stages(&LlamaConfig::llama70b(), 2);
        let r_fwd = run_e2e(&m, &topo, &fwd, 2, E2eFamily::DmaOverlap).unwrap();
        let r_step = run_e2e(&m, &topo, &step, 2, E2eFamily::DmaOverlap).unwrap();
        assert!(r_step.total > r_fwd.total, "backward adds work");
        assert!(r_step.speedup > 0.9);
        assert_eq!(r_step.graph_nodes, 2 * r_fwd.graph_nodes + 4);
        // Gradient reduce-scatters overlap the backward compute but the
        // last one is exposed at the tail.
        assert!(r_step.exposed_comm > 0.0);
    }

    #[test]
    fn tp_chain_overlaps_rs_with_next_layer() {
        let m = m();
        let topo = topo1(&m);
        let t = tp_chain_stages(&LlamaConfig::llama70b(), 4);
        let r = run_e2e(&m, &topo, &t, 1, E2eFamily::DmaOverlap).unwrap();
        // Layer i's reduce-scatter overlaps layer i+1's gather/GEMM, so
        // the chain beats serial even though its gathers cannot be
        // prefetched.
        assert!(r.speedup > 1.0, "tp chain speedup {:.3}", r.speedup);
        assert!(r.speedup < 2.0);
    }

    #[test]
    fn multi_node_e2e_pays_the_nic() {
        let m = m();
        let t = fsdp_forward_stages(&LlamaConfig::llama70b(), 2);
        let r1 = run_e2e(&m, &m.topology(1), &t, 2, E2eFamily::DmaOverlap).unwrap();
        let r2 = run_e2e(&m, &m.topology(2), &t, 2, E2eFamily::DmaOverlap).unwrap();
        assert!(r2.total > r1.total, "NIC-bound gathers must lengthen the step");
        assert!(r2.exposed_comm > r1.exposed_comm);
    }

    #[test]
    fn spec_parse_round_trips_and_rejects_garbage() {
        let s = E2eSpec::parse("fsdp_step:70b:4:2").unwrap();
        assert_eq!(s.kind, E2eKind::FsdpStep);
        assert_eq!(s.layers, 4);
        assert_eq!(s.depth, 2);
        assert_eq!(s.label(), "fsdp_step-70b-l4-d2");
        // Defaults.
        let d = E2eSpec::parse("tp_chain").unwrap();
        assert_eq!((d.layers, d.depth, d.model_tag), (4, 2, "70b"));
        assert_eq!(E2eSpec::parse("fsdp_forward:405b").unwrap().model_tag, "405b");
        assert!(E2eSpec::parse("warp").is_err());
        assert!(E2eSpec::parse("fsdp_step:13b").is_err());
        assert!(E2eSpec::parse("fsdp_step:70b:0").is_err());
        assert!(E2eSpec::parse("fsdp_step:70b:4:2:9").is_err());
        // Family parsing.
        assert_eq!(E2eFamily::parse("dma").unwrap(), E2eFamily::DmaOverlap);
        assert!(E2eFamily::parse("x").is_err());
    }
}
