//! End-to-end multi-layer schedules on the workload-graph engine
//! (§II-C's *stream* of per-layer C3 stages, executed as one continuous
//! timeline instead of a sum of isolated pairs).
//!
//! Three workload families:
//!
//! * **`fsdp_forward`** — the sharded-transformer forward pass: each
//!   stage's weight all-gather must land before its GEMM; a
//!   *prefetch-depth* window (in layers) bounds how many weight gathers
//!   may be in flight concurrently, so `depth >= 2` overlaps a stage's
//!   gather with the *previous* layers' compute — overlap across stage
//!   boundaries that the sum-of-pairs replay cannot express.
//! * **`fsdp_step`** — forward plus backward: backward re-gathers the
//!   (resharded) weights under the same window and issues a gradient
//!   *reduce-scatter* per stage. Reduce-scatter cannot run on DMA
//!   engines (no arithmetic, §VI-B), so even the ConCCL family runs it
//!   on CUs — the §VII-A2 hybrid, end to end.
//! * **`tp_chain`** — a Megatron-style tensor-parallel layer chain:
//!   AG(activations) → GEMM → RS(partials) per layer, where layer
//!   `i+1`'s all-gather depends on layer `i`'s GEMM output and overlaps
//!   layer `i`'s reduce-scatter.
//!
//! Under the `dma_overlap` family, concurrent weight gathers contend
//! for the GPU's finite SDMA engines (the `sdma` fluid resource) and
//! for HBM bandwidth; the run reports end-to-end metrics the pairwise
//! path could not: exposed-communication time, bubble time, and
//! per-resource occupancy. The `auto` family replaces the uniform
//! family stamp with per-node annotations from the cost-model-driven
//! planner ([`crate::sched::policy`]): the graph builder here consumes
//! [`crate::sched::policy::StagePlan`]s, so the fixed families and the
//! planner share one construction.
//!
//! # Contract (where this layer sits)
//!
//! This module is the **workload layer**: it knows model shapes
//! ([`LlamaConfig`]) and training/serving semantics, and turns them into
//! [`crate::sched::graph::Graph`]s — it never touches the fluid
//! simulator directly. Everything below consumes what it emits:
//!
//! * **builders** (`*_stages`, [`build_graph_planned_with`],
//!   [`build_serial_chain_with`]) map an [`E2eTrace`] + per-stage plans
//!   to a task DAG; dependencies encode the workload's semantics
//!   (prefetch windows, activation chains), never scheduling policy;
//! * **runners** ([`run_e2e_planned_with`]) execute the DAG on the
//!   graph engine and report [`E2eRun`] metrics. The invariants the
//!   test suites pin: the serialized chain reproduces [`serial_total`]
//!   to ≤1e-9, and `E2eFamily::Auto` never loses to a fixed family
//!   (the planner's candidate set contains all of them).
//!
//! The serving-side analogue of this module is
//! [`crate::workload::serving`] (per-step decode graphs) driven by
//! [`crate::workload::traffic`] (the open-loop arrival engine).

use crate::conccl::DmaCollective;
use crate::config::machine::MachineConfig;
use crate::config::workload::{CollectiveKind, CollectiveSpec, DType};
use crate::error::Error;
use crate::fabric::Topology;
use crate::gpu::sdma::engine_demand;
use crate::kernels::{CollectiveKernel, GemmKernel};
use crate::sched::graph::{
    self, CommBackend, CommWork, CuPolicy, GemmWork, Graph, NodeSpec, PenaltyStyle, Ready, Work,
};
use crate::workload::llama::{gemm_by_tag, LlamaConfig};

/// Which end-to-end workload family a trace belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum E2eKind {
    FsdpForward,
    FsdpStep,
    TpChain,
}

impl E2eKind {
    /// Name used in CLI specs, JSON and gate keys.
    pub fn name(self) -> &'static str {
        match self {
            E2eKind::FsdpForward => "fsdp_forward",
            E2eKind::FsdpStep => "fsdp_step",
            E2eKind::TpChain => "tp_chain",
        }
    }
}

/// One stage of an end-to-end trace: a GEMM plus the collectives tied
/// to it (the weight/activation gather it consumes, the gradient/partial
/// reduce-scatter it produces).
#[derive(Debug, Clone)]
pub struct E2eStage {
    pub label: String,
    pub gemm: GemmKernel,
    pub gather: Option<CollectiveKernel>,
    pub reduce: Option<CollectiveKernel>,
}

/// A multi-layer end-to-end trace.
#[derive(Debug, Clone)]
pub struct E2eTrace {
    pub kind: E2eKind,
    pub model: &'static str,
    /// Stages per transformer layer (2 for FSDP attn+mlp, 1 for TP).
    pub stages_per_layer: usize,
    pub stages: Vec<E2eStage>,
}

fn fsdp_layer_kernels(l: &LlamaConfig) -> (GemmKernel, GemmKernel, u64, u64) {
    let (attn_tag, mlp_tag) = if l.hidden == 8192 { ("cb1", "mb1") } else { ("cb2", "mb2") };
    let attn_gemm = gemm_by_tag(attn_tag).expect("attn gemm");
    let mlp_gemm = gemm_by_tag(mlp_tag).expect("mlp gemm");
    (
        attn_gemm,
        mlp_gemm,
        l.attn_weight_bytes(DType::Bf16),
        l.mlp_weight_bytes(DType::Bf16),
    )
}

fn ag(bytes: u64) -> CollectiveKernel {
    CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, bytes))
}

fn rs(bytes: u64) -> CollectiveKernel {
    CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::ReduceScatter, bytes))
}

/// FSDP forward trace: per layer, an attention stage and an MLP stage,
/// each gathering its *own* stage's weights (the prefetch window decides
/// how far ahead the gathers run).
pub fn fsdp_forward_stages(l: &LlamaConfig, layers: usize) -> E2eTrace {
    assert!(layers >= 1, "need at least one layer");
    let (attn_gemm, mlp_gemm, attn_w, mlp_w) = fsdp_layer_kernels(l);
    let mut stages = Vec::with_capacity(2 * layers);
    for i in 0..layers {
        stages.push(E2eStage {
            label: format!("layer{i}/attn"),
            gemm: attn_gemm.clone(),
            gather: Some(ag(attn_w)),
            reduce: None,
        });
        stages.push(E2eStage {
            label: format!("layer{i}/mlp"),
            gemm: mlp_gemm.clone(),
            gather: Some(ag(mlp_w)),
            reduce: None,
        });
    }
    E2eTrace {
        kind: E2eKind::FsdpForward,
        model: l.name,
        stages_per_layer: 2,
        stages,
    }
}

/// FSDP training step: the forward stages plus a backward pass in
/// reverse layer order — each backward stage re-gathers its weights
/// (full resharding) and reduce-scatters its weight gradient. The
/// backward GEMM is modelled with the forward stage's kernel (the
/// dominant grad GEMMs share those shapes; Table I's cb2/cb3/mb2 *are*
/// grad GEMMs).
pub fn fsdp_step_stages(l: &LlamaConfig, layers: usize) -> E2eTrace {
    let mut t = fsdp_forward_stages(l, layers);
    t.kind = E2eKind::FsdpStep;
    let (attn_gemm, mlp_gemm, attn_w, mlp_w) = fsdp_layer_kernels(l);
    for i in (0..layers).rev() {
        t.stages.push(E2eStage {
            label: format!("layer{i}/bwd-mlp"),
            gemm: mlp_gemm.clone(),
            gather: Some(ag(mlp_w)),
            reduce: Some(rs(mlp_w)),
        });
        t.stages.push(E2eStage {
            label: format!("layer{i}/bwd-attn"),
            gemm: attn_gemm.clone(),
            gather: Some(ag(attn_w)),
            reduce: Some(rs(attn_w)),
        });
    }
    t
}

/// Megatron-style tensor-parallel layer chain: per layer, gather the
/// (sequence-sharded) activations, run the MLP GEMM, reduce-scatter the
/// partial outputs. Layer `i+1`'s gather depends on layer `i`'s GEMM
/// (an activation, not a weight — it cannot be prefetched) and overlaps
/// layer `i`'s reduce-scatter.
pub fn tp_chain_stages(l: &LlamaConfig, layers: usize) -> E2eTrace {
    assert!(layers >= 1, "need at least one layer");
    let (_, mlp_gemm, _, _) = fsdp_layer_kernels(l);
    let act = (l.tokens * l.hidden * DType::Bf16.bytes()) as u64;
    let stages = (0..layers)
        .map(|i| E2eStage {
            label: format!("layer{i}/tp"),
            gemm: mlp_gemm.clone(),
            gather: Some(ag(act)),
            reduce: Some(rs(act)),
        })
        .collect();
    E2eTrace {
        kind: E2eKind::TpChain,
        model: l.name,
        stages_per_layer: 1,
        stages,
    }
}

/// How an end-to-end trace's collectives execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum E2eFamily {
    /// Everything sequential on the RCCL baseline stack (speedup 1.0).
    Serial,
    /// Overlapped, collectives on CUs (the c3_sp discipline).
    CuOverlap,
    /// Overlapped, offloadable collectives on DMA engines (ConCCL);
    /// reduce-scatters stay on CUs (§VII-A2 hybrid).
    DmaOverlap,
    /// Per-node plan from the cost-model-driven planner
    /// ([`crate::sched::policy::Planner`]): backend / CU partition /
    /// chunk count / issue order decided per graph node, validated
    /// against the fixed families on the graph engine (never worse by
    /// construction).
    Auto,
}

impl E2eFamily {
    pub fn name(self) -> &'static str {
        match self {
            E2eFamily::Serial => "serial",
            E2eFamily::CuOverlap => "cu_overlap",
            E2eFamily::DmaOverlap => "dma_overlap",
            E2eFamily::Auto => "auto",
        }
    }

    /// The four families every e2e point is evaluated under.
    pub fn lineup() -> [E2eFamily; 4] {
        [
            E2eFamily::Serial,
            E2eFamily::CuOverlap,
            E2eFamily::DmaOverlap,
            E2eFamily::Auto,
        ]
    }

    /// Parse a CLI family name; `Err` (never a panic) on unknowns.
    pub fn parse(s: &str) -> Result<E2eFamily, Error> {
        match s {
            "serial" => Ok(E2eFamily::Serial),
            "cu" | "cu_overlap" => Ok(E2eFamily::CuOverlap),
            "dma" | "dma_overlap" | "conccl" => Ok(E2eFamily::DmaOverlap),
            "auto" | "planner" => Ok(E2eFamily::Auto),
            other => Err(Error::Config(format!(
                "unknown e2e family '{other}' (expected serial, cu_overlap, dma_overlap, auto)"
            ))),
        }
    }
}

/// Memoized collective wire pricing, shared across planner candidate
/// builds. Pricing a collective on a multi-node topology rebuilds its
/// hierarchical transfer plan, and the planner's candidates re-price
/// the same handful of (kind, bytes) kernels dozens of times over —
/// once per stage per candidate — so `run_auto` threads one pricer
/// through every `build_graph_planned_with` call. Keys are
/// `(kind, bytes)` for DMA transfers and `(kind, bytes, grant)` for CU
/// kernels (the grant changes the wire time); at these cache sizes a
/// linear scan beats hashing.
#[derive(Debug, Clone, Default)]
pub struct CommPricer {
    dma: Vec<((CollectiveKind, u64), f64)>,
    cu: Vec<((CollectiveKind, u64, u32), f64)>,
}

impl CommPricer {
    /// Fresh, empty pricing memo.
    pub fn new() -> CommPricer {
        CommPricer::default()
    }

    /// Wire time of a DMA transfer, memoized on (kind, bytes).
    fn dma_wire(&mut self, m: &MachineConfig, topo: &Topology, d: &DmaCollective) -> f64 {
        let key = (d.spec.kind, d.spec.size_bytes);
        if let Some(&(_, w)) = self.dma.iter().find(|&&(k, _)| k == key) {
            return w;
        }
        let w = d.wire_time_on(m, topo);
        self.dma.push((key, w));
        w
    }

    /// Wire time of a CU collective at a given CU grant, memoized on
    /// (kind, bytes, grant).
    fn cu_wire(
        &mut self,
        m: &MachineConfig,
        topo: &Topology,
        kernel: &CollectiveKernel,
        grant: u32,
    ) -> f64 {
        let key = (kernel.spec.kind, kernel.spec.size_bytes, grant);
        if let Some(&(_, w)) = self.cu.iter().find(|&&(k, _)| k == key) {
            return w;
        }
        let w = kernel.t_wire_on(m, topo, grant);
        self.cu.push((key, w));
        w
    }
}

/// Build a comm node for an e2e graph (executor-style derivations:
/// wire, HBM demand, §VII-A1 share, engine occupancy). `cu_grant` is
/// the CU reservation while resident on the CU backend (the planner's
/// §V-C pick; the family stamps pass the kernel's full need, which
/// reproduces the pre-planner numbers exactly).
pub(crate) fn comm_node(
    m: &MachineConfig,
    topo: &Topology,
    kernel: CollectiveKernel,
    dma: bool,
    cu_grant: u32,
    pricer: &mut CommPricer,
) -> Result<(Work, Ready), Error> {
    let kind = kernel.spec.kind;
    if dma {
        let d = DmaCollective::try_new(kernel.spec)?;
        let wire = pricer.dma_wire(m, topo, &d);
        Ok((
            Work::Comm(CommWork {
                kernel,
                backend: CommBackend::Dma {
                    wire,
                    engines: engine_demand(m),
                },
                hbm: d.hbm_traffic(m),
                share: kernel.hbm_share_with_wire(m, wire),
                pollution: 0.0,
                co_penalty: m.comm_co_penalty(kind),
                sync: m.sdma.sync_s,
                pen_style: PenaltyStyle::RateScaled,
            }),
            Ready::Queue {
                queue: 0,
                hold: m.sdma.issue_hold(m.num_gpus),
                post: m.sdma.fetch_s,
            },
        ))
    } else {
        let grant = cu_grant.max(1);
        let wire = pricer.cu_wire(m, topo, &kernel, grant);
        Ok((
            Work::Comm(CommWork {
                kernel,
                backend: CommBackend::Cu {
                    backlog_cus: grant,
                    overlap_cus: grant,
                    solo_cus: grant,
                    backlog_until: 0.0,
                    wire_fixed: None,
                },
                hbm: kernel.hbm_traffic(m),
                share: kernel.hbm_share_with_wire(m, wire),
                pollution: m.l2_pollution(kind),
                co_penalty: m.comm_co_penalty(kind),
                sync: 0.0,
                pen_style: PenaltyStyle::RateScaled,
            }),
            Ready::AfterDeps {
                lag: m.coll_launch_s,
            },
        ))
    }
}

/// Delay a comm node's issue by `defer` seconds (the §V-C ordering
/// decision: when the plan schedules the GEMM first, the collective's
/// launch/enqueue waits out the GEMM's launch slot on the CPU).
fn defer_ready(ready: Ready, defer: f64) -> Ready {
    if defer <= 0.0 {
        return ready;
    }
    match ready {
        Ready::AfterDeps { lag } => Ready::AfterDeps { lag: lag + defer },
        Ready::Queue { queue, hold, post } => Ready::Queue {
            queue,
            hold: hold + defer,
            post,
        },
        other => other,
    }
}

/// Append one planned collective to the graph: a single comm node, or —
/// when the plan asks for `chunks >= 2` — a serialized chunk chain
/// (per-chunk transfers riding the shared enqueue queue, §VII-A1
/// interference relieved by `MachineConfig::chunk_align` exactly as in
/// the pairwise chunked pipeline). `defer` delays the (first) issue —
/// the plan's `comm_first = false` case. Returns the node id
/// dependents wait on (the last chunk).
#[allow(clippy::too_many_arguments)]
pub(crate) fn push_planned_comm(
    g: &mut Graph,
    m: &MachineConfig,
    topo: &Topology,
    label: &str,
    kernel: &CollectiveKernel,
    plan: crate::sched::policy::CollPlan,
    issue_deps: Vec<usize>,
    defer: f64,
    pricer: &mut CommPricer,
) -> Result<usize, Error> {
    use crate::sched::policy::PlanBackend;
    let dma = plan.backend == PlanBackend::Dma && kernel.spec.kind.dma_offloadable();
    // Defensive clamps mirroring the pairwise chunked path: at least
    // one byte per chunk, never beyond the machine's candidate cap.
    let k = plan
        .chunks
        .min(m.max_chunks.max(1))
        .min(kernel.spec.size_bytes.min(u32::MAX as u64) as u32)
        .max(1);
    if k <= 1 {
        let (work, ready) = comm_node(m, topo, *kernel, dma, plan.cus, pricer)?;
        return Ok(g.push(NodeSpec {
            label: label.to_string(),
            work,
            issue_deps,
            serial_deps: Vec::new(),
            ready: defer_ready(ready, defer),
        }));
    }
    let align = m.chunk_align(k);
    // The §VII-A1 share a collective inflicts is derived from its
    // whole-kernel wire time (chunks are a scheduling decision, not a
    // bandwidth decision) — same derivation as `sched::graph::chunked`.
    let whole_wire = if dma {
        pricer.dma_wire(m, topo, &DmaCollective::try_new(kernel.spec)?)
    } else {
        pricer.cu_wire(m, topo, kernel, plan.cus.max(1))
    };
    let share = kernel.hbm_share_with_wire(m, whole_wire);
    let mut last = None;
    for (ci, sz) in crate::sched::chunk_sizes(kernel.spec.size_bytes, k)
        .into_iter()
        .enumerate()
    {
        let chunk = CollectiveKernel::new(CollectiveSpec::new(kernel.spec.kind, sz));
        let (mut work, ready) = comm_node(m, topo, chunk, dma, plan.cus, pricer)?;
        if let Work::Comm(cw) = &mut work {
            cw.pen_style = PenaltyStyle::Aligned(align);
            cw.share = share;
        }
        let serial_deps = match last {
            Some(prev) => vec![prev],
            None => Vec::new(),
        };
        // Only the first chunk waits out a GEMM-first launch slot; the
        // rest pipeline behind it.
        let ready = if ci == 0 { defer_ready(ready, defer) } else { ready };
        last = Some(g.push(NodeSpec {
            label: format!("{label}#{ci}"),
            work,
            issue_deps: issue_deps.clone(),
            serial_deps,
            ready,
        }));
    }
    Ok(last.expect("chunk chain is non-empty"))
}

/// A planned e2e graph plus its stage→node index: `stage_nodes[s]` is
/// the id of the first node emitted for stage `s` (nodes are emitted
/// stage by stage, so stage `s` owns ids `stage_nodes[s]
/// .. stage_nodes[s + 1]`), with a trailing sentinel equal to
/// `graph.nodes.len()`. Because the builder is deterministic in the
/// per-stage plan, two candidates whose [`StagePlan`]s agree on stages
/// `0..s` produce byte-identical node prefixes `0..stage_nodes[s]` —
/// the invariant the planner's prefix-memoized re-simulation
/// ([`crate::sched::graph::execute_resuming`]) rests on.
///
/// [`StagePlan`]: crate::sched::policy::StagePlan
#[derive(Debug, Clone)]
pub struct PlannedGraph {
    pub graph: Graph,
    pub stage_nodes: Vec<usize>,
}

/// Build the workload graph of an e2e trace from **per-stage planner
/// annotations** ([`crate::sched::policy::StagePlan`]): collective
/// backend, CU grants, chunk counts and GEMM CU policy are read from
/// the plan instead of a uniform family stamp. `depth` is the prefetch
/// window in *layers*: up to `depth × stages_per_layer` stages' weight
/// gathers may be in flight ahead of the compute consuming them (a
/// stage's weights are freed when its GEMM completes, which opens the
/// slot for the gather `window` stages later). TP-chain gathers carry a
/// data dependency on the previous GEMM instead — activations cannot
/// be prefetched.
pub fn build_graph_planned(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    depth: usize,
    stages: &[crate::sched::policy::StagePlan],
) -> Result<Graph, Error> {
    Ok(build_graph_planned_with(m, topo, trace, depth, stages, &mut CommPricer::new())?.graph)
}

/// [`build_graph_planned`] with a caller-owned pricing memo and the
/// stage→node index the planner's memoized re-simulation needs. The
/// pricer only caches pure wire-time derivations, so sharing one across
/// candidate builds changes nothing about the produced graphs.
pub fn build_graph_planned_with(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    depth: usize,
    stages: &[crate::sched::policy::StagePlan],
    pricer: &mut CommPricer,
) -> Result<PlannedGraph, Error> {
    assert_eq!(
        stages.len(),
        trace.stages.len(),
        "plan must annotate every stage"
    );
    let cus = m.cus_total();
    let window = trace.stages_per_layer * depth.max(1);
    let mut g = Graph::default();
    let mut stage_nodes: Vec<usize> = Vec::with_capacity(trace.stages.len() + 1);
    let mut gemm_ids: Vec<usize> = Vec::with_capacity(trace.stages.len());
    for (s, (stage, plan)) in trace.stages.iter().zip(stages).enumerate() {
        stage_nodes.push(g.nodes.len());
        let gather_id = match (&stage.gather, plan.gather) {
            (Some(k), Some(cp)) => {
                let issue_deps = match trace.kind {
                    // Activation dependency: the previous layer must
                    // have computed before its output can be gathered.
                    E2eKind::TpChain => match s.checked_sub(1) {
                        Some(i) => vec![gemm_ids[i]],
                        None => Vec::new(),
                    },
                    // Prefetch window: a stage's gathered weights live
                    // until its GEMM consumes them, so gather `s` may
                    // issue once the stage `window` back has been
                    // computed (freeing its weight buffer). At most
                    // `depth` layers' gathers are in flight.
                    _ => match s.checked_sub(window) {
                        Some(i) => vec![gemm_ids[i]],
                        None => Vec::new(),
                    },
                };
                // §V-C issue order: when the plan schedules the GEMM
                // first (tiny compute, `comm_first = false`), the
                // gather's launch waits out the GEMM's launch slot.
                let defer = if plan.comm_first { 0.0 } else { m.kernel_launch_s };
                Some(push_planned_comm(
                    &mut g,
                    m,
                    topo,
                    &format!("{}/gather", stage.label),
                    k,
                    cp,
                    issue_deps,
                    defer,
                    pricer,
                )?)
            }
            (None, None) => None,
            // A plan that annotates a collective the trace lacks (or
            // vice versa) must fail loudly — silently dropping the node
            // would report a bogusly fast timeline.
            _ => {
                return Err(Error::Config(format!(
                    "plan/trace mismatch at stage '{}': gather presence differs",
                    stage.label
                )))
            }
        };
        let mut deps = Vec::new();
        if let Some(&prev) = gemm_ids.last() {
            deps.push(prev);
        }
        if let Some(gid) = gather_id {
            deps.push(gid);
        }
        let cu_policy = match plan.gemm_cus {
            Some(k) => CuPolicy::Fixed(k.max(8)),
            None => CuPolicy::Residual,
        };
        let gemm_id = g.push(NodeSpec {
            label: format!("{}/gemm", stage.label),
            work: Work::Gemm(GemmWork {
                comp: stage.gemm.clone(),
                mem: stage.gemm.clone(),
                frac: 1.0,
                share: stage.gemm.hbm_share(m, cus),
                cu_policy,
                pen_style: PenaltyStyle::RateScaled,
            }),
            issue_deps: deps,
            serial_deps: Vec::new(),
            ready: Ready::AfterDeps {
                lag: m.kernel_launch_s,
            },
        });
        gemm_ids.push(gemm_id);
        match (&stage.reduce, plan.reduce) {
            (Some(k), Some(cp)) => {
                // Reduce-scatter is never DMA-offloadable: the planner
                // pins it to CUs (§VII-A2 hybrid) and the builder
                // enforces it. (It already issues after its GEMM, so
                // the stage's comm-first decision does not apply here.)
                push_planned_comm(
                    &mut g,
                    m,
                    topo,
                    &format!("{}/reduce", stage.label),
                    k,
                    cp,
                    vec![gemm_id],
                    0.0,
                    pricer,
                )?;
            }
            (None, None) => {}
            _ => {
                return Err(Error::Config(format!(
                    "plan/trace mismatch at stage '{}': reduce presence differs",
                    stage.label
                )))
            }
        }
    }
    stage_nodes.push(g.nodes.len());
    Ok(PlannedGraph {
        graph: g,
        stage_nodes,
    })
}

/// Build the workload graph of an e2e trace under a fixed overlap
/// family: the uniform whole-graph stamp, expressed as planner
/// annotations ([`crate::sched::policy::family_stages`]) so the stamp
/// and the per-node planner share one builder.
pub fn build_graph(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    depth: usize,
    family: E2eFamily,
) -> Result<Graph, Error> {
    assert!(
        matches!(family, E2eFamily::CuOverlap | E2eFamily::DmaOverlap),
        "build_graph takes a fixed overlap family (serial is analytic; auto runs the planner)"
    );
    let stages = crate::sched::policy::family_stages(m, trace, family);
    build_graph_planned(m, topo, trace, depth, &stages)
}

/// Fully serialized all-CU chain of a trace: every node issue-depends
/// on its predecessor, so nothing overlaps and the timeline reproduces
/// [`serial_total`] exactly (same launch lags, same isolated rates).
/// This is the planner's "do not overlap at all" candidate — it bounds
/// `E2eFamily::Auto` at the serial baseline even in regimes where every
/// overlap family loses (deep NIC-bound topologies).
pub fn build_serial_chain(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
) -> Result<Graph, Error> {
    build_serial_chain_with(m, topo, trace, &mut CommPricer::new())
}

/// [`build_serial_chain`] with a caller-owned pricing memo (shared with
/// the overlap candidates' builds in [`crate::sched::Planner::run_auto`]).
pub fn build_serial_chain_with(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    pricer: &mut CommPricer,
) -> Result<Graph, Error> {
    let mut g = Graph::default();
    let mut prev: Option<usize> = None;
    let chain = |prev: &Option<usize>| prev.map(|p| vec![p]).unwrap_or_default();
    for stage in &trace.stages {
        if let Some(k) = &stage.gather {
            let (work, ready) = comm_node(m, topo, *k, false, k.cu_need(m), pricer)?;
            prev = Some(g.push(NodeSpec {
                label: format!("{}/gather", stage.label),
                work,
                issue_deps: chain(&prev),
                serial_deps: Vec::new(),
                ready,
            }));
        }
        prev = Some(g.push(NodeSpec {
            label: format!("{}/gemm", stage.label),
            work: Work::Gemm(GemmWork {
                comp: stage.gemm.clone(),
                mem: stage.gemm.clone(),
                frac: 1.0,
                share: stage.gemm.hbm_share(m, m.cus_total()),
                cu_policy: CuPolicy::Residual,
                pen_style: PenaltyStyle::RateScaled,
            }),
            issue_deps: chain(&prev),
            serial_deps: Vec::new(),
            ready: Ready::AfterDeps {
                lag: m.kernel_launch_s,
            },
        }));
        if let Some(k) = &stage.reduce {
            let (work, ready) = comm_node(m, topo, *k, false, k.cu_need(m), pricer)?;
            prev = Some(g.push(NodeSpec {
                label: format!("{}/reduce", stage.label),
                work,
                issue_deps: chain(&prev),
                serial_deps: Vec::new(),
                ready,
            }));
        }
    }
    Ok(g)
}

/// Sum-of-pairs baseline of a trace under a pairwise strategy: each
/// stage priced as an isolated (GEMM ∥ gather) pair by the pairwise
/// executor — the pre-graph `trace::replay` model — plus the stage's
/// reduce-scatter serialized after the pair (the pairwise timeline has
/// exactly one compute and one collective slot per stage, so a second
/// concurrent collective is inexpressible there). The workload graph's
/// advantage over this number is overlap the pairwise model cannot
/// realize: gathers prefetched across stage boundaries and gradient
/// reduce-scatters hidden under subsequent backward compute.
pub fn sum_of_pairs_total(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    strategy: crate::sched::Strategy,
) -> Result<f64, Error> {
    let exec = crate::sched::C3Executor::with_topology(m.clone(), *topo);
    let cus = m.cus_total();
    let mut total = 0.0;
    for stage in &trace.stages {
        total += match &stage.gather {
            Some(k) => {
                let sc = crate::workload::ResolvedScenario {
                    scenario: crate::config::workload::C3Scenario {
                        gemm_tag: stage.gemm.tag.clone(),
                        gemm: stage.gemm.shape,
                        comm: k.spec,
                        source: crate::config::workload::Source::Synthetic,
                    },
                    gemm: stage.gemm.clone(),
                    comm: *k,
                    paper_type: crate::workload::taxonomy::C3Type::GLong,
                };
                exec.try_run(&sc, strategy)?.total
            }
            None => stage.gemm.time_isolated(m, cus),
        };
        if let Some(r) = &stage.reduce {
            total += r.time_isolated_full_on(m, topo);
        }
    }
    Ok(total)
}

/// Serial baseline of a trace: every stage's GEMM and collectives run
/// back-to-back in isolation on the RCCL baseline stack.
pub fn serial_total(m: &MachineConfig, topo: &Topology, trace: &E2eTrace) -> f64 {
    let cus = m.cus_total();
    trace
        .stages
        .iter()
        .map(|s| {
            s.gemm.time_isolated(m, cus)
                + s.gather.map_or(0.0, |k| k.time_isolated_full_on(m, topo))
                + s.reduce.map_or(0.0, |k| k.time_isolated_full_on(m, topo))
        })
        .sum()
}

/// Result of one end-to-end graph run.
#[derive(Debug, Clone, Copy)]
pub struct E2eRun {
    pub family: E2eFamily,
    /// End-to-end makespan, seconds.
    pub total: f64,
    /// Serial baseline (sum of isolated stage times).
    pub serial: f64,
    /// Speedup over the serial schedule.
    pub speedup: f64,
    /// Communication time not hidden under any compute.
    pub exposed_comm: f64,
    /// Time covered by neither compute nor communication.
    pub bubble: f64,
    /// Fraction of achievable HBM byte-capacity consumed.
    pub hbm_occupancy: f64,
    /// Fraction of SDMA engine-seconds consumed.
    pub sdma_occupancy: f64,
    /// Nodes in the executed graph (0 for the analytic serial family).
    pub graph_nodes: usize,
    /// Fluid event-loop counters for the executed graph (zeros for the
    /// analytic serial family and for cache-replayed records, which
    /// simulate nothing; for `auto`, accumulated over every candidate
    /// simulation the planner ran).
    pub counters: crate::sim::SimCounters,
}

/// [`run_e2e_planned`] with a caller-provided planner — THE one Auto
/// dispatch site (the sweep engine reuses one planner, and thus one
/// cost-model profile, per (machine, topology) across its whole e2e
/// axis). The planner carries its machine and topology.
pub fn run_e2e_planned_with(
    planner: &crate::sched::Planner,
    trace: &E2eTrace,
    depth: usize,
    family: E2eFamily,
) -> Result<(E2eRun, Option<crate::sched::PlanSummary>), Error> {
    if family == E2eFamily::Auto {
        let (run, plan) = planner.run_auto(trace, depth)?;
        return Ok((run, Some(plan)));
    }
    run_e2e(&planner.cost.m, &planner.cost.topo, trace, depth, family).map(|r| (r, None))
}

/// Evaluate one trace under one family at one prefetch depth,
/// returning the plan summary alongside the run when the family is
/// planner-driven (`Auto`); fixed families carry no plan.
pub fn run_e2e_planned(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    depth: usize,
    family: E2eFamily,
) -> Result<(E2eRun, Option<crate::sched::PlanSummary>), Error> {
    if family == E2eFamily::Auto {
        return run_e2e_planned_with(&crate::sched::Planner::new(m, topo), trace, depth, family);
    }
    run_e2e(m, topo, trace, depth, family).map(|r| (r, None))
}

/// Evaluate one trace under one family at one prefetch depth.
pub fn run_e2e(
    m: &MachineConfig,
    topo: &Topology,
    trace: &E2eTrace,
    depth: usize,
    family: E2eFamily,
) -> Result<E2eRun, Error> {
    if family == E2eFamily::Auto {
        // The planner path lives in `run_e2e_planned_with` (which only
        // calls back here for fixed families — no recursion).
        return run_e2e_planned(m, topo, trace, depth, family).map(|(run, _)| run);
    }
    let serial = serial_total(m, topo, trace);
    if family == E2eFamily::Serial {
        let comm: f64 = trace
            .stages
            .iter()
            .map(|s| {
                s.gather.map_or(0.0, |k| k.time_isolated_full_on(m, topo))
                    + s.reduce.map_or(0.0, |k| k.time_isolated_full_on(m, topo))
            })
            .sum();
        let hbm_bytes: f64 = trace
            .stages
            .iter()
            .map(|s| {
                s.gemm.hbm_traffic(m, m.cus_total())
                    + s.gather.map_or(0.0, |k| k.hbm_traffic(m))
                    + s.reduce.map_or(0.0, |k| k.hbm_traffic(m))
            })
            .sum();
        return Ok(E2eRun {
            family,
            total: serial,
            serial,
            speedup: 1.0,
            exposed_comm: comm,
            bubble: 0.0,
            hbm_occupancy: if serial > 0.0 {
                (hbm_bytes / (m.hbm_bw_achievable() * serial)).min(1.0)
            } else {
                0.0
            },
            sdma_occupancy: 0.0,
            graph_nodes: 0,
            counters: crate::sim::SimCounters::default(),
        });
    }
    let g = build_graph(m, topo, trace, depth, family)?;
    let r = graph::execute(m, topo, &g)?;
    Ok(E2eRun {
        family,
        total: r.total,
        serial,
        speedup: serial / r.total,
        exposed_comm: r.exposed_comm,
        bubble: r.bubble,
        hbm_occupancy: r.hbm_occupancy,
        sdma_occupancy: r.sdma_occupancy,
        graph_nodes: g.nodes.len(),
        counters: r.counters,
    })
}

/// One point of the sweep's end-to-end workload axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E2eSpec {
    pub kind: E2eKind,
    pub model: LlamaConfig,
    pub model_tag: &'static str,
    pub layers: usize,
    pub depth: usize,
}

impl E2eSpec {
    /// Parse a CLI axis entry: `workload[:model[:layers[:depth]]]`,
    /// e.g. `fsdp_step:70b:4:2` (defaults: 70b, 4 layers, depth 2).
    pub fn parse(s: &str) -> Result<E2eSpec, Error> {
        let mut it = s.split(':');
        let kind = match it.next().unwrap_or("") {
            "fsdp_forward" | "fsdp_fwd" => E2eKind::FsdpForward,
            "fsdp_step" | "fsdp" => E2eKind::FsdpStep,
            "tp_chain" | "tp" => E2eKind::TpChain,
            other => {
                return Err(Error::Config(format!(
                    "unknown e2e workload '{other}' (expected fsdp_forward, fsdp_step, tp_chain)"
                )))
            }
        };
        let (model, model_tag) = match it.next().unwrap_or("70b") {
            "70b" => (LlamaConfig::llama70b(), "70b"),
            "405b" => (LlamaConfig::llama405b(), "405b"),
            other => {
                return Err(Error::Config(format!(
                    "unknown e2e model '{other}' (expected 70b or 405b)"
                )))
            }
        };
        let parse_pos = |v: Option<&str>, what: &str, default: usize| -> Result<usize, Error> {
            match v {
                None => Ok(default),
                Some(raw) => raw
                    .parse::<usize>()
                    .ok()
                    .filter(|&x| x >= 1)
                    .ok_or_else(|| {
                        Error::Config(format!("e2e {what} '{raw}': expected a positive integer"))
                    }),
            }
        };
        let layers = parse_pos(it.next(), "layer count", 4)?;
        let depth = parse_pos(it.next(), "prefetch depth", 2)?;
        if let Some(extra) = it.next() {
            return Err(Error::Config(format!(
                "e2e spec '{s}': unexpected trailing segment '{extra}'"
            )));
        }
        Ok(E2eSpec {
            kind,
            model,
            model_tag,
            layers,
            depth,
        })
    }

    /// Stable label used in JSON and gate keys (no `/`).
    pub fn label(&self) -> String {
        format!(
            "{}-{}-l{}-d{}",
            self.kind.name(),
            self.model_tag,
            self.layers,
            self.depth
        )
    }

    /// Materialize the trace.
    pub fn trace(&self) -> E2eTrace {
        match self.kind {
            E2eKind::FsdpForward => fsdp_forward_stages(&self.model, self.layers),
            E2eKind::FsdpStep => fsdp_step_stages(&self.model, self.layers),
            E2eKind::TpChain => tp_chain_stages(&self.model, self.layers),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Strategy;
    use crate::workload::trace::{fsdp_forward_trace, replay};

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn topo1(m: &MachineConfig) -> Topology {
        m.topology(1)
    }

    #[test]
    fn traces_have_expected_structure() {
        let l = LlamaConfig::llama70b();
        let fwd = fsdp_forward_stages(&l, 3);
        assert_eq!(fwd.stages.len(), 6);
        assert!(fwd.stages.iter().all(|s| s.gather.is_some() && s.reduce.is_none()));
        assert_eq!(
            fwd.stages[1].gather.unwrap().spec.size_bytes,
            l.mlp_weight_bytes(DType::Bf16)
        );
        let step = fsdp_step_stages(&l, 3);
        assert_eq!(step.stages.len(), 12);
        // Backward stages reduce-scatter their gradients.
        assert!(step.stages[6..].iter().all(|s| s.reduce.is_some()));
        assert_eq!(
            step.stages[6].reduce.unwrap().spec.kind,
            CollectiveKind::ReduceScatter
        );
        // Backward runs in reverse layer order.
        assert_eq!(step.stages[6].label, "layer2/bwd-mlp");
        let tp = tp_chain_stages(&l, 4);
        assert_eq!(tp.stages.len(), 4);
        assert_eq!(tp.stages_per_layer, 1);
        assert_eq!(
            tp.stages[0].gather.unwrap().spec.size_bytes,
            (l.tokens * l.hidden * 2) as u64
        );
    }

    #[test]
    fn serial_family_is_identity() {
        let m = m();
        let topo = topo1(&m);
        let t = fsdp_forward_stages(&LlamaConfig::llama70b(), 2);
        let r = run_e2e(&m, &topo, &t, 2, E2eFamily::Serial).unwrap();
        assert!((r.speedup - 1.0).abs() < 1e-12);
        assert!((r.total - r.serial).abs() < 1e-12);
        assert!(r.bubble == 0.0 && r.sdma_occupancy == 0.0);
        assert!(r.exposed_comm > 0.0 && r.exposed_comm < r.total);
    }

    #[test]
    fn prefetch_depth_2_beats_sum_of_pairs() {
        // The acceptance criterion: the continuous graph timeline of
        // the LLaMA-70B FSDP step with prefetch depth >= 2 must beat
        // the sum-of-pairs total under ConCCL — the pairwise model
        // serializes every gradient reduce-scatter (no second
        // collective slot) and cannot carry a gather across a stage
        // boundary; the graph realizes both overlaps.
        let m = m();
        let topo = topo1(&m);
        let t = fsdp_step_stages(&LlamaConfig::llama70b(), 3);
        let d2 = run_e2e(&m, &topo, &t, 2, E2eFamily::DmaOverlap).unwrap();
        let pairs = sum_of_pairs_total(&m, &topo, &t, Strategy::Conccl).unwrap();
        assert!(
            d2.total < pairs * 0.95,
            "graph depth-2 {:.3}ms should clearly beat sum-of-pairs {:.3}ms",
            d2.total * 1e3,
            pairs * 1e3
        );
        assert!(d2.speedup > 1.0, "overlap must pay: {:.3}", d2.speedup);
        // Deeper prefetch hides the long MLP-weight gathers that a
        // 1-layer window leaves exposed.
        let d1 = run_e2e(&m, &topo, &t, 1, E2eFamily::DmaOverlap).unwrap();
        assert!(
            d2.total < d1.total,
            "depth 2 ({:.3}ms) should beat depth 1 ({:.3}ms)",
            d2.total * 1e3,
            d1.total * 1e3
        );
        assert!(d2.exposed_comm <= d1.exposed_comm + 1e-12);
        // Forward-only: the graph pays the real first-gather fill and
        // the multi-gather interference the pairwise replay never
        // prices, so it tracks — but need not beat — the all-G-long
        // replay total.
        let fwd = fsdp_forward_stages(&LlamaConfig::llama70b(), 4);
        let g_fwd = run_e2e(&m, &topo, &fwd, 2, E2eFamily::DmaOverlap).unwrap();
        let legacy =
            replay(&m, &fsdp_forward_trace(&LlamaConfig::llama70b(), 4), Strategy::Conccl);
        assert!(
            g_fwd.total < legacy.total * 1.10,
            "graph fwd {:.3}ms vs replay {:.3}ms",
            g_fwd.total * 1e3,
            legacy.total * 1e3
        );
    }

    #[test]
    fn dma_family_beats_cu_family_and_uses_engines() {
        let m = m();
        let topo = topo1(&m);
        let t = fsdp_forward_stages(&LlamaConfig::llama70b(), 3);
        let dma = run_e2e(&m, &topo, &t, 2, E2eFamily::DmaOverlap).unwrap();
        let cu = run_e2e(&m, &topo, &t, 2, E2eFamily::CuOverlap).unwrap();
        assert!(
            dma.total <= cu.total * 1.001,
            "conccl e2e {:.3}ms vs cu {:.3}ms",
            dma.total * 1e3,
            cu.total * 1e3
        );
        assert!(dma.sdma_occupancy > 0.0);
        assert!((cu.sdma_occupancy - 0.0).abs() < 1e-12);
        assert!(cu.speedup > 0.9 && cu.speedup <= 2.5);
    }

    #[test]
    fn fsdp_step_runs_with_hybrid_reduce_scatter() {
        let m = m();
        let topo = topo1(&m);
        let fwd = fsdp_forward_stages(&LlamaConfig::llama70b(), 2);
        let step = fsdp_step_stages(&LlamaConfig::llama70b(), 2);
        let r_fwd = run_e2e(&m, &topo, &fwd, 2, E2eFamily::DmaOverlap).unwrap();
        let r_step = run_e2e(&m, &topo, &step, 2, E2eFamily::DmaOverlap).unwrap();
        assert!(r_step.total > r_fwd.total, "backward adds work");
        assert!(r_step.speedup > 0.9);
        assert_eq!(r_step.graph_nodes, 2 * r_fwd.graph_nodes + 4);
        // Gradient reduce-scatters overlap the backward compute but the
        // last one is exposed at the tail.
        assert!(r_step.exposed_comm > 0.0);
    }

    #[test]
    fn tp_chain_overlaps_rs_with_next_layer() {
        let m = m();
        let topo = topo1(&m);
        let t = tp_chain_stages(&LlamaConfig::llama70b(), 4);
        let r = run_e2e(&m, &topo, &t, 1, E2eFamily::DmaOverlap).unwrap();
        // Layer i's reduce-scatter overlaps layer i+1's gather/GEMM, so
        // the chain beats serial even though its gathers cannot be
        // prefetched.
        assert!(r.speedup > 1.0, "tp chain speedup {:.3}", r.speedup);
        assert!(r.speedup < 2.0);
    }

    #[test]
    fn multi_node_e2e_pays_the_nic() {
        let m = m();
        let t = fsdp_forward_stages(&LlamaConfig::llama70b(), 2);
        let r1 = run_e2e(&m, &m.topology(1), &t, 2, E2eFamily::DmaOverlap).unwrap();
        let r2 = run_e2e(&m, &m.topology(2), &t, 2, E2eFamily::DmaOverlap).unwrap();
        assert!(r2.total > r1.total, "NIC-bound gathers must lengthen the step");
        assert!(r2.exposed_comm > r1.exposed_comm);
    }

    #[test]
    fn spec_parse_round_trips_and_rejects_garbage() {
        let s = E2eSpec::parse("fsdp_step:70b:4:2").unwrap();
        assert_eq!(s.kind, E2eKind::FsdpStep);
        assert_eq!(s.layers, 4);
        assert_eq!(s.depth, 2);
        assert_eq!(s.label(), "fsdp_step-70b-l4-d2");
        // Defaults.
        let d = E2eSpec::parse("tp_chain").unwrap();
        assert_eq!((d.layers, d.depth, d.model_tag), (4, 2, "70b"));
        assert_eq!(E2eSpec::parse("fsdp_forward:405b").unwrap().model_tag, "405b");
        assert!(E2eSpec::parse("warp").is_err());
        assert!(E2eSpec::parse("fsdp_step:13b").is_err());
        assert!(E2eSpec::parse("fsdp_step:70b:0").is_err());
        assert!(E2eSpec::parse("fsdp_step:70b:4:2:9").is_err());
        // Family parsing.
        assert_eq!(E2eFamily::parse("dma").unwrap(), E2eFamily::DmaOverlap);
        assert_eq!(E2eFamily::parse("auto").unwrap(), E2eFamily::Auto);
        assert!(E2eFamily::parse("x").is_err());
        // The lineup carries all four families, auto last (tables and
        // JSON list the planner row after the fixed baselines).
        assert_eq!(E2eFamily::lineup().len(), 4);
        assert_eq!(*E2eFamily::lineup().last().unwrap(), E2eFamily::Auto);
    }

    #[test]
    fn serial_chain_reproduces_serial_total() {
        // The planner's "do not overlap" candidate must price exactly
        // like the analytic serial baseline: same launch lags, same
        // isolated rates, nothing concurrent.
        let m = m();
        let t = fsdp_step_stages(&LlamaConfig::llama70b(), 2);
        for nodes in [1usize, 2] {
            let topo = m.topology(nodes);
            let g = build_serial_chain(&m, &topo, &t).unwrap();
            let run = crate::sched::graph::execute(&m, &topo, &g).unwrap();
            let serial = serial_total(&m, &topo, &t);
            assert!(
                (run.total - serial).abs() / serial < 1e-9,
                "{nodes}n: chain {} vs serial {}",
                run.total,
                serial
            );
        }
    }

    #[test]
    fn mismatched_plan_is_a_typed_error() {
        // A plan that drops a collective the trace carries must fail
        // loudly, never silently simulate a faster timeline.
        let m = m();
        let topo = topo1(&m);
        let t = fsdp_step_stages(&LlamaConfig::llama70b(), 1);
        let mut no_gather = crate::sched::policy::family_stages(&m, &t, E2eFamily::DmaOverlap);
        no_gather[0].gather = None;
        assert!(matches!(
            build_graph_planned(&m, &topo, &t, 2, &no_gather),
            Err(Error::Config(_))
        ));
        let mut no_reduce = crate::sched::policy::family_stages(&m, &t, E2eFamily::DmaOverlap);
        no_reduce[2].reduce = None; // bwd-mlp carries a reduce-scatter
        assert!(matches!(
            build_graph_planned(&m, &topo, &t, 2, &no_reduce),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn comm_first_decision_delays_the_gather_launch() {
        // The §V-C ordering decision is consumed by the builder: a
        // GEMM-first plan defers the gather's launch by the GEMM's
        // launch slot. Needs a GEMM smaller than the collective's
        // workgroup need — the one case the workgroup proxy orders
        // compute first.
        let m = m();
        let topo = topo1(&m);
        let trace = E2eTrace {
            kind: E2eKind::FsdpForward,
            model: "synthetic",
            stages_per_layer: 1,
            stages: vec![E2eStage {
                label: "s0".into(),
                gemm: GemmKernel::new(
                    "tiny",
                    crate::config::workload::GemmShape::bf16(128, 128, 128),
                ),
                gather: Some(ag(64 * crate::util::units::MIB)),
                reduce: None,
            }],
        };
        let planner = crate::sched::Planner::new(&m, &topo);
        assert!(
            !planner.cost.comm_first(&trace.stages[0].gemm, &trace.stages[0].gather.unwrap()),
            "a 1-workgroup GEMM must launch before a 32-CU gather"
        );
        let mut stages = crate::sched::policy::family_stages(&m, &trace, E2eFamily::CuOverlap);
        let comm_first = graph::execute(
            &m,
            &topo,
            &build_graph_planned(&m, &topo, &trace, 1, &stages).unwrap(),
        )
        .unwrap();
        stages[0].comm_first = false;
        let gemm_first = graph::execute(
            &m,
            &topo,
            &build_graph_planned(&m, &topo, &trace, 1, &stages).unwrap(),
        )
        .unwrap();
        // Node 0 is the gather: its issue slips by exactly one kernel
        // launch, and the stage stretches with it.
        assert!(
            (gemm_first.issue[0] - comm_first.issue[0] - m.kernel_launch_s).abs() < 1e-12
        );
        assert!(gemm_first.total > comm_first.total);
    }

    #[test]
    fn auto_family_never_loses_and_reports_a_plan() {
        let m = m();
        let topo = topo1(&m);
        let t = fsdp_forward_stages(&LlamaConfig::llama70b(), 2);
        let (auto, plan) = run_e2e_planned(&m, &topo, &t, 2, E2eFamily::Auto).unwrap();
        let plan = plan.expect("auto carries a plan");
        assert_eq!(auto.family, E2eFamily::Auto);
        // Never worse than any fixed family (argmin by construction).
        for fam in [E2eFamily::Serial, E2eFamily::CuOverlap, E2eFamily::DmaOverlap] {
            let fixed = run_e2e(&m, &topo, &t, 2, fam).unwrap();
            assert!(
                auto.total <= fixed.total * (1.0 + 1e-9),
                "auto {:.4}ms vs {} {:.4}ms",
                auto.total * 1e3,
                fam.name(),
                fixed.total * 1e3
            );
        }
        assert!(auto.speedup >= 1.0 - 1e-9, "auto bounded by the serial chain");
        // The plan names its winning strategy and annotates every node.
        assert!(plan.candidates >= 4, "chain + stamps + proposals");
        assert_eq!(plan.nodes.len(), 2 * t.stages.len(), "gather + gemm per stage");
        assert!(plan.nodes.iter().all(|n| !n.backend.is_empty()));
        // Fixed families carry no plan.
        let (_, none) = run_e2e_planned(&m, &topo, &t, 2, E2eFamily::DmaOverlap).unwrap();
        assert!(none.is_none());
        // Planner runs are deterministic: same inputs, same plan.
        let (auto2, plan2) = run_e2e_planned(&m, &topo, &t, 2, E2eFamily::Auto).unwrap();
        assert_eq!(auto.total, auto2.total);
        assert_eq!(plan.strategy, plan2.unwrap().strategy);
    }
}
