//! Inference-serving workloads on the workload-graph engine: per-step
//! decode graphs for the three serving shapes the C3 literature singles
//! out, plus the memoized step evaluator the open-loop traffic engine
//! ([`crate::workload::traffic`]) drives.
//!
//! Three serving workloads ([`ServeKind`]):
//!
//! * **`tp_decode`** — tensor-parallel autoregressive decode: per layer,
//!   an activation all-gather and a partials reduce-scatter around
//!   *tiny* GEMMs (M = current batch, a few tokens — not 8192). These
//!   collectives sit squarely in the latency-bound regime (Fig 9 left
//!   edge / DMA-Latte): wire time is microseconds, so the per-issue cost
//!   decides the backend, and the MI300X DMA enqueue chain costs more
//!   than a CU kernel launch.
//! * **`moe_dispatch`** — expert-parallel MoE decode: per layer, an
//!   all-to-all token dispatch, the expert GEMM, and an all-to-all
//!   combine.
//! * **`pd_disagg`** — prefill/decode disaggregation: the decode stages
//!   of `tp_decode` plus a **KV-cache ingest stream** — each newly
//!   admitted request ships its prefilled KV cache from the prefill
//!   tier as a bulk, deadline-tolerant background transfer that
//!   contends with the decode collectives for SDMA engines and HBM.
//!
//! The two request classes are the serving form of the paper's §V-A
//! complementary-resource argument: decode collectives are
//! latency-critical and tiny; the KV stream is bandwidth-hungry and
//! deadline-tolerant. A uniform backend stamp gets one of them wrong —
//! `cu_overlap` lets the KV bulk steal CUs and pollute L2 under the
//! decode GEMMs, `dma_overlap` taxes every per-token collective with
//! the DMA enqueue chain. The `auto` family plans **per request class**
//! ([`crate::sched::policy::serve_candidates`]): the cost model
//! proposes, the graph engine disposes — every candidate (plus a fully
//! serialized chain and both uniform stamps) is simulated per step
//! shape and the argmin wins, so auto can never lose to a fixed serving
//! family on any step.
//!
//! # Contract
//!
//! [`ServeSpec`] describes the workload (model, simulated layers, max
//! batch); [`ServeStepper`] maps a step shape `(batch, new_requests)` to
//! a [`StepCost`] by building the step's task graph and executing it on
//! the graph engine. The stepper memoizes aggressively — exact shapes
//! hit a cost cache, and new shapes that share a decode prefix with a
//! recorded shape resume from the recorded engine checkpoint
//! ([`crate::sched::graph::execute_resuming`], bit-identical to a cold
//! run by construction). Everything is deterministic: no wall clock, no
//! thread-count dependence.

use crate::config::machine::MachineConfig;
use crate::config::workload::{CollectiveKind, CollectiveSpec, DType, GemmShape};
use crate::error::Error;
use crate::fabric::Topology;
use crate::heuristics::CostModel;
use crate::kernels::{CollectiveKernel, GemmKernel};
use crate::sched::graph::{self, Graph, PrefixTimeline};
use crate::sched::policy::{serve_candidates, CollPlan, PlanBackend, ServeClassPlan, StagePlan};
use crate::workload::e2e::{
    build_graph_planned_with, build_serial_chain_with, push_planned_comm, CommPricer, E2eFamily,
    E2eKind, E2eStage, E2eTrace,
};
use crate::workload::llama::LlamaConfig;

/// Tensor/expert-parallel ways the decode GEMM shards over (the paper's
/// 8× MI300X node).
const TP_WAYS: usize = 8;

/// Prefill context length (tokens) whose KV cache a newly admitted
/// request ships from the prefill tier (`pd_disagg`).
pub const KV_CONTEXT_TOKENS: usize = 2048;

/// Which inference-serving workload a spec describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ServeKind {
    TpDecode,
    MoeDispatch,
    PdDisagg,
}

impl ServeKind {
    /// Name used in CLI specs, JSON and gate keys.
    pub fn name(self) -> &'static str {
        match self {
            ServeKind::TpDecode => "tp_decode",
            ServeKind::MoeDispatch => "moe_dispatch",
            ServeKind::PdDisagg => "pd_disagg",
        }
    }
}

/// One point of the serving axis: workload kind, model, simulated layer
/// count and the continuous-batching cap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeSpec {
    pub kind: ServeKind,
    pub model: LlamaConfig,
    pub model_tag: &'static str,
    /// Transformer layers simulated per decode step.
    pub layers: usize,
    /// Continuous-batching cap: at most this many requests decode
    /// concurrently.
    pub max_batch: usize,
}

impl ServeSpec {
    /// Parse a CLI axis entry: `workload[:model[:layers[:max_batch]]]`,
    /// e.g. `pd_disagg:70b:4:16` (defaults: 70b, 4 layers, batch 16).
    pub fn parse(s: &str) -> Result<ServeSpec, Error> {
        let mut it = s.split(':');
        let kind = match it.next().unwrap_or("") {
            "tp_decode" | "decode" => ServeKind::TpDecode,
            "moe_dispatch" | "moe" => ServeKind::MoeDispatch,
            "pd_disagg" | "pd" => ServeKind::PdDisagg,
            other => {
                return Err(Error::Config(format!(
                    "unknown serve workload '{other}' (expected tp_decode, moe_dispatch, pd_disagg)"
                )))
            }
        };
        let (model, model_tag) = match it.next().unwrap_or("70b") {
            "70b" => (LlamaConfig::llama70b(), "70b"),
            "405b" => (LlamaConfig::llama405b(), "405b"),
            other => {
                return Err(Error::Config(format!(
                    "unknown serve model '{other}' (expected 70b or 405b)"
                )))
            }
        };
        let parse_pos = |v: Option<&str>, what: &str, default: usize| -> Result<usize, Error> {
            match v {
                None => Ok(default),
                Some(raw) => raw.parse::<usize>().ok().filter(|&x| x >= 1).ok_or_else(|| {
                    Error::Config(format!("serve {what} '{raw}': expected a positive integer"))
                }),
            }
        };
        let layers = parse_pos(it.next(), "layer count", 4)?;
        let max_batch = parse_pos(it.next(), "max batch", 16)?;
        if let Some(extra) = it.next() {
            return Err(Error::Config(format!(
                "serve spec '{s}': unexpected trailing segment '{extra}'"
            )));
        }
        Ok(ServeSpec {
            kind,
            model,
            model_tag,
            layers,
            max_batch,
        })
    }

    /// Stable label used in JSON and gate keys (no `/`).
    pub fn label(&self) -> String {
        format!(
            "{}-{}-l{}-b{}",
            self.kind.name(),
            self.model_tag,
            self.layers,
            self.max_batch
        )
    }

    /// Per-token activation payload of one decode-path collective at a
    /// given batch (bf16, one hidden vector per in-flight request).
    fn act_bytes(&self, batch: usize) -> u64 {
        (batch.max(1) * self.model.hidden * DType::Bf16.bytes()) as u64
    }

    /// Representative decode-path collective of a step (what the
    /// per-class planner prices for the latency-critical class).
    pub fn decode_collective(&self, batch: usize) -> CollectiveKernel {
        let kind = match self.kind {
            ServeKind::MoeDispatch => CollectiveKind::AllToAll,
            _ => CollectiveKind::AllGather,
        };
        CollectiveKernel::new(CollectiveSpec::new(kind, self.act_bytes(batch)))
    }

    /// KV-cache bytes `new_requests` freshly admitted requests ship
    /// from the prefill tier this step (0 for the non-disaggregated
    /// workloads): K and V, all simulated layers, GQA KV heads,
    /// [`KV_CONTEXT_TOKENS`] of prefilled context, bf16.
    pub fn kv_stream_bytes(&self, new_requests: usize) -> u64 {
        if self.kind != ServeKind::PdDisagg {
            return 0;
        }
        let kv_dim = self.model.kv_heads * self.model.head_dim;
        (new_requests * 2 * self.layers * kv_dim * KV_CONTEXT_TOKENS * DType::Bf16.bytes()) as u64
    }

    /// The decode stages of one step at a given batch, as an
    /// [`E2eTrace`] with activation-chain (TP) dependency semantics:
    /// every stage's collective depends on the previous GEMM — decode
    /// has no prefetchable weights.
    pub fn decode_trace(&self, batch: usize) -> E2eTrace {
        let b = batch.max(1);
        let h = self.model.hidden;
        let act = self.act_bytes(b);
        let ag = |bytes| CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, bytes));
        let rs = |bytes| {
            CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::ReduceScatter, bytes))
        };
        let a2a = |bytes| CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllToAll, bytes));
        let mut stages = Vec::new();
        match self.kind {
            ServeKind::TpDecode | ServeKind::PdDisagg => {
                // Megatron decode layer: AG(activations) → QKV-sharded
                // attention GEMM → RS, then AG → MLP-sharded GEMM → RS.
                let attn = GemmKernel::new("dec-attn", GemmShape::bf16(b, 3 * h / TP_WAYS, h));
                let mlp = GemmKernel::new(
                    "dec-mlp",
                    GemmShape::bf16(b, 2 * self.model.ffn / TP_WAYS, h),
                );
                for i in 0..self.layers {
                    stages.push(E2eStage {
                        label: format!("layer{i}/dec-attn"),
                        gemm: attn.clone(),
                        gather: Some(ag(act)),
                        reduce: Some(rs(act)),
                    });
                    stages.push(E2eStage {
                        label: format!("layer{i}/dec-mlp"),
                        gemm: mlp.clone(),
                        gather: Some(ag(act)),
                        reduce: Some(rs(act)),
                    });
                }
            }
            ServeKind::MoeDispatch => {
                // MoE decode layer: all-to-all token dispatch → expert
                // GEMM → all-to-all combine.
                let expert = GemmKernel::new(
                    "moe-expert",
                    GemmShape::bf16(b, 2 * self.model.ffn / TP_WAYS, h),
                );
                for i in 0..self.layers {
                    stages.push(E2eStage {
                        label: format!("layer{i}/moe"),
                        gemm: expert.clone(),
                        gather: Some(a2a(act)),
                        reduce: Some(a2a(act)),
                    });
                }
            }
        }
        E2eTrace {
            kind: E2eKind::TpChain,
            model: self.model.name,
            stages_per_layer: if self.kind == ServeKind::MoeDispatch { 1 } else { 2 },
            stages,
        }
    }
}

/// Simulated cost of one decode step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepCost {
    /// Step makespan, seconds.
    pub time: f64,
    /// HBM occupancy of the step graph (fraction of achievable bytes).
    pub hbm: f64,
    /// SDMA engine occupancy of the step graph.
    pub sdma: f64,
    /// Name of the per-class plan that produced this cost.
    pub plan: &'static str,
}

/// One recorded step shape: the engine checkpoint timeline of the first
/// execution, reusable for any later step that shares the decode-node
/// prefix (same batch, same plan) but differs in the KV suffix.
struct Recorded {
    key: (&'static str, usize, bool),
    decode_nodes: usize,
    timeline: PrefixTimeline,
}

/// Memoized per-step evaluator: the bridge between the traffic loop's
/// `(batch, new_requests)` shapes and the graph engine. One stepper is
/// built per (machine, topology, spec, family) and owns the cost model,
/// the wire-pricing memo and the step caches.
pub struct ServeStepper {
    spec: ServeSpec,
    family: E2eFamily,
    cost: CostModel,
    pricer: CommPricer,
    recorded: Vec<Recorded>,
    costs: Vec<((usize, usize), StepCost)>,
    /// Auto-family candidate wins, in first-win order.
    wins: Vec<(&'static str, usize)>,
    /// Event-loop counters accumulated over every graph execution this
    /// stepper performed (cost-cache hits add nothing: no simulation).
    counters: crate::sim::SimCounters,
}

/// The serialized-chain pseudo-plan (the never-lose bound; also the
/// `serial` serving family).
const SERIAL_PLAN: ServeClassPlan = ServeClassPlan {
    name: "serial-chain",
    decode: PlanBackend::Cu,
    kv: PlanBackend::Cu,
    kv_chunks: 1,
};

impl ServeStepper {
    pub fn new(m: &MachineConfig, topo: &Topology, spec: ServeSpec, family: E2eFamily) -> Self {
        ServeStepper {
            spec,
            family,
            cost: CostModel::new(m, topo),
            pricer: CommPricer::new(),
            recorded: Vec::new(),
            costs: Vec::new(),
            wins: Vec::new(),
            counters: crate::sim::SimCounters::default(),
        }
    }

    /// Event-loop counters summed over every simulated step (resumed
    /// steps report only their replayed suffix).
    pub fn counters(&self) -> crate::sim::SimCounters {
        self.counters
    }

    /// Build one step graph: the decode trace under a per-class plan
    /// (or the serialized chain), plus the KV ingest node(s) when the
    /// step admits new requests. Returns the graph and the decode node
    /// count (the resumable-prefix boundary: every KV node depends on a
    /// decode node, so the suffix is never rooted and
    /// `execute_resuming` applies).
    fn build_step(
        &mut self,
        plan: &ServeClassPlan,
        serialized: bool,
        batch: usize,
        new_requests: usize,
    ) -> Result<(Graph, usize), Error> {
        let m = &self.cost.m;
        let topo = &self.cost.topo;
        let trace = self.spec.decode_trace(batch);
        let mut g;
        let decode_nodes;
        let kv_dep;
        if serialized {
            g = build_serial_chain_with(m, topo, &trace, &mut self.pricer)?;
            decode_nodes = g.nodes.len();
            // Fully serialized: the KV transfer waits for the whole
            // decode chain.
            kv_dep = decode_nodes - 1;
        } else {
            let stages: Vec<StagePlan> = trace
                .stages
                .iter()
                .map(|s| StagePlan {
                    gather: s.gather.as_ref().map(|k| CollPlan {
                        backend: plan.decode,
                        cus: k.cu_need(m),
                        chunks: 1,
                    }),
                    reduce: s.reduce.as_ref().map(|k| CollPlan {
                        backend: plan.decode,
                        cus: k.cu_need(m),
                        chunks: 1,
                    }),
                    gemm_cus: None,
                    comm_first: true,
                })
                .collect();
            let pg = build_graph_planned_with(m, topo, &trace, 1, &stages, &mut self.pricer)?;
            g = pg.graph;
            decode_nodes = g.nodes.len();
            // Overlapped: the KV ingest starts with the step (anchored
            // on the first decode node so the suffix stays
            // dependency-rooted for the resume contract).
            kv_dep = 0;
        }
        let kv_bytes = self.spec.kv_stream_bytes(new_requests);
        if kv_bytes > 0 {
            let kernel =
                CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, kv_bytes));
            push_planned_comm(
                &mut g,
                m,
                topo,
                "kv/ingest",
                &kernel,
                CollPlan {
                    backend: plan.kv,
                    cus: kernel.cu_need(m),
                    chunks: plan.kv_chunks,
                },
                vec![kv_dep],
                0.0,
                &mut self.pricer,
            )?;
        }
        Ok((g, decode_nodes))
    }

    /// Execute one plan for one step shape, resuming from a recorded
    /// checkpoint when this (plan, batch) decode prefix has run before.
    fn evaluate(
        &mut self,
        plan: &ServeClassPlan,
        serialized: bool,
        batch: usize,
        new_requests: usize,
    ) -> Result<StepCost, Error> {
        let key = (plan.name, batch, serialized);
        let (g, decode_nodes) = self.build_step(plan, serialized, batch, new_requests)?;
        let m = &self.cost.m;
        let topo = &self.cost.topo;
        let run = match self.recorded.iter().find(|r| r.key == key) {
            Some(rec) => graph::execute_resuming(m, topo, &g, &rec.timeline, rec.decode_nodes)?,
            None => {
                let (run, timeline) = graph::execute_recording(m, topo, &g)?;
                self.recorded.push(Recorded {
                    key,
                    decode_nodes,
                    timeline,
                });
                run
            }
        };
        self.counters.absorb(run.counters);
        Ok(StepCost {
            time: run.total,
            hbm: run.hbm_occupancy,
            sdma: run.sdma_occupancy,
            plan: plan.name,
        })
    }

    /// Cost of one decode step at `(batch, new_requests)` under this
    /// stepper's family. Exact repeat shapes are served from the cost
    /// cache; the `auto` family simulates the per-class candidate
    /// lineup (seeded with the serialized chain) and takes the argmin,
    /// so it can never lose to `serial`, `cu_overlap` or `dma_overlap`
    /// on any step shape.
    pub fn step(&mut self, batch: usize, new_requests: usize) -> Result<StepCost, Error> {
        let batch = batch.max(1);
        let new_requests = new_requests.min(batch);
        let shape = (batch, new_requests);
        if let Some(&(_, c)) = self.costs.iter().find(|&&(s, _)| s == shape) {
            return Ok(c);
        }
        let cost = match self.family {
            E2eFamily::Serial => self.evaluate(&SERIAL_PLAN, true, batch, new_requests)?,
            E2eFamily::CuOverlap => {
                let plan = ServeClassPlan {
                    name: "cu-uniform",
                    decode: PlanBackend::Cu,
                    kv: PlanBackend::Cu,
                    kv_chunks: 1,
                };
                self.evaluate(&plan, false, batch, new_requests)?
            }
            E2eFamily::DmaOverlap => {
                let plan = ServeClassPlan {
                    name: "dma-uniform",
                    decode: PlanBackend::Dma,
                    kv: PlanBackend::Dma,
                    kv_chunks: 1,
                };
                self.evaluate(&plan, false, batch, new_requests)?
            }
            E2eFamily::Auto => {
                let decode = self.spec.decode_collective(batch);
                let kv_bytes = self.spec.kv_stream_bytes(new_requests);
                let cands = serve_candidates(&self.cost, &decode, kv_bytes);
                let mut best = self.evaluate(&SERIAL_PLAN, true, batch, new_requests)?;
                for c in &cands {
                    let cost = self.evaluate(c, false, batch, new_requests)?;
                    if cost.time < best.time {
                        best = cost;
                    }
                }
                match self.wins.iter_mut().find(|(n, _)| *n == best.plan) {
                    Some((_, n)) => *n += 1,
                    None => self.wins.push((best.plan, 1)),
                }
                best
            }
        };
        self.costs.push((shape, cost));
        Ok(cost)
    }

    /// The modal winning per-class plan of an `auto` stepper (ties go
    /// to the first winner), `None` for fixed families or before any
    /// step ran.
    pub fn winning_plan(&self) -> Option<&'static str> {
        self.wins
            .iter()
            .max_by_key(|&&(_, n)| n)
            .map(|&(name, _)| name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    #[test]
    fn spec_parse_round_trips_and_rejects_garbage() {
        let s = ServeSpec::parse("pd_disagg:70b:4:16").unwrap();
        assert_eq!(s.kind, ServeKind::PdDisagg);
        assert_eq!(s.layers, 4);
        assert_eq!(s.max_batch, 16);
        assert_eq!(s.label(), "pd_disagg-70b-l4-b16");
        // Defaults.
        let d = ServeSpec::parse("tp_decode").unwrap();
        assert_eq!((d.layers, d.max_batch, d.model_tag), (4, 16, "70b"));
        // Aliases.
        assert_eq!(ServeSpec::parse("moe").unwrap().kind, ServeKind::MoeDispatch);
        assert_eq!(ServeSpec::parse("pd:405b").unwrap().model_tag, "405b");
        // Garbage is a typed error, never a panic.
        for bad in ["", "fsdp_step", "tp_decode:13b", "tp_decode:70b:0", "tp_decode:70b:4:x",
            "tp_decode:70b:4:16:9"]
        {
            assert!(ServeSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn decode_traces_have_serving_shapes() {
        let tp = ServeSpec::parse("tp_decode:70b:3:8").unwrap().decode_trace(8);
        assert_eq!(tp.stages.len(), 6, "2 stages per layer");
        for s in &tp.stages {
            assert_eq!(s.gemm.shape.m, 8, "decode GEMM M is the batch, not 8192");
            assert_eq!(s.gather.unwrap().spec.kind, CollectiveKind::AllGather);
            assert_eq!(s.reduce.unwrap().spec.kind, CollectiveKind::ReduceScatter);
            // Per-token activation payloads are tiny — the latency-bound
            // regime the chunk tuner and the issue-latency model target.
            assert!(s.gather.unwrap().spec.size_bytes < 1 << 20);
            assert!(s.gather.unwrap().is_latency_bound(&m()));
        }
        let moe = ServeSpec::parse("moe_dispatch:70b:3:8").unwrap().decode_trace(8);
        assert_eq!(moe.stages.len(), 3, "1 stage per layer");
        for s in &moe.stages {
            assert_eq!(s.gather.unwrap().spec.kind, CollectiveKind::AllToAll);
            assert_eq!(s.reduce.unwrap().spec.kind, CollectiveKind::AllToAll);
        }
    }

    #[test]
    fn kv_stream_only_exists_for_disaggregation() {
        let pd = ServeSpec::parse("pd_disagg:70b").unwrap();
        assert_eq!(pd.kv_stream_bytes(0), 0);
        let one = pd.kv_stream_bytes(1);
        assert!(one > 16 << 20, "a prefilled context is a bulk transfer ({one}B)");
        assert_eq!(pd.kv_stream_bytes(3), 3 * one, "KV bytes scale with admissions");
        assert_eq!(ServeSpec::parse("tp_decode:70b").unwrap().kv_stream_bytes(4), 0);
        assert_eq!(ServeSpec::parse("moe_dispatch:70b").unwrap().kv_stream_bytes(4), 0);
    }

    #[test]
    fn resumed_step_matches_cold_execution_bit_for_bit() {
        let m = m();
        let topo = m.topology(1);
        let spec = ServeSpec::parse("pd_disagg:70b:2:8").unwrap();
        // Warm stepper: records (batch=4) with new=2, then re-evaluates
        // new=1 by resuming from the recorded decode-prefix checkpoint.
        let mut warm = ServeStepper::new(&m, &topo, spec, E2eFamily::CuOverlap);
        warm.step(4, 2).unwrap();
        let resumed = warm.step(4, 1).unwrap();
        // Cold stepper: evaluates (4, 1) as its first, recorded run.
        let mut cold = ServeStepper::new(&m, &topo, spec, E2eFamily::CuOverlap);
        let from_scratch = cold.step(4, 1).unwrap();
        assert_eq!(resumed.time.to_bits(), from_scratch.time.to_bits());
        assert_eq!(resumed.hbm.to_bits(), from_scratch.hbm.to_bits());
        assert_eq!(resumed.sdma.to_bits(), from_scratch.sdma.to_bits());
    }

    #[test]
    fn auto_step_never_loses_to_any_fixed_family() {
        let m = m();
        let topo = m.topology(1);
        for spec_s in ["tp_decode:70b:2:8", "moe_dispatch:70b:2:8", "pd_disagg:70b:2:8"] {
            let spec = ServeSpec::parse(spec_s).unwrap();
            let shapes = [(4usize, 2usize), (8, 0), (1, 1)];
            let mut auto = ServeStepper::new(&m, &topo, spec, E2eFamily::Auto);
            for fam in [E2eFamily::Serial, E2eFamily::CuOverlap, E2eFamily::DmaOverlap] {
                let mut fixed = ServeStepper::new(&m, &topo, spec, fam);
                for &(b, n) in &shapes {
                    let a = auto.step(b, n).unwrap();
                    let f = fixed.step(b, n).unwrap();
                    assert!(
                        a.time <= f.time + 1e-12,
                        "{spec_s} auto {} vs {} {} at ({b},{n})",
                        a.time,
                        fam.name(),
                        f.time
                    );
                }
            }
            assert!(auto.winning_plan().is_some());
        }
    }

    #[test]
    fn disagg_auto_routes_kv_to_dma_and_decode_to_cus() {
        let m = m();
        let topo = m.topology(1);
        let spec = ServeSpec::parse("pd_disagg:70b:4:16").unwrap();
        let mut auto = ServeStepper::new(&m, &topo, spec, E2eFamily::Auto);
        let c = auto.step(16, 2).unwrap();
        assert!(
            c.plan.starts_with("kv-dma"),
            "per-class split must win the disaggregated step (won: {})",
            c.plan
        );
        assert!(c.sdma > 0.0, "the KV stream must occupy SDMA engines");
    }

    #[test]
    fn step_costs_are_cached_and_deterministic() {
        let m = m();
        let topo = m.topology(1);
        let spec = ServeSpec::parse("tp_decode:70b:2:8").unwrap();
        let mut a = ServeStepper::new(&m, &topo, spec, E2eFamily::Auto);
        let mut b = ServeStepper::new(&m, &topo, spec, E2eFamily::Auto);
        let x = a.step(5, 1).unwrap();
        let y = b.step(5, 1).unwrap();
        assert_eq!(x.time.to_bits(), y.time.to_bits());
        // Repeat shape: served from cache, identical.
        let x2 = a.step(5, 1).unwrap();
        assert_eq!(x, x2);
    }
}
