//! C3 taxonomy (paper §III, Fig 4).
//!
//! Three axes classify a C3 manifestation from *isolated* execution
//! characteristics:
//!
//! 1. **C3 type** — relative magnitude of GEMM vs communication time:
//!    `G-long` (GEMM > 115% of comm), `C-long` (comm > 115% of GEMM),
//!    `GC-equal` (within 15%).
//! 2. **GEMM boundedness** — compute- vs memory-bound by measured
//!    op:byte against the machine balance point.
//! 3. **Collective boundedness** — latency- vs bandwidth-bound by
//!    whether latency at this size is commensurate with size.

use crate::config::machine::MachineConfig;
use crate::kernels::{CollectiveKernel, GemmKernel};

/// Relative-magnitude class of a C3 pair (paper Fig 4 ①).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum C3Type {
    GLong,
    CLong,
    GcEqual,
}

impl C3Type {
    /// Classify from isolated execution times with the paper's 15%
    /// threshold.
    pub fn classify(t_gemm: f64, t_comm: f64) -> C3Type {
        assert!(t_gemm > 0.0 && t_comm > 0.0, "times must be positive");
        if t_gemm > 1.15 * t_comm {
            C3Type::GLong
        } else if t_comm > 1.15 * t_gemm {
            C3Type::CLong
        } else {
            C3Type::GcEqual
        }
    }

    /// Display name matching the paper.
    pub fn name(self) -> &'static str {
        match self {
            C3Type::GLong => "G-long",
            C3Type::CLong => "C-long",
            C3Type::GcEqual => "GC-equal",
        }
    }

    /// All three, in paper order.
    pub fn all() -> [C3Type; 3] {
        [C3Type::GLong, C3Type::CLong, C3Type::GcEqual]
    }
}

/// Full taxonomy record for one C3 manifestation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Taxonomy {
    pub c3_type: C3Type,
    /// Isolated GEMM / comm time ratio (Fig 4's "relative magnitude").
    pub magnitude: f64,
    pub gemm_compute_bound: bool,
    pub comm_latency_bound: bool,
}

impl Taxonomy {
    /// Classify a GEMM/collective pair from the analytic models.
    pub fn of(m: &MachineConfig, gemm: &GemmKernel, comm: &CollectiveKernel) -> Taxonomy {
        let tg = gemm.time_isolated(m, m.cus_total());
        let tc = comm.time_isolated_full(m);
        Taxonomy {
            c3_type: C3Type::classify(tg, tc),
            magnitude: tg / tc,
            gemm_compute_bound: gemm.is_compute_bound(m),
            comm_latency_bound: comm.is_latency_bound(m),
        }
    }

    /// The ideal-speedup bound for this pair (paper §IV-B3): serial over
    /// max — the shorter kernel fully hidden in the longer one's shadow.
    pub fn ideal_speedup(t_gemm: f64, t_comm: f64) -> f64 {
        (t_gemm + t_comm) / t_gemm.max(t_comm)
    }
}

/// Percent-of-ideal metric used throughout the evaluation:
/// `(attained - 1) / (ideal - 1)`, in percent. Degenerate ideals (no
/// headroom) report 100 if attained, else 0.
pub fn pct_of_ideal(attained: f64, ideal: f64) -> f64 {
    if ideal <= 1.0 + 1e-12 {
        return if attained >= ideal { 100.0 } else { 0.0 };
    }
    100.0 * (attained - 1.0) / (ideal - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::{CollectiveKind, CollectiveSpec};
    use crate::util::units::MIB;
    use crate::workload::llama::gemm_by_tag;

    #[test]
    fn classify_thresholds() {
        assert_eq!(C3Type::classify(2.0, 1.0), C3Type::GLong);
        assert_eq!(C3Type::classify(1.0, 2.0), C3Type::CLong);
        assert_eq!(C3Type::classify(1.0, 1.1), C3Type::GcEqual);
        assert_eq!(C3Type::classify(1.14, 1.0), C3Type::GcEqual);
        assert_eq!(C3Type::classify(1.16, 1.0), C3Type::GLong);
    }

    #[test]
    fn ideal_speedup_bounds() {
        // Equal kernels: perfect hiding doubles throughput.
        assert!((Taxonomy::ideal_speedup(1.0, 1.0) - 2.0).abs() < 1e-12);
        // Extreme imbalance: no headroom.
        assert!(Taxonomy::ideal_speedup(100.0, 0.001) < 1.01);
    }

    #[test]
    fn pct_of_ideal_metric() {
        assert!((pct_of_ideal(1.13, 1.6) - 21.67).abs() < 0.1); // the paper's 21%
        assert_eq!(pct_of_ideal(1.0, 1.5), 0.0);
        assert_eq!(pct_of_ideal(1.5, 1.5), 100.0);
        assert_eq!(pct_of_ideal(1.2, 1.0), 100.0);
    }

    #[test]
    fn mb1_896m_is_g_long_compute_hidden() {
        let m = MachineConfig::mi300x();
        let g = gemm_by_tag("mb1").unwrap();
        let c = CollectiveKernel::new(CollectiveSpec::new(
            CollectiveKind::AllGather,
            896 * MIB,
        ));
        let t = Taxonomy::of(&m, &g, &c);
        assert_eq!(t.c3_type, C3Type::GLong);
        assert!(!t.gemm_compute_bound);
        assert!(!t.comm_latency_bound);
        assert!(t.magnitude > 1.15);
    }
}
