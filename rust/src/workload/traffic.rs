//! The streaming traffic engine: a long-running, deterministic,
//! open-loop serving simulation over [`crate::workload::serving`].
//!
//! Requests arrive by a Poisson process (identity-seeded, exponential
//! inter-arrivals at `--rate` req/s) with a uniform decode-length
//! distribution around `tokens_mean`. A continuous-batching loop admits
//! arrivals up to the spec's `max_batch`, runs one decode step per
//! iteration through the memoized [`ServeStepper`], advances simulated
//! time by the step's makespan, and retires requests as their tokens
//! drain. Steady-state latency percentiles use the exact sorted
//! estimator ([`crate::util::stats::percentile`]) over per-request
//! completion latencies — no reservoir, no decay.
//!
//! # Determinism
//!
//! Everything is a pure function of `(machine, topology, spec, family,
//! config, seed)`. Arrival draws are consumed in a fixed per-request
//! order — one `(inter-arrival, tokens)` pair per request index — so
//! families with different step clocks still see the byte-identical
//! request stream, and the loop itself is sequential, so reports are
//! byte-identical at any `--threads` setting. Two runs with the same
//! seed produce bit-equal floats.
//!
//! # Example: a minimal serve loop
//!
//! ```
//! use conccl::config::machine::MachineConfig;
//! use conccl::workload::e2e::E2eFamily;
//! use conccl::workload::serving::ServeSpec;
//! use conccl::workload::traffic::{run_serve, TrafficConfig};
//!
//! let m = MachineConfig::mi300x();
//! let topo = m.topology(1);
//! let spec = ServeSpec::parse("tp_decode:70b:2:8").unwrap();
//! let cfg = TrafficConfig { rate: 2000.0, steps: 40, ..TrafficConfig::default() };
//! let r = run_serve(&m, &topo, spec, E2eFamily::Auto, cfg, 42).unwrap();
//! assert!(r.requests_completed > 0);
//! assert!(r.p50 <= r.p95 && r.p95 <= r.p99);
//! assert!(r.goodput_tps > 0.0);
//! ```

use crate::config::machine::MachineConfig;
use crate::error::Error;
use crate::fabric::Topology;
use crate::util::rng::Rng;
use crate::util::stats::percentile;
use crate::workload::e2e::E2eFamily;
use crate::workload::serving::{ServeSpec, ServeStepper};

/// Open-loop traffic parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficConfig {
    /// Request arrival rate, requests per second (Poisson).
    pub rate: f64,
    /// Decode steps to simulate (the primary budget).
    pub steps: usize,
    /// Optional simulated-seconds cap (0 = no cap).
    pub duration: f64,
    /// Mean decode length in tokens; lengths are uniform on
    /// `[1, 2*tokens_mean - 1]`.
    pub tokens_mean: f64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            rate: 2000.0,
            steps: 200,
            duration: 0.0,
            tokens_mean: 24.0,
        }
    }
}

impl TrafficConfig {
    /// Typed validation of CLI-reachable parameters.
    pub fn validate(&self) -> Result<(), Error> {
        if !(self.rate > 0.0) || !self.rate.is_finite() {
            return Err(Error::Config(format!(
                "serve rate must be a positive finite req/s (got {})",
                self.rate
            )));
        }
        if self.steps < 1 {
            return Err(Error::Config("serve steps must be >= 1".into()));
        }
        if !(self.tokens_mean >= 1.0) || !self.tokens_mean.is_finite() {
            return Err(Error::Config(format!(
                "serve tokens mean must be >= 1 (got {})",
                self.tokens_mean
            )));
        }
        if !(self.duration >= 0.0) || !self.duration.is_finite() {
            return Err(Error::Config(format!(
                "serve duration must be >= 0 seconds (got {})",
                self.duration
            )));
        }
        Ok(())
    }
}

/// Steady-state report of one (spec, family) traffic run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeReport {
    pub family: E2eFamily,
    pub requests_arrived: usize,
    pub requests_completed: usize,
    /// Decode steps actually simulated.
    pub steps: usize,
    /// Simulated seconds covered.
    pub elapsed: f64,
    /// Request-latency percentiles (arrival → last token), seconds.
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// Decoded tokens per simulated second.
    pub goodput_tps: f64,
    /// `serial p99 / this family's p99` (1.0 for serial itself).
    pub speedup: f64,
    /// Step-time-weighted HBM occupancy over the busy fraction.
    pub hbm_occupancy: f64,
    /// Step-time-weighted SDMA engine occupancy.
    pub sdma_occupancy: f64,
    /// Modal winning per-class plan (auto family only).
    pub plan: Option<&'static str>,
    /// Fluid-core event-loop counters summed over every simulated step
    /// (cache-replayed reports carry zeros: a replay simulates nothing).
    pub counters: crate::sim::SimCounters,
}

/// Deterministic open-loop arrival process: request `i`'s draws are
/// always the `2i`-th and `2i+1`-th RNG outputs, independent of the
/// consuming family's step clock.
struct Arrivals {
    rng: Rng,
    rate: f64,
    tokens_mean: f64,
    t: f64,
}

impl Arrivals {
    fn new(seed: u64, cfg: &TrafficConfig) -> Arrivals {
        Arrivals {
            rng: Rng::new(seed),
            rate: cfg.rate,
            tokens_mean: cfg.tokens_mean,
            t: 0.0,
        }
    }

    /// Next request: (arrival time, decode tokens).
    fn next(&mut self) -> (f64, usize) {
        let u = self.rng.f64();
        // Inverse-CDF exponential; u ∈ [0,1) keeps the log argument in
        // (0,1] so dt is finite and non-negative.
        self.t += -(1.0 - u).ln() / self.rate;
        let u2 = self.rng.f64();
        let tokens = 1 + (u2 * 2.0 * (self.tokens_mean - 1.0)).floor() as usize;
        (self.t, tokens)
    }
}

/// Run one (spec, family) traffic simulation. Non-serial families also
/// run the serialized baseline internally to report `speedup`; use
/// [`run_serve_lineup`] to share that baseline across a family lineup.
pub fn run_serve(
    m: &MachineConfig,
    topo: &Topology,
    spec: ServeSpec,
    family: E2eFamily,
    cfg: TrafficConfig,
    seed: u64,
) -> Result<ServeReport, Error> {
    let serial_p99 = if family == E2eFamily::Serial {
        None
    } else {
        Some(run_one(m, topo, spec, E2eFamily::Serial, cfg, seed)?.p99)
    };
    let mut r = run_one(m, topo, spec, family, cfg, seed)?;
    if let Some(s) = serial_p99 {
        r.speedup = s / r.p99;
    }
    Ok(r)
}

/// Run the full family lineup (serial, cu_overlap, dma_overlap, auto)
/// on one spec, sharing the serial baseline for the speedup column.
pub fn run_serve_lineup(
    m: &MachineConfig,
    topo: &Topology,
    spec: ServeSpec,
    cfg: TrafficConfig,
    seed: u64,
) -> Result<Vec<ServeReport>, Error> {
    let serial = run_one(m, topo, spec, E2eFamily::Serial, cfg, seed)?;
    let mut out = vec![serial];
    for family in [E2eFamily::CuOverlap, E2eFamily::DmaOverlap, E2eFamily::Auto] {
        let mut r = run_one(m, topo, spec, family, cfg, seed)?;
        r.speedup = serial.p99 / r.p99;
        out.push(r);
    }
    Ok(out)
}

fn run_one(
    m: &MachineConfig,
    topo: &Topology,
    spec: ServeSpec,
    family: E2eFamily,
    cfg: TrafficConfig,
    seed: u64,
) -> Result<ServeReport, Error> {
    cfg.validate()?;
    let mut stepper = ServeStepper::new(m, topo, spec, family);
    let mut arrivals = Arrivals::new(seed, &cfg);
    let mut next_arrival = arrivals.next();
    // Active requests: (arrival time, tokens left). FIFO admission.
    let mut active: Vec<(f64, usize)> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let mut t = 0.0_f64;
    let (mut arrived, mut completed, mut steps_done) = (0usize, 0usize, 0usize);
    let mut tokens_done = 0usize;
    let (mut hbm_w, mut sdma_w) = (0.0_f64, 0.0_f64);
    while steps_done < cfg.steps && (cfg.duration <= 0.0 || t < cfg.duration) {
        // Admit everything that has arrived, up to the batching cap.
        let mut new_requests = 0usize;
        while active.len() < spec.max_batch && next_arrival.0 <= t {
            active.push((next_arrival.0, next_arrival.1));
            next_arrival = arrivals.next();
            arrived += 1;
            new_requests += 1;
        }
        if active.is_empty() {
            // Idle: jump the clock to the next arrival.
            t = next_arrival.0;
            continue;
        }
        let cost = stepper.step(active.len(), new_requests)?;
        t += cost.time;
        hbm_w += cost.hbm * cost.time;
        sdma_w += cost.sdma * cost.time;
        steps_done += 1;
        tokens_done += active.len();
        // Every active request decoded one token this step.
        let mut still = Vec::with_capacity(active.len());
        for (at, tokens) in active.drain(..) {
            if tokens <= 1 {
                completed += 1;
                latencies.push(t - at);
            } else {
                still.push((at, tokens - 1));
            }
        }
        active = still;
    }
    if latencies.is_empty() {
        return Err(Error::Config(format!(
            "serve run completed no requests in {} steps at rate {} — raise --steps or --rate",
            cfg.steps, cfg.rate
        )));
    }
    Ok(ServeReport {
        family,
        requests_arrived: arrived,
        requests_completed: completed,
        steps: steps_done,
        elapsed: t,
        p50: percentile(&latencies, 50.0),
        p95: percentile(&latencies, 95.0),
        p99: percentile(&latencies, 99.0),
        goodput_tps: tokens_done as f64 / t,
        speedup: 1.0,
        hbm_occupancy: hbm_w / t,
        sdma_occupancy: sdma_w / t,
        plan: stepper.winning_plan(),
        counters: stepper.counters(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn cfg(steps: usize) -> TrafficConfig {
        TrafficConfig {
            steps,
            ..TrafficConfig::default()
        }
    }

    #[test]
    fn config_validation_is_typed() {
        assert!(TrafficConfig::default().validate().is_ok());
        for bad in [
            TrafficConfig { rate: 0.0, ..TrafficConfig::default() },
            TrafficConfig { rate: f64::NAN, ..TrafficConfig::default() },
            TrafficConfig { steps: 0, ..TrafficConfig::default() },
            TrafficConfig { tokens_mean: 0.5, ..TrafficConfig::default() },
            TrafficConfig { duration: -1.0, ..TrafficConfig::default() },
        ] {
            assert!(matches!(bad.validate(), Err(Error::Config(_))), "{bad:?}");
        }
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let m = m();
        let topo = m.topology(1);
        let spec = ServeSpec::parse("pd_disagg:70b:2:8").unwrap();
        let a = run_serve(&m, &topo, spec, E2eFamily::Auto, cfg(60), 24301).unwrap();
        let b = run_serve(&m, &topo, spec, E2eFamily::Auto, cfg(60), 24301).unwrap();
        assert_eq!(a.p50.to_bits(), b.p50.to_bits());
        assert_eq!(a.p99.to_bits(), b.p99.to_bits());
        assert_eq!(a.goodput_tps.to_bits(), b.goodput_tps.to_bits());
        assert_eq!(a.requests_completed, b.requests_completed);
        // A different seed sees a different request stream.
        let c = run_serve(&m, &topo, spec, E2eFamily::Auto, cfg(60), 7).unwrap();
        assert_ne!(a.p50.to_bits(), c.p50.to_bits());
    }

    #[test]
    fn lineup_shares_the_serial_baseline_and_auto_never_loses() {
        let m = m();
        let topo = m.topology(1);
        let spec = ServeSpec::parse("pd_disagg:70b:2:8").unwrap();
        let runs = run_serve_lineup(&m, &topo, spec, cfg(60), 24301).unwrap();
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[0].family, E2eFamily::Serial);
        assert_eq!(runs[0].speedup, 1.0);
        let auto = runs.iter().find(|r| r.family == E2eFamily::Auto).unwrap();
        for r in &runs {
            assert!(
                auto.p99 <= r.p99 * (1.0 + 1e-9),
                "auto p99 {} must not lose to {} p99 {}",
                auto.p99,
                r.family.name(),
                r.p99
            );
        }
        assert!(auto.plan.is_some());
        // The percentile ordering invariant.
        for r in &runs {
            assert!(r.p50 <= r.p95 && r.p95 <= r.p99);
            assert!(r.goodput_tps > 0.0 && r.elapsed > 0.0);
        }
    }

    #[test]
    fn empty_run_is_a_typed_error() {
        let m = m();
        let topo = m.topology(1);
        let spec = ServeSpec::parse("tp_decode:70b:2:8").unwrap();
        // A near-zero arrival rate with a tight duration cap: the clock
        // hits the cap before the first request ever arrives.
        let short = TrafficConfig {
            rate: 1e-9,
            duration: 1e-3,
            tokens_mean: 64.0,
            ..TrafficConfig::default()
        };
        let r = run_serve(&m, &topo, spec, E2eFamily::Serial, short, 1);
        assert!(matches!(r, Err(Error::Config(_))));
    }
}
