//! Workload generation: LLaMA-derived GEMMs (Table I), the C3 scenario
//! suite (Table II), and the taxonomy engine (§III).

pub mod e2e;
pub mod llama;
pub mod scenarios;
pub mod taxonomy;
pub mod trace;

pub use scenarios::{
    resolve, resolve_tag, suite, suite_for, try_resolve, ResolvedScenario, Table2Row, TABLE2,
};
pub use taxonomy::{pct_of_ideal, C3Type, Taxonomy};
