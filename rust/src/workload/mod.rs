//! Workload generation: LLaMA-derived GEMMs (Table I), the C3 scenario
//! suite (Table II), the taxonomy engine (§III), the e2e training
//! families, and the inference-serving layer ([`serving`] step graphs
//! driven by the [`traffic`] open-loop arrival engine).

pub mod e2e;
pub mod llama;
pub mod scenarios;
pub mod serving;
pub mod taxonomy;
pub mod trace;
pub mod traffic;

pub use scenarios::{
    resolve, resolve_tag, suite, suite_for, try_resolve, ResolvedScenario, Table2Row, TABLE2,
};
pub use serving::{ServeKind, ServeSpec, ServeStepper, StepCost};
pub use taxonomy::{pct_of_ideal, C3Type, Taxonomy};
pub use traffic::{run_serve, run_serve_lineup, ServeReport, TrafficConfig};
