//! # ConCCL — Concurrent Computation & Communication with GPU DMA engines
//!
//! A full reproduction of *"Optimizing ML Concurrent Computation and
//! Communication with GPU DMA Engines"* (AMD, ISPASS'24) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a C3 scheduler
//!   with schedule prioritization, CU resource partitioning, runtime
//!   heuristics, and ConCCL DMA-offloaded collectives, running over a
//!   discrete-event fluid simulator of an 8× MI300X node (the hardware
//!   substitute; see DESIGN.md) plus a real byte-moving data plane.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs (GEMM /
//!   MLP blocks) lowered once to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — the GEMM hot-spot as a
//!   tiled Pallas kernel, validated against a pure-jnp oracle.
//!
//! The `runtime` module loads the AOT artifacts via PJRT and executes
//! them from Rust — Python is never on the request path.

pub mod cli;
pub mod conccl;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod fabric;
pub mod gpu;
pub mod heuristics;
pub mod kernels;
pub mod node;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sweep;
pub mod util;
pub mod workload;

pub use error::Error;
