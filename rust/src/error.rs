//! Typed errors for the evaluation pipeline.
//!
//! The seed's lookup and simulation paths panicked on bad input (unknown
//! Table I tag, unknown scenario/strategy names, a stalled fluid
//! simulation). The sweep engine runs thousands of jobs concurrently and
//! must be able to fail *one job* with a diagnosable error instead of
//! aborting the whole process, so every such path now surfaces an
//! [`Error`].

use std::fmt;

use crate::sim::fluid::{SimError, StallError, UnboundedRateError};

/// One failure in the scenario/strategy/simulation pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum Error {
    /// A GEMM tag that is not in Table I (`cb1`..`cb5`, `mb1`, `mb2`).
    UnknownGemmTag(String),
    /// A scenario tag that is not a Table II row (e.g. `mb1_896M`).
    UnknownScenario(String),
    /// A strategy name outside the evaluated lineup.
    UnknownStrategy(String),
    /// A collective kind name outside all-gather/all-to-all/all-reduce.
    UnknownCollective(String),
    /// A collective that has no DMA-offloaded form (all-reduce: SDMA
    /// engines move bytes but cannot do arithmetic, §VI-B).
    NotDmaOffloadable(String),
    /// Malformed configuration input (sizes, overrides, variant specs).
    Config(String),
    /// A collective command plan violated the write-exactly-once
    /// conservation invariant (a hole, a double write, or an
    /// out-of-bounds write on a final output buffer).
    Conservation(String),
    /// The fluid simulation stalled: tasks remained with no way to make
    /// progress. Carries the full per-task diagnosis.
    SimStall(StallError),
    /// The fluid rate solver diverged: tasks with an infinite cap and no
    /// positive resource demand have no finite max-min rate. Names the
    /// unbounded tasks.
    SimUnbounded(UnboundedRateError),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnknownGemmTag(t) => {
                write!(f, "unknown Table I GEMM tag '{t}' (expected cb1..cb5, mb1, mb2)")
            }
            Error::UnknownScenario(t) => {
                write!(f, "unknown scenario '{t}' (see `conccl characterize` for Table II tags)")
            }
            Error::UnknownStrategy(s) => {
                write!(f, "unknown strategy '{s}' (expected serial, c3_base, c3_sp, c3_rp, c3_sp_rp, c3_best, conccl, conccl_rp, c3_chunked, conccl_chunked)")
            }
            Error::UnknownCollective(s) => {
                write!(f, "unknown collective '{s}' (expected all-gather, all-to-all, all-reduce)")
            }
            Error::NotDmaOffloadable(k) => {
                write!(f, "{k} cannot be offloaded to DMA engines (no arithmetic)")
            }
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Conservation(msg) => {
                write!(f, "collective plan violates conservation: {msg}")
            }
            Error::SimStall(s) => write!(f, "{s}"),
            Error::SimUnbounded(u) => write!(f, "{u}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<StallError> for Error {
    fn from(s: StallError) -> Error {
        Error::SimStall(s)
    }
}

impl From<UnboundedRateError> for Error {
    fn from(u: UnboundedRateError) -> Error {
        Error::SimUnbounded(u)
    }
}

impl From<SimError> for Error {
    fn from(e: SimError) -> Error {
        match e {
            SimError::Stall(s) => Error::SimStall(s),
            SimError::Unbounded(u) => Error::SimUnbounded(u),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_offender() {
        let e = Error::UnknownScenario("zz_9G".into());
        assert!(e.to_string().contains("zz_9G"));
        let e = Error::UnknownStrategy("warp".into());
        assert!(e.to_string().contains("warp"));
        let e = Error::UnknownGemmTag("cb9".into());
        assert!(e.to_string().contains("cb9"));
        let e = Error::NotDmaOffloadable("all-reduce".into());
        assert!(e.to_string().contains("cannot be offloaded"));
        let e = Error::Conservation("gpu 3 output byte 7 never written".into());
        assert!(e.to_string().contains("conservation"));
        assert!(e.to_string().contains("never written"));
    }

    #[test]
    fn is_std_error() {
        fn takes_err(_: &dyn std::error::Error) {}
        takes_err(&Error::Config("x".into()));
    }
}
