//! AOT artifact manifest: what `python -m compile.aot` produced and how
//! to feed it.
//!
//! Manifest line format (one artifact per line):
//! `<name> <file> <entry> <in0>;<in1>;...` where each input spec is
//! `<d0>x<d1>x...,<dtype>`.

use std::path::{Path, PathBuf};

/// One tensor input description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dims: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    /// Parse `"64x128,float32"`.
    pub fn parse(s: &str) -> Result<TensorSpec, String> {
        let (dims_s, dtype) = s
            .split_once(',')
            .ok_or_else(|| format!("bad tensor spec '{s}'"))?;
        let dims = dims_s
            .split('x')
            .map(|d| d.parse::<usize>().map_err(|e| format!("bad dim in '{s}': {e}")))
            .collect::<Result<Vec<_>, _>>()?;
        if dims.is_empty() {
            return Err(format!("empty dims in '{s}'"));
        }
        Ok(TensorSpec {
            dims,
            dtype: dtype.to_string(),
        })
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Dims as i64 (what the xla crate's reshape wants).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.dims.iter().map(|&d| d as i64).collect()
    }
}

/// One artifact: an HLO-text module plus its input signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub entry: String,
    pub inputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let mut artifacts = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(4, ' ');
            let (name, file, entry, ins) = (
                parts.next().ok_or(format!("line {}: missing name", i + 1))?,
                parts.next().ok_or(format!("line {}: missing file", i + 1))?,
                parts.next().ok_or(format!("line {}: missing entry", i + 1))?,
                parts.next().ok_or(format!("line {}: missing inputs", i + 1))?,
            );
            let inputs = ins
                .split(';')
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>, _>>()
                .map_err(|e| format!("line {}: {e}", i + 1))?;
            artifacts.push(ArtifactSpec {
                name: name.to_string(),
                file: file.to_string(),
                entry: entry.to_string(),
                inputs,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Load `manifest.txt` from a directory.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Default artifact directory: `$CONCCL_ARTIFACTS` or `./artifacts`
    /// (walking up from the current dir so tests work from any cwd).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("CONCCL_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.txt").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parse() {
        let t = TensorSpec::parse("64x128,float32").unwrap();
        assert_eq!(t.dims, vec![64, 128]);
        assert_eq!(t.dtype, "float32");
        assert_eq!(t.numel(), 8192);
        assert_eq!(t.dims_i64(), vec![64, 128]);
        assert!(TensorSpec::parse("no-comma").is_err());
        assert!(TensorSpec::parse("axb,f32").is_err());
    }

    #[test]
    fn manifest_parse_round_trip() {
        let text = "\
gemm_256 gemm_256.hlo.txt gemm 256x256,float32;256x256,float32
fsdp_layer fsdp_layer.hlo.txt layer_fwd_residual 64x128,float32;128x256,float32;256x128,float32
# comment line

";
        let m = Manifest::parse(Path::new("/tmp/arts"), text).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let g = m.get("gemm_256").unwrap();
        assert_eq!(g.entry, "gemm");
        assert_eq!(g.inputs.len(), 2);
        let f = m.get("fsdp_layer").unwrap();
        assert_eq!(f.inputs.len(), 3);
        assert_eq!(f.inputs[1].dims, vec![128, 256]);
        assert_eq!(m.path_of(g), Path::new("/tmp/arts/gemm_256.hlo.txt"));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Manifest::parse(Path::new("."), "justname").is_err());
        assert!(Manifest::parse(Path::new("."), "a b c bad-spec").is_err());
    }
}
