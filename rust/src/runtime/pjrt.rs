//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! Rust hot path — Python is never involved at run time.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `execute`. One compiled executable per
//! artifact, cached after first use.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use super::artifacts::{ArtifactSpec, Manifest};

/// The runtime: a PJRT client plus compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over the default artifact directory.
    pub fn cpu() -> Result<Runtime> {
        Self::with_dir(&Manifest::default_dir())
    }

    /// Create a CPU PJRT runtime over a specific artifact directory.
    pub fn with_dir(dir: &std::path::Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect()
    }

    /// Input signature of an artifact.
    pub fn signature(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Compile (and cache) an artifact's executable.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = self.manifest.path_of(&spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an f32 artifact: `inputs[i]` must match the manifest
    /// signature. Returns the flattened f32 output (first tuple
    /// element — our L2 functions return 1-tuples).
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        self.load(name)?;
        let spec = self.signature(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, tspec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if data.len() != tspec.numel() {
                return Err(anyhow!(
                    "{name} input {i}: expected {} elements, got {}",
                    tspec.numel(),
                    data.len()
                ));
            }
            let lit = xla::Literal::vec1(data)
                .reshape(&tspec.dims_i64())
                .with_context(|| format!("reshaping input {i}"))?;
            literals.push(lit);
        }
        let exe = self.cache.get(name).expect("loaded above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests are skipped (with a loud note) if artifacts haven't been
    /// built — `make artifacts` is a build-time step, and `make test`
    /// always runs it first.
    fn runtime() -> Option<Runtime> {
        match Runtime::cpu() {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!("SKIP pjrt tests: {e}");
                None
            }
        }
    }

    #[test]
    fn gemm_artifact_matches_host_reference() {
        let Some(mut rt) = runtime() else { return };
        let n = 256;
        let x: Vec<f32> = (0..n * n).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let y: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
        let got = rt.execute_f32("gemm_256", &[&x, &y]).expect("execute");
        assert_eq!(got.len(), n * n);
        // Host reference for a few entries.
        for &(r, c) in &[(0usize, 0usize), (5, 9), (100, 200), (255, 255)] {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += (x[r * n + k] as f64) * (y[k * n + c] as f64);
            }
            let got_v = got[r * n + c] as f64;
            assert!(
                (got_v - acc).abs() <= 1e-3 * acc.abs().max(1.0),
                "({r},{c}): {got_v} vs {acc}"
            );
        }
    }

    #[test]
    fn rectangular_gemm_shape() {
        let Some(mut rt) = runtime() else { return };
        let x = vec![0.01f32; 128 * 256];
        let y = vec![0.02f32; 256 * 512];
        let got = rt.execute_f32("gemm_128x512x256", &[&x, &y]).unwrap();
        assert_eq!(got.len(), 128 * 512);
        // All entries equal: 256 * 0.01 * 0.02 = 0.0512.
        for &v in got.iter().take(10) {
            assert!((v - 0.0512).abs() < 1e-5, "{v}");
        }
    }

    #[test]
    fn fsdp_layer_residual_identity_with_zero_weights() {
        let Some(mut rt) = runtime() else { return };
        let x: Vec<f32> = (0..64 * 128).map(|i| (i % 11) as f32 * 0.1).collect();
        let w1 = vec![0.0f32; 128 * 256];
        let w2 = vec![0.0f32; 256 * 128];
        let got = rt.execute_f32("fsdp_layer", &[&x, &w1, &w2]).unwrap();
        assert_eq!(got.len(), x.len());
        for (a, b) in got.iter().zip(&x) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn input_validation_errors() {
        let Some(mut rt) = runtime() else { return };
        let bad = vec![0.0f32; 3];
        assert!(rt.execute_f32("gemm_256", &[&bad, &bad]).is_err());
        assert!(rt.execute_f32("no_such_artifact", &[]).is_err());
    }

    #[test]
    fn executable_cache_reuses_compilation() {
        let Some(mut rt) = runtime() else { return };
        let x = vec![0.0f32; 256 * 256];
        let t0 = std::time::Instant::now();
        rt.execute_f32("gemm_256", &[&x, &x]).unwrap();
        let first = t0.elapsed();
        let t1 = std::time::Instant::now();
        rt.execute_f32("gemm_256", &[&x, &x]).unwrap();
        let second = t1.elapsed();
        // Second call skips compilation; allow generous slack.
        assert!(second < first, "cache ineffective: {second:?} vs {first:?}");
    }
}
