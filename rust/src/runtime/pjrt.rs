//! Artifact runtime: load the AOT manifest produced by
//! `python -m compile.aot` and execute artifacts from Rust — Python is
//! never on the request path.
//!
//! The offline build has no `xla`/PJRT crate (and no `anyhow`), so the
//! execution core is a **native reference executor**: it interprets the
//! manifest's entry points (`gemm`, `mlp_block`, `layer_fwd_residual` —
//! the exact functions `python/compile/model.py` lowers) with
//! f64-accumulated host arithmetic. The API is unchanged from the PJRT
//! wrapper it replaces, signature validation is identical, and the
//! numerics match the JAX/Pallas artifacts to the tolerances the tests
//! assert — so callers (examples, the e2e driver) are oblivious to the
//! backend swap.

use std::collections::HashSet;
use std::fmt;

use super::artifacts::{ArtifactSpec, Manifest};

/// Typed runtime failure (replaces the `anyhow` the offline build
/// cannot fetch).
#[derive(Debug, Clone, PartialEq)]
pub enum RuntimeError {
    /// The artifact directory / manifest could not be loaded.
    ManifestUnavailable(String),
    /// No artifact with that name in the manifest.
    UnknownArtifact(String),
    /// An entry point the native executor cannot interpret.
    UnsupportedEntry { artifact: String, entry: String },
    /// Input arity/shape mismatch against the manifest signature.
    BadInput(String),
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::ManifestUnavailable(e) => write!(f, "artifact manifest unavailable: {e}"),
            RuntimeError::UnknownArtifact(n) => write!(f, "unknown artifact '{n}'"),
            RuntimeError::UnsupportedEntry { artifact, entry } => {
                write!(f, "artifact '{artifact}': entry '{entry}' not supported by the native executor")
            }
            RuntimeError::BadInput(e) => write!(f, "bad input: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

/// Entry points the native executor can interpret — the single source
/// of truth for the supported-entry list (validation and dispatch both
/// go through [`EntryKind::parse`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EntryKind {
    /// `C = A · B`.
    Gemm,
    /// `relu(x @ w1) @ w2`, optionally `+ x` (the FSDP layer stage).
    Mlp { residual: bool },
}

impl EntryKind {
    fn parse(entry: &str) -> Option<EntryKind> {
        match entry {
            "gemm" => Some(EntryKind::Gemm),
            "mlp_block" => Some(EntryKind::Mlp { residual: false }),
            "layer_fwd_residual" => Some(EntryKind::Mlp { residual: true }),
            _ => None,
        }
    }

    fn arity(self) -> usize {
        match self {
            EntryKind::Gemm => 2,
            EntryKind::Mlp { .. } => 3,
        }
    }
}

/// The runtime: manifest + per-artifact load cache.
pub struct Runtime {
    manifest: Manifest,
    loaded: HashSet<String>,
}

impl Runtime {
    /// Create a runtime over the default artifact directory.
    pub fn cpu() -> Result<Runtime, RuntimeError> {
        Self::with_dir(&Manifest::default_dir())
    }

    /// Create a runtime over a specific artifact directory.
    pub fn with_dir(dir: &std::path::Path) -> Result<Runtime, RuntimeError> {
        let manifest = Manifest::load(dir).map_err(RuntimeError::ManifestUnavailable)?;
        Ok(Self::from_manifest(manifest))
    }

    /// Create a runtime directly from a parsed manifest (tests; no
    /// filesystem access needed by the native executor).
    pub fn from_manifest(manifest: Manifest) -> Runtime {
        Runtime {
            manifest,
            loaded: HashSet::new(),
        }
    }

    /// Backend/platform string (diagnostics).
    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect()
    }

    /// Input signature of an artifact.
    pub fn signature(&self, name: &str) -> Result<&ArtifactSpec, RuntimeError> {
        self.manifest
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))
    }

    /// "Compile" (validate and cache) an artifact: the entry must be
    /// interpretable and the signature sane.
    pub fn load(&mut self, name: &str) -> Result<(), RuntimeError> {
        if self.loaded.contains(name) {
            return Ok(());
        }
        let spec = self.signature(name)?;
        let kind = Self::entry_kind(name, spec)?;
        if spec.inputs.len() != kind.arity() {
            return Err(RuntimeError::BadInput(format!(
                "{name}: {} entry expects {} inputs, manifest lists {}",
                spec.entry,
                kind.arity(),
                spec.inputs.len()
            )));
        }
        self.loaded.insert(name.to_string());
        Ok(())
    }

    fn entry_kind(name: &str, spec: &ArtifactSpec) -> Result<EntryKind, RuntimeError> {
        EntryKind::parse(&spec.entry).ok_or_else(|| RuntimeError::UnsupportedEntry {
            artifact: name.to_string(),
            entry: spec.entry.clone(),
        })
    }

    /// Execute an f32 artifact: `inputs[i]` must match the manifest
    /// signature. Returns the flattened f32 output.
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>, RuntimeError> {
        self.load(name)?;
        let spec = self.signature(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            return Err(RuntimeError::BadInput(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (data, tspec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if data.len() != tspec.numel() {
                return Err(RuntimeError::BadInput(format!(
                    "{name} input {i}: expected {} elements, got {}",
                    tspec.numel(),
                    data.len()
                )));
            }
        }
        let dims2 = |i: usize| -> Result<(usize, usize), RuntimeError> {
            let d = &spec.inputs[i].dims;
            if d.len() != 2 {
                return Err(RuntimeError::BadInput(format!(
                    "{name} input {i}: expected rank 2, got rank {}",
                    d.len()
                )));
            }
            Ok((d[0], d[1]))
        };
        match Self::entry_kind(name, &spec)? {
            EntryKind::Gemm => {
                let (m, k) = dims2(0)?;
                let (k2, n) = dims2(1)?;
                if k != k2 {
                    return Err(RuntimeError::BadInput(format!(
                        "{name}: contraction mismatch {k} vs {k2}"
                    )));
                }
                Ok(matmul(inputs[0], inputs[1], m, k, n))
            }
            EntryKind::Mlp { residual } => {
                let (b, h) = dims2(0)?;
                let (h1, ff) = dims2(1)?;
                let (ff2, h2) = dims2(2)?;
                if h != h1 || ff != ff2 || h != h2 {
                    return Err(RuntimeError::BadInput(format!(
                        "{name}: layer shape mismatch x[{b}x{h}] w1[{h1}x{ff}] w2[{ff2}x{h2}]"
                    )));
                }
                let mut hid = matmul(inputs[0], inputs[1], b, h, ff);
                for v in hid.iter_mut() {
                    *v = v.max(0.0); // relu
                }
                let mut y = matmul(&hid, inputs[2], b, ff, h);
                if residual {
                    for (o, x) in y.iter_mut().zip(inputs[0]) {
                        *o += x;
                    }
                }
                Ok(y)
            }
        }
    }
}

/// `C[M,N] = A[M,K] · B[K,N]`, f32 storage with f64 accumulation (the
/// reference the Pallas kernel is validated against on the Python side).
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for r in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for kk in 0..k {
                acc += a[r * k + kk] as f64 * b[kk * n + j] as f64;
            }
            c[r * n + j] = acc as f32;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    /// In-memory manifest mirroring what `python -m compile.aot` emits —
    /// the native executor needs no HLO files on disk.
    fn runtime() -> Runtime {
        let text = "\
gemm_256 gemm_256.hlo.txt gemm 256x256,float32;256x256,float32
gemm_128x512x256 gemm_128x512x256.hlo.txt gemm 128x256,float32;256x512,float32
fsdp_layer fsdp_layer.hlo.txt layer_fwd_residual 64x128,float32;128x256,float32;256x128,float32
mlp_block mlp_block.hlo.txt mlp_block 64x128,float32;128x256,float32;256x128,float32
weird weird.hlo.txt exotic_entry 4x4,float32
";
        Runtime::from_manifest(Manifest::parse(Path::new("/nonexistent"), text).unwrap())
    }

    #[test]
    fn gemm_artifact_matches_host_reference() {
        let mut rt = runtime();
        let n = 256;
        let x: Vec<f32> = (0..n * n).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let y: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
        let got = rt.execute_f32("gemm_256", &[&x, &y]).expect("execute");
        assert_eq!(got.len(), n * n);
        for &(r, c) in &[(0usize, 0usize), (5, 9), (100, 200), (255, 255)] {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += (x[r * n + k] as f64) * (y[k * n + c] as f64);
            }
            let got_v = got[r * n + c] as f64;
            assert!(
                (got_v - acc).abs() <= 1e-3 * acc.abs().max(1.0),
                "({r},{c}): {got_v} vs {acc}"
            );
        }
    }

    #[test]
    fn rectangular_gemm_shape() {
        let mut rt = runtime();
        let x = vec![0.01f32; 128 * 256];
        let y = vec![0.02f32; 256 * 512];
        let got = rt.execute_f32("gemm_128x512x256", &[&x, &y]).unwrap();
        assert_eq!(got.len(), 128 * 512);
        // All entries equal: 256 * 0.01 * 0.02 = 0.0512.
        for &v in got.iter().take(10) {
            assert!((v - 0.0512).abs() < 1e-5, "{v}");
        }
    }

    #[test]
    fn fsdp_layer_residual_identity_with_zero_weights() {
        let mut rt = runtime();
        let x: Vec<f32> = (0..64 * 128).map(|i| (i % 11) as f32 * 0.1).collect();
        let w1 = vec![0.0f32; 128 * 256];
        let w2 = vec![0.0f32; 256 * 128];
        let got = rt.execute_f32("fsdp_layer", &[&x, &w1, &w2]).unwrap();
        assert_eq!(got.len(), x.len());
        for (a, b) in got.iter().zip(&x) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn mlp_block_applies_relu_without_residual() {
        let mut rt = runtime();
        // w1 = 0 -> hidden = relu(0) = 0 -> output = 0 (no residual).
        let x: Vec<f32> = (0..64 * 128).map(|i| (i % 5) as f32).collect();
        let w1 = vec![0.0f32; 128 * 256];
        let w2 = vec![1.0f32; 256 * 128];
        let got = rt.execute_f32("mlp_block", &[&x, &w1, &w2]).unwrap();
        assert!(got.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn input_validation_errors() {
        let mut rt = runtime();
        let bad = vec![0.0f32; 3];
        assert!(matches!(
            rt.execute_f32("gemm_256", &[&bad, &bad]),
            Err(RuntimeError::BadInput(_))
        ));
        assert!(matches!(
            rt.execute_f32("no_such_artifact", &[]),
            Err(RuntimeError::UnknownArtifact(_))
        ));
        assert!(matches!(
            rt.execute_f32("weird", &[&bad]),
            Err(RuntimeError::UnsupportedEntry { .. })
        ));
    }

    #[test]
    fn signatures_and_names_come_from_manifest() {
        let rt = runtime();
        assert_eq!(rt.artifact_names().len(), 5);
        let sig = rt.signature("fsdp_layer").unwrap();
        assert_eq!(sig.inputs.len(), 3);
        assert_eq!(sig.inputs[1].dims, vec![128, 256]);
        assert!(rt.signature("nope").is_err());
    }

    #[test]
    fn missing_artifact_dir_is_a_clean_error() {
        let err = Runtime::with_dir(Path::new("/definitely/not/here")).unwrap_err();
        assert!(matches!(err, RuntimeError::ManifestUnavailable(_)));
    }
}
