//! Request-path runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`
//! produced once by `python -m compile.aot`) and executes them from
//! Rust. Python never runs here. Offline builds use the native
//! reference executor in [`pjrt`] (no `xla` crate available); the API
//! is identical either way.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt::{Runtime, RuntimeError};
