//! Request-path runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`
//! produced once by `python -m compile.aot`) and executes them on the
//! PJRT CPU client. Python never runs here.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt::Runtime;
