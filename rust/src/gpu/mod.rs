//! GPU device machinery: simulated per-GPU memory (real bytes) and the
//! SDMA copy-engine command/queue/timing model (paper §II-B, Fig 3).
//!
//! The *compute* side of the GPU (CU occupancy, waves, caches) is
//! modelled analytically in `kernels/` and composed by `sched/`; this
//! module owns the parts ConCCL's data path touches.

pub mod memory;
pub mod sdma;

pub use memory::{BufferId, GpuMemory};
pub use sdma::{
    engine_demand, schedule, schedule_phases, CommandPacket, EnginePolicy, PhasedSchedule,
    SdmaModel, SdmaSchedule, TransferTiming,
};
