//! SDMA copy-engine machinery (paper §II-B, Fig 3), parameterized by
//! [`SdmaModel`] — the hardware design point the `dse` sweep explores.
//!
//! Mirrors the real orchestration flow:
//!
//! 1. the CPU runtime places a *command packet* in a DMA queue
//!    (`sdma.enqueue_s` per packet, serialized per orchestrating
//!    thread) and rings the engine's doorbell (`sdma.doorbell_s`);
//!    up to `sdma.fused_packets` packets share one enqueue+doorbell
//!    (§VII-B6: a fused command interface amortizes launch cost);
//! 2. the engine fetches and decodes it (`sdma.fetch_s`);
//! 3. the engine issues reads/writes over the fabric link — transfers on
//!    the same engine or the same uni-directional link serialize, and a
//!    finite per-engine command queue (`sdma.queue_depth` slots per
//!    engine) backpressures the enqueuing CPU thread when full;
//! 4. the CPU synchronizes on completion (`sdma.sync_s` per batch).
//!
//! [`schedule`] computes exact per-transfer timing for a batch of
//! command packets (no data movement — usable at 20 GB scale);
//! the data plane in `node/` replays a schedule against real
//! [`GpuMemory`](crate::gpu::memory::GpuMemory) buffers.
//!
//! Links are heterogeneous: intra-node Infinity-Fabric links run at the
//! machine's DMA link bandwidth; inter-node NIC links run at the
//! topology's (lower) NIC bandwidth and charge a per-transfer latency.
//! An engine drives at most `sdma.engine_bw_share` of any link it
//! crosses. A command between GPUs with no direct link becomes a
//! *staged multi-hop copy*: the engine store-and-forwards the payload
//! through each intermediate hop's HBM ([`Topology::path`]), serializing
//! on every link it crosses. [`schedule_phases`] prices
//! barrier-separated phase sequences (hierarchical collectives sync the
//! CPU between phases).
//!
//! # Example
//!
//! Construct a hypothetical DMA subsystem and read its derived costs —
//! the same path `conccl dse` takes for every grid point:
//!
//! ```
//! use conccl::config::machine::MachineConfig;
//! use conccl::gpu::sdma::{engine_demand, SdmaModel};
//!
//! // Default MI300X: 14 engines, unbounded queues, no doorbell cost,
//! // one packet per enqueue. A lone 8-GPU collective occupies
//! // min(num_gpus, engines) = 8 engines.
//! let mut m = MachineConfig::mi300x();
//! assert_eq!(engine_demand(&m), 8.0);
//! // Issuing 8 packets costs 8 serialized enqueues at the default.
//! assert!((m.sdma.issue_hold(8) - 8.0 * m.sdma.enqueue_s).abs() < 1e-15);
//!
//! // A hypothetical part: 4 beefier engines with depth-2 queues and a
//! // 4-packet fused command interface.
//! m.sdma = SdmaModel { engines: 4, queue_depth: 2, fused_packets: 4, ..SdmaModel::mi300x() };
//! assert_eq!(engine_demand(&m), 4.0); // engines now bind
//! // Fusing cuts 8 packets to 2 enqueue+doorbell rounds.
//! assert!((m.sdma.issue_hold(8) - 2.0 * m.sdma.enqueue_s).abs() < 1e-15);
//! // 7 peer transfers over 4 engines serialize by 7/4 on the wire.
//! assert!((m.sdma.wire_factor(7) - 1.75).abs() < 1e-12);
//! assert!(m.validate().is_empty());
//! ```

use crate::config::machine::MachineConfig;
use crate::error::Error;
use crate::fabric::{LinkClass, Topology};
use crate::gpu::memory::BufferId;

/// The DMA subsystem's hardware design point (roadmap item 3; grounded
/// in the finer-grain DSE paper's initiation-interval/queue-depth
/// parameters and DMA-Latte's enqueue/doorbell split). The default is
/// the MI300X as the paper measured it; the `dse` sweep perturbs these
/// fields to price hypothetical parts. Every field is settable via
/// `--set sdma.<field>=...` and `--variants`.
#[derive(Debug, Clone, PartialEq)]
pub struct SdmaModel {
    /// SDMA copy engines per GPU (14 on MI300X).
    pub engines: usize,
    /// Fraction of a link's bandwidth one engine can drive (1.0: an
    /// engine saturates its link, the MI300X PoC assumption; <1 models
    /// narrower per-engine datapaths, so a collective's wire time
    /// inflates once transfers outnumber `engines * engine_bw_share`).
    pub engine_bw_share: f64,
    /// Command-queue slots per engine. 0 = unbounded (the legacy model:
    /// the CPU never stalls on a full ring). Finite depths backpressure
    /// the enqueuing thread once `engines * queue_depth` commands are
    /// in flight.
    pub queue_depth: usize,
    /// CPU-side cost to enqueue ONE command packet, s (Fig 3 step 1;
    /// calibrated against Fig 9's ≤4× ConCCL penalty below 32 MiB).
    pub enqueue_s: f64,
    /// Doorbell-ring cost per enqueue, s (0 on the baseline: folded
    /// into `enqueue_s`; split out so a GPU-orchestrated control path
    /// (§VII-B6) can price cheap enqueues with a residual doorbell).
    pub doorbell_s: f64,
    /// Engine fetch+decode latency per command, s (Fig 3 steps 2–3).
    pub fetch_s: f64,
    /// CPU-side completion-synchronization cost per batch, s.
    pub sync_s: f64,
    /// Packets amortized per enqueue+doorbell (1 = no fusing, the
    /// baseline; >1 models a fused/batched command interface).
    pub fused_packets: usize,
}

impl SdmaModel {
    /// The MI300X subsystem as the paper measured it (also `Default`).
    pub fn mi300x() -> Self {
        SdmaModel {
            engines: 14,
            engine_bw_share: 1.0,
            queue_depth: 0,
            enqueue_s: 6e-6,
            doorbell_s: 0.0,
            fetch_s: 4e-6,
            sync_s: 8e-6,
            fused_packets: 1,
        }
    }

    /// CPU time to issue one fused group: enqueue + doorbell.
    pub fn issue_slot_s(&self) -> f64 {
        self.enqueue_s + self.doorbell_s
    }

    /// CPU time to issue `packets` command packets from one thread:
    /// `ceil(packets / fused_packets)` serialized enqueue+doorbell
    /// rounds. Reduces bit-exactly to `packets * enqueue_s` at the
    /// default (fused_packets = 1, doorbell_s = 0).
    pub fn issue_hold(&self, packets: usize) -> f64 {
        let f = self.fused_packets.max(1);
        (packets.div_ceil(f)) as f64 * self.issue_slot_s()
    }

    /// Wire-time inflation when `transfers` concurrent transfers share
    /// the engine pool: transfers beyond `engines` serialize (fluid
    /// reading: `transfers / engines` rounds), and every transfer runs
    /// at `engine_bw_share` of its link. 1.0 (a bit-exact no-op) at the
    /// MI300X default, where 14 engines cover a node's 7 peer
    /// transfers at full link rate.
    pub fn wire_factor(&self, transfers: usize) -> f64 {
        let rounds = (transfers as f64 / self.engines.max(1) as f64).max(1.0);
        rounds / self.engine_bw_share
    }

    /// Extra serialization a finite command queue adds when issuing
    /// `packets` commands of `wire_per_packet` seconds each: with
    /// `engines * queue_depth` slots, the issuing thread stalls for one
    /// wire time per extra refill round. 0 at the default (unbounded).
    pub fn queue_stall_s(&self, packets: usize, wire_per_packet: f64) -> f64 {
        if self.queue_depth == 0 {
            return 0.0;
        }
        let slots = self.engines.max(1) * self.queue_depth;
        if packets <= slots {
            return 0.0;
        }
        (packets.div_ceil(slots) - 1) as f64 * wire_per_packet
    }

    /// Silicon-area proxy for the Pareto frontier's cost axis: engine
    /// count scaled by queue storage (a depth-16 queue roughly doubles
    /// an engine's footprint; depth 0, the unbounded legacy model, is
    /// priced as depth-free). Dimensionless — only ratios matter.
    pub fn area_proxy(&self) -> f64 {
        self.engines as f64 * (1.0 + self.queue_depth as f64 / 16.0)
    }

    /// Append internal-consistency problems to `errs` (composed into
    /// [`MachineConfig::validate`]).
    pub fn validate_into(&self, errs: &mut Vec<String>) {
        if self.engines == 0 {
            errs.push("sdma.engines must be >= 1".into());
        }
        if !(0.0 < self.engine_bw_share && self.engine_bw_share <= 1.0) {
            errs.push(format!(
                "sdma.engine_bw_share must be in (0,1], got {}",
                self.engine_bw_share
            ));
        }
        if self.fused_packets == 0 {
            errs.push("sdma.fused_packets must be >= 1".into());
        }
        for (name, v) in [
            ("sdma.enqueue_s", self.enqueue_s),
            ("sdma.doorbell_s", self.doorbell_s),
            ("sdma.fetch_s", self.fetch_s),
            ("sdma.sync_s", self.sync_s),
        ] {
            if !(v >= 0.0) {
                errs.push(format!("{name} must be >= 0, got {v}"));
            }
        }
    }
}

impl Default for SdmaModel {
    fn default() -> Self {
        Self::mi300x()
    }
}

/// One DMA command packet: copy `len` bytes from a buffer on `src_gpu`
/// to a buffer on `dst_gpu` (local copies allowed: `src_gpu == dst_gpu`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommandPacket {
    pub src_gpu: usize,
    pub src: BufferId,
    pub src_off: usize,
    pub dst_gpu: usize,
    pub dst: BufferId,
    pub dst_off: usize,
    pub len: usize,
}

/// Timing of one scheduled transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferTiming {
    /// When the CPU finished writing the command packet.
    pub enqueue_done: f64,
    /// When the engine began moving bytes.
    pub start: f64,
    /// When the last byte landed.
    pub finish: f64,
    /// Engine index on the orchestrating GPU.
    pub engine: usize,
}

/// Timing of a whole command batch.
#[derive(Debug, Clone, PartialEq)]
pub struct SdmaSchedule {
    /// Per-GPU, per-command timings (parallel to the input structure).
    pub timings: Vec<Vec<TransferTiming>>,
    /// Completion including the CPU-side sync (§VI-C's unamortized cost).
    pub total: f64,
    /// Max finish over transfers (excludes sync).
    pub last_finish: f64,
}

/// Engine selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnginePolicy {
    /// `i mod engines` — what a simple PoC does.
    RoundRobin,
    /// Earliest-available engine — a slightly smarter runtime.
    LeastLoaded,
}

/// Timing of a barrier-separated sequence of command batches (one
/// [`SdmaSchedule`] per phase). Hierarchical collectives need this: a
/// leader can only forward a node block after the intra-node phase that
/// assembled it completes.
#[derive(Debug, Clone, PartialEq)]
pub struct PhasedSchedule {
    pub phases: Vec<SdmaSchedule>,
    /// Completion of the whole pipeline including the final CPU sync.
    pub total: f64,
}

/// Engine-occupancy demand of one in-flight DMA collective on its
/// orchestrating GPU: the direct plans issue one transfer per
/// destination, so a collective occupies `min(num_gpus, sdma.engines)`
/// engines for the duration of its wire phase. The workload-graph
/// engine registers `machine.sdma.engines` as a finite fluid resource
/// and charges each concurrent DMA collective this demand — two
/// concurrent collectives on one GPU (2×8 = 16 occupancy units against
/// 14 engines on MI300X) slow each other, while a lone collective is
/// never engine-bound (the `min` keeps its own rate cap binding first).
pub fn engine_demand(m: &MachineConfig) -> f64 {
    m.num_gpus.min(m.sdma.engines.max(1)) as f64
}

/// Compute the timing of a batch of DMA commands. `per_gpu[g]` is the
/// command list enqueued by GPU `g`'s orchestrating CPU thread, in
/// order. Commands from different GPUs enqueue in parallel (one host
/// thread per GPU); commands from one GPU serialize at the model's
/// enqueue+doorbell cost per fused group.
///
/// Errors with [`Error::Config`] when the batch shape does not match
/// the topology or a command is not owned by its enqueuing GPU —
/// user-reachable via hand-built plans on hypothetical `dse` machines.
pub fn schedule(
    m: &MachineConfig,
    topo: &Topology,
    per_gpu: &[Vec<CommandPacket>],
    policy: EnginePolicy,
) -> Result<SdmaSchedule, Error> {
    schedule_at(m, topo, per_gpu, policy, 0.0)
}

/// Price a sequence of phases with a CPU-side barrier (sync) between
/// them: phase `p+1` commands are not enqueued before every phase-`p`
/// transfer has landed and the CPU has synchronized on it.
pub fn schedule_phases(
    m: &MachineConfig,
    topo: &Topology,
    phases: &[Vec<Vec<CommandPacket>>],
    policy: EnginePolicy,
) -> Result<PhasedSchedule, Error> {
    let mut t0 = 0.0f64;
    let mut out = Vec::with_capacity(phases.len());
    for per_gpu in phases {
        let s = schedule_at(m, topo, per_gpu, policy, t0)?;
        t0 = s.total; // barrier: last byte landed + CPU sync
        out.push(s);
    }
    Ok(PhasedSchedule {
        phases: out,
        total: t0,
    })
}

/// Split a command batch into `chunks` per-chunk batches for the
/// fine-grain pipeline: every packet's byte range is cut into `chunks`
/// contiguous slices (matching source/destination offsets), and chunk
/// `j`'s batch carries slice `j` of every packet. The union of the
/// chunk batches covers exactly the original bytes — chunking is a
/// scheduling decision, never a data decision — and each chunk batch
/// pays its own per-packet enqueue latency when scheduled, which is
/// what sends small chunks latency-bound (DMA-Latte).
pub fn chunk_commands(
    per_gpu: &[Vec<CommandPacket>],
    chunks: usize,
) -> Vec<Vec<Vec<CommandPacket>>> {
    let k = chunks.max(1);
    (0..k)
        .map(|j| {
            per_gpu
                .iter()
                .map(|cmds| {
                    cmds.iter()
                        .filter_map(|c| {
                            let off = c.len * j / k;
                            let end = c.len * (j + 1) / k;
                            (end > off).then_some(CommandPacket {
                                src_off: c.src_off + off,
                                dst_off: c.dst_off + off,
                                len: end - off,
                                ..*c
                            })
                        })
                        .collect()
                })
                .collect()
        })
        .collect()
}

/// [`schedule`] with all clocks (CPU threads, engines, links) starting
/// at `t0` — the building block of [`schedule_phases`].
fn schedule_at(
    m: &MachineConfig,
    topo: &Topology,
    per_gpu: &[Vec<CommandPacket>],
    policy: EnginePolicy,
    t0: f64,
) -> Result<SdmaSchedule, Error> {
    if per_gpu.len() != topo.num_gpus() {
        return Err(Error::Config(format!(
            "command batch has {} per-GPU lists for a {}-GPU topology",
            per_gpu.len(),
            topo.num_gpus()
        )));
    }
    let sd = &m.sdma;
    let engines = sd.engines.max(1);
    let fused = sd.fused_packets.max(1);
    let queue_slots = engines * sd.queue_depth; // 0 = unbounded
    // Busy-until times.
    let mut engine_free = vec![vec![t0; engines]; topo.num_gpus()];
    let mut link_free = vec![t0; topo.num_links()];
    // Local (intra-GPU) copies run at a fraction of HBM bandwidth
    // (read + write on the same stacks), capped by the engine's share.
    let local_bw = m.hbm_bw_achievable() / 2.0 * sd.engine_bw_share;

    let mut timings: Vec<Vec<TransferTiming>> = Vec::with_capacity(per_gpu.len());
    let mut last_finish = t0;
    for (g, cmds) in per_gpu.iter().enumerate() {
        let mut t_cpu = t0; // this GPU's orchestration thread clock
        // Finish times of commands still occupying a queue slot.
        let mut in_flight: Vec<f64> = Vec::new();
        let mut out = Vec::with_capacity(cmds.len());
        for (i, c) in cmds.iter().enumerate() {
            if c.src_gpu != g && c.dst_gpu != g {
                return Err(Error::Config(format!(
                    "command {i} ({} -> {}) not owned by GPU {g}",
                    c.src_gpu, c.dst_gpu
                )));
            }
            // A full command ring backpressures the enqueuing thread:
            // wait for the earliest in-flight command to retire.
            if queue_slots > 0 && in_flight.len() >= queue_slots {
                let (min_i, _) = in_flight
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .expect("in_flight is non-empty");
                let retired = in_flight.swap_remove(min_i);
                t_cpu = t_cpu.max(retired);
            }
            // Packets in one fused group share a single enqueue+doorbell.
            if i % fused == 0 {
                t_cpu += sd.issue_slot_s();
            }
            let enqueue_done = t_cpu;
            let ready = enqueue_done + sd.fetch_s;
            let engine = match policy {
                EnginePolicy::RoundRobin => i % engines,
                EnginePolicy::LeastLoaded => engine_free[g]
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(idx, _)| idx)
                    .unwrap_or(0),
            };
            let (start, finish) = if c.src_gpu == c.dst_gpu {
                let start = ready.max(engine_free[g][engine]);
                (start, start + c.len as f64 / local_bw)
            } else {
                // Store-and-forward along the route: each hop serializes
                // on its own link; hop k+1 starts when hop k has landed
                // in the intermediate GPU's HBM.
                let mut t = ready.max(engine_free[g][engine]);
                let mut start = f64::NAN;
                for w in topo.path(c.src_gpu, c.dst_gpu).windows(2) {
                    let l = topo.link_id(w[0], w[1]);
                    let (bw, lat) = match topo.link_class(w[0], w[1]) {
                        LinkClass::Fabric => (m.link_bw_dma() * sd.engine_bw_share, 0.0),
                        LinkClass::Nic => {
                            (topo.nic_bw() * sd.engine_bw_share, topo.nic_latency())
                        }
                    };
                    let s = t.max(link_free[l]);
                    if start.is_nan() {
                        start = s;
                    }
                    t = s + lat + c.len as f64 / bw;
                    link_free[l] = t;
                }
                (start, t)
            };
            // The orchestrating engine coordinates the whole (possibly
            // staged) transfer and is busy until the last hop lands.
            engine_free[g][engine] = finish;
            if queue_slots > 0 {
                in_flight.push(finish);
            }
            last_finish = last_finish.max(finish);
            out.push(TransferTiming {
                enqueue_done,
                start,
                finish,
                engine,
            });
        }
        timings.push(out);
    }
    Ok(SdmaSchedule {
        timings,
        total: last_finish + sd.sync_s,
        last_finish,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_rel_close;

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn cmd(src_gpu: usize, dst_gpu: usize, len: usize) -> CommandPacket {
        CommandPacket {
            src_gpu,
            src: BufferId(0),
            src_off: 0,
            dst_gpu,
            dst: BufferId(1),
            dst_off: 0,
            len,
        }
    }

    #[test]
    fn single_transfer_timing_decomposes() {
        let m = m();
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[0].push(cmd(0, 1, 1 << 30));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        let t = s.timings[0][0];
        assert_rel_close!(t.enqueue_done, m.sdma.enqueue_s, 1e-12);
        assert_rel_close!(t.start, m.sdma.enqueue_s + m.sdma.fetch_s, 1e-12);
        let wire = (1u64 << 30) as f64 / m.link_bw_dma();
        assert_rel_close!(t.finish - t.start, wire, 1e-12);
        assert_rel_close!(s.total, t.finish + m.sdma.sync_s, 1e-12);
    }

    #[test]
    fn transfers_to_distinct_peers_run_in_parallel() {
        // 7 peer transfers from GPU 0: distinct links + distinct engines
        // -> finish times differ only by the serialized enqueue steps.
        let m = m();
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        for p in 1..8 {
            per_gpu[0].push(cmd(0, p, 100 << 20));
        }
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        let wire = (100u64 << 20) as f64 / m.link_bw_dma();
        let first = s.timings[0][0];
        let last = s.timings[0][6];
        assert_rel_close!(first.finish - first.start, wire, 1e-12);
        // Last transfer starts later only by 6 extra enqueue slots.
        assert_rel_close!(last.start - first.start, 6.0 * m.sdma.enqueue_s, 1e-9);
    }

    #[test]
    fn same_link_serializes() {
        let m = m();
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[0].push(cmd(0, 1, 100 << 20));
        per_gpu[0].push(cmd(0, 1, 100 << 20));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        let a = s.timings[0][0];
        let b = s.timings[0][1];
        assert!(b.start >= a.finish, "second transfer must wait for link");
    }

    #[test]
    fn engine_contention_with_more_commands_than_engines() {
        let m = m();
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        // 28 transfers to 7 peers (4 each) from one GPU: engines (14) and
        // links (7) both force serialization; per-link 4 transfers.
        for round in 0..4 {
            for p in 1..8 {
                let _ = round;
                per_gpu[0].push(cmd(0, p, 10 << 20));
            }
        }
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::LeastLoaded).unwrap();
        let wire = (10u64 << 20) as f64 / m.link_bw_dma();
        // Lower bound: 4 serialized wire times on each link.
        assert!(s.last_finish >= 4.0 * wire);
        // Upper bound: far below fully-serial 28 transfers.
        assert!(s.last_finish < 28.0 * wire);
    }

    #[test]
    fn local_copy_uses_hbm_path() {
        let m = m();
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[3].push(cmd(3, 3, 1 << 30));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        let t = s.timings[3][0];
        let dur = (1u64 << 30) as f64 / (m.hbm_bw_achievable() / 2.0);
        assert_rel_close!(t.finish - t.start, dur, 1e-12);
    }

    #[test]
    fn gpus_orchestrate_in_parallel() {
        // The same work split across 8 GPUs finishes ~8x sooner than
        // enqueued from one GPU (CPU threads are per-GPU).
        let m = m();
        let topo = Topology::fully_connected(8);
        let mut spread = vec![Vec::new(); 8];
        for g in 0..8 {
            spread[g].push(cmd(g, (g + 1) % 8, 50 << 20));
        }
        let s_spread = schedule(&m, &topo, &spread, EnginePolicy::RoundRobin).unwrap();
        let wire = (50u64 << 20) as f64 / m.link_bw_dma();
        assert_rel_close!(
            s_spread.last_finish,
            m.sdma.enqueue_s + m.sdma.fetch_s + wire,
            1e-9
        );
    }

    #[test]
    fn cross_node_transfer_stages_through_leaders() {
        // 1 → 5 on a 2x4 topology routes 1 → 0 → 4 → 5: two fabric hops
        // plus one NIC hop with its latency; strictly slower than a
        // same-size intra-node transfer.
        let m = m();
        let topo = Topology::multi_node(2, 4, 10e9, 5e-6);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[1].push(cmd(1, 5, 100 << 20));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        let t = s.timings[1][0];
        let fabric_hop = (100u64 << 20) as f64 / m.link_bw_dma();
        let nic_hop = 5e-6 + (100u64 << 20) as f64 / 10e9;
        assert_rel_close!(t.finish - t.start, 2.0 * fabric_hop + nic_hop, 1e-9);

        let mut intra = vec![Vec::new(); 8];
        intra[1].push(cmd(1, 2, 100 << 20));
        let si = schedule(&m, &topo, &intra, EnginePolicy::RoundRobin).unwrap();
        assert!(t.finish > 2.0 * si.timings[1][0].finish);
    }

    #[test]
    fn nic_link_serializes_between_leader_pair() {
        // Two cross-node transfers from the same source node share the
        // single 0 → 4 NIC link and serialize there.
        let m = m();
        let topo = Topology::multi_node(2, 4, 10e9, 0.0);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[0].push(cmd(0, 4, 100 << 20));
        per_gpu[0].push(cmd(0, 4, 100 << 20));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::LeastLoaded).unwrap();
        let nic_hop = (100u64 << 20) as f64 / 10e9;
        let (a, b) = (s.timings[0][0], s.timings[0][1]);
        assert!(b.finish >= a.finish + nic_hop * 0.999, "NIC must serialize");
    }

    #[test]
    fn phases_barrier_between_rounds() {
        // Phase 2 cannot start before phase 1 has landed + synced, even
        // though it uses different links.
        let m = m();
        let topo = Topology::fully_connected(8);
        let mut p1 = vec![Vec::new(); 8];
        p1[0].push(cmd(0, 1, 100 << 20));
        let mut p2 = vec![Vec::new(); 8];
        p2[2].push(cmd(2, 3, 100 << 20));
        let ps =
            schedule_phases(&m, &topo, &[p1.clone(), p2], EnginePolicy::RoundRobin).unwrap();
        assert_eq!(ps.phases.len(), 2);
        let end1 = ps.phases[0].last_finish + m.sdma.sync_s;
        let t2 = ps.phases[1].timings[2][0];
        assert!(t2.enqueue_done >= end1, "phase 2 enqueued before barrier");
        assert_rel_close!(ps.total, ps.phases[1].last_finish + m.sdma.sync_s, 1e-12);
        // A single phase prices identically to plain `schedule` + sync.
        let single =
            schedule_phases(&m, &topo, &[p1.clone()], EnginePolicy::RoundRobin).unwrap();
        let flat = schedule(&m, &topo, &p1, EnginePolicy::RoundRobin).unwrap();
        assert_rel_close!(single.total, flat.total, 1e-12);
    }

    #[test]
    fn chunked_batches_cover_exact_bytes_and_pay_per_chunk_launch() {
        let m = m();
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        for p in 1..8 {
            per_gpu[0].push(cmd(0, p, (100 << 20) + 7)); // odd length
        }
        let chunked = chunk_commands(&per_gpu, 4);
        assert_eq!(chunked.len(), 4);
        // Byte coverage: each packet's slices tile its range exactly.
        for (orig_i, orig) in per_gpu[0].iter().enumerate() {
            let mut covered = 0usize;
            for batch in &chunked {
                let slice = &batch[0][orig_i];
                assert_eq!(slice.src_gpu, orig.src_gpu);
                assert_eq!(slice.dst_gpu, orig.dst_gpu);
                assert_eq!(slice.src_off, orig.src_off + covered);
                assert_eq!(slice.dst_off, orig.dst_off + covered);
                covered += slice.len;
            }
            assert_eq!(covered, orig.len);
        }
        // Scheduling the chunk batches as phases pays per-chunk
        // enqueue/sync: never faster than the whole batch, and the gap
        // shrinks relatively as payloads grow (latency amortizes).
        let whole = schedule(&m, &topo, &per_gpu, EnginePolicy::LeastLoaded).unwrap();
        let phased = schedule_phases(
            &m,
            &topo,
            &chunk_commands(&per_gpu, 4),
            EnginePolicy::LeastLoaded,
        )
        .unwrap();
        assert!(phased.total >= whole.total);
        // Tiny payloads: the per-chunk launch dominates outright.
        let mut small = vec![Vec::new(); 8];
        for p in 1..8 {
            small[0].push(cmd(0, p, 4096));
        }
        let sw = schedule(&m, &topo, &small, EnginePolicy::LeastLoaded).unwrap();
        let sp = schedule_phases(
            &m,
            &topo,
            &chunk_commands(&small, 8),
            EnginePolicy::LeastLoaded,
        )
        .unwrap();
        assert!(
            sp.total > 2.0 * sw.total,
            "latency-bound chunking should collapse: {} vs {}",
            sp.total,
            sw.total
        );
        // Chunking a zero-length-free batch never emits empty packets.
        for batch in chunk_commands(&small, 8) {
            for cmds in batch {
                for c in cmds {
                    assert!(c.len > 0);
                }
            }
        }
    }

    #[test]
    fn foreign_command_rejected_with_typed_error() {
        let m = m();
        let topo = Topology::fully_connected(4);
        let mut per_gpu = vec![Vec::new(); 4];
        per_gpu[0].push(cmd(1, 2, 64));
        let err = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err:?}");
        assert!(err.to_string().contains("not owned"), "{err}");
    }

    #[test]
    fn batch_shape_mismatch_rejected_with_typed_error() {
        let m = m();
        let topo = Topology::fully_connected(8);
        let per_gpu = vec![Vec::new(); 4]; // wrong: 4 lists, 8 GPUs
        let err = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err:?}");
        assert!(err.to_string().contains("8-GPU"), "{err}");
    }

    #[test]
    fn default_model_parameters_are_bit_exact_no_ops() {
        // The generalized formulas must collapse to the legacy terms at
        // the MI300X default — the graph_equiv 1e-9 suite depends on it.
        let sd = SdmaModel::mi300x();
        assert_eq!(sd.issue_hold(8), 8.0 * sd.enqueue_s);
        assert_eq!(sd.issue_slot_s(), sd.enqueue_s);
        assert_eq!(sd.wire_factor(7), 1.0);
        assert_eq!(sd.queue_stall_s(64, 1.0), 0.0);
        let mut errs = Vec::new();
        sd.validate_into(&mut errs);
        assert!(errs.is_empty(), "{errs:?}");
    }

    #[test]
    fn fused_packets_amortize_enqueue() {
        // 8 packets, fuse 4: two enqueue slots instead of eight; the
        // second fused group's packets share one enqueue_done stamp.
        let mut m = m();
        m.sdma.fused_packets = 4;
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        for p in 1..8 {
            per_gpu[0].push(cmd(0, p, 100 << 20));
        }
        per_gpu[0].push(cmd(0, 1, 100 << 20));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        let t = &s.timings[0];
        assert_rel_close!(t[0].enqueue_done, m.sdma.enqueue_s, 1e-12);
        assert_eq!(t[0].enqueue_done, t[3].enqueue_done);
        assert_rel_close!(t[4].enqueue_done, 2.0 * m.sdma.enqueue_s, 1e-12);
        assert_eq!(m.sdma.issue_hold(8), 2.0 * m.sdma.enqueue_s);
    }

    #[test]
    fn doorbell_cost_adds_to_issue_path() {
        let mut m = m();
        m.sdma.doorbell_s = 2e-6;
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[0].push(cmd(0, 1, 1 << 20));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        assert_rel_close!(
            s.timings[0][0].enqueue_done,
            m.sdma.enqueue_s + 2e-6,
            1e-12
        );
    }

    #[test]
    fn finite_queue_depth_backpressures_enqueue() {
        // 1 engine, depth 1: one slot. The second command's enqueue must
        // wait for the first transfer to retire; unbounded depth lets
        // every enqueue proceed back-to-back.
        let mut m = m();
        m.sdma.engines = 1;
        m.sdma.queue_depth = 1;
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[0].push(cmd(0, 1, 100 << 20));
        per_gpu[0].push(cmd(0, 2, 100 << 20));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        let (a, b) = (s.timings[0][0], s.timings[0][1]);
        assert!(
            b.enqueue_done >= a.finish,
            "full ring must stall the CPU: {} < {}",
            b.enqueue_done,
            a.finish
        );
        let mut unbounded = m.clone();
        unbounded.sdma.queue_depth = 0;
        let u = schedule(&unbounded, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        assert_rel_close!(
            u.timings[0][1].enqueue_done,
            2.0 * m.sdma.enqueue_s,
            1e-12
        );
        assert!(s.total >= u.total);
    }

    #[test]
    fn narrow_engine_bw_share_slows_the_wire() {
        let mut m = m();
        m.sdma.engine_bw_share = 0.5;
        let topo = Topology::fully_connected(8);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[0].push(cmd(0, 1, 1 << 30));
        let s = schedule(&m, &topo, &per_gpu, EnginePolicy::RoundRobin).unwrap();
        let t = s.timings[0][0];
        let wire = (1u64 << 30) as f64 / (m.link_bw_dma() * 0.5);
        assert_rel_close!(t.finish - t.start, wire, 1e-12);
        assert_eq!(m.sdma.wire_factor(14), 2.0);
    }

    #[test]
    fn area_proxy_orders_design_points() {
        let base = SdmaModel::mi300x();
        let mut more_engines = base.clone();
        more_engines.engines = 28;
        let mut deeper = base.clone();
        deeper.queue_depth = 16;
        assert!(more_engines.area_proxy() > base.area_proxy());
        assert!(deeper.area_proxy() > base.area_proxy());
        assert_eq!(deeper.area_proxy(), 2.0 * base.area_proxy());
    }

    #[test]
    fn model_validation_catches_bad_fields() {
        let mut sd = SdmaModel::mi300x();
        sd.engines = 0;
        sd.engine_bw_share = 1.5;
        sd.fused_packets = 0;
        sd.enqueue_s = -1.0;
        let mut errs = Vec::new();
        sd.validate_into(&mut errs);
        assert_eq!(errs.len(), 4, "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("engine_bw_share")));
    }
}
