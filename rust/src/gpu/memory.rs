//! Simulated GPU memory: real byte buffers standing in for one GPU's
//! HBM. The data plane's collectives actually move these bytes, so
//! collective *correctness* is testable end-to-end (the paper's ConCCL
//! PoCs move real data; ours must too, at laptop scale).

use std::collections::BTreeMap;

/// Handle to a buffer in one GPU's memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BufferId(pub u64);

/// One GPU's memory space: allocator + byte storage.
#[derive(Debug, Default)]
pub struct GpuMemory {
    next: u64,
    bufs: BTreeMap<BufferId, Vec<u8>>,
}

impl GpuMemory {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a zeroed buffer of `len` bytes.
    pub fn alloc(&mut self, len: usize) -> BufferId {
        let id = BufferId(self.next);
        self.next += 1;
        self.bufs.insert(id, vec![0u8; len]);
        id
    }

    /// Allocate and initialize from a slice.
    pub fn alloc_init(&mut self, data: &[u8]) -> BufferId {
        let id = self.alloc(data.len());
        self.bufs.get_mut(&id).unwrap().copy_from_slice(data);
        id
    }

    /// Free a buffer (panics on double free — that's a bug upstream).
    pub fn free(&mut self, id: BufferId) {
        self.bufs.remove(&id).expect("double free / unknown buffer");
    }

    /// Length of a buffer.
    pub fn len(&self, id: BufferId) -> usize {
        self.bufs[&id].len()
    }

    /// True if no buffers are live.
    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Total allocated bytes (footprint accounting for tests/metrics).
    pub fn footprint(&self) -> usize {
        self.bufs.values().map(Vec::len).sum()
    }

    /// Immutable view of a byte range.
    pub fn read(&self, id: BufferId, off: usize, len: usize) -> &[u8] {
        let b = &self.bufs[&id];
        assert!(
            off + len <= b.len(),
            "read OOB: {}+{} > {}",
            off,
            len,
            b.len()
        );
        &b[off..off + len]
    }

    /// Write bytes at an offset.
    pub fn write(&mut self, id: BufferId, off: usize, data: &[u8]) {
        let b = self.bufs.get_mut(&id).expect("unknown buffer");
        assert!(
            off + data.len() <= b.len(),
            "write OOB: {}+{} > {}",
            off,
            data.len(),
            b.len()
        );
        b[off..off + data.len()].copy_from_slice(data);
    }

    /// Whole-buffer view.
    pub fn bytes(&self, id: BufferId) -> &[u8] {
        &self.bufs[&id]
    }
}

/// Copy `len` bytes between two buffers that may live on different GPUs
/// (the DMA engine's data path). Caller has already split borrows.
pub fn copy_range(
    src: &GpuMemory,
    src_id: BufferId,
    src_off: usize,
    dst: &mut GpuMemory,
    dst_id: BufferId,
    dst_off: usize,
    len: usize,
) {
    // Copy through a temporary to sidestep borrow overlap when src==dst
    // memory spaces are distinct structs anyway; local copies within one
    // GPU go through the same path (DMA engines do local moves too).
    let data = src.read(src_id, src_off, len).to_vec();
    dst.write(dst_id, dst_off, &data);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_read_write_roundtrip() {
        let mut mem = GpuMemory::new();
        let b = mem.alloc(16);
        assert_eq!(mem.len(b), 16);
        assert_eq!(mem.read(b, 0, 16), &[0u8; 16]);
        mem.write(b, 4, &[1, 2, 3]);
        assert_eq!(mem.read(b, 4, 3), &[1, 2, 3]);
        assert_eq!(mem.read(b, 3, 1), &[0]);
    }

    #[test]
    fn alloc_init_and_footprint() {
        let mut mem = GpuMemory::new();
        let a = mem.alloc_init(&[9, 8, 7]);
        let _b = mem.alloc(5);
        assert_eq!(mem.bytes(a), &[9, 8, 7]);
        assert_eq!(mem.footprint(), 8);
        mem.free(a);
        assert_eq!(mem.footprint(), 5);
    }

    #[test]
    #[should_panic(expected = "OOB")]
    fn write_oob_panics() {
        let mut mem = GpuMemory::new();
        let b = mem.alloc(4);
        mem.write(b, 2, &[0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut mem = GpuMemory::new();
        let b = mem.alloc(4);
        mem.free(b);
        mem.free(b);
    }

    #[test]
    fn cross_memory_copy() {
        let mut a = GpuMemory::new();
        let mut b = GpuMemory::new();
        let src = a.alloc_init(&[1, 2, 3, 4]);
        let dst = b.alloc(4);
        copy_range(&a, src, 1, &mut b, dst, 2, 2);
        assert_eq!(b.bytes(dst), &[0, 0, 2, 3]);
    }

    #[test]
    fn distinct_handles() {
        let mut mem = GpuMemory::new();
        let a = mem.alloc(1);
        let b = mem.alloc(1);
        assert_ne!(a, b);
    }
}
