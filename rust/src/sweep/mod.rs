//! Parallel scenario-sweep engine: the batched, concurrent evaluation
//! path behind the paper's Figs 7–10 and Table II characterization.
//!
//! The seed evaluated one scenario at a time through
//! `coordinator::runner`. This subsystem turns that into a *job
//! matrix*:
//!
//! 1. **Plan** ([`plan`]) — expand {Table II scenarios × strategies ×
//!    machine configs × node counts × chunkings} into independent
//!    [`SweepJob`]s, each with a deterministic identity-derived RNG
//!    seed. The node-count axis prices every point on a hierarchical
//!    multi-node topology (`fabric::Topology::MultiNode`); the
//!    chunk-count axis re-prices the chunked pipeline strategies at
//!    fixed or swept-best (`auto`) granularity.
//! 2. **Execute** ([`engine`]) — run jobs concurrently on a worker pool
//!    (shared-counter work stealing over `std::thread::scope`); each job
//!    drives its own `sched::executor` + `sim::fluid` instance.
//!    Isolated-execution baselines (the serial/ideal denominators) are
//!    memoized once per (machine, scenario) instead of once per
//!    strategy. A failed job records a typed [`crate::error::Error`];
//!    the sweep continues.
//! 3. **Report** ([`json`] + `coordinator::report`) — aggregate into the
//!    existing human-readable figure tables and a byte-deterministic
//!    machine-readable JSON report.
//!
//! Determinism: same plan + same base seed ⇒ byte-identical JSON,
//! regardless of worker count (per-job seeds are derived from job
//! identity, never from execution order). `coordinator::run_suite` is a
//! thin wrapper over [`suite_outcomes`], so every figure bench and test
//! rides this engine.
//!
//! A fourth mode, [`dse`], inverts the sweep: instead of many workloads
//! on one machine, it scores workloads on a grid of *hypothetical*
//! DMA-engine subsystems and reports Pareto frontiers of speedup vs.
//! engine area (`conccl dse`).

pub mod baseline;
pub mod cache;
pub mod dse;
pub mod engine;
pub mod json;
pub mod key;
pub mod plan;

pub use baseline::{
    extract_points, gate, is_seeded, parse_json, BenchPoint, GateReport, Json, ParseError,
};
pub use cache::Cache;
pub use dse::{DsePlan, DsePoint, DseResults, DseScore, DseWorkload};
pub use engine::{
    default_threads, execute, execute_with, outcome_lineup, suite_outcomes, E2eOutput,
    ExecCounters, ExecOptions, JobOutput, JobSource, ServeOutput, SweepResults,
};
pub use key::{JobKey, KeyHasher, MODEL_VERSION};
pub use plan::{job_seed, parse_variants, ChunkSel, MachineVariant, SweepJob, SweepPlan};
