//! Machine-readable sweep report: a minimal, dependency-free JSON
//! writer (no `serde` offline).
//!
//! Determinism contract: serializing the same [`SweepResults`] always
//! yields the *byte-identical* string — key order is fixed, numbers use
//! Rust's shortest-roundtrip `f64` formatting, and job results are
//! ordered by dense job id (which the engine guarantees is independent
//! of thread count).

use std::fmt::Write as _;

use crate::coordinator::metrics::headline;

use super::engine::SweepResults;

/// Escape a string for a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Format an `f64` as a JSON number (shortest roundtrip); non-finite
/// values become `null` (JSON has no NaN/inf).
pub fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn opt_u32(v: Option<u32>) -> String {
    match v {
        Some(x) => x.to_string(),
        None => "null".to_string(),
    }
}

impl SweepResults {
    /// Serialize the whole sweep. See module docs for the determinism
    /// contract; the schema is versioned for downstream tooling
    /// (version 2 added the per-machine `topologies` nesting for the
    /// node-count axis; version 3 nests `chunkings` under each topology
    /// for the chunk-count axis and records per-strategy `chunks`;
    /// version 4 adds the per-topology `workloads[]` section for the
    /// end-to-end graph workload axis — present only when the plan
    /// carries e2e specs, so pairwise-only reports keep their shape;
    /// version 5 adds the `auto` family with its per-node `plan`
    /// record — winning strategy plus one backend/CUs/chunks entry per
    /// graph node; version 6 adds the per-topology `serving[]` section
    /// for the inference-serving traffic axis — steady-state latency
    /// percentiles, goodput and occupancies per serving family, present
    /// only when the plan carries serve specs, so v1–v5 consumers keep
    /// their shape; version 7 introduces the companion design-space
    /// report — `conccl dse` emits a separate `{"version":7,"dse":…}`
    /// document ([`super::dse`]) in the same version namespace, while
    /// sweep reports keep their v6 shape).
    pub fn to_json(&self) -> String {
        let cfg = &self.plan.cfg;
        let mut s = String::with_capacity(64 * 1024);
        s.push_str("{\"version\":7,");
        let _ = write!(
            s,
            "\"protocol\":{{\"warmup\":{},\"measured\":{},\"jitter\":{},\"seed\":{}}},",
            cfg.warmup,
            cfg.measured,
            num(cfg.jitter),
            cfg.seed
        );
        let _ = write!(
            s,
            "\"strategies\":[{}],",
            self.plan
                .strategies
                .iter()
                .map(|k| format!("\"{}\"", k.name()))
                .collect::<Vec<_>>()
                .join(",")
        );
        s.push_str("\"machines\":[");
        for (mi, mv) in self.plan.machines.iter().enumerate() {
            if mi > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "{{\"label\":\"{}\",\"name\":\"{}\",\"topologies\":[",
                escape(&mv.label),
                escape(&mv.machine.name)
            );
            for (ni, &nodes) in self.plan.node_counts.iter().enumerate() {
                if ni > 0 {
                    s.push(',');
                }
                let _ = write!(s, "{{\"nodes\":{nodes},\"chunkings\":[");
                for (ci, &chunks) in self.plan.chunk_counts.iter().enumerate() {
                    if ci > 0 {
                        s.push(',');
                    }
                    let chunk_json = match chunks {
                        crate::sweep::plan::ChunkSel::Auto => "\"auto\"".to_string(),
                        crate::sweep::plan::ChunkSel::Fixed(k) => k.to_string(),
                    };
                    let _ = write!(s, "{{\"chunks\":{chunk_json},\"scenarios\":[");
                    for (si, sc) in self.plan.scenarios.iter().enumerate() {
                        if si > 0 {
                            s.push(',');
                        }
                        let b = self.baselines[mi][ni][si];
                        let _ = write!(
                            s,
                            "{{\"tag\":\"{}\",\"collective\":\"{}\",\"source\":\"{}\",\
                             \"t_gemm_iso_s\":{},\"t_comm_iso_s\":{},\"serial_s\":{},\
                             \"ideal_speedup\":{},\"strategies\":{{",
                            escape(&sc.tag()),
                            sc.comm.spec.kind.name(),
                            sc.scenario.source.name(),
                            num(b.t_gemm_iso),
                            num(b.t_comm_iso),
                            num(b.serial()),
                            num(b.ideal())
                        );
                        for (ki, kind) in self.plan.strategies.iter().enumerate() {
                            if ki > 0 {
                                s.push(',');
                            }
                            let _ = write!(s, "\"{}\":", kind.name());
                            let out = &self.outputs[self.plan.job_id(mi, ni, ci, si, ki)];
                            if out.source == super::engine::JobSource::Skipped {
                                // Shard runs leave non-owned slots as
                                // explicit placeholders; a `--merge`
                                // run materializes them from the shard
                                // caches.
                                s.push_str("{\"skipped\":true}");
                                continue;
                            }
                            match &out.result {
                                Ok(m) => {
                                    let _ = write!(
                                        s,
                                        "{{\"total_s\":{},\"gemm_finish_s\":{},\"comm_finish_s\":{},\
                                         \"median_s\":{},\"speedup\":{},\"speedup_median\":{},\
                                         \"pct_ideal\":{},\"pct_ideal_median\":{},\"rp_cus\":{},\
                                         \"chunks\":{},\"seed\":\"{:#018x}\"}}",
                                        num(m.run.total),
                                        num(m.run.gemm_finish),
                                        num(m.run.comm_finish),
                                        num(m.stats.median),
                                        num(m.run.speedup),
                                        num(m.speedup_median),
                                        num(m.run.pct_ideal),
                                        num(m.pct_ideal_median),
                                        opt_u32(out.rp_cus),
                                        opt_u32(out.chunks_used),
                                        out.job.seed
                                    );
                                }
                                Err(e) => {
                                    let _ =
                                        write!(s, "{{\"error\":\"{}\"}}", escape(&e.to_string()));
                                }
                            }
                        }
                        s.push_str("}}");
                    }
                    s.push(']');
                    // Per-(topology, chunking) headline, when the plan
                    // carries the full outcome lineup (mirrors the
                    // human-readable tables).
                    if let Ok(outcomes) = self.to_scenario_outcomes(mi, ni, ci) {
                        let h = headline(&outcomes);
                        let _ = write!(
                            s,
                            ",\"headline\":{{\"n\":{},\"avg_ideal\":{},\"max_ideal\":{},\"per_strategy\":{{",
                            h.n,
                            num(h.avg_ideal),
                            num(h.max_ideal)
                        );
                        for (i, (name, (sp, pct, max))) in h.per_strategy.iter().enumerate() {
                            if i > 0 {
                                s.push(',');
                            }
                            let _ = write!(
                                s,
                                "\"{}\":{{\"avg_speedup\":{},\"avg_pct_ideal\":{},\"max_speedup\":{}}}",
                                name,
                                num(*sp),
                                num(*pct),
                                num(*max)
                            );
                        }
                        s.push_str("}}");
                    }
                    s.push('}');
                }
                s.push(']');
                // End-to-end workload axis (schema v4+): graph-engine
                // metrics per spec × family, nested under the topology.
                if !self.plan.e2e.is_empty() {
                    s.push_str(",\"workloads\":[");
                    for (si, spec) in self.plan.e2e.iter().enumerate() {
                        if si > 0 {
                            s.push(',');
                        }
                        let _ = write!(
                            s,
                            "{{\"name\":\"{}\",\"model\":\"{}\",\"layers\":{},\"depth\":{},\
                             \"label\":\"{}\",\"families\":{{",
                            spec.kind.name(),
                            spec.model_tag,
                            spec.layers,
                            spec.depth,
                            escape(&spec.label())
                        );
                        let mut first = true;
                        for out in self.e2e_point(mi, ni, si) {
                            if !first {
                                s.push(',');
                            }
                            first = false;
                            let _ = write!(s, "\"{}\":", out.family.name());
                            if out.source == super::engine::JobSource::Skipped {
                                s.push_str("{\"skipped\":true}");
                                continue;
                            }
                            match &out.result {
                                Ok(r) => {
                                    let _ = write!(
                                        s,
                                        "{{\"total_s\":{},\"serial_s\":{},\"speedup\":{},\
                                         \"exposed_comm_s\":{},\"bubble_s\":{},\
                                         \"hbm_occupancy\":{},\"sdma_occupancy\":{},\
                                         \"graph_nodes\":{}",
                                        num(r.total),
                                        num(r.serial),
                                        num(r.speedup),
                                        num(r.exposed_comm),
                                        num(r.bubble),
                                        num(r.hbm_occupancy),
                                        num(r.sdma_occupancy),
                                        r.graph_nodes
                                    );
                                    // Schema v5: the planner family
                                    // records its winning per-node plan.
                                    if let Some(p) = &out.plan {
                                        let _ = write!(
                                            s,
                                            ",\"plan\":{{\"strategy\":\"{}\",\"candidates\":{},\"nodes\":[",
                                            escape(p.strategy),
                                            p.candidates
                                        );
                                        for (pi, n) in p.nodes.iter().enumerate() {
                                            if pi > 0 {
                                                s.push(',');
                                            }
                                            let _ = write!(
                                                s,
                                                "{{\"label\":\"{}\",\"role\":\"{}\",\"backend\":\"{}\",\
                                                 \"cus\":{},\"chunks\":{}}}",
                                                escape(&n.label),
                                                n.role,
                                                n.backend,
                                                n.cus,
                                                n.chunks
                                            );
                                        }
                                        s.push_str("]}");
                                    }
                                    s.push('}');
                                }
                                Err(e) => {
                                    let _ =
                                        write!(s, "{{\"error\":\"{}\"}}", escape(&e.to_string()));
                                }
                            }
                        }
                        s.push_str("}}");
                    }
                    s.push(']');
                }
                // Serving traffic axis (schema v6): steady-state
                // percentiles per spec × family, nested under the
                // topology alongside the e2e workloads.
                if !self.plan.serve.is_empty() {
                    let t = &self.plan.traffic;
                    s.push_str(",\"serving\":[");
                    for (si, spec) in self.plan.serve.iter().enumerate() {
                        if si > 0 {
                            s.push(',');
                        }
                        let _ = write!(
                            s,
                            "{{\"workload\":\"{}\",\"name\":\"{}\",\"model\":\"{}\",\
                             \"layers\":{},\"max_batch\":{},\"rate\":{},\"steps\":{},\
                             \"tokens_mean\":{},\"families\":{{",
                            escape(&spec.label()),
                            spec.kind.name(),
                            spec.model_tag,
                            spec.layers,
                            spec.max_batch,
                            num(t.rate),
                            t.steps,
                            num(t.tokens_mean)
                        );
                        let mut first = true;
                        for out in self.serve_point(mi, ni, si) {
                            if !first {
                                s.push(',');
                            }
                            first = false;
                            let _ = write!(s, "\"{}\":", out.family.name());
                            if out.source == super::engine::JobSource::Skipped {
                                s.push_str("{\"skipped\":true}");
                                continue;
                            }
                            match &out.result {
                                Ok(r) => {
                                    let _ = write!(
                                        s,
                                        "{{\"p50_s\":{},\"p95_s\":{},\"p99_s\":{},\
                                         \"goodput_tps\":{},\"speedup\":{},\
                                         \"requests_arrived\":{},\"requests_completed\":{},\
                                         \"steps\":{},\"elapsed_s\":{},\"hbm_occupancy\":{},\
                                         \"sdma_occupancy\":{},\"plan\":{}}}",
                                        num(r.p50),
                                        num(r.p95),
                                        num(r.p99),
                                        num(r.goodput_tps),
                                        num(r.speedup),
                                        r.requests_arrived,
                                        r.requests_completed,
                                        r.steps,
                                        num(r.elapsed),
                                        num(r.hbm_occupancy),
                                        num(r.sdma_occupancy),
                                        match r.plan {
                                            Some(p) => format!("\"{}\"", escape(p)),
                                            None => "null".to_string(),
                                        }
                                    );
                                }
                                Err(e) => {
                                    let _ =
                                        write!(s, "{{\"error\":\"{}\"}}", escape(&e.to_string()));
                                }
                            }
                        }
                        s.push_str("}}");
                    }
                    s.push(']');
                }
                s.push('}');
            }
            s.push_str("]}");
        }
        s.push_str("]}");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::execute;
    use super::super::plan::{MachineVariant, SweepPlan};
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::CollectiveKind;
    use crate::coordinator::runner::RunnerConfig;
    use crate::sched::StrategyKind;
    use crate::workload::scenarios::{resolve, TABLE2};

    #[test]
    fn escaping_and_numbers() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{01}"), "\\u0001");
        assert_eq!(num(1.5), "1.5");
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
    }

    #[test]
    fn json_has_expected_structure() {
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Serial, StrategyKind::Conccl],
            RunnerConfig::default(),
        );
        let j = execute(plan, 1).to_json();
        assert!(j.starts_with("{\"version\":7,"));
        assert!(j.contains("\"topologies\":[{\"nodes\":1,\"chunkings\":[{\"chunks\":\"auto\","));
        // No e2e axis -> no workloads section (pairwise shape kept).
        assert!(!j.contains("\"workloads\""));
        assert!(j.contains("\"tag\":\"mb1_896M\""));
        assert!(j.contains("\"conccl\":{\"total_s\":"));
        assert!(j.contains("\"collective\":\"all-gather\""));
        // Unchunked strategies carry a null chunks field.
        assert!(j.contains("\"chunks\":null"));
        // Partial lineup -> no headline object.
        assert!(!j.contains("\"headline\""));
        // Balanced braces (cheap well-formedness check; no strings in
        // this payload contain braces).
        let open = j.matches('{').count();
        let close = j.matches('}').count();
        assert_eq!(open, close, "unbalanced JSON braces");
    }

    #[test]
    fn full_lineup_embeds_headline() {
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![
                resolve(&TABLE2[0], CollectiveKind::AllGather),
                resolve(&TABLE2[11], CollectiveKind::AllToAll),
            ],
            StrategyKind::lineup().to_vec(),
            RunnerConfig::default(),
        );
        let j = execute(plan, 2).to_json();
        assert!(j.contains("\"headline\""));
        assert!(j.contains("\"c3_best\""));
    }

    #[test]
    fn node_axis_appears_per_machine() {
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Serial, StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_node_counts(vec![1, 2])
        .unwrap();
        let j = execute(plan, 1).to_json();
        assert!(j.contains("{\"nodes\":1,"));
        assert!(j.contains("{\"nodes\":2,"));
        let open = j.matches('{').count();
        assert_eq!(open, j.matches('}').count(), "unbalanced JSON braces");
    }

    #[test]
    fn e2e_workloads_nest_per_topology() {
        use crate::workload::e2e::E2eSpec;
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_node_counts(vec![1, 2])
        .unwrap()
        .with_e2e(vec![E2eSpec::parse("fsdp_step:70b:2:2").unwrap()])
        .unwrap();
        let j = execute(plan, 1).to_json();
        assert!(j.starts_with("{\"version\":7,"));
        assert_eq!(j.matches("\"workloads\":[").count(), 2, "one per topology");
        assert!(j.contains("\"name\":\"fsdp_step\",\"model\":\"70b\",\"layers\":2,\"depth\":2"));
        assert!(j.contains("\"label\":\"fsdp_step-70b-l2-d2\""));
        for fam in ["serial", "cu_overlap", "dma_overlap", "auto"] {
            assert!(j.contains(&format!("\"{fam}\":{{\"total_s\":")), "{fam}");
        }
        assert!(j.contains("\"exposed_comm_s\":"));
        assert!(j.contains("\"sdma_occupancy\":"));
        // Schema v5: the auto family records its per-node plan; fixed
        // families do not.
        assert_eq!(j.matches("\"plan\":{\"strategy\":\"").count(), 2, "one plan per topology");
        assert!(j.contains("\"role\":\"gather\""));
        assert!(j.contains("\"role\":\"reduce\""));
        assert!(j.contains("\"backend\":\"cu\""));
        let open = j.matches('{').count();
        assert_eq!(open, j.matches('}').count(), "unbalanced JSON braces");
        // Still parseable by our own reader.
        assert!(crate::sweep::parse_json(&j).is_ok());
    }

    #[test]
    fn serving_nests_per_topology() {
        use crate::workload::serving::ServeSpec;
        use crate::workload::traffic::TrafficConfig;
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_node_counts(vec![1, 2])
        .unwrap()
        .with_serve(
            vec![ServeSpec::parse("pd_disagg:70b:2:8").unwrap()],
            TrafficConfig { steps: 40, ..TrafficConfig::default() },
        )
        .unwrap();
        let j = execute(plan, 1).to_json();
        assert!(j.starts_with("{\"version\":7,"));
        assert_eq!(j.matches("\"serving\":[").count(), 2, "one per topology");
        assert!(j.contains(
            "\"workload\":\"pd_disagg-70b-l2-b8\",\"name\":\"pd_disagg\",\"model\":\"70b\""
        ));
        assert!(j.contains("\"rate\":2000,\"steps\":40,\"tokens_mean\":24"));
        for fam in ["serial", "cu_overlap", "dma_overlap", "auto"] {
            assert!(j.contains(&format!("\"{fam}\":{{\"p50_s\":")), "{fam}");
        }
        assert!(j.contains("\"goodput_tps\":"));
        assert!(j.contains("\"sdma_occupancy\":"));
        // The auto family records its winning per-class plan; fixed
        // families serialize plan:null.
        assert!(j.contains("\"plan\":\"kv-dma"));
        assert!(j.contains("\"plan\":null"));
        let open = j.matches('{').count();
        assert_eq!(open, j.matches('}').count(), "unbalanced JSON braces");
        // Still parseable by our own reader, and byte-identical across
        // thread counts (the serving loop is sequential by design).
        assert!(crate::sweep::parse_json(&j).is_ok());
    }

    #[test]
    fn chunk_axis_appears_per_topology() {
        use super::super::plan::ChunkSel;
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![resolve(&TABLE2[13], CollectiveKind::AllGather)],
            vec![StrategyKind::Conccl, StrategyKind::ConcclChunked],
            RunnerConfig::default(),
        )
        .with_chunk_counts(vec![ChunkSel::Auto, ChunkSel::Fixed(8)])
        .unwrap();
        let j = execute(plan, 1).to_json();
        assert!(j.contains("{\"chunks\":\"auto\","));
        assert!(j.contains("{\"chunks\":8,"));
        // The chunked strategy records its executed chunk count.
        assert!(j.contains("\"conccl_chunked\":{"));
        assert!(j.contains("\"chunks\":8,\"seed\"") || j.contains("\"chunks\":4,\"seed\""));
        let open = j.matches('{').count();
        assert_eq!(open, j.matches('}').count(), "unbalanced JSON braces");
    }
}
