//! Job identity: the one place gate-key strings are built and the one
//! place content-addressed job keys are hashed.
//!
//! Two different "keys" live here on purpose, because they must move
//! together:
//!
//! * **Gate keys** — the human-readable `machine/2n/k=auto/...` strings
//!   that `bench-gate` compares between a baseline and a report. Both
//!   the report *emitter* (`engine::SweepResults::gate_keys`) and the
//!   report *parser* (`baseline::extract_points`) call the builders
//!   below, so a format change cannot silently desynchronize them.
//! * **Job keys** — 128-bit content hashes over a job's *full input
//!   closure* (every `MachineConfig` field incl. `sdma.*`, topology,
//!   workload spec, strategy/family, chunk selection, seeds, and
//!   [`MODEL_VERSION`]). They address the on-disk result cache
//!   ([`super::cache`]) and partition `--shard i/n` runs.
//!
//! Determinism contract: job keys are a pure function of the closure —
//! no pointers, no iteration order, no wall clock — so the same plan
//! hashes to the same keys on every machine and every run.

use crate::util::rng::SplitMix64;

/// Simulator-semantics version salt, mixed into every job key.
///
/// Bump this whenever a change alters *what a job computes* — timeline
/// semantics, seeding, measurement post-processing, auto-chunk policy —
/// even when no input struct changed shape. A stale cache then misses
/// cleanly instead of replaying results from the old model. Purely
/// additive changes (new axes, new output fields that don't affect
/// existing numbers) do not need a bump; cached records carry the salt
/// and are re-verified on read either way.
///
/// `conccl model-version` prints this string so CI can key its cache
/// restore on it.
///
/// v8.0: the incremental fluid core solves max-min rates per
/// resource-connected component instead of over the whole active set.
/// The allocation is the same max-min fixpoint, but the progressive-fill
/// delta sequences differ, so low-order float bits of timelines can move
/// (within the 1e-9 graph-equivalence envelope) — cached results from
/// v7.0 must re-key.
pub const MODEL_VERSION: &str = "conccl-model-v8.0";

// ---------------------------------------------------------------------------
// Gate keys
// ---------------------------------------------------------------------------

/// Gate key for a pair-scenario point:
/// `{machine}/{nodes}n/k={chunk}/{tag}/{collective}/{strategy}`.
pub fn pair_gate_key(
    machine: &str,
    nodes: u64,
    chunk: &str,
    tag: &str,
    collective: &str,
    strategy: &str,
) -> String {
    format!("{machine}/{nodes}n/k={chunk}/{tag}/{collective}/{strategy}")
}

/// Gate key for an e2e workload point:
/// `{machine}/{nodes}n/wl={workload}/{family}`.
pub fn e2e_gate_key(machine: &str, nodes: u64, workload: &str, family: &str) -> String {
    format!("{machine}/{nodes}n/wl={workload}/{family}")
}

/// Gate key for a serving traffic point:
/// `{machine}/{nodes}n/serve={workload}/{family}`.
pub fn serve_gate_key(machine: &str, nodes: u64, workload: &str, family: &str) -> String {
    format!("{machine}/{nodes}n/serve={workload}/{family}")
}

// ---------------------------------------------------------------------------
// Job keys
// ---------------------------------------------------------------------------

/// A 128-bit content-addressed job identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobKey {
    pub hi: u64,
    pub lo: u64,
}

impl JobKey {
    /// 32-hex-digit rendering; the cache's on-disk file-name stem.
    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }

    /// Which of `n` shards owns this job (`lo % n`). The partition is a
    /// pure function of the key, so every shard of a plan agrees on
    /// ownership without coordination.
    pub fn shard_of(&self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.lo % n.max(1) as u64) as usize
    }
}

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Lane-a seed: the standard FNV-1a 64 offset basis.
const FNV_OFFSET_A: u64 = 0xcbf2_9ce4_8422_2325;
/// Lane-b seed: a distinct constant (the SplitMix64 increment) so the
/// two lanes never collapse onto the same stream.
const FNV_OFFSET_B: u64 = 0x9e37_79b9_7f4a_7c15;

/// Incremental hasher for job closures: two FNV-1a 64 lanes over
/// `name = value` fields with explicit separators, finalized through
/// SplitMix64 for avalanche (so `shard_of`'s modulo sees well-mixed
/// low bits).
#[derive(Debug, Clone)]
pub struct KeyHasher {
    a: u64,
    b: u64,
}

impl KeyHasher {
    /// Start a hash for one job kind ("pair" / "e2e" / "serve" /
    /// "dse"). The kind and [`MODEL_VERSION`] are the first two fields,
    /// so job kinds can never collide and a salt bump re-keys
    /// everything.
    pub fn new(kind: &str) -> Self {
        let mut h = KeyHasher {
            a: FNV_OFFSET_A,
            b: FNV_OFFSET_B,
        };
        h.field("model_version", MODEL_VERSION);
        h.field("kind", kind);
        h
    }

    fn bytes(&mut self, bs: &[u8]) {
        for &byte in bs {
            self.a = (self.a ^ u64::from(byte)).wrapping_mul(FNV_PRIME);
            // The second lane rotates between octets so it is not a
            // bijective function of lane a.
            self.b = (self.b ^ u64::from(byte)).wrapping_mul(FNV_PRIME).rotate_left(29);
        }
    }

    /// Hash one named string field. The name participates in the hash
    /// (with unit separators), so reordering or renaming fields changes
    /// the key — exactly the "any closure change re-keys" contract.
    pub fn field(&mut self, name: &str, value: &str) {
        self.bytes(name.as_bytes());
        self.bytes(&[0x1f]); // unit separator between name and value
        self.bytes(value.as_bytes());
        self.bytes(&[0x1e]); // record separator between fields
    }

    /// Hash an integer field (hex-rendered, so width never ambiguates).
    pub fn u64_field(&mut self, name: &str, v: u64) {
        let mut buf = [0u8; 16];
        let mut x = v;
        for slot in buf.iter_mut().rev() {
            *slot = b"0123456789abcdef"[(x & 0xf) as usize];
            x >>= 4;
        }
        self.bytes(name.as_bytes());
        self.bytes(&[0x1f]);
        self.bytes(&buf);
        self.bytes(&[0x1e]);
    }

    /// Hash an `f64` field by its exact bit pattern — `-0.0`, subnormals
    /// and NaN payloads all key distinctly, matching the cache's
    /// bit-exact reconstruction contract.
    pub fn f64_field(&mut self, name: &str, v: f64) {
        self.u64_field(name, v.to_bits());
    }

    /// Finalize into a [`JobKey`]. Each lane is cross-mixed with the
    /// other before a SplitMix64 finalization pass.
    pub fn finish(&self) -> JobKey {
        let hi = SplitMix64::new(self.a ^ self.b.rotate_left(32)).next_u64();
        let lo = SplitMix64::new(self.b ^ self.a.rotate_left(32)).next_u64();
        JobKey { hi, lo }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key_of(kind: &str, fields: &[(&str, &str)]) -> JobKey {
        let mut h = KeyHasher::new(kind);
        for (n, v) in fields {
            h.field(n, v);
        }
        h.finish()
    }

    #[test]
    fn gate_key_formats_are_frozen() {
        assert_eq!(
            pair_gate_key("mi300x-8", 2, "auto", "mb1_896M", "all-gather", "conccl"),
            "mi300x-8/2n/k=auto/mb1_896M/all-gather/conccl"
        );
        assert_eq!(
            e2e_gate_key("mi300x-8", 1, "fsdp_step-70b-l2-d2", "auto"),
            "mi300x-8/1n/wl=fsdp_step-70b-l2-d2/auto"
        );
        assert_eq!(
            serve_gate_key("slowlink", 4, "tp_decode-70b-l2-b8", "serial"),
            "slowlink/4n/serve=tp_decode-70b-l2-b8/serial"
        );
    }

    #[test]
    fn hex_is_32_digits_and_stable() {
        let k = key_of("pair", &[("a", "1")]);
        assert_eq!(k.hex().len(), 32);
        assert_eq!(k, key_of("pair", &[("a", "1")]));
    }

    #[test]
    fn kind_name_value_and_order_all_matter() {
        let base = key_of("pair", &[("a", "1"), ("b", "2")]);
        assert_ne!(base, key_of("e2e", &[("a", "1"), ("b", "2")]), "kind");
        assert_ne!(base, key_of("pair", &[("a", "2"), ("b", "2")]), "value");
        assert_ne!(base, key_of("pair", &[("x", "1"), ("b", "2")]), "name");
        assert_ne!(base, key_of("pair", &[("b", "2"), ("a", "1")]), "order");
        // Field boundaries are separated: ("ab","c") != ("a","bc").
        assert_ne!(key_of("pair", &[("ab", "c")]), key_of("pair", &[("a", "bc")]));
    }

    #[test]
    fn numeric_fields_key_by_bit_pattern() {
        let f = |v: f64| {
            let mut h = KeyHasher::new("t");
            h.f64_field("x", v);
            h.finish()
        };
        assert_ne!(f(0.0), f(-0.0));
        assert_ne!(f(1.0), f(1.0 + f64::EPSILON));
        assert_eq!(f(0.5), f(0.5));
    }

    #[test]
    fn shard_partition_is_total_and_disjoint() {
        // Every key lands in exactly one shard for every n.
        for n in [2usize, 3, 7] {
            let mut counts = vec![0usize; n];
            for i in 0..256 {
                let k = key_of("pair", &[("i", &i.to_string())]);
                counts[k.shard_of(n)] += 1;
            }
            assert_eq!(counts.iter().sum::<usize>(), 256);
            // The finalizer should spread keys across shards, not
            // degenerately pile onto one.
            assert!(counts.iter().all(|&c| c > 0), "empty shard for n={n}: {counts:?}");
        }
    }
}
