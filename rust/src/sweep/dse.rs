//! Design-space exploration over hypothetical DMA-engine subsystems:
//! sweep an [`SdmaModel`] grid {engines × queue depth × packet fusing ×
//! NIC bandwidth}, evaluate real workloads on every hypothetical
//! machine, and report **Pareto frontiers** of speedup vs. an
//! engine-area proxy ([`SdmaModel::area_proxy`]).
//!
//! The paper closes with "a strong case for GPU DMA engine
//! advancements" (§VII-B6); this module turns that argument into a
//! hardware question a designer can actually ask: *which engine
//! configurations buy workload speedup per unit of die area, and which
//! are dominated?* Every grid point is a full [`MachineConfig`] — the
//! per-node planner consumes it like any real machine, so the `auto`
//! rows answer "what hardware makes the planner's choice win?".
//!
//! Determinism: points are evaluated on the worker pool in index order
//! with identity-derived serving seeds, so [`DseResults::to_json`] is
//! byte-identical at any thread count (same contract as the sweep
//! report; schema version 7, top-level `dse` key).
//!
//! ```
//! use conccl::config::machine::MachineConfig;
//! use conccl::config::workload::CollectiveKind;
//! use conccl::sweep::dse::DsePlan;
//! use conccl::workload::scenarios::resolve_tag;
//!
//! let mut plan = DsePlan::new(MachineConfig::mi300x());
//! plan.engines = vec![2, 14];
//! plan.queue_depths = vec![0];
//! plan.pairs = vec![resolve_tag("mb1_896M", CollectiveKind::AllGather).unwrap()];
//! let res = conccl::sweep::dse::run(plan, 1).unwrap();
//! assert_eq!(res.points.len(), 2);
//! assert_eq!(res.points[0].label, "e2-q0-f1");
//! // The frontier is never empty when at least one point evaluated.
//! assert!(!res.frontier(0).is_empty());
//! assert!(res.to_json().starts_with("{\"version\":7,\"dse\":"));
//! ```
//!
//! [`SdmaModel`]: crate::gpu::sdma::SdmaModel
//! [`SdmaModel::area_proxy`]: crate::gpu::sdma::SdmaModel::area_proxy

use crate::config::machine::MachineConfig;
use crate::error::Error;
use crate::sched::{C3Executor, Planner, Strategy};
use crate::util::pool;
use crate::workload::e2e::{run_e2e_planned_with, E2eFamily, E2eSpec};
use crate::workload::scenarios::ResolvedScenario;
use crate::workload::serving::ServeSpec;
use crate::workload::traffic::{run_serve_lineup, TrafficConfig};

use super::engine::default_threads;
use super::json::{escape, num};
use super::plan::job_seed;

/// The exploration grid plus the workloads scoring every point.
#[derive(Debug, Clone)]
pub struct DsePlan {
    /// Machine every grid point derives from (only the swept fields
    /// change; everything else — GEMM rooflines, fabric, CU counts —
    /// stays the real machine's).
    pub base: MachineConfig,
    /// SDMA engine counts to explore.
    pub engines: Vec<usize>,
    /// Per-engine command-queue depths (0 = unbounded).
    pub queue_depths: Vec<usize>,
    /// Fused-command-packet granularities (1 = no fusing).
    pub fused: Vec<usize>,
    /// Absolute NIC line rates to explore, B/s; empty keeps the base
    /// machine's NIC on every point.
    pub nic_bws: Vec<f64>,
    /// Topology node count every point is evaluated on.
    pub nodes: usize,
    /// Pairwise workloads: each scores a point by the ConCCL strategy's
    /// speedup over the serial baseline.
    pub pairs: Vec<ResolvedScenario>,
    /// End-to-end workloads: each scores a point twice, by the
    /// `dma_overlap` and planner-driven `auto` family speedups.
    pub e2e: Vec<E2eSpec>,
    /// Serving workloads: each scores a point twice, by the
    /// `dma_overlap` and `auto` p99 speedups over serial.
    pub serve: Vec<ServeSpec>,
    /// Traffic parameters shared by every serving evaluation.
    pub traffic: TrafficConfig,
    /// Base seed for the serving arrival processes. Arrivals are seeded
    /// per *workload*, not per point, so every hypothetical machine
    /// faces the identical request sequence.
    pub seed: u64,
}

impl DsePlan {
    /// Default grid around the MI300X point: engines {2, 4, 7, 14} ×
    /// queue depth {0, 8} × no fusing, base NIC, single node, no
    /// workloads yet (callers pick at least one).
    pub fn new(base: MachineConfig) -> DsePlan {
        DsePlan {
            base,
            engines: vec![2, 4, 7, 14],
            queue_depths: vec![0, 8],
            fused: vec![1],
            nic_bws: Vec::new(),
            nodes: 1,
            pairs: Vec::new(),
            e2e: Vec::new(),
            serve: Vec::new(),
            traffic: TrafficConfig::default(),
            seed: 24301,
        }
    }

    /// Validate the grid and workload axes (typed errors, never panics).
    pub fn validate(&self) -> Result<(), Error> {
        for (name, axis) in [
            ("engines", &self.engines),
            ("queue_depths", &self.queue_depths),
            ("fused", &self.fused),
        ] {
            if axis.is_empty() {
                return Err(Error::Config(format!("dse {name} axis cannot be empty")));
            }
            for (i, v) in axis.iter().enumerate() {
                if axis[..i].contains(v) {
                    return Err(Error::Config(format!("duplicate dse {name} entry {v}")));
                }
            }
        }
        if self.engines.contains(&0) {
            return Err(Error::Config("dse engines entries must be >= 1".into()));
        }
        if self.fused.contains(&0) {
            return Err(Error::Config("dse fused entries must be >= 1".into()));
        }
        for (i, &bw) in self.nic_bws.iter().enumerate() {
            if !(bw > 0.0) {
                return Err(Error::Config(format!("dse nic_bw entry {bw} must be > 0 B/s")));
            }
            if self.nic_bws[..i].contains(&bw) {
                return Err(Error::Config(format!("duplicate dse nic_bw entry {bw}")));
            }
        }
        if self.nodes == 0 {
            return Err(Error::Config("dse node count must be >= 1".into()));
        }
        if self.pairs.is_empty() && self.e2e.is_empty() && self.serve.is_empty() {
            return Err(Error::Config(
                "dse needs at least one workload (pairs, e2e or serve)".into(),
            ));
        }
        for (axis, labels) in [
            ("pair", self.pairs.iter().map(|s| s.tag()).collect::<Vec<_>>()),
            ("e2e", self.e2e.iter().map(|s| s.label()).collect()),
            ("serve", self.serve.iter().map(|s| s.label()).collect()),
        ] {
            for (i, l) in labels.iter().enumerate() {
                if labels[..i].contains(l) {
                    return Err(Error::Config(format!("duplicate dse {axis} workload '{l}'")));
                }
            }
        }
        if !self.serve.is_empty() {
            self.traffic.validate()?;
        }
        let errs = self.base.validate();
        if !errs.is_empty() {
            return Err(Error::Config(format!("dse base machine invalid: {}", errs.join("; "))));
        }
        Ok(())
    }

    /// Expand the grid into hypothetical machines, in
    /// engines → queue-depth → fusing → NIC order.
    pub fn points(&self) -> Vec<DsePoint> {
        let nics: Vec<Option<f64>> = if self.nic_bws.is_empty() {
            vec![None]
        } else {
            self.nic_bws.iter().copied().map(Some).collect()
        };
        let mut out = Vec::new();
        for &e in &self.engines {
            for &q in &self.queue_depths {
                for &f in &self.fused {
                    for &nic in &nics {
                        let mut label = format!("e{e}-q{q}-f{f}");
                        if let Some(bw) = nic {
                            // Shortest-roundtrip GB/s keeps labels both
                            // readable and collision-free.
                            label.push_str(&format!("-nic{}", bw / 1e9));
                        }
                        let mut m = self.base.clone();
                        m.sdma.engines = e;
                        m.sdma.queue_depth = q;
                        m.sdma.fused_packets = f;
                        if let Some(bw) = nic {
                            m.nic_bw = bw;
                        }
                        m.name = format!("{}+{label}", self.base.name);
                        let area = m.sdma.area_proxy();
                        out.push(DsePoint {
                            label,
                            engines: e,
                            queue_depth: q,
                            fused: f,
                            nic_bw: nic,
                            area,
                            machine: m,
                        });
                    }
                }
            }
        }
        out
    }

    /// The scored workload columns, in pair → e2e → serve order (e2e
    /// and serve each contribute a `dma_overlap` and an `auto` column).
    pub fn workloads(&self) -> Vec<DseWorkload> {
        let mut out = Vec::new();
        for (i, sc) in self.pairs.iter().enumerate() {
            out.push(DseWorkload {
                key: format!("pair:{}:{}/conccl", sc.tag(), sc.comm.spec.kind.name()),
                kind: DseWorkloadKind::Pair(i),
            });
        }
        for (i, spec) in self.e2e.iter().enumerate() {
            for family in [E2eFamily::DmaOverlap, E2eFamily::Auto] {
                out.push(DseWorkload {
                    key: format!("e2e:{}/{}", spec.label(), family.name()),
                    kind: DseWorkloadKind::E2e(i, family),
                });
            }
        }
        for (i, spec) in self.serve.iter().enumerate() {
            for family in [E2eFamily::DmaOverlap, E2eFamily::Auto] {
                out.push(DseWorkload {
                    key: format!("serve:{}/{}", spec.label(), family.name()),
                    kind: DseWorkloadKind::Serve(i, family),
                });
            }
        }
        out
    }
}

/// One hypothetical machine of the grid.
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// Axis label, e.g. `e14-q8-f1` (`-nic50` appended when the NIC
    /// axis is swept).
    pub label: String,
    pub engines: usize,
    pub queue_depth: usize,
    pub fused: usize,
    /// NIC override, B/s (`None` = base machine's NIC).
    pub nic_bw: Option<f64>,
    /// Engine-area proxy of this point's [`crate::gpu::sdma::SdmaModel`].
    pub area: f64,
    pub machine: MachineConfig,
}

/// How one workload column scores a grid point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DseWorkloadKind {
    /// Index into [`DsePlan::pairs`]; ConCCL-strategy speedup.
    Pair(usize),
    /// Index into [`DsePlan::e2e`] plus the scored family.
    E2e(usize, E2eFamily),
    /// Index into [`DsePlan::serve`] plus the scored family.
    Serve(usize, E2eFamily),
}

/// One scored workload column.
#[derive(Debug, Clone)]
pub struct DseWorkload {
    /// Unique report key, e.g. `e2e:fsdp_step-70b-l2-d2/dma_overlap`.
    pub key: String,
    pub kind: DseWorkloadKind,
}

/// One surviving (or candidate) frontier entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DseScore {
    /// Index into [`DseResults::points`].
    pub point_idx: usize,
    pub area: f64,
    pub speedup: f64,
}

/// All outcomes of one exploration.
#[derive(Debug, Clone)]
pub struct DseResults {
    pub plan: DsePlan,
    pub points: Vec<DsePoint>,
    pub workloads: Vec<DseWorkload>,
    /// `outcomes[point_idx][workload_idx]` = that point's speedup on
    /// that workload column (typed error per slot; the sweep continues).
    pub outcomes: Vec<Vec<Result<f64, Error>>>,
    pub threads_used: usize,
}

/// Explore the grid. `threads == 0` means one worker per core;
/// `threads == 1` is the sequential reference path, byte-identical to
/// any parallel run.
pub fn run(plan: DsePlan, threads: usize) -> Result<DseResults, Error> {
    plan.validate()?;
    let points = plan.points();
    let workloads = plan.workloads();
    let req = if threads == 0 { default_threads() } else { threads };
    let n_threads = req.min(points.len()).max(1);
    let outcomes = pool::run_indexed(points.len(), n_threads, |pi| {
        eval_point(&plan, &points[pi], &workloads)
    });
    Ok(DseResults {
        plan,
        points,
        workloads,
        outcomes,
        threads_used: n_threads,
    })
}

/// Score one hypothetical machine on every workload column.
fn eval_point(plan: &DsePlan, point: &DsePoint, workloads: &[DseWorkload]) -> Vec<Result<f64, Error>> {
    let m = &point.machine;
    let topo = m.topology(plan.nodes);
    // One executor / planner — one cost-model profile — per point,
    // shared across its workload columns.
    let exec = (!plan.pairs.is_empty())
        .then(|| C3Executor::with_topology(m.clone(), m.topology(plan.nodes)));
    let planner = (!plan.e2e.is_empty()).then(|| Planner::new(m, &topo));
    // Serving lineups are memoized per spec (each lineup already runs
    // all four families).
    let mut serve_cache: Vec<Option<Result<Vec<crate::workload::traffic::ServeReport>, Error>>> =
        vec![None; plan.serve.len()];
    workloads
        .iter()
        .map(|w| match w.kind {
            DseWorkloadKind::Pair(i) => {
                let exec = exec.as_ref().expect("executor built when pairs are planned");
                let sc = &plan.pairs[i];
                let b = exec.baselines(sc);
                exec.try_run_with_baselines(sc, Strategy::Conccl, b)
                    .map(|r| r.speedup)
            }
            DseWorkloadKind::E2e(i, family) => {
                let planner = planner.as_ref().expect("planner built when e2e is planned");
                let spec = &plan.e2e[i];
                run_e2e_planned_with(planner, &spec.trace(), spec.depth, family)
                    .map(|(r, _)| r.speedup)
            }
            DseWorkloadKind::Serve(i, family) => {
                let spec = plan.serve[i];
                let lineup = serve_cache[i].get_or_insert_with(|| {
                    // Per-workload (NOT per-point) arrival seed: every
                    // hypothetical machine faces identical requests.
                    let seed = job_seed(
                        plan.seed,
                        "dse",
                        &plan.nodes.to_string(),
                        "serve",
                        &spec.label(),
                        "arrivals",
                        "open-loop",
                    );
                    run_serve_lineup(m, &topo, spec, plan.traffic, seed)
                });
                match lineup {
                    Ok(reports) => reports
                        .iter()
                        .find(|r| r.family == family)
                        .map(|r| r.speedup)
                        .ok_or_else(|| {
                            Error::Config(format!("serve lineup lacks family {}", family.name()))
                        }),
                    Err(e) => Err(e.clone()),
                }
            }
        })
        .collect()
}

impl DseResults {
    /// All successfully scored points of one workload column, in point
    /// order.
    pub fn scores(&self, workload_idx: usize) -> Vec<DseScore> {
        self.outcomes
            .iter()
            .enumerate()
            .filter_map(|(pi, per_w)| {
                per_w[workload_idx].as_ref().ok().map(|&speedup| DseScore {
                    point_idx: pi,
                    area: self.points[pi].area,
                    speedup,
                })
            })
            .collect()
    }

    /// Pareto frontier of one workload column: the scored points not
    /// dominated by any other (dominated = some other point has
    /// `area <=` AND `speedup >=`, at least one strictly). Sorted by
    /// ascending area, ties by point order — deterministic.
    pub fn frontier(&self, workload_idx: usize) -> Vec<DseScore> {
        let scores = self.scores(workload_idx);
        let mut front: Vec<DseScore> = scores
            .iter()
            .filter(|p| {
                !scores.iter().any(|q| {
                    q.area <= p.area
                        && q.speedup >= p.speedup
                        && (q.area < p.area || q.speedup > p.speedup)
                })
            })
            .copied()
            .collect();
        front.sort_by(|a, b| a.area.total_cmp(&b.area).then(a.point_idx.cmp(&b.point_idx)));
        front
    }

    /// Per-slot errors, flattened for reporting.
    pub fn errors(&self) -> Vec<(usize, usize, &Error)> {
        let mut out = Vec::new();
        for (pi, per_w) in self.outcomes.iter().enumerate() {
            for (wi, r) in per_w.iter().enumerate() {
                if let Err(e) = r {
                    out.push((pi, wi, e));
                }
            }
        }
        out
    }

    /// Serialize the exploration (schema version 7, top-level `dse`
    /// key). Byte-identical at any thread count: point, workload and
    /// frontier orders are all plan-derived.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::with_capacity(16 * 1024);
        s.push_str("{\"version\":7,\"dse\":{");
        let _ = write!(
            s,
            "\"base\":\"{}\",\"nodes\":{},\"seed\":{},",
            escape(&self.plan.base.name),
            self.plan.nodes,
            self.plan.seed
        );
        let usize_list =
            |xs: &[usize]| xs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",");
        let _ = write!(
            s,
            "\"axes\":{{\"engines\":[{}],\"queue_depths\":[{}],\"fused\":[{}],\"nic_bws\":[{}]}},",
            usize_list(&self.plan.engines),
            usize_list(&self.plan.queue_depths),
            usize_list(&self.plan.fused),
            self.plan
                .nic_bws
                .iter()
                .map(|&v| num(v))
                .collect::<Vec<_>>()
                .join(",")
        );
        s.push_str("\"points\":[");
        for (pi, p) in self.points.iter().enumerate() {
            if pi > 0 {
                s.push(',');
            }
            // The content-addressed identity of this grid point's job
            // closure (machine fields + topology + seed + model salt):
            // external tooling can diff two explorations point-by-point
            // without re-deriving the closure.
            let _ = write!(
                s,
                "{{\"label\":\"{}\",\"key\":\"{}\",\"engines\":{},\"queue_depth\":{},\
                 \"fused\":{},\"nic_bw\":{},\"area\":{}}}",
                escape(&p.label),
                super::cache::dse_point_key(&p.machine, self.plan.nodes, self.plan.seed).hex(),
                p.engines,
                p.queue_depth,
                p.fused,
                p.nic_bw.map_or("null".to_string(), num),
                num(p.area)
            );
        }
        s.push_str("],\"workloads\":[");
        for (wi, w) in self.workloads.iter().enumerate() {
            if wi > 0 {
                s.push(',');
            }
            let _ = write!(s, "{{\"key\":\"{}\",\"results\":[", escape(&w.key));
            for (pi, per_w) in self.outcomes.iter().enumerate() {
                if pi > 0 {
                    s.push(',');
                }
                let _ = write!(s, "{{\"point\":\"{}\",", escape(&self.points[pi].label));
                match &per_w[wi] {
                    Ok(v) => {
                        let _ = write!(s, "\"speedup\":{}}}", num(*v));
                    }
                    Err(e) => {
                        let _ = write!(s, "\"error\":\"{}\"}}", escape(&e.to_string()));
                    }
                }
            }
            s.push_str("],\"frontier\":[");
            for (fi, f) in self.frontier(wi).iter().enumerate() {
                if fi > 0 {
                    s.push(',');
                }
                let _ = write!(
                    s,
                    "{{\"point\":\"{}\",\"area\":{},\"speedup\":{}}}",
                    escape(&self.points[f.point_idx].label),
                    num(f.area),
                    num(f.speedup)
                );
            }
            s.push_str("]}");
        }
        s.push_str("]}}");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::CollectiveKind;
    use crate::workload::scenarios::resolve_tag;

    fn pair_plan() -> DsePlan {
        let mut plan = DsePlan::new(MachineConfig::mi300x());
        plan.engines = vec![2, 14];
        plan.queue_depths = vec![0];
        plan.pairs = vec![resolve_tag("mb1_896M", CollectiveKind::AllGather).unwrap()];
        plan
    }

    #[test]
    fn grid_expands_in_axis_order_with_area() {
        let mut plan = pair_plan();
        plan.queue_depths = vec![0, 8];
        plan.fused = vec![1, 4];
        let pts = plan.points();
        assert_eq!(pts.len(), 8);
        assert_eq!(pts[0].label, "e2-q0-f1");
        assert_eq!(pts[1].label, "e2-q0-f4");
        assert_eq!(pts[7].label, "e14-q8-f4");
        // Area tracks engines and queue depth, never fusing.
        assert_eq!(pts[0].area, 2.0);
        assert_eq!(pts[1].area, 2.0);
        assert_eq!(pts[7].area, 14.0 * 1.5);
        // Every point is a valid machine carrying its own label.
        for p in &pts {
            assert!(p.machine.validate().is_empty(), "{}", p.label);
            assert!(p.machine.name.ends_with(&p.label));
        }
        // The NIC axis appends to labels and overrides the machine.
        plan.fused = vec![1];
        plan.nic_bws = vec![50e9];
        let pts = plan.points();
        assert_eq!(pts[0].label, "e2-q0-f1-nic50");
        assert_eq!(pts[0].machine.nic_bw, 50e9);
    }

    #[test]
    fn invalid_plans_are_typed_errors() {
        let base = MachineConfig::mi300x();
        let mut p = DsePlan::new(base.clone());
        // No workloads at all.
        assert!(matches!(p.validate(), Err(Error::Config(_))));
        p.pairs = vec![resolve_tag("mb1_896M", CollectiveKind::AllGather).unwrap()];
        assert!(p.validate().is_ok());
        // Empty / zero / duplicate axes.
        let mut bad = p.clone();
        bad.engines = vec![];
        assert!(bad.validate().is_err());
        let mut bad = p.clone();
        bad.engines = vec![0];
        assert!(bad.validate().is_err());
        let mut bad = p.clone();
        bad.queue_depths = vec![8, 8];
        assert!(bad.validate().is_err());
        let mut bad = p.clone();
        bad.fused = vec![0];
        assert!(bad.validate().is_err());
        let mut bad = p.clone();
        bad.nic_bws = vec![-1.0];
        assert!(bad.validate().is_err());
        let mut bad = p.clone();
        bad.nodes = 0;
        assert!(bad.validate().is_err());
        // Duplicate workload labels.
        let mut bad = p.clone();
        bad.pairs.push(bad.pairs[0].clone());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn pair_column_scores_and_dominance_prunes() {
        let res = run(pair_plan(), 1).unwrap();
        assert!(res.errors().is_empty());
        assert_eq!(res.workloads.len(), 1);
        assert_eq!(res.workloads[0].key, "pair:mb1_896M:all-gather/conccl");
        let scores = res.scores(0);
        assert_eq!(scores.len(), 2);
        // 2 engines serialize the 7 peer transfers (wire rounds 4x):
        // the full engine pool is strictly faster end-to-end.
        assert!(scores[1].speedup > scores[0].speedup, "{scores:?}");
        // Both survive the frontier: more area buys more speedup.
        assert_eq!(res.frontier(0).len(), 2);
        // A dominated point — same engines, deeper queues (more area),
        // identical speedup — is pruned.
        let mut plan = pair_plan();
        plan.engines = vec![14];
        plan.queue_depths = vec![0, 8];
        let res = run(plan, 1).unwrap();
        let f = res.frontier(0);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(res.points[f[0].point_idx].label, "e14-q0-f1");
    }

    #[test]
    fn json_is_deterministic_and_well_formed() {
        let a = run(pair_plan(), 1).unwrap().to_json();
        let b = run(pair_plan(), 2).unwrap().to_json();
        assert_eq!(a, b, "thread count leaked into dse JSON");
        assert!(a.starts_with("{\"version\":7,\"dse\":{\"base\":\"mi300x-8\""));
        assert!(a.contains("\"axes\":{\"engines\":[2,14]"));
        assert!(a.contains("\"label\":\"e2-q0-f1\""));
        assert!(a.contains("\"frontier\":["));
        assert_eq!(a.matches('{').count(), a.matches('}').count());
    }
}
