//! Content-addressed on-disk result cache for sweep jobs.
//!
//! Every job kind (pair, e2e, serve lineup, dse point) hashes its *full
//! input closure* — every [`MachineConfig`] field including `sdma.*`,
//! the topology node count, the workload spec, strategy/family, chunk
//! selection, seeds, and [`MODEL_VERSION`] — into a 128-bit
//! [`JobKey`]. A completed job is persisted as one small JSON record
//! named `<kind>-<hex key>.json` under `--cache-dir`; a later run of
//! the same closure reads the record back instead of simulating.
//!
//! Contracts:
//!
//! * **Bit-exact**: every `f64` is stored as the hex of `to_bits()`, so
//!   a reconstructed result is indistinguishable from a recomputed one
//!   and warm-cache JSON reports are byte-identical to cold ones.
//! * **Fail-open**: any anomaly — unreadable file, parse error, salt
//!   mismatch, unknown interned name — is a cache *miss*, never an
//!   error. The job is simply re-simulated.
//! * **Success-only**: failed jobs are never cached; errors always
//!   re-run.
//! * **Atomic**: records are written to a temp file and renamed into
//!   place, so an interrupted sweep leaves only complete records — that
//!   is what makes partial sweeps resumable.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::machine::MachineConfig;
use crate::coordinator::runner::{Measured, RunnerConfig};
use crate::sched::{C3Run, PlanNode, PlanSummary, Strategy};
use crate::util::stats::Summary;
use crate::workload::e2e::{E2eFamily, E2eRun};
use crate::workload::traffic::{ServeReport, TrafficConfig};

use super::baseline::{parse_json, Json};
use super::key::{JobKey, KeyHasher, MODEL_VERSION};

// ---------------------------------------------------------------------------
// Closure hashing
// ---------------------------------------------------------------------------

/// Hash every field of a [`MachineConfig`] (incl. each `sdma.*`
/// subfield). Kept exhaustive by hand, mirrored by the perturbation
/// property test, which drives `config::parse::set_machine_field` over
/// the canonical field list and asserts every field changes the key.
pub fn machine_closure(h: &mut KeyHasher, m: &MachineConfig) {
    h.field("machine.name", &m.name);
    h.u64_field("num_gpus", m.num_gpus as u64);
    h.u64_field("xcds", m.xcds as u64);
    h.u64_field("cus_per_xcd", m.cus_per_xcd as u64);
    h.f64_field("peak_flops_bf16", m.peak_flops_bf16);
    h.f64_field("compute_eff", m.compute_eff);
    h.f64_field("hbm_bw", m.hbm_bw);
    h.f64_field("hbm_eff", m.hbm_eff);
    h.f64_field("per_cu_hbm_bw", m.per_cu_hbm_bw);
    h.f64_field("llc_capacity", m.llc_capacity);
    h.f64_field("llc_bw", m.llc_bw);
    h.f64_field("l2_per_xcd", m.l2_per_xcd);
    h.u64_field("sdma.engines", m.sdma.engines as u64);
    h.f64_field("sdma.engine_bw_share", m.sdma.engine_bw_share);
    h.u64_field("sdma.queue_depth", m.sdma.queue_depth as u64);
    h.f64_field("sdma.enqueue_s", m.sdma.enqueue_s);
    h.f64_field("sdma.doorbell_s", m.sdma.doorbell_s);
    h.f64_field("sdma.fetch_s", m.sdma.fetch_s);
    h.f64_field("sdma.sync_s", m.sdma.sync_s);
    h.u64_field("sdma.fused_packets", m.sdma.fused_packets as u64);
    h.u64_field("link_count", m.link_count as u64);
    h.f64_field("link_bw", m.link_bw);
    h.f64_field("link_eff", m.link_eff);
    h.f64_field("link_eff_dma", m.link_eff_dma);
    h.f64_field("nic_bw", m.nic_bw);
    h.f64_field("nic_latency_s", m.nic_latency_s);
    h.f64_field("kernel_launch_s", m.kernel_launch_s);
    h.f64_field("coll_launch_s", m.coll_launch_s);
    h.u64_field("gemm_tile", m.gemm_tile as u64);
    h.f64_field("gemm_traffic_coeff", m.gemm_traffic_coeff);
    h.f64_field("gemm_traffic_exp", m.gemm_traffic_exp);
    h.f64_field("gemm_traffic_cap", m.gemm_traffic_cap);
    h.f64_field("gemm_cache_damp", m.gemm_cache_damp);
    h.u64_field("ag_cu_need", u64::from(m.ag_cu_need));
    h.u64_field("a2a_cu_need", u64::from(m.a2a_cu_need));
    h.u64_field("ar_cu_need", u64::from(m.ar_cu_need));
    h.u64_field("rs_cu_need", u64::from(m.rs_cu_need));
    h.f64_field("a2a_hbm_factor", m.a2a_hbm_factor);
    h.f64_field("ag_hbm_factor", m.ag_hbm_factor);
    h.f64_field("a2a_link_derate", m.a2a_link_derate);
    h.f64_field("comm_co_penalty_ag", m.comm_co_penalty_ag);
    h.f64_field("comm_co_penalty_a2a", m.comm_co_penalty_a2a);
    h.f64_field("gemm_l2_pollution_ag", m.gemm_l2_pollution_ag);
    h.f64_field("gemm_l2_pollution_a2a", m.gemm_l2_pollution_a2a);
    h.f64_field("mem_interference_coeff", m.mem_interference_coeff);
    h.f64_field("mem_interference_cap", m.mem_interference_cap);
    h.u64_field("base_leak_cus", u64::from(m.base_leak_cus));
    h.f64_field("base_dispatch_backlog", m.base_dispatch_backlog);
    h.u64_field("min_cu_granularity", u64::from(m.min_cu_granularity));
    h.f64_field("roofline_eff", m.roofline_eff);
    h.f64_field("chunk_align_frac", m.chunk_align_frac);
    h.u64_field("max_chunks", u64::from(m.max_chunks));
}

/// Identity of one pair-scenario job. The per-job RNG seed is hashed
/// directly (it already folds in the machine label, node count, chunk
/// label, scenario tag, collective and strategy via `plan::job_seed`),
/// so a seed-derivation change re-keys automatically.
#[allow(clippy::too_many_arguments)]
pub fn pair_job_key(
    m: &MachineConfig,
    nodes: usize,
    chunk: &str,
    tag: &str,
    collective: &str,
    strategy: &str,
    cfg: &RunnerConfig,
    seed: u64,
) -> JobKey {
    let mut h = KeyHasher::new("pair");
    machine_closure(&mut h, m);
    h.u64_field("nodes", nodes as u64);
    h.field("chunk", chunk);
    h.field("scenario", tag);
    h.field("collective", collective);
    h.field("strategy", strategy);
    h.u64_field("cfg.warmup", cfg.warmup as u64);
    h.u64_field("cfg.measured", cfg.measured as u64);
    h.f64_field("cfg.jitter", cfg.jitter);
    h.u64_field("cfg.seed", cfg.seed);
    h.u64_field("job.seed", seed);
    h.finish()
}

/// Identity of one e2e workload job. The spec label encodes the full
/// spec closure (`kind-model-l{layers}-d{depth}`); the graph engine is
/// noise-free, so no RNG seed participates.
pub fn e2e_job_key(m: &MachineConfig, nodes: usize, workload: &str, family: &str) -> JobKey {
    let mut h = KeyHasher::new("e2e");
    machine_closure(&mut h, m);
    h.u64_field("nodes", nodes as u64);
    h.field("workload", workload);
    h.field("family", family);
    h.finish()
}

/// Identity of one serving *lineup* (all four families of one spec on
/// one machine/topology — they share the arrival process and the
/// serial denominator, so they cache and shard as a unit).
pub fn serve_job_key(
    m: &MachineConfig,
    nodes: usize,
    workload: &str,
    traffic: &TrafficConfig,
    seed: u64,
) -> JobKey {
    let mut h = KeyHasher::new("serve");
    machine_closure(&mut h, m);
    h.u64_field("nodes", nodes as u64);
    h.field("workload", workload);
    h.f64_field("traffic.rate", traffic.rate);
    h.u64_field("traffic.steps", traffic.steps as u64);
    h.f64_field("traffic.duration", traffic.duration);
    h.f64_field("traffic.tokens_mean", traffic.tokens_mean);
    h.u64_field("arrival.seed", seed);
    h.finish()
}

/// Identity of one dse grid point (the mutated machine carries the
/// point's `sdma.*`/`nic_bw` overrides and its label in `name`).
pub fn dse_point_key(m: &MachineConfig, nodes: usize, seed: u64) -> JobKey {
    let mut h = KeyHasher::new("dse");
    machine_closure(&mut h, m);
    h.u64_field("nodes", nodes as u64);
    h.u64_field("seed", seed);
    h.finish()
}

// ---------------------------------------------------------------------------
// The cache
// ---------------------------------------------------------------------------

/// Read/write handle over one writable cache dir and any number of
/// extra read-only dirs (`--merge`). Lookups scan the write dir first,
/// then the merge dirs in order.
#[derive(Debug, Clone, Default)]
pub struct Cache {
    read_dirs: Vec<PathBuf>,
    write_dir: Option<PathBuf>,
}

/// Distinguishes temp-file names when concurrent processes share a
/// cache dir (threads within one run never collide on a key).
static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

impl Cache {
    /// A disabled cache: every lookup misses, every store is a no-op.
    pub fn disabled() -> Self {
        Cache::default()
    }

    /// Open a cache. The write dir is created; missing read dirs are
    /// tolerated (their lookups miss).
    pub fn open(write_dir: Option<PathBuf>, read_dirs: Vec<PathBuf>) -> Result<Self, String> {
        if let Some(d) = &write_dir {
            fs::create_dir_all(d)
                .map_err(|e| format!("cannot create cache dir {}: {e}", d.display()))?;
        }
        Ok(Cache { read_dirs, write_dir })
    }

    pub fn enabled(&self) -> bool {
        self.write_dir.is_some() || !self.read_dirs.is_empty()
    }

    fn record_name(kind: &str, key: &JobKey) -> String {
        format!("{kind}-{}.json", key.hex())
    }

    /// Load + validate a record: parseable JSON whose salt and key echo
    /// match. Anything else is a miss.
    fn load(&self, kind: &str, key: &JobKey) -> Option<Json> {
        let name = Self::record_name(kind, key);
        let dirs = self.write_dir.iter().chain(self.read_dirs.iter());
        for d in dirs {
            let Ok(text) = fs::read_to_string(d.join(&name)) else {
                continue;
            };
            let Ok(j) = parse_json(&text) else { continue };
            if str_field(&j, "model_version") == Some(MODEL_VERSION)
                && str_field(&j, "key").is_some_and(|k| k == key.hex())
            {
                return Some(j);
            }
        }
        None
    }

    /// Atomically persist a record body (the caller supplies everything
    /// after the shared `model_version`/`kind`/`key` preamble). Write
    /// failures are swallowed: the cache is an accelerator, not a
    /// correctness dependency.
    fn store(&self, kind: &str, key: &JobKey, body: &str) {
        let Some(d) = &self.write_dir else { return };
        let path = d.join(Self::record_name(kind, key));
        if path.exists() {
            return;
        }
        let record = format!(
            "{{\"model_version\":\"{MODEL_VERSION}\",\"kind\":\"{kind}\",\"key\":\"{}\",{body}}}",
            key.hex()
        );
        let tmp = d.join(format!(
            ".{kind}-{}.{}.{}.tmp",
            key.hex(),
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        if fs::write(&tmp, record).is_ok() && fs::rename(&tmp, &path).is_err() {
            let _ = fs::remove_file(&tmp);
        }
    }

    // -- pair ---------------------------------------------------------------

    /// Reconstructed pair-job result (bit-exact vs. the cold run).
    pub fn lookup_pair(&self, key: &JobKey) -> Option<PairHit> {
        let j = self.load("pair", key)?;
        let strategy = strategy_from_parts(
            str_field(&j, "strategy")?,
            u32_field(&j, "strategy_param")?,
        )?;
        let run = j.get("run")?;
        let stats = j.get("stats")?;
        Some(PairHit {
            measured: Measured {
                strategy,
                run: C3Run {
                    strategy,
                    total: bits_field(run, "total")?,
                    gemm_finish: bits_field(run, "gemm_finish")?,
                    comm_finish: bits_field(run, "comm_finish")?,
                    serial: bits_field(run, "serial")?,
                    ideal: bits_field(run, "ideal")?,
                    speedup: bits_field(run, "speedup")?,
                    pct_ideal: bits_field(run, "pct_ideal")?,
                },
                stats: Summary {
                    n: usize_field(stats, "n")?,
                    mean: bits_field(stats, "mean")?,
                    median: bits_field(stats, "median")?,
                    stddev: bits_field(stats, "stddev")?,
                    min: bits_field(stats, "min")?,
                    max: bits_field(stats, "max")?,
                    p5: bits_field(stats, "p5")?,
                    p95: bits_field(stats, "p95")?,
                },
                speedup_median: bits_field(&j, "speedup_median")?,
                pct_ideal_median: bits_field(&j, "pct_ideal_median")?,
            },
            rp_cus: opt_u32_field(&j, "rp_cus"),
            chunks_used: opt_u32_field(&j, "chunks_used"),
        })
    }

    pub fn store_pair(
        &self,
        key: &JobKey,
        m: &Measured,
        rp_cus: Option<u32>,
        chunks_used: Option<u32>,
    ) {
        if self.write_dir.is_none() {
            return;
        }
        let (sname, sparam) = strategy_to_parts(m.strategy);
        let mut b = String::with_capacity(640);
        push_str_f(&mut b, "strategy", sname);
        push_u64_f(&mut b, "strategy_param", u64::from(sparam));
        push_opt_u32_f(&mut b, "rp_cus", rp_cus);
        push_opt_u32_f(&mut b, "chunks_used", chunks_used);
        b.push_str("\"run\":{");
        push_bits_f(&mut b, "total", m.run.total);
        push_bits_f(&mut b, "gemm_finish", m.run.gemm_finish);
        push_bits_f(&mut b, "comm_finish", m.run.comm_finish);
        push_bits_f(&mut b, "serial", m.run.serial);
        push_bits_f(&mut b, "ideal", m.run.ideal);
        push_bits_f(&mut b, "speedup", m.run.speedup);
        push_bits_last(&mut b, "pct_ideal", m.run.pct_ideal);
        b.push_str("},\"stats\":{");
        push_u64_f(&mut b, "n", m.stats.n as u64);
        push_bits_f(&mut b, "mean", m.stats.mean);
        push_bits_f(&mut b, "median", m.stats.median);
        push_bits_f(&mut b, "stddev", m.stats.stddev);
        push_bits_f(&mut b, "min", m.stats.min);
        push_bits_f(&mut b, "max", m.stats.max);
        push_bits_f(&mut b, "p5", m.stats.p5);
        push_bits_last(&mut b, "p95", m.stats.p95);
        b.push_str("},");
        push_bits_f(&mut b, "speedup_median", m.speedup_median);
        push_bits_last(&mut b, "pct_ideal_median", m.pct_ideal_median);
        self.store("pair", key, &b);
    }

    // -- e2e ----------------------------------------------------------------

    /// Reconstructed e2e-job result. `family` is the caller's slot; a
    /// record whose stored family disagrees is a miss (hash collision
    /// paranoia, effectively free to check).
    pub fn lookup_e2e(&self, key: &JobKey, family: E2eFamily) -> Option<E2eHit> {
        let j = self.load("e2e", key)?;
        if str_field(&j, "family")? != family.name() {
            return None;
        }
        let run = j.get("run")?;
        let plan = match j.get("plan")? {
            Json::Null => None,
            p => Some(plan_summary_from(p)?),
        };
        Some(E2eHit {
            run: E2eRun {
                family,
                total: bits_field(run, "total")?,
                serial: bits_field(run, "serial")?,
                speedup: bits_field(run, "speedup")?,
                exposed_comm: bits_field(run, "exposed_comm")?,
                bubble: bits_field(run, "bubble")?,
                hbm_occupancy: bits_field(run, "hbm_occupancy")?,
                sdma_occupancy: bits_field(run, "sdma_occupancy")?,
                graph_nodes: usize_field(run, "graph_nodes")?,
                // A cache replay simulates nothing: zero events is the
                // truthful counter block (counters never enter the JSON
                // report, so replay stays byte-invisible).
                counters: crate::sim::SimCounters::default(),
            },
            plan,
        })
    }

    pub fn store_e2e(&self, key: &JobKey, run: &E2eRun, plan: Option<&PlanSummary>) {
        if self.write_dir.is_none() {
            return;
        }
        let mut b = String::with_capacity(512);
        push_str_f(&mut b, "family", run.family.name());
        b.push_str("\"run\":{");
        push_bits_f(&mut b, "total", run.total);
        push_bits_f(&mut b, "serial", run.serial);
        push_bits_f(&mut b, "speedup", run.speedup);
        push_bits_f(&mut b, "exposed_comm", run.exposed_comm);
        push_bits_f(&mut b, "bubble", run.bubble);
        push_bits_f(&mut b, "hbm_occupancy", run.hbm_occupancy);
        push_bits_f(&mut b, "sdma_occupancy", run.sdma_occupancy);
        push_u64_last(&mut b, "graph_nodes", run.graph_nodes as u64);
        b.push_str("},\"plan\":");
        match plan {
            None => b.push_str("null"),
            Some(p) => {
                b.push('{');
                push_str_f(&mut b, "strategy", p.strategy);
                push_u64_f(&mut b, "candidates", p.candidates as u64);
                b.push_str("\"nodes\":[");
                for (i, n) in p.nodes.iter().enumerate() {
                    if i > 0 {
                        b.push(',');
                    }
                    b.push('{');
                    push_str_f(&mut b, "label", &super::json::escape(&n.label));
                    push_str_f(&mut b, "role", n.role);
                    push_str_f(&mut b, "backend", n.backend);
                    push_u64_f(&mut b, "cus", u64::from(n.cus));
                    push_u64_last(&mut b, "chunks", u64::from(n.chunks));
                    b.push('}');
                }
                b.push_str("]}");
            }
        }
        self.store("e2e", key, &b);
    }

    // -- serve --------------------------------------------------------------

    /// Reconstructed serving lineup (one report per family, in stored
    /// order).
    pub fn lookup_serve(&self, key: &JobKey) -> Option<Vec<ServeReport>> {
        let j = self.load("serve", key)?;
        let Json::Arr(fams) = j.get("families")? else {
            return None;
        };
        let mut out = Vec::with_capacity(fams.len());
        for f in fams {
            let plan = match f.get("plan")? {
                Json::Null => None,
                Json::Str(s) => Some(intern_plan(s)?),
                _ => return None,
            };
            out.push(ServeReport {
                family: family_from_name(str_field(f, "family")?)?,
                requests_arrived: usize_field(f, "requests_arrived")?,
                requests_completed: usize_field(f, "requests_completed")?,
                steps: usize_field(f, "steps")?,
                elapsed: bits_field(f, "elapsed")?,
                p50: bits_field(f, "p50")?,
                p95: bits_field(f, "p95")?,
                p99: bits_field(f, "p99")?,
                goodput_tps: bits_field(f, "goodput_tps")?,
                speedup: bits_field(f, "speedup")?,
                hbm_occupancy: bits_field(f, "hbm_occupancy")?,
                sdma_occupancy: bits_field(f, "sdma_occupancy")?,
                plan,
                // A cache replay simulates nothing: zero events is the
                // truthful counter block (counters never enter the JSON
                // record, so replay stays byte-invisible).
                counters: crate::sim::SimCounters::default(),
            });
        }
        Some(out)
    }

    pub fn store_serve(&self, key: &JobKey, reports: &[ServeReport]) {
        if self.write_dir.is_none() {
            return;
        }
        let mut b = String::with_capacity(256 * reports.len());
        b.push_str("\"families\":[");
        for (i, r) in reports.iter().enumerate() {
            if i > 0 {
                b.push(',');
            }
            b.push('{');
            push_str_f(&mut b, "family", r.family.name());
            push_u64_f(&mut b, "requests_arrived", r.requests_arrived as u64);
            push_u64_f(&mut b, "requests_completed", r.requests_completed as u64);
            push_u64_f(&mut b, "steps", r.steps as u64);
            push_bits_f(&mut b, "elapsed", r.elapsed);
            push_bits_f(&mut b, "p50", r.p50);
            push_bits_f(&mut b, "p95", r.p95);
            push_bits_f(&mut b, "p99", r.p99);
            push_bits_f(&mut b, "goodput_tps", r.goodput_tps);
            push_bits_f(&mut b, "speedup", r.speedup);
            push_bits_f(&mut b, "hbm_occupancy", r.hbm_occupancy);
            push_bits_f(&mut b, "sdma_occupancy", r.sdma_occupancy);
            match r.plan {
                None => b.push_str("\"plan\":null"),
                Some(p) => {
                    b.push_str("\"plan\":\"");
                    b.push_str(p);
                    b.push('"');
                }
            }
            b.push('}');
        }
        b.push(']');
        self.store("serve", key, &b);
    }
}

/// A cache hit for one pair job.
#[derive(Debug, Clone)]
pub struct PairHit {
    pub measured: Measured,
    pub rp_cus: Option<u32>,
    pub chunks_used: Option<u32>,
}

/// A cache hit for one e2e job.
#[derive(Debug, Clone)]
pub struct E2eHit {
    pub run: E2eRun,
    pub plan: Option<PlanSummary>,
}

// ---------------------------------------------------------------------------
// Serialization helpers
// ---------------------------------------------------------------------------

fn push_str_f(b: &mut String, name: &str, v: &str) {
    b.push('"');
    b.push_str(name);
    b.push_str("\":\"");
    b.push_str(v);
    b.push_str("\",");
}

fn push_u64_f(b: &mut String, name: &str, v: u64) {
    b.push('"');
    b.push_str(name);
    b.push_str("\":");
    b.push_str(&v.to_string());
    b.push(',');
}

fn push_u64_last(b: &mut String, name: &str, v: u64) {
    push_u64_f(b, name, v);
    b.pop();
}

fn push_opt_u32_f(b: &mut String, name: &str, v: Option<u32>) {
    match v {
        Some(x) => push_u64_f(b, name, u64::from(x)),
        None => {
            b.push('"');
            b.push_str(name);
            b.push_str("\":null,");
        }
    }
}

/// `f64` as the 16-hex-digit bit pattern — lossless round-trip.
fn push_bits_f(b: &mut String, name: &str, v: f64) {
    b.push('"');
    b.push_str(name);
    b.push_str("\":\"");
    let bits = v.to_bits();
    for shift in (0..16).rev() {
        b.push(b"0123456789abcdef"[((bits >> (shift * 4)) & 0xf) as usize] as char);
    }
    b.push_str("\",");
}

fn push_bits_last(b: &mut String, name: &str, v: f64) {
    push_bits_f(b, name, v);
    b.pop();
}

fn str_field<'a>(j: &'a Json, name: &str) -> Option<&'a str> {
    match j.get(name)? {
        Json::Str(s) => Some(s.as_str()),
        _ => None,
    }
}

fn u64_num(j: &Json) -> Option<u64> {
    match j {
        // Counters are small integers; anything that lost integrality
        // in transit is a corrupt record → miss.
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => Some(*n as u64),
        _ => None,
    }
}

fn u32_field(j: &Json, name: &str) -> Option<u32> {
    u32::try_from(u64_num(j.get(name)?)).ok()
}

fn usize_field(j: &Json, name: &str) -> Option<usize> {
    usize::try_from(u64_num(j.get(name)?)).ok()
}

fn opt_u32_field(j: &Json, name: &str) -> Option<u32> {
    match j.get(name) {
        Some(Json::Null) | None => None,
        Some(v) => u64_num(v).and_then(|x| u32::try_from(x).ok()),
    }
}

fn bits_field(j: &Json, name: &str) -> Option<f64> {
    let s = str_field(j, name)?;
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

// ---------------------------------------------------------------------------
// `&'static str` interning
// ---------------------------------------------------------------------------
//
// `PlanSummary.strategy`, `PlanNode.role/backend` and `ServeReport.plan`
// are `&'static str` in the simulator; reconstruction maps the stored
// string back onto the canonical static. An unknown name (e.g. a
// candidate added after the record was written) is a miss — the job
// re-simulates, which is always safe.

const PLAN_NAMES: &[&str] = &[
    "cu-rp",
    "cu-uniform",
    "dma-chunked",
    "dma-hybrid",
    "dma-trim",
    "dma-uniform",
    "kv-dma",
    "kv-dma-chunked",
    "split-even",
    "split-odd",
    "split-thirds",
];
const ROLE_NAMES: &[&str] = &["gather", "gemm", "reduce"];
const BACKEND_NAMES: &[&str] = &["cu", "dma"];

fn intern(pool: &'static [&'static str], s: &str) -> Option<&'static str> {
    pool.iter().find(|p| **p == s).copied()
}

fn intern_plan(s: &str) -> Option<&'static str> {
    intern(PLAN_NAMES, s)
}

fn family_from_name(s: &str) -> Option<E2eFamily> {
    E2eFamily::lineup().into_iter().find(|f| f.name() == s)
}

fn plan_summary_from(j: &Json) -> Option<PlanSummary> {
    let Json::Arr(nodes) = j.get("nodes")? else {
        return None;
    };
    let mut out = Vec::with_capacity(nodes.len());
    for n in nodes {
        out.push(PlanNode {
            label: unescape(str_field(n, "label")?),
            role: intern(ROLE_NAMES, str_field(n, "role")?)?,
            backend: intern(BACKEND_NAMES, str_field(n, "backend")?)?,
            cus: u32_field(n, "cus")?,
            chunks: u32_field(n, "chunks")?,
        });
    }
    Some(PlanSummary {
        strategy: intern(PLAN_NAMES, str_field(j, "strategy")?)?,
        candidates: usize_field(j, "candidates")?,
        nodes: out,
    })
}

/// Node labels pass through `json::escape` on store; the baseline
/// parser already decodes JSON escapes, so the parsed string is the
/// original — this is the identity, kept as a named seam.
fn unescape(s: &str) -> String {
    s.to_string()
}

// ---------------------------------------------------------------------------
// Strategy (de)serialization
// ---------------------------------------------------------------------------

/// A `Strategy` flattens to (name, one u32 payload).
pub fn strategy_to_parts(s: Strategy) -> (&'static str, u32) {
    let param = match s {
        Strategy::C3Rp { comm_cus } | Strategy::C3SpRp { comm_cus } => comm_cus,
        Strategy::ConcclRp { cus_removed } => cus_removed,
        Strategy::C3Chunked { chunks } | Strategy::ConcclChunked { chunks } => chunks,
        _ => 0,
    };
    (s.name(), param)
}

pub fn strategy_from_parts(name: &str, param: u32) -> Option<Strategy> {
    Some(match name {
        "serial" => Strategy::Serial,
        "c3_base" => Strategy::C3Base,
        "c3_sp" => Strategy::C3Sp,
        "c3_rp" => Strategy::C3Rp { comm_cus: param },
        "c3_sp_rp" => Strategy::C3SpRp { comm_cus: param },
        "conccl" => Strategy::Conccl,
        "conccl_rp" => Strategy::ConcclRp { cus_removed: param },
        "c3_chunked" => Strategy::C3Chunked { chunks: param },
        "conccl_chunked" => Strategy::ConcclChunked { chunks: param },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("conccl-cache-test-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_measured() -> Measured {
        let strategy = Strategy::ConcclRp { cus_removed: 8 };
        Measured {
            strategy,
            run: C3Run {
                strategy,
                total: 1.25e-3,
                gemm_finish: 1.0e-3,
                comm_finish: 1.2e-3,
                serial: 2.0e-3,
                ideal: 1.9,
                speedup: 1.6,
                pct_ideal: 84.2105263157893,
            },
            stats: Summary {
                n: 9,
                mean: 1.26e-3,
                median: 1.25e-3,
                stddev: 1.0e-6,
                min: 1.24e-3,
                max: 1.29e-3,
                p5: 1.243e-3,
                p95: 1.288e-3,
            },
            speedup_median: 1.6000000000000003,
            pct_ideal_median: 84.21052631578948,
        }
    }

    #[test]
    fn pair_record_round_trips_bit_exactly() {
        let dir = tmpdir("pair");
        let cache = Cache::open(Some(dir.clone()), Vec::new()).unwrap();
        let key = JobKey { hi: 7, lo: 11 };
        let m = sample_measured();
        cache.store_pair(&key, &m, Some(24), None);
        let hit = cache.lookup_pair(&key).expect("hit");
        assert_eq!(hit.rp_cus, Some(24));
        assert_eq!(hit.chunks_used, None);
        assert_eq!(hit.measured.strategy, m.strategy);
        assert_eq!(hit.measured.run.total.to_bits(), m.run.total.to_bits());
        assert_eq!(
            hit.measured.speedup_median.to_bits(),
            m.speedup_median.to_bits()
        );
        assert_eq!(
            hit.measured.pct_ideal_median.to_bits(),
            m.pct_ideal_median.to_bits()
        );
        assert_eq!(hit.measured.stats.n, 9);
        assert_eq!(hit.measured.stats.p95.to_bits(), m.stats.p95.to_bits());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_key_or_salt_misses() {
        let dir = tmpdir("salt");
        let cache = Cache::open(Some(dir.clone()), Vec::new()).unwrap();
        let key = JobKey { hi: 1, lo: 2 };
        cache.store_pair(&key, &sample_measured(), None, None);
        // Unwritten key → miss.
        assert!(cache.lookup_pair(&JobKey { hi: 1, lo: 3 }).is_none());
        // Tamper with the salt → miss, not an error.
        let path = dir.join(Cache::record_name("pair", &key));
        let doctored =
            fs::read_to_string(&path).unwrap().replace(MODEL_VERSION, "conccl-model-v0.0");
        fs::write(&path, doctored).unwrap();
        assert!(cache.lookup_pair(&key).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_record_is_a_miss_not_an_error() {
        let dir = tmpdir("corrupt");
        let cache = Cache::open(Some(dir.clone()), Vec::new()).unwrap();
        let key = JobKey { hi: 3, lo: 4 };
        fs::write(dir.join(Cache::record_name("pair", &key)), "{\"trunc").unwrap();
        assert!(cache.lookup_pair(&key).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_only_merge_dir_serves_hits() {
        let shard = tmpdir("shard");
        let writer = Cache::open(Some(shard.clone()), Vec::new()).unwrap();
        let key = JobKey { hi: 5, lo: 6 };
        writer.store_pair(&key, &sample_measured(), None, Some(4));
        // A merge run opens the shard dir read-only.
        let merged = Cache::open(None, vec![shard.clone()]).unwrap();
        assert_eq!(merged.lookup_pair(&key).unwrap().chunks_used, Some(4));
        // ...and never writes into it.
        merged.store_pair(&JobKey { hi: 9, lo: 9 }, &sample_measured(), None, None);
        assert!(merged.lookup_pair(&JobKey { hi: 9, lo: 9 }).is_none());
        let _ = fs::remove_dir_all(&shard);
    }

    #[test]
    fn strategy_parts_round_trip_every_variant() {
        for s in [
            Strategy::Serial,
            Strategy::C3Base,
            Strategy::C3Sp,
            Strategy::C3Rp { comm_cus: 24 },
            Strategy::C3SpRp { comm_cus: 16 },
            Strategy::Conccl,
            Strategy::ConcclRp { cus_removed: 8 },
            Strategy::C3Chunked { chunks: 6 },
            Strategy::ConcclChunked { chunks: 12 },
        ] {
            let (name, param) = strategy_to_parts(s);
            assert_eq!(strategy_from_parts(name, param), Some(s));
        }
        assert_eq!(strategy_from_parts("warp", 0), None);
    }

    #[test]
    fn disabled_cache_is_inert() {
        let cache = Cache::disabled();
        assert!(!cache.enabled());
        let key = JobKey { hi: 1, lo: 1 };
        cache.store_pair(&key, &sample_measured(), None, None);
        assert!(cache.lookup_pair(&key).is_none());
    }
}
