//! Job-matrix planning: expand {scenarios × strategies × machine
//! configs} into independent, deterministic simulation jobs.
//!
//! Every job carries its own RNG seed derived from the base seed and the
//! job's *identity* (machine label, scenario tag, collective, strategy)
//! — not from its position in an execution order — so results are
//! bit-identical whether the jobs run on one thread or sixteen.

use crate::config::machine::MachineConfig;
use crate::config::parse::set_machine_field;
use crate::config::workload::CollectiveKind;
use crate::coordinator::runner::RunnerConfig;
use crate::error::Error;
use crate::sched::StrategyKind;
use crate::util::rng::SplitMix64;
use crate::workload::e2e::E2eSpec;
use crate::workload::scenarios::{self, ResolvedScenario, TABLE2};
use crate::workload::serving::ServeSpec;
use crate::workload::traffic::TrafficConfig;

/// One machine configuration under evaluation, with a report label.
#[derive(Debug, Clone)]
pub struct MachineVariant {
    pub label: String,
    pub machine: MachineConfig,
}

impl MachineVariant {
    /// The base machine, labelled by its own name.
    pub fn base(machine: MachineConfig) -> MachineVariant {
        MachineVariant {
            label: machine.name.clone(),
            machine,
        }
    }
}

/// Parse a machine-variant spec string into variants derived from
/// `base`. Grammar (one option value, so the hand-rolled CLI can carry
/// it): comma-separated variants, each `label:key=value;key=value`,
/// keys with or without the `machine.` prefix:
///
/// ```text
/// hbm90:hbm_eff=0.9,slowlink:link_eff=0.6;link_eff_dma=0.6
/// ```
pub fn parse_variants(base: &MachineConfig, spec: &str) -> Result<Vec<MachineVariant>, Error> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (label, overrides) = part
            .split_once(':')
            .ok_or_else(|| Error::Config(format!("variant '{part}': expected label:key=value[;...]")))?;
        let label = label.trim();
        if label.is_empty() {
            return Err(Error::Config(format!("variant '{part}': empty label")));
        }
        // Labels key per-job RNG seeds and the JSON report's machines[]
        // entries — duplicates (incl. the base machine's own label) would
        // alias distinct configs.
        if label == base.name || out.iter().any(|v: &MachineVariant| v.label == label) {
            return Err(Error::Config(format!("duplicate machine-variant label '{label}'")));
        }
        let mut m = base.clone();
        for ov in overrides.split(';').map(str::trim).filter(|o| !o.is_empty()) {
            let (k, v) = ov
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("variant '{label}': override '{ov}' is not key=value")))?;
            set_machine_field(&mut m, k.trim(), v.trim())
                .map_err(|e| Error::Config(format!("variant '{label}': {e}")))?;
        }
        let errs = m.validate();
        if !errs.is_empty() {
            return Err(Error::Config(format!(
                "variant '{label}' is invalid: {}",
                errs.join("; ")
            )));
        }
        m.name = format!("{}+{label}", base.name);
        out.push(MachineVariant {
            label: label.to_string(),
            machine: m,
        });
    }
    Ok(out)
}

/// One entry of the sweep's chunk-count axis: a fixed chunk count for
/// the chunked pipeline strategies, or `Auto` — sweep the machine's
/// candidates per scenario and keep the best (the §V-B rp protocol
/// applied to granularity). Non-chunked strategies ignore the axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkSel {
    Auto,
    Fixed(u32),
}

impl ChunkSel {
    /// Axis label used in job seeds, JSON and gate keys.
    pub fn label(self) -> String {
        match self {
            ChunkSel::Auto => "auto".to_string(),
            ChunkSel::Fixed(k) => k.to_string(),
        }
    }

    /// Parse one `--chunks` axis entry (`auto` or a positive integer).
    pub fn parse(s: &str) -> Result<ChunkSel, Error> {
        match s {
            "auto" => Ok(ChunkSel::Auto),
            other => other
                .parse::<u32>()
                .ok()
                .filter(|&k| k >= 1)
                .map(ChunkSel::Fixed)
                .ok_or_else(|| {
                    Error::Config(format!(
                        "chunk axis entry '{other}': expected 'auto' or a positive integer"
                    ))
                }),
        }
    }
}

/// One independent simulation job: a point in the sweep matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SweepJob {
    /// Dense id; doubles as the deterministic output ordering.
    pub id: usize,
    /// Index into [`SweepPlan::machines`].
    pub machine_idx: usize,
    /// Index into [`SweepPlan::node_counts`].
    pub node_idx: usize,
    /// Index into [`SweepPlan::chunk_counts`].
    pub chunk_idx: usize,
    /// Index into [`SweepPlan::scenarios`].
    pub scenario_idx: usize,
    pub strategy: StrategyKind,
    /// Per-job RNG seed (identity-derived; execution-order independent).
    pub seed: u64,
}

/// The expanded sweep: every axis plus the measurement protocol.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub machines: Vec<MachineVariant>,
    /// Node-count axis: every matrix point is evaluated on a topology
    /// of this many nodes (1 = the paper's single fully-connected node;
    /// >1 = the hierarchical leader/NIC topology built from each
    /// machine's `nic_bw`/`nic_latency_s`).
    pub node_counts: Vec<usize>,
    /// Chunk-count axis for the chunked pipeline strategies (default
    /// one `Auto` entry: the per-scenario swept-best chunk count).
    pub chunk_counts: Vec<ChunkSel>,
    /// End-to-end workload axis: every entry is evaluated per
    /// (machine, node-count) under the three e2e families
    /// (serial / cu_overlap / dma_overlap) on the workload-graph
    /// engine, alongside — not multiplying — the pairwise matrix.
    /// Empty by default (pairwise sweeps only).
    pub e2e: Vec<E2eSpec>,
    /// Serving axis: every entry is evaluated per (machine, node-count)
    /// by the traffic engine ([`crate::workload::traffic`]) under the
    /// four serving families, alongside — not multiplying — the
    /// pairwise matrix. Empty by default.
    pub serve: Vec<ServeSpec>,
    /// Traffic parameters shared by every serving point.
    pub traffic: TrafficConfig,
    pub scenarios: Vec<ResolvedScenario>,
    pub strategies: Vec<StrategyKind>,
    pub cfg: RunnerConfig,
}

impl SweepPlan {
    /// Plan over explicit axes (single-node topology, auto chunking).
    pub fn new(
        machines: Vec<MachineVariant>,
        scenarios: Vec<ResolvedScenario>,
        strategies: Vec<StrategyKind>,
        cfg: RunnerConfig,
    ) -> SweepPlan {
        SweepPlan {
            machines,
            node_counts: vec![1],
            chunk_counts: vec![ChunkSel::Auto],
            e2e: Vec::new(),
            serve: Vec::new(),
            traffic: TrafficConfig::default(),
            scenarios,
            strategies,
            cfg,
        }
    }

    /// Replace the serving axis and its traffic parameters. Rejects
    /// duplicate specs (duplicate labels would alias JSON entries and
    /// gate keys) and invalid traffic configs.
    pub fn with_serve(
        mut self,
        specs: Vec<ServeSpec>,
        traffic: TrafficConfig,
    ) -> Result<SweepPlan, Error> {
        traffic.validate()?;
        for (i, s) in specs.iter().enumerate() {
            if specs[..i].iter().any(|p| p.label() == s.label()) {
                return Err(Error::Config(format!(
                    "duplicate serve workload '{}'",
                    s.label()
                )));
            }
        }
        self.serve = specs;
        self.traffic = traffic;
        Ok(self)
    }

    /// Replace the end-to-end workload axis. Rejects duplicate specs
    /// (duplicate labels would alias JSON entries and gate keys).
    pub fn with_e2e(mut self, specs: Vec<E2eSpec>) -> Result<SweepPlan, Error> {
        for (i, s) in specs.iter().enumerate() {
            if specs[..i].iter().any(|p| p.label() == s.label()) {
                return Err(Error::Config(format!(
                    "duplicate e2e workload '{}'",
                    s.label()
                )));
            }
        }
        self.e2e = specs;
        Ok(self)
    }

    /// Replace the node-count axis. Rejects empty lists, zero counts
    /// and duplicates (duplicate axis entries would alias job ids and
    /// RNG seeds).
    pub fn with_node_counts(mut self, node_counts: Vec<usize>) -> Result<SweepPlan, Error> {
        if node_counts.is_empty() {
            return Err(Error::Config("node-count axis cannot be empty".into()));
        }
        for (i, &n) in node_counts.iter().enumerate() {
            if n == 0 {
                return Err(Error::Config("node count must be >= 1".into()));
            }
            if node_counts[..i].contains(&n) {
                return Err(Error::Config(format!("duplicate node count {n}")));
            }
        }
        self.node_counts = node_counts;
        Ok(self)
    }

    /// Replace the chunk-count axis. Rejects empty lists and duplicates
    /// (duplicate axis entries would alias job ids and RNG seeds);
    /// `ChunkSel::parse` already rejects zero counts.
    ///
    /// Like the node-count axis, the chunk axis multiplies the *whole*
    /// matrix: non-chunked strategies are re-measured once per entry
    /// (with per-entry seeds, so under jitter their medians differ
    /// slightly across entries). That keeps job ids dense and every
    /// chunking's table self-contained; restrict `--strategies` to the
    /// chunked columns when sweeping many fixed chunk counts.
    pub fn with_chunk_counts(mut self, chunk_counts: Vec<ChunkSel>) -> Result<SweepPlan, Error> {
        if chunk_counts.is_empty() {
            return Err(Error::Config("chunk axis cannot be empty".into()));
        }
        for (i, &c) in chunk_counts.iter().enumerate() {
            if c == ChunkSel::Fixed(0) {
                return Err(Error::Config("chunk count must be >= 1".into()));
            }
            if chunk_counts[..i].contains(&c) {
                return Err(Error::Config(format!("duplicate chunk axis entry {}", c.label())));
            }
        }
        self.chunk_counts = chunk_counts;
        Ok(self)
    }

    /// The paper's full matrix on one machine: all Table II rows × the
    /// studied collectives × the whole strategy lineup.
    pub fn table2(machine: MachineConfig, cfg: RunnerConfig) -> SweepPlan {
        SweepPlan::new(
            vec![MachineVariant::base(machine)],
            scenarios::suite(),
            StrategyKind::lineup().to_vec(),
            cfg,
        )
    }

    /// Plan from CLI-style selections. `scenario_tags`/`strategy_names`
    /// empty means "all"; unknown names surface typed errors, never
    /// panics.
    pub fn from_selection(
        machines: Vec<MachineVariant>,
        scenario_tags: &[&str],
        kinds: &[CollectiveKind],
        strategy_names: &[&str],
        cfg: RunnerConfig,
    ) -> Result<SweepPlan, Error> {
        if machines.is_empty() {
            return Err(Error::Config("sweep needs at least one machine".into()));
        }
        if kinds.is_empty() {
            return Err(Error::Config("sweep needs at least one collective kind".into()));
        }
        // Duplicate selections would create identical-identity jobs
        // (identical seeds) and duplicate JSON keys — reject them on
        // every axis, matching parse_variants' duplicate-label check.
        reject_duplicates("scenario", scenario_tags)?;
        reject_duplicates(
            "strategy",
            &strategy_names
                .iter()
                .map(|s| StrategyKind::parse(s).map(|k| k.name()))
                .collect::<Result<Vec<_>, _>>()?,
        )?;
        reject_duplicates(
            "collective",
            &kinds.iter().map(|k| k.name()).collect::<Vec<_>>(),
        )?;
        let rows: Vec<&'static crate::workload::Table2Row> = if scenario_tags.is_empty() {
            TABLE2.iter().collect()
        } else {
            scenario_tags
                .iter()
                .map(|t| scenarios::find(t))
                .collect::<Result<Vec<_>, _>>()?
        };
        let mut resolved = Vec::with_capacity(rows.len() * kinds.len());
        for &kind in kinds {
            for row in &rows {
                resolved.push(scenarios::try_resolve(row, kind)?);
            }
        }
        let strategies = if strategy_names.is_empty() {
            StrategyKind::lineup().to_vec()
        } else {
            strategy_names
                .iter()
                .map(|s| StrategyKind::parse(s))
                .collect::<Result<Vec<_>, _>>()?
        };
        Ok(SweepPlan::new(machines, resolved, strategies, cfg))
    }

    /// Number of jobs this plan expands to.
    pub fn job_count(&self) -> usize {
        self.machines.len()
            * self.node_counts.len()
            * self.chunk_counts.len()
            * self.scenarios.len()
            * self.strategies.len()
    }

    /// Dense job id of one matrix point.
    pub fn job_id(
        &self,
        machine_idx: usize,
        node_idx: usize,
        chunk_idx: usize,
        scenario_idx: usize,
        strategy_idx: usize,
    ) -> usize {
        (((machine_idx * self.node_counts.len() + node_idx) * self.chunk_counts.len()
            + chunk_idx)
            * self.scenarios.len()
            + scenario_idx)
            * self.strategies.len()
            + strategy_idx
    }

    /// Expand the matrix into jobs, ids dense in
    /// machine → node-count → chunking → scenario → strategy order.
    pub fn jobs(&self) -> Vec<SweepJob> {
        let mut out = Vec::with_capacity(self.job_count());
        for (mi, mv) in self.machines.iter().enumerate() {
            for (ni, &nodes) in self.node_counts.iter().enumerate() {
                let nodes_label = format!("{nodes}node");
                for (ci, &chunks) in self.chunk_counts.iter().enumerate() {
                    let chunks_label = format!("{}chunk", chunks.label());
                    for (si, sc) in self.scenarios.iter().enumerate() {
                        for (ki, &strategy) in self.strategies.iter().enumerate() {
                            out.push(SweepJob {
                                id: self.job_id(mi, ni, ci, si, ki),
                                machine_idx: mi,
                                node_idx: ni,
                                chunk_idx: ci,
                                scenario_idx: si,
                                strategy,
                                seed: job_seed(
                                    self.cfg.seed,
                                    &mv.label,
                                    &nodes_label,
                                    &chunks_label,
                                    &sc.tag(),
                                    sc.comm.spec.kind.name(),
                                    strategy.name(),
                                ),
                            });
                        }
                    }
                }
            }
        }
        out
    }
}

/// Reject duplicate entries on one selection axis (after normalizing
/// aliases, e.g. `sp` vs `c3_sp`).
fn reject_duplicates(axis: &str, names: &[&str]) -> Result<(), Error> {
    for (i, a) in names.iter().enumerate() {
        if names[..i].contains(a) {
            return Err(Error::Config(format!("duplicate {axis} selection '{a}'")));
        }
    }
    Ok(())
}

/// Identity-derived per-job seed: FNV-1a over the job key (with field
/// separators), mixed through SplitMix64 so nearby keys do not yield
/// correlated xoshiro states.
pub fn job_seed(
    base: u64,
    machine: &str,
    nodes: &str,
    chunks: &str,
    tag: &str,
    collective: &str,
    strategy: &str,
) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for field in [machine, nodes, chunks, tag, collective, strategy] {
        for b in field.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h = (h ^ 0x7c).wrapping_mul(0x0000_0100_0000_01b3); // separator
    }
    SplitMix64::new(base ^ h).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RunnerConfig {
        RunnerConfig::default()
    }

    #[test]
    fn table2_plan_covers_full_matrix() {
        let p = SweepPlan::table2(MachineConfig::mi300x(), cfg());
        assert_eq!(p.scenarios.len(), 30);
        assert_eq!(p.strategies.len(), 9);
        assert_eq!(p.chunk_counts, vec![ChunkSel::Auto]);
        assert_eq!(p.job_count(), 270);
        let jobs = p.jobs();
        assert_eq!(jobs.len(), 270);
        // Dense, ordered ids.
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
        }
    }

    #[test]
    fn chunk_axis_multiplies_matrix_and_validates() {
        let p = SweepPlan::table2(MachineConfig::mi300x(), cfg())
            .with_chunk_counts(vec![ChunkSel::Auto, ChunkSel::Fixed(4), ChunkSel::Fixed(8)])
            .unwrap();
        assert_eq!(p.job_count(), 810);
        let jobs = p.jobs();
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
            assert!(j.chunk_idx < 3);
        }
        // Same point at different chunkings gets distinct seeds.
        let a = jobs.iter().find(|j| j.chunk_idx == 0).unwrap();
        let b = jobs
            .iter()
            .find(|j| {
                j.chunk_idx == 1 && j.scenario_idx == a.scenario_idx && j.strategy == a.strategy
            })
            .unwrap();
        assert_ne!(a.seed, b.seed);
        // Bad axes are typed errors.
        let base = SweepPlan::table2(MachineConfig::mi300x(), cfg());
        assert!(base.clone().with_chunk_counts(vec![]).is_err());
        assert!(base
            .clone()
            .with_chunk_counts(vec![ChunkSel::Fixed(0)])
            .is_err());
        assert!(base
            .with_chunk_counts(vec![ChunkSel::Fixed(4), ChunkSel::Fixed(4)])
            .is_err());
        // Entry parsing.
        assert_eq!(ChunkSel::parse("auto").unwrap(), ChunkSel::Auto);
        assert_eq!(ChunkSel::parse("8").unwrap(), ChunkSel::Fixed(8));
        assert!(ChunkSel::parse("0").is_err());
        assert!(ChunkSel::parse("many").is_err());
        assert_eq!(ChunkSel::Fixed(4).label(), "4");
        assert_eq!(ChunkSel::Auto.label(), "auto");
    }

    #[test]
    fn node_axis_multiplies_matrix_and_validates() {
        let p = SweepPlan::table2(MachineConfig::mi300x(), cfg())
            .with_node_counts(vec![1, 2, 4])
            .unwrap();
        assert_eq!(p.job_count(), 810);
        let jobs = p.jobs();
        assert_eq!(jobs.len(), 810);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
            assert!(j.node_idx < 3);
        }
        // Same scenario at different node counts gets distinct seeds.
        let a = jobs.iter().find(|j| j.node_idx == 0).unwrap();
        let b = jobs
            .iter()
            .find(|j| {
                j.node_idx == 1 && j.scenario_idx == a.scenario_idx && j.strategy == a.strategy
            })
            .unwrap();
        assert_ne!(a.seed, b.seed);
        // Bad axes are typed errors.
        let base = SweepPlan::table2(MachineConfig::mi300x(), cfg());
        assert!(base.clone().with_node_counts(vec![]).is_err());
        assert!(base.clone().with_node_counts(vec![0]).is_err());
        assert!(base.with_node_counts(vec![2, 2]).is_err());
    }

    #[test]
    fn e2e_axis_validates_and_rides_alongside() {
        let p = SweepPlan::table2(MachineConfig::mi300x(), cfg())
            .with_e2e(vec![
                E2eSpec::parse("fsdp_step:70b:2:2").unwrap(),
                E2eSpec::parse("tp_chain:70b:2").unwrap(),
            ])
            .unwrap();
        // The e2e axis does not multiply the pairwise job matrix.
        assert_eq!(p.job_count(), 270);
        assert_eq!(p.e2e.len(), 2);
        // Duplicate labels are rejected.
        let dup = SweepPlan::table2(MachineConfig::mi300x(), cfg()).with_e2e(vec![
            E2eSpec::parse("tp_chain:70b:2").unwrap(),
            E2eSpec::parse("tp_chain:70b:2:2").unwrap(),
        ]);
        assert!(dup.is_err());
    }

    #[test]
    fn seeds_depend_on_identity_not_order() {
        let p = SweepPlan::table2(MachineConfig::mi300x(), cfg());
        let jobs = p.jobs();
        // Same identity -> same seed on re-expansion.
        assert_eq!(jobs[17].seed, p.jobs()[17].seed);
        // Distinct identities -> distinct seeds (no collisions in 270).
        let mut seeds: Vec<u64> = jobs.iter().map(|j| j.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 270);
        // Base seed participates.
        let mut cfg2 = cfg();
        cfg2.seed ^= 1;
        let p2 = SweepPlan::table2(MachineConfig::mi300x(), cfg2);
        assert_ne!(p2.jobs()[17].seed, jobs[17].seed);
    }

    #[test]
    fn selection_rejects_unknown_names_with_typed_errors() {
        let base = vec![MachineVariant::base(MachineConfig::mi300x())];
        let kinds = [CollectiveKind::AllGather];
        let err = SweepPlan::from_selection(base.clone(), &["zz_9G"], &kinds, &[], cfg())
            .unwrap_err();
        assert!(matches!(err, Error::UnknownScenario(_)), "{err}");
        let err = SweepPlan::from_selection(base.clone(), &[], &kinds, &["warp"], cfg())
            .unwrap_err();
        assert!(matches!(err, Error::UnknownStrategy(_)), "{err}");
        let ok = SweepPlan::from_selection(
            base,
            &["mb1_896M", "cb1_896M"],
            &kinds,
            &["c3_sp", "conccl"],
            cfg(),
        )
        .unwrap();
        assert_eq!(ok.job_count(), 4);
    }

    #[test]
    fn duplicate_selections_are_rejected_on_every_axis() {
        let base = vec![MachineVariant::base(MachineConfig::mi300x())];
        let kinds = [CollectiveKind::AllGather];
        // Duplicate scenario tag.
        assert!(SweepPlan::from_selection(
            base.clone(),
            &["mb1_896M", "mb1_896M"],
            &kinds,
            &[],
            cfg()
        )
        .is_err());
        // Duplicate strategy, including via an alias.
        assert!(
            SweepPlan::from_selection(base.clone(), &[], &kinds, &["conccl", "conccl"], cfg())
                .is_err()
        );
        assert!(
            SweepPlan::from_selection(base.clone(), &[], &kinds, &["c3_sp", "sp"], cfg()).is_err()
        );
        // Duplicate collective kind.
        let dup_kinds = [CollectiveKind::AllGather, CollectiveKind::AllGather];
        assert!(SweepPlan::from_selection(base, &[], &dup_kinds, &[], cfg()).is_err());
    }

    #[test]
    fn serve_axis_validates_specs_and_traffic() {
        let plan = || {
            SweepPlan::new(
                vec![MachineVariant::base(MachineConfig::mi300x())],
                scenarios::suite(),
                StrategyKind::lineup().to_vec(),
                cfg(),
            )
        };
        let spec = ServeSpec::parse("tp_decode:70b").unwrap();
        let ok = plan()
            .with_serve(vec![spec, ServeSpec::parse("pd_disagg:70b").unwrap()],
                TrafficConfig::default())
            .unwrap();
        assert_eq!(ok.serve.len(), 2);
        // Duplicate labels alias JSON entries and gate keys.
        assert!(plan().with_serve(vec![spec, spec], TrafficConfig::default()).is_err());
        // Invalid traffic configs are rejected at plan-build time.
        let bad = TrafficConfig { rate: 0.0, ..TrafficConfig::default() };
        assert!(plan().with_serve(vec![spec], bad).is_err());
    }

    #[test]
    fn variants_parse_and_validate() {
        let base = MachineConfig::mi300x();
        let vs = parse_variants(&base, "hbm90:hbm_eff=0.9,slow:link_eff=0.6;link_eff_dma=0.6")
            .unwrap();
        assert_eq!(vs.len(), 2);
        assert_eq!(vs[0].label, "hbm90");
        assert_eq!(vs[0].machine.hbm_eff, 0.9);
        assert_eq!(vs[1].machine.link_eff, 0.6);
        assert_eq!(vs[1].machine.link_eff_dma, 0.6);
        // Unknown field / invalid value / missing label all error.
        assert!(parse_variants(&base, "x:bogus_field=1").is_err());
        assert!(parse_variants(&base, "x:compute_eff=7").is_err());
        assert!(parse_variants(&base, "no-colon-here").is_err());
        // Duplicate labels (incl. the base machine's own) are rejected —
        // labels key per-job seeds and the JSON machines[] entries.
        assert!(parse_variants(&base, "a:hbm_eff=0.9,a:hbm_eff=0.8").is_err());
        assert!(parse_variants(&base, "mi300x-8:hbm_eff=0.9").is_err());
    }
}
