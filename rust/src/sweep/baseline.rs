//! Perf-regression gate over sweep reports (the CI bench trajectory).
//!
//! CI runs `conccl sweep --json` on a small deterministic matrix and
//! compares the fresh report against the checked-in
//! `BENCH_baseline.json` with [`gate`]: any strategy whose median
//! speedup fell more than the tolerance below its baseline value fails
//! the build. The reader ([`parse_json`]) is a minimal recursive-descent
//! JSON parser (no `serde` offline) that understands exactly the
//! documents our own writer emits — plus a `{"seeded":false}` bootstrap
//! form so the first commit can land before any baseline numbers exist.
//!
//! Hardening: a malformed baseline file can never panic the gate. Every
//! failure path returns a typed [`ParseError`] carrying the byte offset
//! of the problem; duplicate object keys, non-finite numbers and
//! runaway nesting are rejected outright (our writer emits none of
//! them, so anything exhibiting one is not a document we wrote).

use std::fmt::Write as _;

/// A typed JSON parse failure: what went wrong and the byte offset at
/// which it was detected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl ParseError {
    fn new(offset: usize, msg: impl Into<String>) -> Self {
        ParseError { offset, msg: msg.into() }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// CLI handlers return `Result<(), String>`; let `?` carry the typed
/// error across that boundary without losing the offset.
impl From<ParseError> for String {
    fn from(e: ParseError) -> String {
        e.to_string()
    }
}

/// Deeper nesting than any document our writer emits (which tops out
/// around depth 12) is rejected instead of risking a recursion-induced
/// stack overflow on adversarial input.
const MAX_DEPTH: usize = 128;

/// A parsed JSON value. Objects keep insertion order (our reports are
/// deterministically ordered; preserving it keeps diffs stable).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Number view.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse a JSON document.
pub fn parse_json(text: &str) -> Result<Json, ParseError> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos, 0)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(ParseError::new(pos, "trailing garbage"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), ParseError> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(ParseError::new(*pos, format!("expected '{}'", c as char)))
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> Result<Json, ParseError> {
    if depth > MAX_DEPTH {
        return Err(ParseError::new(*pos, format!("nesting deeper than {MAX_DEPTH}")));
    }
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(ParseError::new(*pos, "unexpected end of input")),
        Some(b'{') => {
            *pos += 1;
            let mut fields: Vec<(String, Json)> = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key_at = *pos;
                let key = parse_string(b, pos)?;
                if fields.iter().any(|(k, _)| *k == key) {
                    return Err(ParseError::new(
                        key_at,
                        format!("duplicate object key '{key}'"),
                    ));
                }
                expect(b, pos, b':')?;
                let val = parse_value(b, pos, depth + 1)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(ParseError::new(*pos, "expected ',' or '}'")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(ParseError::new(*pos, "expected ',' or ']'")),
                }
            }
        }
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, ParseError> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        // "NaN"/"Infinity" land here too (via 'n' they don't — but no
        // number charset letter starts them, so they surface as bad
        // literals/values with the offset of the offending token).
        Err(ParseError::new(*pos, "bad literal"))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let n = std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .ok_or_else(|| ParseError::new(start, "bad number"))?;
    // JSON has no NaN/inf; an overflowing literal like 1e999 parses to
    // inf in Rust but is not a number our writer emits — reject it
    // rather than let a non-finite baseline value slip into the gate.
    if !n.is_finite() {
        return Err(ParseError::new(start, "non-finite number"));
    }
    Ok(Json::Num(n))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, ParseError> {
    if b.get(*pos) != Some(&b'"') {
        return Err(ParseError::new(*pos, "expected string"));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| ParseError::new(*pos, "truncated \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|_| {
                            ParseError::new(*pos, format!("bad \\u escape '{hex}'"))
                        })?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(ParseError::new(*pos, "bad escape")),
                }
                *pos += 1;
            }
            _ => {
                // Copy the raw UTF-8 byte run up to the next special.
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(
                    std::str::from_utf8(&b[start..*pos])
                        .map_err(|e| ParseError::new(start, e.to_string()))?,
                );
            }
        }
    }
    Err(ParseError::new(*pos, "unterminated string"))
}

/// One measured matrix point extracted from a report:
/// `machine/nodes/chunking/tag/collective/strategy` → median speedup
/// (the chunking segment is present from schema v3 on), or an
/// end-to-end workload point `machine/nodes/wl=<label>/<family>` →
/// speedup (schema v4's `workloads[]` section; v5 adds the `auto`
/// family, whose nested `plan` record is metadata the gate ignores),
/// or a serving point `machine/nodes/serve=<workload>/<family>` →
/// p99-latency speedup over the serial chain (schema v6's `serving[]`
/// section).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchPoint {
    pub key: String,
    pub speedup_median: f64,
}

/// Flatten a sweep report (schema version 1 through 6) into bench
/// points.
pub fn extract_points(report: &Json) -> Result<Vec<BenchPoint>, String> {
    let machines = report
        .get("machines")
        .and_then(Json::as_arr)
        .ok_or("report has no machines[]")?;
    let mut out = Vec::new();
    for m in machines {
        let label = m.get("label").and_then(Json::as_str).unwrap_or("?");
        // v2+ nests scenarios under topologies[]; v1 holds them directly.
        let topos: Vec<(u64, &Json)> = match m.get("topologies").and_then(Json::as_arr) {
            Some(ts) => ts
                .iter()
                .map(|t| (t.get("nodes").and_then(Json::as_num).unwrap_or(1.0) as u64, t))
                .collect(),
            None => vec![(1, m)],
        };
        for (nodes, t) in topos {
            // v3 nests scenarios under chunkings[]; v1/v2 documents have
            // no chunk label (None) and keep their legacy key format so
            // old baselines stay addressable.
            let chunkings: Vec<(Option<String>, &Json)> =
                match t.get("chunkings").and_then(Json::as_arr) {
                    Some(cs) => cs
                        .iter()
                        .map(|c| {
                            let lab = match c.get("chunks") {
                                Some(Json::Str(s)) => s.clone(),
                                Some(Json::Num(n)) => format!("{}", *n as u64),
                                _ => "?".to_string(),
                            };
                            (Some(lab), c)
                        })
                        .collect(),
                    None => vec![(None, t)],
                };
            for (chunk_label, c) in chunkings {
                let scenarios = c
                    .get("scenarios")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("machine '{label}' has no scenarios[]"))?;
                for sc in scenarios {
                    let tag = sc.get("tag").and_then(Json::as_str).unwrap_or("?");
                    let coll = sc.get("collective").and_then(Json::as_str).unwrap_or("?");
                    let Some(Json::Obj(strategies)) = sc.get("strategies") else {
                        continue;
                    };
                    for (name, v) in strategies {
                        if let Some(sp) = v.get("speedup_median").and_then(Json::as_num) {
                            if sp.is_finite() {
                                let key = match &chunk_label {
                                    Some(k) => super::key::pair_gate_key(
                                        label, nodes, k, tag, coll, name,
                                    ),
                                    None => {
                                        format!("{label}/{nodes}n/{tag}/{coll}/{name}")
                                    }
                                };
                                out.push(BenchPoint { key, speedup_median: sp });
                            }
                        }
                    }
                }
            }
            // Schema v4: end-to-end workload points under the topology.
            if let Some(wls) = t.get("workloads").and_then(Json::as_arr) {
                for w in wls {
                    let wl = w.get("label").and_then(Json::as_str).unwrap_or("?");
                    let Some(Json::Obj(families)) = w.get("families") else {
                        continue;
                    };
                    for (fam, v) in families {
                        if let Some(sp) = v.get("speedup").and_then(Json::as_num) {
                            if sp.is_finite() {
                                out.push(BenchPoint {
                                    key: super::key::e2e_gate_key(label, nodes, wl, fam),
                                    speedup_median: sp,
                                });
                            }
                        }
                    }
                }
            }
            // Schema v6: serving traffic points under the topology —
            // `speedup` is the family's p99-latency improvement over
            // the serial chain, which is exactly what the gate should
            // hold (goodput/percentile floors ride along with it).
            if let Some(srv) = t.get("serving").and_then(Json::as_arr) {
                for w in srv {
                    let wl = w.get("workload").and_then(Json::as_str).unwrap_or("?");
                    let Some(Json::Obj(families)) = w.get("families") else {
                        continue;
                    };
                    for (fam, v) in families {
                        if let Some(sp) = v.get("speedup").and_then(Json::as_num) {
                            if sp.is_finite() {
                                out.push(BenchPoint {
                                    key: super::key::serve_gate_key(label, nodes, wl, fam),
                                    speedup_median: sp,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Outcome of gating a report against a baseline.
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    /// Points whose speedup fell more than the tolerance:
    /// (key, baseline, current).
    pub regressions: Vec<(String, f64, f64)>,
    /// Baseline points absent from the current report.
    pub missing: Vec<String>,
    /// Points compared.
    pub compared: usize,
    /// Points at or above baseline (within tolerance).
    pub held: usize,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }

    /// Human-readable summary table.
    pub fn render(&self, tolerance: f64) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "perf gate: {} point(s) compared, {} held, {} regressed, {} missing (tolerance {:.1}%)",
            self.compared,
            self.held,
            self.regressions.len(),
            self.missing.len(),
            tolerance * 100.0
        );
        for (key, base, cur) in &self.regressions {
            let _ = writeln!(
                s,
                "  REGRESSION {key}: speedup {cur:.4} vs baseline {base:.4} ({:+.2}%)",
                (cur / base - 1.0) * 100.0
            );
        }
        for key in &self.missing {
            let _ = writeln!(s, "  MISSING    {key}: in baseline but not in report");
        }
        s
    }
}

/// Is this baseline document still the unseeded bootstrap placeholder?
pub fn is_seeded(baseline: &Json) -> bool {
    if let Some(Json::Bool(false)) = baseline.get("seeded") {
        return false;
    }
    baseline
        .get("machines")
        .and_then(Json::as_arr)
        .map(|m| !m.is_empty())
        .unwrap_or(false)
}

/// Rewrite a pre-v3 gate key (no chunking segment) to address the
/// current report's `auto` chunking entry: the last three segments are
/// always `tag/collective/strategy`, so `k=auto` slots in before them
/// (robust to `/` inside machine labels).
fn with_auto_chunk(key: &str) -> Option<String> {
    let parts: Vec<&str> = key.rsplitn(4, '/').collect();
    match parts[..] {
        [strategy, coll, tag, rest] => Some(format!("{rest}/k=auto/{tag}/{coll}/{strategy}")),
        _ => None,
    }
}

/// Compare `current` against `baseline`: a point regresses when its
/// median speedup drops more than `tolerance` (relative) below the
/// baseline value. Improvements and new points never fail the gate.
/// A v1/v2 baseline (keys without the `k=` chunking segment) gates
/// against the current report's `auto` chunking entry, so baselines
/// seeded before the chunk axis keep working.
pub fn gate(baseline: &Json, current: &Json, tolerance: f64) -> Result<GateReport, String> {
    let base_points = extract_points(baseline)?;
    let cur_points = extract_points(current)?;
    let mut report = GateReport::default();
    for bp in &base_points {
        let hit = cur_points.iter().find(|c| c.key == bp.key).or_else(|| {
            if bp.key.contains("/k=") {
                return None;
            }
            let upgraded = with_auto_chunk(&bp.key)?;
            cur_points.iter().find(|c| c.key == upgraded)
        });
        match hit {
            None => report.missing.push(bp.key.clone()),
            Some(cp) => {
                report.compared += 1;
                if cp.speedup_median < bp.speedup_median * (1.0 - tolerance) {
                    report
                        .regressions
                        .push((bp.key.clone(), bp.speedup_median, cp.speedup_median));
                } else {
                    report.held += 1;
                }
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::CollectiveKind;
    use crate::coordinator::runner::RunnerConfig;
    use crate::sched::StrategyKind;
    use crate::sweep::{execute, MachineVariant, SweepPlan};
    use crate::workload::scenarios::{resolve, TABLE2};

    #[test]
    fn parser_roundtrips_scalars_and_structures() {
        let j = parse_json(r#"{"a":1.5,"b":[true,null,"x\ny"],"c":{"d":-2e3}}"#).unwrap();
        assert_eq!(j.get("a").and_then(Json::as_num), Some(1.5));
        let arr = j.get("b").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_str(), Some("x\ny"));
        assert_eq!(j.get("c").unwrap().get("d").and_then(Json::as_num), Some(-2000.0));
        assert!(parse_json("{oops}").is_err());
        assert!(parse_json("[1,2,").is_err());
        assert!(parse_json("{}extra").is_err());
        assert_eq!(parse_json(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn truncated_input_errors_with_offset_instead_of_panicking() {
        for doc in [
            "",
            "{",
            "{\"a\"",
            "{\"a\":",
            "[1,2",
            "\"unterminated",
            "{\"a\":1,",
            "tru",
        ] {
            let err = parse_json(doc).unwrap_err();
            assert!(err.offset <= doc.len(), "{doc:?}: {err}");
            assert!(err.to_string().starts_with(&format!("byte {}", err.offset)));
        }
    }

    #[test]
    fn duplicate_object_keys_are_rejected_at_their_offset() {
        let doc = r#"{"a":1,"a":2}"#;
        let err = parse_json(doc).unwrap_err();
        assert_eq!(err.offset, 7, "offset of the second \"a\"");
        assert!(err.msg.contains("duplicate"), "{err}");
        assert!(err.msg.contains('a'), "{err}");
        // Same key at different nesting levels is fine.
        assert!(parse_json(r#"{"a":{"a":1}}"#).is_ok());
        // ... and in sibling objects.
        assert!(parse_json(r#"[{"a":1},{"a":2}]"#).is_ok());
    }

    #[test]
    fn non_finite_numbers_are_rejected() {
        // JSON has no NaN/Infinity tokens; they must not sneak in as
        // literals, and overflow-to-inf decimals must not either.
        assert!(parse_json("NaN").is_err());
        assert!(parse_json("Infinity").is_err());
        assert!(parse_json("-Infinity").is_err());
        let err = parse_json(r#"{"speedup":1e999}"#).unwrap_err();
        assert!(err.msg.contains("non-finite"), "{err}");
        assert_eq!(err.offset, 11, "offset of the 1e999 token");
        // Large-but-finite values still parse.
        assert!(parse_json("1e308").is_ok());
    }

    #[test]
    fn runaway_nesting_is_rejected_not_overflowed() {
        let deep = "[".repeat(MAX_DEPTH + 8) + &"]".repeat(MAX_DEPTH + 8);
        let err = parse_json(&deep).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        // Depth at the limit is fine.
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(parse_json(&ok).is_ok());
    }

    #[test]
    fn parse_error_converts_to_string_for_cli_boundaries() {
        let err = parse_json("{").unwrap_err();
        let s: String = err.clone().into();
        assert_eq!(s, err.to_string());
        // And it is a real std error (boxable, source-chainable).
        let _: &dyn std::error::Error = &err;
    }

    fn small_report() -> Json {
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::C3Base, StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_node_counts(vec![1, 2])
        .unwrap();
        parse_json(&execute(plan, 1).to_json()).unwrap()
    }

    #[test]
    fn extracts_points_from_own_reports() {
        let report = small_report();
        let points = extract_points(&report).unwrap();
        // 1 machine × 2 node counts × 1 scenario × 2 strategies.
        assert_eq!(points.len(), 4);
        assert!(points
            .iter()
            .any(|p| p.key == "mi300x-8/1n/k=auto/mb1_896M/all-gather/conccl"));
        assert!(points.iter().any(|p| p.key.contains("/2n/")));
        for p in &points {
            assert!(p.speedup_median > 0.5, "{p:?}");
        }
    }

    #[test]
    fn gate_passes_against_itself_and_catches_regressions() {
        let report = small_report();
        let ok = gate(&report, &report, 0.02).unwrap();
        assert!(ok.passed(), "{}", ok.render(0.02));
        assert_eq!(ok.compared, 4);

        // Inflate the baseline 10%: every point now "regressed".
        let inflated = match &report {
            Json::Obj(_) => {
                let mut points = extract_points(&report).unwrap();
                for p in &mut points {
                    p.speedup_median *= 1.10;
                }
                points
            }
            _ => unreachable!(),
        };
        // Synthesize a v3 baseline document holding the inflated numbers.
        let mut doc = String::from(
            "{\"version\":3,\"machines\":[{\"label\":\"mi300x-8\",\"topologies\":[",
        );
        for (ni, nodes) in [1u64, 2].iter().enumerate() {
            if ni > 0 {
                doc.push(',');
            }
            doc.push_str(&format!(
                "{{\"nodes\":{nodes},\"chunkings\":[{{\"chunks\":\"auto\",\
                 \"scenarios\":[{{\"tag\":\"mb1_896M\",\
                 \"collective\":\"all-gather\",\"strategies\":{{"
            ));
            let mut first = true;
            for p in inflated.iter().filter(|p| p.key.contains(&format!("/{nodes}n/"))) {
                let strat = p.key.rsplit('/').next().unwrap();
                if !first {
                    doc.push(',');
                }
                first = false;
                doc.push_str(&format!(
                    "\"{strat}\":{{\"speedup_median\":{}}}",
                    p.speedup_median
                ));
            }
            doc.push_str("}}]}]}");
        }
        doc.push_str("]}]}");
        let baseline = parse_json(&doc).unwrap();
        let bad = gate(&baseline, &report, 0.02).unwrap();
        assert!(!bad.passed());
        assert_eq!(bad.regressions.len(), 4, "{}", bad.render(0.02));
        // A 10% drop is outside 2% tolerance but inside 15%.
        let wide = gate(&baseline, &report, 0.15).unwrap();
        assert!(wide.passed());
    }

    #[test]
    fn pre_chunk_axis_baseline_gates_against_auto_entry() {
        // Cross-version compat: a baseline seeded under the v2 schema
        // (keys without the k= segment) must gate against the current
        // report's auto-chunking entry instead of failing as missing.
        let report = small_report();
        let v2_baseline = parse_json(
            "{\"version\":2,\"machines\":[{\"label\":\"mi300x-8\",\"topologies\":[\
             {\"nodes\":1,\"scenarios\":[{\"tag\":\"mb1_896M\",\
             \"collective\":\"all-gather\",\"strategies\":{\
             \"conccl\":{\"speedup_median\":0.5},\
             \"c3_base\":{\"speedup_median\":0.5}}}]}]}]}",
        )
        .unwrap();
        let r = gate(&v2_baseline, &report, 0.02).unwrap();
        assert!(r.passed(), "{}", r.render(0.02));
        assert_eq!(r.compared, 2);
        // ... and still regresses when the old numbers are higher.
        let inflated = parse_json(
            "{\"version\":2,\"machines\":[{\"label\":\"mi300x-8\",\"topologies\":[\
             {\"nodes\":1,\"scenarios\":[{\"tag\":\"mb1_896M\",\
             \"collective\":\"all-gather\",\"strategies\":{\
             \"conccl\":{\"speedup_median\":99.0}}}]}]}]}",
        )
        .unwrap();
        assert!(!gate(&inflated, &report, 0.02).unwrap().passed());
    }

    #[test]
    fn missing_points_fail_the_gate() {
        let report = small_report();
        let baseline = parse_json(
            "{\"version\":2,\"machines\":[{\"label\":\"ghost\",\"topologies\":[{\"nodes\":1,\
             \"scenarios\":[{\"tag\":\"zz\",\"collective\":\"all-gather\",\
             \"strategies\":{\"conccl\":{\"speedup_median\":1.0}}}]}]}]}",
        )
        .unwrap();
        let r = gate(&baseline, &report, 0.02).unwrap();
        assert!(!r.passed());
        assert_eq!(r.missing.len(), 1);
    }

    #[test]
    fn committed_baseline_is_seeded_and_gates_the_ci_matrix_green() {
        // The committed BENCH_baseline.json must (a) be a *seeded*
        // baseline — `--strict` in the perf-gate job fails otherwise —
        // and (b) pass the gate against a fresh run of the exact CI
        // sweep matrix (pair points + the e2e workload axis + the
        // serving axis), so the workflow is green by construction until
        // a real regression lands.
        let text = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_baseline.json"));
        let baseline = parse_json(text).unwrap();
        assert!(is_seeded(&baseline), "committed baseline must be seeded");
        let base_points = extract_points(&baseline).unwrap();
        assert_eq!(base_points.len(), 204, "CI matrix coverage changed");

        // The CI perf-gate sweep, exactly as .github/workflows/ci.yml
        // runs it (jitter 0, seed 24301, --chunks auto, --e2e axis,
        // --serve axis at --rate 2000 --serve-steps 120).
        let machines = vec![MachineVariant::base(MachineConfig::mi300x())];
        let kinds = [CollectiveKind::AllGather, CollectiveKind::AllToAll];
        let cfg = RunnerConfig {
            jitter: 0.0,
            seed: 24301,
            ..RunnerConfig::default()
        };
        let plan = SweepPlan::from_selection(
            machines,
            &["mb1_896M", "cb1_896M", "mb2_3.25G", "cb5_13G"],
            &kinds,
            &["c3_base", "c3_sp", "conccl", "conccl_rp", "c3_chunked", "conccl_chunked"],
            cfg,
        )
        .and_then(|p| p.with_node_counts(vec![1, 2, 4]))
        .and_then(|p| {
            p.with_e2e(vec![
                crate::workload::e2e::E2eSpec::parse("fsdp_step:70b:2:2").unwrap(),
                crate::workload::e2e::E2eSpec::parse("tp_chain:70b:2").unwrap(),
                crate::workload::e2e::E2eSpec::parse("fsdp_step:405b:2:2").unwrap(),
            ])
        })
        .and_then(|p| {
            p.with_serve(
                vec![
                    crate::workload::serving::ServeSpec::parse("tp_decode:70b").unwrap(),
                    crate::workload::serving::ServeSpec::parse("pd_disagg:70b").unwrap(),
                ],
                crate::workload::traffic::TrafficConfig {
                    steps: 120,
                    ..crate::workload::traffic::TrafficConfig::default()
                },
            )
        })
        .unwrap();
        let report = parse_json(&execute(plan, 2).to_json()).unwrap();
        let g = gate(&baseline, &report, 0.02).unwrap();
        assert!(g.passed(), "{}", g.render(0.02));
        assert_eq!(g.compared, 204);
    }

    #[test]
    fn v4_workload_points_extract_and_gate() {
        use crate::workload::e2e::E2eSpec;
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_e2e(vec![E2eSpec::parse("tp_chain:70b:2").unwrap()])
        .unwrap();
        let report = parse_json(&execute(plan, 1).to_json()).unwrap();
        let points = extract_points(&report).unwrap();
        // 1 pair point + 4 workload families (v5 adds `auto`).
        assert_eq!(points.len(), 5);
        let wl: Vec<&BenchPoint> =
            points.iter().filter(|p| p.key.contains("/wl=")).collect();
        assert_eq!(wl.len(), 4);
        assert!(wl
            .iter()
            .any(|p| p.key == "mi300x-8/1n/wl=tp_chain-70b-l2-d2/dma_overlap"));
        // The planner family gates like any other; its nested plan
        // record does not leak into the key space.
        assert!(wl
            .iter()
            .any(|p| p.key == "mi300x-8/1n/wl=tp_chain-70b-l2-d2/auto"));
        assert!(points.iter().all(|p| !p.key.contains("plan")));
        // Gate against itself: green.
        assert!(gate(&report, &report, 0.02).unwrap().passed());
        // Inflated workload floor regresses.
        let inflated = parse_json(
            "{\"version\":4,\"machines\":[{\"label\":\"mi300x-8\",\"topologies\":[\
             {\"nodes\":1,\"chunkings\":[{\"chunks\":\"auto\",\"scenarios\":[]}],\
             \"workloads\":[{\"label\":\"tp_chain-70b-l2-d2\",\"families\":{\
             \"dma_overlap\":{\"speedup\":99.0}}}]}]}]}",
        )
        .unwrap();
        assert!(!gate(&inflated, &report, 0.02).unwrap().passed());
    }

    #[test]
    fn v6_serving_points_extract_and_gate() {
        use crate::workload::serving::ServeSpec;
        use crate::workload::traffic::TrafficConfig;
        let plan = SweepPlan::new(
            vec![MachineVariant::base(MachineConfig::mi300x())],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_serve(
            vec![ServeSpec::parse("pd_disagg:70b:2:8").unwrap()],
            TrafficConfig { steps: 40, ..TrafficConfig::default() },
        )
        .unwrap();
        let report = parse_json(&execute(plan, 1).to_json()).unwrap();
        let points = extract_points(&report).unwrap();
        // 1 pair point + 4 serving families.
        assert_eq!(points.len(), 5);
        let srv: Vec<&BenchPoint> =
            points.iter().filter(|p| p.key.contains("/serve=")).collect();
        assert_eq!(srv.len(), 4);
        assert!(srv
            .iter()
            .any(|p| p.key == "mi300x-8/1n/serve=pd_disagg-70b-l2-b8/auto"));
        // The serial chain is its own denominator.
        let serial = srv
            .iter()
            .find(|p| p.key.ends_with("/serial"))
            .expect("serial serving point");
        assert!((serial.speedup_median - 1.0).abs() < 1e-12);
        // Gate against itself: green.
        assert!(gate(&report, &report, 0.02).unwrap().passed());
        // Inflated serving floor regresses.
        let inflated = parse_json(
            "{\"version\":6,\"machines\":[{\"label\":\"mi300x-8\",\"topologies\":[\
             {\"nodes\":1,\"chunkings\":[{\"chunks\":\"auto\",\"scenarios\":[]}],\
             \"serving\":[{\"workload\":\"pd_disagg-70b-l2-b8\",\"families\":{\
             \"auto\":{\"speedup\":99.0}}}]}]}]}",
        )
        .unwrap();
        assert!(!gate(&inflated, &report, 0.02).unwrap().passed());
    }

    #[test]
    fn bootstrap_baseline_detected() {
        let boot = parse_json("{\"version\":2,\"seeded\":false,\"machines\":[]}").unwrap();
        assert!(!is_seeded(&boot));
        assert!(is_seeded(&small_report()));
        assert!(!is_seeded(&parse_json("{}").unwrap()));
    }
}
