//! Concurrent execution of a [`SweepPlan`] on a worker pool.
//!
//! * Each job drives its own executor run with a deterministic,
//!   identity-derived RNG seed, so parallel and sequential execution
//!   produce identical results (asserted by the integration tests).
//! * Isolated-execution baselines (serial compute/comm times — the
//!   ideal-speedup denominators) are memoized once per
//!   (machine, scenario) and shared across all strategy jobs.
//! * A job that fails (unknown input, stalled simulation) records a
//!   typed [`Error`] in its slot; the rest of the sweep proceeds.
//! * Every job carries a content-addressed identity ([`super::key`]);
//!   [`execute_with`] consults the on-disk cache ([`super::cache`])
//!   before simulating, skips jobs another `--shard` owns, and tags
//!   each output with its [`JobSource`] so callers can assert a warm
//!   run performed zero simulations.

use crate::config::machine::MachineConfig;
use crate::coordinator::runner::{measure_run, Measured, RunnerConfig, ScenarioOutcome};
use crate::error::Error;
use crate::sched::{Baselines, C3Executor, C3Run, PlanSummary, Planner, Strategy, StrategyKind};
use crate::util::pool;
use crate::util::rng::Rng;
use crate::workload::e2e::{run_e2e_planned_with, E2eFamily, E2eRun};
use crate::workload::scenarios::ResolvedScenario;
use crate::workload::traffic::{run_serve_lineup, ServeReport};

use super::cache::{self, Cache};
use super::key::{e2e_gate_key, pair_gate_key, serve_gate_key};
use super::plan::{job_seed, ChunkSel, MachineVariant, SweepJob, SweepPlan};

/// Where an output slot's value came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobSource {
    /// Simulated in this run (and persisted, if a cache dir is set).
    Simulated,
    /// Reconstructed bit-exactly from a cache record.
    Cached,
    /// Owned by another `--shard`; the slot holds a placeholder error
    /// and is excluded from error reporting and exit codes.
    Skipped,
}

/// Output-slot counts by [`JobSource`] — the job-execution counter the
/// warm-cache acceptance check (`--require-warm`) asserts on. A serving
/// lineup contributes one count per family slot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecCounters {
    pub simulated: usize,
    pub cached: usize,
    pub skipped: usize,
}

impl ExecCounters {
    fn tally(&mut self, source: JobSource) {
        match source {
            JobSource::Simulated => self.simulated += 1,
            JobSource::Cached => self.cached += 1,
            JobSource::Skipped => self.skipped += 1,
        }
    }
}

/// Execution options beyond the plan itself.
#[derive(Debug, Clone, Default)]
pub struct ExecOptions {
    /// Worker count; 0 = auto (one per core).
    pub threads: usize,
    /// Result cache (disabled by default).
    pub cache: Cache,
    /// `Some((i, n))`: only simulate jobs with `key.shard_of(n) == i`;
    /// everything else is served from cache or skipped.
    pub shard: Option<(usize, usize)>,
}

/// The placeholder error in a shard-skipped slot.
fn skipped_err() -> Error {
    Error::Config("skipped: owned by another --shard (merge shard caches to materialize)".into())
}

/// The measured (or failed) result of one sweep job.
#[derive(Debug, Clone)]
pub struct JobOutput {
    pub job: SweepJob,
    /// For the swept-rp strategy: the winning CU reservation.
    pub rp_cus: Option<u32>,
    /// For the chunked pipeline strategies: the chunk count actually
    /// executed (the swept-best one under an `Auto` axis entry, the
    /// clamped fixed count otherwise).
    pub chunks_used: Option<u32>,
    pub result: Result<Measured, Error>,
    pub source: JobSource,
}

/// The result of one end-to-end workload point: a graph run of one
/// `E2eSpec` under one family on one (machine, node-count).
#[derive(Debug, Clone)]
pub struct E2eOutput {
    pub machine_idx: usize,
    pub node_idx: usize,
    /// Index into [`SweepPlan::e2e`].
    pub spec_idx: usize,
    pub family: E2eFamily,
    pub result: Result<E2eRun, Error>,
    /// Per-node decisions of the planner-driven family (`auto` only;
    /// fixed families carry none).
    pub plan: Option<PlanSummary>,
    pub source: JobSource,
}

/// The result of one serving point: a traffic-engine run of one
/// `ServeSpec` under one serving family on one (machine, node-count).
#[derive(Debug, Clone)]
pub struct ServeOutput {
    pub machine_idx: usize,
    pub node_idx: usize,
    /// Index into [`SweepPlan::serve`].
    pub spec_idx: usize,
    pub family: E2eFamily,
    pub result: Result<ServeReport, Error>,
    pub source: JobSource,
}

/// All outputs of one sweep, with enough plan context to aggregate and
/// serialize them.
#[derive(Debug, Clone)]
pub struct SweepResults {
    pub plan: SweepPlan,
    /// Outputs sorted by job id (dense: `outputs[id].job.id == id`).
    pub outputs: Vec<JobOutput>,
    /// End-to-end workload-axis outputs, in
    /// machine → node-count → spec → family order (empty unless the
    /// plan carries an e2e axis).
    pub e2e_outputs: Vec<E2eOutput>,
    /// Serving-axis outputs, in machine → node-count → spec → family
    /// order (empty unless the plan carries a serving axis).
    pub serve_outputs: Vec<ServeOutput>,
    /// Memoized baselines, `[machine_idx][node_idx][scenario_idx]`.
    /// Closed-form arithmetic, recomputed every run (cheap; not a
    /// simulation, so warm runs still count zero simulated slots).
    pub baselines: Vec<Vec<Vec<Baselines>>>,
    /// Worker threads actually used.
    pub threads_used: usize,
    /// Output-slot counts by source (simulated / cached / skipped).
    pub counters: ExecCounters,
}

/// Default worker count: one per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Execute a plan. `threads == 0` means auto (one worker per core);
/// `threads == 1` runs inline with no pool (the sequential reference
/// path — bit-identical to any parallel run by construction).
pub fn execute(plan: SweepPlan, threads: usize) -> SweepResults {
    execute_with(plan, &ExecOptions { threads, ..ExecOptions::default() })
}

/// Execute a plan with caching/sharding options. The cache is consulted
/// *before* the shard filter, so a merge run (`--merge`, all jobs
/// cached) materializes every slot regardless of sharding — which is
/// what makes the union of shard caches byte-identical to an unsharded
/// run.
pub fn execute_with(plan: SweepPlan, opts: &ExecOptions) -> SweepResults {
    let threads = opts.threads;
    let jobs = plan.jobs();
    // One executor per (machine, node-count): the topology is part of
    // the evaluation point.
    let execs: Vec<Vec<C3Executor>> = plan
        .machines
        .iter()
        .map(|mv| {
            plan.node_counts
                .iter()
                .map(|&nodes| {
                    C3Executor::with_topology(mv.machine.clone(), mv.machine.topology(nodes))
                })
                .collect()
        })
        .collect();
    // Baseline memoization: serial/ideal denominators once per
    // (machine, node-count, scenario), not once per strategy job.
    let baselines: Vec<Vec<Vec<Baselines>>> = execs
        .iter()
        .map(|per_node| {
            per_node
                .iter()
                .map(|e| plan.scenarios.iter().map(|sc| e.baselines(sc)).collect())
                .collect()
        })
        .collect();
    let req_threads = if threads == 0 { default_threads() } else { threads };
    let n_threads = req_threads.min(jobs.len()).max(1);
    // Work-stealing by shared counter (each worker takes the next
    // unclaimed job until the matrix drains), outputs reassembled in
    // job-id order — `util::pool` owns that determinism contract now.
    let outputs = pool::run_indexed(jobs.len(), n_threads, |i| {
        run_job(&plan, &execs, &baselines, &jobs[i], opts)
    });
    // End-to-end workload axis: deterministic graph runs (no
    // measurement protocol — the graph engine is noise-free), a few
    // points per sweep, evaluated inline after the pair matrix.
    let mut e2e_outputs = Vec::with_capacity(
        plan.machines.len() * plan.node_counts.len() * plan.e2e.len() * E2eFamily::lineup().len(),
    );
    for (mi, mv) in plan.machines.iter().enumerate() {
        for (ni, &nodes) in plan.node_counts.iter().enumerate() {
            let topo = mv.machine.topology(nodes);
            // One planner — one cost-model profile — per (machine,
            // topology), shared across every spec's `auto` evaluation.
            // Built lazily so an all-cached (or all-skipped) topology
            // never pays for one.
            let mut planner: Option<Planner> = None;
            for (si, spec) in plan.e2e.iter().enumerate() {
                let mut trace = None;
                for family in E2eFamily::lineup() {
                    let key =
                        cache::e2e_job_key(&mv.machine, nodes, &spec.label(), family.name());
                    let mut slot = E2eOutput {
                        machine_idx: mi,
                        node_idx: ni,
                        spec_idx: si,
                        family,
                        result: Err(skipped_err()),
                        plan: None,
                        source: JobSource::Skipped,
                    };
                    if let Some(hit) = opts.cache.lookup_e2e(&key, family) {
                        slot.result = Ok(hit.run);
                        slot.plan = hit.plan;
                        slot.source = JobSource::Cached;
                        e2e_outputs.push(slot);
                        continue;
                    }
                    if let Some((i, n)) = opts.shard {
                        if key.shard_of(n) != i {
                            e2e_outputs.push(slot);
                            continue;
                        }
                    }
                    let planner =
                        planner.get_or_insert_with(|| Planner::new(&mv.machine, &topo));
                    let trace = trace.get_or_insert_with(|| spec.trace());
                    match run_e2e_planned_with(planner, trace, spec.depth, family) {
                        Ok((run, p)) => {
                            opts.cache.store_e2e(&key, &run, p.as_ref());
                            slot.result = Ok(run);
                            slot.plan = p;
                        }
                        Err(e) => slot.result = Err(e),
                    }
                    slot.source = JobSource::Simulated;
                    e2e_outputs.push(slot);
                }
            }
        }
    }
    // Serving axis: long-running traffic simulations, one lineup per
    // (machine, node-count, spec). The traffic loop is sequential and
    // identity-seeded, so — like the e2e axis — its outputs are
    // byte-identical at any worker-thread count. A lineup's four
    // families share the arrival process and the serial denominator, so
    // the lineup caches and shards as one unit.
    let mut serve_outputs = Vec::with_capacity(
        plan.machines.len()
            * plan.node_counts.len()
            * plan.serve.len()
            * E2eFamily::lineup().len(),
    );
    for (mi, mv) in plan.machines.iter().enumerate() {
        for (ni, &nodes) in plan.node_counts.iter().enumerate() {
            let topo = mv.machine.topology(nodes);
            for (si, spec) in plan.serve.iter().enumerate() {
                let seed = job_seed(
                    plan.cfg.seed,
                    &mv.label,
                    &nodes.to_string(),
                    "serve",
                    &spec.label(),
                    "arrivals",
                    "open-loop",
                );
                let key =
                    cache::serve_job_key(&mv.machine, nodes, &spec.label(), &plan.traffic, seed);
                let push_lineup = |results: Vec<(E2eFamily, Result<ServeReport, Error>)>,
                                   source: JobSource,
                                   out: &mut Vec<ServeOutput>| {
                    for (family, result) in results {
                        out.push(ServeOutput {
                            machine_idx: mi,
                            node_idx: ni,
                            spec_idx: si,
                            family,
                            result,
                            source,
                        });
                    }
                };
                if let Some(reports) = opts.cache.lookup_serve(&key) {
                    let slots = reports.into_iter().map(|r| (r.family, Ok(r))).collect();
                    push_lineup(slots, JobSource::Cached, &mut serve_outputs);
                    continue;
                }
                if let Some((i, n)) = opts.shard {
                    if key.shard_of(n) != i {
                        let slots = E2eFamily::lineup()
                            .into_iter()
                            .map(|f| (f, Err(skipped_err())))
                            .collect();
                        push_lineup(slots, JobSource::Skipped, &mut serve_outputs);
                        continue;
                    }
                }
                match run_serve_lineup(&mv.machine, &topo, *spec, plan.traffic, seed) {
                    Ok(reports) => {
                        opts.cache.store_serve(&key, &reports);
                        let slots = reports.into_iter().map(|r| (r.family, Ok(r))).collect();
                        push_lineup(slots, JobSource::Simulated, &mut serve_outputs);
                    }
                    Err(e) => {
                        // Record the failure once per family so every
                        // lineup slot exists for tables/JSON.
                        let slots = E2eFamily::lineup()
                            .into_iter()
                            .map(|f| (f, Err(e.clone())))
                            .collect();
                        push_lineup(slots, JobSource::Simulated, &mut serve_outputs);
                    }
                }
            }
        }
    }
    let mut counters = ExecCounters::default();
    for o in &outputs {
        counters.tally(o.source);
    }
    for o in &e2e_outputs {
        counters.tally(o.source);
    }
    for o in &serve_outputs {
        counters.tally(o.source);
    }
    SweepResults {
        plan,
        outputs,
        e2e_outputs,
        serve_outputs,
        baselines,
        threads_used: n_threads,
        counters,
    }
}

/// Execute one job: map its [`StrategyKind`] onto concrete executor
/// calls (rp strategies sweep/derive their reservation), then apply the
/// measurement protocol with the job's own RNG.
fn run_job(
    plan: &SweepPlan,
    execs: &[Vec<C3Executor>],
    baselines: &[Vec<Vec<Baselines>>],
    job: &SweepJob,
    opts: &ExecOptions,
) -> JobOutput {
    let exec = &execs[job.machine_idx][job.node_idx];
    let sc = &plan.scenarios[job.scenario_idx];
    let b = baselines[job.machine_idx][job.node_idx][job.scenario_idx];
    let chunk_sel = plan.chunk_counts[job.chunk_idx];
    let key = cache::pair_job_key(
        &plan.machines[job.machine_idx].machine,
        plan.node_counts[job.node_idx],
        &chunk_sel.label(),
        &sc.tag(),
        sc.comm.spec.kind.name(),
        job.strategy.name(),
        &plan.cfg,
        job.seed,
    );
    if let Some(hit) = opts.cache.lookup_pair(&key) {
        return JobOutput {
            job: *job,
            rp_cus: hit.rp_cus,
            chunks_used: hit.chunks_used,
            result: Ok(hit.measured),
            source: JobSource::Cached,
        };
    }
    if let Some((i, n)) = opts.shard {
        if key.shard_of(n) != i {
            return JobOutput {
                job: *job,
                rp_cus: None,
                chunks_used: None,
                result: Err(skipped_err()),
                source: JobSource::Skipped,
            };
        }
    }
    let mut rp_cus = None;
    let mut chunks_used = None;
    let run: Result<C3Run, Error> = match job.strategy {
        StrategyKind::Serial => exec.try_run_with_baselines(sc, Strategy::Serial, b),
        StrategyKind::C3Base => exec.try_run_with_baselines(sc, Strategy::C3Base, b),
        StrategyKind::C3Sp => exec.try_run_with_baselines(sc, Strategy::C3Sp, b),
        StrategyKind::C3Rp => exec.try_run_rp_sweep_with(sc, b).map(|(run, k)| {
            rp_cus = Some(k);
            run
        }),
        StrategyKind::C3SpRp => exec.try_run_with_baselines(
            sc,
            Strategy::C3SpRp {
                comm_cus: sc.comm.cu_need(&exec.m),
            },
            b,
        ),
        StrategyKind::C3Best => exec.try_run_c3_best_with(sc, b),
        StrategyKind::Conccl => exec.try_run_with_baselines(sc, Strategy::Conccl, b),
        StrategyKind::ConcclRp => {
            exec.try_run_with_baselines(sc, Strategy::ConcclRp { cus_removed: 8 }, b)
        }
        StrategyKind::C3Chunked | StrategyKind::ConcclChunked => {
            let dma = job.strategy == StrategyKind::ConcclChunked;
            match chunk_sel {
                ChunkSel::Auto => exec.try_run_chunk_sweep_with(sc, dma, b).map(|(run, k)| {
                    chunks_used = Some(k);
                    run
                }),
                ChunkSel::Fixed(k) => {
                    let k_eff = exec.clamp_chunks(sc, k);
                    chunks_used = Some(k_eff);
                    let strat = if dma {
                        Strategy::ConcclChunked { chunks: k_eff }
                    } else {
                        Strategy::C3Chunked { chunks: k_eff }
                    };
                    exec.try_run_with_baselines(sc, strat, b)
                }
            }
        }
    };
    let mut rng = Rng::new(job.seed);
    let result = run.map(|r| measure_run(r, &plan.cfg, &mut rng));
    if let Ok(m) = &result {
        opts.cache.store_pair(&key, m, rp_cus, chunks_used);
    }
    JobOutput {
        job: *job,
        rp_cus,
        chunks_used,
        result,
        source: JobSource::Simulated,
    }
}

impl SweepResults {
    /// Report label of a machine axis entry.
    pub fn machine_label(&self, machine_idx: usize) -> &str {
        &self.plan.machines[machine_idx].label
    }

    /// Output of one matrix point, if that point is in the plan.
    pub fn output_at(
        &self,
        machine_idx: usize,
        node_idx: usize,
        chunk_idx: usize,
        scenario_idx: usize,
        kind: StrategyKind,
    ) -> Option<&JobOutput> {
        // job_id is dense arithmetic — guard each axis explicitly so an
        // out-of-range index cannot alias another matrix point.
        if machine_idx >= self.plan.machines.len()
            || node_idx >= self.plan.node_counts.len()
            || chunk_idx >= self.plan.chunk_counts.len()
            || scenario_idx >= self.plan.scenarios.len()
        {
            return None;
        }
        let ki = self.plan.strategies.iter().position(|&k| k == kind)?;
        self.outputs
            .get(self.plan.job_id(machine_idx, node_idx, chunk_idx, scenario_idx, ki))
    }

    /// End-to-end outputs of one (machine, node-count, spec) point, in
    /// family-lineup order — the one selection predicate every consumer
    /// (tables, JSON) routes through.
    pub fn e2e_point(
        &self,
        machine_idx: usize,
        node_idx: usize,
        spec_idx: usize,
    ) -> Vec<&E2eOutput> {
        self.e2e_outputs
            .iter()
            .filter(|o| {
                o.machine_idx == machine_idx && o.node_idx == node_idx && o.spec_idx == spec_idx
            })
            .collect()
    }

    /// Serving outputs of one (machine, node-count, spec) point, in
    /// family-lineup order — the one selection predicate every consumer
    /// (tables, JSON) routes through.
    pub fn serve_point(
        &self,
        machine_idx: usize,
        node_idx: usize,
        spec_idx: usize,
    ) -> Vec<&ServeOutput> {
        self.serve_outputs
            .iter()
            .filter(|o| {
                o.machine_idx == machine_idx && o.node_idx == node_idx && o.spec_idx == spec_idx
            })
            .collect()
    }

    /// Job errors, flattened for reporting. Shard-skipped slots are
    /// placeholders, not failures — they are excluded here (and so
    /// from non-zero exit codes).
    pub fn errors(&self) -> Vec<(&SweepJob, &Error)> {
        self.outputs
            .iter()
            .filter(|o| o.source != JobSource::Skipped)
            .filter_map(|o| o.result.as_ref().err().map(|e| (&o.job, e)))
            .collect()
    }

    /// The gate keys this sweep's JSON report will yield when parsed by
    /// `baseline::extract_points` — built from the *same* key module,
    /// so emitter and parser cannot drift. One key per materialized
    /// point with a finite speedup (errors, skipped slots and
    /// non-finite values parse to no point).
    pub fn gate_keys(&self) -> Vec<String> {
        let mut keys = Vec::new();
        for (mi, mv) in self.plan.machines.iter().enumerate() {
            for (ni, &nodes) in self.plan.node_counts.iter().enumerate() {
                let nodes = nodes as u64;
                for (ci, chunk) in self.plan.chunk_counts.iter().enumerate() {
                    for (si, sc) in self.plan.scenarios.iter().enumerate() {
                        for &kind in &self.plan.strategies {
                            let Some(out) = self.output_at(mi, ni, ci, si, kind) else {
                                continue;
                            };
                            if out.source == JobSource::Skipped {
                                continue;
                            }
                            let Ok(m) = &out.result else { continue };
                            if !m.speedup_median.is_finite() {
                                continue;
                            }
                            keys.push(pair_gate_key(
                                &mv.label,
                                nodes,
                                &chunk.label(),
                                &sc.tag(),
                                sc.comm.spec.kind.name(),
                                kind.name(),
                            ));
                        }
                    }
                }
                for (si, spec) in self.plan.e2e.iter().enumerate() {
                    for out in self.e2e_point(mi, ni, si) {
                        if out.source == JobSource::Skipped {
                            continue;
                        }
                        let Ok(run) = &out.result else { continue };
                        if !run.speedup.is_finite() {
                            continue;
                        }
                        keys.push(e2e_gate_key(
                            &mv.label,
                            nodes,
                            &spec.label(),
                            out.family.name(),
                        ));
                    }
                }
                for (si, spec) in self.plan.serve.iter().enumerate() {
                    for out in self.serve_point(mi, ni, si) {
                        if out.source == JobSource::Skipped {
                            continue;
                        }
                        let Ok(r) = &out.result else { continue };
                        if !r.speedup.is_finite() {
                            continue;
                        }
                        keys.push(serve_gate_key(
                            &mv.label,
                            nodes,
                            &spec.label(),
                            out.family.name(),
                        ));
                    }
                }
            }
        }
        keys
    }

    /// Assemble the legacy per-scenario outcome rows (the structure all
    /// figure rendering consumes) for one (machine, node-count,
    /// chunking) point. Requires the plan to contain the six measured
    /// strategy columns; any failed constituent job propagates its
    /// error.
    pub fn to_scenario_outcomes(
        &self,
        machine_idx: usize,
        node_idx: usize,
        chunk_idx: usize,
    ) -> Result<Vec<ScenarioOutcome>, Error> {
        let pick = |si: usize, kind: StrategyKind| -> Result<Measured, Error> {
            let out: &JobOutput = self
                .output_at(machine_idx, node_idx, chunk_idx, si, kind)
                .ok_or_else(|| {
                    Error::Config(format!(
                        "plan lacks strategy '{}' needed for scenario outcomes",
                        kind.name()
                    ))
                })?;
            out.result.clone()
        };
        let mut rows = Vec::with_capacity(self.plan.scenarios.len());
        for (si, sc) in self.plan.scenarios.iter().enumerate() {
            let rp = pick(si, StrategyKind::C3Rp)?;
            let rp_cus = self
                .output_at(machine_idx, node_idx, chunk_idx, si, StrategyKind::C3Rp)
                .and_then(|o| o.rp_cus)
                .unwrap_or(0);
            rows.push(ScenarioOutcome {
                tag: sc.tag(),
                scenario: sc.clone(),
                ideal: self.baselines[machine_idx][node_idx][si].ideal(),
                base: pick(si, StrategyKind::C3Base)?,
                sp: pick(si, StrategyKind::C3Sp)?,
                rp,
                rp_cus,
                sp_rp: pick(si, StrategyKind::C3SpRp)?,
                conccl: pick(si, StrategyKind::Conccl)?,
                conccl_rp: pick(si, StrategyKind::ConcclRp)?,
            });
        }
        Ok(rows)
    }
}

/// The six measured [`ScenarioOutcome`] columns (no serial, no derived
/// best) — what [`suite_outcomes`] plans.
pub fn outcome_lineup() -> [StrategyKind; 6] {
    [
        StrategyKind::C3Base,
        StrategyKind::C3Sp,
        StrategyKind::C3Rp,
        StrategyKind::C3SpRp,
        StrategyKind::Conccl,
        StrategyKind::ConcclRp,
    ]
}

/// Run a scenario list on one machine and return the legacy outcome
/// rows. This is what `coordinator::run_suite` now wraps: the
/// sequential per-scenario loop became a job matrix on the worker pool.
pub fn suite_outcomes(
    m: &MachineConfig,
    scenarios: &[ResolvedScenario],
    cfg: &RunnerConfig,
    threads: usize,
) -> Vec<ScenarioOutcome> {
    let plan = SweepPlan::new(
        vec![MachineVariant::base(m.clone())],
        scenarios.to_vec(),
        outcome_lineup().to_vec(),
        *cfg,
    );
    execute(plan, threads)
        .to_scenario_outcomes(0, 0, 0)
        .unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::CollectiveKind;
    use crate::coordinator::runner::{run_scenario, RunnerConfig};
    use crate::workload::scenarios::{resolve, suite_for, TABLE2};

    #[test]
    fn engine_matches_direct_runner_with_zero_jitter() {
        // With jitter = 0 the protocol median equals model truth, so the
        // engine's outcomes must numerically equal the direct
        // run_scenario path (identical executor calls, shared baselines).
        let m = MachineConfig::mi300x();
        let cfg = RunnerConfig::default();
        let scs = vec![
            resolve(&TABLE2[0], CollectiveKind::AllGather),
            resolve(&TABLE2[9], CollectiveKind::AllToAll),
        ];
        let outs = suite_outcomes(&m, &scs, &cfg, 2);
        let exec = C3Executor::new(m);
        let mut rng = Rng::new(cfg.seed);
        for (o, sc) in outs.iter().zip(&scs) {
            let direct = run_scenario(&exec, sc, &cfg, &mut rng);
            assert_eq!(o.tag, direct.tag);
            assert!((o.ideal - direct.ideal).abs() < 1e-15);
            for (name, m1) in o.all() {
                let m2 = direct.measured_by_name(name).unwrap();
                assert!(
                    (m1.stats.median - m2.stats.median).abs() < 1e-15,
                    "{}/{name}",
                    o.tag
                );
            }
            assert_eq!(o.rp_cus, direct.rp_cus);
        }
    }

    #[test]
    fn parallel_equals_sequential_with_jitter() {
        // The determinism contract: per-job seeds make thread count
        // irrelevant even when the protocol injects noise.
        let m = MachineConfig::mi300x();
        let cfg = RunnerConfig::paper();
        let plan = SweepPlan::new(
            vec![MachineVariant::base(m)],
            suite_for(CollectiveKind::AllGather),
            StrategyKind::lineup().to_vec(),
            cfg,
        );
        let seq = execute(plan.clone(), 1);
        let par = execute(plan, 4);
        assert_eq!(seq.outputs.len(), par.outputs.len());
        for (a, b) in seq.outputs.iter().zip(&par.outputs) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.rp_cus, b.rp_cus);
            let (ma, mb) = (a.result.as_ref().unwrap(), b.result.as_ref().unwrap());
            assert_eq!(ma.stats, mb.stats, "job {}", a.job.id);
            assert_eq!(ma.speedup_median, mb.speedup_median);
        }
    }

    #[test]
    fn node_axis_executes_and_shows_nic_bottleneck() {
        let m = MachineConfig::mi300x();
        let plan = SweepPlan::new(
            vec![MachineVariant::base(m)],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Serial, StrategyKind::C3Base, StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_node_counts(vec![1, 2])
        .unwrap();
        assert_eq!(plan.job_count(), 6);
        let res = execute(plan, 2);
        assert!(res.errors().is_empty());
        // Multi-node comm inflates the serial baseline.
        let b1 = res.baselines[0][0][0];
        let b2 = res.baselines[0][1][0];
        assert!(b2.t_comm_iso > b1.t_comm_iso);
        assert_eq!(b2.t_gemm_iso, b1.t_gemm_iso);
        // conccl's edge over c3_base shrinks on the NIC-bound topology.
        let total = |ni: usize, k: StrategyKind| {
            res.output_at(0, ni, 0, 0, k)
                .unwrap()
                .result
                .as_ref()
                .unwrap()
                .run
                .total
        };
        let edge1 = total(0, StrategyKind::C3Base) / total(0, StrategyKind::Conccl);
        let edge2 = total(1, StrategyKind::C3Base) / total(1, StrategyKind::Conccl);
        assert!(
            edge2 < edge1,
            "conccl edge should shrink across nodes: {edge2:.3} vs {edge1:.3}"
        );
    }

    #[test]
    fn chunk_axis_executes_auto_and_fixed_entries() {
        let m = MachineConfig::mi300x();
        let plan = SweepPlan::new(
            vec![MachineVariant::base(m)],
            vec![
                resolve(&TABLE2[13], CollectiveKind::AllGather), // mb2_26.5G (GC-equal)
                resolve(&TABLE2[0], CollectiveKind::AllGather),  // mb1_896M (G-long)
            ],
            vec![StrategyKind::Conccl, StrategyKind::ConcclChunked, StrategyKind::C3Chunked],
            RunnerConfig::default(),
        )
        .with_chunk_counts(vec![ChunkSel::Auto, ChunkSel::Fixed(4)])
        .unwrap();
        assert_eq!(plan.job_count(), 12);
        let res = execute(plan, 2);
        assert!(res.errors().is_empty(), "{:?}", res.errors());
        let out = |ci: usize, si: usize, k: StrategyKind| res.output_at(0, 0, ci, si, k).unwrap();
        // Auto entries record the swept chunk count; fixed entries echo
        // the (clamped) requested count; unchunked strategies carry none.
        assert!(out(0, 0, StrategyKind::ConcclChunked).chunks_used.unwrap() >= 2);
        assert_eq!(out(1, 0, StrategyKind::ConcclChunked).chunks_used, Some(4));
        assert_eq!(out(0, 0, StrategyKind::Conccl).chunks_used, None);
        // Auto-chunked never loses to unchunked ConCCL (same matrix
        // point), and wins strictly on the GC-equal scenario.
        let total = |ci: usize, si: usize, k: StrategyKind| {
            out(ci, si, k).result.as_ref().unwrap().run.total
        };
        assert!(total(0, 0, StrategyKind::ConcclChunked) < total(0, 0, StrategyKind::Conccl));
        assert!(
            total(0, 1, StrategyKind::ConcclChunked)
                <= total(0, 1, StrategyKind::Conccl) + 1e-12
        );
    }

    #[test]
    fn e2e_axis_runs_per_machine_and_topology() {
        use crate::workload::e2e::E2eSpec;
        let m = MachineConfig::mi300x();
        let plan = SweepPlan::new(
            vec![MachineVariant::base(m)],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_node_counts(vec![1, 2])
        .unwrap()
        .with_e2e(vec![E2eSpec::parse("fsdp_forward:70b:2:2").unwrap()])
        .unwrap();
        let res = execute(plan, 1);
        // 1 machine × 2 node counts × 1 spec × 4 families.
        assert_eq!(res.e2e_outputs.len(), 8);
        assert!(res.e2e_outputs.iter().all(|o| o.result.is_ok()));
        let at1 = res.e2e_point(0, 0, 0);
        assert_eq!(at1.len(), 4);
        let get = |ni: usize, f: E2eFamily| {
            res.e2e_point(0, ni, 0)
                .into_iter()
                .find(|o| o.family == f)
                .unwrap()
                .result
                .clone()
                .unwrap()
        };
        // Serial is the identity; DMA overlap beats it on one node.
        assert!((get(0, E2eFamily::Serial).speedup - 1.0).abs() < 1e-12);
        assert!(get(0, E2eFamily::DmaOverlap).speedup > 1.0);
        // The NIC lengthens the 2-node step.
        assert!(get(1, E2eFamily::DmaOverlap).total > get(0, E2eFamily::DmaOverlap).total);
        // The planner family is never worse than any fixed family at
        // either topology, and only it carries a plan.
        for ni in 0..2 {
            let auto = get(ni, E2eFamily::Auto);
            for f in [E2eFamily::Serial, E2eFamily::CuOverlap, E2eFamily::DmaOverlap] {
                assert!(auto.total <= get(ni, f).total * (1.0 + 1e-9), "{}n vs {}", ni + 1, f.name());
            }
            for o in res.e2e_point(0, ni, 0) {
                assert_eq!(o.plan.is_some(), o.family == E2eFamily::Auto);
            }
        }
    }

    #[test]
    fn serve_axis_runs_per_machine_and_topology() {
        use crate::workload::serving::ServeSpec;
        use crate::workload::traffic::TrafficConfig;
        let m = MachineConfig::mi300x();
        let plan = SweepPlan::new(
            vec![MachineVariant::base(m)],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Conccl],
            RunnerConfig::default(),
        )
        .with_serve(
            vec![ServeSpec::parse("pd_disagg:70b:2:8").unwrap()],
            TrafficConfig { steps: 40, ..TrafficConfig::default() },
        )
        .unwrap();
        let seq = execute(plan.clone(), 1);
        // 1 machine × 1 node count × 1 spec × 4 families.
        assert_eq!(seq.serve_outputs.len(), 4);
        assert!(seq.serve_outputs.iter().all(|o| o.result.is_ok()));
        let point = seq.serve_point(0, 0, 0);
        assert_eq!(point.len(), 4);
        let get = |res: &SweepResults, f: E2eFamily| {
            res.serve_point(0, 0, 0)
                .into_iter()
                .find(|o| o.family == f)
                .unwrap()
                .result
                .clone()
                .unwrap()
        };
        // Serial is the speedup identity; auto never loses on p99.
        assert_eq!(get(&seq, E2eFamily::Serial).speedup, 1.0);
        let auto = get(&seq, E2eFamily::Auto);
        for f in [E2eFamily::Serial, E2eFamily::CuOverlap, E2eFamily::DmaOverlap] {
            assert!(auto.p99 <= get(&seq, f).p99 * (1.0 + 1e-9), "vs {}", f.name());
        }
        // The serving axis is byte-identical at any thread count: the
        // loop is sequential and its seed is identity-derived.
        let par = execute(plan, 4);
        for f in E2eFamily::lineup() {
            let (a, b) = (get(&seq, f), get(&par, f));
            assert_eq!(a.p99.to_bits(), b.p99.to_bits(), "{}", f.name());
            assert_eq!(a.goodput_tps.to_bits(), b.goodput_tps.to_bits());
        }
    }

    #[test]
    fn missing_strategy_column_is_config_error() {
        let m = MachineConfig::mi300x();
        let plan = SweepPlan::new(
            vec![MachineVariant::base(m)],
            vec![resolve(&TABLE2[0], CollectiveKind::AllGather)],
            vec![StrategyKind::Conccl],
            RunnerConfig::default(),
        );
        let res = execute(plan, 1);
        let err = res.to_scenario_outcomes(0, 0, 0).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // ... but the job itself ran fine.
        assert!(res.outputs[0].result.is_ok());
        assert!(res.errors().is_empty());
    }
}
