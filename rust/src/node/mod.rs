//! The simulated GPU cluster: per-GPU memory spaces + topology, and the
//! functional data plane that executes DMA command batches (moving real
//! bytes) with the timing from `gpu::sdma::schedule`.
//!
//! [`Node::new`] builds the paper's fully-connected single node;
//! [`Node::with_topology`] spans a hierarchical multi-node fabric, where
//! cross-node commands between non-leaders are staged through the
//! leaders' HBM exactly as the scheduler prices them.

pub mod dataplane;

use crate::config::machine::MachineConfig;
use crate::fabric::Topology;
use crate::gpu::memory::{copy_range, BufferId, GpuMemory};
use crate::gpu::sdma::{
    schedule, schedule_phases, CommandPacket, EnginePolicy, PhasedSchedule, SdmaSchedule,
};

/// One multi-GPU system with real (simulated) memory contents.
pub struct Node {
    pub machine: MachineConfig,
    pub topo: Topology,
    pub mems: Vec<GpuMemory>,
}

impl Node {
    /// Build a single fully-connected node from a machine config.
    pub fn new(machine: MachineConfig) -> Node {
        let topo = Topology::fully_connected(machine.num_gpus);
        Node::with_topology(machine, topo)
    }

    /// Build a system spanning an arbitrary topology. The machine
    /// config describes one node; `topo.gpus_per_node()` must match its
    /// GPU count.
    pub fn with_topology(machine: MachineConfig, topo: Topology) -> Node {
        assert_eq!(
            topo.gpus_per_node(),
            machine.num_gpus,
            "topology gpus_per_node must match machine.num_gpus"
        );
        let mems = (0..topo.num_gpus()).map(|_| GpuMemory::new()).collect();
        Node {
            machine,
            topo,
            mems,
        }
    }

    /// Total number of GPUs across all nodes.
    pub fn num_gpus(&self) -> usize {
        self.topo.num_gpus()
    }

    /// Allocate a zeroed buffer on one GPU.
    pub fn alloc(&mut self, gpu: usize, len: usize) -> BufferId {
        self.mems[gpu].alloc(len)
    }

    /// Allocate an initialized buffer on one GPU.
    pub fn alloc_init(&mut self, gpu: usize, data: &[u8]) -> BufferId {
        self.mems[gpu].alloc_init(data)
    }

    /// Execute a batch of DMA command packets: compute the SDMA timing
    /// schedule *and* move the bytes. Returns the schedule; errors with
    /// [`Error::Config`](crate::error::Error::Config) on a malformed
    /// batch (wrong per-GPU shape, commands not owned by their GPU)
    /// without touching memory contents.
    pub fn execute_dma(
        &mut self,
        per_gpu: &[Vec<CommandPacket>],
        policy: EnginePolicy,
    ) -> Result<SdmaSchedule, crate::error::Error> {
        let sched = schedule(&self.machine, &self.topo, per_gpu, policy)?;
        for cmds in per_gpu {
            for c in cmds {
                self.apply_copy(c);
            }
        }
        Ok(sched)
    }

    /// Execute a barrier-separated phase sequence (hierarchical
    /// collective plans): phased timing + byte movement in phase order.
    /// Errors like [`Node::execute_dma`], before any byte moves.
    pub fn execute_phases(
        &mut self,
        phases: &[Vec<Vec<CommandPacket>>],
        policy: EnginePolicy,
    ) -> Result<PhasedSchedule, crate::error::Error> {
        let sched = schedule_phases(&self.machine, &self.topo, phases, policy)?;
        for per_gpu in phases {
            for cmds in per_gpu {
                for c in cmds {
                    self.apply_copy(c);
                }
            }
        }
        Ok(sched)
    }

    /// Apply one copy command to memory contents, staging through the
    /// intermediate hops' HBM when the endpoints have no direct link
    /// (mirrors the scheduler's store-and-forward route).
    fn apply_copy(&mut self, c: &CommandPacket) {
        if c.src_gpu == c.dst_gpu {
            // Same memory space: stage through a temp (what a DMA
            // local-copy does anyway).
            let data = self.mems[c.src_gpu].read(c.src, c.src_off, c.len).to_vec();
            self.mems[c.dst_gpu].write(c.dst, c.dst_off, &data);
            return;
        }
        let path = self.topo.path(c.src_gpu, c.dst_gpu);
        if path.len() == 2 {
            let (src_mem, dst_mem) = index_two(&mut self.mems, c.src_gpu, c.dst_gpu);
            copy_range(src_mem, c.src, c.src_off, dst_mem, c.dst, c.dst_off, c.len);
            return;
        }
        // Staged route: land the payload in each intermediate hop's HBM
        // before forwarding (the hop buffers are scratch, freed after).
        let data = self.mems[c.src_gpu].read(c.src, c.src_off, c.len).to_vec();
        for &hop in &path[1..path.len() - 1] {
            let tmp = self.mems[hop].alloc_init(&data);
            self.mems[hop].free(tmp);
        }
        self.mems[c.dst_gpu].write(c.dst, c.dst_off, &data);
    }
}

/// Split-borrow two distinct elements of a slice.
fn index_two<T>(xs: &mut [T], a: usize, b: usize) -> (&T, &mut T) {
    assert_ne!(a, b);
    if a < b {
        let (lo, hi) = xs.split_at_mut(b);
        (&lo[a], &mut hi[0])
    } else {
        let (lo, hi) = xs.split_at_mut(a);
        (&hi[0], &mut lo[b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_node() -> Node {
        let mut m = MachineConfig::mi300x();
        m.num_gpus = 4;
        m.link_count = 3;
        Node::new(m)
    }

    #[test]
    fn node_construction() {
        let n = Node::new(MachineConfig::mi300x());
        assert_eq!(n.num_gpus(), 8);
        assert_eq!(n.topo.num_links(), 56);
    }

    #[test]
    fn multi_node_construction() {
        let m = MachineConfig::mi300x();
        let n = Node::with_topology(m.clone(), m.topology(2));
        assert_eq!(n.num_gpus(), 16);
        assert_eq!(n.mems.len(), 16);
    }

    #[test]
    fn execute_dma_moves_bytes_and_times() {
        let mut n = small_node();
        let src = n.alloc_init(0, &[1, 2, 3, 4, 5, 6, 7, 8]);
        let dst = n.alloc(2, 8);
        let mut per_gpu = vec![Vec::new(); 4];
        per_gpu[0].push(CommandPacket {
            src_gpu: 0,
            src,
            src_off: 4,
            dst_gpu: 2,
            dst,
            dst_off: 0,
            len: 4,
        });
        let sched = n.execute_dma(&per_gpu, EnginePolicy::RoundRobin).unwrap();
        assert_eq!(n.mems[2].read(dst, 0, 4), &[5, 6, 7, 8]);
        assert_eq!(n.mems[2].read(dst, 4, 4), &[0, 0, 0, 0]);
        assert!(sched.total > 0.0);
        assert_eq!(sched.timings[0].len(), 1);
    }

    #[test]
    fn cross_node_copy_stages_through_leader_hbm() {
        // 1 → 5 on 2×4 routes via GPUs 0 and 4; bytes arrive intact and
        // the staging buffers are freed (no footprint left behind).
        let mut m = MachineConfig::mi300x();
        m.num_gpus = 4;
        m.link_count = 3;
        let topo = m.topology(2);
        let mut n = Node::with_topology(m, topo);
        let src = n.alloc_init(1, &[9, 8, 7, 6]);
        let dst = n.alloc(5, 4);
        let mut per_gpu = vec![Vec::new(); 8];
        per_gpu[1].push(CommandPacket {
            src_gpu: 1,
            src,
            src_off: 0,
            dst_gpu: 5,
            dst,
            dst_off: 0,
            len: 4,
        });
        let sched = n.execute_dma(&per_gpu, EnginePolicy::LeastLoaded).unwrap();
        assert_eq!(n.mems[5].bytes(dst), &[9, 8, 7, 6]);
        assert!(n.mems[0].is_empty(), "leader staging not freed");
        assert!(n.mems[4].is_empty(), "leader staging not freed");
        // The staged transfer crosses three links.
        let t = sched.timings[1][0];
        assert!(t.finish > t.start);
    }

    #[test]
    fn index_two_both_orders() {
        let mut v = vec![10, 20, 30];
        {
            let (a, b) = index_two(&mut v, 0, 2);
            assert_eq!((*a, *b), (10, 30));
            *b = 31;
        }
        {
            let (a, b) = index_two(&mut v, 2, 0);
            assert_eq!((*a, *b), (31, 10));
        }
    }
}
