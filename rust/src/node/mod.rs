//! The simulated 8-GPU node: per-GPU memory spaces + topology, and the
//! functional data plane that executes DMA command batches (moving real
//! bytes) with the timing from `gpu::sdma::schedule`.

pub mod dataplane;

use crate::config::machine::MachineConfig;
use crate::fabric::Topology;
use crate::gpu::memory::{copy_range, BufferId, GpuMemory};
use crate::gpu::sdma::{schedule, CommandPacket, EnginePolicy, SdmaSchedule};

/// One multi-GPU node with real (simulated) memory contents.
pub struct Node {
    pub machine: MachineConfig,
    pub topo: Topology,
    pub mems: Vec<GpuMemory>,
}

impl Node {
    /// Build a node from a machine config.
    pub fn new(machine: MachineConfig) -> Node {
        let topo = Topology::fully_connected(machine.num_gpus);
        let mems = (0..machine.num_gpus).map(|_| GpuMemory::new()).collect();
        Node {
            machine,
            topo,
            mems,
        }
    }

    /// Number of GPUs.
    pub fn num_gpus(&self) -> usize {
        self.machine.num_gpus
    }

    /// Allocate a zeroed buffer on one GPU.
    pub fn alloc(&mut self, gpu: usize, len: usize) -> BufferId {
        self.mems[gpu].alloc(len)
    }

    /// Allocate an initialized buffer on one GPU.
    pub fn alloc_init(&mut self, gpu: usize, data: &[u8]) -> BufferId {
        self.mems[gpu].alloc_init(data)
    }

    /// Execute a batch of DMA command packets: compute the SDMA timing
    /// schedule *and* move the bytes. Returns the schedule.
    pub fn execute_dma(
        &mut self,
        per_gpu: &[Vec<CommandPacket>],
        policy: EnginePolicy,
    ) -> SdmaSchedule {
        let sched = schedule(&self.machine, &self.topo, per_gpu, policy);
        for cmds in per_gpu {
            for c in cmds {
                self.apply_copy(c);
            }
        }
        sched
    }

    /// Apply one copy command to memory contents.
    fn apply_copy(&mut self, c: &CommandPacket) {
        if c.src_gpu == c.dst_gpu {
            // Same memory space: stage through a temp (what a DMA
            // local-copy does anyway).
            let data = self.mems[c.src_gpu].read(c.src, c.src_off, c.len).to_vec();
            self.mems[c.dst_gpu].write(c.dst, c.dst_off, &data);
        } else {
            let (src_mem, dst_mem) = index_two(&mut self.mems, c.src_gpu, c.dst_gpu);
            copy_range(src_mem, c.src, c.src_off, dst_mem, c.dst, c.dst_off, c.len);
        }
    }
}

/// Split-borrow two distinct elements of a slice.
fn index_two<T>(xs: &mut [T], a: usize, b: usize) -> (&T, &mut T) {
    assert_ne!(a, b);
    if a < b {
        let (lo, hi) = xs.split_at_mut(b);
        (&lo[a], &mut hi[0])
    } else {
        let (lo, hi) = xs.split_at_mut(a);
        (&hi[0], &mut lo[b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_node() -> Node {
        let mut m = MachineConfig::mi300x();
        m.num_gpus = 4;
        m.link_count = 3;
        Node::new(m)
    }

    #[test]
    fn node_construction() {
        let n = Node::new(MachineConfig::mi300x());
        assert_eq!(n.num_gpus(), 8);
        assert_eq!(n.topo.num_links(), 56);
    }

    #[test]
    fn execute_dma_moves_bytes_and_times() {
        let mut n = small_node();
        let src = n.alloc_init(0, &[1, 2, 3, 4, 5, 6, 7, 8]);
        let dst = n.alloc(2, 8);
        let mut per_gpu = vec![Vec::new(); 4];
        per_gpu[0].push(CommandPacket {
            src_gpu: 0,
            src,
            src_off: 4,
            dst_gpu: 2,
            dst,
            dst_off: 0,
            len: 4,
        });
        let sched = n.execute_dma(&per_gpu, EnginePolicy::RoundRobin);
        assert_eq!(n.mems[2].read(dst, 0, 4), &[5, 6, 7, 8]);
        assert_eq!(n.mems[2].read(dst, 4, 4), &[0, 0, 0, 0]);
        assert!(sched.total > 0.0);
        assert_eq!(sched.timings[0].len(), 1);
    }

    #[test]
    fn index_two_both_orders() {
        let mut v = vec![10, 20, 30];
        {
            let (a, b) = index_two(&mut v, 0, 2);
            assert_eq!((*a, *b), (10, 30));
            *b = 31;
        }
        {
            let (a, b) = index_two(&mut v, 2, 0);
            assert_eq!((*a, *b), (31, 10));
        }
    }
}
