//! Functional collectives over the node's real memory contents.
//!
//! Two backends execute the same logical collectives:
//!
//! * **DMA (ConCCL)** — builds the direct-algorithm command plan
//!   (`conccl::plan`) and replays it through the SDMA machinery:
//!   correctness and timing from the same commands the paper's PoCs
//!   issue via `hsa_amd_memory_async_copy_on_engine`.
//! * **CU (RCCL-like)** — moves the same bytes in one logical step and
//!   takes its timing from the analytic
//!   [`CollectiveKernel`](crate::kernels::CollectiveKernel) model (a
//!   GPU-kernel collective's data path has no command-level structure
//!   to replay).
//!
//! Reductions (all-reduce) sum f32 lanes on the "CUs" — DMA engines
//! cannot reduce (§VI-B); the hybrid path reduce-scatters on CUs then
//! all-gathers on DMA engines (§VII-A2).

//! On multi-node topologies the DMA backend switches to the
//! hierarchical plans (`conccl::plan::allgather_hier` /
//! `alltoall_hier`) — intra-node direct DMA, inter-node leader
//! exchange, leader scatter — and checks the conservation invariant
//! (every output byte written exactly once) before moving bytes; a
//! violation is a typed [`Error::Conservation`], never a panic. Both
//! backends stay byte-identical on every topology, chunked
//! (`*_chunked`, the fine-grain pipeline's per-chunk batches) or not.

use crate::conccl::plan::{
    a2a_stage_bytes, allgather_hier, alltoall_hier, check_conservation, chunk_phased,
    reduce_scatter_plan, PhasedPlan,
};
use crate::error::Error;
use crate::gpu::memory::BufferId;
use crate::gpu::sdma::EnginePolicy;
use crate::node::Node;

/// Execute a phased collective plan after checking conservation over
/// the final outputs; returns total modelled time. A violated
/// invariant is a typed [`Error::Conservation`] — never a panic — so a
/// bad plan fails its own job instead of aborting the process.
fn run_checked(
    node: &mut Node,
    plan: &PhasedPlan,
    outs: &[BufferId],
    out_len: usize,
) -> Result<f64, Error> {
    check_conservation(plan, outs, out_len).map_err(Error::Conservation)?;
    Ok(node.execute_phases(&plan.phases, EnginePolicy::LeastLoaded)?.total)
}

/// Which engine executes the data movement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// GPU-kernel (RCCL-like) data path.
    Cu,
    /// SDMA-engine (ConCCL) data path.
    Dma,
}

/// Result of a functional collective: wall-clock estimate + stats.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveRun {
    /// Modelled execution time, seconds.
    pub time: f64,
    /// Bytes moved across the fabric per GPU.
    pub wire_bytes_per_gpu: u64,
}

/// All-gather: GPU `g` owns `shards[g]`; afterwards every GPU's `outs[g]`
/// holds `shard[0] ‖ shard[1] ‖ … ‖ shard[n-1]`.
///
/// All shards must be the same length; `outs[g]` must be `n × shard_len`.
pub fn all_gather(
    node: &mut Node,
    shards: &[BufferId],
    outs: &[BufferId],
    backend: Backend,
) -> Result<CollectiveRun, Error> {
    all_gather_chunked(node, shards, outs, backend, 1)
}

/// [`all_gather`] executed as `chunks` fine-grain chunk batches (the
/// chunked pipeline's data plane): the DMA backend splits every command
/// into per-chunk slices ([`chunk_phased`]) with a barrier per chunk;
/// the byte movement — and therefore every output buffer — is identical
/// to the unchunked plan on any topology (conservation is checked on
/// the chunked plan itself).
pub fn all_gather_chunked(
    node: &mut Node,
    shards: &[BufferId],
    outs: &[BufferId],
    backend: Backend,
    chunks: usize,
) -> Result<CollectiveRun, Error> {
    let n = node.num_gpus();
    assert_eq!(shards.len(), n);
    assert_eq!(outs.len(), n);
    let shard_len = node.mems[0].len(shards[0]);
    for g in 0..n {
        assert_eq!(node.mems[g].len(shards[g]), shard_len, "ragged shards");
        assert_eq!(node.mems[g].len(outs[g]), n * shard_len, "bad out size");
    }
    match backend {
        Backend::Dma => {
            let mut plan = allgather_hier(&node.topo, shards, outs, shard_len);
            if chunks > 1 {
                plan = chunk_phased(&plan, chunks);
            }
            let time = run_checked(node, &plan, outs, n * shard_len)?;
            Ok(CollectiveRun {
                time,
                wire_bytes_per_gpu: ((n - 1) * shard_len) as u64,
            })
        }
        Backend::Cu => {
            // Functionally identical movement, one logical step
            // (chunking a CU kernel is a launch-schedule detail; its
            // data path has no command-level structure to slice).
            for src in 0..n {
                let data = node.mems[src].bytes(shards[src]).to_vec();
                for dst in 0..n {
                    node.mems[dst].write(outs[dst], src * shard_len, &data);
                }
            }
            let k = crate::kernels::CollectiveKernel::new(
                crate::config::workload::CollectiveSpec::new(
                    crate::config::workload::CollectiveKind::AllGather,
                    (n * shard_len) as u64,
                ),
            );
            Ok(CollectiveRun {
                time: k.time_isolated_full_on(&node.machine, &node.topo),
                wire_bytes_per_gpu: ((n - 1) * shard_len) as u64,
            })
        }
    }
}

/// All-to-all: GPU `g`'s `ins[g]` is `n` chunks of `chunk_len`; chunk `d`
/// goes to GPU `d`'s `outs[d]` at offset `g · chunk_len` (a transpose of
/// the chunk matrix).
pub fn all_to_all(
    node: &mut Node,
    ins: &[BufferId],
    outs: &[BufferId],
    backend: Backend,
) -> Result<CollectiveRun, Error> {
    all_to_all_chunked(node, ins, outs, backend, 1)
}

/// [`all_to_all`] executed as `chunks` fine-grain chunk batches; see
/// [`all_gather_chunked`].
pub fn all_to_all_chunked(
    node: &mut Node,
    ins: &[BufferId],
    outs: &[BufferId],
    backend: Backend,
    chunks: usize,
) -> Result<CollectiveRun, Error> {
    let n = node.num_gpus();
    assert_eq!(ins.len(), n);
    assert_eq!(outs.len(), n);
    let total_len = node.mems[0].len(ins[0]);
    assert!(total_len % n == 0, "input not divisible into {n} chunks");
    let chunk_len = total_len / n;
    for g in 0..n {
        assert_eq!(node.mems[g].len(ins[g]), total_len, "ragged inputs");
        assert_eq!(node.mems[g].len(outs[g]), total_len, "bad out size");
    }
    match backend {
        Backend::Dma => {
            // Multi-node plans stage through per-leader scratch buffers
            // (allocated here, freed after the bytes land).
            let nodes = node.topo.num_nodes();
            let stage_len = a2a_stage_bytes(&node.topo, chunk_len);
            let (so, si): (Vec<BufferId>, Vec<BufferId>) = if nodes > 1 {
                (0..nodes)
                    .map(|i| {
                        let leader = node.topo.leader_of(i);
                        (node.alloc(leader, stage_len), node.alloc(leader, stage_len))
                    })
                    .unzip()
            } else {
                (Vec::new(), Vec::new())
            };
            let mut plan = alltoall_hier(&node.topo, ins, outs, &so, &si, chunk_len);
            if chunks > 1 {
                plan = chunk_phased(&plan, chunks);
            }
            let time = run_checked(node, &plan, outs, total_len);
            for i in 0..nodes.min(so.len()) {
                let leader = node.topo.leader_of(i);
                node.mems[leader].free(so[i]);
                node.mems[leader].free(si[i]);
            }
            Ok(CollectiveRun {
                time: time?,
                wire_bytes_per_gpu: ((n - 1) * chunk_len) as u64,
            })
        }
        Backend::Cu => {
            for src in 0..n {
                let data = node.mems[src].bytes(ins[src]).to_vec();
                for dst in 0..n {
                    let chunk = &data[dst * chunk_len..(dst + 1) * chunk_len];
                    node.mems[dst].write(outs[dst], src * chunk_len, chunk);
                }
            }
            let k = crate::kernels::CollectiveKernel::new(
                crate::config::workload::CollectiveSpec::new(
                    crate::config::workload::CollectiveKind::AllToAll,
                    total_len as u64,
                ),
            );
            Ok(CollectiveRun {
                time: k.time_isolated_full_on(&node.machine, &node.topo),
                wire_bytes_per_gpu: ((n - 1) * chunk_len) as u64,
            })
        }
    }
}

/// Reduce-scatter over f32 lanes (sum): `ins[g]` is `n` equal segments
/// of f32s; afterwards `outs[g]` (one segment long) holds the
/// elementwise sum of every GPU's segment `g`.
///
/// * `Backend::Cu` — classic CU kernel (RCCL-like timing); functional
///   reduction on the host loop.
/// * `Backend::Dma` — the offloadable half on engines: every source
///   pushes its segment `d` into GPU `d`'s staging buffer
///   ([`reduce_scatter_plan`], conservation-checked), then the owner
///   reduces the staged columns on its CUs. Byte-identical to the CU
///   backend on every topology (cross-node commands store-and-forward
///   through the leaders).
pub fn reduce_scatter_f32(
    node: &mut Node,
    ins: &[BufferId],
    outs: &[BufferId],
    backend: Backend,
) -> Result<CollectiveRun, Error> {
    let n = node.num_gpus();
    assert_eq!(ins.len(), n);
    assert_eq!(outs.len(), n);
    let total_len = node.mems[0].len(ins[0]);
    assert!(total_len % (4 * n) == 0, "input not divisible into {n} f32 segments");
    let seg_len = total_len / n;
    for g in 0..n {
        assert_eq!(node.mems[g].len(ins[g]), total_len, "ragged inputs");
        assert_eq!(node.mems[g].len(outs[g]), seg_len, "bad out size");
    }
    let reduce_seg = |bytes: &[u8]| -> Vec<u8> {
        // Sum `n` staged f32 columns into one segment.
        let lanes = seg_len / 4;
        let mut acc = vec![0.0f32; lanes];
        for src in 0..n {
            let col = &bytes[src * seg_len..(src + 1) * seg_len];
            for (i, w) in col.chunks_exact(4).enumerate() {
                acc[i] += f32::from_le_bytes([w[0], w[1], w[2], w[3]]);
            }
        }
        acc.iter().flat_map(|v| v.to_le_bytes()).collect()
    };
    let kernel = crate::kernels::CollectiveKernel::new(
        crate::config::workload::CollectiveSpec::new(
            crate::config::workload::CollectiveKind::ReduceScatter,
            total_len as u64,
        ),
    );
    match backend {
        Backend::Dma => {
            let stages: Vec<BufferId> = (0..n).map(|g| node.alloc(g, total_len)).collect();
            let plan = PhasedPlan {
                phases: vec![reduce_scatter_plan(n, ins, &stages, seg_len)],
            };
            let time = run_checked(node, &plan, &stages, total_len)?;
            for g in 0..n {
                let staged = node.mems[g].bytes(stages[g]).to_vec();
                let out = reduce_seg(&staged);
                node.mems[g].write(outs[g], 0, &out);
                node.mems[g].free(stages[g]);
            }
            // The owner's CU reduction is not free: one kernel launch
            // plus an HBM-bound pass reading the staged columns and
            // writing the reduced segment (mirrors all_reduce_f32's
            // hybrid pricing, which also charges its CU slice).
            let reduce_time = node.machine.kernel_launch_s
                + (total_len + seg_len) as f64 / node.machine.hbm_bw_achievable();
            Ok(CollectiveRun {
                time: time + reduce_time,
                wire_bytes_per_gpu: ((n - 1) * seg_len) as u64,
            })
        }
        Backend::Cu => {
            for g in 0..n {
                // Assemble GPU g's column from every source's segment g.
                let mut staged = Vec::with_capacity(total_len);
                for src in 0..n {
                    staged.extend_from_slice(node.mems[src].read(ins[src], g * seg_len, seg_len));
                }
                let out = reduce_seg(&staged);
                node.mems[g].write(outs[g], 0, &out);
            }
            Ok(CollectiveRun {
                time: kernel.time_isolated_full_on(&node.machine, &node.topo),
                wire_bytes_per_gpu: ((n - 1) * seg_len) as u64,
            })
        }
    }
}

/// All-reduce over f32 lanes (sum). `bufs[g]` are equal-length f32 byte
/// buffers; afterwards every GPU holds the elementwise sum.
///
/// * `Backend::Cu` — classic CU kernel all-reduce (RCCL-like timing).
/// * `Backend::Dma` — the §VII-A2 *hybrid*: reduce-scatter on CUs +
///   all-gather on DMA engines (DMA engines cannot reduce).
pub fn all_reduce_f32(
    node: &mut Node,
    bufs: &[BufferId],
    backend: Backend,
) -> Result<CollectiveRun, Error> {
    let n = node.num_gpus();
    assert_eq!(bufs.len(), n);
    let len = node.mems[0].len(bufs[0]);
    assert!(len % 4 == 0, "not an f32 buffer");
    for g in 0..n {
        assert_eq!(node.mems[g].len(bufs[g]), len, "ragged buffers");
    }
    // Functional reduction (host loop standing in for the CU kernel).
    let mut acc: Vec<f32> = vec![0.0; len / 4];
    for g in 0..n {
        for (i, w) in node.mems[g].bytes(bufs[g]).chunks_exact(4).enumerate() {
            acc[i] += f32::from_le_bytes([w[0], w[1], w[2], w[3]]);
        }
    }
    let out_bytes: Vec<u8> = acc.iter().flat_map(|v| v.to_le_bytes()).collect();
    for g in 0..n {
        node.mems[g].write(bufs[g], 0, &out_bytes);
    }
    let m = &node.machine;
    let topo = &node.topo;
    let size = len as u64;
    match backend {
        Backend::Cu => {
            let k = crate::kernels::CollectiveKernel::new(
                crate::config::workload::CollectiveSpec::new(
                    crate::config::workload::CollectiveKind::AllReduce,
                    size,
                ),
            );
            Ok(CollectiveRun {
                time: k.time_isolated_full_on(m, topo),
                wire_bytes_per_gpu: 2 * ((n - 1) * len / n) as u64,
            })
        }
        Backend::Dma => {
            // Hybrid: RS on CUs (a reduce-scatter's wire profile mirrors
            // the all-gather's, on any topology) ...
            let rs_spec = crate::config::workload::CollectiveSpec::new(
                crate::config::workload::CollectiveKind::AllGather,
                size,
            );
            let rs_kernel = crate::kernels::CollectiveKernel::new(rs_spec);
            let rs = m.coll_launch_s + rs_kernel.t_wire_on(m, topo, rs_kernel.cu_need(m));
            // ... then AG on DMA engines (all-gather is statically
            // offloadable; the typed constructor keeps the panic out).
            let ag = crate::conccl::DmaCollective::try_new(rs_spec)?.time_isolated_on(m, topo);
            Ok(CollectiveRun {
                time: rs + ag,
                wire_bytes_per_gpu: 2 * ((n - 1) * len / n) as u64,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::util::rng::Rng;

    fn node(n: usize) -> Node {
        let mut m = MachineConfig::mi300x();
        m.num_gpus = n;
        m.link_count = n - 1;
        Node::new(m)
    }

    fn multi(nodes: usize, p: usize) -> Node {
        let mut m = MachineConfig::mi300x();
        m.num_gpus = p;
        m.link_count = p.saturating_sub(1).max(1);
        let topo = m.topology(nodes);
        Node::with_topology(m, topo)
    }

    fn random_bytes(rng: &mut Rng, len: usize) -> Vec<u8> {
        (0..len).map(|_| rng.u64_below(256) as u8).collect()
    }

    fn check_allgather(backend: Backend, n: usize, shard_len: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut nd = node(n);
        let shards_data: Vec<Vec<u8>> =
            (0..n).map(|_| random_bytes(&mut rng, shard_len)).collect();
        let shards: Vec<_> = (0..n)
            .map(|g| nd.alloc_init(g, &shards_data[g]))
            .collect();
        let outs: Vec<_> = (0..n).map(|g| nd.alloc(g, n * shard_len)).collect();
        let run = all_gather(&mut nd, &shards, &outs, backend).unwrap();
        let expect: Vec<u8> = shards_data.concat();
        for g in 0..n {
            assert_eq!(nd.mems[g].bytes(outs[g]), &expect[..], "gpu {g}");
        }
        assert!(run.time > 0.0);
        assert_eq!(run.wire_bytes_per_gpu, ((n - 1) * shard_len) as u64);
    }

    #[test]
    fn allgather_correct_dma() {
        check_allgather(Backend::Dma, 8, 1024, 1);
    }

    #[test]
    fn allgather_correct_cu() {
        check_allgather(Backend::Cu, 8, 1024, 2);
    }

    #[test]
    fn allgather_small_node_odd_sizes() {
        check_allgather(Backend::Dma, 3, 17, 3);
        check_allgather(Backend::Cu, 5, 33, 4);
    }

    fn check_alltoall(backend: Backend, n: usize, chunk: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut nd = node(n);
        let ins_data: Vec<Vec<u8>> =
            (0..n).map(|_| random_bytes(&mut rng, n * chunk)).collect();
        let ins: Vec<_> = (0..n).map(|g| nd.alloc_init(g, &ins_data[g])).collect();
        let outs: Vec<_> = (0..n).map(|g| nd.alloc(g, n * chunk)).collect();
        all_to_all(&mut nd, &ins, &outs, backend).unwrap();
        // Oracle: out[d][g·c..] == in[g][d·c..].
        for d in 0..n {
            for g in 0..n {
                assert_eq!(
                    nd.mems[d].read(outs[d], g * chunk, chunk),
                    &ins_data[g][d * chunk..(d + 1) * chunk],
                    "dst {d} src {g}"
                );
            }
        }
    }

    #[test]
    fn alltoall_correct_dma() {
        check_alltoall(Backend::Dma, 8, 256, 5);
    }

    #[test]
    fn alltoall_correct_cu() {
        check_alltoall(Backend::Cu, 4, 64, 6);
    }

    #[test]
    fn multi_node_allgather_correct_both_backends() {
        for (nodes, p) in [(2usize, 4usize), (4, 2)] {
            let shard_len = 24;
            for backend in [Backend::Dma, Backend::Cu] {
                let mut rng = Rng::new(7);
                let mut nd = multi(nodes, p);
                let n = nd.num_gpus();
                let data: Vec<Vec<u8>> =
                    (0..n).map(|_| random_bytes(&mut rng, shard_len)).collect();
                let shards: Vec<_> = (0..n).map(|g| nd.alloc_init(g, &data[g])).collect();
                let outs: Vec<_> = (0..n).map(|g| nd.alloc(g, n * shard_len)).collect();
                let run = all_gather(&mut nd, &shards, &outs, backend).unwrap();
                let expect: Vec<u8> = data.concat();
                for g in 0..n {
                    assert_eq!(nd.mems[g].bytes(outs[g]), &expect[..], "{nodes}x{p} gpu {g}");
                }
                assert!(run.time > 0.0);
            }
        }
    }

    #[test]
    fn multi_node_alltoall_correct_and_staging_freed() {
        let (nodes, p, chunk) = (2usize, 4usize, 16usize);
        let mut a = multi(nodes, p);
        let mut b = multi(nodes, p);
        let n = a.num_gpus();
        let mut rng = Rng::new(11);
        let data: Vec<Vec<u8>> = (0..n).map(|_| random_bytes(&mut rng, n * chunk)).collect();
        let ia: Vec<_> = (0..n).map(|g| a.alloc_init(g, &data[g])).collect();
        let oa: Vec<_> = (0..n).map(|g| a.alloc(g, n * chunk)).collect();
        let ib: Vec<_> = (0..n).map(|g| b.alloc_init(g, &data[g])).collect();
        let ob: Vec<_> = (0..n).map(|g| b.alloc(g, n * chunk)).collect();
        let fp_before = a.mems[0].footprint();
        all_to_all(&mut a, &ia, &oa, Backend::Dma).unwrap();
        all_to_all(&mut b, &ib, &ob, Backend::Cu).unwrap();
        // DMA and CU backends are byte-identical across nodes.
        for g in 0..n {
            assert_eq!(a.mems[g].bytes(oa[g]), b.mems[g].bytes(ob[g]), "gpu {g}");
        }
        // And match the transpose oracle.
        for d in 0..n {
            for g in 0..n {
                assert_eq!(
                    a.mems[d].read(oa[d], g * chunk, chunk),
                    &data[g][d * chunk..(d + 1) * chunk],
                    "dst {d} src {g}"
                );
            }
        }
        // Leader staging buffers were freed.
        assert_eq!(a.mems[0].footprint(), fp_before);
    }

    #[test]
    fn multi_node_slower_than_single_node_same_total_gpus() {
        // 8 GPUs as 2×4 pay the NIC; 8 GPUs in one node do not.
        let shard_len = 1 << 20;
        let mut single = node(8);
        let mut dual = multi(2, 4);
        let run = |nd: &mut Node| {
            let n = nd.num_gpus();
            let shards: Vec<_> = (0..n)
                .map(|g| {
                    let fill = vec![g as u8; shard_len];
                    nd.alloc_init(g, &fill)
                })
                .collect();
            let outs: Vec<_> = (0..n).map(|g| nd.alloc(g, n * shard_len)).collect();
            all_gather(nd, &shards, &outs, Backend::Dma).unwrap().time
        };
        let t1 = run(&mut single);
        let t2 = run(&mut dual);
        assert!(t2 > t1, "2x4 ({t2}) should be slower than 1x8 ({t1})");
    }

    #[test]
    fn allreduce_sums_f32() {
        for backend in [Backend::Cu, Backend::Dma] {
            let n = 4;
            let mut nd = node(n);
            let vals: Vec<Vec<f32>> = (0..n)
                .map(|g| (0..8).map(|i| (g * 10 + i) as f32).collect())
                .collect();
            let bufs: Vec<_> = (0..n)
                .map(|g| {
                    let bytes: Vec<u8> =
                        vals[g].iter().flat_map(|v| v.to_le_bytes()).collect();
                    nd.alloc_init(g, &bytes)
                })
                .collect();
            let run = all_reduce_f32(&mut nd, &bufs, backend).unwrap();
            for g in 0..n {
                let got: Vec<f32> = nd.mems[g]
                    .bytes(bufs[g])
                    .chunks_exact(4)
                    .map(|w| f32::from_le_bytes([w[0], w[1], w[2], w[3]]))
                    .collect();
                for (i, v) in got.iter().enumerate() {
                    let expect: f32 = (0..n).map(|gg| (gg * 10 + i) as f32).sum();
                    assert_eq!(*v, expect, "gpu {g} lane {i}");
                }
            }
            assert!(run.time > 0.0);
        }
    }

    #[test]
    fn reduce_scatter_matches_scalar_reference_on_every_topology() {
        // Byte-check both backends against a host scalar reference on
        // 1-, 2- and 4-node topologies (the satellite requirement for
        // the new collective kind).
        for (nodes, p) in [(1usize, 8usize), (2, 4), (4, 2)] {
            for backend in [Backend::Cu, Backend::Dma] {
                let mut nd = if nodes == 1 { node(p) } else { multi(nodes, p) };
                let n = nd.num_gpus();
                let lanes_per_seg = 6;
                let seg = 4 * lanes_per_seg;
                let vals: Vec<Vec<f32>> = (0..n)
                    .map(|g| {
                        (0..n * lanes_per_seg)
                            .map(|i| (g * 100 + i) as f32 * 0.5)
                            .collect()
                    })
                    .collect();
                let ins: Vec<_> = (0..n)
                    .map(|g| {
                        let bytes: Vec<u8> =
                            vals[g].iter().flat_map(|v| v.to_le_bytes()).collect();
                        nd.alloc_init(g, &bytes)
                    })
                    .collect();
                let outs: Vec<_> = (0..n).map(|g| nd.alloc(g, seg)).collect();
                let run = reduce_scatter_f32(&mut nd, &ins, &outs, backend).unwrap();
                assert!(run.time > 0.0);
                for g in 0..n {
                    let got: Vec<f32> = nd.mems[g]
                        .bytes(outs[g])
                        .chunks_exact(4)
                        .map(|w| f32::from_le_bytes([w[0], w[1], w[2], w[3]]))
                        .collect();
                    for (i, v) in got.iter().enumerate() {
                        // Scalar reference: sum of every source's
                        // segment-g lane i.
                        let expect: f32 = (0..n)
                            .map(|src| vals[src][g * lanes_per_seg + i])
                            .sum();
                        assert_eq!(
                            *v, expect,
                            "{nodes}x{p} {backend:?} gpu {g} lane {i}"
                        );
                    }
                }
                // DMA staging buffers were freed.
                if backend == Backend::Dma {
                    for g in 0..n {
                        assert_eq!(
                            nd.mems[g].footprint(),
                            n * seg + seg,
                            "staging not freed on gpu {g}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn chunked_dataplane_is_byte_identical_and_pays_launches() {
        // Chunked DMA execution lands the same bytes as unchunked (any
        // chunk count), while its modelled time gains per-chunk
        // launch/sync cost.
        let n = 8;
        let shard = 100; // not divisible by 3 or 8
        let mut rng = Rng::new(21);
        let data: Vec<Vec<u8>> = (0..n).map(|_| random_bytes(&mut rng, shard)).collect();
        let mk = |chunks: usize| {
            let mut nd = node(n);
            let shards: Vec<_> = (0..n).map(|g| nd.alloc_init(g, &data[g])).collect();
            let outs: Vec<_> = (0..n).map(|g| nd.alloc(g, n * shard)).collect();
            let run = all_gather_chunked(&mut nd, &shards, &outs, Backend::Dma, chunks).unwrap();
            let bytes: Vec<Vec<u8>> = (0..n).map(|g| nd.mems[g].bytes(outs[g]).to_vec()).collect();
            (run.time, bytes)
        };
        let (t1, b1) = mk(1);
        for chunks in [2usize, 3, 8] {
            let (tk, bk) = mk(chunks);
            assert_eq!(b1, bk, "chunked ({chunks}) bytes diverged");
            assert!(tk >= t1, "chunking cannot be free: {tk} vs {t1}");
        }
        // Same for all-to-all.
        let chunk = 48;
        let a2a_data: Vec<Vec<u8>> =
            (0..n).map(|_| random_bytes(&mut rng, n * chunk)).collect();
        let mk2 = |chunks: usize| {
            let mut nd = node(n);
            let ins: Vec<_> = (0..n).map(|g| nd.alloc_init(g, &a2a_data[g])).collect();
            let outs: Vec<_> = (0..n).map(|g| nd.alloc(g, n * chunk)).collect();
            all_to_all_chunked(&mut nd, &ins, &outs, Backend::Dma, chunks).unwrap();
            (0..n).map(|g| nd.mems[g].bytes(outs[g]).to_vec()).collect::<Vec<_>>()
        };
        assert_eq!(mk2(1), mk2(4));
    }

    #[test]
    fn dma_and_cu_backends_agree_functionally() {
        use crate::util::prop::forall;
        forall("backends agree on all-gather", 20, |rng| {
            (rng.i64_in(2, 8) as u64, rng.i64_in(1, 200) as u64)
        })
        .check(|&(n, shard)| {
            let (n, shard) = (n as usize, shard as usize);
            let mut a = node(n);
            let mut b = node(n);
            let data: Vec<Vec<u8>> = (0..n)
                .map(|g| (0..shard).map(|i| ((g * 31 + i) % 251) as u8).collect())
                .collect();
            let (sa, oa): (Vec<_>, Vec<_>) = (0..n)
                .map(|g| (a.alloc_init(g, &data[g]), a.alloc(g, n * shard)))
                .unzip();
            let (sb, ob): (Vec<_>, Vec<_>) = (0..n)
                .map(|g| (b.alloc_init(g, &data[g]), b.alloc(g, n * shard)))
                .unzip();
            all_gather(&mut a, &sa, &oa, Backend::Dma).unwrap();
            all_gather(&mut b, &sb, &ob, Backend::Cu).unwrap();
            for g in 0..n {
                if a.mems[g].bytes(oa[g]) != b.mems[g].bytes(ob[g]) {
                    return Err(format!("mismatch on gpu {g}"));
                }
            }
            Ok(())
        });
    }
}
