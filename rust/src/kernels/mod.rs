//! Kernel models: the two primary ML operators the paper studies
//! (§III) — GEMM computation kernels and collective communication
//! kernels — as mechanistic analytic models over the machine config.
//!
//! Both expose `time_isolated(cu)`, HBM traffic and slowdown curves;
//! the C3 executor (`sched/`) composes them inside the fluid simulator
//! to produce concurrent timelines.

pub mod collective;
pub mod gemm;

pub use collective::CollectiveKernel;
pub use gemm::GemmKernel;
