//! GEMM kernel model (rocBLAS-like), §III / §IV-B of the paper.
//!
//! The model is mechanistic, not a lookup of paper numbers:
//!
//! * **Compute**: the GEMM is decomposed into 128×128 macro-tile
//!   workgroups dispatched in waves over the allocated CUs; compute time
//!   is `waves(cu) · tile_flops / per_cu_rate` (wave quantization
//!   included — partial waves cost a full wave).
//! * **Memory**: each workgroup streams a `K×tile` A-panel and B-panel.
//!   A single workgroup's arithmetic intensity (`tile/2` FLOP/B = 64) is
//!   *below* the MI300X balance point (~247), so GEMMs only reach peak
//!   through panel reuse in the Infinity Cache. We model the resident
//!   panel working set of the ~304 co-scheduled workgroups; when it
//!   overflows the LLC, panel traffic streams from HBM repeatedly. This
//!   single mechanism reproduces Table I's classification — including
//!   the initially surprising fact that huge-N/K GEMMs (`mb1`, `mb2`)
//!   are *memory*-bound — and footnote 3's "fewer concurrent threads →
//!   better cache behaviour" speedup (Fig 5a's circled dip).
//! * The traffic factor's coefficient/exponent/cap are calibration
//!   constants (see [`MachineConfig`]) fit against Table I + Fig 5a +
//!   Fig 6 jointly.

use crate::config::machine::{smoothmax, MachineConfig};
use crate::config::workload::GemmShape;

/// A GEMM computation kernel with its paper tag (`cb1`…`mb2`, or a
/// synthetic tag).
#[derive(Debug, Clone, PartialEq)]
pub struct GemmKernel {
    pub tag: String,
    pub shape: GemmShape,
}

impl GemmKernel {
    pub fn new(tag: &str, shape: GemmShape) -> Self {
        GemmKernel {
            tag: tag.to_string(),
            shape,
        }
    }

    /// Number of macro-tile workgroups.
    pub fn workgroups(&self, m: &MachineConfig) -> u64 {
        let t = m.gemm_tile as u64;
        let tiles_m = (self.shape.m as u64).div_ceil(t);
        let tiles_n = (self.shape.n as u64).div_ceil(t);
        tiles_m * tiles_n
    }

    /// Dispatch waves needed with `cu` compute units.
    pub fn waves(&self, m: &MachineConfig, cu: u32) -> u64 {
        assert!(cu > 0, "GEMM needs at least one CU");
        self.workgroups(m).div_ceil(cu as u64)
    }

    /// FLOPs of one macro-tile workgroup.
    fn tile_flops(&self, m: &MachineConfig) -> f64 {
        2.0 * (m.gemm_tile * m.gemm_tile) as f64 * self.shape.k as f64
    }

    /// Pure compute time with `cu` CUs (wave-quantized), seconds.
    pub fn t_comp(&self, m: &MachineConfig, cu: u32) -> f64 {
        let per_cu_rate = m.peak_flops_bf16 * m.compute_eff / m.cus_total() as f64;
        self.waves(m, cu) as f64 * self.tile_flops(m) / per_cu_rate
    }

    /// Resident panel working set of the co-scheduled workgroups, bytes.
    ///
    /// With row-major workgroup dispatch, `R = min(wgs, 304)` resident
    /// workgroups span `dA = ceil(R / tiles_n)` distinct A-panels and
    /// `dB = min(R, tiles_n)` distinct B-panels, each `K × tile`
    /// elements.
    pub fn working_set(&self, m: &MachineConfig) -> f64 {
        let t = m.gemm_tile as u64;
        let tiles_n = (self.shape.n as u64).div_ceil(t);
        let r = self.workgroups(m).min(m.cus_total() as u64);
        let d_b = r.min(tiles_n);
        let d_a = r.div_ceil(tiles_n).max(1);
        let panel = self.shape.k as f64 * m.gemm_tile as f64 * self.shape.dtype.bytes() as f64;
        (d_a + d_b) as f64 * panel
    }

    /// LLC-streaming traffic factor at `cu` CUs: how many times the
    /// minimal A+B traffic is actually read from HBM. ≥ 1; capped
    /// (K-blocking bounds streaming); damped as CUs shrink (smaller
    /// resident set → better cache behaviour, paper footnote 3).
    pub fn traffic_factor(&self, m: &MachineConfig, cu: u32) -> f64 {
        let ws_ratio = self.working_set(m) / m.llc_capacity;
        let raw = m.gemm_traffic_coeff * ws_ratio.powf(m.gemm_traffic_exp);
        let damp = (1.0 - m.gemm_cache_damp)
            + m.gemm_cache_damp * cu as f64 / m.cus_total() as f64;
        (raw * damp).clamp(1.0, m.gemm_traffic_cap)
    }

    /// HBM traffic at `cu` CUs, bytes (panel streaming + output write).
    pub fn hbm_traffic(&self, m: &MachineConfig, cu: u32) -> f64 {
        let e = self.shape.dtype.bytes() as f64;
        let ab_min =
            (self.shape.m * self.shape.k + self.shape.k * self.shape.n) as f64 * e;
        let out = (self.shape.m * self.shape.n) as f64 * e;
        ab_min * self.traffic_factor(m, cu) + out
    }

    /// Memory time with `cu` CUs, seconds (per-CU issue limit applies).
    pub fn t_mem(&self, m: &MachineConfig, cu: u32) -> f64 {
        self.hbm_traffic(m, cu) / m.hbm_bw_with_cus(cu)
    }

    /// Isolated execution time with `cu` CUs, seconds: smooth roofline
    /// over compute and memory, plus kernel launch.
    pub fn time_isolated(&self, m: &MachineConfig, cu: u32) -> f64 {
        m.kernel_launch_s + smoothmax(self.t_comp(m, cu), self.t_mem(m, cu))
    }

    /// Measured arithmetic intensity (FLOP per HBM byte) at full CUs.
    pub fn intensity(&self, m: &MachineConfig) -> f64 {
        self.shape.flops() / self.hbm_traffic(m, m.cus_total())
    }

    /// Paper §III: compute-bound iff measured op:byte exceeds the
    /// machine's balance point.
    pub fn is_compute_bound(&self, m: &MachineConfig) -> bool {
        self.intensity(m) > m.machine_intensity()
    }

    /// Fraction of achievable HBM/LLC bandwidth this kernel uses in
    /// isolation (Fig 6's y-axis, relative form).
    pub fn llc_bw_utilization(&self, m: &MachineConfig) -> f64 {
        let cu = m.cus_total();
        self.hbm_traffic(m, cu) / self.time_isolated(m, cu) / m.hbm_bw_achievable()
    }

    /// Fig 5a: slowdown relative to all-CU execution when `lost` CUs are
    /// taken away. Values < 1 are the circled cache-behaviour speedups.
    pub fn slowdown_with_cu_loss(&self, m: &MachineConfig, lost: u32) -> f64 {
        let total = m.cus_total();
        assert!(lost < total, "cannot take all CUs away");
        self.time_isolated(m, total - lost) / self.time_isolated(m, total)
    }

    /// Fraction of achievable HBM bandwidth this kernel demands while
    /// running at `cu` CUs — the §VII-A1 residual-interference share
    /// used by the executor, the chunked pipeline and the chunk tuner
    /// (one derivation, so they cannot drift apart).
    pub fn hbm_share(&self, m: &MachineConfig, cu: u32) -> f64 {
        let t = smoothmax(self.t_comp(m, cu), self.t_mem(m, cu));
        (self.hbm_traffic(m, cu) / t / m.hbm_bw_achievable()).min(1.0)
    }

    /// Largest chunk count an M-split of this GEMM supports: one
    /// macro-tile row per chunk at most.
    pub fn max_m_chunks(&self, m: &MachineConfig) -> u32 {
        (self.shape.m as u64).div_ceil(m.gemm_tile as u64).max(1) as u32
    }

    /// Split the GEMM into `k` sub-kernels along M (macro-tile-row
    /// aligned, as even as the tile grid allows) — the tiled sub-shapes
    /// the chunked C3 pipeline launches back-to-back. `k` is clamped to
    /// the tile-row count; chunk FLOPs and output rows sum exactly to
    /// the parent's. Per-chunk wave quantization (partial waves cost a
    /// full wave) is the compute-side price of chunking.
    pub fn split_m(&self, m: &MachineConfig, k: u32) -> Vec<GemmKernel> {
        let tile = m.gemm_tile;
        let tiles_m = (self.shape.m as u64).div_ceil(tile as u64) as usize;
        let k = (k.max(1) as usize).min(tiles_m);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let row0 = (tiles_m * i / k) * tile;
            let row1 = ((tiles_m * (i + 1) / k) * tile).min(self.shape.m);
            debug_assert!(row1 > row0, "empty GEMM chunk");
            out.push(GemmKernel::new(
                &format!("{}#{i}", self.tag),
                crate::config::workload::GemmShape {
                    m: row1 - row0,
                    n: self.shape.n,
                    k: self.shape.k,
                    dtype: self.shape.dtype,
                },
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::GemmShape;
    use crate::workload::llama::table1;

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn g(tag: &str, m_: usize, n: usize, k: usize) -> GemmKernel {
        GemmKernel::new(tag, GemmShape::bf16(m_, n, k))
    }

    #[test]
    fn workgroup_and_wave_math() {
        let m = m();
        let cb1 = g("cb1", 8192, 8192, 8192);
        assert_eq!(cb1.workgroups(&m), 64 * 64);
        assert_eq!(cb1.waves(&m, 304), 14); // ceil(4096/304)
        assert_eq!(cb1.waves(&m, 240), 18);
        // Partial tiles round up.
        let odd = g("odd", 100, 100, 100);
        assert_eq!(odd.workgroups(&m), 1);
    }

    #[test]
    fn table1_classification_reproduced() {
        // The headline structural test: all five cb GEMMs classify
        // compute-bound and both mb GEMMs memory-bound, from shapes
        // alone (paper Table I).
        let m = m();
        for k in table1() {
            let expect_cb = k.tag.starts_with("cb");
            assert_eq!(
                k.is_compute_bound(&m),
                expect_cb,
                "{}: intensity {:.0} vs machine {:.0}",
                k.tag,
                k.intensity(&m),
                m.machine_intensity()
            );
        }
    }

    #[test]
    fn mb_kernels_have_dominant_llc_utilization() {
        // Fig 6: memory-bound GEMMs dwarf all other kernels' bandwidth.
        let m = m();
        let utils: Vec<(String, f64)> = table1()
            .into_iter()
            .map(|k| (k.tag.clone(), k.llc_bw_utilization(&m)))
            .collect();
        let mb_min = utils
            .iter()
            .filter(|(t, _)| t.starts_with("mb"))
            .map(|(_, u)| *u)
            .fold(f64::INFINITY, f64::min);
        let cb_max = utils
            .iter()
            .filter(|(t, _)| t.starts_with("cb"))
            .map(|(_, u)| *u)
            .fold(0.0, f64::max);
        assert!(
            mb_min > 1.7 * cb_max,
            "mb_min {mb_min:.2} should dwarf cb_max {cb_max:.2}: {utils:?}"
        );
        assert!(mb_min > 0.7, "mb kernels should near-saturate: {mb_min}");
    }

    #[test]
    fn fig5a_compute_bound_slowdown_range() {
        // Fig 5a: cb GEMMs suffer ~17-27% slowdown at 64 lost CUs.
        let m = m();
        for k in table1().iter().filter(|k| k.tag.starts_with("cb")) {
            let s = k.slowdown_with_cu_loss(&m, 64);
            assert!(
                (1.10..=1.35).contains(&s),
                "{}: slowdown at -64 CUs = {s:.3}",
                k.tag
            );
        }
    }

    #[test]
    fn fig5a_memory_bound_resilient_with_speedup_dip() {
        // Fig 5a: mb GEMMs are resilient to CU loss; the *extreme* one
        // (mb1 — the kernel the paper actually plots) shows a small
        // speedup at -8 CUs (better cache behaviour, footnote 3). mb2 is
        // borderline compute/memory so only resilience is required.
        let m = m();
        for k in table1().iter().filter(|k| k.tag.starts_with("mb")) {
            let s8 = k.slowdown_with_cu_loss(&m, 8);
            if k.tag == "mb1" {
                assert!(s8 < 1.0, "mb1: expected speedup at -8, got {s8:.4}");
            } else {
                assert!(s8 < 1.03, "{}: expected resilience at -8, got {s8:.4}", k.tag);
            }
            // mb1 stays flat through -96; mb2 (borderline, near the
            // balance point) drifts toward compute-bound behaviour at
            // heavy loss but remains milder than cb kernels.
            for lost in [16u32, 32, 64, 96] {
                let s = k.slowdown_with_cu_loss(&m, lost);
                let limit = match (k.tag.as_str(), lost) {
                    ("mb1", _) => 1.08,
                    (_, 96) => 1.35,
                    _ => 1.20,
                };
                assert!(
                    s < limit,
                    "{}: mb kernel should be resilient at -{lost} (got {s:.3})",
                    k.tag
                );
                // ... and milder than the worst compute-bound kernel.
                let cb_worst = table1()
                    .iter()
                    .filter(|x| x.tag.starts_with("cb"))
                    .map(|x| x.slowdown_with_cu_loss(&m, lost))
                    .fold(0.0, f64::max);
                assert!(
                    s < cb_worst + 1e-9,
                    "{}: at -{lost}, {s:.3} not milder than cb worst {cb_worst:.3}",
                    k.tag
                );
            }
        }
    }

    #[test]
    fn cb_slowdown_monotone_in_cu_loss() {
        let m = m();
        let cb2 = g("cb2", 16384, 8192, 16384);
        let mut prev = 0.0;
        for lost in [0u32, 8, 16, 32, 64, 128] {
            let s = cb2.slowdown_with_cu_loss(&m, lost);
            assert!(s >= prev - 1e-9, "non-monotone at -{lost}: {s} < {prev}");
            prev = s;
        }
        assert!((cb2.slowdown_with_cu_loss(&m, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn traffic_factor_bounds_and_damping() {
        let m = m();
        let mb1 = g("mb1", 8192, 57344, 8192);
        let f_full = mb1.traffic_factor(&m, 304);
        let f_less = mb1.traffic_factor(&m, 240);
        assert!(f_full <= m.gemm_traffic_cap);
        assert!(f_less <= f_full, "fewer CUs must not increase traffic");
        let tiny = g("t", 256, 256, 256);
        assert!(tiny.traffic_factor(&m, 304) >= 1.0);
    }

    #[test]
    fn intensity_decreases_with_streaming() {
        // A huge-K GEMM must have lower measured intensity than a cubic
        // one of similar FLOPs (the LLC overflow mechanism).
        let m = m();
        let cubic = g("c", 8192, 8192, 8192);
        let fat = g("f", 8192, 57344, 8192);
        assert!(fat.intensity(&m) < cubic.intensity(&m));
    }

    #[test]
    fn split_m_conserves_shape_and_flops() {
        let m = m();
        for tag in ["cb1", "mb1", "mb2", "cb5"] {
            let g = crate::workload::llama::gemm_by_tag(tag).unwrap();
            for k in [1u32, 2, 4, 8, 16] {
                let chunks = g.split_m(&m, k);
                assert_eq!(chunks.len(), k as usize, "{tag} k={k}");
                let m_sum: usize = chunks.iter().map(|c| c.shape.m).sum();
                assert_eq!(m_sum, g.shape.m, "{tag} k={k}: M rows lost");
                let f_sum: f64 = chunks.iter().map(|c| c.shape.flops()).sum();
                assert!((f_sum - g.shape.flops()).abs() / g.shape.flops() < 1e-12);
                for c in &chunks {
                    assert_eq!(c.shape.n, g.shape.n);
                    assert_eq!(c.shape.k, g.shape.k);
                    assert!(c.shape.m > 0);
                }
                // Wave quantization: chunked waves never fewer than whole.
                let w_sum: u64 = chunks.iter().map(|c| c.waves(&m, 304)).sum();
                assert!(w_sum >= g.waves(&m, 304), "{tag} k={k}");
            }
        }
        // Clamp: more chunks than tile rows collapses to one per row.
        let tiny = g("t", 200, 512, 512);
        assert_eq!(tiny.max_m_chunks(&m), 2);
        assert_eq!(tiny.split_m(&m, 16).len(), 2);
        // Partial last tile keeps its true row count.
        let ms: Vec<usize> = tiny.split_m(&m, 2).iter().map(|c| c.shape.m).collect();
        assert_eq!(ms, vec![128, 72]);
    }

    #[test]
    fn prop_time_monotone_in_cus() {
        use crate::util::prop::forall;
        let m = m();
        forall("gemm time monotone non-increasing in CUs", 60, |rng| {
            (
                rng.i64_in(1, 64) * 128,
                rng.i64_in(1, 64) * 128,
                rng.i64_in(1, 64) * 128,
            )
        })
        .check(|&(mm, nn, kk)| {
            let k = GemmKernel::new("p", GemmShape::bf16(mm as usize, nn as usize, kk as usize));
            let mut prev = f64::INFINITY;
            for cu in [64u32, 128, 192, 256, 304] {
                let t = k.time_isolated(&m, cu);
                // Allow the small cache-damp speedup (≤8%) against the
                // strict monotone expectation.
                if t > prev * 1.0 + prev * 1e-9 && t > prev * 1.08 {
                    return Err(format!("time rose with more CUs: {prev} -> {t} at {cu}"));
                }
                prev = t;
            }
            Ok(())
        });
    }
}
