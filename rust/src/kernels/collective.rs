//! CU-based collective kernel model (the RCCL-like baseline, §IV-A1).
//!
//! RCCL collectives on a fully-connected 8-GPU node run a *direct*
//! algorithm: persistent GPU workgroups on each GPU read the local
//! buffer and push shards to all seven peers over Infinity Fabric links.
//! The model captures the three properties the paper measures:
//!
//! * **CU needs** (Fig 5b/c): achieved fabric bandwidth scales with the
//!   CUs granted up to a kernel-specific need (32 for all-gather, 64 for
//!   all-to-all); extra CUs add nothing.
//! * **Wire time**: every GPU moves `7/8 · S` across its 7 links in
//!   parallel → `(S/8) / link_bw` when bandwidth-bound, plus a launch
//!   latency that dominates small sizes (latency-bound regime, §III).
//! * **Memory traffic** (Fig 6): all-gather writes the gathered buffer
//!   (≈ `1.0 · S` of HBM traffic); all-to-all reads *and* writes
//!   distinct per-peer buffers with staging (≈ `1.3 · S`) and runs at a
//!   fabric derate — jointly reproducing all-gather's ~14% lower LLC
//!   bandwidth.
//!
//! `size` semantics follow the paper's scenario tags: the full payload
//! materialized per GPU (gathered buffer for AG, exchanged buffer for
//! A2A/AR).

use crate::config::machine::MachineConfig;
use crate::config::workload::{CollectiveKind, CollectiveSpec};
use crate::fabric::Topology;

/// A CU-based (RCCL-like) collective kernel instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveKernel {
    pub spec: CollectiveSpec,
}

impl CollectiveKernel {
    pub fn new(spec: CollectiveSpec) -> Self {
        CollectiveKernel { spec }
    }

    /// CUs this kernel needs for full bandwidth (Fig 5b/c knees).
    pub fn cu_need(&self, m: &MachineConfig) -> u32 {
        match self.spec.kind {
            CollectiveKind::AllGather => m.ag_cu_need,
            CollectiveKind::AllToAll => m.a2a_cu_need,
            CollectiveKind::AllReduce => m.ar_cu_need,
            CollectiveKind::ReduceScatter => m.rs_cu_need,
        }
    }

    /// Bytes each GPU must push over each of its links (the per-link
    /// serialization quantum). All-reduce is reduce-scatter + all-gather
    /// → two passes; a reduce-scatter alone mirrors the all-gather's
    /// wire profile (one shard per peer link).
    pub fn per_link_bytes(&self, m: &MachineConfig) -> f64 {
        let shard = self.spec.size_bytes as f64 / m.num_gpus as f64;
        match self.spec.kind {
            CollectiveKind::AllGather
            | CollectiveKind::AllToAll
            | CollectiveKind::ReduceScatter => shard,
            CollectiveKind::AllReduce => 2.0 * shard,
        }
    }

    /// Total bytes each GPU sends on the wire (all links combined).
    pub fn wire_bytes_per_gpu(&self, m: &MachineConfig) -> f64 {
        self.per_link_bytes(m) * m.link_count as f64
    }

    /// HBM traffic per GPU, bytes (Fig 6's numerator).
    pub fn hbm_traffic(&self, m: &MachineConfig) -> f64 {
        let s = self.spec.size_bytes as f64;
        match self.spec.kind {
            CollectiveKind::AllGather => s * m.ag_hbm_factor,
            CollectiveKind::AllToAll => s * m.a2a_hbm_factor,
            // Read the full payload, write one shard: read-dominated,
            // same order as the all-gather's gathered-buffer write.
            CollectiveKind::ReduceScatter => s * m.ag_hbm_factor,
            // RS pass reads+writes, AG pass writes: ~2x payload.
            CollectiveKind::AllReduce => 2.0 * s * m.ag_hbm_factor,
        }
    }

    /// Fabric efficiency derate for this collective's traffic pattern.
    pub fn link_derate(&self, m: &MachineConfig) -> f64 {
        match self.spec.kind {
            CollectiveKind::AllGather
            | CollectiveKind::AllReduce
            | CollectiveKind::ReduceScatter => 1.0,
            CollectiveKind::AllToAll => m.a2a_link_derate,
        }
    }

    /// Fraction of full bandwidth achieved with `cu` CUs granted
    /// (Fig 5b/c: linear up to the need, flat beyond).
    pub fn bw_scale(&self, m: &MachineConfig, cu: u32) -> f64 {
        (cu as f64 / self.cu_need(m) as f64).min(1.0)
    }

    /// Pure wire time with `cu` CUs, no launch latency, seconds.
    pub fn t_wire(&self, m: &MachineConfig, cu: u32) -> f64 {
        if cu == 0 {
            return f64::INFINITY;
        }
        let bw = m.link_bw_achievable() * self.link_derate(m) * self.bw_scale(m, cu);
        self.per_link_bytes(m) / bw
    }

    /// Isolated execution time with `cu` CUs, seconds (launch + wire;
    /// HBM is never the binding resource in isolation on MI300X — the
    /// fabric is an order of magnitude slower).
    pub fn time_isolated(&self, m: &MachineConfig, cu: u32) -> f64 {
        m.coll_launch_s + self.t_wire(m, cu)
    }

    /// Isolated time at the kernel's full CU allocation.
    pub fn time_isolated_full(&self, m: &MachineConfig) -> f64 {
        self.time_isolated(m, self.cu_need(m))
    }

    /// §III: latency-bound if the launch overhead is a significant
    /// share of the total (latency doesn't shrink with size).
    pub fn is_latency_bound(&self, m: &MachineConfig) -> bool {
        let need = self.cu_need(m);
        m.coll_launch_s >= 0.3 * self.time_isolated(m, need)
    }

    /// Fraction of achievable HBM bandwidth used in isolation (Fig 6).
    pub fn llc_bw_utilization(&self, m: &MachineConfig) -> f64 {
        self.hbm_traffic(m) / self.time_isolated_full(m) / m.hbm_bw_achievable()
    }

    /// Fraction of achievable HBM bandwidth this collective demands
    /// while its wire phase lasts `wire` seconds — the §VII-A1
    /// residual-interference share. One derivation shared by the
    /// whole-kernel executor, the chunked pipeline and the chunk tuner
    /// (the caller supplies the backend/topology-appropriate wire
    /// time), mirroring [`crate::kernels::GemmKernel::hbm_share`].
    pub fn hbm_share_with_wire(&self, m: &MachineConfig, wire: f64) -> f64 {
        (self.hbm_traffic(m) / wire / m.hbm_bw_achievable()).min(1.0)
    }

    /// Fig 5b/c: slowdown at `cu` assigned CUs vs the kernel's need.
    pub fn slowdown_with_cus(&self, m: &MachineConfig, cu: u32) -> f64 {
        self.time_isolated(m, cu) / self.time_isolated_full(m)
    }

    // ---- hierarchical (multi-node) model ----
    //
    // RCCL on a multi-node job runs the hierarchical algorithm: an
    // intra-node direct phase, an inter-node exchange between the
    // NIC-owning leaders, and an intra-node scatter. The NIC replaces
    // the fabric link as the serialization quantum: its (much lower)
    // bandwidth bounds the exchange and its per-transfer latency keeps
    // multi-node collectives latency-bound far longer.

    /// Bytes each leader ships over each NIC link per algorithm pass
    /// (zero on a single node).
    pub fn per_nic_bytes(&self, t: &Topology) -> f64 {
        match *t {
            Topology::FullyConnected { .. } => 0.0,
            Topology::MultiNode {
                nodes,
                gpus_per_node,
                ..
            } => {
                let s = self.spec.size_bytes as f64;
                match self.spec.kind {
                    // One node block (its gathered shards) per pass.
                    CollectiveKind::AllGather
                    | CollectiveKind::AllReduce
                    | CollectiveKind::ReduceScatter => s / nodes as f64,
                    // A full P×P chunk block per node pair.
                    CollectiveKind::AllToAll => gpus_per_node as f64 * s / nodes as f64,
                }
            }
        }
    }

    /// Pure wire time on a topology with `cu` CUs granted, seconds.
    /// Single node: [`CollectiveKernel::t_wire`]. Multi-node: the sum of
    /// the hierarchical phases, with the NIC exchange in the middle.
    pub fn t_wire_on(&self, m: &MachineConfig, t: &Topology, cu: u32) -> f64 {
        match *t {
            Topology::FullyConnected { .. } => self.t_wire(m, cu),
            Topology::MultiNode {
                nodes,
                gpus_per_node,
                nic_bw,
                nic_latency,
            } => {
                if cu == 0 {
                    return f64::INFINITY;
                }
                let s = self.spec.size_bytes as f64;
                let nn = nodes as f64;
                let p = gpus_per_node as f64;
                let shard = s / (nn * p);
                let bw = m.link_bw_achievable() * self.link_derate(m) * self.bw_scale(m, cu);
                let passes = match self.spec.kind {
                    CollectiveKind::AllReduce => 2.0, // RS + AG, both hierarchical
                    _ => 1.0,
                };
                // Phase 1 bottleneck link: the all-to-all funnels every
                // remote-bound chunk through the member → leader link.
                let ph1 = match self.spec.kind {
                    CollectiveKind::AllToAll => shard * (1.0 + (nn - 1.0) * p),
                    _ => shard,
                };
                // Phase 3: leaders rebroadcast every remote block.
                let ph3 = (nn - 1.0) * s / nn;
                let intra = if gpus_per_node > 1 { (ph1 + ph3) / bw } else { 0.0 };
                let t_nic = nic_latency + self.per_nic_bytes(t) / nic_bw;
                passes * (intra + t_nic)
            }
        }
    }

    /// Isolated execution time on a topology with `cu` CUs, seconds.
    pub fn time_isolated_on(&self, m: &MachineConfig, t: &Topology, cu: u32) -> f64 {
        m.coll_launch_s + self.t_wire_on(m, t, cu)
    }

    /// Isolated time on a topology at the kernel's full CU allocation.
    pub fn time_isolated_full_on(&self, m: &MachineConfig, t: &Topology) -> f64 {
        self.time_isolated_on(m, t, self.cu_need(m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{GIB, MIB};

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn ag(bytes: u64) -> CollectiveKernel {
        CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, bytes))
    }

    fn a2a(bytes: u64) -> CollectiveKernel {
        CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllToAll, bytes))
    }

    #[test]
    fn cu_needs_match_fig5() {
        let m = m();
        assert_eq!(ag(GIB).cu_need(&m), 32);
        assert_eq!(a2a(GIB).cu_need(&m), 64);
    }

    #[test]
    fn wire_math_fully_connected() {
        let m = m();
        let k = ag(8 * GIB);
        // Each GPU owns 1 GiB shard and pushes it to 7 peers.
        assert_eq!(k.per_link_bytes(&m), GIB as f64);
        assert_eq!(k.wire_bytes_per_gpu(&m), 7.0 * GIB as f64);
    }

    #[test]
    fn fig5bc_slowdown_shape() {
        let m = m();
        // Below the need: proportional slowdown; above: flat.
        let k = ag(896 * MIB);
        let s16 = k.slowdown_with_cus(&m, 16);
        assert!((1.8..2.2).contains(&s16), "AG at 16 CUs: {s16}");
        let s64 = k.slowdown_with_cus(&m, 64);
        assert!((s64 - 1.0).abs() < 1e-9, "AG flat beyond 32: {s64}");
        let k2 = a2a(896 * MIB);
        let s32 = k2.slowdown_with_cus(&m, 32);
        assert!((1.8..2.2).contains(&s32), "A2A at 32 CUs: {s32}");
        assert!((k2.slowdown_with_cus(&m, 128) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn a2a_slower_and_hungrier_than_ag() {
        let m = m();
        let s = 896 * MIB;
        let t_ag = ag(s).time_isolated_full(&m);
        let t_a2a = a2a(s).time_isolated_full(&m);
        assert!(t_a2a > t_ag, "A2A derated fabric: {t_a2a} vs {t_ag}");
        // Fig 6 note: AG has ~14% lower LLC bandwidth than A2A.
        let r = ag(s).llc_bw_utilization(&m) / a2a(s).llc_bw_utilization(&m);
        assert!(
            (0.80..0.92).contains(&r),
            "AG/A2A bandwidth ratio {r:.3} (paper ~0.86)"
        );
    }

    #[test]
    fn latency_vs_bandwidth_bound_regimes() {
        let m = m();
        assert!(ag(64 * 1024).is_latency_bound(&m)); // 64 KiB
        assert!(!ag(128 * MIB).is_latency_bound(&m));
        // All Table II sizes (>=128M) are bandwidth-bound (§VI-C).
        assert!(!ag(896 * MIB).is_latency_bound(&m));
    }

    #[test]
    fn reduce_scatter_mirrors_allgather_wire_profile() {
        let m = m();
        let s = 896 * MIB;
        let rs = CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::ReduceScatter, s));
        assert_eq!(rs.cu_need(&m), m.rs_cu_need);
        assert_eq!(rs.per_link_bytes(&m), ag(s).per_link_bytes(&m));
        assert_eq!(rs.link_derate(&m), 1.0);
        // Same wire profile as AG at the same CU grant, and exactly
        // half an all-reduce (AR = RS + AG).
        assert!((rs.t_wire(&m, 32) - ag(s).t_wire(&m, 32)).abs() < 1e-15);
        let ar = CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllReduce, s));
        assert!((2.0 * rs.per_link_bytes(&m) - ar.per_link_bytes(&m)).abs() < 1e-9);
        // Multi-node: the NIC exchange ships one node block per pass.
        let t = m.topology(2);
        assert_eq!(rs.per_nic_bytes(&t), ag(s).per_nic_bytes(&t));
        assert!(rs.time_isolated_full_on(&m, &t) > rs.time_isolated_full(&m));
    }

    #[test]
    fn allreduce_double_pass() {
        let m = m();
        let s = GIB;
        let ar = CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllReduce, s));
        assert_eq!(ar.per_link_bytes(&m), 2.0 * ag(s).per_link_bytes(&m));
        assert!(ar.time_isolated_full(&m) > ag(s).time_isolated_full(&m));
    }

    #[test]
    fn ag_896m_wire_time_plausible() {
        // (896M/8) / (64 GB/s * 0.85) ≈ 2.16 ms.
        let m = m();
        let t = ag(896 * MIB).time_isolated_full(&m);
        assert!((1.9e-3..2.4e-3).contains(&t), "t = {t}");
    }

    #[test]
    fn hierarchical_times_expose_nic_bottleneck() {
        let m = m();
        let s = 896 * MIB;
        for k in [ag(s), a2a(s)] {
            let t1 = k.time_isolated_full_on(&m, &m.topology(1));
            assert_eq!(t1, k.time_isolated_full(&m), "single node must match");
            let t2 = k.time_isolated_full_on(&m, &m.topology(2));
            let t4 = k.time_isolated_full_on(&m, &m.topology(4));
            assert!(t2 > t1, "{}: 2-node {t2} <= 1-node {t1}", k.spec.kind.name());
            assert!(t4 > 0.0 && t2 > 0.0);
            // Dropping NIC bandwidth 10x lengthens the collective.
            let mut slow = m.clone();
            slow.nic_bw = m.nic_bw / 10.0;
            let t2_slow = k.time_isolated_full_on(&slow, &slow.topology(2));
            assert!(t2_slow > 1.5 * t2, "{t2_slow} vs {t2}");
        }
        // A2A ships P× more bytes per NIC link than AG.
        let t = m.topology(2);
        let r = a2a(s).per_nic_bytes(&t) / ag(s).per_nic_bytes(&t);
        assert!((r - m.num_gpus as f64).abs() < 1e-9, "ratio {r}");
    }

    #[test]
    fn prop_time_monotone_in_size_and_cus() {
        use crate::util::prop::forall;
        let m = m();
        forall("collective time monotone", 80, |rng| {
            (rng.i64_in(1, 2000) as u64 * MIB / 8, rng.i64_in(1, 38) as u64 * 8)
        })
        .check(|&(sz, cu)| {
            let k = ag(sz);
            let bigger = ag(sz * 2);
            if bigger.time_isolated(&m, cu as u32) < k.time_isolated(&m, cu as u32) {
                return Err("time decreased with size".into());
            }
            let more = k.time_isolated(&m, (cu as u32) + 8);
            if more > k.time_isolated(&m, cu as u32) + 1e-12 {
                return Err("time increased with more CUs".into());
            }
            Ok(())
        });
    }
}
