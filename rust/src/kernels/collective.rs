//! CU-based collective kernel model (the RCCL-like baseline, §IV-A1).
//!
//! RCCL collectives on a fully-connected 8-GPU node run a *direct*
//! algorithm: persistent GPU workgroups on each GPU read the local
//! buffer and push shards to all seven peers over Infinity Fabric links.
//! The model captures the three properties the paper measures:
//!
//! * **CU needs** (Fig 5b/c): achieved fabric bandwidth scales with the
//!   CUs granted up to a kernel-specific need (32 for all-gather, 64 for
//!   all-to-all); extra CUs add nothing.
//! * **Wire time**: every GPU moves `7/8 · S` across its 7 links in
//!   parallel → `(S/8) / link_bw` when bandwidth-bound, plus a launch
//!   latency that dominates small sizes (latency-bound regime, §III).
//! * **Memory traffic** (Fig 6): all-gather writes the gathered buffer
//!   (≈ `1.0 · S` of HBM traffic); all-to-all reads *and* writes
//!   distinct per-peer buffers with staging (≈ `1.3 · S`) and runs at a
//!   fabric derate — jointly reproducing all-gather's ~14% lower LLC
//!   bandwidth.
//!
//! `size` semantics follow the paper's scenario tags: the full payload
//! materialized per GPU (gathered buffer for AG, exchanged buffer for
//! A2A/AR).

use crate::config::machine::MachineConfig;
use crate::config::workload::{CollectiveKind, CollectiveSpec};

/// A CU-based (RCCL-like) collective kernel instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveKernel {
    pub spec: CollectiveSpec,
}

impl CollectiveKernel {
    pub fn new(spec: CollectiveSpec) -> Self {
        CollectiveKernel { spec }
    }

    /// CUs this kernel needs for full bandwidth (Fig 5b/c knees).
    pub fn cu_need(&self, m: &MachineConfig) -> u32 {
        match self.spec.kind {
            CollectiveKind::AllGather => m.ag_cu_need,
            CollectiveKind::AllToAll => m.a2a_cu_need,
            CollectiveKind::AllReduce => m.ar_cu_need,
        }
    }

    /// Bytes each GPU must push over each of its links (the per-link
    /// serialization quantum). All-reduce is reduce-scatter + all-gather
    /// → two passes.
    pub fn per_link_bytes(&self, m: &MachineConfig) -> f64 {
        let shard = self.spec.size_bytes as f64 / m.num_gpus as f64;
        match self.spec.kind {
            CollectiveKind::AllGather | CollectiveKind::AllToAll => shard,
            CollectiveKind::AllReduce => 2.0 * shard,
        }
    }

    /// Total bytes each GPU sends on the wire (all links combined).
    pub fn wire_bytes_per_gpu(&self, m: &MachineConfig) -> f64 {
        self.per_link_bytes(m) * m.link_count as f64
    }

    /// HBM traffic per GPU, bytes (Fig 6's numerator).
    pub fn hbm_traffic(&self, m: &MachineConfig) -> f64 {
        let s = self.spec.size_bytes as f64;
        match self.spec.kind {
            CollectiveKind::AllGather => s * m.ag_hbm_factor,
            CollectiveKind::AllToAll => s * m.a2a_hbm_factor,
            // RS pass reads+writes, AG pass writes: ~2x payload.
            CollectiveKind::AllReduce => 2.0 * s * m.ag_hbm_factor,
        }
    }

    /// Fabric efficiency derate for this collective's traffic pattern.
    pub fn link_derate(&self, m: &MachineConfig) -> f64 {
        match self.spec.kind {
            CollectiveKind::AllGather | CollectiveKind::AllReduce => 1.0,
            CollectiveKind::AllToAll => m.a2a_link_derate,
        }
    }

    /// Fraction of full bandwidth achieved with `cu` CUs granted
    /// (Fig 5b/c: linear up to the need, flat beyond).
    pub fn bw_scale(&self, m: &MachineConfig, cu: u32) -> f64 {
        (cu as f64 / self.cu_need(m) as f64).min(1.0)
    }

    /// Pure wire time with `cu` CUs, no launch latency, seconds.
    pub fn t_wire(&self, m: &MachineConfig, cu: u32) -> f64 {
        if cu == 0 {
            return f64::INFINITY;
        }
        let bw = m.link_bw_achievable() * self.link_derate(m) * self.bw_scale(m, cu);
        self.per_link_bytes(m) / bw
    }

    /// Isolated execution time with `cu` CUs, seconds (launch + wire;
    /// HBM is never the binding resource in isolation on MI300X — the
    /// fabric is an order of magnitude slower).
    pub fn time_isolated(&self, m: &MachineConfig, cu: u32) -> f64 {
        m.coll_launch_s + self.t_wire(m, cu)
    }

    /// Isolated time at the kernel's full CU allocation.
    pub fn time_isolated_full(&self, m: &MachineConfig) -> f64 {
        self.time_isolated(m, self.cu_need(m))
    }

    /// §III: latency-bound if the launch overhead is a significant
    /// share of the total (latency doesn't shrink with size).
    pub fn is_latency_bound(&self, m: &MachineConfig) -> bool {
        let need = self.cu_need(m);
        m.coll_launch_s >= 0.3 * self.time_isolated(m, need)
    }

    /// Fraction of achievable HBM bandwidth used in isolation (Fig 6).
    pub fn llc_bw_utilization(&self, m: &MachineConfig) -> f64 {
        self.hbm_traffic(m) / self.time_isolated_full(m) / m.hbm_bw_achievable()
    }

    /// Fig 5b/c: slowdown at `cu` assigned CUs vs the kernel's need.
    pub fn slowdown_with_cus(&self, m: &MachineConfig, cu: u32) -> f64 {
        self.time_isolated(m, cu) / self.time_isolated_full(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{GIB, MIB};

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn ag(bytes: u64) -> CollectiveKernel {
        CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, bytes))
    }

    fn a2a(bytes: u64) -> CollectiveKernel {
        CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllToAll, bytes))
    }

    #[test]
    fn cu_needs_match_fig5() {
        let m = m();
        assert_eq!(ag(GIB).cu_need(&m), 32);
        assert_eq!(a2a(GIB).cu_need(&m), 64);
    }

    #[test]
    fn wire_math_fully_connected() {
        let m = m();
        let k = ag(8 * GIB);
        // Each GPU owns 1 GiB shard and pushes it to 7 peers.
        assert_eq!(k.per_link_bytes(&m), GIB as f64);
        assert_eq!(k.wire_bytes_per_gpu(&m), 7.0 * GIB as f64);
    }

    #[test]
    fn fig5bc_slowdown_shape() {
        let m = m();
        // Below the need: proportional slowdown; above: flat.
        let k = ag(896 * MIB);
        let s16 = k.slowdown_with_cus(&m, 16);
        assert!((1.8..2.2).contains(&s16), "AG at 16 CUs: {s16}");
        let s64 = k.slowdown_with_cus(&m, 64);
        assert!((s64 - 1.0).abs() < 1e-9, "AG flat beyond 32: {s64}");
        let k2 = a2a(896 * MIB);
        let s32 = k2.slowdown_with_cus(&m, 32);
        assert!((1.8..2.2).contains(&s32), "A2A at 32 CUs: {s32}");
        assert!((k2.slowdown_with_cus(&m, 128) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn a2a_slower_and_hungrier_than_ag() {
        let m = m();
        let s = 896 * MIB;
        let t_ag = ag(s).time_isolated_full(&m);
        let t_a2a = a2a(s).time_isolated_full(&m);
        assert!(t_a2a > t_ag, "A2A derated fabric: {t_a2a} vs {t_ag}");
        // Fig 6 note: AG has ~14% lower LLC bandwidth than A2A.
        let r = ag(s).llc_bw_utilization(&m) / a2a(s).llc_bw_utilization(&m);
        assert!(
            (0.80..0.92).contains(&r),
            "AG/A2A bandwidth ratio {r:.3} (paper ~0.86)"
        );
    }

    #[test]
    fn latency_vs_bandwidth_bound_regimes() {
        let m = m();
        assert!(ag(64 * 1024).is_latency_bound(&m)); // 64 KiB
        assert!(!ag(128 * MIB).is_latency_bound(&m));
        // All Table II sizes (>=128M) are bandwidth-bound (§VI-C).
        assert!(!ag(896 * MIB).is_latency_bound(&m));
    }

    #[test]
    fn allreduce_double_pass() {
        let m = m();
        let s = GIB;
        let ar = CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllReduce, s));
        assert_eq!(ar.per_link_bytes(&m), 2.0 * ag(s).per_link_bytes(&m));
        assert!(ar.time_isolated_full(&m) > ag(s).time_isolated_full(&m));
    }

    #[test]
    fn ag_896m_wire_time_plausible() {
        // (896M/8) / (64 GB/s * 0.85) ≈ 2.16 ms.
        let m = m();
        let t = ag(896 * MIB).time_isolated_full(&m);
        assert!((1.9e-3..2.4e-3).contains(&t), "t = {t}");
    }

    #[test]
    fn prop_time_monotone_in_size_and_cus() {
        use crate::util::prop::forall;
        let m = m();
        forall("collective time monotone", 80, |rng| {
            (rng.i64_in(1, 2000) as u64 * MIB / 8, rng.i64_in(1, 38) as u64 * 8)
        })
        .check(|&(sz, cu)| {
            let k = ag(sz);
            let bigger = ag(sz * 2);
            if bigger.time_isolated(&m, cu as u32) < k.time_isolated(&m, cu as u32) {
                return Err("time decreased with size".into());
            }
            let more = k.time_isolated(&m, (cu as u32) + 8);
            if more > k.time_isolated(&m, cu as u32) + 1e-12 {
                return Err("time increased with more CUs".into());
            }
            Ok(())
        });
    }
}
