//! Discrete-event fluid simulation core (machine-agnostic).
//!
//! [`fluid::Sim`] provides max-min-fair bandwidth sharing with an event
//! loop; the GPU-specific semantics (CU allocation policies, launch
//! latencies, interference penalties) are layered on top by `gpu/` and
//! `sched/`.

pub mod fluid;

pub use fluid::{
    Blocker, Event, NameId, Resource, ResourceId, Sim, SimCounters, SimError, StallError,
    StalledTask, TaskId, TaskSpec, UnboundedRateError,
};
