//! Fluid-rate discrete-event simulator.
//!
//! Concurrent GPU kernels are modelled as *fluid tasks*: each task has a
//! quantity of abstract work, a per-task rate cap (work-units/s — this is
//! where compute-unit allocation enters: the C3 executor sets the cap
//! from the kernel model's `t(cu)`), and demands on shared *bandwidth
//! resources* (HBM bytes, LLC bytes, fabric-link bytes per unit of
//! work). Between events, every resource's capacity is split among
//! active tasks by **max-min fair progressive filling**, task progress
//! integrates at piecewise-constant rates, and the next event is the
//! earliest task completion / arrival / scheduled wake.
//!
//! This is a processor-sharing fluid approximation of the real node:
//! deterministic, and accurate for the coarse-grained kernel overlap the
//! paper studies (kernels run for milliseconds; interference is a
//! bandwidth/occupancy phenomenon, not a cycle-level one).
//!
//! # Data layout (hot path)
//!
//! The simulator is the innermost loop of the planner and the sweep, so
//! per-task state is kept *data-oriented*:
//!
//! - Hot scalar fields (`remaining`, `caps`, `rates`, `arrival`) live in
//!   parallel struct-of-arrays vectors, so the max-min filling rounds
//!   and the horizon scan stream over dense `f64` lanes.
//! - Demands live in one flat CSR-style arena (`dem_off`/`dem_res`/
//!   `dem_amt`) — [`add_task`](Sim::add_task) copies a borrowed slice in,
//!   so building a task allocates nothing per task beyond the arena tail.
//! - Names are optional interned ids ([`Sim::intern`]); the event loop
//!   never touches a `String`. Stall diagnostics ([`Blocker`]) are kept
//!   as data and formatted lazily, only when an error is displayed.
//! - The event loop is *incremental* in both time and space. Pending
//!   arrivals, scheduled wakes, and projected completions live in three
//!   min-heaps, so finding the next event never scans the task set;
//!   completion entries are lazy (a per-task generation counter
//!   invalidates entries whose rates were re-solved, and stale entries
//!   are dropped on pop).
//! - Rate solving is *component-partitioned*: live tasks are grouped
//!   into resource-connected components (per-resource member lists over
//!   the demand CSR, maintained on arrival / completion / cap and
//!   demand changes), and a dirty event re-runs max-min water-filling
//!   only on its own component. Max-min fairness decomposes exactly
//!   over resource-disjoint components (the feasible region is a
//!   product), so rates elsewhere are provably unaffected; only
//!   low-order float bits can differ from a whole-set fill (the delta
//!   sequences differ), which is why `sweep/key.rs::MODEL_VERSION` was
//!   bumped when this solver landed. Each component pass sweeps its
//!   members in ascending task id, making the result a pure function of
//!   the member set — re-running a pass is bit-stable, which is what
//!   keeps checkpoint/resume bit-identical.
//! - [`Sim::counters`] exposes cheap event-loop counters (events
//!   processed, rate passes, full-active-set passes, tasks swept, max
//!   component size) so callers can assert the incrementality win.
//!
//! The simulator itself knows nothing about GPUs: CU policies, launch
//! latencies and interference penalties are applied by the caller (the
//! workload-graph engine in `sched/`) between events via
//! [`Sim::set_cap`] / [`Sim::set_demand`].
//!
//! # Example: two tasks sharing one bandwidth resource
//!
//! Two unit-work tasks each demand the full capacity of a shared
//! resource; max-min fair filling halves both rates while they overlap,
//! so the pair finishes in 2 s where either alone takes 1 s:
//!
//! ```
//! use conccl::sim::{Sim, TaskSpec};
//!
//! let mut sim = Sim::new();
//! let bw = sim.add_resource("hbm", 1.0);
//! for _ in 0..2 {
//!     sim.add_task(TaskSpec {
//!         name: None,
//!         arrival: 0.0,
//!         work: 1.0,
//!         demands: &[(bw, 1.0)],
//!         cap: f64::INFINITY,
//!     });
//! }
//! let finish = sim.run_to_completion().unwrap();
//! assert!((finish[0] - 2.0).abs() < 1e-12);
//! assert!((finish[1] - 2.0).abs() < 1e-12);
//! ```

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Index of a resource registered with [`Sim::add_resource`].
pub type ResourceId = usize;
/// Index of a task registered with [`Sim::add_task`].
pub type TaskId = usize;
/// Interned diagnostic-name id (see [`Sim::intern`]).
pub type NameId = u32;

/// Tolerance for "work is finished" / "resource is saturated" decisions.
const EPS: f64 = 1e-12;

/// A shared bandwidth resource (capacity in units/s).
#[derive(Debug, Clone)]
pub struct Resource {
    pub name: String,
    pub capacity: f64,
}

/// Specification of a fluid task.
///
/// `Copy`: the demand list is borrowed, and [`Sim::add_task`] copies it
/// into the simulator's flat demand arena — constructing and registering
/// a task performs no per-task heap allocation.
#[derive(Debug, Clone, Copy)]
pub struct TaskSpec<'d> {
    /// Optional diagnostic name, interned via [`Sim::intern`]. Tasks
    /// without one report as `task <id>` on the stall path; callers with
    /// their own node tables can attach labels lazily through
    /// [`Sim::stall_report_named`] instead.
    pub name: Option<NameId>,
    /// Simulation time at which the task becomes runnable.
    pub arrival: f64,
    /// Total abstract work (normally 1.0 = "one kernel").
    pub work: f64,
    /// `(resource, units-per-unit-work)` demands. A task moving 64 GB
    /// over HBM with work=1.0 demands `(hbm, 64e9)`. Every resource the
    /// task will ever demand must be declared here (a zero amount is
    /// fine); [`Sim::set_demand`] updates entries in place.
    pub demands: &'d [(ResourceId, f64)],
    /// Maximum progress rate in work-units/s (∞ allowed only if some
    /// demand bounds the task).
    pub cap: f64,
}

/// Why a stalled task could not make progress. Kept as structured data;
/// the human-readable string is built by `Display` only when an error is
/// actually formatted (the hot path never constructs diagnostics).
#[derive(Debug, Clone, PartialEq)]
pub enum Blocker {
    /// The task's arrival time was never reached.
    NeverArrived { arrival: f64 },
    /// The rate cap is zero: the task awaits a controller grant.
    ZeroCap,
    /// A demanded resource has (effectively) no capacity.
    EmptyResource { resource: String },
}

impl std::fmt::Display for Blocker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Blocker::NeverArrived { arrival } => {
                write!(f, "never arrived (arrival t={arrival:.3e})")
            }
            Blocker::ZeroCap => write!(f, "rate cap is zero (awaiting a controller grant)"),
            Blocker::EmptyResource { resource } => {
                write!(f, "resource '{resource}' has no capacity")
            }
        }
    }
}

/// One task that could not make progress when a simulation stalled:
/// what it is, how much work remains, and what is blocking it.
#[derive(Debug, Clone, PartialEq)]
pub struct StalledTask {
    pub task: TaskId,
    /// Diagnostic name (resolved from the interner or a caller-supplied
    /// label table when the report is built — i.e. on the error path).
    pub name: String,
    /// Remaining work fraction (1 = untouched).
    pub remaining_frac: f64,
    /// The rate cap the controller last granted.
    pub cap: f64,
    /// Structured blockers; `Display` renders them human-readable.
    pub blockers: Vec<Blocker>,
}

/// A simulation stalled: active tasks remained with zero progress rate
/// and nothing scheduled that could change that. Names every stalled
/// task, its blockers, and the simulation time — enough to diagnose a
/// bad sweep job without a debugger.
#[derive(Debug, Clone, PartialEq)]
pub struct StallError {
    /// Simulation time at which progress stopped.
    pub at: f64,
    pub stalled: Vec<StalledTask>,
}

impl std::fmt::Display for StallError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fluid simulation stalled at t={:.6e}s with {} task(s) unable to progress:",
            self.at,
            self.stalled.len()
        )?;
        for t in &self.stalled {
            write!(
                f,
                " [task {} '{}': {:.1}% remaining, cap {:.3e}, blocked by: ",
                t.task,
                t.name,
                t.remaining_frac * 100.0,
                t.cap,
            )?;
            if t.blockers.is_empty() {
                write!(f, "unknown")?;
            } else {
                for (k, b) in t.blockers.iter().enumerate() {
                    if k > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{b}")?;
                }
            }
            write!(f, "]")?;
        }
        Ok(())
    }
}

impl std::error::Error for StallError {}

/// The max-min fill diverged: some tasks have an infinite cap and no
/// positive resource demand, so no finite rate bounds them. Previously a
/// `debug_assert!` (silent garbage in release builds); now a typed error
/// that names the uncapped tasks, like [`StallError`] does.
#[derive(Debug, Clone, PartialEq)]
pub struct UnboundedRateError {
    /// Simulation time at which the divergent fill was attempted.
    pub at: f64,
    /// `(task id, diagnostic name)` of every task left with an
    /// unbounded rate (infinite cap, no positive demand).
    pub tasks: Vec<(TaskId, String)>,
}

impl std::fmt::Display for UnboundedRateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fluid rate fill diverged at t={:.6e}s: {} task(s) have an \
             unbounded rate (infinite cap and no positive resource demand):",
            self.at,
            self.tasks.len()
        )?;
        for (k, (id, name)) in self.tasks.iter().enumerate() {
            if k > 0 {
                write!(f, ",")?;
            }
            write!(f, " task {id} '{name}'")?;
        }
        write!(f, "; add a cap or a demand")
    }
}

impl std::error::Error for UnboundedRateError {}

/// Either way a driverless simulation can fail: tasks that cannot
/// progress ([`StallError`]) or tasks that nothing bounds
/// ([`UnboundedRateError`]).
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    Stall(StallError),
    Unbounded(UnboundedRateError),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Stall(e) => e.fmt(f),
            SimError::Unbounded(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for SimError {}

impl From<StallError> for SimError {
    fn from(e: StallError) -> Self {
        SimError::Stall(e)
    }
}

impl From<UnboundedRateError> for SimError {
    fn from(e: UnboundedRateError) -> Self {
        SimError::Unbounded(e)
    }
}

/// Cheap event-loop counters, maintained by [`Sim::next_event`] and the
/// rate solver. Zero-cost to read; used by `GraphRun`, `ServeReport` and
/// the `--profile` CLI flag to make the incremental core's win
/// assertable in tier-1 tests without a profiler.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimCounters {
    /// Events returned by `next_event` (arrivals, completions, wakes —
    /// not `Idle`).
    pub events: u64,
    /// Water-filling passes run (one per dirty component settled).
    pub rate_passes: u64,
    /// Passes whose component spanned the *entire* active set — what
    /// the pre-incremental solver did on every dirty event.
    pub full_passes: u64,
    /// Total tasks swept across all rate passes (`Σ` component sizes).
    pub tasks_swept: u64,
    /// Largest component any single pass swept.
    pub max_component: u32,
}

impl SimCounters {
    /// Accumulate another counter block (e.g. across the per-step graph
    /// executions of a serving run).
    pub fn absorb(&mut self, o: SimCounters) {
        self.events += o.events;
        self.rate_passes += o.rate_passes;
        self.full_passes += o.full_passes;
        self.tasks_swept += o.tasks_swept;
        self.max_component = self.max_component.max(o.max_component);
    }

    /// Full-active-set recomputes per event processed — the quantity
    /// the incremental core drives toward zero (the old solver's ratio
    /// was ~1 for every dirty event).
    pub fn full_recompute_ratio(&self) -> f64 {
        if self.events == 0 {
            0.0
        } else {
            self.full_passes as f64 / self.events as f64
        }
    }
}

/// A `(time, task, generation)` min-heap entry with a total order:
/// `f64::total_cmp` on time, then lowest task id (preserving the legacy
/// scan's tie-break exactly), then generation.
#[derive(Debug, Clone, Copy)]
struct TimedEntry {
    t: f64,
    id: TaskId,
    gen: u32,
}

impl PartialEq for TimedEntry {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == std::cmp::Ordering::Equal
    }
}
impl Eq for TimedEntry {}
impl PartialOrd for TimedEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for TimedEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&o.t)
            .then(self.id.cmp(&o.id))
            .then(self.gen.cmp(&o.gen))
    }
}

/// Totally ordered wake time (wakes carry no payload).
#[derive(Debug, Clone, Copy)]
struct OrdTime(f64);

impl PartialEq for OrdTime {
    fn eq(&self, o: &Self) -> bool {
        self.0.total_cmp(&o.0) == std::cmp::Ordering::Equal
    }
}
impl Eq for OrdTime {}
impl PartialOrd for OrdTime {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for OrdTime {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&o.0)
    }
}

/// What [`Sim::next_event`] observed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A task became runnable.
    Arrival(TaskId),
    /// A task finished its work.
    Completion(TaskId),
    /// A caller-scheduled wake point was reached.
    Wake(f64),
    /// No runnable or pending work remains.
    Idle,
}

/// The fluid simulator. See module docs for the data layout.
///
/// `Clone` is cheap-ish (a handful of flat vectors) and is what makes
/// checkpoint/resume of a simulation possible — the workload-graph
/// engine snapshots `Sim` mid-run to memoize shared timeline prefixes
/// across planner candidates.
#[derive(Debug, Clone)]
pub struct Sim {
    time: f64,
    resources: Vec<Resource>,
    // ---- per-task state (struct-of-arrays; indexed by TaskId) ----
    names: Vec<Option<NameId>>,
    arrival: Vec<f64>,
    work: Vec<f64>,
    remaining: Vec<f64>,
    caps: Vec<f64>,
    rates: Vec<f64>,
    started: Vec<Option<f64>>,
    finished: Vec<Option<f64>>,
    /// Per-task recompute generation: bumped whenever a task's rate is
    /// re-solved (or it leaves the live set), invalidating any
    /// projected-completion heap entry pushed under an older value.
    gen: Vec<u32>,
    // ---- flat CSR demand arena: task i's demands are
    //      (dem_res, dem_amt)[dem_off[i] .. dem_off[i+1]] ----
    dem_off: Vec<u32>,
    dem_res: Vec<u32>,
    dem_amt: Vec<f64>,
    /// Per demand slot: position in `res_members[dem_res[d]]` while the
    /// slot is enrolled in the solver, else `u32::MAX`. Only positive
    /// demands of *live* tasks are enrolled.
    dem_pos: Vec<u32>,
    // ---- component partition over the live set ----
    /// Per resource: `(task, demand slot)` of every enrolled demand.
    /// Two live tasks are in the same component iff connected through
    /// these lists (transitively).
    res_members: Vec<Vec<(TaskId, u32)>>,
    /// Live tasks — active with a positive cap; the only tasks the
    /// solver and the integrator ever touch. Dense list + position map.
    live: Vec<TaskId>,
    live_pos: Vec<u32>,
    /// Active (started, unfinished) task count, including zero-cap
    /// spectators; `full_passes` compares component size against this.
    active_count: usize,
    /// Seeds of components whose rates need re-solving (a stack of task
    /// ids; `dirty_flag` dedupes, and a sweep clears every member's
    /// flag so one pass settles a whole component — order is irrelevant,
    /// each component's fill is a pure function of its membership).
    dirty: Vec<TaskId>,
    dirty_flag: Vec<bool>,
    /// Live tasks whose work hit zero (via integration or a solve pass)
    /// but whose Completion event has not been emitted yet; drained, not
    /// `finished`. Completed lowest-id-first before anything else.
    drained: Vec<TaskId>,
    drained_flag: Vec<bool>,
    // ---- indexed event horizon ----
    /// Pending arrivals, keyed `(arrival, id)`.
    arrivals: BinaryHeap<Reverse<TimedEntry>>,
    /// Projected completions, keyed `(time, id)`; lazy — entries whose
    /// `gen` no longer matches (or whose task finished) drop on pop.
    completions: BinaryHeap<Reverse<TimedEntry>>,
    /// Caller-scheduled wake points.
    wakes: BinaryHeap<Reverse<OrdTime>>,
    counters: SimCounters,
    // ---- diagnostics (cold path only) ----
    name_table: Vec<String>,
    // ---- scratch buffers reused across events (no allocation) ----
    scratch_frozen: Vec<bool>,
    scratch_load: Vec<f64>,
    scratch_slack: Vec<f64>,
    scratch_touched: Vec<ResourceId>,
    /// BFS output: the component being swept (sorted ascending before
    /// the fill) and the resources it spans.
    scratch_comp: Vec<TaskId>,
    scratch_res: Vec<ResourceId>,
    /// Epoch-stamped visited marks for the BFS (no clearing needed).
    seen_task: Vec<u64>,
    seen_res: Vec<u64>,
    epoch: u64,
}

impl Sim {
    /// Empty simulator at t = 0.
    pub fn new() -> Sim {
        Sim {
            time: 0.0,
            resources: Vec::new(),
            names: Vec::new(),
            arrival: Vec::new(),
            work: Vec::new(),
            remaining: Vec::new(),
            caps: Vec::new(),
            rates: Vec::new(),
            started: Vec::new(),
            finished: Vec::new(),
            gen: Vec::new(),
            dem_off: vec![0],
            dem_res: Vec::new(),
            dem_amt: Vec::new(),
            dem_pos: Vec::new(),
            res_members: Vec::new(),
            live: Vec::new(),
            live_pos: Vec::new(),
            active_count: 0,
            dirty: Vec::new(),
            dirty_flag: Vec::new(),
            drained: Vec::new(),
            drained_flag: Vec::new(),
            arrivals: BinaryHeap::new(),
            completions: BinaryHeap::new(),
            wakes: BinaryHeap::new(),
            counters: SimCounters::default(),
            name_table: Vec::new(),
            scratch_frozen: Vec::new(),
            scratch_load: Vec::new(),
            scratch_slack: Vec::new(),
            scratch_touched: Vec::new(),
            scratch_comp: Vec::new(),
            scratch_res: Vec::new(),
            seen_task: Vec::new(),
            seen_res: Vec::new(),
            epoch: 0,
        }
    }

    /// Register a shared resource.
    pub fn add_resource(&mut self, name: &str, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.resources.push(Resource {
            name: name.to_string(),
            capacity,
        });
        self.res_members.push(Vec::new());
        self.scratch_load.push(0.0);
        self.scratch_slack.push(0.0);
        self.seen_res.push(0);
        self.resources.len() - 1
    }

    /// Intern a diagnostic name for use in [`TaskSpec::name`]. Idempotent
    /// (the same string returns the same id). Cold path: names are only
    /// ever read when a stall report is built.
    pub fn intern(&mut self, name: &str) -> NameId {
        if let Some(pos) = self.name_table.iter().position(|n| n == name) {
            return pos as NameId;
        }
        self.name_table.push(name.to_string());
        (self.name_table.len() - 1) as NameId
    }

    /// Register a task; it arrives at `spec.arrival` (may be in the past,
    /// i.e. ≤ current time, in which case it is runnable immediately).
    pub fn add_task(&mut self, spec: TaskSpec<'_>) -> TaskId {
        assert!(spec.work >= 0.0, "negative work");
        assert!(spec.cap >= 0.0, "negative cap");
        for &(rid, amt) in spec.demands {
            assert!(rid < self.resources.len(), "unknown resource {rid}");
            assert!(amt >= 0.0, "negative demand");
        }
        if let Some(n) = spec.name {
            assert!((n as usize) < self.name_table.len(), "unknown name id {n}");
        }
        let id = self.names.len();
        self.names.push(spec.name);
        self.arrival.push(spec.arrival);
        self.work.push(spec.work);
        self.remaining.push(spec.work);
        self.caps.push(spec.cap);
        self.rates.push(0.0);
        self.started.push(None);
        self.finished.push(None);
        self.gen.push(0);
        for &(rid, amt) in spec.demands {
            self.dem_res.push(rid as u32);
            self.dem_amt.push(amt);
            self.dem_pos.push(u32::MAX);
        }
        self.dem_off.push(self.dem_res.len() as u32);
        self.live_pos.push(u32::MAX);
        self.dirty_flag.push(false);
        self.drained_flag.push(false);
        self.scratch_frozen.push(false);
        self.seen_task.push(0);
        self.arrivals.push(Reverse(TimedEntry {
            t: spec.arrival,
            id,
            gen: 0,
        }));
        id
    }

    /// Number of tasks registered so far (task ids are `0..num_tasks()`).
    pub fn num_tasks(&self) -> usize {
        self.names.len()
    }

    /// Drop every task with id ≥ `keep`, as if they had never been
    /// added. Used by the graph engine to resume a cloned mid-run
    /// snapshot under a different graph suffix: the shared prefix keeps
    /// its state, the suffix is re-added. Scheduled wakes are untouched
    /// (they are the caller's to manage). Panics if any task < `keep`
    /// would be orphaned (ids are dense, so truncation is exact).
    pub fn truncate_tasks(&mut self, keep: usize) {
        assert!(keep <= self.names.len(), "truncate beyond task count");
        // Unenroll dropped live tasks first (their CSR rows must still
        // exist), seeding the surviving fragments of their components.
        // The last removal a resource sees leaves only survivors in its
        // member list, so every affected surviving component gets a
        // dirty seed; graph-resume suffixes are zero-cap spectators, so
        // that path seeds nothing and prefix rates stay bit-identical.
        for i in keep..self.names.len() {
            if self.live_pos[i] != u32::MAX {
                self.remove_live(i);
                self.unenroll(i, true);
            }
        }
        self.names.truncate(keep);
        self.arrival.truncate(keep);
        self.work.truncate(keep);
        self.remaining.truncate(keep);
        self.caps.truncate(keep);
        self.rates.truncate(keep);
        self.started.truncate(keep);
        self.finished.truncate(keep);
        self.gen.truncate(keep);
        self.live_pos.truncate(keep);
        self.dirty_flag.truncate(keep);
        self.drained_flag.truncate(keep);
        let tail = self.dem_off[keep] as usize;
        self.dem_res.truncate(tail);
        self.dem_amt.truncate(tail);
        self.dem_pos.truncate(tail);
        self.dem_off.truncate(keep + 1);
        self.scratch_frozen.truncate(keep);
        self.seen_task.truncate(keep);
        self.dirty.retain(|&i| i < keep);
        self.drained.retain(|&i| i < keep);
        // Dropped ids may sit in the two task heaps; filter and re-heap
        // (entries are totally ordered, so the rebuilt pop order is
        // deterministic regardless of internal layout).
        let mut v = std::mem::take(&mut self.arrivals).into_vec();
        v.retain(|e| e.0.id < keep);
        self.arrivals = BinaryHeap::from(v);
        let mut v = std::mem::take(&mut self.completions).into_vec();
        v.retain(|e| e.0.id < keep);
        self.completions = BinaryHeap::from(v);
        self.active_count = (0..keep)
            .filter(|&i| self.started[i].is_some() && self.finished[i].is_none())
            .count();
    }

    /// Change a task's rate cap (e.g. its CU allocation changed).
    /// No-op (and no rate recomputation) when the cap is unchanged —
    /// the graph engine calls this on every event.
    pub fn set_cap(&mut self, tid: TaskId, cap: f64) {
        assert!(cap >= 0.0);
        if self.caps[tid] == cap {
            return;
        }
        self.caps[tid] = cap;
        if self.started[tid].is_none() || self.finished[tid].is_some() {
            return; // takes effect when (if) the task activates
        }
        let was_live = self.live_pos[tid] != u32::MAX;
        let now_live = cap > EPS;
        match (was_live, now_live) {
            // A controller grant: the task joins the solver.
            (false, true) => self.make_live(tid),
            // Revoked: leave the solver, re-seed the neighbours.
            (true, false) => self.make_dead(tid),
            // A cap change only dirties the task's own component.
            (true, true) => self.mark_dirty(tid),
            (false, false) => {}
        }
    }

    /// Current rate cap of a task.
    pub fn cap(&self, tid: TaskId) -> f64 {
        self.caps[tid]
    }

    /// Update a task's demand on one resource (per unit work). The
    /// resource must have been declared in the task's [`TaskSpec`]
    /// (a zero amount there is fine); updating an undeclared resource
    /// to a non-zero demand panics, and to zero is a no-op.
    pub fn set_demand(&mut self, tid: TaskId, rid: ResourceId, per_work: f64) {
        assert!(per_work >= 0.0);
        let lo = self.dem_off[tid] as usize;
        let hi = self.dem_off[tid + 1] as usize;
        for d in lo..hi {
            if self.dem_res[d] as usize == rid {
                let old = self.dem_amt[d];
                if old == per_work {
                    return;
                }
                self.dem_amt[d] = per_work;
                if self.live_pos[tid] != u32::MAX {
                    if old <= 0.0 && per_work > 0.0 {
                        // The slot becomes a connectivity edge.
                        self.dem_pos[d] = self.res_members[rid].len() as u32;
                        self.res_members[rid].push((tid, d as u32));
                    } else if old > 0.0 && per_work == 0.0 {
                        // Dropping the edge may split the component;
                        // seeding the resource's first surviving member
                        // re-solves the detached side.
                        self.unenroll_slot(d, true);
                    }
                    self.mark_dirty(tid);
                }
                return;
            }
        }
        assert!(
            per_work == 0.0,
            "set_demand: task {tid} never declared resource {rid}; \
             declare a zero demand in its TaskSpec"
        );
    }

    /// Schedule a wake event (control point) at absolute time `t`.
    pub fn schedule_wake(&mut self, t: f64) {
        assert!(t >= self.time, "wake in the past");
        self.wakes.push(Reverse(OrdTime(t)));
    }

    /// Event-loop counters accumulated since construction (or the last
    /// [`reset_counters`](Sim::reset_counters)).
    pub fn counters(&self) -> SimCounters {
        self.counters
    }

    /// Zero the counters — e.g. when resuming from a snapshot, so a
    /// resumed run reports only its own suffix.
    pub fn reset_counters(&mut self) {
        self.counters = SimCounters::default();
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.time
    }

    /// Remaining work fraction of a task (1 = untouched, 0 = done).
    pub fn remaining_frac(&self, tid: TaskId) -> f64 {
        if self.work[tid] <= 0.0 {
            0.0
        } else {
            self.remaining[tid] / self.work[tid]
        }
    }

    /// Completion time, if the task has finished.
    pub fn finish_time(&self, tid: TaskId) -> Option<f64> {
        self.finished[tid]
    }

    /// Start (arrival-activation) time, if the task has become runnable.
    pub fn start_time(&self, tid: TaskId) -> Option<f64> {
        self.started[tid]
    }

    /// Is the task active (arrived, unfinished)?
    pub fn is_active(&self, tid: TaskId) -> bool {
        self.started[tid].is_some() && self.finished[tid].is_none()
    }

    /// Current progress rate of a task (work-units/s) under the last
    /// computed allocation.
    pub fn rate(&self, tid: TaskId) -> f64 {
        self.rates[tid]
    }

    /// Queue a component re-solve, seeded at `i` (deduped).
    fn mark_dirty(&mut self, i: TaskId) {
        if !self.dirty_flag[i] {
            self.dirty_flag[i] = true;
            self.dirty.push(i);
        }
    }

    /// Enter the live set: join the dense list, enroll every positive
    /// demand as a connectivity edge, and dirty the joined component.
    fn make_live(&mut self, i: TaskId) {
        debug_assert_eq!(self.live_pos[i], u32::MAX);
        self.live_pos[i] = self.live.len() as u32;
        self.live.push(i);
        let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
        for d in lo..hi {
            if self.dem_amt[d] > 0.0 {
                let rid = self.dem_res[d] as usize;
                self.dem_pos[d] = self.res_members[rid].len() as u32;
                self.res_members[rid].push((i, d as u32));
            }
        }
        self.mark_dirty(i);
    }

    /// Leave the live set (cap revoked) and re-seed the neighbours.
    fn make_dead(&mut self, i: TaskId) {
        self.rates[i] = 0.0;
        self.gen[i] = self.gen[i].wrapping_add(1);
        self.remove_live(i);
        self.unenroll(i, true);
    }

    fn remove_live(&mut self, i: TaskId) {
        let p = self.live_pos[i] as usize;
        self.live.swap_remove(p);
        if let Some(&moved) = self.live.get(p) {
            self.live_pos[moved] = p as u32;
        }
        self.live_pos[i] = u32::MAX;
    }

    /// Withdraw every enrolled demand slot of task `i`. With `seed`,
    /// each affected resource's first surviving member is marked dirty:
    /// a removal can split a component, and every fragment holds at
    /// least one such member, so every fragment gets re-solved.
    fn unenroll(&mut self, i: TaskId, seed: bool) {
        let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
        for d in lo..hi {
            self.unenroll_slot(d, seed);
        }
    }

    fn unenroll_slot(&mut self, d: usize, seed: bool) {
        let p = self.dem_pos[d];
        if p == u32::MAX {
            return;
        }
        self.dem_pos[d] = u32::MAX;
        let rid = self.dem_res[d] as usize;
        self.res_members[rid].swap_remove(p as usize);
        if let Some(&(_, moved_slot)) = self.res_members[rid].get(p as usize) {
            self.dem_pos[moved_slot as usize] = p;
        }
        if seed {
            if let Some(&(j, _)) = self.res_members[rid].first() {
                self.mark_dirty(j);
            }
        }
    }

    /// Mark a task finished at the current time and detach it from the
    /// solver, seeding its former component for re-solve.
    fn complete_now(&mut self, i: TaskId) {
        self.remaining[i] = 0.0;
        self.rates[i] = 0.0;
        self.finished[i] = Some(self.time);
        self.gen[i] = self.gen[i].wrapping_add(1);
        self.drained_flag[i] = false;
        self.active_count -= 1;
        if self.live_pos[i] != u32::MAX {
            self.remove_live(i);
            self.unenroll(i, true);
        }
    }

    /// Re-solve every dirty component. Normally called lazily inside
    /// [`next_event`](Sim::next_event); public so tests and oracles can
    /// force the rates current and read them.
    pub fn settle(&mut self) -> Result<(), UnboundedRateError> {
        while let Some(seed) = self.dirty.pop() {
            if !self.dirty_flag[seed] {
                continue; // already swept as part of an earlier component
            }
            self.dirty_flag[seed] = false;
            if self.live_pos[seed] == u32::MAX {
                continue; // completed or revoked since it was queued
            }
            self.sweep_component(seed)?;
        }
        Ok(())
    }

    /// BFS the resource-connected component containing `seed`, then run
    /// one max-min water-filling pass restricted to it. The member list
    /// is sorted ascending first, so the resulting rates are a pure
    /// function of (membership, caps, remaining, demands, capacities) —
    /// re-running a pass is bit-stable, which keeps snapshot resume
    /// bit-identical.
    fn sweep_component(&mut self, seed: TaskId) -> Result<(), UnboundedRateError> {
        self.epoch += 1;
        let epoch = self.epoch;
        self.scratch_comp.clear();
        self.scratch_res.clear();
        self.scratch_comp.push(seed);
        self.seen_task[seed] = epoch;
        let mut head = 0;
        while head < self.scratch_comp.len() {
            let i = self.scratch_comp[head];
            head += 1;
            let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
            for d in lo..hi {
                if self.dem_pos[d] == u32::MAX {
                    continue; // zero demand: not a connectivity edge
                }
                let rid = self.dem_res[d] as usize;
                if self.seen_res[rid] == epoch {
                    continue;
                }
                self.seen_res[rid] = epoch;
                self.scratch_res.push(rid);
                for &(j, _) in &self.res_members[rid] {
                    if self.seen_task[j] != epoch {
                        self.seen_task[j] = epoch;
                        self.scratch_comp.push(j);
                    }
                }
            }
        }
        self.scratch_comp.sort_unstable();
        let comp_len = self.scratch_comp.len();
        self.counters.rate_passes += 1;
        self.counters.tasks_swept += comp_len as u64;
        self.counters.max_component = self.counters.max_component.max(comp_len as u32);
        if comp_len == self.active_count {
            self.counters.full_passes += 1;
        }
        // Every member's previous projection is now stale, whether or
        // not the fill pushes a new one; clear their dirty flags so one
        // pass settles the whole component.
        let mut any = false;
        for &i in &self.scratch_comp {
            self.gen[i] = self.gen[i].wrapping_add(1);
            self.dirty_flag[i] = false;
            self.rates[i] = 0.0;
            // Members are live (cap > EPS), so only drained work can
            // exclude one from the fill.
            let participates = self.remaining[i] > EPS;
            self.scratch_frozen[i] = !participates;
            if !participates && !self.drained_flag[i] {
                self.drained_flag[i] = true;
                self.drained.push(i);
            }
            any |= participates;
        }
        if any {
            // Remaining slack, only for the resources this component spans.
            for &rid in &self.scratch_res {
                self.scratch_slack[rid] = self.resources[rid].capacity;
            }
            // Progressive filling: raise all unfrozen rates uniformly
            // until a cap or a resource saturates; iterate. Each round
            // either freezes a task or exhausts the unfrozen set.
            for _round in 0..(comp_len + self.scratch_res.len() + 1) {
                // Load per resource from unfrozen tasks; `scratch_touched`
                // tracks exactly the resources demanded this round so the
                // delta/saturation checks never sweep untouched resources.
                for &rid in &self.scratch_touched {
                    self.scratch_load[rid] = 0.0;
                }
                self.scratch_touched.clear();
                let mut delta = f64::INFINITY;
                let mut any_unfrozen = false;
                for &i in &self.scratch_comp {
                    if self.scratch_frozen[i] {
                        continue;
                    }
                    any_unfrozen = true;
                    delta = delta.min(self.caps[i] - self.rates[i]);
                    let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
                    for d in lo..hi {
                        let amt = self.dem_amt[d];
                        if amt <= 0.0 {
                            continue;
                        }
                        let rid = self.dem_res[d] as usize;
                        if self.scratch_load[rid] == 0.0 {
                            self.scratch_touched.push(rid);
                        }
                        self.scratch_load[rid] += amt;
                    }
                }
                if !any_unfrozen {
                    break;
                }
                for &rid in &self.scratch_touched {
                    if self.scratch_load[rid] > EPS {
                        delta = delta.min(self.scratch_slack[rid] / self.scratch_load[rid]);
                    }
                }
                if !delta.is_finite() {
                    return Err(self.unbounded_error());
                }
                let delta = delta.max(0.0);
                // Apply the uniform raise and consume slack.
                for &i in &self.scratch_comp {
                    if self.scratch_frozen[i] {
                        continue;
                    }
                    self.rates[i] += delta;
                    let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
                    for d in lo..hi {
                        self.scratch_slack[self.dem_res[d] as usize] -= self.dem_amt[d] * delta;
                    }
                }
                // Freeze tasks at cap or touching a saturated resource.
                for &i in &self.scratch_comp {
                    if self.scratch_frozen[i] {
                        continue;
                    }
                    let at_cap = self.rates[i] >= self.caps[i] - EPS * self.caps[i].max(1.0);
                    let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
                    let saturated = (lo..hi).any(|d| {
                        let rid = self.dem_res[d] as usize;
                        self.dem_amt[d] > EPS
                            && self.scratch_slack[rid] <= EPS * self.resources[rid].capacity
                    });
                    if at_cap || saturated {
                        self.scratch_frozen[i] = true;
                    }
                }
            }
        }
        // Re-project completions for the swept members only.
        for &i in &self.scratch_comp {
            if self.rates[i] > EPS && self.remaining[i] > EPS {
                self.completions.push(Reverse(TimedEntry {
                    t: self.time + self.remaining[i] / self.rates[i],
                    id: i,
                    gen: self.gen[i],
                }));
            }
        }
        Ok(())
    }

    /// Build the divergence report from the pass state left by
    /// `sweep_component` (unfrozen members are the unbounded ones).
    fn unbounded_error(&self) -> UnboundedRateError {
        let mut tasks = Vec::new();
        for &i in &self.scratch_comp {
            if !self.scratch_frozen[i] {
                let name = self.names[i]
                    .map(|n| self.name_table[n as usize].clone())
                    .unwrap_or_else(|| format!("task {i}"));
                tasks.push((i, name));
            }
        }
        UnboundedRateError {
            at: self.time,
            tasks,
        }
    }

    /// Drop completion-heap entries whose task finished or whose rates
    /// were re-solved since the entry was pushed.
    fn pop_stale_completions(&mut self) {
        while let Some(&Reverse(e)) = self.completions.peek() {
            if self.finished[e.id].is_some() || self.gen[e.id] != e.gen {
                self.completions.pop();
            } else {
                break;
            }
        }
    }

    /// Advance to the next event and return it. Between calls the caller
    /// may adjust caps/demands (they take effect immediately). Errors if
    /// a dirty component's max-min fill diverges (a task with infinite
    /// cap and no positive demand).
    pub fn next_event(&mut self) -> Result<Event, UnboundedRateError> {
        // A future arrival advances time and loops back through
        // activation — iteratively, so open-loop traffic runs do not
        // grow the stack with arrival depth.
        loop {
            // Zero-time events first: tasks whose work already drained
            // (simultaneous completions after the last integration or a
            // solve pass). Lowest id first, matching the legacy scan;
            // stale entries (already completed / re-flagged) drop here.
            if !self.drained.is_empty() {
                let mut min: Option<TaskId> = None;
                let mut k = 0;
                while k < self.drained.len() {
                    let i = self.drained[k];
                    if !self.drained_flag[i] || self.finished[i].is_some() {
                        self.drained_flag[i] = false;
                        self.drained.swap_remove(k);
                        continue;
                    }
                    if min.map_or(true, |m| i < m) {
                        min = Some(i);
                    }
                    k += 1;
                }
                if let Some(i) = min {
                    let pos = self
                        .drained
                        .iter()
                        .position(|&x| x == i)
                        .expect("drained entry");
                    self.drained.swap_remove(pos);
                    self.complete_now(i);
                    self.counters.events += 1;
                    return Ok(Event::Completion(i));
                }
            }
            // Then activate arrivals that are due *now* — the heap pops
            // the earliest `(arrival, id)`.
            if let Some(&Reverse(e)) = self.arrivals.peek() {
                if e.t <= self.time + EPS {
                    self.arrivals.pop();
                    let i = e.id;
                    self.started[i] = Some(self.time.max(self.arrival[i]));
                    self.active_count += 1;
                    self.counters.events += 1;
                    // Zero-work tasks complete instantly.
                    if self.remaining[i] <= EPS {
                        self.finished[i] = Some(self.time);
                        self.active_count -= 1;
                        return Ok(Event::Completion(i));
                    }
                    if self.caps[i] > EPS {
                        self.make_live(i);
                    }
                    return Ok(Event::Arrival(i));
                }
            }
            self.settle()?;
            // Horizon candidates: projected completions, future
            // arrivals, wakes. Task ties resolve to the lowest id (the
            // legacy scan order); a wake fires only if strictly earlier
            // than every task event.
            self.pop_stale_completions();
            let comp = self.completions.peek().map(|&Reverse(e)| (e.t, e.id));
            let arr = self.arrivals.peek().map(|&Reverse(e)| (e.t, e.id));
            let (best_t, best_task, best_is_completion) = match (comp, arr) {
                (None, None) => (f64::INFINITY, usize::MAX, false),
                (Some((t, i)), None) => (t, i, true),
                (None, Some((t, i))) => (t, i, false),
                (Some((tc, ic)), Some((ta, ia))) => {
                    if tc < ta || (tc == ta && ic < ia) {
                        (tc, ic, true)
                    } else {
                        (ta, ia, false)
                    }
                }
            };
            let mut horizon = best_t;
            let mut fire_wake = false;
            if let Some(&Reverse(OrdTime(w))) = self.wakes.peek() {
                if w < horizon {
                    horizon = w;
                    fire_wake = true;
                }
            }
            if !horizon.is_finite() {
                // Nothing can make progress. Distinguish "all done" from
                // "stalled" (live tasks with zero rate wait for the
                // caller to raise a cap — report Idle either way; the
                // caller drives).
                return Ok(Event::Idle);
            }
            // Integrate progress to the horizon (live tasks only; tasks
            // draining to zero en route queue as zero-time completions).
            let dt = horizon - self.time;
            if dt > 0.0 {
                for &i in &self.live {
                    if self.rates[i] > 0.0 {
                        let left = (self.remaining[i] - self.rates[i] * dt).max(0.0);
                        self.remaining[i] = left;
                        if left <= EPS && !self.drained_flag[i] {
                            self.drained_flag[i] = true;
                            self.drained.push(i);
                        }
                    }
                }
                self.time = horizon;
            }
            if fire_wake {
                self.wakes.pop();
                self.counters.events += 1;
                return Ok(Event::Wake(self.time));
            }
            if best_task == usize::MAX {
                return Ok(Event::Idle);
            }
            if best_is_completion {
                self.completions.pop();
                self.complete_now(best_task);
                self.counters.events += 1;
                return Ok(Event::Completion(best_task));
            }
            // Future arrival: time advanced to it; next iteration
            // activates it through the due-arrival path.
        }
    }

    /// Diagnose why unfinished tasks cannot progress right now. Used to
    /// build [`StallError`]s; empty when every task has finished. Names
    /// resolve from the interner, or to `task <id>`.
    pub fn stall_report(&self) -> Vec<StalledTask> {
        self.stall_report_named(|_| None)
    }

    /// Like [`stall_report`](Sim::stall_report), but lets the caller
    /// attach its own label per task (e.g. the graph engine's node
    /// labels); `None` falls back to the interned name / `task <id>`.
    pub fn stall_report_named<F>(&self, resolve: F) -> Vec<StalledTask>
    where
        F: Fn(TaskId) -> Option<String>,
    {
        let mut out = Vec::new();
        for i in 0..self.num_tasks() {
            if self.finished[i].is_some() {
                continue;
            }
            let mut blockers = Vec::new();
            if self.started[i].is_none() {
                blockers.push(Blocker::NeverArrived {
                    arrival: self.arrival[i],
                });
            }
            if self.caps[i] <= EPS {
                blockers.push(Blocker::ZeroCap);
            }
            let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
            for d in lo..hi {
                let rid = self.dem_res[d] as usize;
                if self.dem_amt[d] > EPS && self.resources[rid].capacity <= EPS {
                    blockers.push(Blocker::EmptyResource {
                        resource: self.resources[rid].name.clone(),
                    });
                }
            }
            let name = resolve(i)
                .or_else(|| self.names[i].map(|n| self.name_table[n as usize].clone()))
                .unwrap_or_else(|| format!("task {i}"));
            out.push(StalledTask {
                task: i,
                name,
                remaining_frac: self.remaining_frac(i),
                cap: self.caps[i],
                blockers,
            });
        }
        out
    }

    /// Drive to completion with no controller; returns per-task finish
    /// times, or a [`SimError`] naming every task that could not finish
    /// ([`StallError`]) or that nothing bounds ([`UnboundedRateError`])
    /// — so a bad job fails itself instead of aborting the whole sweep.
    pub fn run_to_completion(&mut self) -> Result<Vec<f64>, SimError> {
        loop {
            match self.next_event()? {
                Event::Idle => break,
                _ => continue,
            }
        }
        let mut fins = Vec::with_capacity(self.num_tasks());
        for i in 0..self.num_tasks() {
            match self.finished[i] {
                Some(f) => fins.push(f),
                None => {
                    return Err(SimError::Stall(StallError {
                        at: self.time,
                        stalled: self.stall_report(),
                    }))
                }
            }
        }
        Ok(fins)
    }
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_rel_close;

    fn add(
        sim: &mut Sim,
        name: &str,
        arrival: f64,
        work: f64,
        demands: &[(ResourceId, f64)],
        cap: f64,
    ) -> TaskId {
        let name = Some(sim.intern(name));
        sim.add_task(TaskSpec {
            name,
            arrival,
            work,
            demands,
            cap,
        })
    }

    #[test]
    fn single_task_cap_bound() {
        let mut sim = Sim::new();
        let _r = sim.add_resource("hbm", 100.0);
        // work 1, cap 0.5/s, demand far under capacity -> 2 s.
        let t = add(&mut sim, "a", 0.0, 1.0, &[(0, 10.0)], 0.5);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 2.0, 1e-9);
    }

    #[test]
    fn single_task_resource_bound() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        // demand 100 units/work at capacity 10/s -> rate 0.1 -> 10 s.
        let t = add(&mut sim, "a", 0.0, 1.0, &[(r, 100.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 10.0, 1e-9);
    }

    #[test]
    fn two_tasks_share_bandwidth_proportionally() {
        // Two identical tasks on one resource: each gets half.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        // Alone each would take 1 s; sharing, both take 2 s.
        assert_rel_close!(fins[a], 2.0, 1e-9);
        assert_rel_close!(fins[b], 2.0, 1e-9);
    }

    #[test]
    fn max_min_respects_caps_leaving_slack_to_others() {
        // Task a is cap-bound at 0.2 (uses 2 of 10 units/s); task b gets
        // the remaining 8 -> rate 0.8.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 0.2);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[b], 1.25, 1e-9); // 1 / 0.8
        assert_rel_close!(fins[a], 5.0, 1e-9); // cap-bound throughout
    }

    #[test]
    fn completion_frees_bandwidth_for_survivor() {
        // a: work 0.5 shared phase; after a completes, b speeds up.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 0.5, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        // Shared at rate .5 each until t=1 (a done: progress .5 each);
        // then b alone at rate 1: remaining .5 -> t=1.5.
        assert_rel_close!(fins[a], 1.0, 1e-9);
        assert_rel_close!(fins[b], 1.5, 1e-9);
    }

    #[test]
    fn late_arrival_slows_first_task() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.5, 1.0, &[(r, 10.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        // a alone until .5 (progress .5), then shared .5 rate: remaining
        // .5 at rate .5 -> a ends at 1.5. b: work 1 at .5 until a ends
        // (progress .5 at t=1.5), then alone rate 1 -> ends 2.0.
        assert_rel_close!(fins[a], 1.5, 1e-9);
        assert_rel_close!(fins[b], 2.0, 1e-9);
    }

    #[test]
    fn multi_resource_bottleneck_is_binding() {
        let mut sim = Sim::new();
        let fast = sim.add_resource("fast", 100.0);
        let slow = sim.add_resource("slow", 1.0);
        let t = add(&mut sim, "a", 0.0, 1.0, &[(fast, 10.0), (slow, 2.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        // slow allows rate 0.5; fast allows 10 -> 2 s.
        assert_rel_close!(fins[t], 2.0, 1e-9);
    }

    #[test]
    fn wake_allows_mid_flight_cap_change() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let t = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 0.25);
        sim.schedule_wake(2.0);
        // Drive manually: first event is the arrival, then the wake.
        assert_eq!(sim.next_event().unwrap(), Event::Arrival(t));
        assert_eq!(sim.next_event().unwrap(), Event::Wake(2.0));
        // Progress so far: 0.5. Raise cap; remaining 0.5 at rate 1 -> 2.5.
        sim.set_cap(t, 1e18);
        match sim.next_event().unwrap() {
            Event::Completion(tid) => assert_eq!(tid, t),
            e => panic!("expected completion, got {e:?}"),
        }
        assert_rel_close!(sim.finish_time(t).unwrap(), 2.5, 1e-9);
    }

    #[test]
    fn zero_cap_task_waits_for_controller() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 0.0);
        assert_eq!(sim.next_event().unwrap(), Event::Arrival(a));
        assert_eq!(sim.next_event().unwrap(), Event::Arrival(b));
        // b is starved (cap 0): a completes alone at t=1.
        match sim.next_event().unwrap() {
            Event::Completion(tid) => assert_eq!(tid, a),
            e => panic!("{e:?}"),
        }
        assert_rel_close!(sim.now(), 1.0, 1e-9);
        // Controller grants b a cap now.
        sim.set_cap(b, 1e18);
        match sim.next_event().unwrap() {
            Event::Completion(tid) => assert_eq!(tid, b),
            e => panic!("{e:?}"),
        }
        assert_rel_close!(sim.now(), 2.0, 1e-9);
    }

    #[test]
    fn zero_work_task_completes_at_arrival() {
        let mut sim = Sim::new();
        sim.add_resource("hbm", 1.0);
        let t = add(&mut sim, "z", 3.0, 0.0, &[], 1.0);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 3.0, 1e-9);
    }

    #[test]
    fn truncate_tasks_forgets_the_suffix_exactly() {
        // Drive a 2-task sim past the first completion, truncate the
        // second task away, re-add an identical one: the rerun must
        // finish at the same time as an untruncated clone.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let _a = add(&mut sim, "a", 0.0, 0.5, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        // a arrives, b arrives, a completes at t=1.
        sim.next_event().unwrap();
        sim.next_event().unwrap();
        match sim.next_event().unwrap() {
            Event::Completion(tid) => assert_eq!(tid, 0),
            e => panic!("{e:?}"),
        }
        let mut twin = sim.clone();
        sim.truncate_tasks(1);
        assert_eq!(sim.num_tasks(), 1);
        let b2 = add(&mut sim, "b2", 0.0, 1.0, &[(r, 10.0)], 1e18);
        assert_eq!(b2, b);
        // The re-added task restarts from full work, while the twin kept
        // b's progress: both finish times follow from first principles.
        let fins = sim.run_to_completion().unwrap();
        // b2 activates at t=1 with work 1 alone at rate 1 -> t=2.
        assert_rel_close!(fins[b2], 2.0, 1e-9);
        let twin_fins = twin.run_to_completion().unwrap();
        // twin's b had 0.5 progress at t=1 -> finishes at 1.5.
        assert_rel_close!(twin_fins[b], 1.5, 1e-9);
    }

    #[test]
    fn stalled_run_names_task_blockers_and_time() {
        // A zero-cap task with no controller stalls; the error must name
        // the task, its blocker, and the stall time.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let _a = add(&mut sim, "runs", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let _b = add(&mut sim, "starved", 0.0, 1.0, &[(r, 10.0)], 0.0);
        let err = match sim.run_to_completion() {
            Err(SimError::Stall(e)) => e,
            Ok(_) => panic!("expected a stall"),
            Err(e) => panic!("expected a stall, got {e}"),
        };
        assert_rel_close!(err.at, 1.0, 1e-9); // 'runs' finished at t=1
        assert_eq!(err.stalled.len(), 1);
        let s = &err.stalled[0];
        assert_eq!(s.name, "starved");
        assert!(s.remaining_frac > 0.99);
        assert!(s.blockers.contains(&Blocker::ZeroCap));
        let msg = err.to_string();
        assert!(msg.contains("starved") && msg.contains("stalled"), "{msg}");
        assert!(msg.contains("cap is zero"), "{msg}");
    }

    #[test]
    fn stall_report_named_prefers_caller_labels() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let _b = sim.add_task(TaskSpec {
            name: None,
            arrival: 0.0,
            work: 1.0,
            demands: &[(r, 10.0)],
            cap: 0.0,
        });
        let anon = sim.stall_report();
        assert_eq!(anon[0].name, "task 0");
        let named = sim.stall_report_named(|i| Some(format!("node:{i}")));
        assert_eq!(named[0].name, "node:0");
    }

    #[test]
    fn prop_sharing_never_exceeds_capacity() {
        use crate::util::prop::forall;
        forall("fluid rates never exceed resource capacity", 60, |rng| {
            let n = rng.i64_in(1, 6) as u64;
            let cap_r = rng.f64_in(1.0, 100.0);
            // (#tasks, resource capacity, demand scale)
            (n, cap_r, rng.f64_in(0.1, 50.0))
        })
        .check(|&(n, cap_r, dscale)| {
            let mut sim = Sim::new();
            let r = sim.add_resource("r", cap_r);
            for i in 0..n {
                sim.add_task(TaskSpec {
                    name: None,
                    arrival: 0.0,
                    work: 1.0,
                    demands: &[(r, dscale * (i + 1) as f64)],
                    cap: 1e18,
                });
            }
            for _ in 0..n {
                sim.next_event().unwrap(); // n arrival activations
            }
            sim.settle().unwrap();
            let used: f64 = (0..n as usize)
                .map(|i| sim.rate(i) * dscale * (i + 1) as f64)
                .sum();
            if used <= cap_r * (1.0 + 1e-9) {
                Ok(())
            } else {
                Err(format!("used {used} > capacity {cap_r}"))
            }
        });
    }

    #[test]
    fn prop_work_conservation() {
        // Total finish time of identical sharing tasks equals n * solo
        // time (work conservation of processor sharing).
        use crate::util::prop::forall;
        forall("work conservation", 40, |rng| rng.i64_in(1, 8) as u64).check(|&n| {
            let mut sim = Sim::new();
            let r = sim.add_resource("r", 10.0);
            for _ in 0..n {
                sim.add_task(TaskSpec {
                    name: None,
                    arrival: 0.0,
                    work: 1.0,
                    demands: &[(r, 10.0)],
                    cap: 1e18,
                });
            }
            let fins = sim.run_to_completion().unwrap();
            let max = fins.iter().cloned().fold(0.0, f64::max);
            let expect = n as f64;
            if (max - expect).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("makespan {max} vs expected {expect}"))
            }
        });
    }

    #[test]
    fn unbounded_rate_is_a_typed_error_naming_the_task() {
        // Infinite cap, no demand: nothing bounds the rate. This used to
        // be a debug_assert (silent garbage in release); now it names
        // the offender.
        let mut sim = Sim::new();
        sim.add_resource("hbm", 10.0);
        let _ = add(&mut sim, "runaway", 0.0, 1.0, &[], f64::INFINITY);
        let err = match sim.run_to_completion() {
            Err(SimError::Unbounded(e)) => e,
            Ok(_) => panic!("expected divergence"),
            Err(e) => panic!("expected divergence, got {e}"),
        };
        assert_eq!(err.tasks.len(), 1);
        assert_eq!(err.tasks[0].0, 0);
        assert_eq!(err.tasks[0].1, "runaway");
        let msg = err.to_string();
        assert!(msg.contains("runaway") && msg.contains("unbounded"), "{msg}");
    }

    #[test]
    fn disjoint_components_are_solved_separately() {
        // Two pairs on two disjoint resources: every rate pass sweeps
        // one pair, never the whole active set.
        let mut sim = Sim::new();
        let r1 = sim.add_resource("r1", 10.0);
        let r2 = sim.add_resource("r2", 10.0);
        let a1 = add(&mut sim, "a1", 0.0, 1.0, &[(r1, 10.0)], 1e18);
        let _ = add(&mut sim, "a2", 0.0, 1.0, &[(r1, 10.0)], 1e18);
        let _ = add(&mut sim, "b1", 0.0, 2.0, &[(r2, 10.0)], 1e18);
        let _ = add(&mut sim, "b2", 0.0, 2.0, &[(r2, 10.0)], 1e18);
        for _ in 0..4 {
            assert!(matches!(sim.next_event().unwrap(), Event::Arrival(_)));
        }
        sim.settle().unwrap();
        let c = sim.counters();
        assert_eq!(c.rate_passes, 2, "one pass per component");
        assert_eq!(c.tasks_swept, 4);
        assert_eq!(c.max_component, 2, "components never merge");
        assert_eq!(c.full_passes, 0, "no pass spans the active set");
        // Poking one component re-solves only it.
        sim.set_cap(a1, 0.5);
        sim.settle().unwrap();
        let c2 = sim.counters();
        assert_eq!(c2.rate_passes - c.rate_passes, 1);
        assert_eq!(c2.tasks_swept - c.tasks_swept, 2);
        assert_eq!(c2.full_passes, 0);
    }

    #[test]
    fn single_component_pass_counts_as_full() {
        let mut sim = Sim::new();
        let r = sim.add_resource("r", 10.0);
        let _ = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let _ = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        sim.next_event().unwrap();
        sim.next_event().unwrap();
        sim.settle().unwrap();
        let c = sim.counters();
        assert_eq!(c.rate_passes, 1);
        assert_eq!(c.full_passes, 1, "the pair is the whole active set");
        assert_eq!(c.max_component, 2);
    }
}
