//! Fluid-rate discrete-event simulator.
//!
//! Concurrent GPU kernels are modelled as *fluid tasks*: each task has a
//! quantity of abstract work, a per-task rate cap (work-units/s — this is
//! where compute-unit allocation enters: the C3 executor sets the cap
//! from the kernel model's `t(cu)`), and demands on shared *bandwidth
//! resources* (HBM bytes, LLC bytes, fabric-link bytes per unit of
//! work). Between events, every resource's capacity is split among
//! active tasks by **max-min fair progressive filling**, task progress
//! integrates at piecewise-constant rates, and the next event is the
//! earliest task completion / arrival / scheduled wake.
//!
//! This is a processor-sharing fluid approximation of the real node:
//! deterministic, and accurate for the coarse-grained kernel overlap the
//! paper studies (kernels run for milliseconds; interference is a
//! bandwidth/occupancy phenomenon, not a cycle-level one).
//!
//! # Data layout (hot path)
//!
//! The simulator is the innermost loop of the planner and the sweep, so
//! per-task state is kept *data-oriented*:
//!
//! - Hot scalar fields (`remaining`, `caps`, `rates`, `arrival`) live in
//!   parallel struct-of-arrays vectors, so the max-min filling rounds
//!   and the horizon scan stream over dense `f64` lanes.
//! - Demands live in one flat CSR-style arena (`dem_off`/`dem_res`/
//!   `dem_amt`) — [`add_task`](Sim::add_task) copies a borrowed slice in,
//!   so building a task allocates nothing per task beyond the arena tail.
//! - Names are optional interned ids ([`Sim::intern`]); the event loop
//!   never touches a `String`. Stall diagnostics ([`Blocker`]) are kept
//!   as data and formatted lazily, only when an error is displayed.
//! - The event loop maintains *incremental* task sets across events: a
//!   `pending` set (not yet arrived) and an `active` set (started,
//!   unfinished). Each event costs O(active + pending), not O(all
//!   tasks), and rate recomputes only stream over `active`.
//!
//! The simulator itself knows nothing about GPUs: CU policies, launch
//! latencies and interference penalties are applied by the caller (the
//! workload-graph engine in `sched/`) between events via
//! [`Sim::set_cap`] / [`Sim::set_demand`].
//!
//! # Example: two tasks sharing one bandwidth resource
//!
//! Two unit-work tasks each demand the full capacity of a shared
//! resource; max-min fair filling halves both rates while they overlap,
//! so the pair finishes in 2 s where either alone takes 1 s:
//!
//! ```
//! use conccl::sim::{Sim, TaskSpec};
//!
//! let mut sim = Sim::new();
//! let bw = sim.add_resource("hbm", 1.0);
//! for _ in 0..2 {
//!     sim.add_task(TaskSpec {
//!         name: None,
//!         arrival: 0.0,
//!         work: 1.0,
//!         demands: &[(bw, 1.0)],
//!         cap: f64::INFINITY,
//!     });
//! }
//! let finish = sim.run_to_completion().unwrap();
//! assert!((finish[0] - 2.0).abs() < 1e-12);
//! assert!((finish[1] - 2.0).abs() < 1e-12);
//! ```

/// Index of a resource registered with [`Sim::add_resource`].
pub type ResourceId = usize;
/// Index of a task registered with [`Sim::add_task`].
pub type TaskId = usize;
/// Interned diagnostic-name id (see [`Sim::intern`]).
pub type NameId = u32;

/// Tolerance for "work is finished" / "resource is saturated" decisions.
const EPS: f64 = 1e-12;

/// A shared bandwidth resource (capacity in units/s).
#[derive(Debug, Clone)]
pub struct Resource {
    pub name: String,
    pub capacity: f64,
}

/// Specification of a fluid task.
///
/// `Copy`: the demand list is borrowed, and [`Sim::add_task`] copies it
/// into the simulator's flat demand arena — constructing and registering
/// a task performs no per-task heap allocation.
#[derive(Debug, Clone, Copy)]
pub struct TaskSpec<'d> {
    /// Optional diagnostic name, interned via [`Sim::intern`]. Tasks
    /// without one report as `task <id>` on the stall path; callers with
    /// their own node tables can attach labels lazily through
    /// [`Sim::stall_report_named`] instead.
    pub name: Option<NameId>,
    /// Simulation time at which the task becomes runnable.
    pub arrival: f64,
    /// Total abstract work (normally 1.0 = "one kernel").
    pub work: f64,
    /// `(resource, units-per-unit-work)` demands. A task moving 64 GB
    /// over HBM with work=1.0 demands `(hbm, 64e9)`. Every resource the
    /// task will ever demand must be declared here (a zero amount is
    /// fine); [`Sim::set_demand`] updates entries in place.
    pub demands: &'d [(ResourceId, f64)],
    /// Maximum progress rate in work-units/s (∞ allowed only if some
    /// demand bounds the task).
    pub cap: f64,
}

/// Why a stalled task could not make progress. Kept as structured data;
/// the human-readable string is built by `Display` only when an error is
/// actually formatted (the hot path never constructs diagnostics).
#[derive(Debug, Clone, PartialEq)]
pub enum Blocker {
    /// The task's arrival time was never reached.
    NeverArrived { arrival: f64 },
    /// The rate cap is zero: the task awaits a controller grant.
    ZeroCap,
    /// A demanded resource has (effectively) no capacity.
    EmptyResource { resource: String },
}

impl std::fmt::Display for Blocker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Blocker::NeverArrived { arrival } => {
                write!(f, "never arrived (arrival t={arrival:.3e})")
            }
            Blocker::ZeroCap => write!(f, "rate cap is zero (awaiting a controller grant)"),
            Blocker::EmptyResource { resource } => {
                write!(f, "resource '{resource}' has no capacity")
            }
        }
    }
}

/// One task that could not make progress when a simulation stalled:
/// what it is, how much work remains, and what is blocking it.
#[derive(Debug, Clone, PartialEq)]
pub struct StalledTask {
    pub task: TaskId,
    /// Diagnostic name (resolved from the interner or a caller-supplied
    /// label table when the report is built — i.e. on the error path).
    pub name: String,
    /// Remaining work fraction (1 = untouched).
    pub remaining_frac: f64,
    /// The rate cap the controller last granted.
    pub cap: f64,
    /// Structured blockers; `Display` renders them human-readable.
    pub blockers: Vec<Blocker>,
}

/// A simulation stalled: active tasks remained with zero progress rate
/// and nothing scheduled that could change that. Names every stalled
/// task, its blockers, and the simulation time — enough to diagnose a
/// bad sweep job without a debugger.
#[derive(Debug, Clone, PartialEq)]
pub struct StallError {
    /// Simulation time at which progress stopped.
    pub at: f64,
    pub stalled: Vec<StalledTask>,
}

impl std::fmt::Display for StallError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fluid simulation stalled at t={:.6e}s with {} task(s) unable to progress:",
            self.at,
            self.stalled.len()
        )?;
        for t in &self.stalled {
            write!(
                f,
                " [task {} '{}': {:.1}% remaining, cap {:.3e}, blocked by: ",
                t.task,
                t.name,
                t.remaining_frac * 100.0,
                t.cap,
            )?;
            if t.blockers.is_empty() {
                write!(f, "unknown")?;
            } else {
                for (k, b) in t.blockers.iter().enumerate() {
                    if k > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{b}")?;
                }
            }
            write!(f, "]")?;
        }
        Ok(())
    }
}

impl std::error::Error for StallError {}

/// What [`Sim::next_event`] observed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A task became runnable.
    Arrival(TaskId),
    /// A task finished its work.
    Completion(TaskId),
    /// A caller-scheduled wake point was reached.
    Wake(f64),
    /// No runnable or pending work remains.
    Idle,
}

/// The fluid simulator. See module docs for the data layout.
///
/// `Clone` is cheap-ish (a handful of flat vectors) and is what makes
/// checkpoint/resume of a simulation possible — the workload-graph
/// engine snapshots `Sim` mid-run to memoize shared timeline prefixes
/// across planner candidates.
#[derive(Debug, Clone)]
pub struct Sim {
    time: f64,
    resources: Vec<Resource>,
    // ---- per-task state (struct-of-arrays; indexed by TaskId) ----
    names: Vec<Option<NameId>>,
    arrival: Vec<f64>,
    work: Vec<f64>,
    remaining: Vec<f64>,
    caps: Vec<f64>,
    rates: Vec<f64>,
    started: Vec<Option<f64>>,
    finished: Vec<Option<f64>>,
    // ---- flat CSR demand arena: task i's demands are
    //      (dem_res, dem_amt)[dem_off[i] .. dem_off[i+1]] ----
    dem_off: Vec<u32>,
    dem_res: Vec<u32>,
    dem_amt: Vec<f64>,
    // ---- incremental event-loop sets ----
    /// Tasks not yet started (unsorted; scanned, |pending| ≤ n and
    /// usually ~0 after warm-up).
    pending: Vec<TaskId>,
    /// Tasks started and unfinished (unsorted; all selections pick an
    /// explicit minimum id, so the order carries no semantics).
    active: Vec<TaskId>,
    wakes: Vec<f64>,
    rates_dirty: bool,
    // ---- diagnostics (cold path only) ----
    name_table: Vec<String>,
    // ---- scratch buffers reused across events (no allocation) ----
    scratch_frozen: Vec<bool>,
    scratch_load: Vec<f64>,
    scratch_slack: Vec<f64>,
    scratch_touched: Vec<ResourceId>,
}

impl Sim {
    /// Empty simulator at t = 0.
    pub fn new() -> Sim {
        Sim {
            time: 0.0,
            resources: Vec::new(),
            names: Vec::new(),
            arrival: Vec::new(),
            work: Vec::new(),
            remaining: Vec::new(),
            caps: Vec::new(),
            rates: Vec::new(),
            started: Vec::new(),
            finished: Vec::new(),
            dem_off: vec![0],
            dem_res: Vec::new(),
            dem_amt: Vec::new(),
            pending: Vec::new(),
            active: Vec::new(),
            wakes: Vec::new(),
            rates_dirty: true,
            name_table: Vec::new(),
            scratch_frozen: Vec::new(),
            scratch_load: Vec::new(),
            scratch_slack: Vec::new(),
            scratch_touched: Vec::new(),
        }
    }

    /// Register a shared resource.
    pub fn add_resource(&mut self, name: &str, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.resources.push(Resource {
            name: name.to_string(),
            capacity,
        });
        self.scratch_load.push(0.0);
        self.scratch_slack.push(0.0);
        self.resources.len() - 1
    }

    /// Intern a diagnostic name for use in [`TaskSpec::name`]. Idempotent
    /// (the same string returns the same id). Cold path: names are only
    /// ever read when a stall report is built.
    pub fn intern(&mut self, name: &str) -> NameId {
        if let Some(pos) = self.name_table.iter().position(|n| n == name) {
            return pos as NameId;
        }
        self.name_table.push(name.to_string());
        (self.name_table.len() - 1) as NameId
    }

    /// Register a task; it arrives at `spec.arrival` (may be in the past,
    /// i.e. ≤ current time, in which case it is runnable immediately).
    pub fn add_task(&mut self, spec: TaskSpec<'_>) -> TaskId {
        assert!(spec.work >= 0.0, "negative work");
        assert!(spec.cap >= 0.0, "negative cap");
        for &(rid, amt) in spec.demands {
            assert!(rid < self.resources.len(), "unknown resource {rid}");
            assert!(amt >= 0.0, "negative demand");
        }
        if let Some(n) = spec.name {
            assert!((n as usize) < self.name_table.len(), "unknown name id {n}");
        }
        let id = self.names.len();
        self.names.push(spec.name);
        self.arrival.push(spec.arrival);
        self.work.push(spec.work);
        self.remaining.push(spec.work);
        self.caps.push(spec.cap);
        self.rates.push(0.0);
        self.started.push(None);
        self.finished.push(None);
        for &(rid, amt) in spec.demands {
            self.dem_res.push(rid as u32);
            self.dem_amt.push(amt);
        }
        self.dem_off.push(self.dem_res.len() as u32);
        self.scratch_frozen.push(false);
        self.pending.push(id);
        self.rates_dirty = true;
        id
    }

    /// Number of tasks registered so far (task ids are `0..num_tasks()`).
    pub fn num_tasks(&self) -> usize {
        self.names.len()
    }

    /// Drop every task with id ≥ `keep`, as if they had never been
    /// added. Used by the graph engine to resume a cloned mid-run
    /// snapshot under a different graph suffix: the shared prefix keeps
    /// its state, the suffix is re-added. Scheduled wakes are untouched
    /// (they are the caller's to manage). Panics if any task < `keep`
    /// would be orphaned (ids are dense, so truncation is exact).
    pub fn truncate_tasks(&mut self, keep: usize) {
        assert!(keep <= self.names.len(), "truncate beyond task count");
        self.names.truncate(keep);
        self.arrival.truncate(keep);
        self.work.truncate(keep);
        self.remaining.truncate(keep);
        self.caps.truncate(keep);
        self.rates.truncate(keep);
        self.started.truncate(keep);
        self.finished.truncate(keep);
        let tail = self.dem_off[keep] as usize;
        self.dem_res.truncate(tail);
        self.dem_amt.truncate(tail);
        self.dem_off.truncate(keep + 1);
        self.scratch_frozen.truncate(keep);
        self.pending.retain(|&i| i < keep);
        self.active.retain(|&i| i < keep);
        self.rates_dirty = true;
    }

    /// Change a task's rate cap (e.g. its CU allocation changed).
    /// No-op (and no rate recomputation) when the cap is unchanged —
    /// the graph engine calls this on every event.
    pub fn set_cap(&mut self, tid: TaskId, cap: f64) {
        assert!(cap >= 0.0);
        if self.caps[tid] == cap {
            return;
        }
        self.caps[tid] = cap;
        self.rates_dirty = true;
    }

    /// Current rate cap of a task.
    pub fn cap(&self, tid: TaskId) -> f64 {
        self.caps[tid]
    }

    /// Update a task's demand on one resource (per unit work). The
    /// resource must have been declared in the task's [`TaskSpec`]
    /// (a zero amount there is fine); updating an undeclared resource
    /// to a non-zero demand panics, and to zero is a no-op.
    pub fn set_demand(&mut self, tid: TaskId, rid: ResourceId, per_work: f64) {
        assert!(per_work >= 0.0);
        let lo = self.dem_off[tid] as usize;
        let hi = self.dem_off[tid + 1] as usize;
        for d in lo..hi {
            if self.dem_res[d] as usize == rid {
                if self.dem_amt[d] != per_work {
                    self.dem_amt[d] = per_work;
                    self.rates_dirty = true;
                }
                return;
            }
        }
        assert!(
            per_work == 0.0,
            "set_demand: task {tid} never declared resource {rid}; \
             declare a zero demand in its TaskSpec"
        );
    }

    /// Schedule a wake event (control point) at absolute time `t`.
    pub fn schedule_wake(&mut self, t: f64) {
        assert!(t >= self.time, "wake in the past");
        self.wakes.push(t);
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.time
    }

    /// Remaining work fraction of a task (1 = untouched, 0 = done).
    pub fn remaining_frac(&self, tid: TaskId) -> f64 {
        if self.work[tid] <= 0.0 {
            0.0
        } else {
            self.remaining[tid] / self.work[tid]
        }
    }

    /// Completion time, if the task has finished.
    pub fn finish_time(&self, tid: TaskId) -> Option<f64> {
        self.finished[tid]
    }

    /// Start (arrival-activation) time, if the task has become runnable.
    pub fn start_time(&self, tid: TaskId) -> Option<f64> {
        self.started[tid]
    }

    /// Is the task active (arrived, unfinished)?
    pub fn is_active(&self, tid: TaskId) -> bool {
        self.started[tid].is_some() && self.finished[tid].is_none()
    }

    /// Current progress rate of a task (work-units/s) under the last
    /// computed allocation.
    pub fn rate(&self, tid: TaskId) -> f64 {
        self.rates[tid]
    }

    fn recompute_rates(&mut self) {
        // Max-min fair progressive filling over the active set. Rates of
        // non-active tasks are maintained at 0 by the event loop
        // (completion/truncation zero them; pending tasks start at 0).
        self.rates_dirty = false;
        let mut any = false;
        for &i in &self.active {
            self.rates[i] = 0.0;
            let participates = self.remaining[i] > EPS && self.caps[i] > EPS;
            self.scratch_frozen[i] = !participates;
            any |= participates;
        }
        if !any {
            return;
        }
        // Remaining slack per resource.
        for (r, s) in self.resources.iter().zip(self.scratch_slack.iter_mut()) {
            *s = r.capacity;
        }
        // Progressive filling: raise all unfrozen rates uniformly until a
        // cap or a resource saturates; iterate. Each round either freezes
        // a task or exhausts the unfrozen set, so the bound is loose.
        for _round in 0..(self.active.len() + self.resources.len() + 1) {
            // Load per resource from unfrozen tasks; `scratch_touched`
            // tracks exactly the resources demanded this round so the
            // delta/saturation checks never sweep untouched resources.
            for &rid in &self.scratch_touched {
                self.scratch_load[rid] = 0.0;
            }
            self.scratch_touched.clear();
            let mut delta = f64::INFINITY;
            let mut any_unfrozen = false;
            for &i in &self.active {
                if self.scratch_frozen[i] {
                    continue;
                }
                any_unfrozen = true;
                delta = delta.min(self.caps[i] - self.rates[i]);
                let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
                for d in lo..hi {
                    let amt = self.dem_amt[d];
                    if amt <= 0.0 {
                        continue;
                    }
                    let rid = self.dem_res[d] as usize;
                    if self.scratch_load[rid] == 0.0 {
                        self.scratch_touched.push(rid);
                    }
                    self.scratch_load[rid] += amt;
                }
            }
            if !any_unfrozen {
                break;
            }
            for &rid in &self.scratch_touched {
                if self.scratch_load[rid] > EPS {
                    delta = delta.min(self.scratch_slack[rid] / self.scratch_load[rid]);
                }
            }
            debug_assert!(delta.is_finite(), "unbounded task rate: add a cap");
            let delta = delta.max(0.0);
            // Apply the uniform raise and consume slack.
            for &i in &self.active {
                if self.scratch_frozen[i] {
                    continue;
                }
                self.rates[i] += delta;
                let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
                for d in lo..hi {
                    self.scratch_slack[self.dem_res[d] as usize] -= self.dem_amt[d] * delta;
                }
            }
            // Freeze tasks at cap or touching a saturated resource.
            for &i in &self.active {
                if self.scratch_frozen[i] {
                    continue;
                }
                let at_cap = self.rates[i] >= self.caps[i] - EPS * self.caps[i].max(1.0);
                let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
                let saturated = (lo..hi).any(|d| {
                    let rid = self.dem_res[d] as usize;
                    self.dem_amt[d] > EPS
                        && self.scratch_slack[rid] <= EPS * self.resources[rid].capacity
                });
                if at_cap || saturated {
                    self.scratch_frozen[i] = true;
                }
            }
        }
    }

    /// Advance to the next event and return it. Between calls the caller
    /// may adjust caps/demands (they take effect immediately).
    pub fn next_event(&mut self) -> Event {
        // Zero-time events first: tasks that already drained their work
        // (e.g. simultaneous completions after the last integration).
        // Lowest id first, matching the pre-SoA full scan.
        let mut done: Option<usize> = None;
        for (pos, &i) in self.active.iter().enumerate() {
            if self.remaining[i] <= EPS && done.is_none_or(|p| i < self.active[p]) {
                done = Some(pos);
            }
        }
        if let Some(pos) = done {
            let i = self.active.swap_remove(pos);
            self.remaining[i] = 0.0;
            self.rates[i] = 0.0;
            self.finished[i] = Some(self.time);
            self.rates_dirty = true;
            return Event::Completion(i);
        }
        // Then activate arrivals that are due *now*, lowest id first.
        let mut due: Option<usize> = None;
        for (pos, &i) in self.pending.iter().enumerate() {
            if self.arrival[i] <= self.time + EPS && due.is_none_or(|p| i < self.pending[p]) {
                due = Some(pos);
            }
        }
        if let Some(pos) = due {
            let i = self.pending.swap_remove(pos);
            self.started[i] = Some(self.time.max(self.arrival[i]));
            self.rates_dirty = true;
            // Zero-work tasks complete instantly.
            if self.remaining[i] <= EPS {
                self.finished[i] = Some(self.time);
                return Event::Completion(i);
            }
            self.active.push(i);
            return Event::Arrival(i);
        }
        if self.rates_dirty {
            self.recompute_rates();
        }
        // Horizon candidates: completions, future arrivals, wakes. Task
        // ties resolve to the lowest id (the pre-SoA scan order); a wake
        // fires only if strictly earlier than every task event.
        let mut best_t = f64::INFINITY;
        let mut best_task = usize::MAX;
        let mut best_is_completion = false;
        for &i in &self.active {
            if self.rates[i] > EPS {
                let t = self.time + self.remaining[i] / self.rates[i];
                if t < best_t || (t == best_t && i < best_task) {
                    best_t = t;
                    best_task = i;
                    best_is_completion = true;
                }
            }
        }
        for &i in &self.pending {
            let a = self.arrival[i];
            if a < best_t || (a == best_t && i < best_task) {
                best_t = a;
                best_task = i;
                best_is_completion = false;
            }
        }
        let mut horizon = best_t;
        let mut wake_pos: Option<usize> = None;
        for (pos, &w) in self.wakes.iter().enumerate() {
            if w < horizon {
                horizon = w;
                wake_pos = Some(pos);
            }
        }
        if !horizon.is_finite() {
            // Nothing can make progress. Distinguish "all done" from
            // "stalled" (active tasks with zero rate wait for the caller
            // to raise a cap — report Idle either way; the caller drives).
            return Event::Idle;
        }
        // Integrate progress to the horizon.
        let dt = horizon - self.time;
        if dt > 0.0 {
            for &i in &self.active {
                if self.rates[i] > 0.0 {
                    self.remaining[i] = (self.remaining[i] - self.rates[i] * dt).max(0.0);
                }
            }
            self.time = horizon;
        }
        if let Some(pos) = wake_pos {
            self.wakes.swap_remove(pos);
            self.rates_dirty = true;
            return Event::Wake(self.time);
        }
        if best_task != usize::MAX {
            if best_is_completion {
                let pos = self
                    .active
                    .iter()
                    .position(|&i| i == best_task)
                    .expect("completing task is active");
                self.active.swap_remove(pos);
                self.remaining[best_task] = 0.0;
                self.rates[best_task] = 0.0;
                self.finished[best_task] = Some(self.time);
                self.rates_dirty = true;
                return Event::Completion(best_task);
            }
            // Future arrival: loop back through activation at the new time.
            return self.next_event();
        }
        Event::Idle
    }

    /// Diagnose why unfinished tasks cannot progress right now. Used to
    /// build [`StallError`]s; empty when every task has finished. Names
    /// resolve from the interner, or to `task <id>`.
    pub fn stall_report(&self) -> Vec<StalledTask> {
        self.stall_report_named(|_| None)
    }

    /// Like [`stall_report`](Sim::stall_report), but lets the caller
    /// attach its own label per task (e.g. the graph engine's node
    /// labels); `None` falls back to the interned name / `task <id>`.
    pub fn stall_report_named<F>(&self, resolve: F) -> Vec<StalledTask>
    where
        F: Fn(TaskId) -> Option<String>,
    {
        let mut out = Vec::new();
        for i in 0..self.num_tasks() {
            if self.finished[i].is_some() {
                continue;
            }
            let mut blockers = Vec::new();
            if self.started[i].is_none() {
                blockers.push(Blocker::NeverArrived {
                    arrival: self.arrival[i],
                });
            }
            if self.caps[i] <= EPS {
                blockers.push(Blocker::ZeroCap);
            }
            let (lo, hi) = (self.dem_off[i] as usize, self.dem_off[i + 1] as usize);
            for d in lo..hi {
                let rid = self.dem_res[d] as usize;
                if self.dem_amt[d] > EPS && self.resources[rid].capacity <= EPS {
                    blockers.push(Blocker::EmptyResource {
                        resource: self.resources[rid].name.clone(),
                    });
                }
            }
            let name = resolve(i)
                .or_else(|| self.names[i].map(|n| self.name_table[n as usize].clone()))
                .unwrap_or_else(|| format!("task {i}"));
            out.push(StalledTask {
                task: i,
                name,
                remaining_frac: self.remaining_frac(i),
                cap: self.caps[i],
                blockers,
            });
        }
        out
    }

    /// Drive to completion with no controller; returns per-task finish
    /// times, or a [`StallError`] naming every task that could not
    /// finish (so a bad job fails itself instead of aborting the whole
    /// sweep).
    pub fn run_to_completion(&mut self) -> Result<Vec<f64>, StallError> {
        loop {
            match self.next_event() {
                Event::Idle => break,
                _ => continue,
            }
        }
        let mut fins = Vec::with_capacity(self.num_tasks());
        for i in 0..self.num_tasks() {
            match self.finished[i] {
                Some(f) => fins.push(f),
                None => {
                    return Err(StallError {
                        at: self.time,
                        stalled: self.stall_report(),
                    })
                }
            }
        }
        Ok(fins)
    }
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_rel_close;

    fn add(
        sim: &mut Sim,
        name: &str,
        arrival: f64,
        work: f64,
        demands: &[(ResourceId, f64)],
        cap: f64,
    ) -> TaskId {
        let name = Some(sim.intern(name));
        sim.add_task(TaskSpec {
            name,
            arrival,
            work,
            demands,
            cap,
        })
    }

    #[test]
    fn single_task_cap_bound() {
        let mut sim = Sim::new();
        let _r = sim.add_resource("hbm", 100.0);
        // work 1, cap 0.5/s, demand far under capacity -> 2 s.
        let t = add(&mut sim, "a", 0.0, 1.0, &[(0, 10.0)], 0.5);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 2.0, 1e-9);
    }

    #[test]
    fn single_task_resource_bound() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        // demand 100 units/work at capacity 10/s -> rate 0.1 -> 10 s.
        let t = add(&mut sim, "a", 0.0, 1.0, &[(r, 100.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 10.0, 1e-9);
    }

    #[test]
    fn two_tasks_share_bandwidth_proportionally() {
        // Two identical tasks on one resource: each gets half.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        // Alone each would take 1 s; sharing, both take 2 s.
        assert_rel_close!(fins[a], 2.0, 1e-9);
        assert_rel_close!(fins[b], 2.0, 1e-9);
    }

    #[test]
    fn max_min_respects_caps_leaving_slack_to_others() {
        // Task a is cap-bound at 0.2 (uses 2 of 10 units/s); task b gets
        // the remaining 8 -> rate 0.8.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 0.2);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[b], 1.25, 1e-9); // 1 / 0.8
        assert_rel_close!(fins[a], 5.0, 1e-9); // cap-bound throughout
    }

    #[test]
    fn completion_frees_bandwidth_for_survivor() {
        // a: work 0.5 shared phase; after a completes, b speeds up.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 0.5, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        // Shared at rate .5 each until t=1 (a done: progress .5 each);
        // then b alone at rate 1: remaining .5 -> t=1.5.
        assert_rel_close!(fins[a], 1.0, 1e-9);
        assert_rel_close!(fins[b], 1.5, 1e-9);
    }

    #[test]
    fn late_arrival_slows_first_task() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.5, 1.0, &[(r, 10.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        // a alone until .5 (progress .5), then shared .5 rate: remaining
        // .5 at rate .5 -> a ends at 1.5. b: work 1 at .5 until a ends
        // (progress .5 at t=1.5), then alone rate 1 -> ends 2.0.
        assert_rel_close!(fins[a], 1.5, 1e-9);
        assert_rel_close!(fins[b], 2.0, 1e-9);
    }

    #[test]
    fn multi_resource_bottleneck_is_binding() {
        let mut sim = Sim::new();
        let fast = sim.add_resource("fast", 100.0);
        let slow = sim.add_resource("slow", 1.0);
        let t = add(&mut sim, "a", 0.0, 1.0, &[(fast, 10.0), (slow, 2.0)], 1e18);
        let fins = sim.run_to_completion().unwrap();
        // slow allows rate 0.5; fast allows 10 -> 2 s.
        assert_rel_close!(fins[t], 2.0, 1e-9);
    }

    #[test]
    fn wake_allows_mid_flight_cap_change() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let t = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 0.25);
        sim.schedule_wake(2.0);
        // Drive manually: first event is the arrival, then the wake.
        assert_eq!(sim.next_event(), Event::Arrival(t));
        assert_eq!(sim.next_event(), Event::Wake(2.0));
        // Progress so far: 0.5. Raise cap; remaining 0.5 at rate 1 -> 2.5.
        sim.set_cap(t, 1e18);
        match sim.next_event() {
            Event::Completion(tid) => assert_eq!(tid, t),
            e => panic!("expected completion, got {e:?}"),
        }
        assert_rel_close!(sim.finish_time(t).unwrap(), 2.5, 1e-9);
    }

    #[test]
    fn zero_cap_task_waits_for_controller() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = add(&mut sim, "a", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 0.0);
        assert_eq!(sim.next_event(), Event::Arrival(a));
        assert_eq!(sim.next_event(), Event::Arrival(b));
        // b is starved (cap 0): a completes alone at t=1.
        match sim.next_event() {
            Event::Completion(tid) => assert_eq!(tid, a),
            e => panic!("{e:?}"),
        }
        assert_rel_close!(sim.now(), 1.0, 1e-9);
        // Controller grants b a cap now.
        sim.set_cap(b, 1e18);
        match sim.next_event() {
            Event::Completion(tid) => assert_eq!(tid, b),
            e => panic!("{e:?}"),
        }
        assert_rel_close!(sim.now(), 2.0, 1e-9);
    }

    #[test]
    fn zero_work_task_completes_at_arrival() {
        let mut sim = Sim::new();
        sim.add_resource("hbm", 1.0);
        let t = add(&mut sim, "z", 3.0, 0.0, &[], 1.0);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 3.0, 1e-9);
    }

    #[test]
    fn truncate_tasks_forgets_the_suffix_exactly() {
        // Drive a 2-task sim past the first completion, truncate the
        // second task away, re-add an identical one: the rerun must
        // finish at the same time as an untruncated clone.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let _a = add(&mut sim, "a", 0.0, 0.5, &[(r, 10.0)], 1e18);
        let b = add(&mut sim, "b", 0.0, 1.0, &[(r, 10.0)], 1e18);
        // a arrives, b arrives, a completes at t=1.
        sim.next_event();
        sim.next_event();
        match sim.next_event() {
            Event::Completion(tid) => assert_eq!(tid, 0),
            e => panic!("{e:?}"),
        }
        let mut twin = sim.clone();
        sim.truncate_tasks(1);
        assert_eq!(sim.num_tasks(), 1);
        let b2 = add(&mut sim, "b2", 0.0, 1.0, &[(r, 10.0)], 1e18);
        assert_eq!(b2, b);
        // The re-added task restarts from full work, while the twin kept
        // b's progress: both finish times follow from first principles.
        let fins = sim.run_to_completion().unwrap();
        // b2 activates at t=1 with work 1 alone at rate 1 -> t=2.
        assert_rel_close!(fins[b2], 2.0, 1e-9);
        let twin_fins = twin.run_to_completion().unwrap();
        // twin's b had 0.5 progress at t=1 -> finishes at 1.5.
        assert_rel_close!(twin_fins[b], 1.5, 1e-9);
    }

    #[test]
    fn stalled_run_names_task_blockers_and_time() {
        // A zero-cap task with no controller stalls; the error must name
        // the task, its blocker, and the stall time.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let _a = add(&mut sim, "runs", 0.0, 1.0, &[(r, 10.0)], 1e18);
        let _b = add(&mut sim, "starved", 0.0, 1.0, &[(r, 10.0)], 0.0);
        let err = sim.run_to_completion().unwrap_err();
        assert_rel_close!(err.at, 1.0, 1e-9); // 'runs' finished at t=1
        assert_eq!(err.stalled.len(), 1);
        let s = &err.stalled[0];
        assert_eq!(s.name, "starved");
        assert!(s.remaining_frac > 0.99);
        assert!(s.blockers.contains(&Blocker::ZeroCap));
        let msg = err.to_string();
        assert!(msg.contains("starved") && msg.contains("stalled"), "{msg}");
        assert!(msg.contains("cap is zero"), "{msg}");
    }

    #[test]
    fn stall_report_named_prefers_caller_labels() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let _b = sim.add_task(TaskSpec {
            name: None,
            arrival: 0.0,
            work: 1.0,
            demands: &[(r, 10.0)],
            cap: 0.0,
        });
        let anon = sim.stall_report();
        assert_eq!(anon[0].name, "task 0");
        let named = sim.stall_report_named(|i| Some(format!("node:{i}")));
        assert_eq!(named[0].name, "node:0");
    }

    #[test]
    fn prop_sharing_never_exceeds_capacity() {
        use crate::util::prop::forall;
        forall("fluid rates never exceed resource capacity", 60, |rng| {
            let n = rng.i64_in(1, 6) as u64;
            let cap_r = rng.f64_in(1.0, 100.0);
            // (#tasks, resource capacity, demand scale)
            (n, cap_r, rng.f64_in(0.1, 50.0))
        })
        .check(|&(n, cap_r, dscale)| {
            let mut sim = Sim::new();
            let r = sim.add_resource("r", cap_r);
            for i in 0..n {
                sim.add_task(TaskSpec {
                    name: None,
                    arrival: 0.0,
                    work: 1.0,
                    demands: &[(r, dscale * (i + 1) as f64)],
                    cap: 1e18,
                });
            }
            for _ in 0..n {
                sim.next_event(); // n arrival activations
            }
            while sim.rates_dirty {
                sim.recompute_rates();
            }
            let used: f64 = (0..n as usize)
                .map(|i| sim.rate(i) * dscale * (i + 1) as f64)
                .sum();
            if used <= cap_r * (1.0 + 1e-9) {
                Ok(())
            } else {
                Err(format!("used {used} > capacity {cap_r}"))
            }
        });
    }

    #[test]
    fn prop_work_conservation() {
        // Total finish time of identical sharing tasks equals n * solo
        // time (work conservation of processor sharing).
        use crate::util::prop::forall;
        forall("work conservation", 40, |rng| rng.i64_in(1, 8) as u64).check(|&n| {
            let mut sim = Sim::new();
            let r = sim.add_resource("r", 10.0);
            for _ in 0..n {
                sim.add_task(TaskSpec {
                    name: None,
                    arrival: 0.0,
                    work: 1.0,
                    demands: &[(r, 10.0)],
                    cap: 1e18,
                });
            }
            let fins = sim.run_to_completion().unwrap();
            let max = fins.iter().cloned().fold(0.0, f64::max);
            let expect = n as f64;
            if (max - expect).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("makespan {max} vs expected {expect}"))
            }
        });
    }
}
