//! Fluid-rate discrete-event simulator.
//!
//! Concurrent GPU kernels are modelled as *fluid tasks*: each task has a
//! quantity of abstract work, a per-task rate cap (work-units/s — this is
//! where compute-unit allocation enters: the C3 executor sets the cap
//! from the kernel model's `t(cu)`), and demands on shared *bandwidth
//! resources* (HBM bytes, LLC bytes, fabric-link bytes per unit of
//! work). Between events, every resource's capacity is split among
//! active tasks by **max-min fair progressive filling**, task progress
//! integrates at piecewise-constant rates, and the next event is the
//! earliest task completion / arrival / scheduled wake.
//!
//! This is a processor-sharing fluid approximation of the real node:
//! O((tasks + resources) · events), deterministic, and accurate for the
//! coarse-grained kernel overlap the paper studies (kernels run for
//! milliseconds; interference is a bandwidth/occupancy phenomenon, not a
//! cycle-level one).
//!
//! The simulator itself knows nothing about GPUs: CU policies, launch
//! latencies and interference penalties are applied by the caller (the
//! C3 executor in `sched/`) between events via [`Sim::set_cap`] /
//! [`Sim::set_demand`].

/// Index of a resource registered with [`Sim::add_resource`].
pub type ResourceId = usize;
/// Index of a task registered with [`Sim::add_task`].
pub type TaskId = usize;

/// Tolerance for "work is finished" / "resource is saturated" decisions.
const EPS: f64 = 1e-12;

/// A shared bandwidth resource (capacity in units/s).
#[derive(Debug, Clone)]
pub struct Resource {
    pub name: String,
    pub capacity: f64,
}

/// Specification of a fluid task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Diagnostic name.
    pub name: String,
    /// Simulation time at which the task becomes runnable.
    pub arrival: f64,
    /// Total abstract work (normally 1.0 = "one kernel").
    pub work: f64,
    /// `(resource, units-per-unit-work)` demands. A task moving 64 GB
    /// over HBM with work=1.0 demands `(hbm, 64e9)`.
    pub demands: Vec<(ResourceId, f64)>,
    /// Maximum progress rate in work-units/s (∞ allowed only if some
    /// demand bounds the task).
    pub cap: f64,
}

#[derive(Debug, Clone)]
struct TaskState {
    spec: TaskSpec,
    remaining: f64,
    cap: f64,
    rate: f64,
    started: Option<f64>,
    finished: Option<f64>,
}

/// One task that could not make progress when a simulation stalled:
/// what it is, how much work remains, and what is blocking it.
#[derive(Debug, Clone, PartialEq)]
pub struct StalledTask {
    pub task: TaskId,
    /// Diagnostic name from the task spec.
    pub name: String,
    /// Remaining work fraction (1 = untouched).
    pub remaining_frac: f64,
    /// The rate cap the controller last granted.
    pub cap: f64,
    /// Human-readable blockers: a zero cap awaiting a controller grant,
    /// or the saturated resources the task demands.
    pub blockers: Vec<String>,
}

/// A simulation stalled: active tasks remained with zero progress rate
/// and nothing scheduled that could change that. Names every stalled
/// task, its blockers, and the simulation time — enough to diagnose a
/// bad sweep job without a debugger.
#[derive(Debug, Clone, PartialEq)]
pub struct StallError {
    /// Simulation time at which progress stopped.
    pub at: f64,
    pub stalled: Vec<StalledTask>,
}

impl std::fmt::Display for StallError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fluid simulation stalled at t={:.6e}s with {} task(s) unable to progress:",
            self.at,
            self.stalled.len()
        )?;
        for t in &self.stalled {
            write!(
                f,
                " [task {} '{}': {:.1}% remaining, cap {:.3e}, blocked by: {}]",
                t.task,
                t.name,
                t.remaining_frac * 100.0,
                t.cap,
                if t.blockers.is_empty() {
                    "unknown".to_string()
                } else {
                    t.blockers.join(", ")
                }
            )?;
        }
        Ok(())
    }
}

impl std::error::Error for StallError {}

/// What [`Sim::next_event`] observed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A task became runnable.
    Arrival(TaskId),
    /// A task finished its work.
    Completion(TaskId),
    /// A caller-scheduled wake point was reached.
    Wake(f64),
    /// No runnable or pending work remains.
    Idle,
}

/// The fluid simulator. See module docs.
#[derive(Debug, Clone)]
pub struct Sim {
    time: f64,
    resources: Vec<Resource>,
    tasks: Vec<TaskState>,
    wakes: Vec<f64>,
    rates_dirty: bool,
    // Scratch buffers reused across events (hot path: no allocation).
    scratch_frozen: Vec<bool>,
    scratch_load: Vec<f64>,
    scratch_slack: Vec<f64>,
}

impl Sim {
    /// Empty simulator at t = 0.
    pub fn new() -> Sim {
        Sim {
            time: 0.0,
            resources: Vec::new(),
            tasks: Vec::new(),
            wakes: Vec::new(),
            rates_dirty: true,
            scratch_frozen: Vec::new(),
            scratch_load: Vec::new(),
            scratch_slack: Vec::new(),
        }
    }

    /// Register a shared resource.
    pub fn add_resource(&mut self, name: &str, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.resources.push(Resource {
            name: name.to_string(),
            capacity,
        });
        self.scratch_load.push(0.0);
        self.scratch_slack.push(0.0);
        self.resources.len() - 1
    }

    /// Register a task; it arrives at `spec.arrival` (may be in the past,
    /// i.e. ≤ current time, in which case it is runnable immediately).
    pub fn add_task(&mut self, spec: TaskSpec) -> TaskId {
        assert!(spec.work >= 0.0, "negative work");
        assert!(spec.cap >= 0.0, "negative cap");
        for &(rid, amt) in &spec.demands {
            assert!(rid < self.resources.len(), "unknown resource {rid}");
            assert!(amt >= 0.0, "negative demand");
        }
        let cap = spec.cap;
        let remaining = spec.work;
        self.tasks.push(TaskState {
            spec,
            remaining,
            cap,
            rate: 0.0,
            started: None,
            finished: None,
        });
        self.scratch_frozen.push(false);
        self.rates_dirty = true;
        self.tasks.len() - 1
    }

    /// Change a task's rate cap (e.g. its CU allocation changed).
    /// No-op (and no rate recomputation) when the cap is unchanged —
    /// the C3 executor calls this on every event.
    pub fn set_cap(&mut self, tid: TaskId, cap: f64) {
        assert!(cap >= 0.0);
        if self.tasks[tid].cap == cap {
            return;
        }
        self.tasks[tid].cap = cap;
        self.rates_dirty = true;
    }

    /// Current rate cap of a task.
    pub fn cap(&self, tid: TaskId) -> f64 {
        self.tasks[tid].cap
    }

    /// Replace a task's demand on one resource (per unit work).
    pub fn set_demand(&mut self, tid: TaskId, rid: ResourceId, per_work: f64) {
        assert!(per_work >= 0.0);
        let t = &mut self.tasks[tid];
        if let Some(d) = t.spec.demands.iter_mut().find(|(r, _)| *r == rid) {
            if d.1 == per_work {
                return; // unchanged: keep current rates valid
            }
            d.1 = per_work;
        } else {
            t.spec.demands.push((rid, per_work));
        }
        self.rates_dirty = true;
    }

    /// Schedule a wake event (control point) at absolute time `t`.
    pub fn schedule_wake(&mut self, t: f64) {
        assert!(t >= self.time, "wake in the past");
        self.wakes.push(t);
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.time
    }

    /// Remaining work fraction of a task (1 = untouched, 0 = done).
    pub fn remaining_frac(&self, tid: TaskId) -> f64 {
        let t = &self.tasks[tid];
        if t.spec.work <= 0.0 {
            0.0
        } else {
            t.remaining / t.spec.work
        }
    }

    /// Completion time, if the task has finished.
    pub fn finish_time(&self, tid: TaskId) -> Option<f64> {
        self.tasks[tid].finished
    }

    /// Start (arrival-activation) time, if the task has become runnable.
    pub fn start_time(&self, tid: TaskId) -> Option<f64> {
        self.tasks[tid].started
    }

    /// Is the task active (arrived, unfinished)?
    pub fn is_active(&self, tid: TaskId) -> bool {
        let t = &self.tasks[tid];
        t.started.is_some() && t.finished.is_none()
    }

    /// Current progress rate of a task (work-units/s) under the last
    /// computed allocation.
    pub fn rate(&self, tid: TaskId) -> f64 {
        self.tasks[tid].rate
    }

    fn recompute_rates(&mut self) {
        // Max-min fair progressive filling over active tasks.
        let n = self.tasks.len();
        for f in self.scratch_frozen.iter_mut() {
            *f = true;
        }
        let mut any = false;
        for i in 0..n {
            let t = &mut self.tasks[i];
            t.rate = 0.0;
            let active =
                t.finished.is_none() && t.spec.arrival <= self.time + EPS && t.remaining > EPS;
            if active && t.cap > EPS {
                self.scratch_frozen[i] = false;
                any = true;
            }
        }
        if !any {
            self.rates_dirty = false;
            return;
        }
        // Remaining slack per resource.
        for (r, s) in self.resources.iter().zip(self.scratch_slack.iter_mut()) {
            *s = r.capacity;
        }
        // Progressive filling: raise all unfrozen rates uniformly until a
        // cap or a resource saturates; iterate.
        for _round in 0..(n + self.resources.len() + 1) {
            // Load per resource from unfrozen tasks.
            for l in self.scratch_load.iter_mut() {
                *l = 0.0;
            }
            let mut delta = f64::INFINITY;
            let mut any_unfrozen = false;
            for i in 0..n {
                if self.scratch_frozen[i] {
                    continue;
                }
                any_unfrozen = true;
                let t = &self.tasks[i];
                delta = delta.min(t.cap - t.rate);
                for &(rid, amt) in &t.spec.demands {
                    self.scratch_load[rid] += amt;
                }
            }
            if !any_unfrozen {
                break;
            }
            for rid in 0..self.resources.len() {
                if self.scratch_load[rid] > EPS {
                    delta = delta.min(self.scratch_slack[rid] / self.scratch_load[rid]);
                }
            }
            debug_assert!(delta.is_finite(), "unbounded task rate: add a cap");
            let delta = delta.max(0.0);
            // Apply the uniform raise and consume slack.
            for i in 0..n {
                if self.scratch_frozen[i] {
                    continue;
                }
                self.tasks[i].rate += delta;
                for &(rid, amt) in &self.tasks[i].spec.demands {
                    self.scratch_slack[rid] -= amt * delta;
                }
            }
            // Freeze tasks at cap or touching a saturated resource.
            for i in 0..n {
                if self.scratch_frozen[i] {
                    continue;
                }
                let t = &self.tasks[i];
                let at_cap = t.rate >= t.cap - EPS * t.cap.max(1.0);
                let saturated = t
                    .spec
                    .demands
                    .iter()
                    .any(|&(rid, amt)| amt > EPS && self.scratch_slack[rid] <= EPS * self.resources[rid].capacity);
                if at_cap || saturated {
                    self.scratch_frozen[i] = true;
                }
            }
        }
        self.rates_dirty = false;
    }

    /// Advance to the next event and return it. Between calls the caller
    /// may adjust caps/demands (they take effect immediately).
    pub fn next_event(&mut self) -> Event {
        // Zero-time events first: tasks that already drained their work
        // (e.g. simultaneous completions after the last integration).
        for i in 0..self.tasks.len() {
            let t = &mut self.tasks[i];
            if t.started.is_some() && t.finished.is_none() && t.remaining <= EPS {
                t.remaining = 0.0;
                t.finished = Some(self.time);
                self.rates_dirty = true;
                return Event::Completion(i);
            }
        }
        // Then activate arrivals that are due *now*.
        for i in 0..self.tasks.len() {
            let t = &mut self.tasks[i];
            if t.started.is_none() && t.finished.is_none() && t.spec.arrival <= self.time + EPS {
                t.started = Some(self.time.max(t.spec.arrival));
                self.rates_dirty = true;
                // Zero-work tasks complete instantly.
                if t.remaining <= EPS {
                    t.finished = Some(self.time);
                    return Event::Completion(i);
                }
                return Event::Arrival(i);
            }
        }
        if self.rates_dirty {
            self.recompute_rates();
        }
        // Horizon candidates: completions, future arrivals, wakes.
        let mut horizon = f64::INFINITY;
        enum Kind {
            None,
            Completion(TaskId),
            FutureArrival,
            Wake(usize),
        }
        let mut kind = Kind::None;
        for (i, t) in self.tasks.iter().enumerate() {
            if t.finished.is_some() {
                continue;
            }
            if t.started.is_some() {
                if t.rate > EPS {
                    let dt = t.remaining / t.rate;
                    if self.time + dt < horizon {
                        horizon = self.time + dt;
                        kind = Kind::Completion(i);
                    }
                }
            } else if t.spec.arrival < horizon {
                horizon = t.spec.arrival;
                kind = Kind::FutureArrival;
            }
        }
        for (wi, &w) in self.wakes.iter().enumerate() {
            if w < horizon {
                horizon = w;
                kind = Kind::Wake(wi);
            }
        }
        if !horizon.is_finite() {
            // Nothing can make progress. Distinguish "all done" from
            // "stalled" (active tasks with zero rate wait for the caller
            // to raise a cap — report Idle either way; the caller drives).
            return Event::Idle;
        }
        // Integrate progress to the horizon.
        let dt = horizon - self.time;
        if dt > 0.0 {
            for t in self.tasks.iter_mut() {
                if t.started.is_some() && t.finished.is_none() && t.rate > 0.0 {
                    t.remaining = (t.remaining - t.rate * dt).max(0.0);
                }
            }
            self.time = horizon;
        }
        match kind {
            Kind::Completion(i) => {
                self.tasks[i].remaining = 0.0;
                self.tasks[i].finished = Some(self.time);
                self.rates_dirty = true;
                Event::Completion(i)
            }
            Kind::Wake(wi) => {
                self.wakes.swap_remove(wi);
                self.rates_dirty = true;
                Event::Wake(self.time)
            }
            Kind::FutureArrival => {
                // Loop back through arrival activation at the new time.
                self.next_event()
            }
            Kind::None => Event::Idle,
        }
    }

    /// Diagnose why unfinished tasks cannot progress right now. Used to
    /// build [`StallError`]s; empty when every task has finished.
    pub fn stall_report(&self) -> Vec<StalledTask> {
        let mut out = Vec::new();
        for (i, t) in self.tasks.iter().enumerate() {
            if t.finished.is_some() {
                continue;
            }
            let mut blockers = Vec::new();
            if t.started.is_none() {
                blockers.push(format!("never arrived (arrival t={:.3e})", t.spec.arrival));
            }
            if t.cap <= EPS {
                blockers.push("rate cap is zero (awaiting a controller grant)".to_string());
            }
            for &(rid, amt) in &t.spec.demands {
                if amt > EPS && self.resources[rid].capacity <= EPS {
                    blockers.push(format!("resource '{}' has no capacity", self.resources[rid].name));
                }
            }
            out.push(StalledTask {
                task: i,
                name: t.spec.name.clone(),
                remaining_frac: self.remaining_frac(i),
                cap: t.cap,
                blockers,
            });
        }
        out
    }

    /// Drive to completion with no controller; returns per-task finish
    /// times, or a [`StallError`] naming every task that could not
    /// finish (so a bad job fails itself instead of aborting the whole
    /// sweep).
    pub fn run_to_completion(&mut self) -> Result<Vec<f64>, StallError> {
        loop {
            match self.next_event() {
                Event::Idle => break,
                _ => continue,
            }
        }
        let mut fins = Vec::with_capacity(self.tasks.len());
        for t in &self.tasks {
            match t.finished {
                Some(f) => fins.push(f),
                None => {
                    return Err(StallError {
                        at: self.time,
                        stalled: self.stall_report(),
                    })
                }
            }
        }
        Ok(fins)
    }
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_rel_close;

    fn task(name: &str, arrival: f64, work: f64, demands: Vec<(ResourceId, f64)>, cap: f64) -> TaskSpec {
        TaskSpec {
            name: name.into(),
            arrival,
            work,
            demands,
            cap,
        }
    }

    #[test]
    fn single_task_cap_bound() {
        let mut sim = Sim::new();
        let _r = sim.add_resource("hbm", 100.0);
        // work 1, cap 0.5/s, demand far under capacity -> 2 s.
        let t = sim.add_task(task("a", 0.0, 1.0, vec![(0, 10.0)], 0.5));
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 2.0, 1e-9);
    }

    #[test]
    fn single_task_resource_bound() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        // demand 100 units/work at capacity 10/s -> rate 0.1 -> 10 s.
        let t = sim.add_task(task("a", 0.0, 1.0, vec![(r, 100.0)], f64::INFINITY.min(1e18)));
        sim.set_cap(t, 1e18);
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 10.0, 1e-9);
    }

    #[test]
    fn two_tasks_share_bandwidth_proportionally() {
        // Two identical tasks on one resource: each gets half.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = sim.add_task(task("a", 0.0, 1.0, vec![(r, 10.0)], 1e18));
        let b = sim.add_task(task("b", 0.0, 1.0, vec![(r, 10.0)], 1e18));
        let fins = sim.run_to_completion().unwrap();
        // Alone each would take 1 s; sharing, both take 2 s.
        assert_rel_close!(fins[a], 2.0, 1e-9);
        assert_rel_close!(fins[b], 2.0, 1e-9);
    }

    #[test]
    fn max_min_respects_caps_leaving_slack_to_others() {
        // Task a is cap-bound at 0.2 (uses 2 of 10 units/s); task b gets
        // the remaining 8 -> rate 0.8.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = sim.add_task(task("a", 0.0, 1.0, vec![(r, 10.0)], 0.2));
        let b = sim.add_task(task("b", 0.0, 1.0, vec![(r, 10.0)], 1e18));
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[b], 1.25, 1e-9); // 1 / 0.8
        assert_rel_close!(fins[a], 5.0, 1e-9); // cap-bound throughout
    }

    #[test]
    fn completion_frees_bandwidth_for_survivor() {
        // a: work 0.5 shared phase; after a completes, b speeds up.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = sim.add_task(task("a", 0.0, 0.5, vec![(r, 10.0)], 1e18));
        let b = sim.add_task(task("b", 0.0, 1.0, vec![(r, 10.0)], 1e18));
        let fins = sim.run_to_completion().unwrap();
        // Shared at rate .5 each until t=1 (a done: progress .5 each);
        // then b alone at rate 1: remaining .5 -> t=1.5.
        assert_rel_close!(fins[a], 1.0, 1e-9);
        assert_rel_close!(fins[b], 1.5, 1e-9);
    }

    #[test]
    fn late_arrival_slows_first_task() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = sim.add_task(task("a", 0.0, 1.0, vec![(r, 10.0)], 1e18));
        let b = sim.add_task(task("b", 0.5, 1.0, vec![(r, 10.0)], 1e18));
        let fins = sim.run_to_completion().unwrap();
        // a alone until .5 (progress .5), then shared .5 rate: remaining
        // .5 at rate .5 -> a ends at 1.5. b: work 1 at .5 until a ends
        // (progress .5 at t=1.5), then alone rate 1 -> ends 2.0.
        assert_rel_close!(fins[a], 1.5, 1e-9);
        assert_rel_close!(fins[b], 2.0, 1e-9);
    }

    #[test]
    fn multi_resource_bottleneck_is_binding() {
        let mut sim = Sim::new();
        let fast = sim.add_resource("fast", 100.0);
        let slow = sim.add_resource("slow", 1.0);
        let t = sim.add_task(task(
            "a",
            0.0,
            1.0,
            vec![(fast, 10.0), (slow, 2.0)],
            1e18,
        ));
        let fins = sim.run_to_completion().unwrap();
        // slow allows rate 0.5; fast allows 10 -> 2 s.
        assert_rel_close!(fins[t], 2.0, 1e-9);
    }

    #[test]
    fn wake_allows_mid_flight_cap_change() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let t = sim.add_task(task("a", 0.0, 1.0, vec![(r, 10.0)], 0.25));
        sim.schedule_wake(2.0);
        // Drive manually: first event is the arrival, then the wake.
        assert_eq!(sim.next_event(), Event::Arrival(t));
        assert_eq!(sim.next_event(), Event::Wake(2.0));
        // Progress so far: 0.5. Raise cap; remaining 0.5 at rate 1 -> 2.5.
        sim.set_cap(t, 1e18);
        match sim.next_event() {
            Event::Completion(tid) => assert_eq!(tid, t),
            e => panic!("expected completion, got {e:?}"),
        }
        assert_rel_close!(sim.finish_time(t).unwrap(), 2.5, 1e-9);
    }

    #[test]
    fn zero_cap_task_waits_for_controller() {
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let a = sim.add_task(task("a", 0.0, 1.0, vec![(r, 10.0)], 1e18));
        let b = sim.add_task(task("b", 0.0, 1.0, vec![(r, 10.0)], 0.0));
        assert_eq!(sim.next_event(), Event::Arrival(a));
        assert_eq!(sim.next_event(), Event::Arrival(b));
        // b is starved (cap 0): a completes alone at t=1.
        match sim.next_event() {
            Event::Completion(tid) => assert_eq!(tid, a),
            e => panic!("{e:?}"),
        }
        assert_rel_close!(sim.now(), 1.0, 1e-9);
        // Controller grants b a cap now.
        sim.set_cap(b, 1e18);
        match sim.next_event() {
            Event::Completion(tid) => assert_eq!(tid, b),
            e => panic!("{e:?}"),
        }
        assert_rel_close!(sim.now(), 2.0, 1e-9);
    }

    #[test]
    fn zero_work_task_completes_at_arrival() {
        let mut sim = Sim::new();
        sim.add_resource("hbm", 1.0);
        let t = sim.add_task(task("z", 3.0, 0.0, vec![], 1.0));
        let fins = sim.run_to_completion().unwrap();
        assert_rel_close!(fins[t], 3.0, 1e-9);
    }

    #[test]
    fn stalled_run_names_task_blockers_and_time() {
        // A zero-cap task with no controller stalls; the error must name
        // the task, its blocker, and the stall time.
        let mut sim = Sim::new();
        let r = sim.add_resource("hbm", 10.0);
        let _a = sim.add_task(task("runs", 0.0, 1.0, vec![(r, 10.0)], 1e18));
        let _b = sim.add_task(task("starved", 0.0, 1.0, vec![(r, 10.0)], 0.0));
        let err = sim.run_to_completion().unwrap_err();
        assert_rel_close!(err.at, 1.0, 1e-9); // 'runs' finished at t=1
        assert_eq!(err.stalled.len(), 1);
        let s = &err.stalled[0];
        assert_eq!(s.name, "starved");
        assert!(s.remaining_frac > 0.99);
        assert!(s.blockers.iter().any(|b| b.contains("cap is zero")));
        let msg = err.to_string();
        assert!(msg.contains("starved") && msg.contains("stalled"), "{msg}");
    }

    #[test]
    fn prop_sharing_never_exceeds_capacity() {
        use crate::util::prop::forall;
        forall("fluid rates never exceed resource capacity", 60, |rng| {
            let n = rng.i64_in(1, 6) as u64;
            let cap_r = rng.f64_in(1.0, 100.0);
            // (#tasks, resource capacity, demand scale)
            (n, cap_r, rng.f64_in(0.1, 50.0))
        })
        .check(|&(n, cap_r, dscale)| {
            let mut sim = Sim::new();
            let r = sim.add_resource("r", cap_r);
            for i in 0..n {
                sim.add_task(TaskSpec {
                    name: format!("t{i}"),
                    arrival: 0.0,
                    work: 1.0,
                    demands: vec![(r, dscale * (i + 1) as f64)],
                    cap: 1e18,
                });
            }
            sim.next_event(); // activate at least one
            while sim.rates_dirty {
                sim.recompute_rates();
            }
            let used: f64 = (0..n as usize)
                .map(|i| sim.rate(i) * dscale * (i + 1) as f64)
                .sum();
            if used <= cap_r * (1.0 + 1e-9) {
                Ok(())
            } else {
                Err(format!("used {used} > capacity {cap_r}"))
            }
        });
    }

    #[test]
    fn prop_work_conservation() {
        // Total finish time of identical sharing tasks equals n * solo
        // time (work conservation of processor sharing).
        use crate::util::prop::forall;
        forall("work conservation", 40, |rng| rng.i64_in(1, 8) as u64).check(|&n| {
            let mut sim = Sim::new();
            let r = sim.add_resource("r", 10.0);
            for i in 0..n {
                sim.add_task(TaskSpec {
                    name: format!("t{i}"),
                    arrival: 0.0,
                    work: 1.0,
                    demands: vec![(r, 10.0)],
                    cap: 1e18,
                });
            }
            let fins = sim.run_to_completion().unwrap();
            let max = fins.iter().cloned().fold(0.0, f64::max);
            let expect = n as f64;
            if (max - expect).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("makespan {max} vs expected {expect}"))
            }
        });
    }
}
