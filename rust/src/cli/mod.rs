//! Command-line interface (hand-rolled — no `clap` offline): argv
//! parsing ([`Args`]), the help text, and the per-subcommand
//! [`handlers`] the binary dispatches into.
//!
//! ```text
//! conccl <subcommand> [--set machine.key=value ...] [options]
//!   characterize   Tables I/II + Fig 5/6 (isolated-execution analysis)
//!   run            one scenario under one strategy
//!   sweep          parallel scenario sweep: {scenarios x strategies x
//!                  machines} on a worker pool, tables + JSON report
//!   dse            design-space exploration: score workloads on a grid
//!                  of hypothetical DMA-engine subsystems, report
//!                  Pareto frontiers of speedup vs engine area
//!   rp-sweep       c3_rp CU-reservation sweep for one scenario
//!   report         full Table II suite -> Fig 7/8/10 + headline
//!   conccl-bw      Fig 9: ConCCL vs RCCL isolated bandwidth sweep
//!   heuristics     §V-C heuristic vs exhaustive sweep (30 scenarios)
//!   e2e            FSDP trace replay (simulated MI300X timeline)
//!   graph          end-to-end workload graph (multi-layer FSDP/TP) on
//!                  the workload-graph engine, incl. the planner-driven
//!                  `auto` family
//!   serve          streaming inference-serving traffic engine:
//!                  open-loop arrivals into per-step decode graphs
//!                  (tp_decode / moe_dispatch / pd_disagg), steady-state
//!                  p50/p95/p99 + goodput per serving family
//! ```

pub mod handlers;

use std::collections::BTreeMap;

use crate::config::machine::MachineConfig;
use crate::config::parse::Config;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    /// `--key value` / `--flag` options.
    pub options: BTreeMap<String, String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--set machine.x=y` overrides.
    pub sets: Vec<String>,
}

impl Args {
    /// Parse an argv (excluding argv[0]).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.subcommand = it
            .next()
            .cloned()
            .ok_or("missing subcommand (try `conccl help`)")?;
        while let Some(a) = it.next() {
            if a == "--set" {
                let v = it.next().ok_or("--set needs key=value")?;
                args.sets.push(v.clone());
            } else if let Some(key) = a.strip_prefix("--") {
                // Option with a value unless followed by another flag/end.
                let val = match it.peek() {
                    Some(n) if !n.starts_with("--") => {
                        let v = (*n).clone();
                        it.next();
                        v
                    }
                    _ => "true".to_string(),
                };
                args.options.insert(key.to_string(), val);
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    /// Option lookup with default.
    pub fn opt(&self, key: &str, default: &str) -> String {
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Numeric option.
    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    /// Float option.
    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} '{v}': {e}")),
        }
    }

    /// Unsigned 64-bit option (RNG seeds).
    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} '{v}': {e}")),
        }
    }

    /// Boolean flag.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    /// Build the machine config with `--set` overrides applied.
    pub fn machine(&self) -> Result<MachineConfig, String> {
        let mut cfg = Config::default();
        if let Some(path) = self.options.get("config") {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("--config {path}: {e}"))?;
            cfg = Config::parse(&text)?;
        }
        cfg.apply_overrides(&self.sets)?;
        cfg.machine()
    }
}

/// Help text.
pub const HELP: &str = "\
conccl — reproduction of 'Optimizing ML C3 with GPU DMA Engines'

USAGE: conccl <subcommand> [options] [--set machine.key=value]...

SUBCOMMANDS
  characterize              Tables I/II, Fig 5a/5b/5c, Fig 6
  run --scenario mb1_896M --collective all-gather --strategy conccl
      [--nodes N] [--chunks auto|K]   one scenario on an N-node
                            topology; --chunks picks the chunk count of
                            the chunked pipeline strategies (auto = the
                            runtime chunk heuristic)
  sweep                     parallel scenario sweep (see SWEEP OPTIONS)
  dse                       DMA-engine design-space exploration (see
                            DSE OPTIONS)
  bench-gate --report r.json [--baseline BENCH_baseline.json]
      [--tolerance 0.02] [--strict] [--reseed OUT] [--require-exact]
                            CI perf gate: fail on median-speedup drops;
                            --strict also fails on an unseeded baseline;
                            --reseed writes the report back out as an
                            exact-provenance baseline; --require-exact
                            fails unless the baseline was reseeded from
                            a real run (provenance \"exact\")
  model-version             print the simulator-semantics salt mixed
                            into every cached job key (CI cache key)
  rp-sweep --scenario cb1_896M --collective all-to-all
  report [--jitter 0.01]    full suite: Fig 7, Fig 8, Fig 10, headline
  conccl-bw                 Fig 9 size sweep
  heuristics                SP order + RP heuristic + chunk tuner vs
                            exhaustive sweeps (30 scenarios)
  e2e [--layers 4] [--model 70b|405b] [--prefetch-depth 2]
                            FSDP trace replay + the workload-graph
                            engine's continuous-timeline comparison
  graph --workload fsdp_forward|fsdp_step|tp_chain [--model 70b|405b]
      [--layers 4] [--prefetch-depth 2] [--nodes N]
      [--family all|serial|cu|dma|auto] [--profile]
                            one end-to-end workload graph: multi-layer
                            FSDP/TP schedule on the graph engine, with
                            exposed-comm / bubble / occupancy metrics;
                            'auto' runs the per-node planner and prints
                            its backend/CUs/chunks plan table;
                            --profile adds the fluid core's event-loop
                            counter table (events, rate passes, full
                            passes, tasks swept, max component)
  serve --workload tp_decode|moe_dispatch|pd_disagg[:model[:layers[:batch]]]
      [--rate 2000] [--steps 200] [--duration 0] [--tokens 24]
      [--seed 24301] [--nodes N] [--family all|serial|cu|dma|auto]
      [--profile]
                            long-running serving simulation: open-loop
                            Poisson arrivals, continuous batching up to
                            :batch, one decode step per iteration on the
                            graph engine; reports steady-state
                            p50/p95/p99 request latency (exact sorted
                            estimator), goodput and HBM/SDMA occupancy;
                            deterministic for a fixed seed at any thread
                            count; 'auto' plans per request class
                            (latency-bound decode collectives vs the
                            DMA-offloaded KV-cache ingest stream of
                            pd_disagg); --profile adds the fluid-core
                            event-loop counter table
  help                      this text

SWEEP OPTIONS (conccl sweep)
  --scenarios all|tag,tag   Table II tags, e.g. mb1_896M,cb1_896M
  --strategies all|s,s      serial,c3_base,c3_sp,c3_rp,c3_sp_rp,
                            c3_best,conccl,conccl_rp,c3_chunked,
                            conccl_chunked
  --collective both|ag|a2a  collective kinds swept
  --nodes 1,2,4             node-count axis: re-price every point on a
                            hierarchical multi-node topology (leaders
                            exchange over the NIC; see machine.nic_bw)
  --chunks auto|1,2,4,8     chunk-count axis for the chunked pipeline
                            strategies (c3_chunked/conccl_chunked):
                            'auto' sweeps the machine's candidates per
                            scenario and keeps the best (recording the
                            winning k); numbers pin the count
  --e2e spec,spec           end-to-end workload axis, evaluated per
                            (machine, node-count) on the graph engine
                            under serial/cu_overlap/dma_overlap/auto
                            (auto = per-node planner; its winning plan
                            is printed and recorded in the JSON); spec =
                            workload[:model[:layers[:depth]]], e.g.
                            fsdp_step:70b:4:2 (JSON schema v5
                            workloads[] section, gated by bench-gate)
  --serve spec,spec         serving axis, evaluated per (machine,
                            node-count) by the traffic engine under the
                            four serving families; spec =
                            workload[:model[:layers[:batch]]], e.g.
                            pd_disagg:70b:4:16 (JSON schema v6
                            serving[] section, gated by bench-gate)
  --rate R                  serving arrival rate, req/s (default 2000)
  --serve-steps N           decode steps per serving point (default 200)
  --serve-tokens T          mean decode length in tokens (default 24)
  --variants l:k=v;k=v,...  extra machine variants derived from the base
                            machine (label:field=value;field=value)
  --threads N               worker threads (0 = one per core)
  --jitter X --seed N       measurement-protocol noise / base RNG seed
  --json PATH|-             write the machine-readable report
  --cache-dir DIR           content-addressed result cache: store every
                            simulated job keyed by its full input
                            closure (machine fields incl. sdma.*,
                            topology, spec, strategy, chunking, seeds,
                            model-version salt); a re-sweep only
                            simulates changed points, and an
                            interrupted run resumes from the records it
                            already wrote
  --shard i/n               own only the jobs whose key hashes to shard
                            i of n (0-based); skipped slots are emitted
                            as {\"skipped\":true} placeholders
  --merge d1,d2             extra read-only cache dirs (other shards'
                            --cache-dir); with every shard cached, the
                            merged run simulates nothing and emits the
                            same bytes as an unsharded run
  --require-warm            fail unless zero slots were simulated
                            (CI's proof that a merge is pure replay)

DSE OPTIONS (conccl dse)
  --engines 2,4,7,14        SDMA engine-count axis
  --queue-depths 0,8        per-engine command-queue depths (0 = legacy
                            unbounded queues)
  --fused 1,4               fused-command-packet granularities
  --nic-bw 25,50,100        NIC line-rate axis, GB/s (omit = base NIC)
  --pairs tag,tag           pairwise workloads (Table II tags) scored by
                            the ConCCL strategy's speedup
  --collective ag|a2a|...   collective kind for --pairs (default ag)
  --e2e spec,spec           e2e workloads; each scores every grid point
                            under dma_overlap AND the planner's auto
  --serve spec,spec         serving workloads (dma_overlap + auto p99
                            speedups; identical arrivals on every point)
  --rate/--serve-steps/--serve-tokens   as in sweep
  --nodes N                 topology node count (single value)
  --threads N --seed N      worker threads / arrival base seed
  --json PATH|-             write the v7 {\"dse\": ...} report with
                            per-workload Pareto frontiers
                            (default grid scores fsdp_step:70b:2:2 when
                            no workload option is given)

COMMON OPTIONS
  --config <file>           TOML-lite machine config
  --set machine.<k>=<v>     override one machine constant
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn parses_subcommand_options_positionals() {
        let a = parse("run --scenario mb1_896M --strategy conccl extra");
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.opt("scenario", ""), "mb1_896M");
        assert_eq!(a.opt("strategy", ""), "conccl");
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn parses_sets_and_flags() {
        let a = parse("report --verbose --set machine.compute_eff=0.5 --set machine.hbm_eff=0.9");
        assert!(a.flag("verbose"));
        assert_eq!(a.sets.len(), 2);
        let m = a.machine().unwrap();
        assert_eq!(m.compute_eff, 0.5);
        assert_eq!(m.hbm_eff, 0.9);
    }

    #[test]
    fn missing_subcommand_errors() {
        assert!(Args::parse(&[]).is_err());
    }

    #[test]
    fn numeric_options() {
        let a = parse("e2e --layers 7");
        assert_eq!(a.opt_usize("layers", 4).unwrap(), 7);
        assert_eq!(a.opt_usize("missing", 3).unwrap(), 3);
        let bad = parse("e2e --layers seven");
        assert!(bad.opt_usize("layers", 4).is_err());
    }

    #[test]
    fn bad_override_surfaces_error() {
        let a = parse("report --set machine.nonexistent=1");
        assert!(a.machine().is_err());
    }

    #[test]
    fn trailing_flag_takes_no_value() {
        // The option-value branch must not panic when a flag is the
        // last token (the old peek-then-unwrap shape).
        let a = parse("serve --verbose");
        assert!(a.flag("verbose"));
        let b = parse("serve --rate 10 --verbose");
        assert_eq!(b.opt("rate", ""), "10");
        assert!(b.flag("verbose"));
    }

    #[test]
    fn float_and_seed_options() {
        let a = parse("serve --rate 1500.5 --seed 42");
        assert_eq!(a.opt_f64("rate", 0.0).unwrap(), 1500.5);
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 42);
        assert_eq!(a.opt_f64("missing", 3.5).unwrap(), 3.5);
        assert_eq!(a.opt_u64("missing", 9).unwrap(), 9);
        // Malformed values surface typed errors, never panics.
        let bad = parse("serve --rate fast --seed minus-one");
        assert!(bad.opt_f64("rate", 0.0).is_err());
        assert!(bad.opt_u64("seed", 0).is_err());
    }

    #[test]
    fn missing_set_value_errors() {
        let argv: Vec<String> = vec!["run".into(), "--set".into()];
        assert!(Args::parse(&argv).is_err());
    }
}
