//! `conccl e2e` / `conccl graph`: multi-layer end-to-end schedules on
//! the workload-graph engine (FSDP trace replay, workload families,
//! the planner-driven `auto` family with its plan summary).

use crate::cli::Args;
use crate::coordinator::report;
use crate::kernels::CollectiveKernel;
use crate::sched::Strategy;
use crate::util::table::{f as fnum, speedup, Table};
use crate::util::units::fmt_seconds;
use crate::workload::e2e::{run_e2e_planned, E2eFamily, E2eSpec};
use crate::workload::llama::LlamaConfig;
use crate::workload::trace::{fsdp_forward_trace, replay};

/// Run one end-to-end workload graph (multi-layer FSDP/TP schedule) on
/// the workload-graph engine and report the e2e metrics per family
/// (plus the per-node plan table for the planner-driven family).
pub(crate) fn graph_cmd(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let nodes = args.opt_usize("nodes", 1)?.max(1);
    let depth = args.opt_usize("prefetch-depth", 2)?.max(1);
    let layers = args.opt_usize("layers", 4)?.max(1);
    let spec_str = format!(
        "{}:{}:{layers}:{depth}",
        args.opt("workload", "fsdp_step"),
        args.opt("model", "70b"),
    );
    let spec = E2eSpec::parse(&spec_str).map_err(|e| e.to_string())?;
    let topo = m.topology(nodes);
    let trace = spec.trace();
    let families: Vec<E2eFamily> = match args.opt("family", "all").as_str() {
        "all" => E2eFamily::lineup().to_vec(),
        other => vec![E2eFamily::parse(other).map_err(|e| e.to_string())?],
    };
    let mut runs = Vec::with_capacity(families.len());
    let mut plans = Vec::new();
    for fam in families {
        let (run, plan) =
            run_e2e_planned(&m, &topo, &trace, spec.depth, fam).map_err(|e| e.to_string())?;
        runs.push(run);
        if let Some(p) = plan {
            plans.push(p);
        }
    }
    report::render_graph_e2e(
        &format!(
            "workload graph: {} ({} stages, prefetch depth {depth}, {nodes} node(s))",
            spec.label(),
            trace.stages.len()
        ),
        &runs,
    )
    .print();
    if args.flag("profile") {
        let rows: Vec<(&str, crate::sim::SimCounters)> =
            runs.iter().map(|r| (r.family.name(), r.counters)).collect();
        println!();
        report::render_profile("fluid-core event-loop profile", &rows).print();
    }
    for p in &plans {
        println!();
        report::render_plan_summary(&format!("auto plan for {}", spec.label()), p).print();
    }
    Ok(())
}

pub(crate) fn e2e(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let layers = args.opt_usize("layers", 4)?;
    let model = match args.opt("model", "70b").as_str() {
        "70b" => LlamaConfig::llama70b(),
        "405b" => LlamaConfig::llama405b(),
        other => return Err(format!("unknown model '{other}'")),
    };
    let trace = fsdp_forward_trace(&model, layers);
    let mut t = Table::new(vec!["strategy", "step time", "speedup", "%ideal"]).left_cols(1).title(format!(
        "FSDP forward, {} × {layers} layers ({} C3 stages)",
        model.name,
        trace.stages.len()
    ));
    for strat in [
        Strategy::Serial,
        Strategy::C3Base,
        Strategy::C3Sp,
        Strategy::Conccl,
        Strategy::ConcclRp { cus_removed: 8 },
        // Auto-tuned chunked pipeline, per stage (chunks: 0 = auto).
        Strategy::ConcclChunked { chunks: 0 },
    ] {
        let r = replay(&m, &trace, strat);
        t.row(vec![
            strat.name().to_string(),
            fmt_seconds(r.total),
            speedup(r.speedup()),
            fnum(r.pct_ideal(), 1),
        ]);
    }
    t.print();
    // Isolated comparison of CU vs DMA collectives on this trace.
    let mut wire = Table::new(vec!["stage", "gather", "rccl", "conccl"]).left_cols(2);
    for s in trace.stages.iter().take(2) {
        let dma = crate::conccl::DmaCollective::try_new(s.gather.spec)
            .map_err(|e| e.to_string())?;
        wire.row(vec![
            s.label.clone(),
            s.gather.spec.size_tag(),
            fmt_seconds(CollectiveKernel::new(s.gather.spec).time_isolated_full(&m)),
            fmt_seconds(dma.time_isolated(&m)),
        ]);
    }
    println!();
    wire.print();
    // The workload-graph engine's continuous timeline for the same
    // forward trace: the prefetch window overlaps weight gathers across
    // stage boundaries, which the per-stage replay above only prices
    // pairwise. `conccl graph` exposes the full workload lineup; the
    // `auto` row is the per-node planner with its plan table below.
    let depth = args.opt_usize("prefetch-depth", 2)?.max(1);
    let gtrace = crate::workload::e2e::fsdp_forward_stages(&model, layers.max(1));
    let topo = m.topology(1);
    let mut runs = Vec::new();
    let mut plan = None;
    for fam in E2eFamily::lineup() {
        let (run, p) = run_e2e_planned(&m, &topo, &gtrace, depth, fam).map_err(|e| e.to_string())?;
        runs.push(run);
        plan = plan.or(p);
    }
    println!();
    report::render_graph_e2e(
        &format!("graph engine: FSDP forward × {layers} layers, prefetch depth {depth}"),
        &runs,
    )
    .print();
    if let Some(p) = &plan {
        println!();
        report::render_plan_summary("auto plan", p).print();
    }
    Ok(())
}
