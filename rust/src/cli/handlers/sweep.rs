//! `conccl sweep` / `conccl bench-gate`: the parallel scenario-sweep
//! engine and the CI perf-regression gate.

use crate::cli::Args;
use crate::config::workload::CollectiveKind;
use crate::coordinator::{headline, report, RunnerConfig};
use crate::sweep::{
    execute_with, parse_variants, Cache, ChunkSel, ExecOptions, JobSource, MachineVariant,
    SweepPlan,
};
use crate::util::table::{speedup, Table};
use crate::util::units::fmt_seconds;
use crate::workload::e2e::{E2eFamily, E2eSpec};
use crate::workload::serving::ServeSpec;
use crate::workload::traffic::TrafficConfig;

use super::{csv_list, parse_collective};

/// The parallel scenario-sweep engine: {scenarios × strategies ×
/// machine configs} evaluated concurrently, reported as tables + JSON.
pub(crate) fn sweep_cmd(args: &Args) -> Result<(), String> {
    // The pre-rename `sweep` took --scenario/--strategy (singular);
    // silently ignoring those would run a completely different
    // computation, so reject them loudly.
    if args.options.contains_key("scenario") {
        return Err(
            "`sweep` takes --scenarios (plural, comma-separated); for the single-scenario \
             CU-reservation sweep use `conccl rp-sweep --scenario ...`"
                .into(),
        );
    }
    if args.options.contains_key("strategy") {
        return Err("`sweep` takes --strategies (plural, comma-separated)".into());
    }
    let m = args.machine()?;
    let jitter: f64 = args
        .opt("jitter", "0")
        .parse()
        .map_err(|e| format!("--jitter: {e}"))?;
    let seed: u64 = args
        .opt("seed", "24301")
        .parse()
        .map_err(|e| format!("--seed: {e}"))?;
    let cfg = RunnerConfig {
        jitter,
        seed,
        ..RunnerConfig::default()
    };
    let kind_opt = args.opt("collective", "both");
    let kinds: Vec<CollectiveKind> = match kind_opt.as_str() {
        "both" | "all" => CollectiveKind::studied().to_vec(),
        other => vec![parse_collective(other)?],
    };
    let strat_opt = args.opt("strategies", "all");
    let strategy_names: Vec<&str> = csv_list(&strat_opt);
    let scen_opt = args.opt("scenarios", "all");
    let scenario_tags: Vec<&str> = csv_list(&scen_opt);
    let mut machines = vec![MachineVariant::base(m.clone())];
    if let Some(spec) = args.options.get("variants") {
        machines.extend(parse_variants(&m, spec).map_err(|e| e.to_string())?);
    }
    let threads = args.opt_usize("threads", 0)?;
    let node_counts: Vec<usize> = args
        .opt("nodes", "1")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().map_err(|e| format!("--nodes: {e}")))
        .collect::<Result<_, _>>()?;
    let chunk_counts: Vec<ChunkSel> = args
        .opt("chunks", "auto")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(ChunkSel::parse)
        .collect::<Result<_, _>>()
        .map_err(|e| format!("--chunks: {e}"))?;
    let e2e_specs: Vec<E2eSpec> = match args.options.get("e2e") {
        None => Vec::new(),
        Some(spec) => spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(E2eSpec::parse)
            .collect::<Result<_, _>>()
            .map_err(|e| format!("--e2e: {e}"))?,
    };
    let serve_specs: Vec<ServeSpec> = match args.options.get("serve") {
        None => Vec::new(),
        Some(spec) => spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(ServeSpec::parse)
            .collect::<Result<_, _>>()
            .map_err(|e| format!("--serve: {e}"))?,
    };
    let traffic = TrafficConfig {
        rate: args.opt_f64("rate", 2000.0)?,
        steps: args.opt_usize("serve-steps", 200)?,
        tokens_mean: args.opt_f64("serve-tokens", 24.0)?,
        duration: 0.0,
    };
    let plan = SweepPlan::from_selection(machines, &scenario_tags, &kinds, &strategy_names, cfg)
        .and_then(|p| p.with_node_counts(node_counts))
        .and_then(|p| p.with_chunk_counts(chunk_counts))
        .and_then(|p| p.with_e2e(e2e_specs))
        .and_then(|p| {
            if serve_specs.is_empty() {
                Ok(p)
            } else {
                p.with_serve(serve_specs, traffic)
            }
        })
        .map_err(|e| e.to_string())?;
    // Result cache + sharding: --cache-dir is the read/write store for
    // this run's job records; --merge adds read-only stores (typically
    // the cache dirs of other shards) so a merge run materializes every
    // slot without simulating; --shard i/n owns only this shard's jobs.
    let merge_dirs: Vec<std::path::PathBuf> = match args.options.get("merge") {
        None => Vec::new(),
        Some(spec) => spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(std::path::PathBuf::from)
            .collect(),
    };
    let cache = match (args.options.get("cache-dir"), merge_dirs.is_empty()) {
        (None, true) => Cache::disabled(),
        (write_dir, _) => Cache::open(write_dir.map(std::path::PathBuf::from), merge_dirs)?,
    };
    let shard = match args.options.get("shard") {
        None => None,
        Some(spec) => {
            let (i, n) = spec
                .split_once('/')
                .ok_or_else(|| format!("--shard '{spec}': expected i/n, e.g. 0/3"))?;
            let i: usize = i.parse().map_err(|e| format!("--shard '{spec}': {e}"))?;
            let n: usize = n.parse().map_err(|e| format!("--shard '{spec}': {e}"))?;
            if n == 0 || i >= n {
                return Err(format!("--shard '{spec}': need 0 <= i < n"));
            }
            Some((i, n))
        }
    };
    let opts = ExecOptions {
        threads,
        cache,
        shard,
    };
    let n_jobs = plan.job_count();
    let t0 = std::time::Instant::now();
    let results = execute_with(plan, &opts);
    let elapsed = t0.elapsed().as_secs_f64();

    for (mi, mv) in results.plan.machines.iter().enumerate() {
        for (ni, &nodes) in results.plan.node_counts.iter().enumerate() {
            for (ci, &chunks) in results.plan.chunk_counts.iter().enumerate() {
                let mut headers: Vec<String> =
                    vec!["scenario".to_string(), "collective".to_string()];
                headers.extend(results.plan.strategies.iter().map(|k| k.name().to_string()));
                let mut t = Table::new(headers).left_cols(2).title(format!(
                    "sweep: machine '{}' × {nodes} node(s) × chunks={} — median-speedup per strategy",
                    mv.label,
                    chunks.label()
                ));
                for (si, sc) in results.plan.scenarios.iter().enumerate() {
                    let mut row = vec![sc.tag(), sc.comm.spec.kind.name().to_string()];
                    for (ki, _) in results.plan.strategies.iter().enumerate() {
                        let out = &results.outputs[results.plan.job_id(mi, ni, ci, si, ki)];
                        if out.source == JobSource::Skipped {
                            row.push("—".to_string());
                            continue;
                        }
                        row.push(match &out.result {
                            Ok(meas) => match (out.rp_cus, out.chunks_used) {
                                (Some(k), _) => format!("{} @{k}CU", speedup(meas.speedup_median)),
                                (None, Some(k)) => {
                                    format!("{} @{k}ch", speedup(meas.speedup_median))
                                }
                                (None, None) => speedup(meas.speedup_median),
                            },
                            Err(_) => "ERR".to_string(),
                        });
                    }
                    t.row(row);
                }
                t.print();
                if let Ok(outs) = results.to_scenario_outcomes(mi, ni, ci) {
                    let h = headline(&outs);
                    let p = |k: &str| h.per_strategy[k].1;
                    println!(
                        "machine '{}' × {nodes} node(s) × chunks={}: avg %ideal — base {:.0}, \
                         sp {:.0}, rp {:.0}, best {:.0}, conccl {:.0}, conccl_rp {:.0}",
                        mv.label,
                        chunks.label(),
                        p("c3_base"),
                        p("c3_sp"),
                        p("c3_rp"),
                        p("c3_best"),
                        p("conccl"),
                        p("conccl_rp")
                    );
                }
                println!();
            }
            // End-to-end workload axis (graph engine): one table per
            // spec on this (machine, topology) point, plus the planner
            // family's per-node plan summary.
            for (si, spec) in results.plan.e2e.iter().enumerate() {
                let point = results.e2e_point(mi, ni, si);
                let runs: Vec<_> = point
                    .iter()
                    .filter_map(|o| o.result.as_ref().ok().copied())
                    .collect();
                report::render_graph_e2e(
                    &format!(
                        "e2e workload '{}': machine '{}' × {nodes} node(s)",
                        spec.label(),
                        mv.label
                    ),
                    &runs,
                )
                .print();
                for o in &point {
                    if let (E2eFamily::Auto, Some(plan)) = (o.family, &o.plan) {
                        report::render_plan_summary(&format!("auto plan '{}'", spec.label()), plan)
                            .print();
                    }
                }
                println!();
            }
            // Serving traffic axis: one steady-state table per spec on
            // this (machine, topology) point.
            for (si, spec) in results.plan.serve.iter().enumerate() {
                let point = results.serve_point(mi, ni, si);
                let runs: Vec<_> = point
                    .iter()
                    .filter_map(|o| o.result.as_ref().ok().copied())
                    .collect();
                report::render_serve(
                    &format!(
                        "serving '{}': machine '{}' × {nodes} node(s)",
                        spec.label(),
                        mv.label
                    ),
                    &runs,
                )
                .print();
                println!();
            }
        }
    }
    let errs = results.errors();
    if !errs.is_empty() {
        println!("{} job(s) failed (sweep continued without them):", errs.len());
        for (job, e) in &errs {
            println!(
                "  job {} [{} × {}n × {}ch × {} × {}]: {e}",
                job.id,
                results.machine_label(job.machine_idx),
                results.plan.node_counts[job.node_idx],
                results.plan.chunk_counts[job.chunk_idx].label(),
                results.plan.scenarios[job.scenario_idx].tag(),
                job.strategy.name()
            );
        }
    }
    // Failed e2e workload points are dropped from their tables above —
    // name them here so a non-JSON run cannot mistake a missing row
    // for success (the JSON carries the {"error": ...} object).
    let e2e_errs: Vec<&crate::sweep::E2eOutput> = results
        .e2e_outputs
        .iter()
        .filter(|o| o.source != JobSource::Skipped && o.result.is_err())
        .collect();
    if !e2e_errs.is_empty() {
        println!("{} e2e workload point(s) failed:", e2e_errs.len());
        for o in &e2e_errs {
            println!(
                "  [{} × {}n × {} × {}]: {}",
                results.machine_label(o.machine_idx),
                results.plan.node_counts[o.node_idx],
                results.plan.e2e[o.spec_idx].label(),
                o.family.name(),
                o.result.as_ref().unwrap_err()
            );
        }
    }
    // Same for failed serving points.
    let serve_errs: Vec<&crate::sweep::ServeOutput> = results
        .serve_outputs
        .iter()
        .filter(|o| o.source != JobSource::Skipped && o.result.is_err())
        .collect();
    if !serve_errs.is_empty() {
        println!("{} serving point(s) failed:", serve_errs.len());
        for o in &serve_errs {
            println!(
                "  [{} × {}n × {} × {}]: {}",
                results.machine_label(o.machine_idx),
                results.plan.node_counts[o.node_idx],
                results.plan.serve[o.spec_idx].label(),
                o.family.name(),
                o.result.as_ref().unwrap_err()
            );
        }
    }
    println!(
        "{n_jobs} jobs on {} worker thread(s) in {}",
        results.threads_used,
        fmt_seconds(elapsed)
    );
    if opts.cache.enabled() || opts.shard.is_some() {
        println!(
            "cache: {} slot(s) simulated, {} from cache, {} skipped (other shards)",
            results.counters.simulated, results.counters.cached, results.counters.skipped
        );
    }
    if let Some(path) = args.options.get("json") {
        let j = results.to_json();
        if path == "-" {
            println!("{j}");
        } else {
            std::fs::write(path, &j).map_err(|e| format!("--json {path}: {e}"))?;
            println!("wrote JSON report to {path}");
        }
    }
    // --require-warm: assert the run performed zero simulations (every
    // slot came from cache or was skipped to another shard) — CI uses
    // this to prove a merged re-sweep is pure cache replay.
    if args.flag("require-warm") && results.counters.simulated > 0 {
        return Err(format!(
            "--require-warm: {} slot(s) were simulated instead of served from cache",
            results.counters.simulated
        ));
    }
    // Partial failure must not look like success to scripts/CI: the
    // tables and JSON above still describe what ran, but the exit
    // status reports the failed jobs (pairwise and e2e alike).
    if errs.is_empty() && e2e_errs.is_empty() && serve_errs.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "{} of {n_jobs} sweep jobs, {} e2e point(s) and {} serving point(s) failed \
             (see list above)",
            errs.len(),
            e2e_errs.len(),
            serve_errs.len()
        ))
    }
}

/// CI perf-regression gate: compare a fresh `sweep --json` report
/// against the checked-in baseline; non-zero exit on any >tolerance
/// median-speedup regression. Without `--strict` a `{"seeded":false}`
/// baseline passes with seeding instructions (bootstrap mode, useful
/// locally); with `--strict` — what CI uses — an unseeded baseline is
/// a hard failure, so the gate can never pass vacuously.
///
/// `--reseed OUT` additionally writes the report back out as an
/// *exact-provenance* baseline (every measured value verbatim, tagged
/// `"provenance":"exact"`), which is the recipe for tightening the
/// gate from conservative floors to real 2% regression tracking.
/// `--require-exact` fails unless the baseline being gated against
/// carries that exact provenance — CI's merged-matrix gate sets it so
/// a floor-seeded baseline can never satisfy the tight-tolerance leg.
pub(crate) fn bench_gate(args: &Args) -> Result<(), String> {
    let baseline_path = args.opt("baseline", "BENCH_baseline.json");
    let report_path = args
        .options
        .get("report")
        .ok_or("bench-gate needs --report <sweep --json output>")?;
    let tolerance: f64 = args
        .opt("tolerance", "0.02")
        .parse()
        .map_err(|e| format!("--tolerance: {e}"))?;
    let read = |p: &str| -> Result<crate::sweep::Json, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))?;
        crate::sweep::parse_json(&text).map_err(|e| format!("{p}: {e}"))
    };
    let report_text =
        std::fs::read_to_string(report_path).map_err(|e| format!("{report_path}: {e}"))?;
    let report =
        crate::sweep::parse_json(&report_text).map_err(|e| format!("{report_path}: {e}"))?;
    if let Some(out) = args.options.get("reseed") {
        // An exact baseline is the report itself with seeding metadata
        // spliced into the document head; every value is verbatim from
        // the run, so provenance is honestly "exact".
        let body = report_text
            .trim_start()
            .strip_prefix('{')
            .ok_or_else(|| format!("{report_path}: report is not a JSON object"))?;
        let seeded = format!("{{\"seeded\":true,\"provenance\":\"exact\",{body}");
        std::fs::write(out, &seeded).map_err(|e| format!("--reseed {out}: {e}"))?;
        println!("bench-gate: wrote exact-provenance baseline to {out}");
    }
    let baseline = read(&baseline_path)?;
    let provenance = baseline
        .get("provenance")
        .and_then(crate::sweep::Json::as_str)
        .unwrap_or("unknown");
    if args.flag("require-exact") && provenance != "exact" {
        return Err(format!(
            "--require-exact: baseline '{baseline_path}' has provenance '{provenance}', \
             not 'exact'; reseed it from a real run (bench-gate --reseed)"
        ));
    }
    if !args.flag("require-exact") && provenance == "floor-seeded" {
        println!(
            "bench-gate: note — baseline '{baseline_path}' is floor-seeded (conservative \
             model-derived floors). Floor compatibility is kept for one release; CI's \
             exact gate reseeds from a cold run and enforces --require-exact."
        );
    }
    if !crate::sweep::is_seeded(&baseline) {
        let points = crate::sweep::extract_points(&report)?;
        println!(
            "bench-gate: baseline '{baseline_path}' is not seeded yet; {} point(s) measured.",
            points.len()
        );
        println!(
            "  To seed the bench trajectory, commit the fresh report as {baseline_path}:\n  \
             cp {report_path} {baseline_path}"
        );
        // --strict: an unseeded/bootstrap baseline is a FAILURE, not a
        // pass — CI must gate against real numbers.
        if args.flag("strict") {
            return Err(format!(
                "--strict: baseline '{baseline_path}' is not seeded; seed it and re-run"
            ));
        }
        return Ok(());
    }
    let gate = crate::sweep::gate(&baseline, &report, tolerance)?;
    print!("{}", gate.render(tolerance));
    if gate.passed() {
        Ok(())
    } else {
        Err(format!(
            "perf gate failed: {} regression(s), {} missing point(s)",
            gate.regressions.len(),
            gate.missing.len()
        ))
    }
}
