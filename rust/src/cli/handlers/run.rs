//! `conccl run` / `conccl rp-sweep`: single-scenario execution.

use crate::cli::Args;
use crate::heuristics;
use crate::sched::{C3Executor, Strategy};
use crate::util::table::{f as fnum, speedup, Table};
use crate::util::units::fmt_seconds;

use super::{find_scenario, parse_collective, parse_strategy};

pub(crate) fn run_one(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let kind = parse_collective(&args.opt("collective", "all-gather"))?;
    let sc = find_scenario(&args.opt("scenario", "mb1_896M"), kind)?;
    let nodes = args.opt_usize("nodes", 1)?.max(1);
    let exec = C3Executor::with_topology(m.clone(), m.topology(nodes));
    let mut strat = parse_strategy(&args.opt("strategy", "conccl"), sc.comm.cu_need(&exec.m))?;
    // --chunks auto|N applies to the chunked pipeline strategies: auto
    // asks the runtime-style heuristic (heuristics::chunk) on the
    // paper's single node — the regime it is calibrated for — and the
    // topology-aware exhaustive chunk sweep on multi-node topologies
    // (the heuristic's rooflines know nothing about the NIC, where
    // chunking's win shrinks); a number pins the count (clamped to
    // what the scenario supports).
    let mut chunk_note = String::new();
    // The multi-node auto path already simulates every candidate; keep
    // its winning run instead of re-simulating the same point.
    let mut swept_run = None;
    if strat.is_chunked() {
        let dma = !strat.comm_on_cus();
        let k = match args.opt("chunks", "auto").as_str() {
            "auto" if nodes <= 1 => {
                let k = heuristics::recommend_chunks(&exec.m, &sc, dma);
                chunk_note = format!("{k} (auto-tuned)");
                k
            }
            "auto" => {
                let (run, k) = exec
                    .try_run_chunk_sweep_with(&sc, dma, exec.baselines(&sc))
                    .map_err(|e| e.to_string())?;
                chunk_note = format!("{k} (swept, {nodes}-node topology)");
                swept_run = Some(run);
                k
            }
            other => {
                let k: u32 = other.parse().map_err(|e| format!("--chunks: {e}"))?;
                if k == 0 {
                    return Err("--chunks: chunk count must be >= 1 (or 'auto')".into());
                }
                let k = exec.clamp_chunks(&sc, k);
                chunk_note = k.to_string();
                k
            }
        };
        strat = match strat {
            Strategy::C3Chunked { .. } => Strategy::C3Chunked { chunks: k },
            Strategy::ConcclChunked { .. } => Strategy::ConcclChunked { chunks: k },
            other => other,
        };
    } else if args.options.contains_key("chunks") {
        // Silently ignoring --chunks would misreport the measurement.
        return Err(format!(
            "--chunks applies to the chunked pipeline strategies \
             (c3_chunked, conccl_chunked), not '{}'",
            strat.name()
        ));
    }
    let r = match swept_run {
        Some(run) => run,
        None => exec.try_run(&sc, strat).map_err(|e| e.to_string())?,
    };
    let mut t = Table::new(vec!["metric", "value"]).left_cols(2).title(format!(
        "{} × {} under {} ({nodes} node(s))",
        sc.tag(),
        kind.name(),
        strat.name()
    ));
    if !chunk_note.is_empty() {
        t.row(vec!["chunks".to_string(), chunk_note]);
    }
    t.row(vec!["serial".to_string(), fmt_seconds(r.serial)]);
    t.row(vec!["concurrent".to_string(), fmt_seconds(r.total)]);
    t.row(vec!["gemm finish".to_string(), fmt_seconds(r.gemm_finish)]);
    t.row(vec!["comm finish".to_string(), fmt_seconds(r.comm_finish)]);
    t.row(vec!["ideal speedup".to_string(), speedup(r.ideal)]);
    t.row(vec!["attained speedup".to_string(), speedup(r.speedup)]);
    t.row(vec!["% of ideal".to_string(), fnum(r.pct_ideal, 1)]);
    t.print();
    Ok(())
}

/// The original single-scenario c3_rp CU-reservation sweep.
pub(crate) fn rp_sweep(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let kind = parse_collective(&args.opt("collective", "all-gather"))?;
    let sc = find_scenario(&args.opt("scenario", "cb1_896M"), kind)?;
    let exec = C3Executor::new(m);
    let mut t = Table::new(vec!["comm CUs", "total", "speedup", "%ideal"])
        .title(format!("c3_rp sweep: {} × {}", sc.tag(), kind.name()));
    for k in exec.m.rp_candidates() {
        let r = exec.run(&sc, Strategy::C3Rp { comm_cus: k });
        t.row(vec![
            k.to_string(),
            fmt_seconds(r.total),
            speedup(r.speedup),
            fnum(r.pct_ideal, 1),
        ]);
    }
    let (best, k) = exec.run_rp_sweep(&sc);
    t.rule();
    t.row(vec![
        format!("best={k}"),
        fmt_seconds(best.total),
        speedup(best.speedup),
        fnum(best.pct_ideal, 1),
    ]);
    t.print();
    Ok(())
}
