//! Subcommand handlers: the dispatch table and one module per
//! subcommand group, so `main.rs` stays a thin parse → dispatch shell.
//!
//! Every handler takes the parsed [`Args`] and returns
//! `Result<(), String>`; the binary maps `Err` to a non-zero exit.

mod analyze;
mod dse;
mod e2e;
mod run;
mod serve;
mod sweep;

use crate::cli::{Args, HELP};
use crate::config::workload::CollectiveKind;
use crate::sched::Strategy;
use crate::workload::scenarios::resolve_tag;
use crate::workload::ResolvedScenario;

/// Route a parsed command line to its handler.
pub fn dispatch(args: &Args) -> Result<(), String> {
    match args.subcommand.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "characterize" => analyze::characterize(args),
        "run" => run::run_one(args),
        "sweep" => sweep::sweep_cmd(args),
        "dse" => dse::dse_cmd(args),
        "bench-gate" => sweep::bench_gate(args),
        // CI keys its result-cache restore on this salt; a model-
        // semantics bump then misses the stale cache cleanly.
        "model-version" => {
            println!("{}", crate::sweep::MODEL_VERSION);
            Ok(())
        }
        "rp-sweep" => run::rp_sweep(args),
        "report" => analyze::full_report(args),
        "conccl-bw" => analyze::conccl_bw(args),
        "heuristics" => analyze::heuristics_cmd(args),
        "e2e" => e2e::e2e(args),
        "graph" => e2e::graph_cmd(args),
        "serve" => serve::serve_cmd(args),
        other => Err(format!("unknown subcommand '{other}'\n\n{HELP}")),
    }
}

/// Parse a collective name shared by several subcommands.
pub(crate) fn parse_collective(s: &str) -> Result<CollectiveKind, String> {
    match s {
        "all-gather" | "ag" => Ok(CollectiveKind::AllGather),
        "all-to-all" | "a2a" => Ok(CollectiveKind::AllToAll),
        "all-reduce" | "ar" => Ok(CollectiveKind::AllReduce),
        "reduce-scatter" | "rs" => Ok(CollectiveKind::ReduceScatter),
        other => Err(format!("unknown collective '{other}'")),
    }
}

pub(crate) fn parse_strategy(s: &str, comm_need: u32) -> Result<Strategy, String> {
    Strategy::parse(s, comm_need).map_err(|e| e.to_string())
}

pub(crate) fn find_scenario(tag: &str, kind: CollectiveKind) -> Result<ResolvedScenario, String> {
    resolve_tag(tag, kind).map_err(|e| e.to_string())
}

/// Split a comma-separated option; "all" or empty means "everything".
pub(crate) fn csv_list(opt: &str) -> Vec<&str> {
    if opt == "all" || opt.trim().is_empty() {
        Vec::new()
    } else {
        opt.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_rejects_unknown_subcommand() {
        let args = Args {
            subcommand: "warp".into(),
            ..Args::default()
        };
        let err = dispatch(&args).unwrap_err();
        assert!(err.contains("unknown subcommand 'warp'"));
    }

    #[test]
    fn collective_aliases_parse() {
        assert_eq!(parse_collective("ag").unwrap(), CollectiveKind::AllGather);
        assert_eq!(parse_collective("rs").unwrap(), CollectiveKind::ReduceScatter);
        assert!(parse_collective("warp").is_err());
    }

    #[test]
    fn csv_list_semantics() {
        assert!(csv_list("all").is_empty());
        assert!(csv_list("  ").is_empty());
        assert_eq!(csv_list("a, b,,c"), vec!["a", "b", "c"]);
    }
}
