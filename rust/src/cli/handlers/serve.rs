//! `conccl serve`: the streaming inference-serving traffic engine —
//! open-loop arrivals into the per-step decode graphs of
//! [`crate::workload::serving`], reporting steady-state latency
//! percentiles, goodput and engine occupancy per serving family.

use crate::cli::Args;
use crate::coordinator::report;
use crate::workload::e2e::E2eFamily;
use crate::workload::serving::ServeSpec;
use crate::workload::traffic::{run_serve, run_serve_lineup, TrafficConfig};

/// Run one serving workload under the traffic engine and print the
/// family lineup (or one family with `--family`).
pub(crate) fn serve_cmd(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let nodes = args.opt_usize("nodes", 1)?.max(1);
    let spec =
        ServeSpec::parse(&args.opt("workload", "tp_decode:70b")).map_err(|e| e.to_string())?;
    let cfg = TrafficConfig {
        rate: args.opt_f64("rate", 2000.0)?,
        steps: args.opt_usize("steps", 200)?,
        duration: args.opt_f64("duration", 0.0)?,
        tokens_mean: args.opt_f64("tokens", 24.0)?,
    };
    cfg.validate().map_err(|e| e.to_string())?;
    let seed = args.opt_u64("seed", 24301)?;
    let topo = m.topology(nodes);
    let runs = match args.opt("family", "all").as_str() {
        "all" => run_serve_lineup(&m, &topo, spec, cfg, seed).map_err(|e| e.to_string())?,
        other => {
            let family = E2eFamily::parse(other).map_err(|e| e.to_string())?;
            vec![run_serve(&m, &topo, spec, family, cfg, seed).map_err(|e| e.to_string())?]
        }
    };
    report::render_serve(
        &format!(
            "serving traffic: {} @ {} req/s, {} steps, seed {seed}, {nodes} node(s)",
            spec.label(),
            cfg.rate,
            cfg.steps
        ),
        &runs,
    )
    .print();
    if args.flag("profile") {
        let rows: Vec<(&str, crate::sim::SimCounters)> =
            runs.iter().map(|r| (r.family.name(), r.counters)).collect();
        println!();
        report::render_profile("fluid-core event-loop profile", &rows).print();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn serve_runs_the_lineup() {
        assert!(serve_cmd(&args("serve --workload tp_decode:70b:2:8 --steps 40")).is_ok());
    }

    #[test]
    fn serve_single_family_and_overrides() {
        assert!(serve_cmd(&args(
            "serve --workload pd:70b:2:8 --family auto --rate 1500 --steps 40 --seed 7"
        ))
        .is_ok());
    }

    #[test]
    fn serve_profile_flag_prints_event_loop_counters() {
        assert!(serve_cmd(&args(
            "serve --workload tp_decode:70b:2:8 --family serial --steps 40 --profile"
        ))
        .is_ok());
    }

    #[test]
    fn malformed_inputs_are_typed_errors_not_panics() {
        for bad in [
            "serve --workload warp_decode",
            "serve --workload tp_decode:13b",
            "serve --workload tp_decode:70b:0",
            "serve --workload tp_decode:70b:2:8:9",
            "serve --rate 0",
            "serve --rate nan --steps 10",
            "serve --steps 0",
            "serve --tokens 0.2",
            "serve --duration -1",
            "serve --family warp",
            "serve --seed minus-one",
        ] {
            assert!(serve_cmd(&args(bad)).is_err(), "{bad:?} must fail cleanly");
        }
    }
}
