//! `conccl characterize` / `report` / `conccl-bw` / `heuristics`:
//! table & figure regeneration plus heuristic-accuracy comparisons.

use crate::cli::Args;
use crate::config::workload::CollectiveKind;
use crate::coordinator::{report, run_suite, taxonomy_divergences, RunnerConfig};
use crate::heuristics::{self, SlowdownTable};
use crate::sched::{C3Executor, Strategy};
use crate::util::table::{f as fnum, Table};
use crate::util::units::MIB;
use crate::workload::scenarios::{resolve, TABLE2};

pub(crate) fn characterize(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    report::render_table1(&m).print();
    println!();
    report::render_table2(&m).print();
    println!();
    report::render_fig5a(&m, &[0, 8, 16, 32, 64, 96, 128]).print();
    println!();
    let sizes = [896 * MIB, 3328 * MIB, 13 * 1024 * MIB];
    report::render_fig5bc(&m, CollectiveKind::AllGather, &sizes, &[8, 16, 32, 64, 128]).print();
    println!();
    report::render_fig5bc(&m, CollectiveKind::AllToAll, &sizes, &[8, 16, 32, 64, 128]).print();
    println!();
    report::render_fig6(&m, &[896 * MIB, 3328 * MIB]).print();
    Ok(())
}

pub(crate) fn full_report(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let jitter: f64 = args
        .opt("jitter", "0.01")
        .parse()
        .map_err(|e| format!("--jitter: {e}"))?;
    let cfg = RunnerConfig {
        jitter,
        ..RunnerConfig::default()
    };
    let outs = run_suite(&m, &crate::workload::scenarios::suite(), &cfg);
    report::render_fig7(&outs).print();
    println!();
    report::render_fig8(&outs).print();
    println!();
    report::render_fig10(&outs).print();
    let div = taxonomy_divergences(&m, &outs);
    if !div.is_empty() {
        println!("\ntaxonomy divergences (paper label vs our models):");
        for (tag, paper, ours) in div {
            println!("  {tag}: paper {} / computed {}", paper.name(), ours.name());
        }
    }
    Ok(())
}

pub(crate) fn conccl_bw(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let sizes: Vec<u64> = [1, 4, 8, 16, 32, 64, 128, 256, 896, 2048, 8192, 20480]
        .iter()
        .map(|mb| mb * MIB)
        .collect();
    report::render_fig9(&m, &sizes).print();
    Ok(())
}

pub(crate) fn heuristics_cmd(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let table = SlowdownTable::build(&m);
    let exec = C3Executor::new(m.clone());
    let mut t = Table::new(vec![
        "scenario", "collective", "heuristic", "sweep-best", "match", "loss%",
    ])
    .title("§V-C RP heuristic vs exhaustive sweep")
    .left_cols(2);
    let mut matches = 0;
    let mut worst_loss: f64 = 0.0;
    let mut n = 0;
    for kind in CollectiveKind::studied() {
        for row in &TABLE2 {
            let sc = resolve(row, kind);
            let k_h = heuristics::recommend(&m, &table, &sc);
            let (best, k_b) = exec.run_rp_sweep(&sc);
            let r_h = exec.run_rp_at(&sc, k_h);
            let loss = (r_h.total / best.total - 1.0) * 100.0;
            let is_match = k_h == k_b || loss < 0.1;
            matches += is_match as usize;
            worst_loss = worst_loss.max(loss);
            n += 1;
            t.row(vec![
                sc.tag(),
                kind.name().to_string(),
                k_h.to_string(),
                k_b.to_string(),
                if is_match { "yes" } else { "no" }.to_string(),
                fnum(loss, 2),
            ]);
        }
    }
    t.print();
    println!(
        "heuristic optimal for {matches}/{n} scenarios; worst loss {worst_loss:.2}% \
         (paper: 24/30, <=1.5%)"
    );
    let sp_ok = TABLE2.iter().all(|row| {
        let sc = resolve(row, CollectiveKind::AllGather);
        heuristics::comm_first(&m, &sc.gemm, &sc.comm)
    });
    println!("SP heuristic schedules communication first for all scenarios: {sp_ok}");

    // Chunk-count tuner vs the exhaustive chunk sweep (the granularity
    // analog of the rp comparison above), on the ConCCL pipeline.
    let mut ct = Table::new(vec![
        "scenario", "collective", "heuristic k", "sweep-best k", "match", "loss%",
    ])
    .title("chunk auto-tuner vs exhaustive chunk sweep (conccl_chunked)")
    .left_cols(2);
    let mut c_matches = 0;
    let mut c_worst: f64 = 0.0;
    for kind in CollectiveKind::studied() {
        for row in &TABLE2 {
            let sc = resolve(row, kind);
            let k_h = heuristics::recommend_chunks(&m, &sc, true);
            let at_h = exec.run(&sc, Strategy::ConcclChunked { chunks: k_h });
            let (best, k_b) = exec.run_chunk_sweep(&sc, true);
            let loss = (at_h.total / best.total - 1.0) * 100.0;
            let is_match = k_h == k_b || loss < 0.1;
            c_matches += is_match as usize;
            c_worst = c_worst.max(loss);
            ct.row(vec![
                sc.tag(),
                kind.name().to_string(),
                k_h.to_string(),
                k_b.to_string(),
                if is_match { "yes" } else { "no" }.to_string(),
                fnum(loss, 2),
            ]);
        }
    }
    println!();
    ct.print();
    println!("chunk tuner optimal for {c_matches}/{n} scenarios; worst loss {c_worst:.2}%");
    Ok(())
}
