//! `conccl dse`: design-space exploration over hypothetical DMA-engine
//! subsystems — every grid point is a full machine the planner can
//! consume, reported as Pareto frontiers of speedup vs. engine area.

use crate::cli::Args;
use crate::sweep::dse::{run as run_dse, DsePlan};
use crate::util::table::{speedup, Table};
use crate::util::units::fmt_seconds;
use crate::workload::e2e::E2eSpec;
use crate::workload::serving::ServeSpec;
use crate::workload::traffic::TrafficConfig;

use super::{csv_list, find_scenario, parse_collective};

/// Parse a comma-separated `usize` axis option.
fn usize_axis(args: &Args, key: &str, default: &str) -> Result<Vec<usize>, String> {
    args.opt(key, default)
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().map_err(|e| format!("--{key}: {e}")))
        .collect()
}

/// Sweep {engines × queue depth × packet fusing × NIC bandwidth} and
/// report per-workload Pareto frontiers of speedup vs. engine area.
pub(crate) fn dse_cmd(args: &Args) -> Result<(), String> {
    let m = args.machine()?;
    let mut plan = DsePlan::new(m);
    plan.engines = usize_axis(args, "engines", "2,4,7,14")?;
    plan.queue_depths = usize_axis(args, "queue-depths", "0,8")?;
    plan.fused = usize_axis(args, "fused", "1")?;
    // The NIC axis is given in GB/s on the CLI, stored in B/s.
    if let Some(spec) = args.options.get("nic-bw") {
        plan.nic_bws = spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<f64>()
                    .map(|gb| gb * 1e9)
                    .map_err(|e| format!("--nic-bw: {e}"))
            })
            .collect::<Result<_, _>>()?;
    }
    plan.nodes = args.opt_usize("nodes", 1)?;
    plan.seed = args.opt_u64("seed", 24301)?;

    let kind = parse_collective(&args.opt("collective", "ag"))?;
    if let Some(tags) = args.options.get("pairs") {
        for tag in csv_list(tags) {
            plan.pairs.push(find_scenario(tag, kind)?);
        }
    }
    if let Some(spec) = args.options.get("e2e") {
        plan.e2e = spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(E2eSpec::parse)
            .collect::<Result<_, _>>()
            .map_err(|e| format!("--e2e: {e}"))?;
    }
    if let Some(spec) = args.options.get("serve") {
        plan.serve = spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(ServeSpec::parse)
            .collect::<Result<_, _>>()
            .map_err(|e| format!("--serve: {e}"))?;
    }
    plan.traffic = TrafficConfig {
        rate: args.opt_f64("rate", 2000.0)?,
        steps: args.opt_usize("serve-steps", 200)?,
        tokens_mean: args.opt_f64("serve-tokens", 24.0)?,
        duration: 0.0,
    };
    // No workload options at all: score the canonical FSDP step so a
    // bare `conccl dse` still answers the headline hardware question.
    if plan.pairs.is_empty() && plan.e2e.is_empty() && plan.serve.is_empty() {
        plan.e2e = vec![E2eSpec::parse("fsdp_step:70b:2:2").map_err(|e| e.to_string())?];
    }

    let threads = args.opt_usize("threads", 0)?;
    let t0 = std::time::Instant::now();
    let res = run_dse(plan, threads).map_err(|e| e.to_string())?;
    let elapsed = t0.elapsed().as_secs_f64();

    for (wi, w) in res.workloads.iter().enumerate() {
        let front: Vec<usize> = res.frontier(wi).iter().map(|s| s.point_idx).collect();
        let mut t = Table::new(vec![
            "point".to_string(),
            "area".to_string(),
            "speedup".to_string(),
            "pareto".to_string(),
        ])
        .left_cols(1)
        .title(format!(
            "dse '{}': speedup vs engine-area proxy (* = Pareto frontier)",
            w.key
        ));
        for (pi, p) in res.points.iter().enumerate() {
            let cell = match &res.outcomes[pi][wi] {
                Ok(v) => speedup(*v),
                Err(_) => "ERR".to_string(),
            };
            t.row(vec![
                p.label.clone(),
                format!("{:.2}", p.area),
                cell,
                if front.contains(&pi) { "*".to_string() } else { String::new() },
            ]);
        }
        t.print();
        println!();
    }

    let errs = res.errors();
    if !errs.is_empty() {
        println!("{} dse point(s) failed (exploration continued):", errs.len());
        for (pi, wi, e) in &errs {
            println!("  [{} × {}]: {e}", res.points[*pi].label, res.workloads[*wi].key);
        }
    }
    println!(
        "{} points × {} workload column(s) on {} worker thread(s) in {}",
        res.points.len(),
        res.workloads.len(),
        res.threads_used,
        fmt_seconds(elapsed)
    );
    if let Some(path) = args.options.get("json") {
        let j = res.to_json();
        if path == "-" {
            println!("{j}");
        } else {
            std::fs::write(path, &j).map_err(|e| format!("--json {path}: {e}"))?;
            println!("wrote dse report to {path}");
        }
    }
    if errs.is_empty() {
        Ok(())
    } else {
        Err(format!("{} dse point(s) failed (see list above)", errs.len()))
    }
}
