//! Minimal property-based testing harness (no `proptest` offline).
//!
//! Provides the 20% of proptest we need: generate N random cases from a
//! seeded [`Rng`](crate::util::rng::Rng), check a property, and on failure
//! greedily shrink the counterexample before reporting it.
//!
//! Usage (`no_run`: doctest binaries can't locate the xla shared
//! library this crate links; the same code runs as a unit test below):
//! ```no_run
//! use conccl::util::prop::{forall, Shrink};
//! forall("sum is commutative", 200, |rng| {
//!     (rng.i64_in(-100, 100), rng.i64_in(-100, 100))
//! })
//! .check(|&(a, b)| {
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a}+{b}")) }
//! });
//! ```

use crate::util::rng::Rng;

/// Types that know how to propose smaller versions of themselves.
/// Shrinking is greedy: we repeatedly take the first candidate that still
/// fails the property until no candidate fails.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-"smaller" values, most aggressive first.
    fn shrink_candidates(&self) -> Vec<Self>;
}

impl Shrink for i64 {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut c = Vec::new();
        if *self != 0 {
            c.push(0);
            c.push(self / 2);
            if *self < 0 {
                c.push(-self);
            }
            if self.abs() > 1 {
                c.push(self - self.signum());
            }
        }
        c.retain(|x| x != self);
        c.dedup();
        c
    }
}

impl Shrink for u64 {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut c = Vec::new();
        if *self != 0 {
            c.push(0);
            c.push(self / 2);
            if *self > 1 {
                c.push(self - 1);
            }
        }
        c.retain(|x| x != self);
        c.dedup();
        c
    }
}

impl Shrink for usize {
    fn shrink_candidates(&self) -> Vec<Self> {
        (*self as u64)
            .shrink_candidates()
            .into_iter()
            .map(|x| x as usize)
            .collect()
    }
}

impl Shrink for f64 {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut c = Vec::new();
        if *self != 0.0 {
            c.push(0.0);
            c.push(self / 2.0);
            c.push(self.trunc());
        }
        c.retain(|x| x != self && x.is_finite());
        c
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink_candidates() {
            out.push((a, self.1.clone()));
        }
        for b in self.1.shrink_candidates() {
            out.push((self.0.clone(), b));
        }
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink_candidates() {
            out.push((a, self.1.clone(), self.2.clone()));
        }
        for b in self.1.shrink_candidates() {
            out.push((self.0.clone(), b, self.2.clone()));
        }
        for c in self.2.shrink_candidates() {
            out.push((self.0.clone(), self.1.clone(), c));
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, then single elements, then shrink elements.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 8 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for cand in self[i].shrink_candidates() {
                    let mut v = self.clone();
                    v[i] = cand;
                    out.push(v);
                }
            }
        }
        out
    }
}

/// A property-check builder; see module docs for usage.
pub struct Forall<T, G: FnMut(&mut Rng) -> T> {
    name: &'static str,
    cases: usize,
    gen: G,
    seed: u64,
}

/// Entry point: run `cases` random cases of `gen` against a property.
pub fn forall<T, G: FnMut(&mut Rng) -> T>(
    name: &'static str,
    cases: usize,
    gen: G,
) -> Forall<T, G> {
    Forall {
        name,
        cases,
        gen,
        seed: 0xC0FFEE,
    }
}

impl<T: Shrink + std::fmt::Debug, G: FnMut(&mut Rng) -> T> Forall<T, G> {
    /// Override the seed (each named property is deterministic anyway).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the property; panics with the shrunk counterexample on failure.
    /// The property returns `Err(reason)` to fail.
    pub fn check<P: FnMut(&T) -> Result<(), String>>(mut self, mut prop: P) {
        // Mix the name into the seed so different properties see
        // different streams even with the default seed.
        let mut h: u64 = self.seed;
        for b in self.name.bytes() {
            h = h.wrapping_mul(0x100000001b3).wrapping_add(b as u64);
        }
        let mut rng = Rng::new(h);
        for case in 0..self.cases {
            let value = (self.gen)(&mut rng);
            if let Err(first_reason) = prop(&value) {
                let (shrunk, reason, steps) = shrink_loop(value, first_reason, &mut prop);
                panic!(
                    "property '{}' failed (case {}/{}, {} shrink steps)\n  \
                     counterexample: {:?}\n  reason: {}",
                    self.name, case + 1, self.cases, steps, shrunk, reason
                );
            }
        }
    }
}

fn shrink_loop<T: Shrink, P: FnMut(&T) -> Result<(), String>>(
    mut value: T,
    mut reason: String,
    prop: &mut P,
) -> (T, String, usize) {
    let mut steps = 0;
    // Cap shrink work so pathological shrinkers can't loop forever.
    'outer: while steps < 1000 {
        for cand in value.shrink_candidates() {
            if let Err(r) = prop(&cand) {
                value = cand;
                reason = r;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (value, reason, steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("abs is non-negative", 500, |rng| rng.i64_in(-1000, 1000)).check(|&x| {
            if x.abs() >= 0 {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            forall("all values below 50", 500, |rng| rng.i64_in(0, 1000)).check(|&x| {
                if x < 50 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 50"))
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land on exactly 50 (minimal failing value).
        assert!(msg.contains("counterexample: 50"), "msg: {msg}");
    }

    #[test]
    fn tuple_shrink_covers_both_slots() {
        let cands = (4i64, 6i64).shrink_candidates();
        assert!(cands.contains(&(0, 6)));
        assert!(cands.contains(&(4, 0)));
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let v = vec![1i64, 2, 3, 4];
        let cands = v.shrink_candidates();
        assert!(cands.iter().any(|c| c.len() < v.len()));
    }

    #[test]
    fn deterministic_given_name() {
        // Two runs of the same property generate identical streams: if it
        // passes once it passes always (no flaky CI).
        for _ in 0..2 {
            forall("determinism", 100, |rng| rng.u64_below(1_000_000)).check(|_| Ok(()));
        }
    }
}
