//! Foundational utilities built from scratch for the offline environment
//! (no `rand`, `proptest`, `criterion`, `log` crates available):
//! deterministic PRNG, statistics, unit parsing/formatting, a
//! property-test harness, ASCII tables, a bench harness, a scoped
//! worker pool and a logger.

pub mod bench;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
