//! Small statistics toolkit used by the measurement protocol and the
//! bench harness.
//!
//! The paper's protocol (§IV-A1) is: 15 executions, first 6 warm-up, last
//! 9 measured; we report medians. [`Summary`] captures the usual
//! location/spread statistics of a measured sample.

/// Arithmetic mean. Returns `NaN` on an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; all inputs must be positive. Standard aggregate for
/// speedup ratios (used for the paper's "on average X× speedup" rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positives");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Sample standard deviation (n-1 denominator). 0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation, `q` in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Summary statistics of one measured sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p5: f64,
    pub p95: f64,
}

impl Summary {
    /// Summarize a sample; `NaN`-filled for empty input.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                median: f64::NAN,
                stddev: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                p5: f64::NAN,
                p95: f64::NAN,
            };
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            median: median(xs),
            stddev: stddev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            p5: percentile(xs, 5.0),
            p95: percentile(xs, 95.0),
        }
    }

    /// Coefficient of variation (stddev / mean).
    pub fn cv(&self) -> f64 {
        self.stddev / self.mean
    }
}

/// The paper's measurement protocol: run `total` times, discard the first
/// `warmup`, summarize the rest. `f` returns one measurement (seconds).
pub fn measure_protocol<F: FnMut(usize) -> f64>(
    warmup: usize,
    measured: usize,
    mut f: F,
) -> Summary {
    let mut samples = Vec::with_capacity(measured);
    for i in 0..(warmup + measured) {
        let v = f(i);
        if i >= warmup {
            samples.push(v);
        }
    }
    Summary::of(&samples)
}

/// Relative difference |a-b| / max(|a|,|b|); 0 if both are 0.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs());
    if denom == 0.0 {
        0.0
    } else {
        (a - b).abs() / denom
    }
}

/// Assert two floats are within `tol` relative difference (test helper).
#[macro_export]
macro_rules! assert_rel_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol) = ($a as f64, $b as f64, $tol as f64);
        let rd = $crate::util::stats::rel_diff(a, b);
        assert!(
            rd <= tol,
            "assert_rel_close failed: {} vs {} (rel diff {:.4} > {:.4})",
            a,
            b,
            rd,
            tol
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(mean(&xs), 22.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn median_interpolates_even_n() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn geomean_of_ratios() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_known_value() {
        // Sample stddev of [2,4,4,4,5,5,7,9] is ~2.138 (n-1).
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_exact_quantiles_on_known_distribution() {
        // 0..=100 — the linear-interpolation estimator lands exactly on
        // integers at every integer percentile (rank = q), so the
        // serving engine's p50/p95/p99 are exact sample quantiles.
        let xs: Vec<f64> = (0..=100).map(f64::from).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 95.0), 95.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        // ... and interpolates linearly between ranks.
        assert_eq!(percentile(&[10.0, 20.0], 25.0), 12.5);
        assert_eq!(percentile(&[10.0, 20.0, 30.0], 75.0), 25.0);
        // Single sample: every percentile is that sample.
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_is_order_independent() {
        // The estimator sorts a copy: input order (e.g. request
        // completion order in the traffic engine) must not matter, and
        // the input slice must stay untouched.
        let sorted: Vec<f64> = (1..=32).map(f64::from).collect();
        let mut shuffled = sorted.clone();
        // Deterministic shuffle: stride through the slice coprime to
        // its length.
        shuffled.rotate_left(13);
        shuffled.swap(0, 17);
        shuffled.swap(5, 29);
        let before = shuffled.clone();
        for q in [0.0, 13.7, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile(&sorted, q).to_bits(), percentile(&shuffled, q).to_bits());
        }
        assert_eq!(shuffled, before, "percentile must not reorder its input");
    }

    #[test]
    fn summary_consistency() {
        let xs = [3.0, 1.0, 2.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan() && s.median.is_nan());
    }

    #[test]
    fn protocol_discards_warmup() {
        // Warm-up iterations return garbage; measured return 1.0.
        let s = measure_protocol(6, 9, |i| if i < 6 { 1000.0 } else { 1.0 });
        assert_eq!(s.n, 9);
        assert_eq!(s.mean, 1.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn rel_close_macro() {
        assert_rel_close!(100.0, 101.0, 0.02);
    }

    #[test]
    #[should_panic]
    fn rel_close_macro_fails() {
        assert_rel_close!(100.0, 120.0, 0.05);
    }
}
