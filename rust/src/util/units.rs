//! Byte / time / rate units: parsing and pretty-printing.
//!
//! The paper tags scenarios like `mb1_896M` and `cb5_13G`: sizes use
//! binary-ish ML conventions (M = MiB, G = GiB). [`parse_bytes`] accepts
//! those suffixes; formatters render engineering-friendly strings for
//! tables and reports.

/// 1 KiB.
pub const KIB: u64 = 1024;
/// 1 MiB.
pub const MIB: u64 = 1024 * KIB;
/// 1 GiB.
pub const GIB: u64 = 1024 * MIB;

/// Parse a byte-size string: `"896M"`, `"3.25G"`, `"512K"`, `"64"` (raw
/// bytes), `"13G"`. Suffixes are binary (K=KiB, M=MiB, G=GiB, T=TiB),
/// matching the paper's scenario tags. Case-insensitive; optional final
/// `B`/`iB` tolerated (`"896MiB"`).
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim();
    if t.is_empty() {
        return Err("empty size string".into());
    }
    let lower = t.to_ascii_lowercase();
    let lower = lower
        .strip_suffix("ib")
        .or_else(|| lower.strip_suffix('b').filter(|r| !r.is_empty()))
        .unwrap_or(&lower);
    let (num_part, mult) = match lower.chars().last() {
        Some('k') => (&lower[..lower.len() - 1], KIB as f64),
        Some('m') => (&lower[..lower.len() - 1], MIB as f64),
        Some('g') => (&lower[..lower.len() - 1], GIB as f64),
        Some('t') => (&lower[..lower.len() - 1], (GIB * KIB) as f64),
        _ => (&lower[..], 1.0),
    };
    let v: f64 = num_part
        .trim()
        .parse()
        .map_err(|e| format!("bad size '{s}': {e}"))?;
    if v < 0.0 {
        return Err(format!("negative size '{s}'"));
    }
    Ok((v * mult).round() as u64)
}

/// Format bytes with a binary suffix, trimming trailing zeros:
/// `939524096 -> "896M"`, `3489660928 -> "3.25G"`.
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    let (v, suffix) = if b >= GIB as f64 {
        (b / GIB as f64, "G")
    } else if b >= MIB as f64 {
        (b / MIB as f64, "M")
    } else if b >= KIB as f64 {
        (b / KIB as f64, "K")
    } else {
        return format!("{bytes}B");
    };
    let s = format!("{v:.2}");
    let s = s.trim_end_matches('0').trim_end_matches('.');
    format!("{s}{suffix}")
}

/// Format a duration in seconds with an adaptive unit (ns/us/ms/s).
pub fn fmt_seconds(secs: f64) -> String {
    let a = secs.abs();
    if !a.is_finite() {
        format!("{secs}")
    } else if a >= 1.0 {
        format!("{secs:.3}s")
    } else if a >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Format a bandwidth in bytes/second as GB/s (decimal GB, the convention
/// the paper uses for link/HBM bandwidths).
pub fn fmt_bw(bytes_per_s: f64) -> String {
    if bytes_per_s >= 1e12 {
        format!("{:.2}TB/s", bytes_per_s / 1e12)
    } else {
        format!("{:.1}GB/s", bytes_per_s / 1e9)
    }
}

/// Format a FLOP rate as TFLOP/s or PFLOP/s.
pub fn fmt_flops(flops_per_s: f64) -> String {
    if flops_per_s >= 1e15 {
        format!("{:.2}PF/s", flops_per_s / 1e15)
    } else {
        format!("{:.1}TF/s", flops_per_s / 1e12)
    }
}

/// Format a count with thousands separators (`1234567 -> "1,234,567"`).
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_plain_and_suffixed() {
        assert_eq!(parse_bytes("64").unwrap(), 64);
        assert_eq!(parse_bytes("1K").unwrap(), 1024);
        assert_eq!(parse_bytes("896M").unwrap(), 896 * MIB);
        assert_eq!(parse_bytes("3.25G").unwrap(), (3.25 * GIB as f64) as u64);
        assert_eq!(parse_bytes("13G").unwrap(), 13 * GIB);
    }

    #[test]
    fn parse_tolerates_case_and_ib() {
        assert_eq!(parse_bytes("896m").unwrap(), 896 * MIB);
        assert_eq!(parse_bytes("896MiB").unwrap(), 896 * MIB);
        assert_eq!(parse_bytes("896MB").unwrap(), 896 * MIB);
        assert_eq!(parse_bytes(" 2G ").unwrap(), 2 * GIB);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("-5M").is_err());
    }

    #[test]
    fn fmt_round_trips_paper_tags() {
        assert_eq!(fmt_bytes(896 * MIB), "896M");
        assert_eq!(fmt_bytes((3.25 * GIB as f64) as u64), "3.25G");
        assert_eq!(fmt_bytes(13 * GIB), "13G");
        assert_eq!(fmt_bytes(512 * MIB), "512M");
        assert_eq!(fmt_bytes(100), "100B");
    }

    #[test]
    fn parse_fmt_inverse_on_common_sizes() {
        for s in ["128M", "512M", "896M", "1G", "2.5G", "4G", "6G", "13G", "20G", "26.5G"] {
            let b = parse_bytes(s).unwrap();
            assert_eq!(parse_bytes(&fmt_bytes(b)).unwrap(), b, "tag {s}");
        }
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_seconds(1.5), "1.500s");
        assert_eq!(fmt_seconds(0.00125), "1.250ms");
        assert_eq!(fmt_seconds(2.5e-6), "2.500us");
        assert_eq!(fmt_seconds(3e-9), "3.0ns");
    }

    #[test]
    fn fmt_rates() {
        assert_eq!(fmt_bw(5.3e12), "5.30TB/s");
        assert_eq!(fmt_bw(64e9), "64.0GB/s");
        assert_eq!(fmt_flops(1.3e15), "1.30PF/s");
    }

    #[test]
    fn fmt_count_separators() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }
}
