//! Tiny leveled logger (no `log`/`env_logger` wiring needed).
//!
//! Level is read once from `CONCCL_LOG` (`error|warn|info|debug|trace`,
//! default `warn`). The macros are cheap when disabled (level check on an
//! atomic). All simulator/ coordinator diagnostics route through here so
//! benches stay quiet by default.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ascending verbosity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Short tag used in output lines.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static CURRENT: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("CONCCL_LOG")
        .ok()
        .and_then(|v| Level::from_str(&v))
        .unwrap_or(Level::Warn) as u8;
    CURRENT.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level (lazily initialized from the environment).
pub fn level() -> Level {
    let raw = CURRENT.load(Ordering::Relaxed);
    let raw = if raw == u8::MAX { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, `--verbose` flags).
pub fn set_level(l: Level) {
    CURRENT.store(l as u8, Ordering::Relaxed);
}

/// Is `l` enabled right now?
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Core emit function used by the macros.
pub fn emit(l: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{} {}] {}", l.tag(), module, args);
    }
}

/// Log at a given level: `log_at!(Level::Info, "fmt {}", x)`.
#[macro_export]
macro_rules! log_at {
    ($lvl:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($lvl, module_path!(), format_args!($($arg)*))
    };
}

/// Error-level log.
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Error, $($arg)*) };
}

/// Warn-level log.
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Warn, $($arg)*) };
}

/// Info-level log.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Info, $($arg)*) };
}

/// Debug-level log.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Debug, $($arg)*) };
}

/// Trace-level log (event-loop granularity; very chatty).
#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Trace, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn ordering_semantics() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Info);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Warn); // restore-ish for other tests
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error);
        info!("should be suppressed {}", 42);
        error!("visible error {}", 1);
        set_level(Level::Warn);
    }
}
