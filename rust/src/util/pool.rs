//! A shared scoped-thread fan-out helper: run `n` index-identified jobs
//! on a small worker pool with deterministic, identity-ordered results.
//!
//! Both the sweep engine's pair-job matrix and the planner's candidate
//! evaluation use this shape: workers pull job indices from a shared
//! atomic counter (dynamic load balancing — job costs vary wildly), and
//! the outputs are reassembled in index order afterwards, so the result
//! is byte-identical to a sequential run no matter the thread count or
//! scheduling interleave.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(0..n)` on up to `threads` scoped workers and return the
/// results in index order. `threads <= 1` (or `n <= 1`) degenerates to
/// a plain sequential loop with zero thread overhead; the parallel
/// path is observationally identical because results are reordered by
/// job index before returning.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Sync + Fn(usize) -> T,
{
    let threads = threads.min(n).max(1);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                out.lock().expect("pool output lock poisoned").push((i, v));
            });
        }
    });
    let mut pairs = out.into_inner().expect("pool output lock poisoned");
    pairs.sort_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, v)| v).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_identity_ordered_at_any_width() {
        let seq = run_indexed(17, 1, |i| i * i);
        for threads in [2, 3, 8, 32] {
            assert_eq!(run_indexed(17, threads, |i| i * i), seq, "{threads} threads");
        }
        assert_eq!(run_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn jobs_run_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let calls = AtomicUsize::new(0);
        let v = run_indexed(100, 7, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert_eq!(v, (0..100).collect::<Vec<_>>());
    }
}
