//! ASCII table rendering for reports and bench output.
//!
//! Every table/figure regeneration bench prints through this module so
//! the output is uniform and diffable against EXPERIMENTS.md.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple ASCII table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers. Numeric-looking columns
    /// default to right alignment once rows are added; override with
    /// [`Table::aligns`].
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        let headers: Vec<String> = headers.into_iter().map(Into::into).collect();
        let n = headers.len();
        Table {
            title: None,
            headers,
            aligns: vec![Align::Right; n],
            rows: Vec::new(),
        }
    }

    /// Set a title rendered above the table.
    pub fn title<S: Into<String>>(mut self, title: S) -> Self {
        self.title = Some(title.into());
        self
    }

    /// Override column alignments (panics on length mismatch).
    pub fn aligns(mut self, aligns: Vec<Align>) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns;
        self
    }

    /// Mark the first `n` columns left-aligned (label columns).
    pub fn left_cols(mut self, n: usize) -> Self {
        for a in self.aligns.iter_mut().take(n) {
            *a = Align::Left;
        }
        self
    }

    /// Add a row (panics on column-count mismatch).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row has {} cells, table has {} columns",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    /// Add a separator row (rendered as a rule).
    pub fn rule(&mut self) -> &mut Self {
        self.rows.push(Vec::new()); // empty row encodes a rule
        self
    }

    /// Number of data rows (rules excluded).
    pub fn len(&self) -> usize {
        self.rows.iter().filter(|r| !r.is_empty()).count()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let rule: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = widths[i] - cell.chars().count();
                match aligns[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(cell);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(cell);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&rule);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers, &vec![Align::Left; ncols]));
        out.push('\n');
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            if row.is_empty() {
                out.push_str(&rule);
            } else {
                out.push_str(&fmt_row(row, &self.aligns));
            }
            out.push('\n');
        }
        out.push_str(&rule);
        out.push('\n');
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as tab-separated values (for piping into plotting tools).
    pub fn to_tsv(&self) -> String {
        let mut out = self.headers.join("\t");
        out.push('\n');
        for row in &self.rows {
            if row.is_empty() {
                continue;
            }
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }
}

/// Format an f64 with fixed decimals — table cell helper.
pub fn f(v: f64, decimals: usize) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.decimals$}")
    }
}

/// Format a speedup like `1.43x`.
pub fn speedup(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.2}x")
    }
}

/// Format a percentage like `72%` (already in 0-100 space).
pub fn pct(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.0}%")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new(vec!["name", "value"]).left_cols(1);
        t.row(vec!["alpha", "1.00"]);
        t.row(vec!["b", "123.45"]);
        let r = t.render();
        assert!(r.contains("| alpha |"));
        assert!(r.contains("| 123.45 |"));
        // All lines same width.
        let widths: Vec<usize> = r.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn title_and_rule() {
        let mut t = Table::new(vec!["a"]).title("T");
        t.row(vec!["1"]);
        t.rule();
        t.row(vec!["2"]);
        let r = t.render();
        assert!(r.starts_with("T\n"));
        assert_eq!(r.matches("+---+").count(), 4); // top, after header, mid-rule, bottom
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn tsv_skips_rules() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        t.rule();
        t.row(vec!["3", "4"]);
        assert_eq!(t.to_tsv(), "a\tb\n1\t2\n3\t4\n");
    }

    #[test]
    fn cell_formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(speedup(1.666), "1.67x");
        assert_eq!(pct(72.4), "72%");
        assert_eq!(f(f64::NAN, 2), "-");
    }
}
