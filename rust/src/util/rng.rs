//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement the two small,
//! well-known generators we need ourselves:
//!
//! * [`SplitMix64`] — used for seeding (it is the recommended seeder for
//!   the xoshiro family; passes quick statistical checks and cannot be
//!   "zero-locked").
//! * [`Xoshiro256StarStar`] — the workhorse generator used by workload
//!   generation, jitter injection and the property-test harness.
//!
//! Everything here is deterministic given a seed: simulator runs, property
//! tests and benches are exactly reproducible.

/// SplitMix64: a tiny 64-bit generator, mainly used to expand a single
/// `u64` seed into the 256-bit state of [`Xoshiro256StarStar`].
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna). All-purpose 64-bit generator with
/// 256 bits of state; not cryptographic, excellent for simulation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (handles the all-zero-state hazard).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `u64` in `[0, bound)` using Lemire's multiply-shift with
    /// rejection for exact uniformity.
    pub fn u64_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "u64_below: bound must be positive");
        // Rejection sampling over the top of the range to kill modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.u64_below(bound as u64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "i64_in: empty range");
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.u64_below(span) as i64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Log-uniform `f64` in `[lo, hi)` — useful for size sweeps spanning
    /// decades (collective payloads from 1 MiB to 20 GiB).
    pub fn f64_log_in(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.f64_in(lo.ln(), hi.ln())).exp()
    }

    /// `true` with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; we don't cache
    /// the second — simplicity over speed, this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/stddev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choice: empty slice");
        &xs[self.usize_below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (for parallel / nested deterministic streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 from the published C code.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let mut r3 = Rng::new(43);
        let s1: Vec<u64> = (0..16).map(|_| r1.next_u64()).collect();
        let s2: Vec<u64> = (0..16).map(|_| r2.next_u64()).collect();
        let s3: Vec<u64> = (0..16).map(|_| r3.next_u64()).collect();
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn u64_below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.u64_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn u64_below_unbiased_rough() {
        // Chi-square-ish sanity: each bucket of 8 should get ~12.5% ± 2%.
        let mut r = Rng::new(11);
        let n = 80_000;
        let mut counts = [0usize; 8];
        for _ in 0..n {
            counts[r.u64_below(8) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.125).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn i64_in_inclusive_bounds() {
        let mut r = Rng::new(13);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.i64_in(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn log_uniform_spans_decades() {
        let mut r = Rng::new(23);
        let mut lo_dec = 0;
        let mut hi_dec = 0;
        for _ in 0..5_000 {
            let x = r.f64_log_in(1e6, 1e10);
            assert!((1e6..1e10).contains(&x));
            if x < 1e7 {
                lo_dec += 1;
            }
            if x > 1e9 {
                hi_dec += 1;
            }
        }
        // Each decade should get ~25% of samples under log-uniform.
        assert!(lo_dec > 800 && hi_dec > 800);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(29);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
