//! Criterion-lite: a micro-benchmark harness for `harness = false`
//! benches (the offline build has no `criterion` crate).
//!
//! Two kinds of benches coexist in `benches/`:
//!
//! 1. **Wall-clock micro-benches** over the simulator hot path
//!    ([`Bencher::bench`]) — warmup + timed iterations, median/stddev.
//! 2. **Figure/table regenerations** — model outputs printed as tables;
//!    these use [`Bencher::section`] for uniform headers and the filter
//!    arg (`cargo bench --bench fig8_c3_strategies -- <filter>`).

use std::time::Instant;

use crate::util::stats::Summary;
use crate::util::table::Table;
use crate::util::units::fmt_seconds;

/// Harness entry: parses the CLI args cargo-bench passes through
/// (`--bench` flag and an optional name filter) and runs benches.
pub struct Bencher {
    filter: Option<String>,
    /// (name, summary) for every wall-clock bench that ran.
    results: Vec<(String, Summary)>,
    warmup_iters: usize,
    measure_iters: usize,
    /// Smoke mode (CI bit-rot guard): clamp every bench to 0 warmup /
    /// 1 measured iteration regardless of later `iters()` calls, so
    /// all bench binaries execute end-to-end in seconds. Enabled by a
    /// `--smoke` arg or the `CONCCL_BENCH_SMOKE` env var.
    smoke: bool,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::from_args()
    }
}

impl Bencher {
    /// Build from `std::env::args()`: skips the flags cargo passes
    /// (`--bench`), honors `--smoke` / `CONCCL_BENCH_SMOKE`, treats the
    /// first free arg as a substring filter.
    pub fn from_args() -> Self {
        let mut filter = None;
        let mut smoke =
            std::env::var_os("CONCCL_BENCH_SMOKE").is_some_and(|v| !v.is_empty() && v != "0");
        for a in std::env::args().skip(1) {
            if a == "--smoke" {
                smoke = true;
                continue;
            }
            if a == "--bench" || a.starts_with("--") {
                continue;
            }
            filter = Some(a);
            break;
        }
        Bencher {
            filter,
            results: Vec::new(),
            warmup_iters: 3,
            measure_iters: 10,
            smoke,
        }
    }

    /// Override iteration counts (paper protocol: 6 warmup / 9 measured).
    /// Smoke mode wins: the clamp survives any `iters()` call.
    pub fn iters(mut self, warmup: usize, measure: usize) -> Self {
        self.warmup_iters = warmup;
        self.measure_iters = measure;
        self
    }

    /// Is smoke mode active?
    pub fn smoke(&self) -> bool {
        self.smoke
    }

    /// Effective (warmup, measure) iteration counts.
    fn effective_iters(&self) -> (usize, usize) {
        if self.smoke {
            (0, 1)
        } else {
            (self.warmup_iters, self.measure_iters)
        }
    }

    /// Should this named bench run under the current filter?
    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Print a section header (used by figure-regeneration benches).
    pub fn section(&self, name: &str) {
        if self.enabled(name) {
            println!("\n=== {name} ===");
        }
    }

    /// Time a closure: `warmup` untimed runs then `measure` timed runs.
    /// Returns the summary and prints one line. The closure's return value
    /// is black-boxed to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Option<Summary> {
        if !self.enabled(name) {
            return None;
        }
        let (warmup, measure) = self.effective_iters();
        for _ in 0..warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(measure);
        for _ in 0..measure {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Summary::of(&samples);
        println!(
            "bench {name:<48} median {:>10}  mean {:>10}  sd {:>10}  (n={})",
            fmt_seconds(s.median),
            fmt_seconds(s.mean),
            fmt_seconds(s.stddev),
            s.n
        );
        self.results.push((name.to_string(), s));
        Some(s)
    }

    /// Print a closing summary table of all wall-clock benches.
    pub fn finish(&self) {
        if self.results.is_empty() {
            return;
        }
        let mut t = Table::new(vec!["bench", "median", "mean", "stddev", "n"]).left_cols(1);
        for (name, s) in &self.results {
            t.row(vec![
                name.clone(),
                fmt_seconds(s.median),
                fmt_seconds(s.mean),
                fmt_seconds(s.stddev),
                s.n.to_string(),
            ]);
        }
        println!();
        t.title("wall-clock summary").print();
    }
}

/// A best-effort `black_box` on stable rust.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(filter: Option<&str>) -> Bencher {
        Bencher {
            filter: filter.map(String::from),
            results: Vec::new(),
            warmup_iters: 1,
            measure_iters: 3,
            smoke: false,
        }
    }

    #[test]
    fn filter_gates_benches() {
        let b = mk(Some("fig8"));
        assert!(b.enabled("fig8_c3_strategies"));
        assert!(!b.enabled("fig9_conccl"));
        let b = mk(None);
        assert!(b.enabled("anything"));
    }

    #[test]
    fn bench_collects_samples() {
        let mut b = mk(None);
        let mut calls = 0;
        let s = b.bench("noop", || {
            calls += 1;
        });
        let s = s.unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(calls, 4); // 1 warmup + 3 measured
        assert!(s.median >= 0.0);
    }

    #[test]
    fn filtered_bench_returns_none() {
        let mut b = mk(Some("nope"));
        let mut calls = 0;
        assert!(b.bench("other", || calls += 1).is_none());
        assert_eq!(calls, 0);
    }

    #[test]
    fn smoke_mode_clamps_iterations_even_after_iters() {
        let mut b = Bencher {
            filter: None,
            results: Vec::new(),
            warmup_iters: 1,
            measure_iters: 3,
            smoke: true,
        }
        .iters(6, 9); // the paper protocol must NOT undo the clamp
        assert!(b.smoke());
        let mut calls = 0;
        let s = b.bench("fast", || calls += 1).unwrap();
        assert_eq!(calls, 1, "smoke = 0 warmup + 1 measured");
        assert_eq!(s.n, 1);
    }
}
