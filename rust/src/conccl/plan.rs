//! Command plans for ConCCL's direct-algorithm collectives (§VI-B).
//!
//! The paper's PoCs "break down the collective operation into a series
//! of individual transfers … and schedule each such transfer on a
//! specific available DMA engine". These builders emit exactly those
//! per-GPU command-packet lists; `gpu::sdma::schedule` prices them and
//! `node::Node::execute_dma` moves the bytes.
//!
//! Ordering matters for the launch-cost model: peer transfers are
//! enqueued first (they ride the slow fabric links), the local shard
//! copy last (it rides local HBM and is never the critical path).

use crate::gpu::memory::BufferId;
use crate::gpu::sdma::CommandPacket;

/// Direct all-gather: every GPU pushes its shard to every peer's output
/// buffer at the shard's slot, plus one local copy into its own output.
pub fn allgather_plan(
    n: usize,
    shards: &[BufferId],
    outs: &[BufferId],
    shard_len: usize,
) -> Vec<Vec<CommandPacket>> {
    assert_eq!(shards.len(), n);
    assert_eq!(outs.len(), n);
    let mut per_gpu = vec![Vec::with_capacity(n); n];
    for g in 0..n {
        for d in (0..n).filter(|&d| d != g) {
            per_gpu[g].push(CommandPacket {
                src_gpu: g,
                src: shards[g],
                src_off: 0,
                dst_gpu: d,
                dst: outs[d],
                dst_off: g * shard_len,
                len: shard_len,
            });
        }
        per_gpu[g].push(CommandPacket {
            src_gpu: g,
            src: shards[g],
            src_off: 0,
            dst_gpu: g,
            dst: outs[g],
            dst_off: g * shard_len,
            len: shard_len,
        });
    }
    per_gpu
}

/// Direct all-to-all: GPU `g`'s input chunk `d` lands in GPU `d`'s
/// output at slot `g` (the "transpose of data buffers", §IV-C).
pub fn alltoall_plan(
    n: usize,
    ins: &[BufferId],
    outs: &[BufferId],
    chunk_len: usize,
) -> Vec<Vec<CommandPacket>> {
    assert_eq!(ins.len(), n);
    assert_eq!(outs.len(), n);
    let mut per_gpu = vec![Vec::with_capacity(n); n];
    for g in 0..n {
        for d in (0..n).filter(|&d| d != g) {
            per_gpu[g].push(CommandPacket {
                src_gpu: g,
                src: ins[g],
                src_off: d * chunk_len,
                dst_gpu: d,
                dst: outs[d],
                dst_off: g * chunk_len,
                len: chunk_len,
            });
        }
        per_gpu[g].push(CommandPacket {
            src_gpu: g,
            src: ins[g],
            src_off: g * chunk_len,
            dst_gpu: g,
            dst: outs[g],
            dst_off: g * chunk_len,
            len: chunk_len,
        });
    }
    per_gpu
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize, base: u64) -> Vec<BufferId> {
        (0..n as u64).map(|i| BufferId(base + i)).collect()
    }

    #[test]
    fn allgather_plan_structure() {
        let n = 8;
        let plan = allgather_plan(n, &ids(n, 0), &ids(n, 100), 64);
        assert_eq!(plan.len(), n);
        for (g, cmds) in plan.iter().enumerate() {
            assert_eq!(cmds.len(), n, "gpu {g}: 7 peers + 1 local");
            // Local copy is last.
            let local = cmds.last().unwrap();
            assert_eq!(local.src_gpu, g);
            assert_eq!(local.dst_gpu, g);
            // Every destination slot is g's shard slot.
            for c in cmds {
                assert_eq!(c.dst_off, g * 64);
                assert_eq!(c.src_off, 0);
                assert_eq!(c.len, 64);
            }
            // All 8 destinations covered exactly once.
            let mut dsts: Vec<usize> = cmds.iter().map(|c| c.dst_gpu).collect();
            dsts.sort_unstable();
            assert_eq!(dsts, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn alltoall_plan_is_transpose() {
        let n = 4;
        let chunk = 32;
        let plan = alltoall_plan(n, &ids(n, 0), &ids(n, 100), chunk);
        for (g, cmds) in plan.iter().enumerate() {
            assert_eq!(cmds.len(), n);
            for c in cmds {
                // Chunk d of src g lands at slot g of dst d.
                assert_eq!(c.src_off, c.dst_gpu * chunk);
                assert_eq!(c.dst_off, g * chunk);
            }
        }
    }

    #[test]
    fn plans_cover_all_ordered_pairs_once() {
        let n = 8;
        for plan in [
            allgather_plan(n, &ids(n, 0), &ids(n, 100), 8),
            alltoall_plan(n, &ids(n, 0), &ids(n, 100), 8),
        ] {
            let mut pairs = std::collections::BTreeSet::new();
            for cmds in &plan {
                for c in cmds {
                    assert!(pairs.insert((c.src_gpu, c.dst_gpu)), "dup pair");
                }
            }
            assert_eq!(pairs.len(), n * n);
        }
    }
}
