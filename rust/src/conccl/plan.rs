//! Command plans for ConCCL's direct-algorithm collectives (§VI-B).
//!
//! The paper's PoCs "break down the collective operation into a series
//! of individual transfers … and schedule each such transfer on a
//! specific available DMA engine". These builders emit exactly those
//! per-GPU command-packet lists; `gpu::sdma::schedule` prices them and
//! `node::Node::execute_dma` moves the bytes.
//!
//! Ordering matters for the launch-cost model: peer transfers are
//! enqueued first (they ride the slow fabric links), the local shard
//! copy last (it rides local HBM and is never the critical path).
//!
//! On a [`Topology::MultiNode`] the direct algorithm is replaced by a
//! *hierarchical* one ([`allgather_hier`] / [`alltoall_hier`]): an
//! intra-node direct phase, an inter-node leader exchange over the NIC
//! mesh, and an intra-node scatter — with a barrier between phases
//! (priced by `gpu::sdma::schedule_phases`). Every plan preserves the
//! conservation invariant checked by [`check_conservation`]: each byte
//! of each final output buffer is written exactly once.

use crate::fabric::Topology;
use crate::gpu::memory::BufferId;
use crate::gpu::sdma::CommandPacket;

/// A command plan split into barrier-separated phases:
/// `phases[p][g]` is GPU `g`'s command list for phase `p`.
#[derive(Debug, Clone, PartialEq)]
pub struct PhasedPlan {
    pub phases: Vec<Vec<Vec<CommandPacket>>>,
}

impl PhasedPlan {
    /// Iterate every command in phase order.
    pub fn commands(&self) -> impl Iterator<Item = &CommandPacket> + '_ {
        self.phases.iter().flatten().flatten()
    }
}

/// Direct all-gather: every GPU pushes its shard to every peer's output
/// buffer at the shard's slot, plus one local copy into its own output.
pub fn allgather_plan(
    n: usize,
    shards: &[BufferId],
    outs: &[BufferId],
    shard_len: usize,
) -> Vec<Vec<CommandPacket>> {
    assert_eq!(shards.len(), n);
    assert_eq!(outs.len(), n);
    let mut per_gpu = vec![Vec::with_capacity(n); n];
    for g in 0..n {
        for d in (0..n).filter(|&d| d != g) {
            per_gpu[g].push(CommandPacket {
                src_gpu: g,
                src: shards[g],
                src_off: 0,
                dst_gpu: d,
                dst: outs[d],
                dst_off: g * shard_len,
                len: shard_len,
            });
        }
        per_gpu[g].push(CommandPacket {
            src_gpu: g,
            src: shards[g],
            src_off: 0,
            dst_gpu: g,
            dst: outs[g],
            dst_off: g * shard_len,
            len: shard_len,
        });
    }
    per_gpu
}

/// Direct all-to-all: GPU `g`'s input chunk `d` lands in GPU `d`'s
/// output at slot `g` (the "transpose of data buffers", §IV-C).
pub fn alltoall_plan(
    n: usize,
    ins: &[BufferId],
    outs: &[BufferId],
    chunk_len: usize,
) -> Vec<Vec<CommandPacket>> {
    assert_eq!(ins.len(), n);
    assert_eq!(outs.len(), n);
    let mut per_gpu = vec![Vec::with_capacity(n); n];
    for g in 0..n {
        for d in (0..n).filter(|&d| d != g) {
            per_gpu[g].push(CommandPacket {
                src_gpu: g,
                src: ins[g],
                src_off: d * chunk_len,
                dst_gpu: d,
                dst: outs[d],
                dst_off: g * chunk_len,
                len: chunk_len,
            });
        }
        per_gpu[g].push(CommandPacket {
            src_gpu: g,
            src: ins[g],
            src_off: g * chunk_len,
            dst_gpu: g,
            dst: outs[g],
            dst_off: g * chunk_len,
            len: chunk_len,
        });
    }
    per_gpu
}

/// Reduce-scatter *movement* plan: DMA engines cannot reduce (§VI-B),
/// so the offloadable part of a reduce-scatter is gathering every
/// source's segment `d` into GPU `d`'s staging buffer (`stages[d]`,
/// `n × seg_len` bytes, slot `g` holding GPU `g`'s contribution); the
/// owner then reduces the staged columns on its CUs. Works unchanged on
/// multi-node topologies: non-adjacent transfers store-and-forward
/// through the leaders exactly as `gpu::sdma::schedule` prices them.
/// [`check_conservation`] holds over the staging buffers — every staged
/// byte is written exactly once.
pub fn reduce_scatter_plan(
    n: usize,
    ins: &[BufferId],
    stages: &[BufferId],
    seg_len: usize,
) -> Vec<Vec<CommandPacket>> {
    assert_eq!(ins.len(), n);
    assert_eq!(stages.len(), n);
    let mut per_gpu = vec![Vec::with_capacity(n); n];
    for g in 0..n {
        for d in (0..n).filter(|&d| d != g) {
            per_gpu[g].push(CommandPacket {
                src_gpu: g,
                src: ins[g],
                src_off: d * seg_len,
                dst_gpu: d,
                dst: stages[d],
                dst_off: g * seg_len,
                len: seg_len,
            });
        }
        per_gpu[g].push(CommandPacket {
            src_gpu: g,
            src: ins[g],
            src_off: g * seg_len,
            dst_gpu: g,
            dst: stages[g],
            dst_off: g * seg_len,
            len: seg_len,
        });
    }
    per_gpu
}

/// Hierarchical all-gather on `topo`. Single node: one phase, the
/// direct plan. Multi-node, with `L_i` = node `i`'s leader:
///
/// 1. **intra-node all-gather** — every GPU pushes its shard to every
///    node peer's output at the shard's global slot (+ local copy);
/// 2. **leader exchange** — `L_i` sends its node's now-contiguous
///    block `outs[L_i][i·P·shard ..]` to every other leader's output
///    over the NIC mesh;
/// 3. **scatter** — each leader forwards every received remote block to
///    its node peers' outputs.
///
/// Leaders stage through their *output* buffer (no scratch needed);
/// every output byte is still written exactly once.
pub fn allgather_hier(
    topo: &Topology,
    shards: &[BufferId],
    outs: &[BufferId],
    shard_len: usize,
) -> PhasedPlan {
    let n = topo.num_gpus();
    assert_eq!(shards.len(), n);
    assert_eq!(outs.len(), n);
    if topo.num_nodes() == 1 {
        return PhasedPlan {
            phases: vec![allgather_plan(n, shards, outs, shard_len)],
        };
    }
    let (nodes, p) = (topo.num_nodes(), topo.gpus_per_node());
    let block = p * shard_len; // one node's worth of shards
    let mut ph1 = vec![Vec::new(); n];
    for g in 0..n {
        let i = topo.node_of(g);
        for d in (i * p..(i + 1) * p).filter(|&d| d != g) {
            ph1[g].push(CommandPacket {
                src_gpu: g,
                src: shards[g],
                src_off: 0,
                dst_gpu: d,
                dst: outs[d],
                dst_off: g * shard_len,
                len: shard_len,
            });
        }
        ph1[g].push(CommandPacket {
            src_gpu: g,
            src: shards[g],
            src_off: 0,
            dst_gpu: g,
            dst: outs[g],
            dst_off: g * shard_len,
            len: shard_len,
        });
    }
    let mut ph2 = vec![Vec::new(); n];
    for i in 0..nodes {
        let li = topo.leader_of(i);
        for j in (0..nodes).filter(|&j| j != i) {
            let lj = topo.leader_of(j);
            ph2[li].push(CommandPacket {
                src_gpu: li,
                src: outs[li],
                src_off: i * block,
                dst_gpu: lj,
                dst: outs[lj],
                dst_off: i * block,
                len: block,
            });
        }
    }
    let mut ph3 = vec![Vec::new(); n];
    for i in 0..nodes {
        let li = topo.leader_of(i);
        for j in (0..nodes).filter(|&j| j != i) {
            for d in (i * p..(i + 1) * p).filter(|&d| d != li) {
                ph3[li].push(CommandPacket {
                    src_gpu: li,
                    src: outs[li],
                    src_off: j * block,
                    dst_gpu: d,
                    dst: outs[d],
                    dst_off: j * block,
                    len: block,
                });
            }
        }
    }
    PhasedPlan {
        phases: vec![ph1, ph2, ph3],
    }
}

/// Per-leader staging-buffer size (bytes) the hierarchical all-to-all
/// needs on each side (outbound and inbound): one `P×P` chunk block per
/// remote node. Zero on a single node.
pub fn a2a_stage_bytes(topo: &Topology, chunk_len: usize) -> usize {
    let p = topo.gpus_per_node();
    (topo.num_nodes() - 1) * p * p * chunk_len
}

/// Hierarchical all-to-all on `topo`. Single node: one phase, the
/// direct transpose. Multi-node:
///
/// 1. **intra + stage** — each GPU delivers node-local chunks directly
///    and funnels every remote-bound chunk into its leader's
///    `stage_out` buffer (laid out so each remote node's block is
///    contiguous: `[remote node][dst][src]`);
/// 2. **leader exchange** — `L_i` ships each remote node's whole block
///    to that leader's `stage_in` over the NIC;
/// 3. **scatter** — each leader unpacks `stage_in` into its node's
///    outputs (one contiguous `P·chunk` run per (source node, dst)).
///
/// `stage_out[i]` / `stage_in[i]` are buffers on node `i`'s leader of
/// at least [`a2a_stage_bytes`] bytes each (unused on a single node).
pub fn alltoall_hier(
    topo: &Topology,
    ins: &[BufferId],
    outs: &[BufferId],
    stage_out: &[BufferId],
    stage_in: &[BufferId],
    chunk_len: usize,
) -> PhasedPlan {
    let n = topo.num_gpus();
    assert_eq!(ins.len(), n);
    assert_eq!(outs.len(), n);
    if topo.num_nodes() == 1 {
        return PhasedPlan {
            phases: vec![alltoall_plan(n, ins, outs, chunk_len)],
        };
    }
    let (nodes, p) = (topo.num_nodes(), topo.gpus_per_node());
    assert_eq!(stage_out.len(), nodes);
    assert_eq!(stage_in.len(), nodes);
    // Rank of node `other` among node `of`'s remote nodes (dense 0..N-1).
    let rank = |of: usize, other: usize| if other < of { other } else { other - 1 };
    let blk = p * p * chunk_len;
    let mut ph1 = vec![Vec::new(); n];
    for g in 0..n {
        let i = topo.node_of(g);
        let li = topo.leader_of(i);
        for d in (i * p..(i + 1) * p).filter(|&d| d != g) {
            ph1[g].push(CommandPacket {
                src_gpu: g,
                src: ins[g],
                src_off: d * chunk_len,
                dst_gpu: d,
                dst: outs[d],
                dst_off: g * chunk_len,
                len: chunk_len,
            });
        }
        for d in (0..n).filter(|&d| topo.node_of(d) != i) {
            let j = topo.node_of(d);
            let off = (rank(i, j) * p * p + (d - j * p) * p + (g - i * p)) * chunk_len;
            ph1[g].push(CommandPacket {
                src_gpu: g,
                src: ins[g],
                src_off: d * chunk_len,
                dst_gpu: li,
                dst: stage_out[i],
                dst_off: off,
                len: chunk_len,
            });
        }
        ph1[g].push(CommandPacket {
            src_gpu: g,
            src: ins[g],
            src_off: g * chunk_len,
            dst_gpu: g,
            dst: outs[g],
            dst_off: g * chunk_len,
            len: chunk_len,
        });
    }
    let mut ph2 = vec![Vec::new(); n];
    for i in 0..nodes {
        let li = topo.leader_of(i);
        for j in (0..nodes).filter(|&j| j != i) {
            ph2[li].push(CommandPacket {
                src_gpu: li,
                src: stage_out[i],
                src_off: rank(i, j) * blk,
                dst_gpu: topo.leader_of(j),
                dst: stage_in[j],
                dst_off: rank(j, i) * blk,
                len: blk,
            });
        }
    }
    let mut ph3 = vec![Vec::new(); n];
    for j in 0..nodes {
        let lj = topo.leader_of(j);
        for i in (0..nodes).filter(|&i| i != j) {
            for d in j * p..(j + 1) * p {
                // Chunks from node i's sources to `d` sit contiguously
                // (ordered by source), matching out[d]'s slot run.
                ph3[lj].push(CommandPacket {
                    src_gpu: lj,
                    src: stage_in[j],
                    src_off: (rank(j, i) * p * p + (d - j * p) * p) * chunk_len,
                    dst_gpu: d,
                    dst: outs[d],
                    dst_off: i * p * chunk_len,
                    len: p * chunk_len,
                });
            }
        }
    }
    PhasedPlan {
        phases: vec![ph1, ph2, ph3],
    }
}

/// Chunk a phased plan for the fine-grain pipeline: every phase is
/// split into `chunks` barrier-separated chunk batches
/// ([`crate::gpu::sdma::chunk_commands`] — slice `j` of every packet),
/// so each chunk pays its own per-packet launch and sync. The chunked
/// plan moves *exactly* the bytes of the original (chunking is a
/// scheduling decision): [`check_conservation`] holds for one iff it
/// holds for the other, and the data plane lands byte-identical
/// outputs — asserted across topologies by `rust/tests/hierarchy.rs`.
pub fn chunk_phased(plan: &PhasedPlan, chunks: usize) -> PhasedPlan {
    PhasedPlan {
        phases: plan
            .phases
            .iter()
            .flat_map(|per_gpu| crate::gpu::sdma::chunk_commands(per_gpu, chunks))
            .collect(),
    }
}

/// Conservation invariant: every byte of every final output buffer
/// (`outs[g]` on GPU `g`, each `out_len` bytes) is written exactly once
/// across the whole plan. Writes to other buffers (staging) are
/// ignored. Returns a description of the first violation.
pub fn check_conservation(
    plan: &PhasedPlan,
    outs: &[BufferId],
    out_len: usize,
) -> Result<(), String> {
    let mut writes: Vec<Vec<u32>> = outs.iter().map(|_| vec![0u32; out_len]).collect();
    for c in plan.commands() {
        if c.dst_gpu >= outs.len() || c.dst != outs[c.dst_gpu] {
            continue; // staging or foreign buffer
        }
        if c.dst_off + c.len > out_len {
            return Err(format!(
                "write OOB on gpu {}: {}+{} > {}",
                c.dst_gpu, c.dst_off, c.len, out_len
            ));
        }
        for w in &mut writes[c.dst_gpu][c.dst_off..c.dst_off + c.len] {
            *w += 1;
            if *w > 1 {
                return Err(format!(
                    "gpu {} output byte range [{}, {}) written more than once",
                    c.dst_gpu,
                    c.dst_off,
                    c.dst_off + c.len
                ));
            }
        }
    }
    for (g, w) in writes.iter().enumerate() {
        if let Some(off) = w.iter().position(|&x| x == 0) {
            return Err(format!("gpu {g} output byte {off} never written"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize, base: u64) -> Vec<BufferId> {
        (0..n as u64).map(|i| BufferId(base + i)).collect()
    }

    #[test]
    fn allgather_plan_structure() {
        let n = 8;
        let plan = allgather_plan(n, &ids(n, 0), &ids(n, 100), 64);
        assert_eq!(plan.len(), n);
        for (g, cmds) in plan.iter().enumerate() {
            assert_eq!(cmds.len(), n, "gpu {g}: 7 peers + 1 local");
            // Local copy is last.
            let local = cmds.last().unwrap();
            assert_eq!(local.src_gpu, g);
            assert_eq!(local.dst_gpu, g);
            // Every destination slot is g's shard slot.
            for c in cmds {
                assert_eq!(c.dst_off, g * 64);
                assert_eq!(c.src_off, 0);
                assert_eq!(c.len, 64);
            }
            // All 8 destinations covered exactly once.
            let mut dsts: Vec<usize> = cmds.iter().map(|c| c.dst_gpu).collect();
            dsts.sort_unstable();
            assert_eq!(dsts, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn alltoall_plan_is_transpose() {
        let n = 4;
        let chunk = 32;
        let plan = alltoall_plan(n, &ids(n, 0), &ids(n, 100), chunk);
        for (g, cmds) in plan.iter().enumerate() {
            assert_eq!(cmds.len(), n);
            for c in cmds {
                // Chunk d of src g lands at slot g of dst d.
                assert_eq!(c.src_off, c.dst_gpu * chunk);
                assert_eq!(c.dst_off, g * chunk);
            }
        }
    }

    #[test]
    fn reduce_scatter_plan_stages_every_segment_once() {
        let n = 8;
        let seg = 16;
        let plan = reduce_scatter_plan(n, &ids(n, 0), &ids(n, 100), seg);
        // Every staging buffer byte is written exactly once.
        let phased = PhasedPlan {
            phases: vec![plan.clone()],
        };
        check_conservation(&phased, &ids(n, 100), n * seg).unwrap();
        for (g, cmds) in plan.iter().enumerate() {
            assert_eq!(cmds.len(), n, "gpu {g}: n-1 peers + 1 local");
            for c in cmds {
                // Source slot is the destination's segment; staged at
                // the source's slot in the owner's staging buffer.
                assert_eq!(c.src_off, c.dst_gpu * seg);
                assert_eq!(c.dst_off, g * seg);
            }
        }
    }

    #[test]
    fn hier_plans_collapse_to_direct_on_single_node() {
        let n = 8;
        let t = Topology::fully_connected(n);
        let ag = allgather_hier(&t, &ids(n, 0), &ids(n, 100), 64);
        assert_eq!(ag.phases.len(), 1);
        assert_eq!(ag.phases[0], allgather_plan(n, &ids(n, 0), &ids(n, 100), 64));
        let a2a = alltoall_hier(&t, &ids(n, 0), &ids(n, 100), &[], &[], 32);
        assert_eq!(a2a.phases.len(), 1);
        assert_eq!(a2a.phases[0], alltoall_plan(n, &ids(n, 0), &ids(n, 100), 32));
        assert_eq!(a2a_stage_bytes(&t, 32), 0);
    }

    #[test]
    fn hier_allgather_conserves_and_stays_adjacent() {
        for (nodes, p) in [(2usize, 4usize), (4, 2), (2, 2), (4, 1)] {
            let t = Topology::multi_node(nodes, p, 50e9, 5e-6);
            let n = t.num_gpus();
            let shard = 16;
            let shards = ids(n, 0);
            let outs = ids(n, 100);
            let plan = allgather_hier(&t, &shards, &outs, shard);
            assert_eq!(plan.phases.len(), 3);
            check_conservation(&plan, &outs, n * shard)
                .unwrap_or_else(|e| panic!("{nodes}x{p}: {e}"));
            for c in plan.commands() {
                assert!(
                    c.src_gpu == c.dst_gpu || t.are_adjacent(c.src_gpu, c.dst_gpu),
                    "{nodes}x{p}: non-adjacent command {c:?}"
                );
            }
        }
    }

    #[test]
    fn hier_alltoall_conserves_and_stays_adjacent() {
        for (nodes, p) in [(2usize, 4usize), (4, 2), (2, 2)] {
            let t = Topology::multi_node(nodes, p, 50e9, 5e-6);
            let n = t.num_gpus();
            let chunk = 8;
            let ins = ids(n, 0);
            let outs = ids(n, 100);
            let so = ids(nodes, 200);
            let si = ids(nodes, 300);
            let plan = alltoall_hier(&t, &ins, &outs, &so, &si, chunk);
            assert_eq!(plan.phases.len(), 3);
            check_conservation(&plan, &outs, n * chunk)
                .unwrap_or_else(|e| panic!("{nodes}x{p}: {e}"));
            for c in plan.commands() {
                assert!(
                    c.src_gpu == c.dst_gpu || t.are_adjacent(c.src_gpu, c.dst_gpu),
                    "{nodes}x{p}: non-adjacent command {c:?}"
                );
            }
            // Staging writes stay inside the declared staging size.
            let cap = a2a_stage_bytes(&t, chunk);
            for c in plan.commands() {
                if so.contains(&c.dst) || si.contains(&c.dst) {
                    assert!(c.dst_off + c.len <= cap, "staging OOB: {c:?}");
                }
            }
        }
    }

    #[test]
    fn chunked_plans_conserve_on_every_topology() {
        // The chunked plan writes exactly the same output bytes as the
        // whole plan — holes/doubles would fail the conservation check.
        for (nodes, p) in [(1usize, 8usize), (2, 4), (4, 2)] {
            let t = if nodes == 1 {
                Topology::fully_connected(p)
            } else {
                Topology::multi_node(nodes, p, 50e9, 5e-6)
            };
            let n = t.num_gpus();
            let shard = 24; // not divisible by 16: exercises ragged slices
            let outs = ids(n, 100);
            let ag = allgather_hier(&t, &ids(n, 0), &outs, shard);
            for k in [1usize, 2, 3, 8, 16] {
                let chunked = chunk_phased(&ag, k);
                assert!(chunked.phases.len() >= ag.phases.len());
                check_conservation(&chunked, &outs, n * shard)
                    .unwrap_or_else(|e| panic!("{nodes}x{p} k={k}: {e}"));
                // Same multiset of moved bytes.
                let total: usize = chunked.commands().map(|c| c.len).sum();
                let orig: usize = ag.commands().map(|c| c.len).sum();
                assert_eq!(total, orig, "{nodes}x{p} k={k}");
            }
        }
    }

    #[test]
    fn conservation_check_catches_violations() {
        let n = 4;
        let t = Topology::fully_connected(n);
        let outs = ids(n, 100);
        let mut plan = allgather_hier(&t, &ids(n, 0), &outs, 16);
        // Drop one command: a hole.
        plan.phases[0][2].pop();
        assert!(check_conservation(&plan, &outs, n * 16)
            .unwrap_err()
            .contains("never written"));
        // Duplicate one command: a double write.
        let mut plan = allgather_hier(&t, &ids(n, 0), &outs, 16);
        let dup = plan.phases[0][1][0];
        plan.phases[0][1].push(dup);
        assert!(check_conservation(&plan, &outs, n * 16)
            .unwrap_err()
            .contains("more than once"));
    }

    #[test]
    fn plans_cover_all_ordered_pairs_once() {
        let n = 8;
        for plan in [
            allgather_plan(n, &ids(n, 0), &ids(n, 100), 8),
            alltoall_plan(n, &ids(n, 0), &ids(n, 100), 8),
        ] {
            let mut pairs = std::collections::BTreeSet::new();
            for cmds in &plan {
                for c in cmds {
                    assert!(pairs.insert((c.src_gpu, c.dst_gpu)), "dup pair");
                }
            }
            assert_eq!(pairs.len(), n * n);
        }
    }
}
