//! **ConCCL** — concurrent communication collectives on DMA engines
//! (paper §VI).
//!
//! Instead of spending 32–64 CUs on a communication kernel, ConCCL
//! offloads each collective as a series of point-to-point SDMA
//! transfers: zero CU demand, no L1/L2 pollution (engines sit on the
//! IODs behind the XCD caches), at the price of CPU-side launch/sync
//! latency that is not amortized below ~32 MiB (Fig 9).
//!
//! [`DmaCollective`] is the analytic model used inside C3 composition;
//! it is *exactly consistent* with the command-level machinery — a unit
//! test asserts its time equals `gpu::sdma::schedule` on the plan from
//! [`plan`] to float precision.

pub mod discussion;
pub mod plan;

use crate::config::machine::MachineConfig;
use crate::config::workload::{CollectiveKind, CollectiveSpec};
use crate::error::Error;
use crate::fabric::Topology;
use crate::gpu::memory::BufferId;
use crate::gpu::sdma::{schedule_phases, EnginePolicy};
use crate::kernels::CollectiveKernel;

/// A DMA-offloaded collective (all-gather or all-to-all; all-reduce has
/// no DMA form — engines cannot reduce, §VI-B).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmaCollective {
    pub spec: CollectiveSpec,
}

impl DmaCollective {
    /// Typed constructor — the only constructor:
    /// `Err(Error::NotDmaOffloadable)` on all-reduce (SDMA engines move
    /// bytes but cannot do arithmetic). Every caller routes through
    /// this so a bad input fails its own job instead of aborting the
    /// process; statically-offloadable call sites `.expect(..)` with
    /// the reason.
    pub fn try_new(spec: CollectiveSpec) -> Result<Self, Error> {
        if !spec.kind.dma_offloadable() {
            return Err(Error::NotDmaOffloadable(spec.kind.name().to_string()));
        }
        Ok(DmaCollective { spec })
    }

    /// CUs consumed: none — the whole point (§VI-A).
    pub fn cu_need(&self) -> u32 {
        0
    }

    /// Bytes each GPU pushes over each peer link (same shard math as the
    /// CU collectives — the algorithm is direct either way).
    pub fn per_link_bytes(&self, m: &MachineConfig) -> f64 {
        self.spec.size_bytes as f64 / m.num_gpus as f64
    }

    /// Effective per-link bandwidth for this collective's pattern. The
    /// all-to-all derate is a *fabric* property (all-pairs port
    /// contention), so DMA transfers pay it too — which is also why
    /// ConCCL stays "at par" with RCCL for bandwidth-bound A2A (Fig 9).
    pub fn link_bw_eff(&self, m: &MachineConfig) -> f64 {
        m.link_bw_dma() * CollectiveKernel::new(self.spec).link_derate(m)
    }

    /// Shard length per GPU, bytes.
    pub fn shard_bytes(&self, m: &MachineConfig) -> usize {
        (self.spec.size_bytes as usize).div_ceil(m.num_gpus)
    }

    /// HBM traffic per GPU (same payload-derived factors as the CU
    /// model; what changes with DMA is *which caches* see it, not the
    /// HBM bytes — §VII-A1: HBM contention remains).
    pub fn hbm_traffic(&self, m: &MachineConfig) -> f64 {
        CollectiveKernel::new(self.spec).hbm_traffic(m)
    }

    /// CPU-side launch cost: one command packet per destination
    /// (peers + the local copy), serialized on the orchestration thread
    /// (Fig 3 step 1) in `ceil(n / fused_packets)` enqueue+doorbell
    /// rounds.
    pub fn launch_time(&self, m: &MachineConfig) -> f64 {
        m.sdma.issue_hold(m.num_gpus)
    }

    /// Isolated execution time, seconds. Mirrors `sdma::schedule` on the
    /// direct plan exactly at the default [`SdmaModel`]:
    /// * peer transfers issue in serialized enqueue+doorbell rounds,
    ///   then the last peer lands after fetch + the wire time (inflated
    ///   by the model's engine-pool/bandwidth-share factor, plus any
    ///   finite-command-queue refill stalls);
    /// * the local copy (enqueued last) rides HBM at `hbm/2`;
    /// * plus the CPU sync.
    ///
    /// [`SdmaModel`]: crate::gpu::sdma::SdmaModel
    pub fn time_isolated(&self, m: &MachineConfig) -> f64 {
        let sd = &m.sdma;
        let per_wire = self.per_link_bytes(m) / self.link_bw_eff(m);
        let wire = per_wire * sd.wire_factor(m.num_gpus - 1);
        let last_peer = sd.issue_hold(m.num_gpus - 1)
            + sd.fetch_s
            + wire
            + sd.queue_stall_s(m.num_gpus, per_wire);
        let local_dur =
            self.per_link_bytes(m) / (m.hbm_bw_achievable() / 2.0 * sd.engine_bw_share);
        let local = sd.issue_hold(m.num_gpus) + sd.fetch_s + local_dur;
        last_peer.max(local) + sd.sync_s
    }

    /// Fig 9's y-axis: ConCCL speedup over the CU-based (RCCL) kernel
    /// at the same size (< 1 means ConCCL is slower).
    pub fn speedup_vs_cu(&self, m: &MachineConfig) -> f64 {
        let cu = CollectiveKernel::new(self.spec);
        cu.time_isolated_full(m) / self.time_isolated(m)
    }

    /// Isolated execution time on an arbitrary topology. Single node:
    /// the closed-form [`DmaCollective::time_isolated`]. Multi-node:
    /// priced *exactly* by building the hierarchical command plan and
    /// running it through `gpu::sdma::schedule_phases` — the analytic
    /// model and the command machinery cannot drift apart because they
    /// are the same computation.
    pub fn time_isolated_on(&self, m: &MachineConfig, topo: &Topology) -> f64 {
        if topo.num_nodes() == 1 {
            return self.time_isolated(m);
        }
        let n = topo.num_gpus();
        let shard = (self.spec.size_bytes as usize).div_ceil(n);
        // Synthetic buffer ids: the scheduler prices commands without
        // touching memory contents.
        let ins: Vec<BufferId> = (0..n as u64).map(BufferId).collect();
        let outs: Vec<BufferId> = (0..n as u64).map(|i| BufferId(1_000 + i)).collect();
        let plan = match self.spec.kind {
            CollectiveKind::AllGather => plan::allgather_hier(topo, &ins, &outs, shard),
            CollectiveKind::AllToAll => {
                let nn = topo.num_nodes() as u64;
                let so: Vec<BufferId> = (0..nn).map(|i| BufferId(2_000 + i)).collect();
                let si: Vec<BufferId> = (0..nn).map(|i| BufferId(3_000 + i)).collect();
                plan::alltoall_hier(topo, &ins, &outs, &so, &si, shard)
            }
            CollectiveKind::AllReduce | CollectiveKind::ReduceScatter => {
                unreachable!("constructor rejects non-offloadable kinds")
            }
        };
        schedule_phases(m, topo, &plan.phases, EnginePolicy::LeastLoaded)
            .expect("hierarchical plans are built for this topology")
            .total
    }

    /// Wire-phase duration on a topology, for the C3 composition (the
    /// executor accounts launch/fetch/sync separately around it).
    pub fn wire_time_on(&self, m: &MachineConfig, topo: &Topology) -> f64 {
        if topo.num_nodes() == 1 {
            return self.per_link_bytes(m) / self.link_bw_eff(m)
                * m.sdma.wire_factor(m.num_gpus - 1);
        }
        (self.time_isolated_on(m, topo) - self.launch_time(m) - m.sdma.fetch_s - m.sdma.sync_s)
            .max(1e-12)
    }
}

/// The §VII-A2 hybrid all-reduce: reduce-scatter on CUs, all-gather on
/// DMA engines. Returns (total time, CU time slice, DMA time slice).
/// Surfaces a typed [`Error`] instead of panicking if the AG half ever
/// stopped being offloadable (the last panic-shaped path left in
/// `conccl` after the sweep-engine error-typing pass).
pub fn hybrid_allreduce_time(m: &MachineConfig, size_bytes: u64) -> Result<(f64, f64, f64), Error> {
    let rs_wire = (size_bytes as f64 / m.num_gpus as f64) / m.link_bw_achievable();
    let rs = m.coll_launch_s + rs_wire;
    let ag = DmaCollective::try_new(CollectiveSpec::new(CollectiveKind::AllGather, size_bytes))?
        .time_isolated(m);
    Ok((rs + ag, rs, ag))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_rel_close;
    use crate::fabric::Topology;
    use crate::gpu::memory::BufferId;
    use crate::gpu::sdma::{schedule, EnginePolicy};
    use crate::util::units::{GIB, MIB};

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn ag(bytes: u64) -> DmaCollective {
        DmaCollective::try_new(CollectiveSpec::new(CollectiveKind::AllGather, bytes)).unwrap()
    }

    fn a2a(bytes: u64) -> DmaCollective {
        DmaCollective::try_new(CollectiveSpec::new(CollectiveKind::AllToAll, bytes)).unwrap()
    }

    #[test]
    fn try_new_surfaces_typed_error() {
        let bad = CollectiveSpec::new(CollectiveKind::AllReduce, GIB);
        let err = DmaCollective::try_new(bad).unwrap_err();
        assert!(matches!(err, crate::error::Error::NotDmaOffloadable(_)), "{err}");
        let ok = CollectiveSpec::new(CollectiveKind::AllGather, GIB);
        assert!(DmaCollective::try_new(ok).is_ok());
    }

    #[test]
    fn multi_node_time_exceeds_single_node_and_tracks_nic_bw() {
        // The NIC is the new bottleneck: 2-node collectives are slower
        // than single-node ones at the same payload, and get worse as
        // NIC bandwidth drops.
        let m = m();
        for model in [ag(896 * MIB), a2a(896 * MIB)] {
            let t1 = model.time_isolated_on(&m, &m.topology(1));
            let t2 = model.time_isolated_on(&m, &m.topology(2));
            assert!(t2 > t1, "{}: {t2} vs {t1}", model.spec.kind.name());
            let mut slow = m.clone();
            slow.nic_bw = m.nic_bw / 10.0;
            let t2_slow = model.time_isolated_on(&slow, &slow.topology(2));
            assert!(t2_slow > 2.0 * t2, "{t2_slow} vs {t2}");
        }
        // Single-node `_on` matches the closed form exactly.
        let model = ag(896 * MIB);
        assert_eq!(model.time_isolated_on(&m, &m.topology(1)), model.time_isolated(&m));
    }

    #[test]
    fn analytic_time_matches_command_schedule_exactly() {
        // The analytic model and the command-level SDMA machinery must
        // agree to float precision on the direct all-gather plan.
        let m = m();
        let size = 896 * MIB;
        let model = ag(size);
        let n = m.num_gpus;
        let shard = model.shard_bytes(&m);
        let shards: Vec<BufferId> = (0..n as u64).map(BufferId).collect();
        let outs: Vec<BufferId> = (100..100 + n as u64).map(BufferId).collect();
        let plan = plan::allgather_plan(n, &shards, &outs, shard);
        let topo = Topology::fully_connected(n);
        let sched = schedule(&m, &topo, &plan, EnginePolicy::LeastLoaded).unwrap();
        assert_rel_close!(sched.total, model.time_isolated(&m), 1e-9);
    }

    #[test]
    fn fig9_small_sizes_up_to_4x_slower() {
        // Fig 9: below 32 MiB ConCCL is slower than RCCL, by as much as
        // ~4x at the smallest sizes (launch/sync not amortized).
        let m = m();
        let s_64k = ag(64 * 1024).speedup_vs_cu(&m);
        assert!(
            (0.2..0.35).contains(&s_64k),
            "64KiB speedup {s_64k:.2} (paper: up to 4x slower)"
        );
        let s_8m = ag(8 * MIB).speedup_vs_cu(&m);
        assert!(s_8m < 0.75, "8MiB should still be slower: {s_8m:.2}");
        // Monotone recovery with size.
        let mut prev = 0.0;
        for mb in [1u64, 4, 16, 64, 256, 1024] {
            let s = ag(mb * MIB).speedup_vs_cu(&m);
            assert!(s >= prev, "speedup not monotone at {mb}M: {s} < {prev}");
            prev = s;
        }
    }

    #[test]
    fn fig9_large_sizes_at_par() {
        // ≥128 MiB: at par with RCCL (within ~15%); the paper's C3 sizes
        // all live here, making the C3 comparison fair (§VI-C).
        let m = m();
        for mb in [128u64, 256, 896, 4096] {
            let s = ag(mb * MIB).speedup_vs_cu(&m);
            assert!(
                (0.85..=1.1).contains(&s),
                "{mb}MiB: ConCCL/RCCL speedup {s:.3} not at par"
            );
        }
        // A2A ConCCL beats the derated CU kernel at large sizes.
        let s = a2a(GIB).speedup_vs_cu(&m);
        assert!(s > 0.95, "A2A at 1GiB: {s:.3}");
    }

    #[test]
    fn zero_cu_demand() {
        assert_eq!(ag(GIB).cu_need(), 0);
    }

    #[test]
    fn hybrid_allreduce_decomposes() {
        let m = m();
        let (total, rs, ag_t) = hybrid_allreduce_time(&m, GIB).unwrap();
        assert_rel_close!(total, rs + ag_t, 1e-12);
        // Hybrid must beat pure-CU all-reduce on CU seconds but not
        // necessarily on wall-clock.
        assert!(rs > 0.0 && ag_t > 0.0);
    }

    #[test]
    fn launch_cost_scales_with_gpu_count() {
        let mut cfg = m();
        let t8 = ag(GIB).launch_time(&cfg);
        cfg.num_gpus = 4;
        cfg.link_count = 3;
        let t4 = ag(GIB).launch_time(&cfg);
        assert!(t8 > t4);
    }
}
