//! §VII extensions implemented as evaluable features (the paper's
//! discussion items, promoted to code so the `ablations` bench can
//! quantify them):
//!
//! * **§VII-A2 hybrid all-reduce** — reduce-scatter on CUs + all-gather
//!   on DMA engines (see [`super::hybrid_allreduce_time`]), plus the C3
//!   composition: how much GEMM interference the hybrid avoids.
//! * **DMA-engine-count sensitivity** — the paper's closing argument is
//!   "a strong case for GPU DMA engine advancements"; we sweep
//!   `sdma.engines` to show where the PoC design stops scaling (the
//!   `dse` sweep generalizes this to the full [`SdmaModel`] grid).
//!
//! [`SdmaModel`]: crate::gpu::sdma::SdmaModel
//! * **§VII-B1 multi-kernel schedule prioritization** — the workgroup-
//!   count ordering applied to >2 concurrent kernels.

use crate::config::machine::MachineConfig;
use crate::config::workload::{CollectiveKind, CollectiveSpec};
use crate::fabric::Topology;
use crate::gpu::memory::BufferId;
use crate::gpu::sdma::{schedule, EnginePolicy};
use crate::kernels::CollectiveKernel;

use super::plan::allgather_plan;
use super::hybrid_allreduce_time;

/// All-reduce strategy comparison point (§VII-A2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AllReducePoint {
    pub size_bytes: u64,
    /// Pure CU (RCCL-like) all-reduce time.
    pub cu_time: f64,
    /// Hybrid RS(CU) + AG(DMA) time.
    pub hybrid_time: f64,
    /// CU-seconds consumed by each (the resource ConCCL frees).
    pub cu_busy_cu: f64,
    pub cu_busy_hybrid: f64,
}

/// Evaluate the hybrid all-reduce against the CU kernel at one size.
/// Propagates the hybrid decomposition's typed error (never a panic).
pub fn allreduce_point(
    m: &MachineConfig,
    size_bytes: u64,
) -> Result<AllReducePoint, crate::error::Error> {
    let cu = CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllReduce, size_bytes));
    let cu_time = cu.time_isolated_full(m);
    let (hybrid_time, rs, _ag) = hybrid_allreduce_time(m, size_bytes)?;
    Ok(AllReducePoint {
        size_bytes,
        cu_time,
        hybrid_time,
        // CU-seconds: kernel time x CUs held.
        cu_busy_cu: cu_time * cu.cu_need(m) as f64,
        cu_busy_hybrid: rs * m.ar_cu_need as f64, // AG phase holds zero CUs
    })
}

/// DMA-engine-count sensitivity: ConCCL all-gather completion time at a
/// given engine count, from the command-level scheduler (not the
/// analytic model, which assumes enough engines).
pub fn allgather_time_with_engines(
    m: &MachineConfig,
    size_bytes: u64,
    engines: usize,
) -> f64 {
    let mut cfg = m.clone();
    cfg.sdma.engines = engines;
    let n = cfg.num_gpus;
    let shard = (size_bytes as usize).div_ceil(n);
    let shards: Vec<BufferId> = (0..n as u64).map(BufferId).collect();
    let outs: Vec<BufferId> = (100..100 + n as u64).map(BufferId).collect();
    let plan = allgather_plan(n, &shards, &outs, shard);
    let topo = Topology::fully_connected(n);
    schedule(&cfg, &topo, &plan, EnginePolicy::LeastLoaded)
        .expect("direct all-gather plan matches its own topology")
        .total
}

/// §VII-B1: order N concurrent kernels (GEMMs + collectives) for launch
/// by ascending workgroup count; returns the schedule order and whether
/// every collective precedes every GEMM (the expected outcome for the
/// paper's workloads).
pub fn multi_kernel_sp_order(
    m: &MachineConfig,
    gemms: &[crate::kernels::GemmKernel],
    comms: &[CollectiveKernel],
) -> (Vec<String>, bool) {
    use crate::heuristics::sp::{launch_order, LaunchInfo};
    let mut infos: Vec<LaunchInfo> = Vec::new();
    for g in gemms {
        infos.push(LaunchInfo::of_gemm(m, g));
    }
    for c in comms {
        infos.push(LaunchInfo::of_collective(m, c));
    }
    let order = launch_order(&infos);
    let names: Vec<String> = order.iter().map(|&i| infos[i].name.clone()).collect();
    let comms_first = order
        .iter()
        .take(comms.len())
        .all(|&i| i >= gemms.len());
    (names, comms_first)
}

/// A "future GPU" with beefier DMA orchestration (§VII-B6: a GPU
/// control path would amortize launch costs): same machine with the
/// CPU enqueue/sync replaced by µs-scale on-GPU doorbells.
pub fn gpu_orchestrated_variant(m: &MachineConfig) -> MachineConfig {
    let mut v = m.clone();
    v.name = format!("{}+gpu-dma-ctl", m.name);
    v.sdma.enqueue_s = 0.5e-6;
    v.sdma.sync_s = 1e-6;
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conccl::DmaCollective;
    use crate::util::units::{GIB, MIB};
    use crate::workload::llama::table1;

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    #[test]
    fn hybrid_allreduce_frees_cu_seconds() {
        let m = m();
        let p = allreduce_point(&m, GIB).unwrap();
        // Wall-clock: hybrid pays the DMA launch tax but saves CU time.
        assert!(p.cu_busy_hybrid < 0.6 * p.cu_busy_cu, "{p:?}");
        // Hybrid wall-clock within ~25% of the CU kernel at large sizes.
        assert!(p.hybrid_time < 1.25 * p.cu_time, "{p:?}");
    }

    #[test]
    fn engine_count_sensitivity_saturates_at_link_count() {
        // With >= 7 engines per GPU the 7 peer links are the binding
        // resource; fewer engines serialize transfers.
        let m = m();
        let t14 = allgather_time_with_engines(&m, 896 * MIB, 14);
        let t7 = allgather_time_with_engines(&m, 896 * MIB, 7);
        let t2 = allgather_time_with_engines(&m, 896 * MIB, 2);
        let t1 = allgather_time_with_engines(&m, 896 * MIB, 1);
        assert!((t14 - t7).abs() / t7 < 0.02, "7 engines should suffice");
        assert!(t2 > 2.5 * t14, "2 engines must serialize: {t2} vs {t14}");
        assert!(t1 > t2);
    }

    #[test]
    fn multi_kernel_sp_puts_all_comms_first() {
        let m = m();
        let gemms = table1();
        let comms: Vec<CollectiveKernel> = [64 * MIB, 896 * MIB, 4 * GIB]
            .iter()
            .map(|&s| CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, s)))
            .collect();
        let (order, comms_first) = multi_kernel_sp_order(&m, &gemms, &comms);
        assert!(comms_first, "order: {order:?}");
        assert_eq!(order.len(), 10);
    }

    #[test]
    fn gpu_orchestration_fixes_small_size_regime() {
        // §VII-B6: with a GPU control path, ConCCL's Fig 9 left edge
        // recovers (small sizes no longer 3-4x slower).
        let m = m();
        let v = gpu_orchestrated_variant(&m);
        let small = CollectiveSpec::new(CollectiveKind::AllGather, MIB);
        let before = DmaCollective::try_new(small).unwrap().speedup_vs_cu(&m);
        let after = DmaCollective::try_new(small).unwrap().speedup_vs_cu(&v);
        assert!(before < 0.5);
        assert!(after > 1.5 * before, "{before} -> {after}");
    }
}
