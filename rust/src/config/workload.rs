//! Workload descriptions: GEMM shapes, collective operations and the C3
//! scenarios pairing them (paper Tables I and II).

use crate::util::units::{fmt_bytes, parse_bytes};

/// Element type of a GEMM (the paper's kernels are bf16 with f32
/// accumulation; collectives move bf16 payloads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    Bf16,
    F32,
}

impl DType {
    /// Size in bytes of one element.
    pub fn bytes(self) -> usize {
        match self {
            DType::Bf16 => 2,
            DType::F32 => 4,
        }
    }

    /// Lowercase name (matches the python artifact manifest).
    pub fn name(self) -> &'static str {
        match self {
            DType::Bf16 => "bf16",
            DType::F32 => "f32",
        }
    }
}

/// A GEMM `C[M,N] += A[M,K] · B[K,N]` (paper writes shapes `MxNxK`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype: DType,
}

impl GemmShape {
    /// bf16 GEMM shape (the paper's default).
    pub fn bf16(m: usize, n: usize, k: usize) -> Self {
        GemmShape {
            m,
            n,
            k,
            dtype: DType::Bf16,
        }
    }

    /// Total FLOPs (multiply-accumulate counted as 2).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Minimum memory traffic: read A and B once, write C once (bytes).
    pub fn min_bytes(&self) -> f64 {
        let e = self.dtype.bytes() as f64;
        (self.m * self.k + self.k * self.n + self.m * self.n) as f64 * e
    }

    /// Paper-style tag, e.g. `8192x8192x8192`.
    pub fn tag(&self) -> String {
        format!("{}x{}x{}", self.m, self.n, self.k)
    }
}

/// Collective kinds studied in the paper. All-reduce is included for the
/// §VII-A2 hybrid discussion and reduce-scatter for the FSDP backward /
/// tensor-parallel traces; neither is DMA-offloadable as a whole (DMA
/// engines have no arithmetic — the data plane moves the shards on
/// engines and reduces on CUs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    AllGather,
    AllToAll,
    AllReduce,
    ReduceScatter,
}

impl CollectiveKind {
    /// Short name used in tags and tables.
    pub fn name(self) -> &'static str {
        match self {
            CollectiveKind::AllGather => "all-gather",
            CollectiveKind::AllToAll => "all-to-all",
            CollectiveKind::AllReduce => "all-reduce",
            CollectiveKind::ReduceScatter => "reduce-scatter",
        }
    }

    /// Can this collective be offloaded to DMA engines? (§VI-B: engines
    /// expose no arithmetic, so the reducing collectives cannot.)
    pub fn dma_offloadable(self) -> bool {
        !matches!(self, CollectiveKind::AllReduce | CollectiveKind::ReduceScatter)
    }

    /// The two kinds the paper's evaluation sweeps.
    pub fn studied() -> [CollectiveKind; 2] {
        [CollectiveKind::AllGather, CollectiveKind::AllToAll]
    }
}

/// One collective operation: kind + data size. `size_bytes` is the
/// paper's scenario tag size — the full payload materialized per GPU
/// (the gathered buffer for all-gather, the exchanged buffer for
/// all-to-all).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveSpec {
    pub kind: CollectiveKind,
    pub size_bytes: u64,
}

impl CollectiveSpec {
    pub fn new(kind: CollectiveKind, size_bytes: u64) -> Self {
        CollectiveSpec { kind, size_bytes }
    }

    /// Parse a size tag like `"896M"` into a spec.
    pub fn parse(kind: CollectiveKind, size: &str) -> Result<Self, String> {
        Ok(CollectiveSpec {
            kind,
            size_bytes: parse_bytes(size)?,
        })
    }

    /// Paper-style size tag (`896M`, `3.25G`).
    pub fn size_tag(&self) -> String {
        fmt_bytes(self.size_bytes)
    }
}

/// Where a scenario comes from (paper Table II `source` column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Source {
    Llama70B,
    Llama405B,
    Synthetic,
}

impl Source {
    pub fn name(self) -> &'static str {
        match self {
            Source::Llama70B => "LLaMA-70B",
            Source::Llama405B => "LLaMA-405B",
            Source::Synthetic => "synthetic",
        }
    }
}

/// A C3 scenario: one GEMM paired with one concurrent collective
/// (paper Table II rows; the collective kind is swept separately).
#[derive(Debug, Clone, PartialEq)]
pub struct C3Scenario {
    /// GEMM tag from Table I (`cb1`..`cb5`, `mb1`, `mb2`).
    pub gemm_tag: String,
    pub gemm: GemmShape,
    pub comm: CollectiveSpec,
    pub source: Source,
}

impl C3Scenario {
    /// Paper-style scenario tag, e.g. `mb1_896M`.
    pub fn tag(&self) -> String {
        format!("{}_{}", self.gemm_tag, self.comm.size_tag())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MIB;

    #[test]
    fn gemm_flops_and_bytes() {
        let g = GemmShape::bf16(8192, 8192, 8192);
        assert_eq!(g.flops(), 2.0 * 8192f64.powi(3));
        assert_eq!(g.min_bytes(), 3.0 * 8192.0 * 8192.0 * 2.0);
        assert_eq!(g.tag(), "8192x8192x8192");
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::Bf16.bytes(), 2);
        assert_eq!(DType::F32.bytes(), 4);
    }

    #[test]
    fn collective_offloadability() {
        assert!(CollectiveKind::AllGather.dma_offloadable());
        assert!(CollectiveKind::AllToAll.dma_offloadable());
        assert!(!CollectiveKind::AllReduce.dma_offloadable());
        assert!(!CollectiveKind::ReduceScatter.dma_offloadable());
        assert_eq!(CollectiveKind::ReduceScatter.name(), "reduce-scatter");
    }

    #[test]
    fn spec_parse_and_tag() {
        let s = CollectiveSpec::parse(CollectiveKind::AllGather, "896M").unwrap();
        assert_eq!(s.size_bytes, 896 * MIB);
        assert_eq!(s.size_tag(), "896M");
    }

    #[test]
    fn scenario_tag_matches_paper_format() {
        let sc = C3Scenario {
            gemm_tag: "mb1".into(),
            gemm: GemmShape::bf16(8192, 57344, 8192),
            comm: CollectiveSpec::parse(CollectiveKind::AllGather, "896M").unwrap(),
            source: Source::Llama70B,
        };
        assert_eq!(sc.tag(), "mb1_896M");
        assert_eq!(sc.source.name(), "LLaMA-70B");
    }
}
