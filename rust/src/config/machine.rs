//! Machine description: an MI300X-like GPU node (§II-A of the paper).
//!
//! Every model constant the simulator uses lives here, with a note on
//! where it comes from: either a published MI300X datum (cited) or a
//! calibration constant fit against a specific paper figure. Calibrated
//! constants reproduce the *shape* of the paper's curves — orderings,
//! crossovers, approximate factors — not the authors' absolute numbers
//! (our substrate is a simulator, not their testbed).

/// Full description of one GPU node (default: 8× MI300X Infinity
/// Platform, fully connected).
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    /// Human-readable name, e.g. `"mi300x-8"`.
    pub name: String,

    // ---- Topology (paper §II-A) ----
    /// GPUs per node (8 for the MI300X Infinity Platform).
    pub num_gpus: usize,
    /// Accelerator complex dies per GPU (8 XCDs).
    pub xcds: usize,
    /// Active compute units per XCD (38 → 304 total).
    pub cus_per_xcd: usize,

    // ---- Compute ----
    /// Peak bf16 matrix throughput, FLOP/s. MI300X: ~1307.4 TFLOP/s
    /// (CDNA3 whitepaper).
    pub peak_flops_bf16: f64,
    /// Achievable fraction of peak for large rocBLAS GEMMs (~0.75;
    /// consistent with the MI300X performance validation guide).
    pub compute_eff: f64,

    // ---- Memory subsystem ----
    /// Peak HBM bandwidth, B/s. MI300X: 5.3 TB/s.
    pub hbm_bw: f64,
    /// Achievable fraction of peak HBM bandwidth (~0.85, STREAM-like).
    pub hbm_eff: f64,
    /// Bandwidth a single CU can draw from HBM, B/s. Calibrated so that
    /// ~240 CUs saturate achievable HBM bandwidth (Fig 5a: memory-bound
    /// GEMMs stay flat when losing up to ~64 CUs).
    pub per_cu_hbm_bw: f64,
    /// AMD Infinity Cache (memory-side LLC) capacity, bytes (256 MiB).
    pub llc_capacity: f64,
    /// Infinity Cache peak bandwidth, B/s (~17 TB/s, CDNA3 whitepaper).
    pub llc_bw: f64,
    /// Per-XCD L2 capacity, bytes (4 MiB).
    pub l2_per_xcd: f64,

    // ---- Interconnect (paper §II-A) ----
    /// The DMA subsystem's design point: engine count, per-engine
    /// bandwidth share, command-queue depth, enqueue/doorbell/fetch/sync
    /// latencies, and fused command packets. See
    /// [`SdmaModel`](crate::gpu::sdma::SdmaModel) for field-level docs
    /// and HARDWARE.md for provenance; the `dse` sweep perturbs it.
    pub sdma: crate::gpu::sdma::SdmaModel,
    /// Infinity Fabric peer links per GPU (7, fully connected).
    pub link_count: usize,
    /// Uni-directional bandwidth per link, B/s (64 GB/s).
    pub link_bw: f64,
    /// Achievable fraction of link peak for CU-driven (RCCL-like)
    /// collectives (~0.85).
    pub link_eff: f64,
    /// Achievable fraction of link peak for SDMA transfers. Set equal to
    /// `link_eff` so ConCCL is at-par with RCCL when bandwidth-bound
    /// (paper Fig 9, ≥128 MiB region).
    pub link_eff_dma: f64,
    /// Achievable uni-directional bandwidth of the node's NIC, B/s
    /// (multi-node topologies only; ~400 Gb/s InfiniBand-class). An
    /// order of magnitude below the aggregate intra-node fabric — the
    /// inter-node serialization quantum.
    pub nic_bw: f64,
    /// Per-transfer NIC latency, s (RDMA post + wire + completion;
    /// multi-node collectives are latency-bound far longer than
    /// intra-node ones).
    pub nic_latency_s: f64,

    // ---- Launch / orchestration latencies ----
    /// GPU kernel launch latency, s (HIP stream dispatch, ~5 µs).
    pub kernel_launch_s: f64,
    /// Launch + protocol-setup latency of a CU-based (RCCL-like)
    /// collective kernel, s (~15 µs: kernel launch, channel setup,
    /// intra-kernel sync). Sets the latency-bound regime of Fig 9.
    /// (The DMA-side launch latencies live in [`MachineConfig::sdma`]:
    /// `sdma.enqueue_s`, `sdma.fetch_s`, `sdma.sync_s`.)
    pub coll_launch_s: f64,

    // ---- GEMM kernel model (calibrated: Table I classes, Fig 5a, Fig 6) ----
    /// Macro-tile edge (rocBLAS-like 128×128 workgroup tiles).
    pub gemm_tile: usize,
    /// Coefficient of the LLC-streaming traffic factor:
    /// `factor = clamp(1, coeff * (ws/llc)^exp, cap)`. Fit so Table I's
    /// cb/mb classification is reproduced from shapes alone and Fig 6's
    /// "mb dwarfs everything" utilization gap appears.
    pub gemm_traffic_coeff: f64,
    /// Exponent of the traffic factor (see `gemm_traffic_coeff`).
    pub gemm_traffic_exp: f64,
    /// Upper bound on the traffic factor (K-blocking bounds streaming).
    pub gemm_traffic_cap: f64,
    /// Strength of the "fewer concurrent threads → better cache
    /// behaviour" effect (paper footnote 3): traffic is damped by
    /// `(1-damp) + damp·cu/304`. Fit to the small circled mb speedup in
    /// Fig 5a.
    pub gemm_cache_damp: f64,

    // ---- Collective kernel model (Fig 5b/c, Fig 6, Fig 9) ----
    /// CUs an all-gather kernel needs for full bandwidth (32, Fig 5b).
    pub ag_cu_need: u32,
    /// CUs an all-to-all kernel needs for full bandwidth (64, Fig 5c).
    pub a2a_cu_need: u32,
    /// CUs an all-reduce kernel needs (like AG; §VII-A2 discussion).
    pub ar_cu_need: u32,
    /// CUs a reduce-scatter kernel needs (the all-reduce's first pass;
    /// the FSDP-backward gradient collective of the e2e graphs).
    pub rs_cu_need: u32,
    /// HBM traffic factor of all-to-all relative to its payload: A2A
    /// reads and writes distinct buffers both ways plus staging; AG
    /// writes the gathered buffer once (≈1×). Together with
    /// `a2a_link_derate`, fit to Fig 6's "AG ~14% lower bandwidth than
    /// A2A" note.
    pub a2a_hbm_factor: f64,
    /// HBM traffic factor of all-gather relative to its payload.
    pub ag_hbm_factor: f64,
    /// Fabric efficiency derate for all-to-all relative to all-gather
    /// (the all-pairs pattern self-interferes on the fabric; A2A kernels
    /// also stage through intermediate buffers).
    pub a2a_link_derate: f64,

    // ---- Concurrency interference (calibrated: Fig 8, Fig 10) ----
    /// Fractional bandwidth loss a CU-based all-gather suffers while a
    /// GEMM is co-resident even with enough CUs (LLC/HBM/queueing
    /// interference beyond explicit bandwidth sharing).
    pub comm_co_penalty_ag: f64,
    /// Same for all-to-all (higher: more traffic, more staging).
    pub comm_co_penalty_a2a: f64,
    /// Fractional compute-rate loss a GEMM suffers from a co-resident
    /// CU-based all-gather polluting L1/L2 (eliminated under ConCCL —
    /// DMA engines sit behind L2, §VI-A).
    pub gemm_l2_pollution_ag: f64,
    /// Same for a co-resident all-to-all.
    pub gemm_l2_pollution_a2a: f64,
    /// Strength of memory-subsystem interference beyond explicit
    /// bandwidth accounting (LLC port / HBM row-buffer contention): a
    /// co-running kernel's rate is shaved by
    /// `min(cap, coeff · other's-bandwidth-share)`. This is §VII-A1's
    /// residual — it applies to ConCCL too ("contention for HBM
    /// bandwidth remains") and is what keeps ConCCL at ~66-72% of ideal
    /// rather than ~100%. Fit jointly to Fig 8 / Fig 10 averages.
    pub mem_interference_coeff: f64,
    /// Upper bound of the memory-interference rate penalty.
    pub mem_interference_cap: f64,
    /// CUs that "leak" to a later-launched kernel while an earlier
    /// saturating kernel is resident (c3_base starvation model: the CP
    /// backfills mostly from the first queue; one XCD's worth spills).
    pub base_leak_cus: u32,
    /// Fraction of the first kernel's lifetime before the second
    /// stream's kernel gets dispatched at all under c3_base (FIFO
    /// dispatch backlog; fit to Fig 8's c3_base ≈ 21%-of-ideal).
    pub base_dispatch_backlog: f64,

    // ---- Partitioning / heuristics ----
    /// Minimum CU-reservation granularity (8: one XCD partition step,
    /// Fig 5 caption).
    pub min_cu_granularity: u32,
    /// Efficiency the RP heuristic's roofline model assumes (70%, §V-C).
    pub roofline_eff: f64,

    // ---- Fine-grain chunked pipelining (arXiv 2512.10236 / DMA-Latte) ----
    /// Fraction of the residual memory-subsystem interference
    /// (`mem_interference_*`, the co-run penalties and L2 pollution)
    /// eliminated in the fine-grained limit when compute and
    /// communication are issued at matching chunk boundaries: per-tile
    /// DMA issue rides the GEMM's inter-chunk HBM gaps instead of
    /// colliding with its panel-streaming bursts. The surviving penalty
    /// at `k` chunks is `1 - chunk_align_frac · (1 - 1/k)` of the
    /// whole-kernel value. Calibration constant in the spirit of
    /// `mem_interference_coeff`, fit so chunked ConCCL closes roughly
    /// half the remaining gap to ideal on GC-equal scenarios (the
    /// finer-grain DSE result) while G-long scenarios see no benefit.
    pub chunk_align_frac: f64,
    /// Largest chunk count the auto-tuner / chunk sweep considers
    /// (powers of two from 1; DMA-Latte: beyond this the per-packet
    /// launch costs dominate every realistic payload).
    pub max_chunks: u32,
}

impl MachineConfig {
    /// The default machine: one 8× MI300X Infinity Platform node.
    pub fn mi300x() -> Self {
        MachineConfig {
            name: "mi300x-8".to_string(),
            num_gpus: 8,
            xcds: 8,
            cus_per_xcd: 38,
            peak_flops_bf16: 1307.4e12,
            compute_eff: 0.75,
            hbm_bw: 5.3e12,
            hbm_eff: 0.85,
            per_cu_hbm_bw: 25e9,
            llc_capacity: 256.0 * 1024.0 * 1024.0,
            llc_bw: 17.0e12,
            l2_per_xcd: 4.0 * 1024.0 * 1024.0,
            sdma: crate::gpu::sdma::SdmaModel::mi300x(),
            link_count: 7,
            link_bw: 64e9,
            link_eff: 0.85,
            link_eff_dma: 0.85,
            nic_bw: 50e9,
            nic_latency_s: 5e-6,
            kernel_launch_s: 5e-6,
            coll_launch_s: 15e-6,
            gemm_tile: 128,
            gemm_traffic_coeff: 9.0,
            gemm_traffic_exp: 2.2,
            gemm_traffic_cap: 70.0,
            gemm_cache_damp: 0.15,
            ag_cu_need: 32,
            a2a_cu_need: 64,
            ar_cu_need: 32,
            rs_cu_need: 32,
            a2a_hbm_factor: 1.3,
            ag_hbm_factor: 1.0,
            a2a_link_derate: 0.89,
            comm_co_penalty_ag: 0.20,
            comm_co_penalty_a2a: 0.30,
            gemm_l2_pollution_ag: 0.05,
            gemm_l2_pollution_a2a: 0.08,
            mem_interference_coeff: 0.7,
            mem_interference_cap: 0.35,
            base_leak_cus: 24,
            base_dispatch_backlog: 0.45,
            min_cu_granularity: 8,
            roofline_eff: 0.7,
            chunk_align_frac: 0.7,
            max_chunks: 16,
        }
    }

    /// Total compute units on one GPU (304 on MI300X).
    pub fn cus_total(&self) -> u32 {
        (self.xcds * self.cus_per_xcd) as u32
    }

    /// Achievable GEMM FLOP rate with `cu` compute units, FLOP/s.
    pub fn flops_with_cus(&self, cu: u32) -> f64 {
        self.peak_flops_bf16 * self.compute_eff * cu as f64 / self.cus_total() as f64
    }

    /// Achievable HBM bandwidth for a kernel running on `cu` CUs, B/s
    /// (per-CU issue limit below the machine-wide achievable peak).
    pub fn hbm_bw_with_cus(&self, cu: u32) -> f64 {
        (self.per_cu_hbm_bw * cu as f64).min(self.hbm_bw * self.hbm_eff)
    }

    /// Machine-wide achievable HBM bandwidth, B/s.
    pub fn hbm_bw_achievable(&self) -> f64 {
        self.hbm_bw * self.hbm_eff
    }

    /// Machine op:byte balance point (FLOP per HBM byte). Kernels whose
    /// measured intensity exceeds this are compute-bound (paper §III).
    pub fn machine_intensity(&self) -> f64 {
        self.peak_flops_bf16 / self.hbm_bw
    }

    /// Achievable uni-directional bandwidth of one fabric link for
    /// CU-driven collectives, B/s.
    pub fn link_bw_achievable(&self) -> f64 {
        self.link_bw * self.link_eff
    }

    /// Achievable uni-directional bandwidth of one fabric link for SDMA
    /// transfers, B/s.
    pub fn link_bw_dma(&self) -> f64 {
        self.link_bw * self.link_eff_dma
    }

    /// Interconnect topology for a job spanning `nodes` copies of this
    /// machine: the paper's fully-connected node for `nodes <= 1`, else
    /// the hierarchical leader/NIC topology parameterized by this
    /// machine's NIC constants.
    pub fn topology(&self, nodes: usize) -> crate::fabric::Topology {
        if nodes <= 1 {
            crate::fabric::Topology::fully_connected(self.num_gpus)
        } else {
            crate::fabric::Topology::multi_node(
                nodes,
                self.num_gpus,
                self.nic_bw,
                self.nic_latency_s,
            )
        }
    }

    /// All legal CU reservations for resource partitioning: powers of two
    /// from the minimum granularity up to half the machine (§V-B sweeps
    /// "all possible powers-of-two CU allocations").
    pub fn rp_candidates(&self) -> Vec<u32> {
        let mut v = Vec::new();
        let mut k = self.min_cu_granularity.max(1);
        while k <= self.cus_total() / 2 {
            v.push(k);
            k *= 2;
        }
        v
    }

    /// Chunk-count candidates for the chunked C3 pipeline: powers of two
    /// from 1 (no chunking — the whole-kernel strategies) up to
    /// `max_chunks`. The sweep's `--chunks auto` and the §V-C-style
    /// chunk heuristic both pick from this set.
    pub fn chunk_candidates(&self) -> Vec<u32> {
        let mut v = Vec::new();
        let mut k = 1u32;
        while k <= self.max_chunks.max(1) {
            v.push(k);
            match k.checked_mul(2) {
                Some(next) => k = next,
                None => break, // absurd max_chunks override; stop at 2^31
            }
        }
        v
    }

    /// Residual-interference survival factor at `k` chunks (see
    /// [`MachineConfig::chunk_align_frac`]): 1.0 at `k = 1`, shrinking
    /// toward `1 - chunk_align_frac` as granularity grows.
    pub fn chunk_align(&self, k: u32) -> f64 {
        let k = k.max(1) as f64;
        1.0 - self.chunk_align_frac * (1.0 - 1.0 / k)
    }

    /// §VII-A1 residual memory-subsystem interference penalty inflicted
    /// by a co-runner holding `other_share` of achievable HBM
    /// bandwidth. The single derivation the whole-kernel executor and
    /// the chunked pipeline share.
    pub fn mem_pen(&self, other_share: f64) -> f64 {
        (self.mem_interference_coeff * other_share).min(self.mem_interference_cap)
    }

    /// L1/L2 pollution a CU-resident collective of `kind` inflicts on a
    /// co-running GEMM (zero under DMA offload — the caller gates that).
    pub fn l2_pollution(&self, kind: crate::config::workload::CollectiveKind) -> f64 {
        match kind {
            crate::config::workload::CollectiveKind::AllToAll => self.gemm_l2_pollution_a2a,
            _ => self.gemm_l2_pollution_ag,
        }
    }

    /// Co-run bandwidth derate a CU collective of `kind` suffers while
    /// a GEMM is resident.
    pub fn comm_co_penalty(&self, kind: crate::config::workload::CollectiveKind) -> f64 {
        match kind {
            crate::config::workload::CollectiveKind::AllToAll => self.comm_co_penalty_a2a,
            _ => self.comm_co_penalty_ag,
        }
    }

    /// Validate internal consistency; returns a list of problems.
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        if self.num_gpus < 2 {
            errs.push("num_gpus must be >= 2 for collectives".into());
        }
        if self.link_count + 1 != self.num_gpus {
            errs.push(format!(
                "fully-connected topology needs link_count == num_gpus-1 \
                 (got {} links for {} GPUs)",
                self.link_count, self.num_gpus
            ));
        }
        if self.xcds * self.cus_per_xcd == 0 {
            errs.push("zero compute units".into());
        }
        for (name, v) in [
            ("compute_eff", self.compute_eff),
            ("hbm_eff", self.hbm_eff),
            ("link_eff", self.link_eff),
            ("link_eff_dma", self.link_eff_dma),
            ("roofline_eff", self.roofline_eff),
        ] {
            if !(0.0 < v && v <= 1.0) {
                errs.push(format!("{name} must be in (0,1], got {v}"));
            }
        }
        for (name, v) in [
            ("comm_co_penalty_ag", self.comm_co_penalty_ag),
            ("comm_co_penalty_a2a", self.comm_co_penalty_a2a),
            ("gemm_l2_pollution_ag", self.gemm_l2_pollution_ag),
            ("gemm_l2_pollution_a2a", self.gemm_l2_pollution_a2a),
            ("base_dispatch_backlog", self.base_dispatch_backlog),
            ("gemm_cache_damp", self.gemm_cache_damp),
            ("chunk_align_frac", self.chunk_align_frac),
        ] {
            if !(0.0..1.0).contains(&v) {
                errs.push(format!("{name} must be in [0,1), got {v}"));
            }
        }
        if self.max_chunks == 0 {
            errs.push("max_chunks must be >= 1".into());
        }
        if self.min_cu_granularity == 0 || self.min_cu_granularity > self.cus_total() {
            errs.push("bad min_cu_granularity".into());
        }
        if self.nic_bw <= 0.0 {
            errs.push(format!("nic_bw must be positive, got {}", self.nic_bw));
        }
        if self.nic_latency_s < 0.0 {
            errs.push(format!("nic_latency_s must be >= 0, got {}", self.nic_latency_s));
        }
        self.sdma.validate_into(&mut errs);
        errs
    }
}

impl Default for MachineConfig {
    fn default() -> Self {
        Self::mi300x()
    }
}

/// Smooth maximum with exponent 4 — used where the roofline transition
/// between compute- and memory-bound should be gradual rather than a hard
/// kink (matches measured GEMM behaviour near the balance point).
pub fn smoothmax(a: f64, b: f64) -> f64 {
    let m = a.max(b);
    if m <= 0.0 {
        return m;
    }
    let (x, y) = (a / m, b / m);
    m * (x.powi(4) + y.powi(4)).powf(0.25)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mi300x_headline_numbers() {
        let m = MachineConfig::mi300x();
        assert_eq!(m.cus_total(), 304);
        assert_eq!(m.num_gpus, 8);
        assert_eq!(m.sdma.engines, 14);
        assert_eq!(m.sdma.queue_depth, 0, "default queue is unbounded");
        assert_eq!(m.sdma.fused_packets, 1, "default issues one packet per enqueue");
        assert_eq!(m.link_count, 7);
        assert!((m.hbm_bw - 5.3e12).abs() < 1.0);
        assert!((m.llc_capacity - 268435456.0).abs() < 1.0);
        assert!(m.validate().is_empty(), "{:?}", m.validate());
    }

    #[test]
    fn machine_intensity_near_247() {
        let m = MachineConfig::mi300x();
        let i = m.machine_intensity();
        assert!((i - 246.7).abs() < 1.0, "intensity {i}");
    }

    #[test]
    fn cu_scaled_rates_monotone() {
        let m = MachineConfig::mi300x();
        assert!(m.flops_with_cus(304) > m.flops_with_cus(240));
        assert!(m.flops_with_cus(240) > m.flops_with_cus(8));
        // HBM saturates before full CU count.
        assert_eq!(m.hbm_bw_with_cus(304), m.hbm_bw_achievable());
        assert!(m.hbm_bw_with_cus(100) < m.hbm_bw_achievable());
    }

    #[test]
    fn hbm_saturation_point_calibration() {
        // Fig 5a calibration: losing 64 CUs must NOT drop a memory-bound
        // kernel below achievable HBM bandwidth.
        let m = MachineConfig::mi300x();
        assert_eq!(m.hbm_bw_with_cus(304 - 64), m.hbm_bw_achievable());
    }

    #[test]
    fn rp_candidates_are_powers_of_two() {
        let m = MachineConfig::mi300x();
        let c = m.rp_candidates();
        assert_eq!(c, vec![8, 16, 32, 64, 128]);
    }

    #[test]
    fn topology_helper_switches_on_node_count() {
        use crate::fabric::Topology;
        let m = MachineConfig::mi300x();
        assert_eq!(m.topology(1), Topology::fully_connected(8));
        let t = m.topology(2);
        assert_eq!(t.num_gpus(), 16);
        assert_eq!(t.num_nodes(), 2);
        assert_eq!(t.nic_bw(), m.nic_bw);
        assert_eq!(t.nic_latency(), m.nic_latency_s);
    }

    #[test]
    fn chunk_candidates_and_alignment() {
        let m = MachineConfig::mi300x();
        assert_eq!(m.chunk_candidates(), vec![1, 2, 4, 8, 16]);
        // Survival factor: full penalty unchunked, floor at 1 - frac.
        assert!((m.chunk_align(1) - 1.0).abs() < 1e-12);
        assert!(m.chunk_align(2) < m.chunk_align(1));
        assert!(m.chunk_align(16) < m.chunk_align(2));
        assert!(m.chunk_align(u32::MAX) >= 1.0 - m.chunk_align_frac - 1e-9);
        let mut bad = m.clone();
        bad.chunk_align_frac = 1.5;
        assert!(!bad.validate().is_empty());
        bad = m;
        bad.max_chunks = 0;
        assert!(!bad.validate().is_empty());
    }

    #[test]
    fn validate_catches_bad_topology() {
        let mut m = MachineConfig::mi300x();
        m.link_count = 3;
        assert!(!m.validate().is_empty());
    }

    #[test]
    fn smoothmax_behaves() {
        assert!((smoothmax(1.0, 0.0) - 1.0).abs() < 1e-12);
        assert!(smoothmax(1.0, 1.0) > 1.0); // inflated near the kink
        assert!(smoothmax(1.0, 1.0) < 1.2);
        assert!(smoothmax(10.0, 1.0) < 10.01); // far from kink ≈ max
        // Symmetry.
        assert_eq!(smoothmax(2.0, 3.0), smoothmax(3.0, 2.0));
    }
}
