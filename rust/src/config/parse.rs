//! Config-file and override parsing (TOML-lite; no `serde` offline).
//!
//! Grammar:
//! ```text
//! # comment
//! [machine]
//! compute_eff = 0.75
//! llc_capacity = 256M          # byte suffixes allowed
//! name = "mi300x-8"
//! ```
//! plus CLI-style dotted overrides: `machine.compute_eff=0.8`.
//! Unknown keys are hard errors — silent typos in calibration constants
//! would corrupt experiments.

use std::collections::BTreeMap;

use crate::config::machine::MachineConfig;
use crate::util::units::parse_bytes;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num(f64),
    Str(String),
    Bool(bool),
}

impl Value {
    /// Parse a raw token: quoted string, bool, number, or byte-suffixed
    /// number (`256M`).
    pub fn parse(raw: &str) -> Result<Value, String> {
        let t = raw.trim();
        if t.is_empty() {
            return Err("empty value".into());
        }
        if let Some(inner) = t
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
        {
            return Ok(Value::Str(inner.to_string()));
        }
        match t {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(n) = t.parse::<f64>() {
            return Ok(Value::Num(n));
        }
        // Scientific shorthand like 5.3e12 parses above; try byte suffix.
        if let Ok(b) = parse_bytes(t) {
            return Ok(Value::Num(b as f64));
        }
        Err(format!("cannot parse value '{raw}'"))
    }

    /// Numeric view.
    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            Value::Num(n) => Ok(*n),
            other => Err(format!("expected number, got {other:?}")),
        }
    }

    /// Positive-integer view.
    pub fn as_usize(&self) -> Result<usize, String> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(format!("expected non-negative integer, got {n}"));
        }
        Ok(n as usize)
    }
}

/// Parsed config: `section.key -> value`. Keys outside a section land in
/// the `""` section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    /// Parse config text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // Don't strip '#' inside quotes — keep it simple: only
                // strip when no quote precedes it.
                Some(i) if !raw[..i].contains('"') => &raw[..i],
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = inner.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = Value::parse(v)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            if values.insert(key.clone(), val).is_some() {
                return Err(format!("line {}: duplicate key '{key}'", lineno + 1));
            }
        }
        Ok(Config { values })
    }

    /// Merge dotted `key=value` override strings (CLI `--set`).
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<(), String> {
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| format!("override '{o}': expected key=value"))?;
            self.values
                .insert(k.trim().to_string(), Value::parse(v)?);
        }
        Ok(())
    }

    /// Look up a key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// Build a [`MachineConfig`] starting from the MI300X default and
    /// applying every `machine.*` and `sdma.*` key (a `[sdma]` section
    /// or `--set sdma.engines=4` addresses the DMA-subsystem model
    /// directly). Unknown keys error.
    pub fn machine(&self) -> Result<MachineConfig, String> {
        let mut m = MachineConfig::mi300x();
        for (key, val) in &self.values {
            let field = if let Some(f) = key.strip_prefix("machine.") {
                f
            } else if key.starts_with("sdma.") {
                key.as_str()
            } else {
                continue;
            };
            apply_machine_field(&mut m, field, val)?;
        }
        let errs = m.validate();
        if !errs.is_empty() {
            return Err(format!("invalid machine config: {}", errs.join("; ")));
        }
        Ok(m)
    }
}

/// Apply one machine override from raw strings (the sweep engine's
/// machine-variant specs); accepts keys with or without the `machine.`
/// prefix. The resulting config is NOT validated here — callers batch
/// several fields then run [`MachineConfig::validate`].
pub fn set_machine_field(m: &mut MachineConfig, key: &str, raw: &str) -> Result<(), String> {
    let field = key.strip_prefix("machine.").unwrap_or(key);
    let v = Value::parse(raw)?;
    apply_machine_field(m, field, &v)
}

/// Apply one `machine.<field>` override. Exhaustive by hand (no serde);
/// the test below cross-checks against the struct so new fields cannot be
/// silently forgotten.
fn apply_machine_field(m: &mut MachineConfig, field: &str, v: &Value) -> Result<(), String> {
    macro_rules! f64_field {
        ($f:ident) => {{
            m.$f = v.as_f64()?;
            return Ok(());
        }};
    }
    macro_rules! usize_field {
        ($f:ident) => {{
            m.$f = v.as_usize()?;
            return Ok(());
        }};
    }
    macro_rules! u32_field {
        ($f:ident) => {{
            m.$f = v.as_usize()? as u32;
            return Ok(());
        }};
    }
    match field {
        "name" => {
            if let Value::Str(s) = v {
                m.name = s.clone();
                Ok(())
            } else {
                Err("machine.name must be a string".into())
            }
        }
        "num_gpus" => usize_field!(num_gpus),
        "xcds" => usize_field!(xcds),
        "cus_per_xcd" => usize_field!(cus_per_xcd),
        "peak_flops_bf16" => f64_field!(peak_flops_bf16),
        "compute_eff" => f64_field!(compute_eff),
        "hbm_bw" => f64_field!(hbm_bw),
        "hbm_eff" => f64_field!(hbm_eff),
        "per_cu_hbm_bw" => f64_field!(per_cu_hbm_bw),
        "llc_capacity" => f64_field!(llc_capacity),
        "llc_bw" => f64_field!(llc_bw),
        "l2_per_xcd" => f64_field!(l2_per_xcd),
        // ---- DMA subsystem (SdmaModel): dotted `sdma.*` keys ----
        "sdma.engines" => {
            m.sdma.engines = v.as_usize()?;
            Ok(())
        }
        "sdma.engine_bw_share" => {
            m.sdma.engine_bw_share = v.as_f64()?;
            Ok(())
        }
        "sdma.queue_depth" => {
            m.sdma.queue_depth = v.as_usize()?;
            Ok(())
        }
        "sdma.enqueue_s" => {
            m.sdma.enqueue_s = v.as_f64()?;
            Ok(())
        }
        "sdma.doorbell_s" => {
            m.sdma.doorbell_s = v.as_f64()?;
            Ok(())
        }
        "sdma.fetch_s" => {
            m.sdma.fetch_s = v.as_f64()?;
            Ok(())
        }
        "sdma.sync_s" => {
            m.sdma.sync_s = v.as_f64()?;
            Ok(())
        }
        "sdma.fused_packets" => {
            m.sdma.fused_packets = v.as_usize()?;
            Ok(())
        }
        // Legacy flat spellings (pre-SdmaModel configs keep working).
        "sdma_engines" => {
            m.sdma.engines = v.as_usize()?;
            Ok(())
        }
        "dma_enqueue_s" => {
            m.sdma.enqueue_s = v.as_f64()?;
            Ok(())
        }
        "dma_fetch_s" => {
            m.sdma.fetch_s = v.as_f64()?;
            Ok(())
        }
        "dma_sync_s" => {
            m.sdma.sync_s = v.as_f64()?;
            Ok(())
        }
        "link_count" => usize_field!(link_count),
        "link_bw" => f64_field!(link_bw),
        "link_eff" => f64_field!(link_eff),
        "link_eff_dma" => f64_field!(link_eff_dma),
        "nic_bw" => f64_field!(nic_bw),
        "nic_latency_s" => f64_field!(nic_latency_s),
        "kernel_launch_s" => f64_field!(kernel_launch_s),
        "coll_launch_s" => f64_field!(coll_launch_s),
        "gemm_tile" => usize_field!(gemm_tile),
        "gemm_traffic_coeff" => f64_field!(gemm_traffic_coeff),
        "gemm_traffic_exp" => f64_field!(gemm_traffic_exp),
        "gemm_traffic_cap" => f64_field!(gemm_traffic_cap),
        "gemm_cache_damp" => f64_field!(gemm_cache_damp),
        "ag_cu_need" => u32_field!(ag_cu_need),
        "a2a_cu_need" => u32_field!(a2a_cu_need),
        "ar_cu_need" => u32_field!(ar_cu_need),
        "rs_cu_need" => u32_field!(rs_cu_need),
        "a2a_hbm_factor" => f64_field!(a2a_hbm_factor),
        "ag_hbm_factor" => f64_field!(ag_hbm_factor),
        "a2a_link_derate" => f64_field!(a2a_link_derate),
        "comm_co_penalty_ag" => f64_field!(comm_co_penalty_ag),
        "comm_co_penalty_a2a" => f64_field!(comm_co_penalty_a2a),
        "gemm_l2_pollution_ag" => f64_field!(gemm_l2_pollution_ag),
        "gemm_l2_pollution_a2a" => f64_field!(gemm_l2_pollution_a2a),
        "mem_interference_coeff" => f64_field!(mem_interference_coeff),
        "mem_interference_cap" => f64_field!(mem_interference_cap),
        "base_leak_cus" => u32_field!(base_leak_cus),
        "base_dispatch_backlog" => f64_field!(base_dispatch_backlog),
        "min_cu_granularity" => u32_field!(min_cu_granularity),
        "roofline_eff" => f64_field!(roofline_eff),
        "chunk_align_frac" => f64_field!(chunk_align_frac),
        "max_chunks" => u32_field!(max_chunks),
        other => Err(format!("unknown machine config field '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_and_types() {
        let cfg = Config::parse(
            r#"
            # a comment
            top = 1
            [machine]
            compute_eff = 0.8        # inline comment
            name = "test-box"
            llc_capacity = 128M
            [other]
            flag = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.get("top"), Some(&Value::Num(1.0)));
        assert_eq!(cfg.get("machine.compute_eff"), Some(&Value::Num(0.8)));
        assert_eq!(
            cfg.get("machine.name"),
            Some(&Value::Str("test-box".into()))
        );
        assert_eq!(
            cfg.get("machine.llc_capacity"),
            Some(&Value::Num((128u64 * 1024 * 1024) as f64))
        );
        assert_eq!(cfg.get("other.flag"), Some(&Value::Bool(true)));
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(Config::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn missing_equals_rejected() {
        let e = Config::parse("justakey").unwrap_err();
        assert!(e.contains("line 1"));
    }

    #[test]
    fn machine_built_with_overrides() {
        let mut cfg = Config::parse("[machine]\ncompute_eff = 0.5").unwrap();
        cfg.apply_overrides(&["machine.hbm_eff=0.9".to_string()])
            .unwrap();
        let m = cfg.machine().unwrap();
        assert_eq!(m.compute_eff, 0.5);
        assert_eq!(m.hbm_eff, 0.9);
        // Untouched fields keep MI300X defaults.
        assert_eq!(m.cus_total(), 304);
    }

    #[test]
    fn unknown_machine_field_is_error() {
        let cfg = Config::parse("[machine]\nbogus_knob = 3").unwrap();
        let err = cfg.machine().unwrap_err();
        assert!(err.contains("bogus_knob"), "{err}");
    }

    #[test]
    fn invalid_machine_rejected() {
        let cfg = Config::parse("[machine]\ncompute_eff = 1.5").unwrap();
        assert!(cfg.machine().is_err());
    }

    #[test]
    fn every_machine_field_is_settable() {
        // Guard against forgetting to wire a new field: set each numeric
        // field via override and confirm the struct changed or errored.
        let fields = [
            "num_gpus", "xcds", "cus_per_xcd", "peak_flops_bf16", "compute_eff",
            "hbm_bw", "hbm_eff", "per_cu_hbm_bw", "llc_capacity", "llc_bw",
            "l2_per_xcd", "sdma.engines", "sdma.engine_bw_share", "sdma.queue_depth",
            "sdma.enqueue_s", "sdma.doorbell_s", "sdma.fetch_s", "sdma.sync_s",
            "sdma.fused_packets",
            "sdma_engines", "link_count", "link_bw", "link_eff",
            "link_eff_dma", "nic_bw", "nic_latency_s",
            "kernel_launch_s", "coll_launch_s", "dma_enqueue_s", "dma_fetch_s",
            "dma_sync_s", "gemm_tile", "gemm_traffic_coeff", "gemm_traffic_exp",
            "gemm_traffic_cap", "gemm_cache_damp", "ag_cu_need", "a2a_cu_need",
            "ar_cu_need", "rs_cu_need", "a2a_hbm_factor", "ag_hbm_factor", "a2a_link_derate",
            "comm_co_penalty_ag",
            "comm_co_penalty_a2a", "gemm_l2_pollution_ag", "gemm_l2_pollution_a2a",
            "mem_interference_coeff", "mem_interference_cap",
            "base_leak_cus", "base_dispatch_backlog", "min_cu_granularity",
            "roofline_eff", "chunk_align_frac", "max_chunks",
        ];
        let mut m = MachineConfig::mi300x();
        for f in fields {
            // 0.5 is a valid value for f64 fractions; integers will error
            // on fraction — both outcomes prove the field is known.
            let r = apply_machine_field(&mut m, f, &Value::Num(0.5));
            if let Err(e) = r {
                assert!(
                    e.contains("integer"),
                    "field {f} should be known, got: {e}"
                );
            }
        }
        assert!(apply_machine_field(&mut m, "nope", &Value::Num(1.0)).is_err());
    }

    #[test]
    fn set_machine_field_accepts_both_key_forms() {
        let mut m = MachineConfig::mi300x();
        set_machine_field(&mut m, "machine.hbm_eff", "0.9").unwrap();
        assert_eq!(m.hbm_eff, 0.9);
        set_machine_field(&mut m, "compute_eff", "0.6").unwrap();
        assert_eq!(m.compute_eff, 0.6);
        assert!(set_machine_field(&mut m, "bogus", "1").is_err());
        assert!(set_machine_field(&mut m, "hbm_eff", "not-a-number").is_err());
    }

    #[test]
    fn sdma_section_and_dotted_keys_reach_the_model() {
        // A `[sdma]` section addresses the subsystem directly...
        let cfg = Config::parse("[sdma]\nengines = 4\nqueue_depth = 8").unwrap();
        let m = cfg.machine().unwrap();
        assert_eq!(m.sdma.engines, 4);
        assert_eq!(m.sdma.queue_depth, 8);
        // ...as do `--set sdma.*` overrides and the legacy flat names.
        let mut cfg = Config::default();
        cfg.apply_overrides(&[
            "sdma.fused_packets=4".to_string(),
            "sdma.doorbell_s=2e-6".to_string(),
            "machine.sdma_engines=6".to_string(),
            "machine.dma_enqueue_s=1e-6".to_string(),
        ])
        .unwrap();
        let m = cfg.machine().unwrap();
        assert_eq!(m.sdma.fused_packets, 4);
        assert_eq!(m.sdma.doorbell_s, 2e-6);
        assert_eq!(m.sdma.engines, 6);
        assert_eq!(m.sdma.enqueue_s, 1e-6);
    }

    #[test]
    fn malformed_sdma_overrides_are_typed_errors() {
        // Fractional engine count: integer-typed field rejects it.
        let mut m = MachineConfig::mi300x();
        let e = set_machine_field(&mut m, "sdma.engines", "2.5").unwrap_err();
        assert!(e.contains("integer"), "{e}");
        let e = set_machine_field(&mut m, "sdma.queue_depth", "-1").unwrap_err();
        assert!(e.contains("integer"), "{e}");
        // Unknown subsystem field is a hard error, not a silent skip.
        let e = set_machine_field(&mut m, "sdma.turbo", "1").unwrap_err();
        assert!(e.contains("sdma.turbo"), "{e}");
        // Out-of-range values pass field assignment but fail validation
        // when a full machine is built.
        let mut cfg = Config::default();
        cfg.apply_overrides(&["sdma.engine_bw_share=1.5".to_string()])
            .unwrap();
        let err = cfg.machine().unwrap_err();
        assert!(err.contains("engine_bw_share"), "{err}");
        let mut cfg = Config::default();
        cfg.apply_overrides(&["sdma.engines=0".to_string()]).unwrap();
        assert!(cfg.machine().is_err());
    }

    #[test]
    fn value_parsing_edge_cases() {
        assert_eq!(Value::parse("5.3e12").unwrap(), Value::Num(5.3e12));
        assert_eq!(Value::parse("\"x y\"").unwrap(), Value::Str("x y".into()));
        assert!(Value::parse("").is_err());
        assert!(Value::parse("12garbage34").is_err());
    }
}
