//! Configuration system: the machine model (MI300X node description +
//! calibrated model constants), workload types (GEMMs, collectives, C3
//! scenarios), and a TOML-lite parser for files and CLI overrides.

pub mod machine;
pub mod parse;
pub mod workload;

pub use machine::MachineConfig;
pub use parse::{set_machine_field, Config, Value};
pub use workload::{C3Scenario, CollectiveKind, CollectiveSpec, DType, GemmShape, Source};
