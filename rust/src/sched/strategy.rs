//! C3 execution strategies: the configurations the paper evaluates in
//! Fig 8 and Fig 10.

/// How a C3 scenario's computation and communication are scheduled.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Serialize: GEMM then collective (the speedup baseline of 1.0×).
    Serial,
    /// Concurrent streams, GEMM scheduled first (`c3_base`, §IV-C). The
    /// CP works through the GEMM's queued grid first, so the collective
    /// is dispatch-starved for most of the GEMM's lifetime.
    C3Base,
    /// Schedule prioritization (`c3_sp`, §V-A): the collective — the
    /// kernel with the smaller, complementary resource need — is
    /// launched first and gets its full CU need.
    C3Sp,
    /// Resource partitioning (`c3_rp`, §V-B): GEMM first, but `comm_cus`
    /// CUs are reserved for the collective's stream so its workgroups
    /// dispatch immediately into the partition.
    C3Rp { comm_cus: u32 },
    /// Both (`c3_sp_rp`, §V-B): comm first *and* a CU reservation. The
    /// paper found no further gain over `c3_sp`.
    C3SpRp { comm_cus: u32 },
    /// ConCCL (§VI): communication offloaded to SDMA engines; all CUs
    /// stay with the GEMM; no L1/L2 pollution.
    Conccl,
    /// ConCCL + resource partitioning (§VI-F): additionally take
    /// `cus_removed` CUs away from *memory-bound* GEMMs (the Fig 5a
    /// cache-behaviour speedup also helps under ConCCL).
    ConcclRp { cus_removed: u32 },
}

impl Strategy {
    /// Display name matching the paper's figure legends.
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Serial => "serial",
            Strategy::C3Base => "c3_base",
            Strategy::C3Sp => "c3_sp",
            Strategy::C3Rp { .. } => "c3_rp",
            Strategy::C3SpRp { .. } => "c3_sp_rp",
            Strategy::Conccl => "conccl",
            Strategy::ConcclRp { .. } => "conccl_rp",
        }
    }

    /// Does this strategy run the collective on compute units?
    pub fn comm_on_cus(self) -> bool {
        !matches!(self, Strategy::Conccl | Strategy::ConcclRp { .. })
    }

    /// The Fig 8 lineup (CU-collective strategies; the rp variants are
    /// swept by the runner).
    pub fn fig8_lineup() -> [Strategy; 3] {
        [Strategy::C3Base, Strategy::C3Sp, Strategy::C3SpRp { comm_cus: 0 }]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_paper() {
        assert_eq!(Strategy::C3Base.name(), "c3_base");
        assert_eq!(Strategy::C3Rp { comm_cus: 32 }.name(), "c3_rp");
        assert_eq!(Strategy::ConcclRp { cus_removed: 8 }.name(), "conccl_rp");
    }

    #[test]
    fn cu_usage_classification() {
        assert!(Strategy::C3Base.comm_on_cus());
        assert!(Strategy::C3Sp.comm_on_cus());
        assert!(!Strategy::Conccl.comm_on_cus());
        assert!(!Strategy::ConcclRp { cus_removed: 8 }.comm_on_cus());
    }
}
