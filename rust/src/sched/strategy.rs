//! C3 execution strategies: the configurations the paper evaluates in
//! Fig 8 and Fig 10.

use crate::error::Error;

/// How a C3 scenario's computation and communication are scheduled.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Serialize: GEMM then collective (the speedup baseline of 1.0×).
    Serial,
    /// Concurrent streams, GEMM scheduled first (`c3_base`, §IV-C). The
    /// CP works through the GEMM's queued grid first, so the collective
    /// is dispatch-starved for most of the GEMM's lifetime.
    C3Base,
    /// Schedule prioritization (`c3_sp`, §V-A): the collective — the
    /// kernel with the smaller, complementary resource need — is
    /// launched first and gets its full CU need.
    C3Sp,
    /// Resource partitioning (`c3_rp`, §V-B): GEMM first, but `comm_cus`
    /// CUs are reserved for the collective's stream so its workgroups
    /// dispatch immediately into the partition.
    C3Rp { comm_cus: u32 },
    /// Both (`c3_sp_rp`, §V-B): comm first *and* a CU reservation. The
    /// paper found no further gain over `c3_sp`.
    C3SpRp { comm_cus: u32 },
    /// ConCCL (§VI): communication offloaded to SDMA engines; all CUs
    /// stay with the GEMM; no L1/L2 pollution.
    Conccl,
    /// ConCCL + resource partitioning (§VI-F): additionally take
    /// `cus_removed` CUs away from *memory-bound* GEMMs (the Fig 5a
    /// cache-behaviour speedup also helps under ConCCL).
    ConcclRp { cus_removed: u32 },
    /// Fine-grain chunked pipeline on the CU backend (arXiv 2512.10236):
    /// the GEMM is launched as `chunks` tiled sub-kernels and the
    /// CU collective as `chunks` chunk kernels; collective chunk `i` is
    /// issued at GEMM chunk `i`'s completion (so it overlaps GEMM chunk
    /// `i+1`). `chunks == 1` degenerates to [`Strategy::C3Sp`] exactly;
    /// `chunks == 0` means "auto" — the executor sweeps the machine's
    /// chunk candidates and keeps the best (the §V-B rp protocol,
    /// applied to granularity).
    C3Chunked { chunks: u32 },
    /// Fine-grain chunked pipeline on the DMA backend: per-chunk
    /// `CommandPacket` batches with per-packet launch latency, so small
    /// chunks go latency-bound (DMA-Latte). `chunks == 1` degenerates to
    /// [`Strategy::Conccl`] exactly; `chunks == 0` means "auto".
    ConcclChunked { chunks: u32 },
}

impl Strategy {
    /// Display name matching the paper's figure legends.
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Serial => "serial",
            Strategy::C3Base => "c3_base",
            Strategy::C3Sp => "c3_sp",
            Strategy::C3Rp { .. } => "c3_rp",
            Strategy::C3SpRp { .. } => "c3_sp_rp",
            Strategy::Conccl => "conccl",
            Strategy::ConcclRp { .. } => "conccl_rp",
            Strategy::C3Chunked { .. } => "c3_chunked",
            Strategy::ConcclChunked { .. } => "conccl_chunked",
        }
    }

    /// Does this strategy run the collective on compute units?
    pub fn comm_on_cus(self) -> bool {
        !matches!(
            self,
            Strategy::Conccl | Strategy::ConcclRp { .. } | Strategy::ConcclChunked { .. }
        )
    }

    /// Is this one of the fine-grain chunked pipeline strategies?
    pub fn is_chunked(self) -> bool {
        matches!(self, Strategy::C3Chunked { .. } | Strategy::ConcclChunked { .. })
    }

    /// The Fig 8 lineup (CU-collective strategies; the rp variants are
    /// swept by the runner).
    pub fn fig8_lineup() -> [Strategy; 3] {
        [Strategy::C3Base, Strategy::C3Sp, Strategy::C3SpRp { comm_cus: 0 }]
    }

    /// Parse a CLI strategy name. `comm_cus` seeds the rp variants'
    /// reservation (the CLI passes the collective's CU need); `c3_rp`
    /// callers that sweep ignore the embedded value.
    pub fn parse(s: &str, comm_cus: u32) -> Result<Strategy, Error> {
        match s {
            "serial" => Ok(Strategy::Serial),
            "c3_base" | "base" => Ok(Strategy::C3Base),
            "c3_sp" | "sp" => Ok(Strategy::C3Sp),
            "c3_rp" | "rp" => Ok(Strategy::C3Rp { comm_cus }),
            "c3_sp_rp" | "sp_rp" => Ok(Strategy::C3SpRp { comm_cus }),
            "conccl" => Ok(Strategy::Conccl),
            "conccl_rp" => Ok(Strategy::ConcclRp { cus_removed: 8 }),
            // Chunk count 0 = auto; the CLI overrides it from --chunks.
            // Aliases match StrategyKind::parse.
            "c3_chunked" | "chunked" => Ok(Strategy::C3Chunked { chunks: 0 }),
            "conccl_chunked" => Ok(Strategy::ConcclChunked { chunks: 0 }),
            other => Err(Error::UnknownStrategy(other.to_string())),
        }
    }
}

/// A strategy *name* as the figures/report tables use it: no embedded
/// parameters (the runner picks rp reservations itself), plus the
/// derived `c3_best` column. This is the sweep engine's job axis and the
/// typed replacement for the string-keyed lookups that used to panic on
/// unknown names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StrategyKind {
    Serial,
    C3Base,
    C3Sp,
    /// Resource partitioning with the reservation swept to the best
    /// power of two (§V-B's protocol).
    C3Rp,
    C3SpRp,
    /// Best CU-collective variant (min total over base/sp/rp/sp_rp) —
    /// the Fig 10 comparison column. As a sweep job this selects by
    /// noise-free model-truth totals; `ScenarioOutcome::c3_best`
    /// selects by measured median, so under protocol jitter the two
    /// estimators can disagree on near-tied candidates.
    C3Best,
    Conccl,
    ConcclRp,
    /// Chunked CU-backend pipeline; the sweep's chunk axis picks the
    /// chunk count (auto entries sweep the candidates, rp-style).
    C3Chunked,
    /// Chunked DMA-backend (ConCCL) pipeline.
    ConcclChunked,
}

impl StrategyKind {
    /// Figure-legend name.
    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::Serial => "serial",
            StrategyKind::C3Base => "c3_base",
            StrategyKind::C3Sp => "c3_sp",
            StrategyKind::C3Rp => "c3_rp",
            StrategyKind::C3SpRp => "c3_sp_rp",
            StrategyKind::C3Best => "c3_best",
            StrategyKind::Conccl => "conccl",
            StrategyKind::ConcclRp => "conccl_rp",
            StrategyKind::C3Chunked => "c3_chunked",
            StrategyKind::ConcclChunked => "conccl_chunked",
        }
    }

    /// Is this one of the fine-grain chunked pipeline columns (the ones
    /// the sweep's chunk axis applies to)?
    pub fn is_chunked(self) -> bool {
        matches!(self, StrategyKind::C3Chunked | StrategyKind::ConcclChunked)
    }

    /// Parse a name; `Err` (never a panic) on anything unknown.
    pub fn parse(s: &str) -> Result<StrategyKind, Error> {
        match s {
            "serial" => Ok(StrategyKind::Serial),
            "c3_base" | "base" => Ok(StrategyKind::C3Base),
            "c3_sp" | "sp" => Ok(StrategyKind::C3Sp),
            "c3_rp" | "rp" => Ok(StrategyKind::C3Rp),
            "c3_sp_rp" | "sp_rp" => Ok(StrategyKind::C3SpRp),
            "c3_best" | "best" => Ok(StrategyKind::C3Best),
            "conccl" => Ok(StrategyKind::Conccl),
            "conccl_rp" => Ok(StrategyKind::ConcclRp),
            "c3_chunked" | "chunked" => Ok(StrategyKind::C3Chunked),
            "conccl_chunked" => Ok(StrategyKind::ConcclChunked),
            other => Err(Error::UnknownStrategy(other.to_string())),
        }
    }

    /// Every concrete strategy (all figure columns except the derived
    /// `c3_best`, plus the chunked pipeline columns), in figure order.
    /// This is the full sweep lineup.
    pub fn lineup() -> [StrategyKind; 9] {
        [
            StrategyKind::Serial,
            StrategyKind::C3Base,
            StrategyKind::C3Sp,
            StrategyKind::C3Rp,
            StrategyKind::C3SpRp,
            StrategyKind::Conccl,
            StrategyKind::ConcclRp,
            StrategyKind::C3Chunked,
            StrategyKind::ConcclChunked,
        ]
    }

    /// The columns the report tables aggregate (includes `c3_best`,
    /// excludes the trivial serial row).
    pub fn reported() -> [StrategyKind; 7] {
        [
            StrategyKind::C3Base,
            StrategyKind::C3Sp,
            StrategyKind::C3Rp,
            StrategyKind::C3SpRp,
            StrategyKind::C3Best,
            StrategyKind::Conccl,
            StrategyKind::ConcclRp,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_paper() {
        assert_eq!(Strategy::C3Base.name(), "c3_base");
        assert_eq!(Strategy::C3Rp { comm_cus: 32 }.name(), "c3_rp");
        assert_eq!(Strategy::ConcclRp { cus_removed: 8 }.name(), "conccl_rp");
    }

    #[test]
    fn cu_usage_classification() {
        assert!(Strategy::C3Base.comm_on_cus());
        assert!(Strategy::C3Sp.comm_on_cus());
        assert!(Strategy::C3Chunked { chunks: 4 }.comm_on_cus());
        assert!(!Strategy::Conccl.comm_on_cus());
        assert!(!Strategy::ConcclRp { cus_removed: 8 }.comm_on_cus());
        assert!(!Strategy::ConcclChunked { chunks: 4 }.comm_on_cus());
        assert!(Strategy::ConcclChunked { chunks: 4 }.is_chunked());
        assert!(!Strategy::Conccl.is_chunked());
    }

    #[test]
    fn strategy_parse_round_trips() {
        for s in [
            "serial", "c3_base", "c3_sp", "c3_rp", "c3_sp_rp", "conccl", "conccl_rp",
            "c3_chunked", "conccl_chunked",
        ] {
            assert_eq!(Strategy::parse(s, 32).unwrap().name(), s);
        }
        assert!(Strategy::parse("warp", 32).is_err());
        // Bare chunked parse defaults to auto chunk selection.
        assert_eq!(
            Strategy::parse("conccl_chunked", 32).unwrap(),
            Strategy::ConcclChunked { chunks: 0 }
        );
    }

    #[test]
    fn kind_parse_round_trips_and_rejects_unknown() {
        for k in StrategyKind::lineup() {
            assert_eq!(StrategyKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(StrategyKind::parse("c3_best").unwrap(), StrategyKind::C3Best);
        let err = StrategyKind::parse("bogus").unwrap_err();
        assert!(err.to_string().contains("bogus"));
    }

    #[test]
    fn reported_covers_figure_columns() {
        let names: Vec<&str> = StrategyKind::reported().iter().map(|k| k.name()).collect();
        assert_eq!(
            names,
            vec!["c3_base", "c3_sp", "c3_rp", "c3_sp_rp", "c3_best", "conccl", "conccl_rp"]
        );
    }
}
