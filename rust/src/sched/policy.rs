//! The per-node C3 planner: a cost-model-driven policy layer over the
//! workload-graph engine.
//!
//! The pairwise heuristics each answer one question about one isolated
//! (GEMM, collective) pair; the PR-4 e2e families stamp one uniform
//! answer onto every node of a workload graph. This module closes the
//! gap the paper's §V-C/§VI-G runtime argument leaves open: walk an
//! [`E2eTrace`] and decide **per node** —
//!
//! * **backend** — offloadable collectives go to the SDMA engines,
//!   reduce-scatters stay on CUs (the §VII-A2 hybrid), *and* when the
//!   prefetch window keeps more concurrent DMA collectives in flight
//!   than the GPU has engines
//!   ([`CostModel::engines_oversubscribed`]), the planner splits the
//!   window's gathers across both pools instead of piling them onto
//!   one (the engines and the collective CUs are disjoint resources —
//!   exactly the complementary-resource argument of §V-A, applied
//!   between two *communication* backends);
//! * **CU partition** — CU-resident collectives get their §V-C
//!   reservation ([`CostModel::recommend_cus`]) and memory-bound GEMMs
//!   shed the §VI-G cache-dip CUs ([`CostModel::recommend_cu_shed`]);
//! * **granularity** — each DMA gather gets the chunk tuner's count
//!   ([`CostModel::recommend_chunks`]);
//! * **issue order** — per-stage priority from the workgroup proxy
//!   ([`CostModel::comm_first`]).
//!
//! The cost model *proposes*; the graph engine *disposes*: every
//! proposal (plus the fixed-family stamps and a fully serialized
//! chain) is simulated and the best timeline wins — the same sweep
//! protocol the executor already applies to rp reservations and chunk
//! counts (§V-B), lifted to whole-graph plans. Because the candidate
//! set always contains the serialized chain and both fixed overlap
//! families, `E2eFamily::Auto` can never lose to any of them — by
//! construction, not by tuning.

use crate::config::machine::MachineConfig;
use crate::error::Error;
use crate::fabric::Topology;
use crate::heuristics::CostModel;
use crate::kernels::CollectiveKernel;
use crate::sched::graph;
use crate::util::pool;
use crate::workload::e2e::{
    build_graph_planned_with, build_serial_chain_with, serial_total, CommPricer, E2eFamily,
    E2eKind, E2eRun, E2eStage, E2eTrace, PlannedGraph,
};
use crate::workload::ResolvedScenario;

/// Execution backend of one collective node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanBackend {
    /// SDMA engines (ConCCL).
    Dma,
    /// CU-resident (RCCL-like) kernel.
    Cu,
}

impl PlanBackend {
    pub fn name(self) -> &'static str {
        match self {
            PlanBackend::Dma => "dma",
            PlanBackend::Cu => "cu",
        }
    }
}

/// Plan of one collective node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollPlan {
    pub backend: PlanBackend,
    /// CU grant while resident (CU backend; ignored for DMA).
    pub cus: u32,
    /// Chunk count (1 = whole kernel).
    pub chunks: u32,
}

/// Per-stage node annotations the graph builder consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StagePlan {
    pub gather: Option<CollPlan>,
    pub reduce: Option<CollPlan>,
    /// Fixed CU grant for the stage's GEMM (`None` = residual policy).
    pub gemm_cus: Option<u32>,
    /// §V-C issue order: `true` enqueues the gather before the GEMM
    /// launch; `false` (a GEMM with fewer workgroups than the
    /// collective's CU need) makes the gather's launch wait out the
    /// GEMM's launch slot (`workload::e2e::build_graph_planned` adds
    /// `kernel_launch_s` to its ready lag).
    pub comm_first: bool,
}

/// One fully annotated plan candidate.
#[derive(Debug, Clone)]
pub struct PlanCandidate {
    pub name: &'static str,
    pub stages: Vec<StagePlan>,
}

/// One row of the rendered plan summary (one graph node's decisions).
#[derive(Debug, Clone, PartialEq)]
pub struct PlanNode {
    pub label: String,
    /// `gather` / `gemm` / `reduce`.
    pub role: &'static str,
    /// `dma` / `cu` (GEMMs report `cu`).
    pub backend: &'static str,
    /// CU grant (collectives: reservation; GEMMs: fixed grant, 0 =
    /// residual).
    pub cus: u32,
    /// Chunk count (compute nodes report 1).
    pub chunks: u32,
}

/// The winning plan of one `E2eFamily::Auto` evaluation.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanSummary {
    /// Name of the winning candidate (e.g. `split-even`).
    pub strategy: &'static str,
    /// How many candidate plans were simulated.
    pub candidates: usize,
    pub nodes: Vec<PlanNode>,
}

/// The one per-stage `StagePlan` constructor every stamp and candidate
/// shares: whole-kernel collectives at their full CU need,
/// reduce-scatters pinned to CUs (never DMA-offloadable — the §VII-A2
/// hybrid), residual GEMMs, comm-first issue order. The gather backend
/// comes from `gather_backend(gather_index, kernel)` (consulted only
/// for offloadable kinds).
fn stamp_stages<F: FnMut(usize, &CollectiveKernel) -> PlanBackend>(
    m: &MachineConfig,
    trace: &E2eTrace,
    mut gather_backend: F,
) -> Vec<StagePlan> {
    let mut gi = 0usize;
    trace
        .stages
        .iter()
        .map(|stage| {
            let gather = stage.gather.as_ref().map(|k| {
                let backend = if k.spec.kind.dma_offloadable() {
                    gather_backend(gi, k)
                } else {
                    PlanBackend::Cu
                };
                gi += 1;
                CollPlan {
                    backend,
                    cus: k.cu_need(m),
                    chunks: 1,
                }
            });
            StagePlan {
                gather,
                reduce: stage.reduce.as_ref().map(|k| CollPlan {
                    backend: PlanBackend::Cu,
                    cus: k.cu_need(m),
                    chunks: 1,
                }),
                gemm_cus: None,
                comm_first: true,
            }
        })
        .collect()
}

/// Uniform per-stage annotations of a fixed overlap family — the
/// "whole-graph family stamp" the planner generalizes. `build_graph`
/// routes fixed families through this, so the stamp and the planner
/// share one graph builder. (The stamp keeps `comm_first = true`
/// unconditionally: it must reproduce the pre-planner family numbers
/// bit-for-bit; the planner's derived candidates overwrite the
/// ordering from the cost model via `Planner::apply_comm_first`.)
pub fn family_stages(m: &MachineConfig, trace: &E2eTrace, family: E2eFamily) -> Vec<StagePlan> {
    let dma = family == E2eFamily::DmaOverlap;
    stamp_stages(m, trace, |_, _| {
        if dma {
            PlanBackend::Dma
        } else {
            PlanBackend::Cu
        }
    })
}

/// Number of leading stages on which two per-stage plans agree. Over
/// those stages the candidates' graphs are byte-identical node for node
/// ([`crate::workload::e2e::PlannedGraph::stage_nodes`] maps the stage
/// count to the node prefix), which is exactly the prefix a memoized
/// re-simulation ([`graph::execute_resuming`]) may skip.
pub fn common_prefix_stages(a: &[StagePlan], b: &[StagePlan]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// A per-**request-class** serving plan: one backend decision for the
/// latency-critical decode-path collectives and one for the
/// deadline-tolerant KV-cache/background stream — the serving analogue
/// of a [`PlanCandidate`]. The two classes have complementary needs
/// (issue latency vs bulk wire rate), so the right answer is usually
/// *mixed*: decode stays CU-resident, the KV stream takes the otherwise
/// idle SDMA engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeClassPlan {
    pub name: &'static str,
    /// Backend for the per-token decode collectives (reducing
    /// collectives stay on CUs regardless — the builder enforces it).
    pub decode: PlanBackend,
    /// Backend for the KV-cache/background stream.
    pub kv: PlanBackend,
    /// Chunk count for the KV stream (1 = whole transfer).
    pub kv_chunks: u32,
}

/// Candidate per-request-class plans for one serving step shape,
/// heuristic pick first ([`CostModel::stream_prefers_dma`] orders the
/// lineup; the traffic engine's simulate-and-argmin protocol decides).
/// `decode` is a representative decode-path collective of the step;
/// `kv_bytes > 0` adds the KV-stream candidates. Duplicates (e.g. when
/// the heuristic pick coincides with a uniform stamp) are dropped, so
/// every candidate simulated is a distinct graph.
pub fn serve_candidates(
    cost: &CostModel,
    decode: &CollectiveKernel,
    kv_bytes: u64,
) -> Vec<ServeClassPlan> {
    let backend = |dma: bool| if dma { PlanBackend::Dma } else { PlanBackend::Cu };
    let dec = backend(cost.stream_prefers_dma(decode, false));
    let mut out: Vec<ServeClassPlan> = Vec::new();
    let mut push = |p: ServeClassPlan| {
        if !out.iter().any(|q| (q.decode, q.kv, q.kv_chunks) == (p.decode, p.kv, p.kv_chunks)) {
            out.push(p);
        }
    };
    if kv_bytes > 0 {
        // Per-class split first: decode per its own latency regime, the
        // bulk stream on the engines.
        push(ServeClassPlan { name: "kv-dma", decode: dec, kv: PlanBackend::Dma, kv_chunks: 1 });
        // Chunked KV ingest: per-chunk DMA batches ride the shared
        // enqueue queue, releasing SDMA occupancy between chunks.
        push(ServeClassPlan {
            name: "kv-dma-chunked",
            decode: dec,
            kv: PlanBackend::Dma,
            kv_chunks: 4,
        });
    }
    // The two uniform stamps — identical to the fixed cu_overlap /
    // dma_overlap serving families, so auto can never lose to either.
    push(ServeClassPlan {
        name: "cu-uniform",
        decode: PlanBackend::Cu,
        kv: PlanBackend::Cu,
        kv_chunks: 1,
    });
    push(ServeClassPlan {
        name: "dma-uniform",
        decode: PlanBackend::Dma,
        kv: PlanBackend::Dma,
        kv_chunks: 1,
    });
    out
}

/// The per-node planner: one [`CostModel`] per `(machine, topology)`,
/// reused across every stage decision and candidate.
#[derive(Debug, Clone)]
pub struct Planner {
    pub cost: CostModel,
    /// Worker threads for the parallel candidate evaluation in
    /// [`Planner::run_auto`] (`1` = fully inline). The result is
    /// byte-identical at any width — this knob only trades wall clock.
    pub threads: usize,
}

impl Planner {
    /// Build the planner (profiles the cost model's slowdown table
    /// once).
    pub fn new(m: &MachineConfig, topo: &Topology) -> Planner {
        Planner {
            cost: CostModel::new(m, topo),
            // The candidate lineup tops out around eight graphs and two
            // of them are simulated inline as recordings, so a handful
            // of workers already saturates the fan-out.
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(4),
        }
    }

    /// Override the candidate-evaluation thread count (benchmarks pin
    /// sequential vs parallel this way).
    pub fn with_threads(mut self, threads: usize) -> Planner {
        self.threads = threads.max(1);
        self
    }

    fn m(&self) -> &MachineConfig {
        &self.cost.m
    }

    /// The isolated (GEMM, collective) pair scenario the pairwise
    /// heuristics price a stage's decision from.
    fn pair(&self, stage: &E2eStage, kernel: &CollectiveKernel) -> ResolvedScenario {
        ResolvedScenario {
            scenario: crate::config::workload::C3Scenario {
                gemm_tag: stage.gemm.tag.clone(),
                gemm: stage.gemm.shape,
                comm: kernel.spec,
                source: crate::config::workload::Source::Synthetic,
            },
            gemm: stage.gemm.clone(),
            comm: *kernel,
            paper_type: crate::workload::taxonomy::C3Type::GLong,
        }
    }

    /// Largest number of weight gathers the dependency structure lets
    /// run concurrently: the prefetch window for FSDP traces, 1 for the
    /// TP chain (activation gathers serialize on the previous GEMM).
    pub fn max_inflight_gathers(&self, trace: &E2eTrace, depth: usize) -> usize {
        let gathers = trace.stages.iter().filter(|s| s.gather.is_some()).count();
        match trace.kind {
            E2eKind::TpChain => 1.min(gathers),
            _ => (trace.stages_per_layer * depth.max(1)).min(gathers),
        }
    }

    /// Overwrite every stage's issue-order bit with the cost model's
    /// launch-latency decision — applied to each *derived* candidate
    /// (the pure family stamps keep comm-first to stay bit-identical
    /// with the pre-planner families). In the graph model a GEMM-first
    /// ordering is a pure defer on the gather, so the comm-first stamps
    /// double as the ordering control: if a derived plan would win on
    /// backends/grants but lose on its ordering, the argmin falls back
    /// to the stamp rather than shipping the handicap.
    fn apply_comm_first(&self, trace: &E2eTrace, stages: &mut [StagePlan]) {
        for (sp, stage) in stages.iter_mut().zip(&trace.stages) {
            sp.comm_first = stage
                .gather
                .as_ref()
                .map(|k| self.cost.comm_first(&stage.gemm, k))
                .unwrap_or(false);
        }
    }

    /// Stage plans with the gather backends chosen by a pool-assignment
    /// rule (`assign(gather_index) -> backend`); reduces stay on CUs,
    /// chunks default to whole kernels, issue order from the cost
    /// model.
    fn assigned_stages<F: FnMut(usize) -> PlanBackend>(
        &self,
        trace: &E2eTrace,
        mut assign: F,
    ) -> Vec<StagePlan> {
        let mut stages = stamp_stages(self.m(), trace, |gi, _| assign(gi));
        self.apply_comm_first(trace, &mut stages);
        stages
    }

    /// The candidate plan lineup for one trace: the fixed-family stamps
    /// plus every cost-model proposal that applies to this trace's
    /// regime. (The serialized chain rides separately in
    /// [`Planner::run_auto`] — its dependency structure is not a
    /// per-stage annotation.)
    pub fn candidates(&self, trace: &E2eTrace, depth: usize) -> Vec<PlanCandidate> {
        let m = self.m();
        let mut out = Vec::new();
        out.push(PlanCandidate {
            name: "cu-uniform",
            stages: family_stages(m, trace, E2eFamily::CuOverlap),
        });
        out.push(PlanCandidate {
            name: "dma-hybrid",
            stages: family_stages(m, trace, E2eFamily::DmaOverlap),
        });

        // §V-C CU reservations for CU-resident collectives instead of
        // the blanket full-need grant.
        let mut rp_stages = family_stages(m, trace, E2eFamily::CuOverlap);
        self.apply_comm_first(trace, &mut rp_stages);
        let mut rp_differs = false;
        for (si, (sp, stage)) in rp_stages.iter_mut().zip(&trace.stages).enumerate() {
            if let (Some(cp), Some(k)) = (&mut sp.gather, &stage.gather) {
                let rec = self.cost.recommend_cus(&self.pair(stage, k));
                if rec != cp.cus {
                    cp.cus = rec;
                    rp_differs = true;
                }
            }
            if let (Some(cp), Some(k)) = (&mut sp.reduce, &stage.reduce) {
                // A reduce issues after its own GEMM, so the compute it
                // actually co-runs with is the *next* stage's — price
                // the reservation against that pairing.
                let co_stage = trace.stages.get(si + 1).unwrap_or(stage);
                let rec = self.cost.recommend_cus(&self.pair(co_stage, k));
                if rec != cp.cus {
                    cp.cus = rec;
                    rp_differs = true;
                }
            }
        }
        if rp_differs {
            out.push(PlanCandidate { name: "cu-rp", stages: rp_stages });
        }

        // Pool splitting: only when the window genuinely oversubscribes
        // the SDMA engines (otherwise a lone DMA collective is never
        // engine-bound and the hybrid stamp already covers it).
        if self.cost.engines_oversubscribed(self.max_inflight_gathers(trace, depth)) {
            out.push(PlanCandidate {
                name: "split-even",
                stages: self.assigned_stages(trace, |gi| {
                    if gi % 2 == 0 { PlanBackend::Dma } else { PlanBackend::Cu }
                }),
            });
            out.push(PlanCandidate {
                name: "split-odd",
                stages: self.assigned_stages(trace, |gi| {
                    if gi % 2 == 1 { PlanBackend::Dma } else { PlanBackend::Cu }
                }),
            });
            out.push(PlanCandidate {
                name: "split-thirds",
                stages: self.assigned_stages(trace, |gi| {
                    if gi % 3 == 0 { PlanBackend::Dma } else { PlanBackend::Cu }
                }),
            });
        }

        // Chunked-DMA gathers where the tuner projects a win. The
        // proposal is priced on the pairwise co-chunked projection —
        // deliberately conservative for the e2e graph, whose stage
        // GEMMs stay whole; the simulated argmin, not the projection,
        // decides whether the chunking actually pays.
        let mut chunked = family_stages(m, trace, E2eFamily::DmaOverlap);
        self.apply_comm_first(trace, &mut chunked);
        let mut any_chunked = false;
        for (sp, stage) in chunked.iter_mut().zip(&trace.stages) {
            if let (Some(cp), Some(k)) = (&mut sp.gather, &stage.gather) {
                if cp.backend == PlanBackend::Dma {
                    let rec = self.cost.recommend_comm_chunks(&self.pair(stage, k), true);
                    if rec >= 2 {
                        cp.chunks = rec;
                        any_chunked = true;
                    }
                }
            }
        }
        if any_chunked {
            out.push(PlanCandidate { name: "dma-chunked", stages: chunked });
        }

        // §VI-G cache-dip CU shedding on memory-bound GEMMs under DMA
        // offload.
        let mut trimmed = family_stages(m, trace, E2eFamily::DmaOverlap);
        self.apply_comm_first(trace, &mut trimmed);
        let mut any_trim = false;
        for (sp, stage) in trimmed.iter_mut().zip(&trace.stages) {
            let shed = self.cost.recommend_cu_shed(&stage.gemm);
            if shed > 0 {
                sp.gemm_cus = Some(m.cus_total().saturating_sub(shed).max(8));
                any_trim = true;
            }
        }
        if any_trim {
            out.push(PlanCandidate { name: "dma-trim", stages: trimmed });
        }

        out
    }

    /// Evaluate `E2eFamily::Auto`: simulate the serialized chain, both
    /// fixed overlap stamps and every cost-model proposal on the graph
    /// engine, keep the best timeline, and return it with the winning
    /// plan. Never worse than serial / cu_overlap / dma_overlap by
    /// construction.
    ///
    /// The fixed stamps are deliberately re-simulated even when the
    /// caller (the sweep's family lineup) has already run them: the
    /// candidate set stays self-contained and auditable, and the cost —
    /// a handful of sub-millisecond graph runs per e2e point — is noise
    /// next to the pairwise job matrix.
    ///
    /// Evaluation is prefix-memoized and parallel: all candidate graphs
    /// are built first (sequentially — they share one wire-pricing
    /// memo), the two family poles are simulated inline with prefix
    /// checkpoints recorded, and every remaining candidate resumes from
    /// the deepest checkpoint preceding its first planned deviation, on
    /// a worker pool. Which checkpoint a candidate resumes from depends
    /// only on the stamps — never on timing or thread schedule — and a
    /// resumed timeline is bit-identical to a cold run, so the argmin
    /// (first strictly-smaller total wins, in candidate order) produces
    /// byte-identical output at any thread count.
    pub fn run_auto(
        &self,
        trace: &E2eTrace,
        depth: usize,
    ) -> Result<(E2eRun, PlanSummary), Error> {
        let m = self.m();
        let topo = &self.cost.topo;
        let serial = serial_total(m, topo, trace);

        // Build every graph up front: the builds share one pricing
        // memo (collective wire time is the expensive derivation), and
        // the simulations below only ever read the graphs.
        let mut pricer = CommPricer::new();
        let chain = build_serial_chain_with(m, topo, trace, &mut pricer)?;
        let cands = self.candidates(trace, depth);
        let built: Vec<PlannedGraph> = cands
            .iter()
            .map(|c| build_graph_planned_with(m, topo, trace, depth, &c.stages, &mut pricer))
            .collect::<Result<_, _>>()?;

        // The "do not overlap" bound seeds the argmin.
        let chain_run = graph::execute(m, topo, &chain)?;

        // Simulate the two family poles (always candidates 0 and 1:
        // cu-uniform and dma-hybrid) inline, recording prefix
        // checkpoints — every other candidate is a per-stage deviation
        // from one of them, so it can resume mid-timeline instead of
        // replaying the shared prefix.
        let n_rec = cands.len().min(2);
        let mut timelines: Vec<graph::PrefixTimeline> = Vec::with_capacity(n_rec);
        let mut runs: Vec<Option<graph::GraphRun>> = vec![None; cands.len()];
        for i in 0..n_rec {
            let (run, tl) = graph::execute_recording(m, topo, &built[i].graph)?;
            runs[i] = Some(run);
            timelines.push(tl);
        }
        let rest = pool::run_indexed(cands.len() - n_rec, self.threads, |j| {
            let i = n_rec + j;
            // Deepest shared prefix wins; ties resolve to the later
            // recording — a fixed rule, so the pick is deterministic.
            let (r, boundary) = (0..n_rec)
                .map(|r| {
                    let s = common_prefix_stages(&cands[r].stages, &cands[i].stages);
                    (r, built[i].stage_nodes[s])
                })
                .max_by_key(|&(_, b)| b)
                .unwrap_or((0, 0));
            graph::execute_resuming(m, topo, &built[i].graph, &timelines[r], boundary)
        });
        for (j, r) in rest.into_iter().enumerate() {
            runs[n_rec + j] = Some(r?);
        }

        let chain_stages = family_stages(m, trace, E2eFamily::CuOverlap);
        let mut n_candidates = 1usize;
        // Auto's counters aggregate every simulation the lineup cost —
        // the chain bound plus all candidates — not just the winner's.
        let mut counters = chain_run.counters;
        let mut best: (graph::GraphRun, usize, &'static str, Vec<StagePlan>) =
            (chain_run, chain.nodes.len(), "serial-chain", chain_stages);
        for (i, cand) in cands.into_iter().enumerate() {
            let run = runs[i].take().expect("every candidate was simulated");
            counters.absorb(run.counters);
            n_candidates += 1;
            if run.total < best.0.total {
                best = (run, built[i].graph.nodes.len(), cand.name, cand.stages);
            }
        }
        let (run, graph_nodes, name, stages) = best;
        let e2e = E2eRun {
            family: E2eFamily::Auto,
            total: run.total,
            serial,
            speedup: serial / run.total,
            exposed_comm: run.exposed_comm,
            bubble: run.bubble,
            hbm_occupancy: run.hbm_occupancy,
            sdma_occupancy: run.sdma_occupancy,
            graph_nodes,
            counters,
        };
        Ok((e2e, self.summarize(trace, name, n_candidates, &stages)))
    }

    /// Flatten a winning plan into per-node records for tables/JSON.
    fn summarize(
        &self,
        trace: &E2eTrace,
        strategy: &'static str,
        candidates: usize,
        stages: &[StagePlan],
    ) -> PlanSummary {
        let mut nodes = Vec::new();
        for (stage, sp) in trace.stages.iter().zip(stages) {
            if let (Some(_), Some(cp)) = (&stage.gather, &sp.gather) {
                nodes.push(PlanNode {
                    label: format!("{}/gather", stage.label),
                    role: "gather",
                    backend: cp.backend.name(),
                    cus: if cp.backend == PlanBackend::Cu { cp.cus } else { 0 },
                    chunks: cp.chunks,
                });
            }
            nodes.push(PlanNode {
                label: format!("{}/gemm", stage.label),
                role: "gemm",
                backend: "cu",
                cus: sp.gemm_cus.unwrap_or(0),
                chunks: 1,
            });
            if let (Some(_), Some(cp)) = (&stage.reduce, &sp.reduce) {
                nodes.push(PlanNode {
                    label: format!("{}/reduce", stage.label),
                    role: "reduce",
                    backend: cp.backend.name(),
                    cus: cp.cus,
                    chunks: cp.chunks,
                });
            }
        }
        PlanSummary {
            strategy,
            candidates,
            nodes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::e2e::{fsdp_step_stages, tp_chain_stages};
    use crate::workload::llama::LlamaConfig;

    #[test]
    fn serve_candidates_split_per_request_class() {
        use crate::config::workload::{CollectiveKind, CollectiveSpec};
        let m = MachineConfig::mi300x();
        let cost = CostModel::new(&m, &m.topology(1));
        let tiny =
            CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, 256 * 1024));
        // With a KV stream, the per-class split leads the lineup and the
        // two uniform stamps are always present (so argmin over the
        // candidates can never lose to a fixed serving family).
        let cands = serve_candidates(&cost, &tiny, 64 << 20);
        assert_eq!(cands[0].name, "kv-dma");
        assert_eq!(cands[0].decode, PlanBackend::Cu, "tiny decode collectives stay CU-resident");
        assert_eq!(cands[0].kv, PlanBackend::Dma);
        assert!(cands.iter().any(|c| c.name == "cu-uniform"));
        assert!(cands.iter().any(|c| c.name == "dma-uniform"));
        assert!(cands.iter().any(|c| c.name == "kv-dma-chunked" && c.kv_chunks > 1));
        // No duplicate (decode, kv, chunks) triples.
        for (i, a) in cands.iter().enumerate() {
            for b in &cands[i + 1..] {
                assert_ne!((a.decode, a.kv, a.kv_chunks), (b.decode, b.kv, b.kv_chunks));
            }
        }
        // Without a KV stream only the uniform stamps remain.
        let no_kv = serve_candidates(&cost, &tiny, 0);
        assert_eq!(no_kv.len(), 2);
        assert_eq!(no_kv[0].name, "cu-uniform");
    }

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    #[test]
    fn family_stamps_are_uniform_and_hybrid() {
        let m = m();
        let t = fsdp_step_stages(&LlamaConfig::llama70b(), 2);
        let dma = family_stages(&m, &t, E2eFamily::DmaOverlap);
        assert_eq!(dma.len(), t.stages.len());
        // Every offloadable gather on DMA; every reduce pinned to CUs.
        for sp in &dma {
            assert_eq!(sp.gather.unwrap().backend, PlanBackend::Dma);
            if let Some(r) = sp.reduce {
                assert_eq!(r.backend, PlanBackend::Cu);
                assert_eq!(r.cus, m.rs_cu_need);
            }
        }
        let cu = family_stages(&m, &t, E2eFamily::CuOverlap);
        assert!(cu.iter().all(|sp| sp.gather.unwrap().backend == PlanBackend::Cu));
    }

    #[test]
    fn window_detection_respects_dependency_structure() {
        let p = Planner::new(&m(), &m().topology(1));
        let fsdp = fsdp_step_stages(&LlamaConfig::llama70b(), 2);
        // FSDP window = stages_per_layer * depth.
        assert_eq!(p.max_inflight_gathers(&fsdp, 2), 4);
        assert_eq!(p.max_inflight_gathers(&fsdp, 1), 2);
        // TP activations serialize on the previous GEMM: never > 1.
        let tp = tp_chain_stages(&LlamaConfig::llama70b(), 4);
        assert_eq!(p.max_inflight_gathers(&tp, 2), 1);
    }

    #[test]
    fn candidate_lineup_matches_the_regime() {
        let m = m();
        let p = Planner::new(&m, &m.topology(1));
        // FSDP window 4 oversubscribes 14 engines (4x8 = 32): the pool
        // splits are proposed.
        let fsdp = fsdp_step_stages(&LlamaConfig::llama70b(), 2);
        let names: Vec<&str> = p.candidates(&fsdp, 2).iter().map(|c| c.name).collect();
        assert!(names.contains(&"cu-uniform") && names.contains(&"dma-hybrid"));
        assert!(names.contains(&"split-even") && names.contains(&"split-odd"));
        // mb1 sheds CUs under DMA offload (§VI-G), so the trim rides.
        assert!(names.contains(&"dma-trim"));
        // TP chain: one gather in flight — no pool split to propose.
        let tp = tp_chain_stages(&LlamaConfig::llama70b(), 2);
        let tp_names: Vec<&str> = p.candidates(&tp, 2).iter().map(|c| c.name).collect();
        assert!(!tp_names.iter().any(|n| n.starts_with("split")));
        assert!(tp_names.contains(&"cu-uniform") && tp_names.contains(&"dma-hybrid"));
    }

    #[test]
    fn split_assignment_alternates_pools() {
        let m = m();
        let p = Planner::new(&m, &m.topology(2));
        let fsdp = fsdp_step_stages(&LlamaConfig::llama70b(), 2);
        let cands = p.candidates(&fsdp, 2);
        let split = cands.iter().find(|c| c.name == "split-even").unwrap();
        let backends: Vec<PlanBackend> =
            split.stages.iter().map(|sp| sp.gather.unwrap().backend).collect();
        // Gathers alternate DMA/CU starting from DMA...
        for (i, b) in backends.iter().enumerate() {
            let expect = if i % 2 == 0 { PlanBackend::Dma } else { PlanBackend::Cu };
            assert_eq!(*b, expect, "gather {i}");
        }
        // ... and every reduce still rides CUs (the hybrid is preserved
        // under every candidate).
        for c in &cands {
            for sp in &c.stages {
                if let Some(r) = sp.reduce {
                    assert_eq!(r.backend, PlanBackend::Cu, "{}", c.name);
                }
            }
        }
    }

    #[test]
    fn comm_first_is_recorded_per_stage() {
        let m = m();
        let p = Planner::new(&m, &m.topology(1));
        let fsdp = fsdp_step_stages(&LlamaConfig::llama70b(), 1);
        for c in p.candidates(&fsdp, 2) {
            if c.name.starts_with("split") {
                // All Table-I-sized GEMMs dwarf the collectives'
                // workgroup needs: comm launches first on every stage.
                assert!(c.stages.iter().all(|sp| sp.comm_first));
            }
        }
    }
}
