//! Fine-grain chunked C3 pipelining (the follow-up direction of
//! arXiv 2512.10236 "Design Space Exploration of DMA based Finer-Grain
//! Compute Communication Overlap", priced against DMA-Latte's
//! per-packet launch costs).
//!
//! The whole-kernel strategies overlap one GEMM with one collective for
//! their entire lifetimes, so both kernels pay the §VII-A1 *residual*
//! memory-subsystem interference (`mem_interference_*`,
//! `comm_co_penalty_*`, `gemm_l2_pollution_*`) throughout the overlap
//! window. The chunked pipeline instead splits the GEMM into `k` tiled
//! sub-kernels ([`GemmKernel::split_m`]) and the collective into `k`
//! chunk transfers, and issues collective chunk `i` at GEMM chunk `i`'s
//! completion — so it overlaps GEMM chunk `i+1` and rides the GEMM's
//! inter-chunk HBM gaps instead of colliding with its panel-streaming
//! bursts. Granularity buys interference relief (the surviving penalty
//! is `MachineConfig::chunk_align(k)` of the whole-kernel value) and
//! costs launches:
//!
//! * every GEMM chunk pays `kernel_launch_s` plus wave quantization of
//!   its sub-grid;
//! * every DMA chunk is a fresh `CommandPacket` batch: the CPU thread
//!   serializes `num_gpus · dma_enqueue_s` per chunk (and the engine
//!   `dma_fetch_s`), so small chunks go *latency-bound* exactly as
//!   DMA-Latte reports — naive chunking collapses below a few MiB;
//! * CU-backend chunks pay `coll_launch_s` each.
//!
//! `chunks == 1` is defined as the whole-kernel strategy itself (there
//! is no pipeline with a single chunk; the executor delegates to
//! `c3_sp` / `conccl` exactly), which makes the swept/auto chunk count
//! *never worse* than the unchunked strategy by construction. The
//! timeline runs on the same fluid simulator as the whole-kernel
//! executor — one task per chunk, caps recomputed at every event.

use crate::conccl::DmaCollective;
use crate::config::machine::smoothmax;
use crate::config::workload::CollectiveSpec;
use crate::error::Error;
use crate::kernels::{CollectiveKernel, GemmKernel};
use crate::sim::fluid::StallError;
use crate::sim::{Event, Sim, TaskSpec};
use crate::workload::ResolvedScenario;

use super::executor::C3Executor;

/// Split a collective payload into `k` near-equal chunk sizes that sum
/// exactly to `total`.
pub fn chunk_sizes(total: u64, k: u32) -> Vec<u64> {
    let k = k.max(1) as u64;
    (0..k)
        .map(|i| total * (i + 1) / k - total * i / k)
        .collect()
}

/// Simulate the chunked pipeline for one scenario at `k >= 2` chunks.
/// `cu_backend` selects the CU-collective chunks (`c3_chunked`) vs the
/// DMA chunk batches (`conccl_chunked`). Returns
/// `(total, gemm_finish, comm_finish)` like the whole-kernel timeline.
pub(crate) fn simulate_chunked(
    exec: &C3Executor,
    sc: &ResolvedScenario,
    cu_backend: bool,
    k: u32,
) -> Result<(f64, f64, f64), Error> {
    let m = &exec.m;
    let topo = &exec.topo;
    let cus = m.cus_total();
    let comm_need = sc.comm.cu_need(m);

    // Effective chunk count: never more chunks than the scenario
    // supports (the executor pre-clamps; stay defensive — same shared
    // clamp, `ResolvedScenario::chunk_cap`).
    let kk = k.max(2).min(sc.chunk_cap(m)).max(1) as usize;
    let align = m.chunk_align(kk as u32);

    let gemm_chunks: Vec<GemmKernel> = sc.gemm.split_m(m, kk as u32);
    debug_assert_eq!(gemm_chunks.len(), kk);
    // Memory-side chunk pricing is *prorated* from the whole kernel:
    // the LLC keeps its panel working set across chunk boundaries (the
    // hardware does not flush between back-to-back sub-kernels), so
    // re-evaluating the traffic model on each sub-shape would charge
    // every chunk a full B-panel re-stream that never happens. Only the
    // compute side re-quantizes (partial waves per sub-grid cost full
    // waves — the genuine dispatch price of chunking).
    let whole_flops = sc.gemm.shape.flops();
    let g_frac: Vec<f64> = gemm_chunks
        .iter()
        .map(|c| c.shape.flops() / whole_flops)
        .collect();
    let comm_specs: Vec<CollectiveSpec> = chunk_sizes(sc.comm.spec.size_bytes, kk as u32)
        .into_iter()
        .map(|s| CollectiveSpec::new(sc.comm.spec.kind, s))
        .collect();

    // Backend: typed failure (never a panic) when a non-offloadable
    // collective meets the DMA pipeline.
    let dma: Option<Vec<DmaCollective>> = if cu_backend {
        None
    } else {
        Some(
            comm_specs
                .iter()
                .map(|&s| DmaCollective::try_new(s))
                .collect::<Result<Vec<_>, Error>>()?,
        )
    };

    // Per-chunk wire times and HBM demands are loop-invariant.
    let wire: Vec<f64> = match &dma {
        Some(ds) => ds.iter().map(|d| d.wire_time_on(m, topo)).collect(),
        None => comm_specs
            .iter()
            .map(|&s| CollectiveKernel::new(s).t_wire_on(m, topo, comm_need.max(1)))
            .collect(),
    };
    let comm_hbm: Vec<f64> = comm_specs
        .iter()
        .map(|&s| CollectiveKernel::new(s).hbm_traffic(m))
        .collect();

    // Whole-kernel §VII-A1 bandwidth shares and penalty terms (the
    // shared derivations on `GemmKernel`/`CollectiveKernel`/
    // `MachineConfig` — identical to the whole-kernel executor, so the
    // two simulators cannot drift apart; the share is a rate fraction,
    // which chunking does not change).
    let mem_pen = |other_share: f64| m.mem_pen(other_share);
    let gemm_share = sc.gemm.hbm_share(m, cus);
    let comm_share = {
        let whole_wire = match &dma {
            Some(_) => DmaCollective::try_new(sc.comm.spec)?.wire_time_on(m, topo),
            None => sc.comm.t_wire_on(m, topo, comm_need.max(1)),
        };
        sc.comm.hbm_share_with_wire(m, whole_wire)
    };
    let pollution = if cu_backend {
        m.l2_pollution(sc.comm.spec.kind)
    } else {
        0.0
    };
    let co_penalty = m.comm_co_penalty(sc.comm.spec.kind);

    // Per-chunk issue costs. The DMA CPU enqueue thread serializes
    // across chunks (`cpu_free` chain) — DMA-Latte's collapse mechanism;
    // CU chunk launches are stream-ordered behind the matching GEMM
    // chunk instead.
    let dma_launch = m.num_gpus as f64 * m.dma_enqueue_s;

    let mut sim = Sim::new();
    let hbm = sim.add_resource("hbm", m.hbm_bw_achievable());
    let g_tasks: Vec<usize> = gemm_chunks
        .iter()
        .enumerate()
        .map(|(i, gk)| {
            sim.add_task(TaskSpec {
                name: format!("gemm:{}", gk.tag),
                arrival: 0.0,
                work: 1.0,
                demands: vec![(hbm, sc.gemm.hbm_traffic(m, cus) * g_frac[i])],
                cap: 0.0,
            })
        })
        .collect();
    let c_tasks: Vec<usize> = comm_specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            sim.add_task(TaskSpec {
                name: format!("comm:{}#{i}", s.kind.name()),
                arrival: 0.0,
                work: 1.0,
                demands: vec![(hbm, comm_hbm[i])],
                cap: 0.0,
            })
        })
        .collect();

    // Chain state: finish times and issue-ready times per chunk.
    let mut g_fin: Vec<Option<f64>> = vec![None; kk];
    let mut c_fin: Vec<Option<f64>> = vec![None; kk];
    let mut g_ready: Vec<f64> = vec![f64::INFINITY; kk];
    let mut c_ready: Vec<f64> = vec![f64::INFINITY; kk];
    g_ready[0] = m.kernel_launch_s;
    sim.schedule_wake(g_ready[0]);
    let mut cpu_free = 0.0f64; // DMA enqueue-thread clock
    let mut g_done = 0usize;
    let mut c_done = 0usize;

    loop {
        let now = sim.now();
        let eps = 1e-18;
        let gemm_running = g_done < kk && now + eps >= g_ready[g_done];
        let comm_running = c_done < kk && now + eps >= c_ready[c_done];

        if g_done < kk {
            let gi = g_done;
            let g_cus = if cu_backend && comm_running {
                cus - comm_need.min(cus / 2)
            } else {
                cus
            }
            .max(8);
            let chunk = &gemm_chunks[gi];
            let t_pure = smoothmax(
                chunk.t_comp(m, g_cus),
                sc.gemm.t_mem(m, g_cus) * g_frac[gi],
            );
            let pol = if cu_backend && comm_running {
                pollution * align
            } else {
                0.0
            };
            let mp = if comm_running {
                mem_pen(comm_share) * align
            } else {
                0.0
            };
            let cap = if gemm_running {
                (1.0 - pol) * (1.0 - mp) / t_pure
            } else {
                0.0
            };
            sim.set_cap(g_tasks[gi], cap);
            sim.set_demand(g_tasks[gi], hbm, sc.gemm.hbm_traffic(m, g_cus) * g_frac[gi]);
        }
        if c_done < kk {
            let ci = c_done;
            let mp = if gemm_running {
                mem_pen(gemm_share) * align
            } else {
                0.0
            };
            let cap = if !comm_running {
                0.0
            } else if cu_backend {
                let pen = if gemm_running { co_penalty * align } else { 0.0 };
                (1.0 - pen) * (1.0 - mp) / wire[ci]
            } else {
                (1.0 - mp) / wire[ci]
            };
            sim.set_cap(c_tasks[ci], cap);
        }

        match sim.next_event() {
            Event::Completion(t) => {
                if g_done < kk && t == g_tasks[g_done] {
                    let fin = sim.now();
                    g_fin[g_done] = Some(fin);
                    // Issue the matching collective chunk.
                    let ci = g_done;
                    c_ready[ci] = if cu_backend {
                        fin + m.coll_launch_s
                    } else {
                        // CPU enqueue chain: n packets per chunk,
                        // serialized on the orchestration thread, then
                        // the engine fetch.
                        let start = cpu_free.max(fin);
                        cpu_free = start + dma_launch;
                        cpu_free + m.dma_fetch_s
                    };
                    sim.schedule_wake(c_ready[ci].max(fin));
                    g_done += 1;
                    // Launch the next GEMM chunk.
                    if g_done < kk {
                        g_ready[g_done] = fin + m.kernel_launch_s;
                        sim.schedule_wake(g_ready[g_done]);
                    }
                } else if c_done < kk && t == c_tasks[c_done] {
                    c_fin[c_done] = Some(sim.now());
                    c_done += 1;
                }
            }
            Event::Idle => break,
            _ => {}
        }
        if g_done == kk && c_done == kk {
            break;
        }
    }
    if g_done < kk || c_done < kk {
        return Err(Error::SimStall(StallError {
            at: sim.now(),
            stalled: sim.stall_report(),
        }));
    }
    let gemm_finish = g_fin[kk - 1].expect("all gemm chunks finished");
    let sync = if dma.is_some() { m.dma_sync_s } else { 0.0 };
    let comm_finish = c_fin[kk - 1].expect("all comm chunks finished") + sync;
    Ok((gemm_finish.max(comm_finish), gemm_finish, comm_finish))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::CollectiveKind;
    use crate::sched::Strategy;
    use crate::util::units::MIB;
    use crate::workload::scenarios::resolve_tag;

    fn exec() -> C3Executor {
        C3Executor::new(MachineConfig::mi300x())
    }

    #[test]
    fn chunk_sizes_sum_exactly() {
        for (total, k) in [(896 * MIB, 8u32), (7, 3), (1, 1), (13 * 1024 * MIB, 16)] {
            let v = chunk_sizes(total, k);
            assert_eq!(v.len(), k as usize);
            assert_eq!(v.iter().sum::<u64>(), total);
            let (lo, hi) = (v.iter().min().unwrap(), v.iter().max().unwrap());
            assert!(hi - lo <= 1, "uneven split {v:?}");
        }
    }

    #[test]
    fn pipeline_timeline_is_well_formed() {
        let e = exec();
        let sc = resolve_tag("mb2_26.5G", CollectiveKind::AllGather).unwrap();
        let (total, g, c) = simulate_chunked(&e, &sc, false, 8).unwrap();
        assert!(total > 0.0 && g > 0.0 && c > 0.0);
        assert!((total - g.max(c)).abs() < 1e-15);
        // The collective is gated on the first GEMM chunk: it cannot
        // finish before that chunk's pure-compute time.
        let first = sc.gemm.split_m(&e.m, 8)[0].t_comp(&e.m, e.m.cus_total());
        assert!(c > first, "comm finished before the first GEMM chunk: {c} vs {first}");
        // And the whole thing can't beat the ideal lower bound.
        let b = e.baselines(&sc);
        assert!(total >= b.t_gemm_iso.max(b.t_comm_iso) * 0.999);
    }

    #[test]
    fn latency_bound_chunks_collapse_like_dma_latte() {
        // A small payload (4 MiB) chunked 16 ways pays 16 CPU enqueue
        // batches; the pipeline must be clearly worse than whole-kernel
        // ConCCL there (the DMA-Latte result the auto-tuner prices).
        let e = exec();
        let mut sc = resolve_tag("cb1_896M", CollectiveKind::AllGather).unwrap();
        sc.comm = CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, 4 * MIB));
        sc.scenario.comm = sc.comm.spec;
        let whole = e.run(&sc, Strategy::Conccl);
        let (chunk_total, _, chunk_comm) = simulate_chunked(&e, &sc, false, 16).unwrap();
        // The comm pipeline trails the GEMM (issue gated per chunk), so
        // its finish moves past the whole-kernel collective's.
        assert!(
            chunk_comm > whole.comm_finish,
            "chunked comm {chunk_comm} should trail whole-kernel {}",
            whole.comm_finish
        );
        assert!(chunk_total + 1e-12 >= whole.total);
    }

    #[test]
    fn more_chunks_reduce_interference_on_gc_equal() {
        // On a GC-equal scenario the surviving interference shrinks with
        // granularity: k=16 beats k=2.
        let e = exec();
        let sc = resolve_tag("cb5_13G", CollectiveKind::AllGather).unwrap();
        let (t2, _, _) = simulate_chunked(&e, &sc, false, 2).unwrap();
        let (t16, _, _) = simulate_chunked(&e, &sc, false, 16).unwrap();
        assert!(t16 < t2, "k=16 ({t16}) should beat k=2 ({t2}) on GC-equal");
    }

    #[test]
    fn cu_backend_pipeline_runs_and_holds_cus() {
        let e = exec();
        let sc = resolve_tag("cb5_13G", CollectiveKind::AllToAll).unwrap();
        let (total, g, c) = simulate_chunked(&e, &sc, true, 8).unwrap();
        assert!(total > 0.0 && g > 0.0 && c > 0.0);
        // All-reduce on the DMA pipeline is a typed error.
        let ar = resolve_tag("cb5_13G", CollectiveKind::AllReduce).unwrap();
        assert!(matches!(
            simulate_chunked(&e, &ar, false, 8),
            Err(Error::NotDmaOffloadable(_))
        ));
        // ... but fine on the CU pipeline.
        assert!(simulate_chunked(&e, &ar, true, 8).is_ok());
    }
}
