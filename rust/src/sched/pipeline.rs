//! Fine-grain chunked C3 pipelining (the follow-up direction of
//! arXiv 2512.10236 "Design Space Exploration of DMA based Finer-Grain
//! Compute Communication Overlap", priced against DMA-Latte's
//! per-packet launch costs).
//!
//! The chunked pipeline splits the GEMM into `k` tiled sub-kernels
//! ([`crate::kernels::GemmKernel::split_m`]) and the collective into
//! `k` chunk transfers, and issues collective chunk `i` at GEMM chunk
//! `i`'s completion — so it overlaps GEMM chunk `i+1` and rides the
//! GEMM's inter-chunk HBM gaps instead of colliding with its
//! panel-streaming bursts. Granularity buys interference relief (the
//! surviving penalty is `MachineConfig::chunk_align(k)` of the
//! whole-kernel value) and costs launches: every GEMM chunk pays
//! `kernel_launch_s` plus wave quantization; every DMA chunk is a fresh
//! `CommandPacket` batch serialized on the CPU enqueue thread (so small
//! chunks go *latency-bound* exactly as DMA-Latte reports); CU-backend
//! chunks pay `coll_launch_s` each.
//!
//! The hand-built pipeline simulator that used to live here was folded
//! into the workload-graph engine: `simulate_chunked` now builds the
//! 2k-node chunk graph ([`super::graph::chunked`]) and runs it on
//! [`super::graph::execute`]. `chunks == 1` is still defined as the
//! whole-kernel strategy itself (the executor delegates to `c3_sp` /
//! `conccl` exactly), which keeps the swept/auto chunk count never
//! worse than the unchunked strategy by construction.

use crate::error::Error;
use crate::workload::ResolvedScenario;

use super::executor::C3Executor;

/// Split a collective payload into `k` near-equal chunk sizes that sum
/// exactly to `total`.
pub fn chunk_sizes(total: u64, k: u32) -> Vec<u64> {
    let k = k.max(1) as u64;
    (0..k)
        .map(|i| total * (i + 1) / k - total * i / k)
        .collect()
}

/// Simulate the chunked pipeline for one scenario at `k >= 2` chunks.
/// `cu_backend` selects the CU-collective chunks (`c3_chunked`) vs the
/// DMA chunk batches (`conccl_chunked`). Returns
/// `(total, gemm_finish, comm_finish)` like the whole-kernel timeline.
pub(crate) fn simulate_chunked(
    exec: &C3Executor,
    sc: &ResolvedScenario,
    cu_backend: bool,
    k: u32,
) -> Result<(f64, f64, f64), Error> {
    let g = super::graph::chunked(&exec.m, &exec.topo, sc, cu_backend, k)?;
    let run = super::graph::execute(&exec.m, &exec.topo, &g)?;
    Ok((run.total, run.gemm_finish, run.comm_finish))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::{CollectiveKind, CollectiveSpec};
    use crate::kernels::CollectiveKernel;
    use crate::sched::Strategy;
    use crate::util::units::MIB;
    use crate::workload::scenarios::resolve_tag;

    fn exec() -> C3Executor {
        C3Executor::new(MachineConfig::mi300x())
    }

    #[test]
    fn chunk_sizes_sum_exactly() {
        for (total, k) in [(896 * MIB, 8u32), (7, 3), (1, 1), (13 * 1024 * MIB, 16)] {
            let v = chunk_sizes(total, k);
            assert_eq!(v.len(), k as usize);
            assert_eq!(v.iter().sum::<u64>(), total);
            let (lo, hi) = (v.iter().min().unwrap(), v.iter().max().unwrap());
            assert!(hi - lo <= 1, "uneven split {v:?}");
        }
    }

    #[test]
    fn pipeline_timeline_is_well_formed() {
        let e = exec();
        let sc = resolve_tag("mb2_26.5G", CollectiveKind::AllGather).unwrap();
        let (total, g, c) = simulate_chunked(&e, &sc, false, 8).unwrap();
        assert!(total > 0.0 && g > 0.0 && c > 0.0);
        assert!((total - g.max(c)).abs() < 1e-15);
        // The collective is gated on the first GEMM chunk: it cannot
        // finish before that chunk's pure-compute time.
        let first = sc.gemm.split_m(&e.m, 8)[0].t_comp(&e.m, e.m.cus_total());
        assert!(c > first, "comm finished before the first GEMM chunk: {c} vs {first}");
        // And the whole thing can't beat the ideal lower bound.
        let b = e.baselines(&sc);
        assert!(total >= b.t_gemm_iso.max(b.t_comm_iso) * 0.999);
    }

    #[test]
    fn latency_bound_chunks_collapse_like_dma_latte() {
        // A small payload (4 MiB) chunked 16 ways pays 16 CPU enqueue
        // batches; the pipeline must be clearly worse than whole-kernel
        // ConCCL there (the DMA-Latte result the auto-tuner prices).
        let e = exec();
        let mut sc = resolve_tag("cb1_896M", CollectiveKind::AllGather).unwrap();
        sc.comm = CollectiveKernel::new(CollectiveSpec::new(CollectiveKind::AllGather, 4 * MIB));
        sc.scenario.comm = sc.comm.spec;
        let whole = e.run(&sc, Strategy::Conccl);
        let (chunk_total, _, chunk_comm) = simulate_chunked(&e, &sc, false, 16).unwrap();
        // The comm pipeline trails the GEMM (issue gated per chunk), so
        // its finish moves past the whole-kernel collective's.
        assert!(
            chunk_comm > whole.comm_finish,
            "chunked comm {chunk_comm} should trail whole-kernel {}",
            whole.comm_finish
        );
        assert!(chunk_total + 1e-12 >= whole.total);
    }

    #[test]
    fn more_chunks_reduce_interference_on_gc_equal() {
        // On a GC-equal scenario the surviving interference shrinks with
        // granularity: k=16 beats k=2.
        let e = exec();
        let sc = resolve_tag("cb5_13G", CollectiveKind::AllGather).unwrap();
        let (t2, _, _) = simulate_chunked(&e, &sc, false, 2).unwrap();
        let (t16, _, _) = simulate_chunked(&e, &sc, false, 16).unwrap();
        assert!(t16 < t2, "k=16 ({t16}) should beat k=2 ({t2}) on GC-equal");
    }

    #[test]
    fn cu_backend_pipeline_runs_and_holds_cus() {
        let e = exec();
        let sc = resolve_tag("cb5_13G", CollectiveKind::AllToAll).unwrap();
        let (total, g, c) = simulate_chunked(&e, &sc, true, 8).unwrap();
        assert!(total > 0.0 && g > 0.0 && c > 0.0);
        // All-reduce on the DMA pipeline is a typed error.
        let ar = resolve_tag("cb5_13G", CollectiveKind::AllReduce).unwrap();
        assert!(matches!(
            simulate_chunked(&e, &ar, false, 8),
            Err(Error::NotDmaOffloadable(_))
        ));
        // ... but fine on the CU pipeline.
        assert!(simulate_chunked(&e, &ar, true, 8).is_ok());
    }
}
