//! The C3 scheduler: strategies (§IV-C, §V, §VI), the executor that
//! produces concurrent timelines over the fluid simulator, and the
//! fine-grain chunked pipeline (arXiv 2512.10236 / DMA-Latte).

pub mod executor;
pub mod pipeline;
pub mod strategy;

pub use executor::{Baselines, C3Executor, C3Run};
pub use pipeline::chunk_sizes;
pub use strategy::{Strategy, StrategyKind};
