//! The C3 scheduler: strategies (§IV-C, §V, §VI), the workload-graph
//! engine that produces concurrent timelines over the fluid simulator,
//! and the executor / fine-grain chunked pipeline builders on top of it
//! (arXiv 2512.10236 / DMA-Latte).

pub mod executor;
pub mod graph;
pub mod pipeline;
pub mod strategy;

pub use executor::{Baselines, C3Executor, C3Run};
pub use graph::{Graph, GraphRun, NodeSpec, Ready, Work};
pub use pipeline::chunk_sizes;
pub use strategy::{Strategy, StrategyKind};
