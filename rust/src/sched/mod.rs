//! The C3 scheduler: strategies (§IV-C, §V, §VI) and the executor that
//! produces concurrent timelines over the fluid simulator.

pub mod executor;
pub mod strategy;

pub use executor::{Baselines, C3Executor, C3Run};
pub use strategy::{Strategy, StrategyKind};
