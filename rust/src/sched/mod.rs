//! The C3 scheduler: strategies (§IV-C, §V, §VI), the workload-graph
//! engine that produces concurrent timelines over the fluid simulator
//! (including the fine-grain chunked pipeline builders, arXiv
//! 2512.10236 / DMA-Latte, and prefix-memoized candidate
//! re-simulation), the executor on top of it, and the
//! cost-model-driven per-node planner ([`policy`]) behind
//! `E2eFamily::Auto`.

pub mod executor;
pub mod graph;
pub mod policy;
pub mod strategy;

pub use executor::{Baselines, C3Executor, C3Run};
pub use graph::{chunk_sizes, Graph, GraphRun, NodeSpec, PrefixTimeline, Ready, Work};
pub use policy::{
    serve_candidates, PlanBackend, PlanNode, PlanSummary, Planner, ServeClassPlan, StagePlan,
};
pub use strategy::{Strategy, StrategyKind};
