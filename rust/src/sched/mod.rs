//! The C3 scheduler: strategies (§IV-C, §V, §VI), the workload-graph
//! engine that produces concurrent timelines over the fluid simulator,
//! the executor / fine-grain chunked pipeline builders on top of it
//! (arXiv 2512.10236 / DMA-Latte), and the cost-model-driven per-node
//! planner ([`policy`]) behind `E2eFamily::Auto`.

pub mod executor;
pub mod graph;
pub mod pipeline;
pub mod policy;
pub mod strategy;

pub use executor::{Baselines, C3Executor, C3Run};
pub use graph::{Graph, GraphRun, NodeSpec, Ready, Work};
pub use pipeline::chunk_sizes;
pub use policy::{PlanBackend, PlanNode, PlanSummary, Planner, StagePlan};
pub use strategy::{Strategy, StrategyKind};
