//! The C3 executor: composes one GEMM and one collective under a
//! [`Strategy`] inside the fluid simulator and reports the paper's
//! metrics (speedup over serial, %-of-ideal).
//!
//! Interference enters through four mechanisms, each tied to a paper
//! observation:
//!
//! 1. **CU splitting** — each kernel's rate cap comes from its analytic
//!    `t(cu)` at its *current* CU grant (Fig 5).
//! 2. **HBM/LLC bandwidth sharing** — both kernels demand bytes of the
//!    shared `hbm` fluid resource; max-min sharing slows whichever
//!    kernel over-subscribes it (§IV-B2).
//! 3. **L1/L2 pollution** — a CU-resident collective thrashes the XCD
//!    caches, shaving the GEMM's compute rate (`gemm_l2_pollution_*`);
//!    eliminated under ConCCL because SDMA engines sit behind L2
//!    (§VI-A).
//! 4. **Dispatch starvation** — under `c3_base` the second-launched
//!    collective waits out a dispatch backlog
//!    (`base_dispatch_backlog · t_gemm`) and then runs on leaked CUs
//!    only (`base_leak_cus`) until the GEMM drains (§V-A's motivation).

use crate::config::machine::MachineConfig;
use crate::error::Error;
use crate::fabric::Topology;
use crate::workload::taxonomy::pct_of_ideal;
use crate::workload::ResolvedScenario;

use super::strategy::Strategy;

/// Isolated-execution baselines of one scenario: the serial and ideal
/// denominators every strategy shares (§IV-B3). The sweep engine
/// computes these once per scenario and reuses them across all
/// strategies instead of re-deriving them per run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Baselines {
    /// Isolated GEMM time at full CUs, seconds.
    pub t_gemm_iso: f64,
    /// Isolated CU-collective time at its full CU need, seconds.
    pub t_comm_iso: f64,
}

impl Baselines {
    /// Serial baseline (isolated GEMM + isolated collective).
    pub fn serial(self) -> f64 {
        self.t_gemm_iso + self.t_comm_iso
    }

    /// Ideal speedup bound: the shorter kernel fully hidden.
    pub fn ideal(self) -> f64 {
        self.serial() / self.t_gemm_iso.max(self.t_comm_iso)
    }
}

/// Result of executing one scenario under one strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct C3Run {
    pub strategy: Strategy,
    /// Concurrent makespan, seconds.
    pub total: f64,
    /// GEMM completion time.
    pub gemm_finish: f64,
    /// Collective completion time (incl. DMA sync for ConCCL).
    pub comm_finish: f64,
    /// Serial baseline (isolated GEMM + isolated RCCL collective).
    pub serial: f64,
    /// Ideal speedup bound (§IV-B3).
    pub ideal: f64,
    /// Attained speedup over serial.
    pub speedup: f64,
    /// Percent of ideal speedup attained.
    pub pct_ideal: f64,
}

/// Executes C3 scenarios against a machine model on an interconnect
/// topology (the paper's single fully-connected node by default).
#[derive(Debug, Clone)]
pub struct C3Executor {
    pub m: MachineConfig,
    pub topo: Topology,
}

impl C3Executor {
    /// Single fully-connected node (the paper's setting).
    pub fn new(m: MachineConfig) -> Self {
        let topo = Topology::fully_connected(m.num_gpus);
        C3Executor { m, topo }
    }

    /// Executor on an arbitrary topology; `topo.gpus_per_node()` must
    /// match the machine's GPU count.
    pub fn with_topology(m: MachineConfig, topo: Topology) -> Self {
        assert_eq!(
            topo.gpus_per_node(),
            m.num_gpus,
            "topology gpus_per_node must match machine.num_gpus"
        );
        C3Executor { m, topo }
    }

    /// Isolated GEMM time at full CUs.
    pub fn t_gemm_iso(&self, sc: &ResolvedScenario) -> f64 {
        sc.gemm.time_isolated(&self.m, self.m.cus_total())
    }

    /// Isolated CU-collective time at its full CU need (the serial and
    /// ideal baselines always use the CU collective — the paper's
    /// baseline stack is rocBLAS + RCCL). On a multi-node topology this
    /// is the hierarchical collective with the NIC exchange.
    pub fn t_comm_iso(&self, sc: &ResolvedScenario) -> f64 {
        sc.comm.time_isolated_full_on(&self.m, &self.topo)
    }

    /// Compute the scenario's isolated-execution baselines once.
    pub fn baselines(&self, sc: &ResolvedScenario) -> Baselines {
        Baselines {
            t_gemm_iso: self.t_gemm_iso(sc),
            t_comm_iso: self.t_comm_iso(sc),
        }
    }

    /// Run one scenario under one strategy, surfacing simulation stalls
    /// as typed errors (the sweep engine's entry point).
    pub fn try_run(&self, sc: &ResolvedScenario, strategy: Strategy) -> Result<C3Run, Error> {
        self.try_run_with_baselines(sc, strategy, self.baselines(sc))
    }

    /// [`C3Executor::try_run`] with precomputed baselines, so the
    /// serial/ideal denominators are derived once per scenario rather
    /// than once per strategy.
    pub fn try_run_with_baselines(
        &self,
        sc: &ResolvedScenario,
        strategy: Strategy,
        b: Baselines,
    ) -> Result<C3Run, Error> {
        let serial = b.serial();
        let ideal = b.ideal();
        let (total, gemm_finish, comm_finish) = match strategy {
            Strategy::Serial => (serial, b.t_gemm_iso, serial),
            // Chunked pipelines: `chunks == 0` means auto — sweep the
            // machine's candidates (the §V-B rp protocol applied to
            // granularity) and keep the best run.
            Strategy::C3Chunked { chunks: 0 } | Strategy::ConcclChunked { chunks: 0 } => {
                return self
                    .try_run_chunk_sweep_with(sc, !strategy.comm_on_cus(), b)
                    .map(|(run, _)| run);
            }
            Strategy::C3Chunked { chunks } | Strategy::ConcclChunked { chunks } => {
                let k = self.clamp_chunks(sc, chunks);
                if k <= 1 {
                    // A single chunk is the whole-kernel strategy; keep
                    // the chunked label on the returned run.
                    let base = if strategy.comm_on_cus() {
                        Strategy::C3Sp
                    } else {
                        Strategy::Conccl
                    };
                    self.simulate(sc, base, b)?
                } else {
                    super::graph::simulate_chunked(self, sc, strategy.comm_on_cus(), k)?
                }
            }
            _ => self.simulate(sc, strategy, b)?,
        };
        let speedup = serial / total;
        Ok(C3Run {
            strategy,
            total,
            gemm_finish,
            comm_finish,
            serial,
            ideal,
            speedup,
            pct_ideal: pct_of_ideal(speedup, ideal),
        })
    }

    /// Run one scenario under one strategy. Panicking convenience
    /// wrapper over [`C3Executor::try_run`] — infallible for the
    /// Table II scenarios on a valid machine; batch callers (the sweep
    /// engine) use `try_run` so one bad job cannot abort the process.
    pub fn run(&self, sc: &ResolvedScenario, strategy: Strategy) -> C3Run {
        self.try_run(sc, strategy).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Sweep power-of-two CU reservations for `c3_rp` and return the
    /// best run plus the winning reservation (§V-B: "we sweep all
    /// possible powers-of-two CU allocations ... and plot the best").
    pub fn try_run_rp_sweep_with(
        &self,
        sc: &ResolvedScenario,
        b: Baselines,
    ) -> Result<(C3Run, u32), Error> {
        let mut best: Option<(C3Run, u32)> = None;
        for k in self.m.rp_candidates() {
            let run = self.try_run_with_baselines(sc, Strategy::C3Rp { comm_cus: k }, b)?;
            if best.map_or(true, |(prev, _)| run.total < prev.total) {
                best = Some((run, k));
            }
        }
        best.ok_or_else(|| Error::Config("machine has no rp candidates".into()))
    }

    /// Panicking convenience wrapper over
    /// [`C3Executor::try_run_rp_sweep_with`].
    pub fn run_rp_sweep(&self, sc: &ResolvedScenario) -> (C3Run, u32) {
        self.try_run_rp_sweep_with(sc, self.baselines(sc))
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Run `c3_rp` at a specific reservation (heuristic evaluation).
    pub fn run_rp_at(&self, sc: &ResolvedScenario, k: u32) -> C3Run {
        self.run(sc, Strategy::C3Rp { comm_cus: k })
    }

    /// Clamp a requested chunk count to what the scenario supports
    /// ([`ResolvedScenario::chunk_cap`]).
    pub fn clamp_chunks(&self, sc: &ResolvedScenario, chunks: u32) -> u32 {
        chunks.clamp(1, sc.chunk_cap(&self.m))
    }

    /// Sweep the machine's chunk-count candidates for a chunked pipeline
    /// strategy and return the best run plus the winning (clamped)
    /// chunk count. `k = 1` — the whole-kernel strategy — is always a
    /// candidate, so the swept result is never worse than unchunked.
    pub fn try_run_chunk_sweep_with(
        &self,
        sc: &ResolvedScenario,
        dma_backend: bool,
        b: Baselines,
    ) -> Result<(C3Run, u32), Error> {
        let mut best: Option<(C3Run, u32)> = None;
        let mut tried: Vec<u32> = Vec::new();
        for k in self.m.chunk_candidates() {
            let k_eff = self.clamp_chunks(sc, k);
            if tried.contains(&k_eff) {
                continue; // clamped duplicate (tiny GEMM / payload)
            }
            tried.push(k_eff);
            let strategy = if dma_backend {
                Strategy::ConcclChunked { chunks: k_eff }
            } else {
                Strategy::C3Chunked { chunks: k_eff }
            };
            let run = self.try_run_with_baselines(sc, strategy, b)?;
            if best.as_ref().map_or(true, |(prev, _)| run.total < prev.total) {
                best = Some((run, k_eff));
            }
        }
        best.ok_or_else(|| Error::Config("machine has no chunk candidates".into()))
    }

    /// Panicking convenience wrapper over
    /// [`C3Executor::try_run_chunk_sweep_with`].
    pub fn run_chunk_sweep(&self, sc: &ResolvedScenario, dma_backend: bool) -> (C3Run, u32) {
        self.try_run_chunk_sweep_with(sc, dma_backend, self.baselines(sc))
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Best CU-collective variant (`c3_best` in Fig 10): min total over
    /// base / sp / swept rp / sp_rp.
    pub fn try_run_c3_best_with(
        &self,
        sc: &ResolvedScenario,
        b: Baselines,
    ) -> Result<C3Run, Error> {
        let mut best = self.try_run_with_baselines(sc, Strategy::C3Base, b)?;
        for cand in [
            self.try_run_with_baselines(sc, Strategy::C3Sp, b)?,
            self.try_run_rp_sweep_with(sc, b)?.0,
            self.try_run_with_baselines(
                sc,
                Strategy::C3SpRp {
                    comm_cus: sc.comm.cu_need(&self.m),
                },
                b,
            )?,
        ] {
            if cand.total < best.total {
                best = cand;
            }
        }
        Ok(best)
    }

    /// Panicking convenience wrapper over
    /// [`C3Executor::try_run_c3_best_with`].
    pub fn run_c3_best(&self, sc: &ResolvedScenario) -> C3Run {
        self.try_run_c3_best_with(sc, self.baselines(sc))
            .unwrap_or_else(|e| panic!("{e}"))
    }

    // ---- the concurrent timeline ----

    /// Build the single-pair workload graph and run it on the graph
    /// engine. The old hand-built pair timeline lived here; it is now
    /// `sched::graph::single_pair` + `sched::graph::execute`, and
    /// `rust/tests/graph_equiv.rs` pins the numbers against a frozen
    /// copy of the pre-refactor implementation.
    fn simulate(
        &self,
        sc: &ResolvedScenario,
        strategy: Strategy,
        b: Baselines,
    ) -> Result<(f64, f64, f64), Error> {
        let g = super::graph::single_pair(&self.m, &self.topo, sc, strategy, b)?;
        let run = super::graph::execute(&self.m, &self.topo, &g)?;
        Ok((run.total, run.gemm_finish, run.comm_finish))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::CollectiveKind;
    use crate::workload::scenarios::{resolve, TABLE2};

    fn exec() -> C3Executor {
        C3Executor::new(MachineConfig::mi300x())
    }

    fn scenario(tag: &str, kind: CollectiveKind) -> ResolvedScenario {
        crate::workload::scenarios::resolve_tag(tag, kind).unwrap()
    }

    #[test]
    fn try_run_matches_run_and_reuses_baselines() {
        let e = exec();
        let sc = scenario("mb1_896M", CollectiveKind::AllGather);
        let b = e.baselines(&sc);
        assert!((b.serial() - (e.t_gemm_iso(&sc) + e.t_comm_iso(&sc))).abs() < 1e-15);
        for strat in [Strategy::Serial, Strategy::C3Sp, Strategy::Conccl] {
            let via_try = e.try_run_with_baselines(&sc, strat, b).unwrap();
            assert_eq!(via_try, e.run(&sc, strat));
        }
    }

    #[test]
    fn conccl_on_allreduce_is_typed_error_not_panic() {
        let e = exec();
        let sc = scenario("mb1_896M", CollectiveKind::AllReduce);
        let err = e.try_run(&sc, Strategy::Conccl).unwrap_err();
        assert!(matches!(err, Error::NotDmaOffloadable(_)), "{err}");
        // CU strategies still handle all-reduce fine.
        assert!(e.try_run(&sc, Strategy::C3Sp).is_ok());
    }

    #[test]
    fn multi_node_comm_becomes_the_bottleneck() {
        // Same scenario on 1 vs 2 nodes: the hierarchical collective
        // over the NIC dominates, and the conccl advantage over c3_base
        // shrinks as NIC bandwidth drops (both become comm-bound).
        let m = MachineConfig::mi300x();
        let sc = scenario("mb1_896M", CollectiveKind::AllGather);
        let e1 = C3Executor::new(m.clone());
        let e2 = C3Executor::with_topology(m.clone(), m.topology(2));
        assert!(e2.t_comm_iso(&sc) > e1.t_comm_iso(&sc));
        assert_eq!(e2.t_gemm_iso(&sc), e1.t_gemm_iso(&sc));

        let ratio = |e: &C3Executor| {
            let base = e.run(&sc, Strategy::C3Base);
            let con = e.run(&sc, Strategy::Conccl);
            base.total / con.total
        };
        let r_fast = ratio(&e2);
        let mut slow = m.clone();
        slow.nic_bw = m.nic_bw / 20.0;
        let e2_slow = C3Executor::with_topology(slow.clone(), slow.topology(2));
        let r_slow = ratio(&e2_slow);
        assert!(
            r_slow < r_fast,
            "conccl advantage should shrink with NIC bw: {r_slow:.3} vs {r_fast:.3}"
        );
        // Deep in the NIC-bound regime both strategies converge on the
        // collective's time.
        assert!(r_slow < 1.1, "r_slow {r_slow:.3}");
    }

    #[test]
    fn multi_node_speedups_stay_sane() {
        let m = MachineConfig::mi300x();
        let e = C3Executor::with_topology(m.clone(), m.topology(2));
        for kind in CollectiveKind::studied() {
            let sc = resolve(&TABLE2[0], kind);
            for strat in [Strategy::C3Base, Strategy::C3Sp, Strategy::Conccl] {
                let r = e.run(&sc, strat);
                assert!(
                    r.speedup >= 0.85 && r.speedup <= r.ideal * 1.02 + 1e-9,
                    "{} {}: speedup {:.3} ideal {:.3}",
                    sc.tag(),
                    strat.name(),
                    r.speedup,
                    r.ideal
                );
            }
        }
    }

    #[test]
    fn serial_is_identity() {
        let e = exec();
        let sc = scenario("mb1_896M", CollectiveKind::AllGather);
        let r = e.run(&sc, Strategy::Serial);
        assert!((r.speedup - 1.0).abs() < 1e-12);
        assert!((r.total - r.serial).abs() < 1e-12);
        assert!(r.pct_ideal.abs() < 1e-9);
    }

    #[test]
    fn all_strategies_bounded_by_serial_and_ideal() {
        let e = exec();
        for kind in CollectiveKind::studied() {
            for row in &TABLE2 {
                let sc = resolve(row, kind);
                // A *fixed* rp reservation can legitimately slow down
                // (e.g. 32 CUs for an A2A that needs 64 — prior work [5]
                // observed C3 slowdowns); the swept rp must not.
                let (rp_best, _) = e.run_rp_sweep(&sc);
                assert!(
                    rp_best.speedup >= 0.95 && rp_best.speedup <= rp_best.ideal * 1.02,
                    "{}: swept rp speedup {:.3}",
                    sc.tag(),
                    rp_best.speedup
                );
                for strat in [
                    Strategy::C3Base,
                    Strategy::C3Sp,
                    Strategy::Conccl,
                    Strategy::ConcclRp { cus_removed: 8 },
                ] {
                    let r = e.run(&sc, strat);
                    assert!(
                        r.speedup >= 0.90,
                        "{} {}: pathological slowdown {:.3}",
                        sc.tag(),
                        strat.name(),
                        r.speedup
                    );
                    assert!(
                        r.speedup <= r.ideal * 1.02 + 1e-9,
                        "{} {}: speedup {:.3} exceeds ideal {:.3}",
                        sc.tag(),
                        strat.name(),
                        r.speedup,
                        r.ideal
                    );
                }
            }
        }
    }

    #[test]
    fn sp_beats_base_and_conccl_beats_sp_on_average() {
        // The paper's headline ordering, as suite averages.
        let e = exec();
        let mut sums = [0.0f64; 3]; // base, sp, conccl (pct of ideal)
        let mut n = 0;
        for kind in CollectiveKind::studied() {
            for row in &TABLE2 {
                let sc = resolve(row, kind);
                sums[0] += e.run(&sc, Strategy::C3Base).pct_ideal;
                sums[1] += e.run(&sc, Strategy::C3Sp).pct_ideal;
                sums[2] += e.run(&sc, Strategy::Conccl).pct_ideal;
                n += 1;
            }
        }
        let avg: Vec<f64> = sums.iter().map(|s| s / n as f64).collect();
        assert!(
            avg[0] + 8.0 < avg[1],
            "sp ({:.0}%) should clearly beat base ({:.0}%)",
            avg[1],
            avg[0]
        );
        assert!(
            avg[1] + 8.0 < avg[2],
            "conccl ({:.0}%) should clearly beat sp ({:.0}%)",
            avg[2],
            avg[1]
        );
    }

    #[test]
    fn conccl_rp_helps_memory_bound_gemms() {
        let e = exec();
        let sc = scenario("mb1_896M", CollectiveKind::AllGather);
        let plain = e.run(&sc, Strategy::Conccl);
        let rp = e.run(&sc, Strategy::ConcclRp { cus_removed: 8 });
        assert!(
            rp.total <= plain.total,
            "rp {:.4}ms vs plain {:.4}ms",
            rp.total * 1e3,
            plain.total * 1e3
        );
        // ... and is a no-op for compute-bound GEMMs.
        let sc_cb = scenario("cb3_512M", CollectiveKind::AllGather);
        let p = e.run(&sc_cb, Strategy::Conccl);
        let r = e.run(&sc_cb, Strategy::ConcclRp { cus_removed: 8 });
        assert!((p.total - r.total).abs() < 1e-12);
    }

    #[test]
    fn rp_sweep_returns_legal_best() {
        let e = exec();
        let sc = scenario("cb1_896M", CollectiveKind::AllGather);
        let (best, k) = e.run_rp_sweep(&sc);
        assert!(e.m.rp_candidates().contains(&k));
        // Sweep best is no worse than any single candidate.
        for cand in e.m.rp_candidates() {
            let r = e.run(&sc, Strategy::C3Rp { comm_cus: cand });
            assert!(best.total <= r.total + 1e-12);
        }
    }

    #[test]
    fn c3_best_is_min_of_variants() {
        let e = exec();
        let sc = scenario("cb2_3.25G", CollectiveKind::AllToAll);
        let best = e.run_c3_best(&sc);
        for s in [Strategy::C3Base, Strategy::C3Sp] {
            assert!(best.total <= e.run(&sc, s).total + 1e-12);
        }
    }

    #[test]
    fn base_starves_a2a_harder_than_ag() {
        // Fig 8: all-to-all attains 0-13% of ideal under c3_base,
        // all-gather 24-46% — the 64-CU need vs 8 leaked CUs bites.
        let e = exec();
        let mut ag_sum = 0.0;
        let mut a2a_sum = 0.0;
        for row in &TABLE2 {
            ag_sum += e
                .run(&resolve(row, CollectiveKind::AllGather), Strategy::C3Base)
                .pct_ideal;
            a2a_sum += e
                .run(&resolve(row, CollectiveKind::AllToAll), Strategy::C3Base)
                .pct_ideal;
        }
        assert!(
            a2a_sum < ag_sum,
            "a2a base ({a2a_sum:.0}) should trail ag base ({ag_sum:.0})"
        );
    }

    #[test]
    fn chunked_with_one_chunk_equals_whole_kernel() {
        // `chunks = 1` is *defined* as the whole-kernel strategy: the
        // pipeline degenerates exactly, to the last bit.
        let e = exec();
        for (tag, kind) in [
            ("mb1_896M", CollectiveKind::AllGather),
            ("cb5_13G", CollectiveKind::AllToAll),
        ] {
            let sc = scenario(tag, kind);
            let conccl = e.run(&sc, Strategy::Conccl);
            let chunked1 = e.run(&sc, Strategy::ConcclChunked { chunks: 1 });
            assert_eq!(chunked1.total, conccl.total, "{tag}");
            assert_eq!(chunked1.comm_finish, conccl.comm_finish, "{tag}");
            let sp = e.run(&sc, Strategy::C3Sp);
            let cu1 = e.run(&sc, Strategy::C3Chunked { chunks: 1 });
            assert_eq!(cu1.total, sp.total, "{tag}");
        }
    }

    #[test]
    fn chunked_auto_never_loses_to_unchunked() {
        // The swept chunk count includes k = 1 (the whole-kernel
        // strategy), so auto-chunked is never worse — on any scenario.
        let e = exec();
        for kind in CollectiveKind::studied() {
            for row in &TABLE2 {
                let sc = resolve(row, kind);
                let conccl = e.run(&sc, Strategy::Conccl);
                let (chunked, k) = e.run_chunk_sweep(&sc, true);
                assert!(
                    chunked.total <= conccl.total + 1e-12,
                    "{} {}: chunked {:.6}ms @ k={k} vs conccl {:.6}ms",
                    sc.tag(),
                    kind.name(),
                    chunked.total * 1e3,
                    conccl.total * 1e3
                );
                assert!(e.m.chunk_candidates().contains(&k) || k == e.clamp_chunks(&sc, k));
            }
        }
    }

    #[test]
    fn chunked_conccl_beats_whole_kernel_on_gc_equal() {
        // The acceptance criterion and the headline of the fine-grain
        // DSE follow-up: on every GC-equal Table II scenario — where
        // neither kernel hides the other and the whole-kernel overlap
        // pays the §VII-A1 residual for its entire span — the chunked
        // pipeline closes part of the remaining gap to ideal.
        let e = exec();
        for kind in CollectiveKind::studied() {
            for row in TABLE2.iter().filter(|r| {
                r.paper_type == crate::workload::taxonomy::C3Type::GcEqual
            }) {
                let sc = resolve(row, kind);
                let conccl = e.run(&sc, Strategy::Conccl);
                let (chunked, k) = e.run_chunk_sweep(&sc, true);
                assert!(
                    chunked.speedup >= conccl.speedup,
                    "{} {}: chunked {:.3}x @ k={k} vs conccl {:.3}x",
                    sc.tag(),
                    kind.name(),
                    chunked.speedup,
                    conccl.speedup
                );
                // Strictly better, not just the k=1 fallback: the tuned
                // pipeline must pick real chunking here and win by a
                // visible margin.
                assert!(k >= 2, "{} {}: auto picked k={k}", sc.tag(), kind.name());
                assert!(
                    chunked.speedup > conccl.speedup * 1.02,
                    "{} {}: no real gain ({:.3} vs {:.3})",
                    sc.tag(),
                    kind.name(),
                    chunked.speedup,
                    conccl.speedup
                );
            }
        }
    }

    #[test]
    fn chunked_strategies_stay_bounded() {
        let e = exec();
        for kind in CollectiveKind::studied() {
            for row in &TABLE2 {
                let sc = resolve(row, kind);
                for strat in [
                    Strategy::ConcclChunked { chunks: 0 },
                    Strategy::C3Chunked { chunks: 0 },
                ] {
                    let r = e.run(&sc, strat);
                    assert!(
                        r.speedup >= 0.90 && r.speedup <= r.ideal * 1.02 + 1e-9,
                        "{} {}: speedup {:.3} ideal {:.3}",
                        sc.tag(),
                        strat.name(),
                        r.speedup,
                        r.ideal
                    );
                }
            }
        }
    }

    #[test]
    fn prop_conccl_total_consistent() {
        use crate::util::prop::forall;
        let e = exec();
        forall("conccl C3 never loses to serial by >10%", 30, |rng| {
            (rng.usize_below(TABLE2.len()) as u64, rng.bool(0.5) as u64)
        })
        .check(|&(i, k)| {
            let kind = if k == 0 {
                CollectiveKind::AllGather
            } else {
                CollectiveKind::AllToAll
            };
            let sc = resolve(&TABLE2[i as usize], kind);
            let r = e.run(&sc, Strategy::Conccl);
            if r.speedup < 0.9 {
                return Err(format!("{}: speedup {:.3}", sc.tag(), r.speedup));
            }
            if r.comm_finish <= 0.0 || r.gemm_finish <= 0.0 {
                return Err("degenerate finish times".into());
            }
            Ok(())
        });
    }
}
