//! The workload-graph engine: one continuous fluid-simulator timeline
//! for an arbitrary DAG of compute (GEMM) and communication (collective)
//! task nodes.
//!
//! This unifies what used to be three hand-built timeline constructors —
//! the whole-kernel pair executor, the chunked pipeline, and the
//! sum-of-pairs trace replay — into a single engine:
//!
//! * **Nodes** carry their kernel models plus per-node strategy
//!   annotations (CU policy, collective backend, penalty style) that the
//!   engine applies at every event boundary, exactly as the legacy
//!   executors did.
//! * **Edges** are issue dependencies (`issue_deps`, with a launch lag
//!   or a serialized CPU issue queue — the DMA enqueue thread) and
//!   serialization dependencies (`serial_deps`, e.g. the chunk chain of
//!   the fine-grain pipeline).
//! * **Resources**: all nodes share achievable HBM bandwidth; DMA
//!   collectives additionally demand *SDMA engine occupancy*
//!   ([`crate::gpu::sdma::engine_demand`]) on a finite `sdma` fluid
//!   resource, so two concurrent DMA collectives on one GPU slow each
//!   other (a single collective is never engine-bound — its own rate cap
//!   binds first — which keeps single-pair graphs numerically identical
//!   to the pre-refactor executor; `rust/tests/graph_equiv.rs` pins
//!   that equivalence against a frozen reference implementation).
//!
//! [`single_pair`] and [`chunked`] are the graph builders the
//! [`super::C3Executor`] and `sched::pipeline` now delegate to; the
//! multi-layer FSDP/TP builders live in `workload::e2e`.

use crate::config::machine::{smoothmax, MachineConfig};
use crate::config::workload::CollectiveSpec;
use crate::conccl::DmaCollective;
use crate::error::Error;
use crate::fabric::Topology;
use crate::gpu::sdma::engine_demand;
use crate::kernels::{CollectiveKernel, GemmKernel};
use crate::sim::fluid::StallError;
use crate::sim::{Event, Sim, TaskSpec};
use crate::workload::ResolvedScenario;

use super::executor::Baselines;
use super::pipeline::chunk_sizes;
use super::strategy::Strategy;

/// Index of a node within a [`Graph`].
pub type NodeId = usize;

/// Absolute tolerance on "has this node's issue time been reached"
/// comparisons (matches the legacy pipeline's ready-time epsilon).
const ISSUE_EPS: f64 = 1e-18;

/// How a node's §VII-A1 interference penalties are combined from its
/// co-runners.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PenaltyStyle {
    /// Whole-kernel executor style: each co-running collective's
    /// contribution is scaled by its *current* traffic-rate scale (a
    /// starved collective crawling on leaked CUs barely pollutes).
    RateScaled,
    /// Chunked-pipeline style: whole-kernel penalty terms shrunk by the
    /// alignment survival factor `MachineConfig::chunk_align(k)`.
    Aligned(f64),
}

/// CU allocation policy of a compute node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CuPolicy {
    /// All CUs minus whatever active CU-collective nodes currently hold.
    Residual,
    /// A fixed grant for the whole run (an rp-style CU mask persists
    /// even after the collective completes).
    Fixed(u32),
}

/// A compute (GEMM) node.
#[derive(Debug, Clone)]
pub struct GemmWork {
    /// Kernel priced for compute time (a tiled sub-kernel when chunked).
    pub comp: GemmKernel,
    /// Parent kernel for memory-side pricing (LLC working set persists
    /// across chunk boundaries, so memory time/traffic are prorated
    /// from the whole kernel rather than re-derived per sub-shape).
    pub mem: GemmKernel,
    /// Memory proration fraction (1.0 for a whole kernel).
    pub frac: f64,
    /// HBM-bandwidth share this GEMM inflicts on co-running collectives.
    pub share: f64,
    pub cu_policy: CuPolicy,
    pub pen_style: PenaltyStyle,
}

/// Collective execution backend of a comm node.
#[derive(Debug, Clone, Copy)]
pub enum CommBackend {
    /// CU-resident (RCCL-like) kernel: CU grants per phase, plus the
    /// c3_base dispatch-backlog window.
    Cu {
        /// CUs held while dispatch-backlogged (c3_base leakage).
        backlog_cus: u32,
        /// CUs held while any compute node is unfinished.
        overlap_cus: u32,
        /// CUs held once all compute has drained.
        solo_cus: u32,
        /// Absolute sim time until which the dispatch backlog lasts
        /// (0 = no backlog).
        backlog_until: f64,
        /// Fixed wire time (the chunked pipeline prices chunks at the
        /// full CU need); `None` re-prices from the current CU grant.
        wire_fixed: Option<f64>,
    },
    /// SDMA engines: precomputed wire-phase duration plus the engine
    /// occupancy demanded from the shared `sdma` fluid resource. Like
    /// every fluid demand this is *per unit work* (engine-seconds are
    /// conserved), so a collective throttled by HBM interference also
    /// draws engines more slowly — engine contention is understated
    /// when heavy compute co-runs, a known limit of the fluid
    /// abstraction (see EXPERIMENTS.md).
    Dma { wire: f64, engines: f64 },
}

/// A communication (collective) node.
#[derive(Debug, Clone)]
pub struct CommWork {
    pub kernel: CollectiveKernel,
    pub backend: CommBackend,
    /// HBM bytes moved per unit work.
    pub hbm: f64,
    /// HBM-bandwidth share this collective inflicts on co-runners.
    pub share: f64,
    /// L1/L2 pollution inflicted on co-running GEMMs while CU-resident.
    pub pollution: f64,
    /// Bandwidth derate suffered while a GEMM co-runs (CU backend).
    pub co_penalty: f64,
    /// CPU-side completion sync appended to the reported finish
    /// (`dma_sync_s` for DMA batches; dependents wait for it).
    pub sync: f64,
    pub pen_style: PenaltyStyle,
}

/// What a node computes.
#[derive(Debug, Clone)]
pub enum Work {
    Gemm(GemmWork),
    Comm(CommWork),
}

/// When a node may begin making progress.
#[derive(Debug, Clone, Copy)]
pub enum Ready {
    /// Root node with an absolute arrival time (stream setup order).
    At(f64),
    /// Ready `lag` after the last issue dependency completes (kernel /
    /// collective launch latency).
    AfterDeps { lag: f64 },
    /// Issue goes through a serialized CPU queue (the DMA enqueue
    /// thread): `start = max(queue_free, deps_done)`, the queue is busy
    /// for `hold` (the per-packet enqueue batch), and the node is ready
    /// `post` after that (engine fetch).
    Queue { queue: usize, hold: f64, post: f64 },
}

/// One node of a workload graph.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub label: String,
    pub work: Work,
    /// Dependencies whose completion triggers issue (edges use the
    /// *reported* finish, i.e. including a DMA collective's CPU sync).
    pub issue_deps: Vec<NodeId>,
    /// Dependencies that must merely have finished before this node can
    /// progress (chain serialization; raw sim finish, no launch lag).
    pub serial_deps: Vec<NodeId>,
    pub ready: Ready,
}

/// A workload graph: a DAG of task nodes (edges point backward — every
/// dependency id is smaller than the dependent's id).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<NodeSpec>,
}

impl Graph {
    /// Append a node, returning its id.
    pub fn push(&mut self, spec: NodeSpec) -> NodeId {
        self.nodes.push(spec);
        self.nodes.len() - 1
    }
}

/// Result of executing a workload graph.
#[derive(Debug, Clone)]
pub struct GraphRun {
    /// Per-node issue (ready) times.
    pub issue: Vec<f64>,
    /// Per-node reported finish times (a DMA collective's includes its
    /// CPU sync).
    pub finish: Vec<f64>,
    /// End-to-end makespan (max reported finish).
    pub total: f64,
    /// Last compute completion.
    pub gemm_finish: f64,
    /// Last collective completion (incl. sync).
    pub comm_finish: f64,
    /// Communication time not hidden under any compute interval.
    pub exposed_comm: f64,
    /// Time covered by neither compute nor communication (launch gaps,
    /// dependency stalls).
    pub bubble: f64,
    /// Fraction of achievable HBM byte-capacity the run consumed.
    pub hbm_occupancy: f64,
    /// Fraction of SDMA engine-seconds the run consumed.
    pub sdma_occupancy: f64,
}

/// Per-iteration phase state of one collective node.
#[derive(Debug, Clone, Copy)]
struct CommPhase {
    moving: bool,
    is_cu: bool,
    holds: u32,
    scale: f64,
}

fn ready_time(ready: Ready, t_deps: f64, queue_free: &mut [f64]) -> f64 {
    match ready {
        Ready::At(t) => t,
        Ready::AfterDeps { lag } => t_deps + lag,
        Ready::Queue { queue, hold, post } => {
            let start = queue_free[queue].max(t_deps);
            queue_free[queue] = start + hold;
            queue_free[queue] + post
        }
    }
}

fn union_intervals(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.retain(|&(a, b)| b > a);
    iv.sort_by(|x, y| x.0.total_cmp(&y.0));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for (a, b) in iv {
        match out.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

fn measure(iv: &[(f64, f64)]) -> f64 {
    iv.iter().map(|&(a, b)| b - a).sum()
}

fn intersect_measure(a: &[(f64, f64)], b: &[(f64, f64)]) -> f64 {
    let (mut i, mut j, mut s) = (0usize, 0usize, 0.0f64);
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            s += hi - lo;
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    s
}

/// Execute a workload graph on the fluid simulator: one continuous
/// timeline, per-node strategy annotations applied at every event
/// boundary, HBM and SDMA-engine occupancy shared across all concurrent
/// nodes. Returns a typed [`Error::SimStall`] (never a panic) when a
/// node cannot finish.
pub fn execute(m: &MachineConfig, topo: &Topology, g: &Graph) -> Result<GraphRun, Error> {
    let n = g.nodes.len();
    assert!(n > 0, "empty workload graph");
    let cus = m.cus_total();

    let mut sim = Sim::new();
    let hbm = sim.add_resource("hbm", m.hbm_bw_achievable());
    let sdma = sim.add_resource("sdma", m.sdma_engines.max(1) as f64);

    let mut queues = 0usize;
    for (i, spec) in g.nodes.iter().enumerate() {
        for &d in spec.issue_deps.iter().chain(spec.serial_deps.iter()) {
            assert!(d < i, "graph edges must point backward (node {i} depends on {d})");
        }
        if let Ready::Queue { queue, .. } = spec.ready {
            queues = queues.max(queue + 1);
        }
        if matches!(spec.ready, Ready::At(_)) {
            assert!(spec.issue_deps.is_empty(), "At-rooted node {i} cannot have issue deps");
        }
    }
    let mut queue_free = vec![0.0f64; queues];

    for (i, spec) in g.nodes.iter().enumerate() {
        let arrival = match spec.ready {
            Ready::At(t) => t,
            _ => 0.0,
        };
        let demands = match &spec.work {
            Work::Gemm(gw) => vec![(hbm, gw.mem.hbm_traffic(m, cus) * gw.frac)],
            Work::Comm(cw) => {
                let mut d = vec![(hbm, cw.hbm)];
                if let CommBackend::Dma { wire, engines } = cw.backend {
                    d.push((sdma, engines * wire));
                }
                d
            }
        };
        let tid = sim.add_task(TaskSpec {
            name: spec.label.clone(),
            arrival,
            work: 1.0,
            demands,
            cap: 0.0,
        });
        debug_assert_eq!(tid, i);
        if let Work::Comm(cw) = &spec.work {
            if let CommBackend::Cu { backlog_until, .. } = cw.backend {
                if backlog_until > 0.0 {
                    sim.schedule_wake(backlog_until);
                }
            }
        }
    }

    let mut finished: Vec<Option<f64>> = vec![None; n];
    let mut reported: Vec<f64> = vec![0.0; n];
    let mut issue: Vec<Option<f64>> = vec![None; n];
    // Resolve ready times of root nodes (dep-gated roots get a wake at
    // their issue time; At-rooted nodes get the Sim arrival event).
    for (i, spec) in g.nodes.iter().enumerate() {
        match spec.ready {
            Ready::At(t) => issue[i] = Some(t),
            _ if spec.issue_deps.is_empty() => {
                let r = ready_time(spec.ready, 0.0, &mut queue_free);
                issue[i] = Some(r);
                sim.schedule_wake(r.max(0.0));
            }
            _ => {}
        }
    }

    let mut done = 0usize;
    // Per-event scratch (reused: this loop is the sweep's hot path).
    let mut running: Vec<bool> = vec![false; n];
    let mut phases: Vec<Option<CommPhase>> = vec![None; n];
    loop {
        let now = sim.now();
        let gemm_unfinished = g
            .nodes
            .iter()
            .zip(finished.iter())
            .any(|(s, f)| matches!(s.work, Work::Gemm(_)) && f.is_none());

        // Which nodes may progress right now.
        for (i, spec) in g.nodes.iter().enumerate() {
            running[i] = if finished[i].is_some() {
                false
            } else {
                match spec.ready {
                    Ready::At(_) => sim.is_active(i),
                    _ => {
                        issue[i].is_some_and(|r| now + ISSUE_EPS >= r)
                            && spec.serial_deps.iter().all(|&d| finished[d].is_some())
                    }
                }
            };
        }

        // Per-collective phase state (CU holds, traffic-rate scale).
        for (i, spec) in g.nodes.iter().enumerate() {
            let Work::Comm(cw) = &spec.work else {
                phases[i] = None;
                continue;
            };
            if finished[i].is_some() {
                phases[i] = Some(CommPhase {
                    moving: false,
                    is_cu: false,
                    holds: 0,
                    scale: 0.0,
                });
                continue;
            }
            let (is_cu, holds) = match cw.backend {
                CommBackend::Cu {
                    backlog_cus,
                    overlap_cus,
                    solo_cus,
                    backlog_until,
                    ..
                } => {
                    let h = if !running[i] {
                        0
                    } else if backlog_until > 0.0 && now < backlog_until && gemm_unfinished {
                        backlog_cus
                    } else if gemm_unfinished {
                        overlap_cus
                    } else {
                        solo_cus
                    };
                    (true, h)
                }
                CommBackend::Dma { .. } => (false, 0),
            };
            let moving = running[i] && (!is_cu || holds > 0);
            let scale = if !moving {
                0.0
            } else if is_cu {
                cw.kernel.bw_scale(m, holds)
            } else {
                1.0
            };
            phases[i] = Some(CommPhase {
                moving,
                is_cu,
                holds,
                scale,
            });
        }
        let held_cus: u32 = phases.iter().flatten().map(|p| p.holds).sum();

        // Compute-node caps.
        for (i, spec) in g.nodes.iter().enumerate() {
            let Work::Gemm(gw) = &spec.work else { continue };
            if finished[i].is_some() {
                continue;
            }
            let g_cus = match gw.cu_policy {
                CuPolicy::Fixed(k) => k,
                CuPolicy::Residual => cus.saturating_sub(held_cus),
            }
            .max(8);
            let t_pure = smoothmax(gw.comp.t_comp(m, g_cus), gw.mem.t_mem(m, g_cus) * gw.frac);
            let mut pol_sum = 0.0;
            let mut share_sum = 0.0;
            for (j, p) in phases.iter().enumerate() {
                let Some(p) = p else { continue };
                if !p.moving {
                    continue;
                }
                let Work::Comm(cw) = &g.nodes[j].work else { unreachable!() };
                match gw.pen_style {
                    PenaltyStyle::RateScaled => {
                        share_sum += cw.share * p.scale;
                        if p.is_cu {
                            pol_sum += cw.pollution * p.scale;
                        }
                    }
                    PenaltyStyle::Aligned(_) => {
                        share_sum += cw.share;
                        if p.is_cu {
                            pol_sum += cw.pollution;
                        }
                    }
                }
            }
            let (pol, mp) = match gw.pen_style {
                PenaltyStyle::RateScaled => (pol_sum, m.mem_pen(share_sum)),
                PenaltyStyle::Aligned(a) => (pol_sum * a, m.mem_pen(share_sum) * a),
            };
            let cap = (1.0 - pol) * (1.0 - mp) / t_pure;
            if matches!(spec.ready, Ready::At(_)) || running[i] {
                sim.set_cap(i, cap);
                sim.set_demand(i, hbm, gw.mem.hbm_traffic(m, g_cus) * gw.frac);
            } else {
                sim.set_cap(i, 0.0);
            }
        }

        // Collective-node caps.
        let mut gshare_sum = 0.0;
        let mut any_gemm_moving = false;
        for (j, spec) in g.nodes.iter().enumerate() {
            if let Work::Gemm(gw) = &spec.work {
                if finished[j].is_none() && running[j] {
                    gshare_sum += gw.share;
                    any_gemm_moving = true;
                }
            }
        }
        for (i, spec) in g.nodes.iter().enumerate() {
            let Work::Comm(cw) = &spec.work else { continue };
            if finished[i].is_some() {
                continue;
            }
            let Some(p) = phases[i] else { unreachable!() };
            let (mp, pen) = match cw.pen_style {
                PenaltyStyle::RateScaled => (
                    m.mem_pen(gshare_sum),
                    if any_gemm_moving { cw.co_penalty } else { 0.0 },
                ),
                PenaltyStyle::Aligned(a) => (
                    m.mem_pen(gshare_sum) * a,
                    if any_gemm_moving { cw.co_penalty * a } else { 0.0 },
                ),
            };
            let cap = match cw.backend {
                CommBackend::Dma { wire, .. } => (1.0 - mp) / wire,
                CommBackend::Cu { wire_fixed, .. } => {
                    if p.holds == 0 {
                        0.0
                    } else {
                        let w = match wire_fixed {
                            Some(w) => w,
                            None => cw.kernel.t_wire_on(m, topo, p.holds),
                        };
                        (1.0 - pen) * (1.0 - mp) / w
                    }
                }
            };
            match spec.ready {
                Ready::At(_) => sim.set_cap(i, cap),
                _ => sim.set_cap(i, if running[i] { cap } else { 0.0 }),
            }
        }

        match sim.next_event() {
            Event::Completion(i) => {
                finished[i] = Some(sim.now());
                reported[i] = sim.now()
                    + match &g.nodes[i].work {
                        Work::Comm(cw) => cw.sync,
                        Work::Gemm(_) => 0.0,
                    };
                done += 1;
                if done == n {
                    break;
                }
                // Resolve newly-unblocked dependents in ascending id
                // order (keeps CPU-queue transactions deterministic).
                for j in (i + 1)..n {
                    let spec_j = &g.nodes[j];
                    if issue[j].is_some()
                        || spec_j.issue_deps.is_empty()
                        || !spec_j.issue_deps.contains(&i)
                        || !spec_j.issue_deps.iter().all(|&d| finished[d].is_some())
                    {
                        continue;
                    }
                    let t_deps = spec_j
                        .issue_deps
                        .iter()
                        .fold(0.0f64, |a, &d| a.max(reported[d]));
                    let r = ready_time(spec_j.ready, t_deps, &mut queue_free);
                    issue[j] = Some(r);
                    sim.schedule_wake(r.max(sim.now()));
                }
            }
            Event::Idle => break,
            _ => {}
        }
    }
    if done < n {
        return Err(Error::SimStall(StallError {
            at: sim.now(),
            stalled: sim.stall_report(),
        }));
    }

    // Aggregate metrics.
    let finish_raw: Vec<f64> = finished.iter().map(|f| f.expect("all nodes finished")).collect();
    let issue_t: Vec<f64> = issue.iter().map(|r| r.unwrap_or(0.0).max(0.0)).collect();
    let total = reported.iter().cloned().fold(0.0, f64::max);
    let mut gemm_finish = 0.0f64;
    let mut comm_finish = 0.0f64;
    let mut gemm_iv = Vec::new();
    let mut comm_iv = Vec::new();
    let mut hbm_bytes = 0.0f64;
    let mut engine_secs = 0.0f64;
    for (i, spec) in g.nodes.iter().enumerate() {
        match &spec.work {
            Work::Gemm(gw) => {
                gemm_finish = gemm_finish.max(reported[i]);
                gemm_iv.push((issue_t[i], finish_raw[i]));
                hbm_bytes += gw.mem.hbm_traffic(m, cus) * gw.frac;
            }
            Work::Comm(cw) => {
                comm_finish = comm_finish.max(reported[i]);
                comm_iv.push((issue_t[i], finish_raw[i]));
                hbm_bytes += cw.hbm;
                if let CommBackend::Dma { wire, engines } = cw.backend {
                    engine_secs += engines * wire;
                }
            }
        }
    }
    let gemm_u = union_intervals(gemm_iv.clone());
    let comm_u = union_intervals(comm_iv.clone());
    let mut all_iv = gemm_iv;
    all_iv.extend(comm_iv);
    let all_u = union_intervals(all_iv);
    let exposed_comm = (measure(&comm_u) - intersect_measure(&comm_u, &gemm_u)).max(0.0);
    let bubble = (total - measure(&all_u)).max(0.0);
    let hbm_occupancy = if total > 0.0 {
        (hbm_bytes / (m.hbm_bw_achievable() * total)).min(1.0)
    } else {
        0.0
    };
    let sdma_occupancy = if total > 0.0 {
        (engine_secs / (m.sdma_engines.max(1) as f64 * total)).min(1.0)
    } else {
        0.0
    };
    Ok(GraphRun {
        issue: issue_t,
        finish: reported,
        total,
        gemm_finish,
        comm_finish,
        exposed_comm,
        bubble,
        hbm_occupancy,
        sdma_occupancy,
    })
}

// ---- graph builders for the legacy timelines ----

/// Build the single-pair graph of one C3 scenario under a whole-kernel
/// strategy — the pre-refactor `C3Executor` timeline as a 2-node graph.
/// The derivations (arrivals, CU phase grants, dispatch backlog, wire
/// times, §VII-A1 shares) are byte-for-byte the legacy executor's, so
/// the engine reproduces its numbers exactly.
pub fn single_pair(
    m: &MachineConfig,
    topo: &Topology,
    sc: &ResolvedScenario,
    strategy: Strategy,
    b: Baselines,
) -> Result<Graph, Error> {
    let cus = m.cus_total();
    let comm_need = sc.comm.cu_need(m);
    let tg_iso = b.t_gemm_iso;

    // Collective backend: typed failure (never a panic) when a
    // non-offloadable collective meets a ConCCL strategy.
    let dma = if strategy.comm_on_cus() {
        None
    } else {
        Some(DmaCollective::try_new(sc.comm.spec)?)
    };

    // Arrival times: who is launched first (stream setup order).
    let (gemm_arrival, comm_arrival) = match strategy {
        Strategy::C3Base | Strategy::C3Rp { .. } => {
            (m.kernel_launch_s, m.kernel_launch_s + m.coll_launch_s)
        }
        Strategy::C3Sp | Strategy::C3SpRp { .. } => {
            (m.coll_launch_s + m.kernel_launch_s, m.coll_launch_s)
        }
        // ConCCL: CPU thread enqueues DMA commands while the GEMM
        // launches; neither waits on the other.
        Strategy::Conccl | Strategy::ConcclRp { .. } => {
            let d = dma.as_ref().expect("conccl strategies carry a DMA collective");
            (m.kernel_launch_s, d.launch_time(m) + m.dma_fetch_s)
        }
        Strategy::Serial => unreachable!("serial handled analytically"),
        Strategy::C3Chunked { .. } | Strategy::ConcclChunked { .. } => {
            unreachable!("chunked strategies route to the chunked graph builder")
        }
    };

    // comm CU grant per phase: (while dispatch-backlogged, while any
    // GEMM is unfinished, after compute drains).
    let (comm_backlog_cus, comm_overlap_cus, comm_solo_cus) = match strategy {
        Strategy::C3Base => (0, m.base_leak_cus.min(comm_need), comm_need),
        Strategy::C3Sp => (comm_need, comm_need, comm_need),
        Strategy::C3Rp { comm_cus } | Strategy::C3SpRp { comm_cus } => {
            let k = comm_cus.min(cus / 2);
            (k, k, k)
        }
        Strategy::Conccl | Strategy::ConcclRp { .. } => (0, 0, 0),
        Strategy::Serial => unreachable!(),
        Strategy::C3Chunked { .. } | Strategy::ConcclChunked { .. } => unreachable!(),
    };
    // Dispatch backlog applies only to c3_base (FIFO dispatch) and only
    // when the GEMM's grid saturates the machine.
    let backlog_until = match strategy {
        Strategy::C3Base if sc.gemm.workgroups(m) > cus as u64 => {
            comm_arrival + m.base_dispatch_backlog * tg_iso
        }
        _ => 0.0,
    };
    // GEMM CU policy (§VI-G: conccl_rp removes CUs only when the
    // one-time CU-loss slowdown table predicts a cache speedup).
    let cu_policy = match strategy {
        Strategy::C3Rp { comm_cus } | Strategy::C3SpRp { comm_cus } => {
            CuPolicy::Fixed(cus - comm_cus.min(cus / 2))
        }
        Strategy::ConcclRp { cus_removed } => {
            let r = cus_removed.min(cus / 2);
            if !sc.gemm.is_compute_bound(m) && sc.gemm.slowdown_with_cu_loss(m, r) < 1.0 {
                CuPolicy::Fixed(cus - r)
            } else {
                CuPolicy::Fixed(cus)
            }
        }
        Strategy::Conccl => CuPolicy::Fixed(cus),
        _ => CuPolicy::Residual,
    };

    let pollution = if strategy.comm_on_cus() {
        m.l2_pollution(sc.comm.spec.kind)
    } else {
        0.0
    };
    let co_penalty = m.comm_co_penalty(sc.comm.spec.kind);
    let comm_hbm = match &dma {
        Some(d) => d.hbm_traffic(m),
        None => sc.comm.hbm_traffic(m),
    };
    let gemm_share = sc.gemm.hbm_share(m, cus);
    // DMA wire duration is loop-invariant (and on multi-node topologies
    // pricing it rebuilds the hierarchical plan) — computed once here.
    let dma_wire = dma.as_ref().map(|d| d.wire_time_on(m, topo));
    let comm_share = {
        let t_wire = match dma_wire {
            Some(wire) => wire,
            None => sc.comm.t_wire_on(m, topo, comm_need.max(1)),
        };
        sc.comm.hbm_share_with_wire(m, t_wire)
    };

    let mut g = Graph::default();
    g.push(NodeSpec {
        label: format!("gemm:{}", sc.scenario.gemm_tag),
        work: Work::Gemm(GemmWork {
            comp: sc.gemm.clone(),
            mem: sc.gemm.clone(),
            frac: 1.0,
            share: gemm_share,
            cu_policy,
            pen_style: PenaltyStyle::RateScaled,
        }),
        issue_deps: Vec::new(),
        serial_deps: Vec::new(),
        ready: Ready::At(gemm_arrival),
    });
    let backend = match dma_wire {
        Some(wire) => CommBackend::Dma {
            wire,
            engines: engine_demand(m),
        },
        None => CommBackend::Cu {
            backlog_cus: comm_backlog_cus,
            overlap_cus: comm_overlap_cus,
            solo_cus: comm_solo_cus,
            backlog_until,
            wire_fixed: None,
        },
    };
    g.push(NodeSpec {
        label: format!("comm:{}", sc.comm.spec.kind.name()),
        work: Work::Comm(CommWork {
            kernel: sc.comm,
            backend,
            hbm: comm_hbm,
            share: comm_share,
            pollution,
            co_penalty,
            sync: if dma.is_some() { m.dma_sync_s } else { 0.0 },
            pen_style: PenaltyStyle::RateScaled,
        }),
        issue_deps: Vec::new(),
        serial_deps: Vec::new(),
        ready: Ready::At(comm_arrival),
    });
    Ok(g)
}

/// Build the k-chunk fine-grain pipeline graph of one C3 scenario —
/// the pre-refactor `sched::pipeline` timeline as a 2k-node graph
/// (GEMM chunk chain + issue-gated collective chunk chain). The
/// derivations are the legacy pipeline's, so the engine reproduces its
/// numbers exactly.
pub fn chunked(
    m: &MachineConfig,
    topo: &Topology,
    sc: &ResolvedScenario,
    cu_backend: bool,
    k: u32,
) -> Result<Graph, Error> {
    let cus = m.cus_total();
    let comm_need = sc.comm.cu_need(m);

    // Effective chunk count: never more chunks than the scenario
    // supports (the executor pre-clamps; stay defensive).
    let kk = k.max(2).min(sc.chunk_cap(m)).max(1) as usize;
    let align = m.chunk_align(kk as u32);

    let gemm_chunks: Vec<GemmKernel> = sc.gemm.split_m(m, kk as u32);
    debug_assert_eq!(gemm_chunks.len(), kk);
    // Memory-side chunk pricing is prorated from the whole kernel (the
    // LLC keeps its panel working set across chunk boundaries); only
    // the compute side re-quantizes its waves.
    let whole_flops = sc.gemm.shape.flops();
    let g_frac: Vec<f64> = gemm_chunks
        .iter()
        .map(|c| c.shape.flops() / whole_flops)
        .collect();
    let comm_specs: Vec<CollectiveSpec> = chunk_sizes(sc.comm.spec.size_bytes, kk as u32)
        .into_iter()
        .map(|s| CollectiveSpec::new(sc.comm.spec.kind, s))
        .collect();

    // Backend: typed failure (never a panic) when a non-offloadable
    // collective meets the DMA pipeline.
    let dma: Option<Vec<DmaCollective>> = if cu_backend {
        None
    } else {
        Some(
            comm_specs
                .iter()
                .map(|&s| DmaCollective::try_new(s))
                .collect::<Result<Vec<_>, Error>>()?,
        )
    };

    // Per-chunk wire times and HBM demands are loop-invariant.
    let wire: Vec<f64> = match &dma {
        Some(ds) => ds.iter().map(|d| d.wire_time_on(m, topo)).collect(),
        None => comm_specs
            .iter()
            .map(|&s| CollectiveKernel::new(s).t_wire_on(m, topo, comm_need.max(1)))
            .collect(),
    };
    let comm_hbm: Vec<f64> = comm_specs
        .iter()
        .map(|&s| CollectiveKernel::new(s).hbm_traffic(m))
        .collect();

    let gemm_share = sc.gemm.hbm_share(m, cus);
    let comm_share = {
        let whole_wire = match &dma {
            Some(_) => DmaCollective::try_new(sc.comm.spec)?.wire_time_on(m, topo),
            None => sc.comm.t_wire_on(m, topo, comm_need.max(1)),
        };
        sc.comm.hbm_share_with_wire(m, whole_wire)
    };
    let pollution = if cu_backend {
        m.l2_pollution(sc.comm.spec.kind)
    } else {
        0.0
    };
    let co_penalty = m.comm_co_penalty(sc.comm.spec.kind);
    let clamped_need = comm_need.min(cus / 2);
    let dma_launch = m.num_gpus as f64 * m.dma_enqueue_s;

    let mut g = Graph::default();
    // GEMM chunk chain first (node ids 0..kk, matching the legacy task
    // order), then the collective chunk chain (kk..2kk).
    for (i, gk) in gemm_chunks.iter().enumerate() {
        g.push(NodeSpec {
            label: format!("gemm:{}", gk.tag),
            work: Work::Gemm(GemmWork {
                comp: gk.clone(),
                mem: sc.gemm.clone(),
                frac: g_frac[i],
                share: gemm_share,
                cu_policy: CuPolicy::Residual,
                pen_style: PenaltyStyle::Aligned(align),
            }),
            issue_deps: if i == 0 { Vec::new() } else { vec![i - 1] },
            serial_deps: Vec::new(),
            ready: Ready::AfterDeps {
                lag: m.kernel_launch_s,
            },
        });
    }
    for (i, &spec) in comm_specs.iter().enumerate() {
        let backend = if cu_backend {
            CommBackend::Cu {
                backlog_cus: 0,
                overlap_cus: clamped_need,
                solo_cus: clamped_need,
                backlog_until: 0.0,
                wire_fixed: Some(wire[i]),
            }
        } else {
            CommBackend::Dma {
                wire: wire[i],
                engines: engine_demand(m),
            }
        };
        g.push(NodeSpec {
            label: format!("comm:{}#{i}", spec.kind.name()),
            work: Work::Comm(CommWork {
                kernel: CollectiveKernel::new(spec),
                backend,
                hbm: comm_hbm[i],
                share: comm_share,
                pollution,
                co_penalty,
                sync: if dma.is_some() { m.dma_sync_s } else { 0.0 },
                pen_style: PenaltyStyle::Aligned(align),
            }),
            issue_deps: vec![i],
            serial_deps: if i == 0 { Vec::new() } else { vec![kk + i - 1] },
            ready: if cu_backend {
                Ready::AfterDeps {
                    lag: m.coll_launch_s,
                }
            } else {
                Ready::Queue {
                    queue: 0,
                    hold: dma_launch,
                    post: m.dma_fetch_s,
                }
            },
        });
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_rel_close;
    use crate::config::workload::CollectiveKind;
    use crate::util::units::MIB;

    fn m() -> MachineConfig {
        MachineConfig::mi300x()
    }

    fn dma_node(m: &MachineConfig, topo: &Topology, bytes: u64, label: &str) -> NodeSpec {
        let spec = CollectiveSpec::new(CollectiveKind::AllGather, bytes);
        let d = DmaCollective::try_new(spec).unwrap();
        let wire = d.wire_time_on(m, topo);
        NodeSpec {
            label: label.to_string(),
            work: Work::Comm(CommWork {
                kernel: CollectiveKernel::new(spec),
                backend: CommBackend::Dma {
                    wire,
                    engines: engine_demand(m),
                },
                hbm: d.hbm_traffic(m),
                share: CollectiveKernel::new(spec).hbm_share_with_wire(m, wire),
                pollution: 0.0,
                co_penalty: m.comm_co_penalty(spec.kind),
                sync: 0.0,
                pen_style: PenaltyStyle::RateScaled,
            }),
            issue_deps: Vec::new(),
            serial_deps: Vec::new(),
            ready: Ready::At(0.0),
        }
    }

    #[test]
    fn single_dma_collective_is_never_engine_bound() {
        // The sdma fluid resource must not change a lone collective's
        // time: its own rate cap binds first (min(num_gpus, engines)
        // occupancy against the full engine pool).
        let m = m();
        let topo = Topology::fully_connected(m.num_gpus);
        let spec = CollectiveSpec::new(CollectiveKind::AllGather, 896 * MIB);
        let wire = DmaCollective::try_new(spec).unwrap().wire_time_on(&m, &topo);
        let mut g = Graph::default();
        g.push(dma_node(&m, &topo, 896 * MIB, "ag"));
        let r = execute(&m, &topo, &g).unwrap();
        assert_rel_close!(r.finish[0], wire, 1e-9);
        // Even with fewer engines than peers the demand is clamped to
        // the pool, so a lone collective still finishes at its wire time.
        let mut small = m.clone();
        small.sdma_engines = 3;
        let mut g2 = Graph::default();
        g2.push(dma_node(&small, &topo, 896 * MIB, "ag"));
        let r2 = execute(&small, &topo, &g2).unwrap();
        let wire2 = DmaCollective::try_new(spec).unwrap().wire_time_on(&small, &topo);
        assert_rel_close!(r2.finish[0], wire2, 1e-9);
    }

    #[test]
    fn concurrent_dma_collectives_contend_for_engines() {
        // The satellite regression test: two concurrent DMA collectives
        // on one GPU demand 2×8 = 16 engine-occupancy units against the
        // machine's 14 SDMA engines, so max-min sharing slows each to
        // 14/16 of its solo rate (finish stretches by 16/14).
        let m = m();
        let topo = Topology::fully_connected(m.num_gpus);
        let spec = CollectiveSpec::new(CollectiveKind::AllGather, 896 * MIB);
        let wire = DmaCollective::try_new(spec).unwrap().wire_time_on(&m, &topo);
        let mut g = Graph::default();
        g.push(dma_node(&m, &topo, 896 * MIB, "ag0"));
        g.push(dma_node(&m, &topo, 896 * MIB, "ag1"));
        let r = execute(&m, &topo, &g).unwrap();
        let expect = wire * 16.0 / 14.0;
        assert_rel_close!(r.finish[0], expect, 1e-9);
        assert_rel_close!(r.finish[1], expect, 1e-9);
        assert!(r.sdma_occupancy > 0.9, "both collectives near-saturate the engines");
        // Three concurrent collectives contend harder still.
        let mut g3 = Graph::default();
        for i in 0..3 {
            g3.push(dma_node(&m, &topo, 896 * MIB, &format!("ag{i}")));
        }
        let r3 = execute(&m, &topo, &g3).unwrap();
        assert_rel_close!(r3.finish[0], wire * 24.0 / 14.0, 1e-9);
    }

    #[test]
    fn queue_serializes_issue() {
        // Two queue-issued DMA chunks at t=0: the second's ready time
        // pays both enqueue batches on the shared CPU thread.
        let m = m();
        let topo = Topology::fully_connected(m.num_gpus);
        let hold = m.num_gpus as f64 * m.dma_enqueue_s;
        let mut g = Graph::default();
        for i in 0..2 {
            let mut n = dma_node(&m, &topo, 64 * MIB, &format!("c{i}"));
            n.ready = Ready::Queue {
                queue: 0,
                hold,
                post: m.dma_fetch_s,
            };
            g.push(n);
        }
        let r = execute(&m, &topo, &g).unwrap();
        assert_rel_close!(r.issue[0], hold + m.dma_fetch_s, 1e-12);
        assert_rel_close!(r.issue[1], 2.0 * hold + m.dma_fetch_s, 1e-12);
        assert!(r.finish[1] > r.finish[0]);
    }

    #[test]
    fn unsatisfiable_node_is_a_typed_stall() {
        // A CU collective with zero CU grants in every phase can never
        // progress: the engine surfaces Error::SimStall, never a panic.
        let m = m();
        let topo = Topology::fully_connected(m.num_gpus);
        let spec = CollectiveSpec::new(CollectiveKind::AllGather, MIB);
        let mut g = Graph::default();
        g.push(NodeSpec {
            label: "starved".into(),
            work: Work::Comm(CommWork {
                kernel: CollectiveKernel::new(spec),
                backend: CommBackend::Cu {
                    backlog_cus: 0,
                    overlap_cus: 0,
                    solo_cus: 0,
                    backlog_until: 0.0,
                    wire_fixed: None,
                },
                hbm: 0.0,
                share: 0.0,
                pollution: 0.0,
                co_penalty: 0.0,
                sync: 0.0,
                pen_style: PenaltyStyle::RateScaled,
            }),
            issue_deps: Vec::new(),
            serial_deps: Vec::new(),
            ready: Ready::At(0.0),
        });
        let err = execute(&m, &topo, &g).unwrap_err();
        assert!(matches!(err, Error::SimStall(_)), "{err}");
    }

    #[test]
    fn interval_helpers_measure_correctly() {
        let u = union_intervals(vec![(0.0, 1.0), (0.5, 2.0), (3.0, 4.0)]);
        assert_eq!(u, vec![(0.0, 2.0), (3.0, 4.0)]);
        assert!((measure(&u) - 3.0).abs() < 1e-12);
        let a = union_intervals(vec![(0.0, 2.0)]);
        let b = union_intervals(vec![(1.0, 3.0)]);
        assert!((intersect_measure(&a, &b) - 1.0).abs() < 1e-12);
    }
}
